"""Shared fixtures.  NOTE: XLA_FLAGS / 512-device forcing is deliberately
NOT set here — smoke tests and benches see the real (1-device) host; only
launch/dryrun.py forces placeholder devices (per the assignment).

Also provides a guarded ``hypothesis`` import: test modules do

    from conftest import given, settings, st

and get the real hypothesis API when it is installed, or skip-stubs when it
is not — so every module collects (and its non-property tests run) on hosts
without hypothesis.
"""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        """Stub @given: replace the property test with a zero-arg skipper
        (a plain function, so pytest never tries to resolve the strategy
        parameters as fixtures)."""
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _AnyStrategy:
        """st.integers(...), st.sampled_from(...), ... — decoration-time
        placeholders; the wrapped test is skipped before they are drawn."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def tol_for(dtype):
    import jax.numpy as jnp
    return {"float32": dict(rtol=2e-3, atol=2e-3),
            "bfloat16": dict(rtol=5e-2, atol=5e-2)}[jnp.dtype(dtype).name]
