"""Shared fixtures.  NOTE: XLA_FLAGS / 512-device forcing is deliberately
NOT set here — smoke tests and benches see the real (1-device) host; only
launch/dryrun.py forces placeholder devices (per the assignment)."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def tol_for(dtype):
    import jax.numpy as jnp
    return {"float32": dict(rtol=2e-3, atol=2e-3),
            "bfloat16": dict(rtol=5e-2, atol=5e-2)}[jnp.dtype(dtype).name]
