"""End-to-end behaviour tests for the paper's system."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=900):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    res = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert res.returncode == 0, f"\nSTDOUT:{res.stdout}\nSTDERR:{res.stderr}"
    return res.stdout


def test_train_driver_loss_decreases(tmp_path):
    out = _run(["repro.launch.train", "--arch", "llama3-8b", "--reduced",
                "--steps", "40", "--batch", "8", "--seq", "64",
                "--lr", "3e-3", "--ckpt-dir", str(tmp_path),
                "--ckpt-every", "20", "--log-every", "20"])
    assert "improved" in out and "NOT improved" not in out


def test_train_driver_resume(tmp_path):
    _run(["repro.launch.train", "--arch", "mamba2-370m", "--reduced",
          "--steps", "10", "--batch", "4", "--seq", "32",
          "--ckpt-dir", str(tmp_path), "--ckpt-every", "5"])
    out = _run(["repro.launch.train", "--arch", "mamba2-370m", "--reduced",
                "--steps", "15", "--batch", "4", "--seq", "32",
                "--ckpt-dir", str(tmp_path), "--resume"])
    assert "resumed from step 10" in out


def test_serve_driver():
    out = _run(["repro.launch.serve", "--arch", "granite-moe-1b-a400m",
                "--reduced", "--batch", "2", "--prompt-len", "16",
                "--gen", "8"])
    assert "ms/tok" in out


def test_elastic_checkpoint_remesh(tmp_path):
    """A checkpoint saved unsharded restores onto a different topology."""
    from repro.checkpoint import CheckpointManager
    from repro.launch.elastic import RemeshPlan
    mgr = CheckpointManager(str(tmp_path))
    state = {"params": {"w": jnp.arange(32.0).reshape(4, 8)}}
    mgr.save(3, state)
    restored, _ = mgr.restore(state)      # same-host restore
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    plan = RemeshPlan.plan(False, True)   # 256 -> 512 chips
    assert plan.batch_ratio == 2.0


def test_straggler_detection():
    from repro.launch.elastic import StepTimer
    t = StepTimer(window=20, ratio=2.0)
    t.times = [0.1] * 18 + [0.5, 0.6]
    assert t.straggling
    t.times = [0.1] * 20
    assert not t.straggling


def test_googlenet_scheduler_beats_serial():
    """The paper's headline behaviour on its own network."""
    from repro.configs import get_config
    from repro.core import compare_policies
    from repro.models.cnn import build_graph
    g = build_graph(get_config("googlenet"), batch=32)
    res = compare_policies(g)
    assert res["speedup"] > 1.05
    co = [grp for grp in res["concurrent"].groups if len(grp.ops) > 1]
    assert len(co) >= 9   # at least one co-exec group per inception module


def test_dryrun_artifacts_complete():
    """Every (arch x shape x mesh) cell the assignment requires has a
    passing dry-run record (produced by launch/dryrun.py)."""
    d = os.path.join(REPO, "results", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run results not generated in this environment")
    from repro.configs import ARCHS, get_config
    missing, failed = [], []
    for arch in (a for a in ARCHS if a != "googlenet"):
        cfg = get_config(arch)
        shapes = ["train_4k", "prefill_32k", "decode_32k"] + \
            (["long_500k"] if cfg.sub_quadratic else [])
        for shape in shapes:
            for mesh in ("single", "multi"):
                p = os.path.join(d, f"{arch}__{shape}__{mesh}.json")
                if not os.path.exists(p):
                    missing.append((arch, shape, mesh))
                    continue
                rec = json.load(open(p))
                if not rec.get("ok"):
                    failed.append((arch, shape, mesh))
    assert not missing, f"missing cells: {missing}"
    assert not failed, f"failed cells: {failed}"


def test_hlo_analyzer_against_xla_on_unrolled():
    """The while-corrected analyzer agrees with XLA cost_analysis when
    there are no loops (exactness check)."""
    from repro.roofline import analyze_hlo, xla_cost_analysis

    def unrolled(w, x):
        for i in range(4):
            x = jnp.tanh(x @ w[i])
        return x

    w = jax.ShapeDtypeStruct((4, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    c = jax.jit(unrolled).lower(w, x).compile()
    mine = analyze_hlo(c.as_text()).flops
    xla = xla_cost_analysis(c)["flops"]
    assert abs(mine - xla) / xla < 0.05


def test_hlo_analyzer_corrects_scan_undercount():
    from repro.roofline import analyze_hlo, xla_cost_analysis

    def scanned(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        return jax.lax.scan(body, x, w)[0]

    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    c = jax.jit(scanned).lower(w, x).compile()
    mine = analyze_hlo(c.as_text()).flops
    xla = xla_cost_analysis(c)["flops"]
    assert mine > 7 * xla / 8 * 7      # ~8x the single-body count
    assert abs(mine - 8 * 2 * 64 * 128 * 128) / mine < 0.1
