"""Cross-module streaming (ISSUE 6): the chained grouped launch, the
chain-lowering pass, launch-count pins on googlenet, the partial shared-X
dedup, and the layout-pass hygiene (zero gather/concat in the counted
trace) the single-digit-launch claim rests on."""
import dataclasses
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tol_for
from repro.configs.googlenet import CONFIG as GOOGLENET, reduced
from repro.core import launch_count as lc
from repro.core import plan as planlib
from repro.core.plan import OpImpl
from repro.kernels import ops as kops
from repro.models import cnn as CNN
from repro.models.cnn import CNNConfig, InceptionSpec

gmm = importlib.import_module("repro.kernels.grouped_matmul")

# The ceilings scripts/ci.sh gates on (keep in sync with ci.sh): the
# chained googlenet forward must stay single-digit-launch territory
# counting EVERY surviving launch-like primitive, the default plan's
# pallas count is its 21-kernel structure plus one slack.
LAUNCH_CEILING_CHAINED_FWD = 12
LAUNCH_CEILING_UNCHAINED_PALLAS = 22


# ---------------------------------------------------------------------------
# kernel-level: one hand-built 2-phase chain vs the tap-shift reference
# ---------------------------------------------------------------------------

def _tap_rows(wmat, kh, kw, dh, dw):
    return jax.lax.slice(wmat, (dh * kw + dw, 0), wmat.shape, (kh * kw, 1))


def _chain_reference(x0, w0, b0, wmat, b1, m, h, w):
    """Phase 0 dense GEMM -> phase 1 in-launch 3x3 ring conv, as plain
    differentiable jnp (shift-tap semantics == SAME conv, zero borders)."""
    y0 = jnp.maximum(x0 @ w0 + b0, 0.0)
    acc = b1.astype(jnp.float32)
    for dh in range(3):
        for dw in range(3):
            sh = gmm._shift_spatial(y0, m, h, w, dh - 1, dw - 1)
            acc = acc + sh @ _tap_rows(wmat, 3, 3, dh, dw)
    return y0, jnp.maximum(acc, 0.0)


def _chain_phases(x0, w0, b0, wmat, b1):
    return [
        [{"n": w0.shape[1], "w": planlib._pad_w_dense(w0, 128), "b": b0,
          "src": ("x", [x0]), "ring_write": (0,)}],
        [{"n": wmat.shape[1],
          "w": planlib._pack_w_ring(wmat, 3, 3, w0.shape[1], 1, 128),
          "b": b1, "src": ("ring", 3, 3, (0,)), "ring_write": None}],
    ]


def _chain_fixture(dtype=jnp.float32):
    b, h, w = 2, 8, 8
    m = b * h * w
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x0 = jax.random.normal(ks[0], (m, 64), dtype) * 0.3
    w0 = jax.random.normal(ks[1], (64, 48), dtype) * 0.3
    b0 = jax.random.normal(ks[2], (48,), dtype)
    wmat = jax.random.normal(ks[3], (48 * 9, 40), dtype) * 0.1
    b1 = jax.random.normal(ks[4], (40,), dtype)
    return (x0, w0, b0, wmat, b1), m, h, w


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chained_kernel_matches_reference(dtype):
    args, m, h, w = _chain_fixture(dtype)
    x0, w0, b0, wmat, b1 = args
    outs = kops.grouped_matmul_chained(_chain_phases(*args), m=m, h=h, w=w,
                                       interpret=True)
    refs = kops.grouped_matmul_chained_ref(_chain_phases(*args), m=m, h=h,
                                           w=w)
    y0, y1 = _chain_reference(*(a.astype(jnp.float32) for a in args), m, h, w)
    tol = tol_for(dtype)
    for got in (outs, refs):
        np.testing.assert_allclose(np.asarray(got[0][:m, :48], np.float32),
                                   np.asarray(y0, np.float32), **tol)
        np.testing.assert_allclose(np.asarray(got[1][:m, :40], np.float32),
                                   np.asarray(y1, np.float32), **tol)
        # padding columns are part of the panel contract: exactly zero
        assert not np.asarray(got[0][:m, 48:]).any()
        assert not np.asarray(got[1][:m, 40:]).any()


def test_chained_kernel_gradients_match_reference():
    args, m, h, w = _chain_fixture()

    def f_kernel(*a):
        outs = kops.grouped_matmul_chained(_chain_phases(*a), m=m, h=h, w=w,
                                           interpret=True)
        wt0 = jnp.arange(1, m * 48 + 1, dtype=jnp.float32).reshape(m, 48)
        wt1 = jnp.arange(1, m * 40 + 1, dtype=jnp.float32).reshape(m, 40)
        return (outs[0][:m, :48] * wt0).sum() + (outs[1][:m, :40] * wt1).sum()

    def f_ref(*a):
        y0, y1 = _chain_reference(*a, m, h, w)
        wt0 = jnp.arange(1, m * 48 + 1, dtype=jnp.float32).reshape(m, 48)
        wt1 = jnp.arange(1, m * 40 + 1, dtype=jnp.float32).reshape(m, 40)
        return (y0 * wt0).sum() + (y1 * wt1).sum()

    gk = jax.grad(f_kernel, argnums=(0, 1, 2, 3, 4))(*args)
    gr = jax.grad(f_ref, argnums=(0, 1, 2, 3, 4))(*args)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# plan-level: chained vs unchained equivalence (value AND gradient)
# ---------------------------------------------------------------------------

def _batch(cfg, n, dtype=jnp.float32, seed=1):
    r = np.random.RandomState(seed)
    return {"images": jnp.asarray(r.randn(n, *cfg.img), dtype),
            "labels": jnp.asarray(r.randint(0, cfg.num_classes, n))}


STRIDED = dataclasses.replace(
    GOOGLENET, name="tiny-strided", img=(16, 16, 3),
    stem=((3, 16, 2), (1, 16, 1)),
    modules=(InceptionSpec(8, 12, 16, 4, 8, 8),),
    pool_between=(), num_classes=5)


@pytest.mark.parametrize("cfg,dtype", [
    (reduced(), jnp.float32),
    (reduced(), jnp.bfloat16),
    (STRIDED, jnp.float32),
])
def test_chained_plan_forward_matches_unchained(cfg, dtype):
    params = CNN.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype)
    batch = _batch(cfg, 2, dtype)
    plan_c, _ = CNN.plan_cnn(cfg, batch=2, chain_modules=True)
    plan_u, _ = CNN.plan_cnn(cfg, batch=2)
    assert any(g.mode == "grouped_chained" for g in plan_c.groups), \
        [g.mode for g in plan_c.groups]
    yc = CNN.forward_plan(params, cfg, batch["images"], plan_c)
    yu = CNN.forward_plan(params, cfg, batch["images"], plan_u)
    np.testing.assert_allclose(np.asarray(yc, np.float32),
                               np.asarray(yu, np.float32), **tol_for(dtype))


@pytest.mark.parametrize("cfg", [reduced(), STRIDED])
def test_chained_plan_gradcheck(cfg):
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 2)
    plan_c, _ = CNN.plan_cnn(cfg, batch=2, chain_modules=True, train=True)
    plan_u, _ = CNN.plan_cnn(cfg, batch=2, train=True)
    vc, gc = jax.value_and_grad(
        lambda p: CNN.loss_fn(p, cfg, batch, plan=plan_c)[0])(params)
    vu, gu = jax.value_and_grad(
        lambda p: CNN.loss_fn(p, cfg, batch, plan=plan_u)[0])(params)
    assert abs(float(vc) - float(vu)) < 1e-5
    errs = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()), gc, gu)
    assert max(jax.tree.leaves(errs)) < 1e-4, errs


# ---------------------------------------------------------------------------
# googlenet: launch-count pins + modeled-makespan ordering
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def googlenet_plans():
    plan_c, _ = CNN.plan_cnn(GOOGLENET, batch=2, chain_modules=True,
                             train=True)
    plan_u, _ = CNN.plan_cnn(GOOGLENET, batch=2, train=True)
    return plan_c, plan_u


def test_googlenet_launch_pins(googlenet_plans):
    """Per-direction traced-jaxpr launch counts: the chained plan's
    forward is 10 launches TOTAL (1 stem chain + 9 module chains, zero
    surviving concat/conv/reduce_window), under the CI ceiling; the
    backward adds one combined launch per chain phase; and the chained
    trace is strictly cheaper than the default plan in both directions."""
    plan_c, plan_u = googlenet_plans
    params = CNN.init_params(GOOGLENET, jax.random.PRNGKey(0))
    batch = _batch(GOOGLENET, 2)

    def loss(plan):
        return lambda p, b: CNN.loss_fn(p, GOOGLENET, b, plan=plan)[0]

    fwd_c = lc.count_launches(loss(plan_c), params, batch)
    assert fwd_c["total"] == fwd_c["pallas_call"] == 10, fwd_c
    assert fwd_c["total"] <= LAUNCH_CEILING_CHAINED_FWD
    fwd_u = lc.count_launches(loss(plan_u), params, batch)
    assert fwd_u["pallas_call"] <= LAUNCH_CEILING_UNCHAINED_PALLAS, fwd_u

    both_c = lc.count_grad_launches(loss(plan_c), params, batch)
    both_u = lc.count_grad_launches(loss(plan_u), params, batch)
    # 10 forward + ONE combined bwd launch per chain phase (3 stem + 9x2)
    assert both_c["pallas_call"] == 31, both_c
    assert both_c["total"] < both_u["total"], (both_c, both_u)
    assert fwd_c["total"] < fwd_u["total"], (fwd_c, fwd_u)


def test_googlenet_chained_modeled_makespan_beats_unchained(googlenet_plans):
    plan_c, plan_u = googlenet_plans
    assert plan_c.makespan < plan_u.makespan, \
        (plan_c.makespan, plan_u.makespan)
    bwd_c = plan_c.context["backward"]
    bwd_u = plan_u.context["backward"]
    assert bwd_c.makespan < bwd_u.makespan, (bwd_c.makespan, bwd_u.makespan)


def test_googlenet_chained_plan_shape(googlenet_plans):
    """1 three-phase stem chain + 9 two-phase module chains; the grad plan
    mirrors every chain with reversed phases."""
    plan_c, _ = googlenet_plans
    chains = [g for g in plan_c.groups if g.mode == "grouped_chained"]
    assert len(chains) == 10
    phase_shapes = sorted(tuple(len(p) for p in g.chain) for g in chains)
    assert phase_shapes.count((1, 1, 1)) == 1     # the absorbed stem
    assert phase_shapes.count((4, 2)) == 9        # the inception modules
    bwd = plan_c.context["backward"]
    gchains = [g for g in bwd.groups if g.mode == "grouped_chained"]
    assert len(gchains) == 10
    for g in gchains:
        assert all(n.startswith("grad:") for ph in g.chain for n in ph)


# ---------------------------------------------------------------------------
# layout-pass hygiene: the counted-primitive-free decompositions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chain", [((3, 1),), ((3, 2),), ((3, 2), (3, 1))])
def test_pool_tap_views_trace_is_clean(chain):
    """Strided tap views must lower to pad+slice — jnp's strided getitem
    gathers (with a concatenate-built index grid) and one counted
    primitive per tap would void the chained launch ceiling."""
    x = jnp.ones((2, 14, 14, 4))
    counts = lc.count_launches(
        lambda a: gmm.pool_from_taps(gmm.pool_tap_views(a, chain)), x)
    assert counts["total"] == 0, counts


@pytest.mark.parametrize("dh,dw", [(0, 0), (1, -1), (-1, 1), (1, 1)])
def test_shift_spatial_matches_roll_reference(dh, dw):
    b, h, w, c = 2, 5, 4, 3
    m = b * h * w
    x = jnp.asarray(np.random.RandomState(0).randn(m, c), jnp.float32)
    got = np.asarray(gmm._shift_spatial(x, m, h, w, dh, dw))
    img = np.asarray(x).reshape(b, h, w, c)
    want = np.zeros_like(img)
    for i in range(h):
        for j in range(w):
            if 0 <= i + dh < h and 0 <= j + dw < w:
                want[:, i, j] = img[:, i + dh, j + dw]
    np.testing.assert_array_equal(got, want.reshape(m, c))
    counts = lc.count_launches(
        lambda a: gmm._shift_spatial(a, m, h, w, dh, dw), x)
    assert counts["total"] == 0, counts


# ---------------------------------------------------------------------------
# partial shared-X dedup (satellite): bucketing + numerics
# ---------------------------------------------------------------------------

def _impl(deps, key, k):
    return OpImpl(deps=deps, fn=lambda *a: None, gemm_x=lambda *a: a,
                  gemm_x_key=key, gemm_w=np.zeros((k, 4), np.float32))


def test_dedup_buckets_partial():
    """The inception shape: three branches share (deps, x-key, K) and
    bucket into one wide sub-GEMM; the pooled branch (different absorbed
    pool) and the different-K branch stay ragged singletons."""
    impls = {"a": _impl(("x",), "relu:x", 8),
             "b": _impl(("x",), "relu:x", 8),
             "c": _impl(("x",), "relu:x", 8),
             "p": _impl(("x",), "relu:x", 8),
             "q": _impl(("x",), "relu:x", 16)}
    buckets = planlib._dedup_buckets(
        impls, ["a", "b", "p", "c", "q"], {"p": ((3, 1),)})
    assert buckets == [["a", "b", "c"], ["p"], ["q"]]


def test_dedup_buckets_none_key_never_buckets():
    impls = {"a": _impl(("x",), None, 8), "b": _impl(("x",), None, 8)}
    assert planlib._dedup_buckets(impls, ["a", "b"], {}) == [["a"], ["b"]]


def test_grouped_forward_matches_eager_with_dedup():
    """The always-on partial dedup inside _run_grouped must not change the
    unchained plan's numerics (reduced googlenet, plan vs eager)."""
    cfg = reduced()
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, 2)
    plan_u, _ = CNN.plan_cnn(cfg, batch=2)
    yp = CNN.forward_plan(params, cfg, batch["images"], plan_u)
    ye = CNN.forward(params, cfg, batch["images"])
    np.testing.assert_allclose(np.asarray(yp), np.asarray(ye),
                               rtol=2e-4, atol=2e-4)
