"""Per-arch smoke tests (reduced configs): forward / loss / decode, no NaNs.

The FULL configs are exercised only by the dry-run (per assignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_reduced
from repro.models import cnn as CNN
from repro.models import transformer as T

LM_ARCHS = [a for a in ARCHS if a != "googlenet"]


def _batch(cfg, b=2, s=32):
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "patch":
        batch["extra_embeds"] = 0.02 * jax.random.normal(
            key, (b, cfg.frontend_len, cfg.d_model))
    elif cfg.frontend == "frame":
        batch["extra_embeds"] = 0.02 * jax.random.normal(
            key, (b, cfg.enc_context_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_reduced_forward_and_loss(arch):
    cfg = get_reduced(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = T.forward(params, cfg, batch["tokens"],
                            extra_embeds=batch.get("extra_embeds"))
    exp_s = batch["tokens"].shape[1] + (
        cfg.frontend_len if cfg.frontend == "patch" else 0)
    assert logits.shape == (2, exp_s, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, parts = T.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss)) and float(loss) > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_reduced_train_step_improves(arch):
    from repro.launch import steps as ST
    cfg = get_reduced(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = ST.make_optimizer(cfg)
    opt = type(opt)(**{**opt.__dict__, "lr": 5e-3, "warmup": 1, "total": 10})
    state = opt.init(params)
    step = jax.jit(ST.make_train_step(cfg, opt, remat=False))
    batch = _batch(cfg)
    losses = []
    for _ in range(8):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], losses   # memorizes one batch


@pytest.mark.parametrize("arch", ["llama3_8b", "gemma2_27b", "mamba2_370m",
                                  "jamba_1_5_large_398b", "whisper_tiny",
                                  "granite_moe_1b_a400m"])
def test_decode_matches_forward(arch):
    """Prefill + incremental decode logits == full forward logits."""
    cfg = get_reduced(arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 24
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab)
    extra = ctx = None
    if cfg.enc_dec:
        extra = 0.02 * jax.random.normal(key, (b, cfg.enc_context_len,
                                               cfg.d_model))
        ctx = T._encoder(cfg, params, extra)
    full, _ = T.forward(params, cfg, toks, extra_embeds=extra)

    cache = T.init_cache(cfg, b, s, dtype=jnp.float32)
    half = s // 2
    _, cache = T.prefill(params, cfg, toks[:, :half], cache,
                         extra_embeds=extra)
    logits_steps = []
    for i in range(half, s):
        lg, cache = T.decode_step(params, cfg, cache, toks[:, i:i + 1],
                                  jnp.int32(i), context=ctx)
        logits_steps.append(lg[:, 0])
    got = jnp.stack(logits_steps, axis=1)          # (B, s-half, V)
    want = full[:, half:s]
    np.testing.assert_allclose(
        jax.nn.log_softmax(got.astype(jnp.float32)),
        jax.nn.log_softmax(want.astype(jnp.float32)), rtol=2e-2, atol=2e-2)


def test_cnn_smoke():
    cfg = get_reduced("googlenet")
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2, *cfg.img))
    logits = CNN.forward(params, cfg, imgs)
    assert logits.shape == (2, cfg.num_classes)
    assert bool(jnp.isfinite(logits).all())
    # kernel-backed forward (paper path) matches XLA forward
    algs, sch = CNN.schedule_algorithms(cfg, batch=2)
    logits2 = CNN.forward(params, cfg, imgs, algorithms=algs)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2),
                               rtol=5e-3, atol=5e-3)


def test_param_counts_match_published():
    from repro.configs import get_config
    expect = {"jamba_1_5_large_398b": 398e9, "llama3_8b": 8.0e9,
              "gemma2_27b": 27.2e9, "mamba2_370m": 0.37e9,
              "codeqwen1_5_7b": 7.8e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.06, (arch, got, n)
