"""Fused epilogue-concat (single-launch inception modules): kernel
equivalence, the ONE combined dx/dw/db backward launch, join-absorption
lowering, cost-model concat pricing, and full fused-plan gradchecks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels as K
from repro.core import (Op, OpGraph, OpImpl, backward_plan, concat_profile,
                        group_execution_time, group_execution_time_bwd,
                        lower, profile, run_plan, serial_time)
from repro.core.scheduler import CoGroup, Schedule
from repro.kernels import ops as kops
from repro.models import cnn as CNN
from repro.models.cnn import CNNConfig, InceptionSpec

# ragged branch sets: aligned, unaligned, K-ragged, singleton, quad
RAGGED_SETS = [
    [(128, 128), (128, 128)],
    [(100, 60), (300, 129), (64, 16)],
    [(64, 384), (192, 32)],
    [(130, 250)],
    [(64, 96), (64, 16), (576, 208), (400, 48)],
]


def _branches(m, shapes, dtype, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3 * len(shapes))
    xs = [jax.random.normal(ks[3 * i], (m, kg), dtype) * 0.3
          for i, (kg, _) in enumerate(shapes)]
    ws = [jax.random.normal(ks[3 * i + 1], (kg, ng), dtype) * 0.3
          for i, (kg, ng) in enumerate(shapes)]
    bs = [jax.random.normal(ks[3 * i + 2], (ng,), dtype)
          for i, (_, ng) in enumerate(shapes)]
    return xs, ws, bs


def _layout(shapes, gap_after=None, lead=0):
    """Concat layout: branch offsets (optionally a passthrough gap after
    branch ``gap_after`` and a leading passthrough segment)."""
    offs, off = [], lead
    for i, (_, n) in enumerate(shapes):
        offs.append(off)
        off += n
        if gap_after == i:
            off += 37   # unaligned passthrough hole
    return offs, off


# ---------------------------------------------------------------------------
# kernel equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shapes", RAGGED_SETS)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 5e-2)])
def test_concat_kernel_matches_reference(shapes, dtype, tol):
    """Branch slices land at their true (unaligned) offsets in the join
    buffer, bias+ReLU fused, vs the per-branch XLA scatter oracle."""
    xs, ws, bs = _branches(77, shapes, dtype)
    offs, total = _layout(shapes, gap_after=0, lead=19)
    got = kops.grouped_matmul_concat(xs, ws, bs, offsets=offs, total=total,
                                     relu=True)
    want = K.grouped_matmul_concat_ref(xs, ws, bs, offsets=offs,
                                       total=total, relu=True)
    assert got.shape == (77, total) and got.dtype == dtype
    for off, (_, n) in zip(offs, shapes):
        np.testing.assert_allclose(
            np.asarray(got[:, off:off + n], np.float32),
            np.asarray(want[:, off:off + n], np.float32),
            rtol=tol, atol=tol)


def test_concat_kernel_no_bias_no_relu_and_jit():
    shapes = [(100, 60), (300, 129), (64, 16)]
    xs, ws, _ = _branches(50, shapes, jnp.float32)
    offs, total = _layout(shapes)
    got = jax.jit(lambda xs, ws: kops.grouped_matmul_concat(
        xs, ws, offsets=offs, total=total))(xs, ws)
    want = K.grouped_matmul_concat_ref(xs, ws, offsets=offs, total=total)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shapes", RAGGED_SETS)
@pytest.mark.parametrize("masked", [False, True])
def test_combined_bwd_kernel_matches_reference(shapes, masked):
    """ONE launch computes dx/dw/db for the whole ragged branch set, with
    the ReLU cotangent mask folded into the dY packing."""
    xs, ws, _ = _branches(77, shapes, jnp.float32)
    ks = jax.random.split(jax.random.PRNGKey(9), 2 * len(shapes))
    dys = [jax.random.normal(ks[2 * i], (77, n), jnp.float32)
           for i, (_, n) in enumerate(shapes)]
    mask = [jax.random.normal(ks[2 * i + 1], (77, n), jnp.float32)
            for i, (_, n) in enumerate(shapes)] if masked else None
    dxs, dws, dbs = kops.grouped_matmul_bwd(xs, ws, dys, mask)
    rxs, rws, rbs = K.grouped_matmul_bwd_ref(xs, ws, dys, mask)
    for a, b in zip(list(dxs) + list(dws) + list(dbs),
                    list(rxs) + list(rws) + list(rbs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_concat_vjp_is_one_combined_launch():
    """Forward: one concat launch.  Pullback: exactly ONE combined
    backward kernel — the launch count the plan's grad CoGroups ride."""
    shapes = [(100, 60), (300, 129), (64, 16)]
    xs, ws, bs = _branches(64, shapes, jnp.float32)
    offs, total = _layout(shapes)

    def loss(xs, ws, bs):
        y = kops.grouped_matmul_concat(xs, ws, bs, offsets=offs,
                                       total=total, relu=True)
        return (y * y).sum()

    kops.reset_launch_counts()
    jax.grad(loss, argnums=(0, 1, 2))(xs, ws, bs)
    assert kops.KERNEL_LAUNCHES.get("grouped_matmul_concat") == 1
    assert kops.KERNEL_LAUNCHES.get("grouped_matmul_bwd") == 1
    assert "grouped_matmul_dw" not in kops.KERNEL_LAUNCHES

    # plain grouped pullback is a single combined launch too (was two)
    kops.reset_launch_counts()
    jax.grad(lambda xs, ws, bs: sum(
        (y * y).sum() for y in K.grouped_matmul(xs, ws, bs, relu=True)),
        argnums=(0, 1, 2))(xs, ws, bs)
    assert kops.KERNEL_LAUNCHES.get("grouped_matmul_bwd") == 1


def test_concat_vjp_matches_reference_grads():
    shapes = [(64, 96), (64, 16), (576, 208)]
    xs, ws, bs = _branches(33, shapes, jnp.float32)
    offs, total = _layout(shapes, gap_after=1)

    def loss(xs, ws, bs):
        y = kops.grouped_matmul_concat(xs, ws, bs, offsets=offs,
                                       total=total, relu=True)
        sl = [y[:, o:o + n] for o, (_, n) in zip(offs, shapes)]
        return sum((s * s * jnp.cos(s)).sum() for s in sl)

    def loss_ref(xs, ws, bs):
        ys = K.grouped_matmul_ref(xs, ws, bs, relu=True)
        return sum((s * s * jnp.cos(s)).sum() for s in ys)

    got = jax.grad(loss, argnums=(0, 1, 2))(xs, ws, bs)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(xs, ws, bs)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# cost model: the concat term
# ---------------------------------------------------------------------------

def test_concat_pricing_no_longer_free():
    """The join's read+write is an explicit term: unfused grouped + its
    standalone join price ABOVE the fused epilogue-concat group, whose
    rider covers only the passthrough columns — the modeled win the
    benchmark's fused_concat column shows."""
    m = 512
    ops = [Op.make("a", "matmul", m=m, k=864, n=384),
           Op.make("b", "matmul", m=m, k=200, n=64)]
    join = Op.make("j", "pointwise", elements=m * 880)
    profs = [profile(op, "mxu128") for op in ops]
    mode_u, t_u = group_execution_time(ops, profs)
    assert mode_u == "grouped"
    mode_f, t_f = group_execution_time(ops, profs, join=join)
    assert mode_f == "grouped_concat"
    t_join = serial_time([profile(join, "vpu")])
    assert t_f < t_u + t_join
    # the rider prices exactly the passthrough columns' copy traffic
    own = m * (384 + 64)
    rider = concat_profile(join, m * 880 - own)
    assert rider.hbm_bytes == 2 * (m * 880 - own) * join.dtype_bytes
    assert rider.flops == 0.0
    # full standalone concat: both sides of the join's element count
    assert concat_profile(join).hbm_bytes == 2 * m * 880 * join.dtype_bytes

    # backward: combined launch + sliced cotangent beats the unfused
    # two-step (grouped bwd + standalone split)
    mode_b, t_b = group_execution_time_bwd(
        ops, mode="grouped_concat", join=join)
    assert mode_b == "grouped_concat"
    _, t_bu = group_execution_time_bwd(ops, mode="grouped")
    from repro.core import backward_profiles
    t_split = sum(p.time for p in backward_profiles(join, "vpu"))
    assert t_b < t_bu + t_split


# ---------------------------------------------------------------------------
# lowering: join absorption
# ---------------------------------------------------------------------------

def _fork_join_graph():
    g = OpGraph()
    g.add(Op.make("src", "pointwise", elements=256 * 128))
    g.add(Op.make("a", "matmul", m=256, k=128, n=384), ["src"])
    g.add(Op.make("b", "matmul", m=256, k=128, n=32), ["src"])
    g.add(Op.make("j", "pointwise", elements=256 * 416), ["a", "b"])
    sch = Schedule([CoGroup(["src"], {"src": "vpu"}, 0.0),
                    CoGroup(["a", "b"], {"a": "mxu128", "b": "mxu128"}, 1.0),
                    CoGroup(["j"], {"j": "vpu"}, 0.0)])
    return g, sch


def test_lower_absorbs_join_into_grouped():
    g, sch = _fork_join_graph()
    plan = lower(g, sch)
    assert [gr.mode for gr in plan.groups] == ["serial", "grouped_concat"]
    cg = plan.groups[1]
    assert cg.join == "j" and cg.ops == ("a", "b", "j")
    assert set(plan.algorithms) == set(g.ops)          # join alg survives
    # backward mirror: one grouped_concat grad group
    bwd = backward_plan(g, plan)
    assert bwd.groups[0].mode == "grouped_concat"
    assert bwd.groups[0].join == "grad:j"
    # opting out keeps the standalone join
    plan_u = lower(g, sch, fuse_concat=False)
    assert [gr.mode for gr in plan_u.groups] == ["serial", "grouped",
                                                 "serial"]
    assert plan.makespan < plan_u.makespan


def test_lower_skips_absorption_with_outside_consumer():
    """A branch consumed by anything besides the join keeps the
    standalone concat (its output must materialize anyway)."""
    g = OpGraph()
    g.add(Op.make("src", "pointwise", elements=256 * 128))
    g.add(Op.make("a", "matmul", m=256, k=128, n=384), ["src"])
    g.add(Op.make("b", "matmul", m=256, k=128, n=32), ["src"])
    g.add(Op.make("j", "pointwise", elements=256 * 416), ["a", "b"])
    g.add(Op.make("tap", "pointwise", elements=256 * 384), ["a"])
    sch = Schedule([CoGroup(["src"], {"src": "vpu"}, 0.0),
                    CoGroup(["a", "b"], {"a": "mxu128", "b": "mxu128"}, 1.0),
                    CoGroup(["j"], {"j": "vpu"}, 0.0),
                    CoGroup(["tap"], {"tap": "vpu"}, 0.0)])
    plan = lower(g, sch)
    assert "grouped_concat" not in plan.mode_counts()


def test_run_plan_grouped_concat_with_passthrough():
    """Executor: the concat group assembles the join from its own kernel
    slices plus a passthrough segment produced by an earlier op."""
    g = OpGraph()
    g.add(Op.make("src", "pointwise", elements=64 * 128))
    g.add(Op.make("p", "matmul", m=64, k=128, n=48), ["src"])
    # ragged widths (384 vs 33): stacked would pay pad-to-max, so the
    # pair lowers grouped — the mode absorption requires
    g.add(Op.make("a", "matmul", m=64, k=128, n=384), ["src"])
    g.add(Op.make("b", "matmul", m=64, k=128, n=33), ["src"])
    g.add(Op.make("j", "pointwise", elements=64 * 465), ["p", "a", "b"])
    sch = Schedule([CoGroup(["src"], {"src": "vpu"}, 0.0),
                    CoGroup(["p"], {"p": "mxu128"}, 0.0),
                    CoGroup(["a", "b"], {"a": "mxu128", "b": "mxu128"}, 1.0),
                    CoGroup(["j"], {"j": "vpu"}, 0.0)])
    plan = lower(g, sch)
    (cg,) = [gr for gr in plan.groups if gr.mode == "grouped_concat"]
    assert cg.join == "j"
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (64, 128), jnp.float32) * 0.2
    wp = jax.random.normal(ks[1], (128, 48), jnp.float32) * 0.2
    wa = jax.random.normal(ks[2], (128, 384), jnp.float32) * 0.2
    wb = jax.random.normal(ks[3], (128, 33), jnp.float32) * 0.2

    def mk(w, relu=True):
        return OpImpl(
            deps=("src",),
            fn=lambda x, algorithm=None, w=w: jax.nn.relu(x @ w),
            gemm_x=lambda x: x, gemm_w=w,
            gemm_post=lambda y: jax.nn.relu(y),
            gemm_bias=jnp.zeros((w.shape[1],), jnp.float32),
            gemm_relu=True, gemm_reshape=lambda y: y)

    impls = {
        "src": OpImpl(deps=("x0",), fn=lambda x, algorithm=None: x),
        "p": mk(wp), "a": mk(wa), "b": mk(wb),
        "j": OpImpl(deps=("p", "a", "b"),
                    fn=lambda *ys, algorithm=None: jnp.concatenate(
                        ys, axis=-1),
                    gemm_reshape=lambda y2d: y2d),
    }
    env = run_plan(impls, {"x0": x}, plan)
    want = jnp.concatenate([jax.nn.relu(x @ w) for w in (wp, wa, wb)],
                           axis=-1)
    np.testing.assert_allclose(np.asarray(env["j"]), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    # in-launch branch outputs are never materialized standalone
    assert "a" not in env and "b" not in env and "p" in env

    # missing split epilogue -> graceful per-op degrade, same value
    impls_nofuse = dict(impls)
    impls_nofuse["a"] = dataclasses.replace(impls["a"], gemm_bias=None)
    env2 = run_plan(impls_nofuse, {"x0": x}, plan)
    np.testing.assert_allclose(np.asarray(env2["j"]), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# full fused plans: gradcheck vs the XLA reference
# ---------------------------------------------------------------------------

def _cfgs():
    # sized so the 3x3/5x5 pair still wins co-execution under the
    # gemm_shape-based pricing (an 8x8 module is too small for the
    # scheduler's 2% improvement bar — the pair would run serial)
    return {
        # strided stem + one ragged module (unpooled)
        "strided": CNNConfig(name="t1", img=(12, 12, 3),
                             stem=((3, 12, 2),),
                             modules=(InceptionSpec(16, 12, 24, 4, 8, 8),),
                             pool_between=(), num_classes=5),
        # two modules with an inter-module maxpool (pooled path: the
        # second module's branches — and its join — read pooled input,
        # and the whole quad absorbs the inter-module pool)
        "pooled": CNNConfig(name="t2", img=(16, 16, 3), stem=((3, 16, 1),),
                            modules=(InceptionSpec(16, 16, 32, 4, 8, 8),
                                     InceptionSpec(16, 16, 32, 4, 8, 8)),
                            pool_between=(1,), num_classes=5),
    }


@pytest.mark.parametrize("which", ["strided", "pooled"])
@pytest.mark.parametrize("dtype,rtol,atol", [
    (jnp.float32, 2e-3, 2e-3),
    (jnp.bfloat16, 1e-1, 1e-1),
])
def test_fused_plan_gradcheck_vs_xla(which, dtype, rtol, atol):
    """jax.grad through the FUSED plan (epilogue-concat forward, ONE
    combined backward launch per grad CoGroup) against autodiff of the
    plain XLA forward — ragged widths, strides, pooled and unpooled
    modules, f32 and bf16."""
    cfg = _cfgs()[which]
    plan, _ = CNN.plan_cnn(cfg, batch=2)
    assert plan.mode_counts().get("grouped_concat", 0) >= 1
    assert not [g for g in plan.groups
                if g.mode != "grouped_concat"
                and any(n.endswith("/join") for n in g.ops)]
    params = CNN.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype)
    batch = {"images": jax.random.normal(jax.random.PRNGKey(1),
                                         (2, *cfg.img), dtype),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2,), 0,
                                          cfg.num_classes)}
    (lp, _), gp = jax.value_and_grad(CNN.loss_fn, has_aux=True)(
        params, cfg, batch, plan=plan)
    (l0, _), g0 = jax.value_and_grad(CNN.loss_fn, has_aux=True)(
        params, cfg, batch)
    np.testing.assert_allclose(float(lp), float(l0), rtol=max(rtol, 1e-4))
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(gp)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=rtol, atol=atol)


def test_fused_plan_under_jit():
    """jit(value_and_grad) on a full fused plan — the train driver's
    exact path (PR 3 showed eager gradchecks can mask jit-linearize
    failures)."""
    cfg = _cfgs()["pooled"]
    plan, _ = CNN.plan_cnn(cfg, batch=2, train=True)
    assert plan.mode_counts().get("grouped_concat", 0) >= 1
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"images": jax.random.normal(jax.random.PRNGKey(1),
                                         (2, *cfg.img), jnp.float32),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2,), 0,
                                          cfg.num_classes)}
    vg = jax.value_and_grad(CNN.loss_fn, has_aux=True)
    (lj, _), gj = jax.jit(lambda p: vg(p, cfg, batch, plan=plan))(params)
    (le, _), ge = vg(params, cfg, batch, plan=plan)
    np.testing.assert_allclose(float(lj), float(le), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(ge), jax.tree.leaves(gj)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
