"""Conv2D algorithm zoo vs lax.conv oracle (the paper's core op)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels as K
from repro.kernels import ref
from conftest import tol_for

CASES = [
    # (n, h, w, c, kh, kw, k, stride, padding)
    (2, 16, 16, 32, 3, 3, 64, 1, "SAME"),
    (2, 15, 15, 16, 3, 3, 24, 1, "SAME"),
    (1, 16, 16, 8, 5, 5, 16, 1, "SAME"),
    (2, 16, 16, 8, 3, 3, 16, 2, "SAME"),
    (1, 14, 14, 8, 1, 1, 16, 1, "VALID"),
    (1, 16, 16, 8, 3, 3, 16, 1, "VALID"),
    (1, 28, 28, 192, 1, 1, 64, 1, "SAME"),      # inception 3a 1x1
    (1, 8, 8, 4, 7, 7, 8, 2, "SAME"),           # stem-style
]


@pytest.mark.parametrize("alg", K.CONV2D_ALGORITHMS)
@pytest.mark.parametrize("case", CASES)
def test_conv2d_algorithms(alg, case):
    n, h, w, c, kh, kw, k, s, pad = case
    if not K.conv2d_supported(alg, kh, kw, s):
        pytest.skip(f"{alg} unsupported for this input (cuDNN Table-2 "
                    "footnote analogue)")
    kx, kw_ = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31))
    x = jax.random.normal(kx, (n, h, w, c), jnp.float32)
    wgt = jax.random.normal(kw_, (kh, kw, c, k), jnp.float32) * 0.1
    got = K.conv2d(x, wgt, stride=s, padding=pad, algorithm=alg)
    want = ref.conv2d_ref(x, wgt, stride=s, padding=pad)
    tol = dict(rtol=5e-3, atol=5e-3) if alg == "winograd3x3" \
        else tol_for(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)


def test_conv2d_bf16():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 16, 16), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 16, 32), jnp.bfloat16) * 0.1
    for alg in K.CONV2D_ALGORITHMS:
        got = K.conv2d(x, w, algorithm=alg)
        want = ref.conv2d_ref(x, w)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   **tol_for(jnp.bfloat16))


def test_workspace_ordering_matches_paper_table2():
    """im2col (PRECOMP_GEMM analogue) >> winograd > direct == 0 workspace."""
    xs, ws = (32, 28, 28, 256), (3, 3, 256, 128)
    im2col = K.conv2d_workspace_bytes("im2col_gemm", xs, ws)
    wino = K.conv2d_workspace_bytes("winograd3x3", xs, ws)
    direct = K.conv2d_workspace_bytes("direct", xs, ws)
    assert im2col > 0 and wino > 0 and direct == 0
    assert im2col > wino  # 9x patch duplication vs 16/4 tile transform
