"""The execution-plan layer: lowering modes + plan execution correctness."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_reduced
from repro.core import Op, OpGraph, OpImpl, execute_plan, lower, run_plan, \
    schedule
from repro.core.scheduler import CoGroup, Schedule
from repro.models import cnn as CNN

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# lowering: mode per group shape
# ---------------------------------------------------------------------------

def test_lower_googlenet_mode_mix():
    """The acceptance shape: every inception CoGroup lowers to a real
    co-execution mode — ragged branch sets (and the im2col-viewed
    3x3/5x5 pairs) go grouped, uniform-shape quads stay stacked, and no
    group falls back to XLA interleaving."""
    plan, _ = CNN.plan_cnn(get_config("googlenet"), batch=32)
    modes = plan.mode_counts()
    # two grouped-family launches per inception module: the pooled quad
    # and the join-absorbing pair — and zero standalone pooling groups
    assert modes.get("grouped", 0) + modes.get("grouped_concat", 0) \
        + modes.get("grouped_pooled", 0) >= 18, modes
    assert modes.get("grouped_concat", 0) == 9, modes
    assert modes.get("grouped_pooled", 0) == 9, modes
    assert modes.get("xla", 0) == 0, modes
    for g in plan.groups:
        if len(g.ops) > 1:
            assert g.mode in ("grouped", "grouped_concat",
                              "grouped_pooled", "stacked"), g
            # a join rides a multi-op group only as an absorbed concat
            if g.mode == "grouped_concat":
                assert g.join and g.join in g.ops, g
            else:
                assert all("join" not in n for n in g.ops)
        assert all(not n.endswith("/pool") and not n.endswith("/pppool")
                   for n in g.ops), g
    # the schedule's algorithm choices survive lowering (absorbed pool
    # ops keep their entries on the absorbing groups)
    assert set(plan.algorithms) == set(
        CNN.build_graph(get_config("googlenet"), 32).ops)


def test_lower_fused_pair_mode():
    """A compute-bound GEMM + memory-bound pointwise pair lowers to the
    fused co-execution kernel."""
    g = OpGraph()
    g.add(Op.make("gemm", "matmul", m=1024, k=2048, n=1024))
    g.add(Op.make("red", "pointwise", elements=1 << 22))
    cg = CoGroup(["gemm", "red"], {"gemm": "mxu128", "red": "vpu"}, 1.0)
    plan = lower(g, Schedule([cg]))
    assert plan.groups[0].mode == "fused", plan.groups[0]


def test_lower_infeasible_budget_falls_back_to_serial():
    """Paper C2: a group whose combined footprint exceeds the budget is
    demoted to serial execution."""
    cfg = get_reduced("googlenet")
    g = CNN.build_graph(cfg, 2)
    sch = schedule(g)
    assert any(len(cg.ops) > 1 for cg in sch.groups)
    plan = lower(g, sch, vmem_budget=1.0)
    assert plan.mode_counts() == {"serial": len(plan.groups)}
    assert any("C2" in grp.reason for grp in plan.groups
               if len(grp.ops) > 1)
    # and end-to-end: planning under a tiny budget never packs at all
    plan2, _ = CNN.plan_cnn(cfg, 2, hbm_budget=1.0, vmem_budget=1.0)
    assert set(plan2.mode_counts()) == {"serial"}


def test_plan_makespan_and_algorithms_consistency():
    cfg = get_reduced("googlenet")
    plan, sch = CNN.plan_cnn(cfg, batch=2)
    assert plan.makespan > 0
    assert plan.algorithms == sch.algorithms
    # every absorbed join collapses its singleton group into the
    # grouped_concat launch, every absorbed maxpool its reduce_window
    # group into the consuming launch; nothing else changes group count
    absorbed = plan.mode_counts().get("grouped_concat", 0)
    g = CNN.build_graph(cfg, 2)
    n_pools = sum(1 for op in g.ops.values() if op.kind == "maxpool")
    assert len(plan.groups) == len(sch.groups) - absorbed - n_pools
    assert absorbed == len(cfg.modules)
    plan_u, sch_u = CNN.plan_cnn(cfg, batch=2, fuse_concat=False,
                                 fuse_pool=False)
    assert len(plan_u.groups) == len(sch_u.groups)


# ---------------------------------------------------------------------------
# execution: plan output == serial XLA forward
# ---------------------------------------------------------------------------

def test_execute_plan_matches_forward():
    """2-module GoogleNet slice (googlenet-reduced), fp32 interpret mode:
    the planned execution path is the same function as the plain forward."""
    cfg = get_reduced("googlenet")
    plan, _ = CNN.plan_cnn(cfg, batch=2)
    assert plan.mode_counts().get("grouped_pooled", 0) >= 1
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, *cfg.img), jnp.float32)
    want = CNN.forward(params, cfg, x)
    got = execute_plan(params, x, plan)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # jitted too (the path launch/train.py runs)
    got_jit = jax.jit(lambda p, x: execute_plan(p, x, plan))(params, x)
    np.testing.assert_allclose(np.asarray(got_jit), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_execute_serial_plan_matches_forward():
    """concurrent=False lowers to all-serial groups whose algorithms match
    the legacy schedule_algorithms path."""
    cfg = get_reduced("googlenet")
    plan, _ = CNN.plan_cnn(cfg, batch=2, concurrent=False)
    assert set(plan.mode_counts()) == {"serial"}
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, *cfg.img), jnp.float32)
    want = CNN.forward(params, cfg, x)
    got = execute_plan(params, x, plan)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_plan_train_step_grads_match_unplanned():
    cfg = get_reduced("googlenet")
    plan, _ = CNN.plan_cnn(cfg, batch=2)
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"images": jax.random.normal(jax.random.PRNGKey(1),
                                         (2, *cfg.img), jnp.float32),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2,), 0,
                                          cfg.num_classes)}
    (lp, _), gp = jax.value_and_grad(CNN.loss_fn, has_aux=True)(
        params, cfg, batch, plan=plan)
    (l0, _), g0 = jax.value_and_grad(CNN.loss_fn, has_aux=True)(
        params, cfg, batch)
    assert abs(float(lp) - float(l0)) < 1e-4
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(gp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_run_plan_fused_group_matches_oracles():
    g = OpGraph()
    g.add(Op.make("gemm", "matmul", m=1024, k=2048, n=1024))
    g.add(Op.make("red", "pointwise", elements=1 << 22))
    cg = CoGroup(["gemm", "red"], {"gemm": "mxu128", "red": "vpu"}, 1.0)
    plan = lower(g, Schedule([cg]))
    assert plan.groups[0].mode == "fused"
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, (1024, 2048), jnp.float32) * 0.05
    w = jax.random.normal(k2, (2048, 1024), jnp.float32) * 0.05
    z = jax.random.normal(k3, (1 << 14, 256), jnp.float32)
    impls = {
        "gemm": OpImpl(deps=("xin",), fn=lambda x, algorithm=None: x @ w,
                       gemm_x=lambda x: x, gemm_w=w,
                       gemm_post=lambda y: y),
        "red": OpImpl(deps=("zin",),
                      fn=lambda z, algorithm=None: jax.nn.silu(z).sum(0),
                      stream_z=lambda z: z, stream_post=lambda r: r),
    }
    env = run_plan(impls, {"xin": x, "zin": z}, plan)
    np.testing.assert_allclose(np.asarray(env["gemm"]), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(env["red"]),
                               np.asarray(jax.nn.silu(z).sum(0)),
                               rtol=1e-4, atol=1e-3)


def test_run_plan_fused_group_trainable():
    """Plans with fused groups differentiate: the fused kernel's custom
    VJP routes the backward pass through XLA (like stacked/conv)."""
    g = OpGraph()
    g.add(Op.make("gemm", "matmul", m=1024, k=2048, n=1024))
    g.add(Op.make("red", "pointwise", elements=1 << 22))
    cg = CoGroup(["gemm", "red"], {"gemm": "mxu128", "red": "vpu"}, 1.0)
    plan = lower(g, Schedule([cg]))
    assert plan.groups[0].mode == "fused"
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, (256, 256), jnp.float32) * 0.1
    w = jax.random.normal(k2, (256, 256), jnp.float32) * 0.1
    z = jax.random.normal(k3, (512, 128), jnp.float32)
    impls = {
        "gemm": OpImpl(deps=("xin",), fn=lambda x, algorithm=None: x @ w,
                       gemm_x=lambda x: x, gemm_w=w,
                       gemm_post=lambda y: y),
        "red": OpImpl(deps=("zin",),
                      fn=lambda z, algorithm=None: jax.nn.silu(z).sum(0),
                      stream_z=lambda z: z, stream_post=lambda r: r),
    }

    def loss(x, z):
        env = run_plan(impls, {"xin": x, "zin": z}, plan)
        return env["gemm"].sum() + env["red"].sum()

    def loss_ref(x, z):
        return (x @ w).sum() + jax.nn.silu(z).sum()

    lp, (gx, gz) = jax.value_and_grad(loss, argnums=(0, 1))(x, z)
    l0, (gx0, gz0) = jax.value_and_grad(loss_ref, argnums=(0, 1))(x, z)
    np.testing.assert_allclose(float(lp), float(l0), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx0),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gz), np.asarray(gz0),
                               rtol=1e-4, atol=1e-4)


def test_run_plan_falls_back_without_gemm_views():
    """lower() picks modes from the graph alone, so fn-only OpImpl
    bindings (the model-agnostic run_plan path) must degrade a stacked
    group to the per-op path — and pre-seeded env values must survive."""
    g = OpGraph()
    g.add(Op.make("m0", "matmul", m=256, k=256, n=256))
    g.add(Op.make("m1", "matmul", m=256, k=256, n=256))
    cg = CoGroup(["m0", "m1"], {"m0": "mxu128", "m1": "mxu128"}, 1.0)
    plan = lower(g, Schedule([cg]))
    assert plan.groups[0].mode == "stacked"
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, (256, 256), jnp.float32) * 0.1
    w0 = jax.random.normal(k2, (256, 256), jnp.float32) * 0.1
    w1 = jax.random.normal(k3, (256, 256), jnp.float32) * 0.1
    impls = {
        "m0": OpImpl(deps=("xin",), fn=lambda x, algorithm=None: x @ w0),
        "m1": OpImpl(deps=("xin",), fn=lambda x, algorithm=None: x @ w1),
    }
    env = run_plan(impls, {"xin": x}, plan)
    np.testing.assert_allclose(np.asarray(env["m0"]), np.asarray(x @ w0),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(env["m1"]), np.asarray(x @ w1),
                               rtol=1e-5, atol=1e-5)
    sentinel = jnp.zeros((256, 256), jnp.float32)
    env2 = run_plan(impls, {"xin": x, "m0": sentinel}, plan)
    np.testing.assert_array_equal(np.asarray(env2["m0"]),
                                  np.asarray(sentinel))
    np.testing.assert_allclose(np.asarray(env2["m1"]), np.asarray(x @ w1),
                               rtol=1e-5, atol=1e-5)


def test_run_plan_spatial_group_multichip():
    """Spatial lowering + execution on a forced 8-device host (subprocess,
    like tests/test_sharding.py)."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import Op, OpGraph, OpImpl, lower, run_plan
    from repro.core.scheduler import CoGroup, Schedule
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("model",))
    g = OpGraph()
    g.add(Op.make("src", "pointwise", elements=16 * 130 * 3))
    # 3x3 convs with identical output shapes but DIFFERENT weights: not
    # stackable (kh != 1), same-output -> spatial
    for i in range(4):
        g.add(Op.make(f"b{i}", "conv2d", n=16, h=8, w=8, c=3, kh=3, kw=3,
                      k=8, stride=1), ["src"])
    cg = CoGroup([f"b{i}" for i in range(4)],
                 {f"b{i}": "im2col_gemm" for i in range(4)}, 1.0)
    plan = lower(g, Schedule([CoGroup(["src"], {"src": "vpu"}, 0.0), cg]),
                 mesh=mesh)
    assert [gr.mode for gr in plan.groups] == ["serial", "spatial"], plan
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 8, 8, 3))
    ws = [jax.random.normal(jax.random.PRNGKey(i + 1), (3, 3, 3, 8)) * 0.2
          for i in range(4)]
    from repro.kernels import ref as k_ref
    impls = {"src": OpImpl(deps=("x0",),
                           fn=lambda x, algorithm=None: jnp.tanh(x))}
    for i in range(4):
        impls[f"b{i}"] = OpImpl(
            deps=("src",),
            fn=lambda x, algorithm=None, w=ws[i]: k_ref.conv2d_ref(
                x, w, stride=1, padding="SAME"))
    env = run_plan(impls, {"x0": x}, plan, mesh=mesh)
    for i in range(4):
        want = k_ref.conv2d_ref(jnp.tanh(x), ws[i], stride=1,
                                padding="SAME")
        np.testing.assert_allclose(np.asarray(env[f"b{i}"]),
                                   np.asarray(want), rtol=1e-5, atol=1e-5)
    print("spatial plan ok")
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert res.returncode == 0, f"\nSTDOUT:{res.stdout}\nSTDERR:{res.stderr}"
    assert "spatial plan ok" in res.stdout
