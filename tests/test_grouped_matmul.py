"""Grouped ragged branch GEMM: kernel equivalence, VJP, lowering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels as K
from repro.configs import get_config
from repro.core import Op, OpGraph, OpImpl, gemm_shape, lower, run_plan
from repro.core.scheduler import CoGroup, Schedule
from repro.models import cnn as CNN

# ragged (K_g, N_g) branch sets: aligned, unaligned, K-ragged, N-ragged,
# singleton, and an inception-like quad
RAGGED_SETS = [
    [(128, 128), (128, 128)],
    [(100, 60), (300, 129), (64, 16)],
    [(256, 128), (128, 128), (128, 128), (128, 128)],
    [(64, 384), (192, 32)],
    [(130, 250)],
    [(64, 96), (64, 16), (576, 208), (400, 48)],
]


def _branches(m, shapes, dtype, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3 * len(shapes))
    xs = [jax.random.normal(ks[3 * i], (m, kg), dtype) * 0.3
          for i, (kg, _) in enumerate(shapes)]
    ws = [jax.random.normal(ks[3 * i + 1], (kg, ng), dtype) * 0.3
          for i, (kg, ng) in enumerate(shapes)]
    bs = [jax.random.normal(ks[3 * i + 2], (ng,), dtype)
          for i, (_, ng) in enumerate(shapes)]
    return xs, ws, bs


@pytest.mark.parametrize("shapes", RAGGED_SETS)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 5e-2)])
def test_grouped_matches_per_branch_reference(shapes, dtype, tol):
    """Ragged widths, fused bias+ReLU epilogue, vs per-branch XLA GEMMs."""
    xs, ws, bs = _branches(77, shapes, dtype)
    got = K.grouped_matmul(xs, ws, bs, relu=True)
    want = K.grouped_matmul_ref(xs, ws, bs, relu=True)
    for y, yw, (kg, ng) in zip(got, want, shapes):
        assert y.shape == (77, ng) and y.dtype == dtype
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yw, np.float32),
                                   rtol=tol, atol=tol)


def test_grouped_no_bias_no_relu_and_jit():
    shapes = [(100, 60), (300, 129), (64, 16)]
    xs, ws, _ = _branches(50, shapes, jnp.float32)
    got = jax.jit(lambda xs, ws: K.grouped_matmul(xs, ws))(xs, ws)
    for y, yw in zip(got, K.grouped_matmul_ref(xs, ws)):
        np.testing.assert_allclose(np.asarray(y), np.asarray(yw),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shapes", RAGGED_SETS)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 6e-2)])
def test_grouped_dw_matches_per_branch_reference(shapes, dtype, tol):
    """The grouped dw kernel: G transposed GEMMs x^T @ dy with db reduced
    in the same pass, masked and unmasked, vs per-branch XLA."""
    m = 77
    xs, _, _ = _branches(m, shapes, dtype)
    ks = jax.random.split(jax.random.PRNGKey(7), 2 * len(shapes))
    dys = [jax.random.normal(ks[2 * i], (m, ng), dtype)
           for i, (_, ng) in enumerate(shapes)]
    ys = [jax.random.normal(ks[2 * i + 1], (m, ng), dtype)
          for i, (_, ng) in enumerate(shapes)]
    for mask in (None, ys):
        dws, dbs = K.grouped_matmul_dw(xs, dys, mask)
        dwr, dbr = K.grouped_matmul_dw_ref(xs, dys, mask)
        for a, b, (kg, ng) in zip(dws, dwr, shapes):
            assert a.shape == (kg, ng) and a.dtype == dtype
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=tol, atol=tol)
        for a, b, (_, ng) in zip(dbs, dbr, shapes):
            assert a.shape == (ng,) and a.dtype == jnp.float32
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=tol, atol=tol)


def test_grouped_masked_dx_epilogue():
    """The forward kernel's mask operand (the ReLU cotangent mask of the
    backward dx GEMMs) zeroes LHS elements in-kernel."""
    shapes = [(100, 60), (300, 129), (64, 16)]
    xs, ws, _ = _branches(50, shapes, jnp.float32)
    import importlib
    # the package re-exports the grouped_matmul FUNCTION under the same
    # name, so fetch the module itself for the kernel-level mask kwarg
    gmm = importlib.import_module("repro.kernels.grouped_matmul")
    mask = [jax.random.normal(jax.random.PRNGKey(i + 40), x.shape)
            for i, x in enumerate(xs)]
    got = gmm.grouped_matmul(xs, ws, mask=mask, interpret=True)
    want = K.grouped_matmul_ref(xs, ws, mask=mask)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_grouped_block_shape_heuristic_and_debug():
    """ROADMAP block-size tuning: 256-row M-blocks past 16k rows, 256-wide
    bf16 weight tiles when every branch is 256-aligned — and the choice is
    visible in the debug repr."""
    small = K.grouped_block_shape(1000, [(100, 60)], jnp.float32)
    assert (small.bm, small.bn, small.bk) == (128, 128, 128)
    big = K.grouped_block_shape(32768, [(100, 60)], jnp.float32)
    assert big.bm == 256 and (big.bn, big.bk) == (128, 128)
    wide = K.grouped_block_shape(32768, [(256, 512), (512, 256)],
                                 jnp.bfloat16)
    assert (wide.bm, wide.bn, wide.bk) == (256, 256, 256)
    # one branch off the 256 alignment -> that axis stays at 128
    mixed = K.grouped_block_shape(1000, [(256, 512), (192, 256)],
                                  jnp.bfloat16)
    assert (mixed.bn, mixed.bk) == (256, 128)
    assert "bm=256" in repr(big) and "16k" in big.note
    xs = [jnp.zeros((32768, 256), jnp.bfloat16)]
    ws = [jnp.zeros((256, 512), jnp.bfloat16)]
    dbg = K.grouped_debug(xs, ws)
    assert "G=1" in dbg and "M=32768" in dbg and "bm=256" in dbg
    # the heuristic blocks still produce correct results (big-M path)
    x = jax.random.normal(jax.random.PRNGKey(0), (16500, 40), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (40, 24), jnp.float32)
    (y,) = K.grouped_matmul([x], [w])
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=1e-4, atol=1e-4)


def test_grouped_vjp_matches_reference_grads():
    """The custom VJP — two grouped launches: masked-dx through the
    forward kernel, dw/db through the grouped dw kernel — against
    autodiff through the per-branch oracle."""
    shapes = [(100, 60), (300, 129), (64, 16), (129, 250)]
    xs, ws, bs = _branches(64, shapes, jnp.float32)

    def loss(fn):
        return lambda xs, ws, bs: sum(
            (y * y).sum() for y in fn(xs, ws, bs, relu=True))

    got = jax.grad(loss(K.grouped_matmul), argnums=(0, 1, 2))(xs, ws, bs)
    want = jax.grad(loss(K.grouped_matmul_ref), argnums=(0, 1, 2))(xs, ws, bs)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_grouped_flops_ragged_beats_stacked():
    """Zero pad-to-max FLOPs: per-branch alignment only."""
    shapes = [(512, 64, 96), (512, 64, 16), (512, 576, 208), (512, 400, 48)]
    grouped, stacked = K.grouped_matmul_flops(shapes)
    assert grouped < stacked
    # uniform shapes: identical work
    g2, s2 = K.grouped_matmul_flops([(256, 128, 128)] * 4)
    assert g2 == s2


# ---------------------------------------------------------------------------
# lowering + plan execution
# ---------------------------------------------------------------------------

def test_gemm_shape_im2col_view():
    op = Op.make("c", "conv2d", n=2, h=16, w=16, c=64, kh=3, kw=3, k=96,
                 stride=1)
    assert gemm_shape(op) == (2 * 16 * 16, 64 * 9, 96)
    op2 = Op.make("c2", "conv2d", n=2, h=16, w=16, c=64, kh=5, kw=5, k=32,
                  stride=2)
    assert gemm_shape(op2) == (2 * 8 * 8, 64 * 25, 32)


def test_lower_ragged_branches_to_grouped():
    g = OpGraph()
    g.add(Op.make("a", "matmul", m=256, k=256, n=256))
    g.add(Op.make("b", "matmul", m=256, k=128, n=384))
    cg = CoGroup(["a", "b"], {"a": "mxu128", "b": "mxu128"}, 1.0)
    plan = lower(g, Schedule([cg]))
    assert plan.groups[0].mode == "grouped", plan.groups[0]


def test_run_plan_grouped_group_matches_reference():
    g = OpGraph()
    g.add(Op.make("a", "matmul", m=256, k=256, n=256))
    g.add(Op.make("b", "matmul", m=256, k=128, n=384))
    cg = CoGroup(["a", "b"], {"a": "mxu128", "b": "mxu128"}, 1.0)
    plan = lower(g, Schedule([cg]))
    assert plan.groups[0].mode == "grouped"
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, (256, 256), jnp.float32) * 0.1
    wa = jax.random.normal(k2, (256, 256), jnp.float32) * 0.1
    wb = jax.random.normal(k3, (128, 384), jnp.float32) * 0.1
    impls = {
        "a": OpImpl(deps=("xin",), fn=lambda x, algorithm=None: x @ wa,
                    gemm_x=lambda x: x, gemm_w=wa, gemm_post=lambda y: y),
        "b": OpImpl(deps=("xin",),
                    fn=lambda x, algorithm=None: x[:, :128] @ wb,
                    gemm_x=lambda x: x[:, :128], gemm_w=wb,
                    gemm_post=lambda y: y),
    }
    env = run_plan(impls, {"xin": x}, plan)
    np.testing.assert_allclose(np.asarray(env["a"]), np.asarray(x @ wa),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(env["b"]),
                               np.asarray(x[:, :128] @ wb),
                               rtol=1e-4, atol=1e-4)
    # fn-only impls degrade to the per-op path instead of failing
    impls_fn = {
        "a": OpImpl(deps=("xin",), fn=lambda x, algorithm=None: x @ wa),
        "b": OpImpl(deps=("xin",),
                    fn=lambda x, algorithm=None: x[:, :128] @ wb),
    }
    env2 = run_plan(impls_fn, {"xin": x}, plan)
    np.testing.assert_allclose(np.asarray(env2["a"]), np.asarray(x @ wa),
                               rtol=1e-5, atol=1e-5)


def test_run_plan_grouped_strided_conv_branches():
    """Strided K×K convs carry a valid im2col view too: a stride-2 pair
    lowers to grouped and matches the reference convs."""
    from repro.kernels import ref as k_ref
    g = OpGraph()
    g.add(Op.make("src", "pointwise", elements=2 * 16 * 16 * 8))
    g.add(Op.make("a", "conv2d", n=2, h=16, w=16, c=8, kh=3, kw=3, k=24,
                  stride=2), ["src"])
    g.add(Op.make("b", "conv2d", n=2, h=16, w=16, c=8, kh=5, kw=5, k=8,
                  stride=2), ["src"])
    cg = CoGroup(["a", "b"], {"a": "im2col_gemm", "b": "im2col_gemm"}, 1.0)
    plan = lower(g, Schedule([CoGroup(["src"], {"src": "vpu"}, 0.0), cg]))
    assert plan.groups[1].mode == "grouped", plan.groups[1]
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (2, 16, 16, 8), jnp.float32)
    was = jax.random.normal(ks[1], (3, 3, 8, 24), jnp.float32) * 0.2
    wbs = jax.random.normal(ks[2], (5, 5, 8, 8), jnp.float32) * 0.2

    def im2col_impl(w4d, s):
        kh, kw, cin, cout = w4d.shape

        def gemm_x(x):
            p = jax.lax.conv_general_dilated_patches(
                x, filter_shape=(kh, kw), window_strides=(s, s),
                padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
            return p.reshape(-1, cin * kh * kw)

        return OpImpl(
            deps=("src",),
            fn=lambda x, algorithm=None, w=w4d: k_ref.conv2d_ref(
                x, w, stride=s, padding="SAME"),
            gemm_x=gemm_x,
            gemm_w=w4d.transpose(2, 0, 1, 3).reshape(cin * kh * kw, cout),
            gemm_post=lambda y: y.reshape(-1, 8, 8, y.shape[-1]))

    impls = {
        "src": OpImpl(deps=("x0",), fn=lambda x, algorithm=None: x),
        "a": im2col_impl(was, 2),
        "b": im2col_impl(wbs, 2),
    }
    env = run_plan(impls, {"x0": x}, plan)
    for name, w4d in (("a", was), ("b", wbs)):
        want = k_ref.conv2d_ref(x, w4d, stride=2, padding="SAME")
        np.testing.assert_allclose(np.asarray(env[name]), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_plan_cnn_googlenet_zero_xla_inception_groups():
    """The acceptance regression: on full GoogleNet every Inception
    CoGroup lowers to a real co-execution mode; nothing falls back to the
    XLA-interleave baseline the paper critiques."""
    plan, _ = CNN.plan_cnn(get_config("googlenet"), batch=32)
    assert plan.groups_of_mode("xla") == []
    multi = [g for g in plan.groups if len(g.ops) > 1]
    assert len(multi) >= 18   # 2 co-exec groups per inception module
    for g in multi:
        assert g.mode in ("grouped", "grouped_concat", "grouped_pooled",
                          "stacked", "fused", "spatial"), g
    # the K×K critical-path convs co-execute instead of running serially —
    # and their launch absorbs the module's join (fused epilogue-concat)
    kxk = [g for g in multi
           if any(n.endswith("/3x3") or n.endswith("/5x5") for n in g.ops)]
    assert kxk and all(g.mode == "grouped_concat" for g in kxk), kxk
    # zero standalone join ops on the fused path
    assert not [g for g in plan.groups
                if g.mode != "grouped_concat"
                and any(n.endswith("/join") for n in g.ops)]
    # and zero standalone maxpool (reduce_window) groups: pooling streams
    # through the quad launches (the pool-proj pre-pool everywhere, the
    # inter-module pool on pooled modules)
    assert not [g for g in plan.groups
                if any(n.endswith("/pool") or n.endswith("/pppool")
                       for n in g.ops)]
