"""Backward co-execution: mirrored plan lowering, backward pricing, the
full-plan gradcheck vs the XLA reference, and shared-X dedup."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (Op, OpGraph, OpImpl, backward_plan, backward_profiles,
                        gemm_shape_bwd, group_execution_time_bwd, lower,
                        profile, run_plan, schedule)
from repro.core.scheduler import CoGroup, Schedule
from repro.models import cnn as CNN
from repro.models.cnn import CNNConfig, InceptionSpec


# ---------------------------------------------------------------------------
# cost model: backward GEMM shapes + pricing
# ---------------------------------------------------------------------------

def test_gemm_shape_bwd_mirrors_forward():
    op = Op.make("c", "conv2d", n=2, h=16, w=16, c=64, kh=3, kw=3, k=96,
                 stride=1)
    # forward im2col view (512, 576, 96) -> dx (M, N, K), dw (K, M, N)
    assert gemm_shape_bwd(op) == ((512, 96, 576), (576, 512, 96))
    op2 = Op.make("c2", "conv2d", n=2, h=16, w=16, c=64, kh=5, kw=5, k=32,
                  stride=2)
    assert gemm_shape_bwd(op2) == ((128, 32, 1600), (1600, 128, 32))
    assert gemm_shape_bwd(Op.make("p", "pointwise", elements=64)) is None


def test_backward_profiles_shapes_and_kinds():
    op = Op.make("m", "matmul", m=256, k=128, n=384)
    profs = backward_profiles(op, "mxu128")
    assert [p.op for p in profs] == ["m:dx", "m:dw"]
    # dx has the forward FLOPs (aligned shapes: identical MACs), dw too
    fwd = profile(op, "mxu128")
    assert all(p.flops == fwd.flops for p in profs)
    # pointwise grad is the same traffic shape (concat backward = split)
    pw = Op.make("j", "pointwise", elements=1 << 16)
    assert len(backward_profiles(pw, "vpu")) == 1


def test_direct_conv_1x1_io_not_undercounted():
    """The PR-2 flag: the direct algorithm's kh*kw*0.5 re-read factor
    bottomed out below 1 for 1x1 convs, undercounting input traffic."""
    op = Op.make("c", "conv2d", n=32, h=28, w=28, c=192, kh=1, kw=1, k=64)
    p = profile(op, "direct")
    eb = op.dtype_bytes
    xin = 32 * 28 * 28 * 192 * eb
    xout = 32 * 28 * 28 * 64 * eb
    wts = 192 * 64 * eb
    assert p.hbm_bytes >= xin + xout + wts
    # 3x3 keeps the overlapping-window re-read factor (4.5x input)
    p3 = profile(Op.make("c3", "conv2d", n=32, h=28, w=28, c=192, kh=3,
                         kw=3, k=64), "direct")
    assert p3.hbm_bytes > 4 * xin


def test_group_execution_time_bwd_modes():
    ragged = [Op.make(f"b{i}", "matmul", m=512, k=k, n=n)
              for i, (k, n) in enumerate([(64, 96), (64, 16), (576, 208),
                                          (400, 48)])]
    mode, t = group_execution_time_bwd(ragged)
    assert mode == "grouped" and t > 0
    # forcing the lowered forward mode prices that mode
    assert group_execution_time_bwd(ragged, mode="grouped")[0] == "grouped"
    uniform = [Op.make(f"u{i}", "matmul", m=512, k=128, n=128)
               for i in range(3)]
    assert group_execution_time_bwd(uniform, mode="stacked")[0] == "stacked"
    het = [Op.make("g", "matmul", m=512, k=128, n=128),
           Op.make("p", "pointwise", elements=1 << 20)]
    assert group_execution_time_bwd(het)[0] == "xla"
    single = [Op.make("s", "matmul", m=512, k=128, n=128)]
    assert group_execution_time_bwd(single)[0] == "serial"


# ---------------------------------------------------------------------------
# backward-plan lowering
# ---------------------------------------------------------------------------

def test_backward_plan_googlenet_zero_xla():
    """The acceptance regression: googlenet's backward plan mirrors the
    forward fork/join groups in reverse and lowers every Inception grad
    CoGroup to grouped/stacked — zero XLA fallbacks, just like PR 2
    achieved forward."""
    plan, _ = CNN.plan_cnn(get_config("googlenet"), batch=32)
    bwd = plan.context["backward"]
    assert len(bwd.groups) == len(plan.groups)
    # mirrored order, grad:-prefixed ops
    assert [g.ops for g in bwd.groups] == [
        tuple(f"grad:{n}" for n in g.ops) for g in reversed(plan.groups)]
    assert bwd.groups_of_mode("xla") == []
    multi = [g for g in bwd.groups if len(g.ops) > 1]
    assert len(multi) >= 18    # 2 grad co-exec groups per inception module
    for g in multi:
        assert g.mode in ("grouped", "grouped_concat", "grouped_pooled",
                          "stacked"), g
    # the K×K critical-path conv grads co-execute in ONE combined launch
    # whose packing slices the joint cotangent (the absorbed join's grad)
    kxk = [g for g in multi
           if any(n.endswith("/3x3") or n.endswith("/5x5") for n in g.ops)]
    assert kxk and all(g.mode == "grouped_concat" for g in kxk), kxk
    # forward mode mirrors backward mode group-for-group (pools included)
    for fg, bg in zip(reversed(plan.groups), bwd.groups):
        if fg.mode in ("grouped", "grouped_concat", "grouped_pooled",
                       "stacked"):
            assert bg.mode == fg.mode, (fg, bg)
        assert bg.pools == tuple(
            (f"grad:{b}", f"grad:{p}") for b, p in fg.pools), (fg, bg)
    assert bwd.makespan > 0
    # the train driver's exact lowering (train=True packing + per-direction
    # budget checks, conv backward workspace charged) holds zero-xla too
    plan_tr, _ = CNN.plan_cnn(get_config("googlenet"), batch=32, train=True)
    assert plan_tr.context["backward"].groups_of_mode("xla") == []
    counts = plan_tr.mode_counts()
    assert counts.get("grouped", 0) + counts.get("grouped_concat", 0) \
        + counts.get("grouped_pooled", 0) >= 18


def test_backward_plan_budget_demotes_to_serial():
    """The C2 safety net mirrors: grad groups over budget price serial."""
    g = OpGraph()
    g.add(Op.make("a", "matmul", m=256, k=256, n=256))
    g.add(Op.make("b", "matmul", m=256, k=128, n=384))
    cg = CoGroup(["a", "b"], {"a": "mxu128", "b": "mxu128"}, 1.0)
    plan = lower(g, Schedule([cg]))
    assert plan.groups[0].mode == "grouped"
    bwd = backward_plan(g, plan, vmem_budget=1.0)
    assert bwd.groups[0].mode == "serial"
    assert "C2" in bwd.groups[0].reason
    bwd_ok = backward_plan(g, plan)
    assert bwd_ok.groups[0].mode == "grouped"


def test_lower_train_budget_covers_backward():
    """lower(train=True) checks C2 budgets against fwd+bwd profiles, so a
    group whose backward footprint doesn't fit runs serial both ways."""
    g = OpGraph()
    g.add(Op.make("a", "matmul", m=256, k=256, n=256))
    g.add(Op.make("b", "matmul", m=256, k=128, n=384))
    cg = CoGroup(["a", "b"], {"a": "mxu128", "b": "mxu128"}, 1.0)
    fwd_only = profile(g.ops["a"], "mxu128").vmem_bytes \
        + profile(g.ops["b"], "mxu128").vmem_bytes
    # budget fits the forward profiles alone but not fwd+bwd
    plan_fwd = lower(g, Schedule([cg]), vmem_budget=fwd_only + 1)
    assert plan_fwd.groups[0].mode == "grouped"
    plan_tr = lower(g, Schedule([cg]), vmem_budget=fwd_only + 1, train=True)
    assert plan_tr.groups[0].mode == "serial"
    assert "C2" in plan_tr.groups[0].reason


def test_scheduler_train_packs_backward():
    """train=True prices candidates at fwd+bwd cost: groups still form on
    googlenet and recorded times grow by the backward makespan."""
    g = CNN.build_graph(get_config("googlenet"), batch=32)
    sch = schedule(g)
    sch_tr = schedule(g, train=True)
    assert any(len(cg.ops) > 1 for cg in sch_tr.groups)
    assert sch_tr.makespan > sch.makespan


# ---------------------------------------------------------------------------
# full-plan gradcheck vs the XLA reference
# ---------------------------------------------------------------------------

def _tiny_cfg():
    """Stride-2 stem (serial GEMM-view backward) + one ragged Inception
    module (grouped dw/db/dx kernels) — every backward path in one net."""
    return CNNConfig(name="tiny", img=(8, 8, 3), stem=((3, 8, 2),),
                     modules=(InceptionSpec(16, 8, 24, 4, 8, 8),),
                     pool_between=(), num_classes=5)


@pytest.mark.parametrize("dtype,rtol,atol", [
    (jnp.float32, 2e-3, 2e-3),
    (jnp.bfloat16, 1e-1, 1e-1),
])
def test_full_plan_backward_matches_xla_reference(dtype, rtol, atol):
    """jax.grad through the lowered plan (grouped dw/db/dx kernels,
    GEMM-view serial conv backward) against autodiff of the plain XLA
    forward — ragged shapes, a strided stem, f32 and bf16."""
    cfg = _tiny_cfg()
    plan, _ = CNN.plan_cnn(cfg, batch=2)
    counts = plan.mode_counts()
    assert counts.get("grouped", 0) + counts.get("grouped_concat", 0) \
        + counts.get("grouped_pooled", 0) >= 1
    params = CNN.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype)
    batch = {"images": jax.random.normal(jax.random.PRNGKey(1),
                                         (2, *cfg.img), dtype),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2,), 0,
                                          cfg.num_classes)}
    (lp, _), gp = jax.value_and_grad(CNN.loss_fn, has_aux=True)(
        params, cfg, batch, plan=plan)
    (l0, _), g0 = jax.value_and_grad(CNN.loss_fn, has_aux=True)(
        params, cfg, batch)
    np.testing.assert_allclose(float(lp), float(l0), rtol=max(rtol, 1e-4))
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(gp)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=rtol, atol=atol)


def test_full_plan_backward_under_jit():
    """jit(value_and_grad(loss_fn)) through the plan — the train driver's
    exact path.  Eager gradchecks alone missed a maxpool init that
    defeated reduce_window's max-monoid lowering, which only the
    jit-of-vjp combination trips (linearize asserts on an unknown
    primal)."""
    cfg = _tiny_cfg()
    plan, _ = CNN.plan_cnn(cfg, batch=2, train=True)
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"images": jax.random.normal(jax.random.PRNGKey(1),
                                         (2, *cfg.img), jnp.float32),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2,), 0,
                                          cfg.num_classes)}
    vg = jax.value_and_grad(CNN.loss_fn, has_aux=True)
    (lj, _), gj = jax.jit(
        lambda p: vg(p, cfg, batch, plan=plan))(params)
    (le, _), ge = vg(params, cfg, batch, plan=plan)
    np.testing.assert_allclose(float(lj), float(le), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(ge), jax.tree.leaves(gj)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_strided_grouped_branches_gradcheck():
    """Grads of a stride-2 grouped conv group (im2col GEMM views) match
    autodiff through the reference convs — weights AND input."""
    from repro.kernels import ref as k_ref
    g = OpGraph()
    g.add(Op.make("src", "pointwise", elements=2 * 16 * 16 * 8))
    g.add(Op.make("a", "conv2d", n=2, h=16, w=16, c=8, kh=3, kw=3, k=24,
                  stride=2), ["src"])
    g.add(Op.make("b", "conv2d", n=2, h=16, w=16, c=8, kh=5, kw=5, k=8,
                  stride=2), ["src"])
    cg = CoGroup(["a", "b"], {"a": "im2col_gemm", "b": "im2col_gemm"}, 1.0)
    plan = lower(g, Schedule([CoGroup(["src"], {"src": "vpu"}, 0.0), cg]))
    assert plan.groups[1].mode == "grouped"
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (2, 16, 16, 8), jnp.float32)
    was = jax.random.normal(ks[1], (3, 3, 8, 24), jnp.float32) * 0.2
    wbs = jax.random.normal(ks[2], (5, 5, 8, 8), jnp.float32) * 0.2

    def build_impls(was, wbs):
        def im2col_impl(w4d, s):
            kh, kw, cin, cout = w4d.shape

            def gemm_x(x):
                p = jax.lax.conv_general_dilated_patches(
                    x, filter_shape=(kh, kw), window_strides=(s, s),
                    padding="SAME",
                    dimension_numbers=("NHWC", "HWIO", "NHWC"))
                return p.reshape(-1, cin * kh * kw)

            return OpImpl(
                deps=("src",),
                fn=lambda x, algorithm=None, w=w4d: k_ref.conv2d_ref(
                    x, w, stride=s, padding="SAME"),
                gemm_x=gemm_x,
                gemm_w=w4d.transpose(2, 0, 1, 3).reshape(cin * kh * kw,
                                                         cout),
                gemm_post=lambda y: y.reshape(-1, 8, 8, y.shape[-1]))

        return {"src": OpImpl(deps=("x0",), fn=lambda x, algorithm=None: x),
                "a": im2col_impl(was, 2), "b": im2col_impl(wbs, 2)}

    def loss(x, was, wbs):
        env = run_plan(build_impls(was, wbs), {"x0": x}, plan)
        return (env["a"] * env["a"]).sum() + (env["b"] * env["b"]).sum()

    def loss_ref(x, was, wbs):
        ya = k_ref.conv2d_ref(x, was, stride=2, padding="SAME")
        yb = k_ref.conv2d_ref(x, wbs, stride=2, padding="SAME")
        return (ya * ya).sum() + (yb * yb).sum()

    got = jax.grad(loss, argnums=(0, 1, 2))(x, was, wbs)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, was, wbs)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


def test_conv_alg_gemm_backward_matches_conv_transpose():
    """The serial conv backward (stride-aware im2col GEMM view) equals
    the XLA conv-transpose gradients it replaced."""
    from repro.kernels import ref as k_ref
    for kh, stride in ((1, 1), (3, 1), (3, 2), (5, 2)):
        ks = jax.random.split(jax.random.PRNGKey(kh * 10 + stride), 2)
        x = jax.random.normal(ks[0], (2, 8, 8, 6), jnp.float32)
        w = jax.random.normal(ks[1], (kh, kh, 6, 10), jnp.float32) * 0.3

        def loss(x, w):
            y = CNN._conv_alg(x, w, stride, "im2col_gemm", True)
            return (y * y).sum()

        def loss_ref(x, w):
            y = k_ref.conv2d_ref(x, w, stride=stride, padding="SAME")
            return (y * y).sum()

        got = jax.grad(loss, argnums=(0, 1))(x, w)
        want = jax.grad(loss_ref, argnums=(0, 1))(x, w)
        for a, b in zip(got, want):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4), (kh, stride)


# ---------------------------------------------------------------------------
# shared-input X dedup (wide GEMM)
# ---------------------------------------------------------------------------

def test_shared_x_dedup_lowers_to_one_wide_gemm(monkeypatch):
    """Uniform-K branches with one (deps, gemm_x_key) run as ONE wide GEMM
    (weights concatenated along N — a single X read); outputs and grads
    match the per-branch references, and the ragged kernel stays for
    impls without the key."""
    import repro.kernels.ops as kops
    g = OpGraph()
    g.add(Op.make("a", "matmul", m=256, k=128, n=384))
    g.add(Op.make("b", "matmul", m=256, k=128, n=32))
    cg = CoGroup(["a", "b"], {"a": "mxu128", "b": "mxu128"}, 1.0)
    plan = lower(g, Schedule([cg]))
    assert plan.groups[0].mode == "grouped", plan.groups[0]
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, (256, 128), jnp.float32) * 0.1
    wa = jax.random.normal(k2, (128, 384), jnp.float32) * 0.1
    wb = jax.random.normal(k3, (128, 32), jnp.float32) * 0.1

    calls = []
    orig = kops.grouped_matmul_pooled   # the executor's entry point
    # (delegates to the plain grouped kernel when nothing pools)

    def spy(xs, ws, bs=None, **kw):
        calls.append(len(list(xs)))
        return orig(xs, ws, bs, **kw)

    monkeypatch.setattr(kops, "grouped_matmul_pooled", spy)

    def impls(wa, wb, key):
        return {
            "a": OpImpl(deps=("xin",), fn=lambda x, algorithm=None: x @ wa,
                        gemm_x=lambda x: x, gemm_x_key=key, gemm_w=wa,
                        gemm_post=lambda y: y),
            "b": OpImpl(deps=("xin",), fn=lambda x, algorithm=None: x @ wb,
                        gemm_x=lambda x: x, gemm_x_key=key, gemm_w=wb,
                        gemm_post=lambda y: y),
        }

    env = run_plan(impls(wa, wb, ("shared", 1)), {"xin": x}, plan)
    assert calls == [1], calls          # ONE wide GEMM, not G ragged
    np.testing.assert_allclose(np.asarray(env["a"]), np.asarray(x @ wa),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(env["b"]), np.asarray(x @ wb),
                               rtol=1e-4, atol=1e-4)

    # grads flow through the wide GEMM and its column split
    def loss(x, wa, wb):
        env = run_plan(impls(wa, wb, ("shared", 1)), {"xin": x}, plan)
        return (env["a"] * env["a"]).sum() + (env["b"] * env["b"]).sum()

    got = jax.grad(loss, argnums=(0, 1, 2))(x, wa, wb)
    want = jax.grad(lambda x, wa, wb: ((x @ wa) ** 2).sum()
                    + ((x @ wb) ** 2).sum(), argnums=(0, 1, 2))(x, wa, wb)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)

    # no key -> the ragged kernel with G branches (no dedup)
    calls.clear()
    run_plan(impls(wa, wb, None), {"xin": x}, plan)
    assert calls == [2], calls
