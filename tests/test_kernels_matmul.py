"""Per-kernel allclose vs the pure-jnp oracle: matmul algorithm zoo."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis, or skip-stubs

from repro import kernels as K
from repro.kernels import ref
from conftest import tol_for

SHAPES = [(128, 128, 128), (256, 384, 512), (64, 200, 72), (8, 1024, 16),
          (512, 128, 384), (100, 100, 100)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("alg", K.MATMUL_ALGORITHMS)
@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_matmul_algorithms(alg, m, k, n, dtype):
    kx, ky = jax.random.split(jax.random.PRNGKey(m * k + n))
    x = jax.random.normal(kx, (m, k), dtype)
    y = jax.random.normal(ky, (k, n), dtype)
    got = K.matmul(x, y, algorithm=alg)
    want = ref.matmul_ref(x, y)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol_for(dtype))


def test_matmul_batched_lead():
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 96, 130))
    y = jax.random.normal(jax.random.PRNGKey(1), (130, 40))
    got = K.matmul(x.reshape(15, 96, 130), y)
    want = jnp.einsum("bmk,kn->bmn", x.reshape(15, 96, 130), y)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_branch_matmul_matches_loop():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 96, 60))
    y = jax.random.normal(jax.random.PRNGKey(1), (4, 60, 72))
    got = K.branch_matmul(x, y)
    want = ref.branch_matmul_ref(x, y)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_workspace_accounting():
    # ksplit is the only GEMM algorithm with HBM workspace (paper C4)
    assert K.matmul_workspace_bytes("ksplit", 512, 512, 1024) > 0
    assert K.matmul_workspace_bytes("mxu128", 512, 512, 1024) == 0
    # large_tile claims more VMEM (the static-resource knob, paper C3)
    assert K.matmul_vmem_bytes("large_tile") > K.matmul_vmem_bytes("mxu128")


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 300), k=st.integers(1, 300), n=st.integers(1, 300),
       alg=st.sampled_from(["mxu128", "large_tile", "ksplit"]))
def test_matmul_property_any_shape(m, k, n, alg):
    """Property: wrapper pads any shape correctly for any algorithm."""
    x = jnp.ones((m, k), jnp.float32)
    y = jnp.full((k, n), 0.5, jnp.float32)
    got = K.matmul(x, y, algorithm=alg)
    np.testing.assert_allclose(got, jnp.full((m, n), 0.5 * k), rtol=1e-4)
