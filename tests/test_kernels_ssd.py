"""Mamba-2 SSD kernel vs quadratic oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis, or skip-stubs

from repro import kernels as K
from repro.kernels import ref
from repro.kernels.ssd import ssd_chunked

CASES = [
    # (b, s, h, p, g, n, chunk)
    (2, 256, 4, 16, 2, 32, 64),
    (1, 100, 2, 8, 1, 16, 32),     # non-divisible seq
    (1, 64, 8, 32, 8, 64, 64),     # single chunk
    (2, 96, 4, 64, 1, 128, 32),    # mamba2-370m-like dims
]


def _inputs(case, key=0):
    b, s, h, p, g, n, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    a = -jnp.abs(jax.random.normal(ks[1], (b, s, h))) * 0.3
    bb = jax.random.normal(ks[2], (b, s, g, n)) * (n ** -0.5)
    cc = jax.random.normal(ks[3], (b, s, g, n)) * (n ** -0.5)
    d = jax.random.normal(ks[4], (h,))
    return x, a, bb, cc, d


@pytest.mark.parametrize("alg", K.SSD_ALGORITHMS)
@pytest.mark.parametrize("case", CASES)
def test_ssd_algorithms(alg, case):
    x, a, bb, cc, d = _inputs(case)
    got = K.ssd(x, a, bb, cc, chunk=case[-1], d_skip=d, algorithm=alg)
    want = ref.ssd_ref(x, a, bb, cc, d_skip=d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_ssd_chunk_invariance():
    """Chunk size is an algorithm knob, not a semantics knob (paper C3)."""
    case = (1, 128, 4, 16, 2, 32, 0)
    x, a, bb, cc, d = _inputs(case)
    outs = [ssd_chunked(x, a, bb, cc, chunk=c, interpret=True)
            for c in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-3, atol=2e-3)


def test_ssd_init_state_continuation():
    """Processing [first half] then [second half from state] == full pass."""
    case = (1, 128, 2, 16, 1, 32, 32)
    x, a, bb, cc, _ = _inputs(case)
    full = ssd_chunked(x, a, bb, cc, chunk=32, interpret=True)
    y1, st = ssd_chunked(x[:, :64], a[:, :64], bb[:, :64], cc[:, :64],
                         chunk=32, return_final_state=True, interpret=True)
    y2 = ssd_chunked(x[:, 64:], a[:, 64:], bb[:, 64:], cc[:, 64:],
                     chunk=32, init_state=st, interpret=True)
    got = jnp.concatenate([y1, y2], axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_ssd_workspace_quadratic_blowup():
    """The materialized algorithm's workspace is the paper's C4 hazard."""
    wq = K.ssd_workspace_bytes("quadratic", 1, 32768, 8, 128, 64)
    wc = K.ssd_workspace_bytes("chunked", 1, 32768, 8, 128, 64)
    # ratio = S*chunk/(N*P) = 512x at 32k tokens; grows linearly with S
    assert wq / wc > 100
    assert K.ssd_workspace_bytes("quadratic", 1, 2 * 32768, 8, 128, 64) \
        == 4 * wq


@settings(max_examples=10, deadline=None)
@given(s=st.integers(4, 80), chunk=st.sampled_from([8, 16, 32]))
def test_ssd_property_seq_len(s, chunk):
    x, a, bb, cc, _ = _inputs((1, s, 2, 8, 1, 16, chunk), key=s)
    got = ssd_chunked(x, a, bb, cc, chunk=chunk, interpret=True)
    want = ref.ssd_ref(x, a, bb, cc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-3, atol=3e-3)
