"""The paper's contribution: graph / cost model / selector / scheduler."""
import pytest
from conftest import given, settings, st  # hypothesis, or skip-stubs

from repro.core import (Op, OpGraph, best_algorithm, co_execution_time,
                        compare_policies, profile, schedule, select_fastest,
                        select_for_group, serial_time, spatial_time,
                        supported_algorithms)
from repro.core import cost_model as cm


def _inception(g, name, cin, n1, r3, n3, r5, n5, pp, hw=28, bs=32, dep="in"):
    for nm, kh, k, c in [("1x1", 1, n1, cin), ("r3", 1, r3, cin),
                         ("r5", 1, r5, cin), ("pp", 1, pp, cin)]:
        g.add(Op.make(f"{name}/{nm}", "conv2d", n=bs, h=hw, w=hw, c=c,
                      kh=kh, kw=kh, k=k, stride=1), [dep])
    g.add(Op.make(f"{name}/3x3", "conv2d", n=bs, h=hw, w=hw, c=r3, kh=3,
                  kw=3, k=n3, stride=1), [f"{name}/r3"])
    g.add(Op.make(f"{name}/5x5", "conv2d", n=bs, h=hw, w=hw, c=r5, kh=5,
                  kw=5, k=n5, stride=1), [f"{name}/r5"])
    g.add(Op.make(f"{name}/join", "pointwise",
                  elements=bs * hw * hw * (n1 + n3 + n5 + pp)),
          [f"{name}/1x1", f"{name}/3x3", f"{name}/5x5", f"{name}/pp"])
    return f"{name}/join"


@pytest.fixture
def googlenet_head():
    g = OpGraph()
    g.add(Op.make("in", "pointwise", elements=1))
    d = _inception(g, "3a", 192, 64, 96, 128, 16, 32, 32)
    _inception(g, "3b", 256, 128, 128, 192, 32, 96, 64, dep=d)
    return g


def test_graph_topology(googlenet_head):
    g = googlenet_head
    levels = g.levels()
    assert levels[0] == ["in"]
    assert set(levels[1]) == {"3a/1x1", "3a/r3", "3a/r5", "3a/pp"}
    assert g.independent("3a/1x1", "3a/3x3")         # C1: cross-layer ILP
    assert not g.independent("3a/r3", "3a/3x3")
    assert len(g.independent_sets()) >= 2


def test_profiles_are_complementary(googlenet_head):
    """Table-1 analogue: algorithms for one op differ in boundedness."""
    op = googlenet_head.ops["3b/5x5"]
    profs = {a: profile(op, a) for a in supported_algorithms(op)}
    bounds = {p.bound for p in profs.values()}
    assert len(profs) >= 2
    # workspace differs by orders of magnitude across algorithms (C4)
    ws = sorted(p.workspace_bytes for p in profs.values())
    assert ws[0] == 0 and ws[-1] > 1e6


def test_workspace_time_not_correlated():
    """Table 2: the fastest algorithm may need far MORE workspace.  The
    inception 5x5 reduce branch (c=16) is MXU-misaligned, so im2col (big
    patch workspace, aligned GEMM) beats zero-workspace direct."""
    op = Op.make("c", "conv2d", n=32, h=28, w=28, c=16, kh=5, kw=5, k=96,
                 stride=1)
    profs = {a: profile(op, a) for a in supported_algorithms(op)}
    assert profs["im2col_gemm"].time < profs["direct"].time
    assert profs["im2col_gemm"].workspace_bytes \
        > profs["direct"].workspace_bytes
    # rankings by time and by workspace disagree (non-correlation)
    by_time = sorted(profs.values(), key=lambda p: p.time)
    by_ws = sorted(profs.values(), key=lambda p: p.workspace_bytes)
    assert [p.algorithm for p in by_time] != [p.algorithm for p in by_ws]


def test_co_execution_beats_serial_for_complementary_pair():
    """C3: compute-bound + memory-bound co-execute faster than serial."""
    big = Op.make("big", "conv2d", n=32, h=28, w=28, c=256, kh=5, kw=5,
                  k=128, stride=1)
    small = Op.make("small", "conv2d", n=32, h=28, w=28, c=256, kh=1, kw=1,
                    k=64, stride=1)
    sel, t_group = select_for_group([big, small])
    t_serial = best_algorithm(big)[1] + best_algorithm(small)[1]
    assert t_group < t_serial


def test_workspace_budget_forces_serialization():
    """C2: when no algorithm combination fits, the group serializes."""
    ops = [Op.make(f"o{i}", "conv2d", n=64, h=56, w=56, c=256, kh=3, kw=3,
                   k=256, stride=1) for i in range(2)]
    # impossible budgets: no algorithm pair fits (HBM nor VMEM)
    sel, t = select_for_group(ops, hbm_budget=1.0, vmem_budget=1.0)
    t_serial = sum(best_algorithm(o)[1] for o in ops)
    assert t == pytest.approx(t_serial)


def test_scheduler_finds_concurrent_win(googlenet_head):
    res = compare_policies(googlenet_head)
    assert res["speedup"] > 1.02
    multi = [g for g in res["concurrent"].groups if len(g.ops) > 1]
    assert multi, "scheduler found no co-execution groups"
    # fastest-per-op selection differs from concurrency-aware (C3)
    fastest = select_fastest(googlenet_head).algorithms
    conc = res["concurrent"].algorithms
    assert any(fastest[n] != conc[n] for n in fastest)


def test_spatial_partitioning_scales():
    ops = [Op.make(f"b{i}", "matmul", m=4096, k=4096, n=4096)
           for i in range(4)]
    profs = [profile(o, "mxu128") for o in ops]
    t1 = spatial_time(profs, chips=4)
    t2 = spatial_time(profs, chips=16)
    assert t2 < t1 < serial_time(profs)


@settings(max_examples=30, deadline=None)
@given(m=st.integers(64, 4096), k=st.integers(64, 4096),
       n=st.integers(64, 4096))
def test_cost_model_properties(m, k, n):
    """Properties: times positive; co-exec never slower than modeled sum;
    group makespan monotone in membership."""
    a = Op.make("a", "matmul", m=m, k=k, n=n)
    b = Op.make("b", "matmul", m=n, k=m, n=k)
    pa, pb = profile(a, "mxu128"), profile(b, "mxu128")
    assert pa.time > 0 and pa.flops > 0 and pa.hbm_bytes > 0
    assert co_execution_time([pa, pb]) <= serial_time([pa, pb]) + 1e-12
    assert co_execution_time([pa]) >= min(pa.compute_time, pa.memory_time)
