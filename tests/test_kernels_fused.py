"""Fused complementary-branch kernel (intra-chip co-execution) vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis, or skip-stubs

from repro.kernels.fused_branches import (fused_gemm_reduce,
                                          fused_gemm_reduce_ref)

CASES = [(256, 256, 256, 1000, 64), (128, 384, 256, 77, 128),
         (256, 128, 128, 4096, 32), (128, 128, 128, 7, 8)]


@pytest.mark.parametrize("case", CASES)
def test_fused_gemm_reduce(case):
    m, k, n, r, c = case
    ks = jax.random.split(jax.random.PRNGKey(sum(case)), 3)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    y = jax.random.normal(ks[1], (k, n), jnp.float32)
    z = jax.random.normal(ks[2], (r, c), jnp.float32)
    gc, gr = fused_gemm_reduce(x, y, z, interpret=True)
    wc, wr = fused_gemm_reduce_ref(x, y, z)
    np.testing.assert_allclose(np.asarray(gc), np.asarray(wc),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(gr), np.asarray(wr),
                               rtol=2e-4, atol=2e-4)


def test_fused_matches_separate_kernels():
    """Co-executed branches == the two ops run serially (the paper's
    correctness requirement for co-scheduling: semantics untouched)."""
    from repro import kernels as K
    x = jax.random.normal(jax.random.PRNGKey(0), (128, 128))
    y = jax.random.normal(jax.random.PRNGKey(1), (128, 128))
    z = jax.random.normal(jax.random.PRNGKey(2), (512, 64))
    gc, gr = fused_gemm_reduce(x, y, z, interpret=True)
    sc = K.matmul(x, y, algorithm="mxu128")
    sr = jax.nn.silu(z).sum(0)
    np.testing.assert_allclose(np.asarray(gc), np.asarray(sc),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(gr), np.asarray(sr),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(r=st.integers(1, 600), c=st.sampled_from([8, 32, 64]))
def test_fused_property_any_reduce_shape(r, c):
    """B's slice partitioning pads to the A-grid size for any R."""
    x = jnp.ones((128, 128))
    y = jnp.ones((128, 128)) * 0.5
    z = jnp.ones((r, c)) * 2.0
    gc, gr = fused_gemm_reduce(x, y, z, interpret=True)
    np.testing.assert_allclose(np.asarray(gc), np.full((128, 128), 64.0),
                               rtol=1e-5)
    want_r = float(jax.nn.silu(2.0)) * r
    np.testing.assert_allclose(np.asarray(gr), np.full((c,), want_r),
                               rtol=1e-4)
