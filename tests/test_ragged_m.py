"""Ragged-M grouped launches: the continuous-batching serving path.

Kernel level — every grouped-family wrapper with ``m_valid`` set must
BIT-match its per-branch XLA oracle (requests pack contiguously, so the
raggedness is a tail mask; K <= 128 keeps kernel and oracle on the same
single-k-block f32 accumulation, making exact equality the honest bar)
and store exact zeros past the true row count.  Model level — a padded
batch served with ``valid_images`` must reproduce the dense run's logits
for the valid images bit-for-bit, through ONE grouped-family launch per
co-executed group (the eager launch counters), and must be invariant to
whatever garbage sits in the padding images.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st

from repro import kernels as K
from repro.configs import get_reduced
from repro.kernels import ops as kops
from repro.models import cnn as CNN

# K <= 128 (one k-block): kernel accumulation == oracle's single f32 dot
RAGGED_SETS = [
    [(128, 128), (64, 60)],
    [(100, 60), (64, 129), (128, 16)],
    [(96, 250)],
]


def _branches(m, shapes, dtype, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3 * len(shapes))
    xs = [jax.random.normal(ks[3 * i], (m, kg), dtype) * 0.3
          for i, (kg, _) in enumerate(shapes)]
    ws = [jax.random.normal(ks[3 * i + 1], (kg, ng), dtype) * 0.3
          for i, (kg, ng) in enumerate(shapes)]
    bs = [jax.random.normal(ks[3 * i + 2], (ng,), dtype)
          for i, (_, ng) in enumerate(shapes)]
    return xs, ws, bs


def _assert_ragged_bitmatch(got, want, m_valid):
    for y, yw in zip(got, want):
        y, yw = np.asarray(y), np.asarray(yw)
        assert np.array_equal(y, yw), (
            f"ragged output != oracle (max |d| "
            f"{np.abs(y.astype(np.float32) - yw.astype(np.float32)).max()})")
        assert not y[m_valid:].any(), "tail rows past m_valid not zeroed"


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 200), st.integers(0, 2),
       st.sampled_from(["float32", "bfloat16"]))
def test_ragged_grouped_bitmatches_oracle(m_valid, set_idx, dtype):
    """Mixed request sizes x dtypes: the ragged grouped launch equals the
    per-request XLA oracle bit-for-bit, zeros past the true M."""
    shapes = RAGGED_SETS[set_idx]
    m = 200   # fixed padded M (the bucket); m_valid is the true row count
    xs, ws, bs = _branches(m, shapes, jnp.dtype(dtype))
    got = K.grouped_matmul(xs, ws, bs, relu=True, m_valid=m_valid)
    want = K.grouped_matmul_ref(xs, ws, bs, relu=True, m_valid=m_valid)
    _assert_ragged_bitmatch(got, want, m_valid)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 150), st.sampled_from(["float32", "bfloat16"]))
def test_ragged_concat_bitmatches_oracle(m_valid, dtype):
    """Ragged fused-concat: branch outputs land in the join buffer with
    the same tail mask.  compact=True — compact=False returns the padded
    panel layout for the executor to assemble, not the (M, total) join
    the oracle produces."""
    shapes = RAGGED_SETS[1]
    xs, ws, bs = _branches(150, shapes, jnp.dtype(dtype))
    offs = [0, 60, 189]
    total = 205
    got = K.grouped_matmul_concat(xs, ws, bs, offsets=offs, total=total,
                                  relu=True, compact=True,
                                  m_valid=m_valid)
    want = K.grouped_matmul_concat_ref(xs, ws, bs, offsets=offs,
                                       total=total, relu=True,
                                       m_valid=m_valid)
    _assert_ragged_bitmatch([got], [want], m_valid)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("set_idx", range(len(RAGGED_SETS)))
def test_ragged_seeded_sweep(set_idx, dtype):
    """Seeded fallback for the property tests above (runs without
    hypothesis, mirroring test_properties.py): a spread of valid counts
    incl. both block-aligned and mid-block tails."""
    shapes = RAGGED_SETS[set_idx]
    xs, ws, bs = _branches(200, shapes, jnp.dtype(dtype), key=set_idx)
    for m_valid in (1, 77, 128, 200):
        got = K.grouped_matmul(xs, ws, bs, relu=True, m_valid=m_valid)
        want = K.grouped_matmul_ref(xs, ws, bs, relu=True, m_valid=m_valid)
        _assert_ragged_bitmatch(got, want, m_valid)


def test_ragged_concat_seeded_sweep():
    shapes = RAGGED_SETS[1]
    offs, total = [0, 60, 189], 205
    for dtype in ("float32", "bfloat16"):
        xs, ws, bs = _branches(150, shapes, jnp.dtype(dtype))
        for m_valid in (1, 64, 150):
            got = K.grouped_matmul_concat(xs, ws, bs, offsets=offs,
                                          total=total, relu=True,
                                          compact=True, m_valid=m_valid)
            want = K.grouped_matmul_concat_ref(xs, ws, bs, offsets=offs,
                                               total=total, relu=True,
                                               m_valid=m_valid)
            _assert_ragged_bitmatch([got], [want], m_valid)


def test_ragged_pooled_bitmatches_oracle():
    """Ragged pooled launch: in-kernel maxpool + GEMM with the tail mask
    on the pooled output's row space."""
    b, h, w, c = 4, 8, 8, 5
    x4 = jnp.maximum(
        jax.random.normal(jax.random.PRNGKey(0), (b, h, w, c)), 0)
    taps = tuple(t.reshape(-1, c) for t in K.pool_tap_views(x4, ((3, 1),)))
    m = b * h * w
    xs = [taps,
          jax.random.normal(jax.random.PRNGKey(1), (m, 64)) * 0.3]
    ws = [jax.random.normal(jax.random.PRNGKey(2), (c, 60)) * 0.3,
          jax.random.normal(jax.random.PRNGKey(3), (64, 16)) * 0.3]
    for m_valid in (1, h * w, 3 * h * w):   # 1 row .. whole-image counts
        got = kops.grouped_matmul_pooled(xs, ws, relu=True, m_valid=m_valid)
        want = K.grouped_matmul_pooled_ref(xs, ws, relu=True,
                                           m_valid=m_valid)
        _assert_ragged_bitmatch(got, want, m_valid)


def test_ragged_traced_m_valid_shares_one_executable():
    """A TRACED i32 ``m_valid`` jits once and serves every valid count —
    the property that lets one bucket executable cover all request
    mixes."""
    xs, ws, bs = _branches(128, RAGGED_SETS[0], jnp.float32)
    traces = []

    @jax.jit
    def run(mv):
        traces.append(1)
        return K.grouped_matmul(xs, ws, bs, m_valid=mv)

    for mv in (1, 37, 128):
        got = run(jnp.int32(mv))
        want = K.grouped_matmul_ref(xs, ws, bs, m_valid=mv)
        _assert_ragged_bitmatch(got, want, mv)
    assert len(traces) == 1, "m_valid retraced per value"


# ---------------------------------------------------------------------------
# model level: the served planned forward
# ---------------------------------------------------------------------------

def test_planned_ragged_forward_bitmatches_dense_one_launch_per_group():
    """Batch-4 plan served with valid_images=2: (a) the first two logits
    rows bit-match the dense (unragged) run of the same padded batch,
    (b) zeroing the padding images changes nothing (per-image isolation
    of the padded rows), (c) the mixed batch runs ONE grouped-family
    launch per co-executed group."""
    cfg = get_reduced("googlenet")
    plan, _ = CNN.plan_cnn(cfg, batch=4)
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (4,) + cfg.img)

    dense = CNN.forward_plan(params, cfg, imgs, plan)
    kops.reset_launch_counts()
    ragged = CNN.forward_plan(params, cfg, imgs, plan, valid_images=2)
    launches = dict(kops.KERNEL_LAUNCHES)
    grouped_family = {g.mode for g in plan.groups
                      if g.mode.startswith("grouped")}
    n_grouped_groups = sum(1 for g in plan.groups
                           if g.mode.startswith("grouped"))
    assert grouped_family, "reduced googlenet plan lost its grouped groups"
    assert sum(launches.get(k, 0) for k in
               ("grouped_matmul", "grouped_matmul_pooled",
                "grouped_matmul_concat",
                "grouped_matmul_pooled_concat")) == n_grouped_groups, \
        (launches, plan.mode_counts())

    np.testing.assert_array_equal(np.asarray(ragged)[:2],
                                  np.asarray(dense)[:2])

    junk = imgs.at[2:].set(jax.random.normal(jax.random.PRNGKey(9),
                                             (2,) + cfg.img) * 50.0)
    ragged_junk = CNN.forward_plan(params, cfg, junk, plan, valid_images=2)
    np.testing.assert_array_equal(np.asarray(ragged_junk)[:2],
                                  np.asarray(ragged)[:2])


def test_run_plan_valid_images_requires_batch_context():
    """valid_images without plan.context['batch'] must fail loudly, not
    silently mis-scale the per-group row counts."""
    cfg = get_reduced("googlenet")
    plan, _ = CNN.plan_cnn(cfg, batch=2)
    plan.context.pop("batch", None)
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    imgs = jnp.zeros((2,) + cfg.img)
    with pytest.raises(AssertionError):
        CNN.forward_plan(params, cfg, imgs, plan, valid_images=1)
