"""Ragged-M grouped launches: the continuous-batching serving path.

Kernel level — every grouped-family wrapper with ``m_valid`` set must
BIT-match its per-branch XLA oracle (requests pack contiguously, so the
raggedness is a tail mask; K <= 128 keeps kernel and oracle on the same
single-k-block f32 accumulation, making exact equality the honest bar)
and store exact zeros past the true row count.  Model level — a padded
batch served with ``valid_images`` must reproduce the dense run's logits
for the valid images bit-for-bit, through ONE grouped-family launch per
co-executed group (the eager launch counters), and must be invariant to
whatever garbage sits in the padding images.
"""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st

from repro import kernels as K
from repro.configs import get_reduced
from repro.core import plan as planlib
from repro.kernels import ops as kops
from repro.models import cnn as CNN

# the package re-exports a function named ``grouped_matmul`` that
# shadows the submodule attribute — importlib reaches the module
gmm = importlib.import_module("repro.kernels.grouped_matmul")

# K <= 128 (one k-block): kernel accumulation == oracle's single f32 dot
RAGGED_SETS = [
    [(128, 128), (64, 60)],
    [(100, 60), (64, 129), (128, 16)],
    [(96, 250)],
]


def _branches(m, shapes, dtype, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3 * len(shapes))
    xs = [jax.random.normal(ks[3 * i], (m, kg), dtype) * 0.3
          for i, (kg, _) in enumerate(shapes)]
    ws = [jax.random.normal(ks[3 * i + 1], (kg, ng), dtype) * 0.3
          for i, (kg, ng) in enumerate(shapes)]
    bs = [jax.random.normal(ks[3 * i + 2], (ng,), dtype)
          for i, (_, ng) in enumerate(shapes)]
    return xs, ws, bs


def _assert_ragged_bitmatch(got, want, m_valid):
    for y, yw in zip(got, want):
        y, yw = np.asarray(y), np.asarray(yw)
        assert np.array_equal(y, yw), (
            f"ragged output != oracle (max |d| "
            f"{np.abs(y.astype(np.float32) - yw.astype(np.float32)).max()})")
        assert not y[m_valid:].any(), "tail rows past m_valid not zeroed"


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 200), st.integers(0, 2),
       st.sampled_from(["float32", "bfloat16"]))
def test_ragged_grouped_bitmatches_oracle(m_valid, set_idx, dtype):
    """Mixed request sizes x dtypes: the ragged grouped launch equals the
    per-request XLA oracle bit-for-bit, zeros past the true M."""
    shapes = RAGGED_SETS[set_idx]
    m = 200   # fixed padded M (the bucket); m_valid is the true row count
    xs, ws, bs = _branches(m, shapes, jnp.dtype(dtype))
    got = K.grouped_matmul(xs, ws, bs, relu=True, m_valid=m_valid)
    want = K.grouped_matmul_ref(xs, ws, bs, relu=True, m_valid=m_valid)
    _assert_ragged_bitmatch(got, want, m_valid)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 150), st.sampled_from(["float32", "bfloat16"]))
def test_ragged_concat_bitmatches_oracle(m_valid, dtype):
    """Ragged fused-concat: branch outputs land in the join buffer with
    the same tail mask.  compact=True — compact=False returns the padded
    panel layout for the executor to assemble, not the (M, total) join
    the oracle produces."""
    shapes = RAGGED_SETS[1]
    xs, ws, bs = _branches(150, shapes, jnp.dtype(dtype))
    offs = [0, 60, 189]
    total = 205
    got = K.grouped_matmul_concat(xs, ws, bs, offsets=offs, total=total,
                                  relu=True, compact=True,
                                  m_valid=m_valid)
    want = K.grouped_matmul_concat_ref(xs, ws, bs, offsets=offs,
                                       total=total, relu=True,
                                       m_valid=m_valid)
    _assert_ragged_bitmatch([got], [want], m_valid)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("set_idx", range(len(RAGGED_SETS)))
def test_ragged_seeded_sweep(set_idx, dtype):
    """Seeded fallback for the property tests above (runs without
    hypothesis, mirroring test_properties.py): a spread of valid counts
    incl. both block-aligned and mid-block tails."""
    shapes = RAGGED_SETS[set_idx]
    xs, ws, bs = _branches(200, shapes, jnp.dtype(dtype), key=set_idx)
    for m_valid in (1, 77, 128, 200):
        got = K.grouped_matmul(xs, ws, bs, relu=True, m_valid=m_valid)
        want = K.grouped_matmul_ref(xs, ws, bs, relu=True, m_valid=m_valid)
        _assert_ragged_bitmatch(got, want, m_valid)


def test_ragged_concat_seeded_sweep():
    shapes = RAGGED_SETS[1]
    offs, total = [0, 60, 189], 205
    for dtype in ("float32", "bfloat16"):
        xs, ws, bs = _branches(150, shapes, jnp.dtype(dtype))
        for m_valid in (1, 64, 150):
            got = K.grouped_matmul_concat(xs, ws, bs, offsets=offs,
                                          total=total, relu=True,
                                          compact=True, m_valid=m_valid)
            want = K.grouped_matmul_concat_ref(xs, ws, bs, offsets=offs,
                                               total=total, relu=True,
                                               m_valid=m_valid)
            _assert_ragged_bitmatch([got], [want], m_valid)


def test_ragged_pooled_bitmatches_oracle():
    """Ragged pooled launch: in-kernel maxpool + GEMM with the tail mask
    on the pooled output's row space."""
    b, h, w, c = 4, 8, 8, 5
    x4 = jnp.maximum(
        jax.random.normal(jax.random.PRNGKey(0), (b, h, w, c)), 0)
    taps = tuple(t.reshape(-1, c) for t in K.pool_tap_views(x4, ((3, 1),)))
    m = b * h * w
    xs = [taps,
          jax.random.normal(jax.random.PRNGKey(1), (m, 64)) * 0.3]
    ws = [jax.random.normal(jax.random.PRNGKey(2), (c, 60)) * 0.3,
          jax.random.normal(jax.random.PRNGKey(3), (64, 16)) * 0.3]
    for m_valid in (1, h * w, 3 * h * w):   # 1 row .. whole-image counts
        got = kops.grouped_matmul_pooled(xs, ws, relu=True, m_valid=m_valid)
        want = K.grouped_matmul_pooled_ref(xs, ws, relu=True,
                                           m_valid=m_valid)
        _assert_ragged_bitmatch(got, want, m_valid)


def test_ragged_traced_m_valid_shares_one_executable():
    """A TRACED i32 ``m_valid`` jits once and serves every valid count —
    the property that lets one bucket executable cover all request
    mixes."""
    xs, ws, bs = _branches(128, RAGGED_SETS[0], jnp.float32)
    traces = []

    @jax.jit
    def run(mv):
        traces.append(1)
        return K.grouped_matmul(xs, ws, bs, m_valid=mv)

    for mv in (1, 37, 128):
        got = run(jnp.int32(mv))
        want = K.grouped_matmul_ref(xs, ws, bs, m_valid=mv)
        _assert_ragged_bitmatch(got, want, mv)
    assert len(traces) == 1, "m_valid retraced per value"


# ---------------------------------------------------------------------------
# chained launch: ragged-M inside grouped_matmul_chained
# ---------------------------------------------------------------------------

def _chain_case(b, h, w, dtype=jnp.float32, key=0):
    """2-phase chain (dense producer -> in-launch 3x3 ring conv) plus a
    phase-dict builder, so the same weights spec both the padded-bucket
    launch and its sliced-input oracle."""
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    m = b * h * w
    x0 = jax.random.normal(ks[0], (m, 64), dtype) * 0.3
    w0 = jax.random.normal(ks[1], (64, 48), dtype) * 0.3
    b0 = jax.random.normal(ks[2], (48,), dtype)
    wmat = jax.random.normal(ks[3], (48 * 9, 40), dtype) * 0.3
    b1 = jax.random.normal(ks[4], (40,), dtype)

    def phases(x):
        p0 = [{"n": 48, "w": planlib._pad_w_dense(w0, 128), "b": b0,
               "src": ("x", [x]), "ring_write": (0,)}]
        p1 = [{"n": 40, "w": planlib._pack_w_ring(wmat, 3, 3, 48, 1, 128),
               "b": b1, "src": ("ring", 3, 3, (0,)), "ring_write": None}]
        return [p0, p1]

    return x0, phases


def _assert_chained_ragged(got, oracle, m_valid, bm=128):
    """Live rows bit-match; the LIVE TAIL BLOCK stores exact zeros past
    ``m_valid``.  Dead blocks past the live tail are skipped outright —
    their contents are unspecified garbage no live consumer reads, so
    they are deliberately NOT asserted on."""
    tail_end = min(-(-m_valid // bm) * bm, got[0].shape[0])
    for y, yw in zip(got, oracle):
        y = np.asarray(y)
        np.testing.assert_array_equal(y[:m_valid],
                                      np.asarray(yw)[:m_valid])
        assert not y[m_valid:tail_end].any(), \
            "live tail block rows past m_valid not zeroed"


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("bucket", [2, 4])
def test_ragged_chained_bitmatches_per_request_oracle(bucket, dtype):
    """Every ladder bucket x dtype: the masked chained launch bit-matches
    the dense chained kernel run on just the request's images (requests
    pack contiguously, so the per-request oracle IS the sliced input;
    accumulation is row-local, so padding cannot perturb live rows)."""
    h, w = 8, 8
    x0, phases = _chain_case(bucket, h, w, jnp.dtype(dtype), key=bucket)
    for vi in range(1, bucket + 1):
        mv = vi * h * w
        got = kops.grouped_matmul_chained(phases(x0), m=x0.shape[0],
                                          h=h, w=w, m_valid=mv)
        oracle = kops.grouped_matmul_chained(phases(x0[:mv]), m=mv,
                                             h=h, w=w)
        _assert_chained_ragged(got, oracle, mv)


def test_ragged_chained_traced_m_valid_shares_one_executable():
    x0, phases = _chain_case(2, 8, 8)
    traces = []

    @jax.jit
    def run(mv):
        traces.append(1)
        return kops.grouped_matmul_chained(phases(x0), m=x0.shape[0],
                                           h=8, w=8, m_valid=mv)

    for vi in (1, 2):
        mv = vi * 64
        got = run(jnp.int32(mv))
        oracle = kops.grouped_matmul_chained(phases(x0[:mv]), m=mv,
                                             h=8, w=8)
        _assert_chained_ragged(got, oracle, mv)
    assert len(traces) == 1, "chained m_valid retraced per value"


def test_ragged_chained_dead_blocks_execute_zero_steps():
    """The no-op guard SKIPS dead M-blocks — it does not merely zero
    them.  rows/image == bm (h*w = 128) makes image count == block
    count, so the grid-step counter must read exactly the live blocks'
    share of the table and the skip ratio is exactly 1 - n/bucket."""
    b, h, w = 4, 16, 8          # 128 rows/image == bm: 4 images, 4 blocks
    x0, phases = _chain_case(b, h, w)
    m = b * h * w
    spec = gmm._chain_static(phases(x0), 128, 128, w)
    tab = np.asarray(gmm._plan_tiles_chained(m // 128, spec))
    total = tab.shape[1]
    from repro.analysis import tables
    for vi in (1, 2, 3, 4):
        _, steps = gmm.grouped_matmul_chained(
            phases(x0), m=m, h=h, w=w, m_valid=vi * h * w,
            debug_steps=True, interpret=True)
        executed = int(np.asarray(steps)[0, 0])
        expected = int((tab[tables.CH_I] < vi).sum())
        assert executed == expected, (vi, executed, expected)
        assert total - executed == total * (1 - vi / b), \
            "skip ratio != 1 - n/bucket"


# ---------------------------------------------------------------------------
# model level: the served planned forward
# ---------------------------------------------------------------------------

def test_planned_ragged_forward_bitmatches_dense_one_launch_per_group():
    """Batch-4 plan served with valid_images=2: (a) the first two logits
    rows bit-match the dense (unragged) run of the same padded batch,
    (b) zeroing the padding images changes nothing (per-image isolation
    of the padded rows), (c) the mixed batch runs ONE grouped-family
    launch per co-executed group."""
    cfg = get_reduced("googlenet")
    plan, _ = CNN.plan_cnn(cfg, batch=4)
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (4,) + cfg.img)

    dense = CNN.forward_plan(params, cfg, imgs, plan)
    kops.reset_launch_counts()
    ragged = CNN.forward_plan(params, cfg, imgs, plan, valid_images=2)
    launches = dict(kops.KERNEL_LAUNCHES)
    grouped_family = {g.mode for g in plan.groups
                      if g.mode.startswith("grouped")}
    n_grouped_groups = sum(1 for g in plan.groups
                           if g.mode.startswith("grouped"))
    assert grouped_family, "reduced googlenet plan lost its grouped groups"
    assert sum(launches.get(k, 0) for k in
               ("grouped_matmul", "grouped_matmul_pooled",
                "grouped_matmul_concat",
                "grouped_matmul_pooled_concat")) == n_grouped_groups, \
        (launches, plan.mode_counts())

    np.testing.assert_array_equal(np.asarray(ragged)[:2],
                                  np.asarray(dense)[:2])

    junk = imgs.at[2:].set(jax.random.normal(jax.random.PRNGKey(9),
                                             (2,) + cfg.img) * 50.0)
    ragged_junk = CNN.forward_plan(params, cfg, junk, plan, valid_images=2)
    np.testing.assert_array_equal(np.asarray(ragged_junk)[:2],
                                  np.asarray(ragged)[:2])


def test_run_plan_valid_images_requires_batch_context():
    """valid_images without plan.context['batch'] must fail loudly, not
    silently mis-scale the per-group row counts."""
    cfg = get_reduced("googlenet")
    plan, _ = CNN.plan_cnn(cfg, batch=2)
    plan.context.pop("batch", None)
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    imgs = jnp.zeros((2,) + cfg.img)
    with pytest.raises(AssertionError):
        CNN.forward_plan(params, cfg, imgs, plan, valid_images=1)


def test_valid_rows_rejects_inconsistent_geometry():
    """_valid_rows must not trust xs[0]: mixed per-branch M is a loud
    error, and M not divisible by the batch (fractional rows/image)
    cannot produce an image-aligned cutoff."""
    a, b = jnp.zeros((128, 4)), jnp.zeros((64, 4))
    with pytest.raises(ValueError, match="mixes lhs row counts"):
        planlib._valid_rows([a, b], 1, 2)
    with pytest.raises(ValueError, match="not a multiple"):
        planlib._valid_rows([jnp.zeros((129, 4))], 1, 2)
    assert planlib._valid_rows([a, a], 1, 2) == 64
    assert planlib._valid_rows([a], None, 2) is None


def test_planned_ragged_chained_forward_bitmatches_dense():
    """The chained (cross-module) plan served with valid_images: valid
    logits bit-match the dense run and are invariant to garbage in the
    padding images — the masked chained launch, not a caller-side slice,
    provides the isolation."""
    cfg = get_reduced("googlenet")
    plan, _ = CNN.plan_cnn(cfg, batch=4, chain_modules=True)
    assert any(g.mode == "grouped_chained" for g in plan.groups), \
        "chain_modules plan lost its chained groups"
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (4,) + cfg.img)

    dense = CNN.forward_plan(params, cfg, imgs, plan)
    for vi in (1, 3):
        ragged = CNN.forward_plan(params, cfg, imgs, plan, valid_images=vi)
        np.testing.assert_array_equal(np.asarray(ragged)[:vi],
                                      np.asarray(dense)[:vi])
    junk = imgs.at[2:].set(jax.random.normal(jax.random.PRNGKey(9),
                                             (2,) + cfg.img) * 50.0)
    ragged2 = CNN.forward_plan(params, cfg, junk, plan, valid_images=2)
    np.testing.assert_array_equal(np.asarray(ragged2)[:2],
                                  np.asarray(dense)[:2])


# ---------------------------------------------------------------------------
# serving: admission, oversized splits, request-level latency
# ---------------------------------------------------------------------------

def test_serve_split_request_conserves_images():
    from repro.launch import serve

    imgs = np.arange(5 * 2 * 2 * 1, dtype=np.float32).reshape(5, 2, 2, 1)
    chunks = serve._split_request(7, imgs, 0.1, max_images=2)
    assert [c["imgs"].shape[0] for c in chunks] == [2, 2, 1]
    assert all(c["rid"] == 7 for c in chunks)
    np.testing.assert_array_equal(
        np.concatenate([c["imgs"] for c in chunks]), imgs)


def test_serve_admit_edf_anchor_and_waste_packing():
    from repro.core.cost_model import padded_m_factor
    from repro.launch import serve

    def chunk(rid, n, dl):
        return {"rid": rid, "imgs": np.zeros((n, 2, 2, 1), np.float32),
                "deadline": dl}

    # rows_per_image = 128 = bm, so factor(n images) =
    # bucket_for(n)/n and the packing choice is visible.  EDF: the
    # earliest deadline (r2) anchors even from the back of the queue.
    # Fill: r1 (earlier deadline) would leave 3 images in the 4-bucket
    # (factor 4/3); r0 fills it exactly (factor 1.0) — waste, not queue
    # order, picks the rider.
    pending = [chunk(0, 2, 0.9), chunk(1, 1, 0.5), chunk(2, 2, 0.1)]
    batch, total = serve._admit(pending, 4, [1, 2, 4], 128,
                                padded_m_factor)
    assert batch[0]["rid"] == 2 and total == 4
    assert {c["rid"] for c in batch} == {0, 2}

    # conservation: repeated admission drains every chunk exactly once
    pending = [chunk(i, 1 + i % 3, 0.1 * i) for i in range(7)]
    want = sum(c["imgs"].shape[0] for c in pending)
    got = 0
    while pending:
        batch, total = serve._admit(pending, 4, [1, 2, 4], 128,
                                    padded_m_factor)
        got += total
    assert got == want, "admission dropped or duplicated a chunk"


def test_serving_loop_serves_every_submitted_image():
    """End-to-end regression for the oversized-truncation bug: the
    stream contains requests larger than max_images (sizes reach
    max_images + 1), and every submitted image must reach a launch.
    Also pins the request-level latency contract: one sample per
    request, not per dispatch."""
    from repro.launch.serve import serve_cnn_metrics

    m = serve_cnn_metrics(get_reduced("googlenet"), max_images=2,
                          num_requests=5, seed=3)
    assert m["images"] == m["images_submitted"] > 0
    assert m["latency_samples"] == m["requests"] == 5
    assert m["p99_ms"] >= m["p50_ms"] > 0
    assert m["dispatch_p99_ms"] >= m["dispatch_p50_ms"] > 0
    assert m["plan_cache"]["hit_rate"] == 1.0
