"""planlint — zero findings on real lowerings, and fault injection
proving every checker class fires.

The static verifier is only trustworthy if (a) every table the real
``_plan_tiles*`` builders emit comes back clean and (b) corrupting ANY
row of those tables produces a finding.  The mutation tests walk every
row of every family's table, corrupt one cell, and require the family
checker to object — a checker that ignores a row would pass a broken
schedule silently, which is exactly the failure mode planlint exists to
rule out.  Hazard, budget and fallback-provenance classes get targeted
mutants of their own.
"""
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import Finding, PlanVerificationError, verify_plan
from repro.analysis import fallbacks, hazards, tables
from repro.configs import get_reduced
from repro.core import launch_count as lc
from repro.models import cnn

# the package re-exports a function named ``grouped_matmul`` that shadows
# the submodule attribute — importlib reaches the module itself; the
# package-level experts entry point is the differentiable custom-vjp one
gmm = importlib.import_module("repro.kernels.grouped_matmul")
from repro import kernels as K


def _mutants_fire(tab, check, rows):
    """Corrupting each listed row (at some step) must produce >= 1
    finding; returns the number of fired mutants."""
    fired = 0
    for row in rows:
        hit = False
        for t in range(tab.shape[1]):
            bad = np.array(tab, copy=True)
            bad[row, t] += 3
            if check(bad):
                hit = True
                fired += 1
                break
        assert hit, f"no mutant on row {row} produced a finding"
    return fired


# ---------------------------------------------------------------------------
# table schemas: builder output is clean, every row is load-bearing
# ---------------------------------------------------------------------------

def test_plain_table_clean_and_mutants():
    tab = gmm._plan_tiles(2, (2, 1), (1, 2))
    check = lambda tb: tables.check_plain(tb, 2, (2, 1), (1, 2))
    assert check(tab) == []
    assert _mutants_fire(tab, check, range(tables.GM_ROWS)) == 7


def test_pooled_table_clean_and_mutants():
    # group 0 pooled (3x3 = 9 taps), group 1 plain
    tab = gmm._plan_tiles_pooled(2, (1, 1), (1, 1), (9, 1), False)
    check = lambda tb: tables.check_pooled(tb, 2, (1, 1), (1, 1),
                                           (9, 1), False)
    assert check(tab) == []
    assert _mutants_fire(tab, check, range(tables.GP_ROWS)) == 11


def test_dw_table_clean_and_mutants():
    tab = gmm._plan_tiles_dw(2, (2, 1), (1, 2))
    check = lambda tb: tables.check_dw(tb, 2, (2, 1), (1, 2))
    assert check(tab) == []
    assert _mutants_fire(tab, check, range(tables.DW_ROWS)) == 7


def test_bwd_table_clean_and_mutants():
    tab = gmm._plan_tiles_bwd(2, (2, 1), (1, 2))
    check = lambda tb: tables.check_bwd(tb, 2, (2, 1), (1, 2))
    assert check(tab) == []
    assert _mutants_fire(tab, check, range(tables.BW_ROWS)) == 8


def _chained_spec():
    """2-phase chain on a 4x4 image: phase 0 a packed-x producer that
    ring-writes column 0, phase 1 a 3x3 in-launch conv consuming it."""
    taps = tuple((dh * 4 + dw, dh, dw)
                 for dh in (-1, 0, 1) for dw in (-1, 0, 1))
    return ((("x", 2, 1, (0,)),),
            (("ring", (taps, (0,)), 1, ()),))


def test_chained_table_clean_and_mutants():
    spec = _chained_spec()
    tab = gmm._plan_tiles_chained(2, spec)
    check = lambda tb: tables.check_chained(tb, 2, spec)
    assert check(tab) == []
    # ... + 1: the trailing per-phase mrow slot row ragged launches
    # read their liveness from (``tables.ch_mrow_row``)
    nrows = tables.CH_ROWS + 2 * len(spec) + 1
    assert _mutants_fire(tab, check, range(nrows)) == nrows


def test_experts_tables_clean_and_mutants():
    tab = gmm._plan_tiles_experts(2, 1, 1, 1)
    check = lambda tb: tables.check_experts(tb, 2, 1, 1, 1)
    assert check(tab) == []
    assert _mutants_fire(tab, check, range(tables.EX_ROWS)) == 10

    tabb = gmm._plan_tiles_experts_bwd(2, 1, 1, 1)
    checkb = lambda tb: tables.check_experts_bwd(tb, 2, 1, 1, 1)
    assert checkb(tabb) == []
    assert _mutants_fire(tabb, checkb, range(tables.EB_ROWS)) == 13


# ---------------------------------------------------------------------------
# hazards: wave happens-before and concat write-write
# ---------------------------------------------------------------------------

def _schedule(tab):
    return hazards.check_chained_schedule(np.asarray(tab), 2, 2,
                                          h=4, w=4, bm=128, nring=1)


def test_chained_schedule_clean():
    assert _schedule(gmm._plan_tiles_chained(2, _chained_spec())) == []


def test_chained_schedule_order_violation():
    # reversed execution order: every ring read now precedes its
    # producer's ring write
    tab = np.array(gmm._plan_tiles_chained(2, _chained_spec()))[:, ::-1]
    out = _schedule(tab)
    assert any(kind == "hazard" for kind, _ in out)


def test_chained_schedule_bounds_mutants():
    base = np.array(gmm._plan_tiles_chained(2, _chained_spec()))
    ring_steps = np.nonzero(base[tables.CH_SRC] == 2)[0]
    t = int(ring_steps[0])

    bad = base.copy()
    bad[tables.CH_RC, t] = 5                       # outside nring=1
    assert any(k == "bounds" for k, _ in _schedule(bad))

    bad = base.copy()
    bad[tables.CH_DELTA, t] = 200                  # halo beyond bm=128
    assert any(k == "bounds" for k, _ in _schedule(bad))

    bad = base.copy()
    bad[tables.CH_DH, t] += 1                      # delta != dh*W + dw
    assert any(k == "bounds" for k, _ in _schedule(bad))


def _masked(tab):
    return hazards.check_chained_masked(np.asarray(tab), 2, 2, h=4, w=4)


def test_chained_masked_clean():
    assert _masked(gmm._plan_tiles_chained(2, _chained_spec())) == []


def test_chained_masked_mutants():
    """Fault injection for every obligation of the ragged no-op guard:
    a wrong liveness slot, an out-of-range slot, a tap whose delta
    breaks the in-image identity (the masked proof's boundary premise),
    and a table with no mrow row at all."""
    base = np.array(gmm._plan_tiles_chained(2, _chained_spec()))
    mrr = tables.ch_mrow_row(2)

    bad = base.copy()
    bad[mrr, 1] += 1                               # wrong (phase, block)
    assert any(k == "hazard" for k, _ in _masked(bad))

    bad = base.copy()
    bad[mrr, 0] = 99                               # outside [0, nph*mb)
    assert any(k == "bounds" for k, _ in _masked(bad))

    t = int(np.nonzero(base[tables.CH_SRC] == 2)[0][0])
    bad = base.copy()
    bad[tables.CH_DW, t] += 1                      # delta != dh*W + dw
    assert any(k == "bounds" for k, _ in _masked(bad))

    assert any(k == "hazard" for k, _ in _masked(base[:mrr]))


def test_concat_segments():
    ok = [(0, 4, "a"), (4, 6, "b")]
    assert hazards.check_concat_segments(ok, 10) == []
    overlap = [(0, 5, "a"), (4, 6, "b")]
    assert any(k == "hazard"
               for k, _ in hazards.check_concat_segments(overlap, 10))
    gap = [(0, 4, "a"), (6, 4, "b")]
    assert any(k == "schema"
               for k, _ in hazards.check_concat_segments(gap, 10))
    escape = [(0, 12, "a")]
    assert any(k == "hazard"
               for k, _ in hazards.check_concat_segments(escape, 10))


# ---------------------------------------------------------------------------
# plan level: zero findings, default-on stamping, budget fault injection
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def fused_plan():
    plan, _ = cnn.plan_cnn(get_reduced("googlenet"), 2)
    return plan


def test_verify_plan_zero_findings(fused_plan):
    assert verify_plan(fused_plan) == []
    assert verify_plan(fused_plan.context["backward"]) == []


def test_lower_stamps_verified_under_pytest(fused_plan):
    # PYTEST_CURRENT_TEST is set, so lower()/backward_plan() auto-verify
    # and stamp the context for the plan cache's ``verified`` flag
    assert fused_plan.context.get("verified") is True
    assert fused_plan.context["backward"].context.get("verified") is True


def test_budget_fault_injection(fused_plan):
    from repro.core import plan as planlib
    plan, _ = cnn.plan_cnn(get_reduced("googlenet"), 2)
    plan.context["budgets"] = {"hbm": 64.0, "vmem": 64.0}
    out = verify_plan(plan)
    assert out and all(f.checker == "budget" for f in out)
    with pytest.raises(PlanVerificationError):
        planlib._maybe_verify(plan, None, True)


# ---------------------------------------------------------------------------
# fallback provenance lint
# ---------------------------------------------------------------------------

def test_fallback_leak_in_clean_scope_fires():
    # the chained pack path is dynamic-update-slice only by contract —
    # a concatenate in its scope is a finding (grouped/pooled/stacked
    # get a packing-copy allowance; chained does not)
    def leaky(a, b):
        with jax.named_scope("plan[grouped_chained:inc3a.b3x3]"):
            return jnp.concatenate([a, b], axis=0)
    out = fallbacks.lint_fallbacks(leaky, jnp.ones((2, 2)),
                                   jnp.ones((2, 2)))
    assert len(out) == 1 and out[0][0] == "fallback"
    assert "concatenate" in out[0][1] and "grouped_chained" in out[0][1]


def test_fallback_gather_attribution():
    def leaky(a):
        with jax.named_scope("plan[grouped_chained:stem]"):
            return jnp.take(a, jnp.array([1, 0]), axis=0)
    out = fallbacks.lint_fallbacks(leaky, jnp.ones((2, 2)))
    assert out and "gather" in out[0][1]


def test_fallback_serial_scope_exempt():
    def serial(a, b):
        with jax.named_scope("plan[serial:pool3]"):
            return jnp.concatenate([a, b], axis=0)
    assert fallbacks.lint_fallbacks(serial, jnp.ones((2, 2)),
                                    jnp.ones((2, 2))) == []


def test_fallback_concat_mode_allows_assembly():
    def assembly(a, b):
        with jax.named_scope("plan[grouped_concat:inc3a.join]"):
            return jnp.concatenate([a, b], axis=1)
    assert fallbacks.lint_fallbacks(assembly, jnp.ones((2, 2)),
                                    jnp.ones((2, 2))) == []


# ---------------------------------------------------------------------------
# launch_count: MoE grouped path, scan and checkpoint bodies
# ---------------------------------------------------------------------------

def _moe_case(counts=(16, 0, 9, 3), d=128, f=64, bm=8):
    offs = np.asarray(gmm.expert_row_offsets(counts, bm))
    e = len(counts)
    n_rows = int(np.maximum(-(-np.asarray(counts) // bm), 1).sum()) * bm
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xp = jnp.zeros((n_rows, d), jnp.float32)
    swp = jnp.zeros((n_rows,), jnp.float32)
    for g, c in enumerate(counts):
        if c:
            xp = xp.at[offs[g]:offs[g] + c].set(
                jax.random.normal(jax.random.fold_in(ks[0], g),
                                  (c, d)) * 0.3)
            swp = swp.at[offs[g]:offs[g] + c].set(1.0)
    w_in = jax.random.normal(ks[1], (e, d, f)) * 0.3
    w_out = jax.random.normal(ks[2], (e, f, d)) * 0.3
    w_gate = jax.random.normal(ks[3], (e, d, f)) * 0.3
    return xp, swp, w_in, w_out, w_gate, jnp.asarray(counts, jnp.int32)


def test_launch_count_moe_grouped():
    xp, swp, w_in, w_out, w_gate, cnt = _moe_case()
    fwd = lc.count_launches(
        lambda x: gmm.grouped_matmul_experts(x, swp, w_in, w_out, w_gate,
                                             cnt, bm=8), xp)
    assert fwd["pallas_call"] == 1

    both = lc.count_grad_launches(
        lambda x: jnp.sum(K.grouped_matmul_experts(
            x, swp, w_in, w_out, w_gate, cnt, bm=8)), xp)
    # residual forward + the ONE combined experts backward
    assert both["pallas_call"] == 2


def test_launch_count_inside_scan_and_checkpoint():
    xp, swp, w_in, w_out, w_gate, cnt = _moe_case()
    f = lambda x: K.grouped_matmul_experts(x, swp, w_in, w_out, w_gate,
                                           cnt, bm=8)
    # the scan body's sub-jaxpr is walked: its single kernel is counted
    scanned = lc.count_launches(
        lambda x: jax.lax.scan(lambda c, _: (f(c), None), x, None,
                               length=3)[0], xp)
    assert scanned["pallas_call"] == 1

    # checkpoint (remat) bodies are walked too — the grad trace sees the
    # rematerialized forward kernel plus the backward kernel
    both = lc.count_grad_launches(
        lambda x: jnp.sum(jax.checkpoint(f)(x)), xp)
    assert both["pallas_call"] >= 2
