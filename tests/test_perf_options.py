"""§Perf option correctness: every optimization must be math-preserving."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401
from conftest import given, settings, st  # hypothesis, or skip-stubs

from repro.models.moe import moe_init, _moe_apply_core

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_in_subprocess(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert res.returncode == 0, f"\nSTDOUT:{res.stdout}\nSTDERR:{res.stderr}"
    return res.stdout


def test_moe_ep_window_partial_sums_equal_full():
    """Sum of expert-window partials == full MoE (the psum-join invariant
    behind the moe_ep spatial partitioning)."""
    B, S, D, F, E, K = 2, 16, 32, 48, 8, 2
    params = moe_init(jax.random.PRNGKey(0), D, F, E)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
    full, _ = _moe_apply_core(params, x, top_k=K, capacity_factor=8.0)
    for n_groups in (2, 4):
        el = E // n_groups
        parts = []
        for m in range(n_groups):
            p_local = {k: (v[m * el:(m + 1) * el]
                           if k in ("w_in", "w_gate", "w_out") else v)
                       for k, v in params.items()}
            y, _ = _moe_apply_core(p_local, x, top_k=K, capacity_factor=8.0,
                                   expert_offset=m * el,
                                   n_global_experts=E)
            parts.append(y)
        np.testing.assert_allclose(np.asarray(sum(parts)),
                                   np.asarray(full), rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(off_group=st.integers(0, 3), cf=st.floats(0.5, 4.0))
def test_moe_ep_window_property(off_group, cf):
    """Windowed dispatch never assigns tokens outside its window and its
    drop stats stay in [0, 1]."""
    B, S, D, F, E, K = 1, 8, 16, 16, 8, 2
    params = moe_init(jax.random.PRNGKey(off_group), D, F, E)
    el = 2
    p_local = {k: (v[off_group * el:(off_group + 1) * el]
                   if k in ("w_in", "w_gate", "w_out") else v)
               for k, v in params.items()}
    x = jax.random.normal(jax.random.PRNGKey(7), (B, S, D))
    y, aux = _moe_apply_core(p_local, x, top_k=K, capacity_factor=cf,
                             expert_offset=off_group * el,
                             n_global_experts=E)
    assert bool(jnp.isfinite(y).all())
    assert 0.0 <= float(aux["drop_fraction"]) <= 1.0


def test_train_perf_options_preserve_loss():
    code = """
    import jax, jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.launch import steps as ST
    from repro.models import transformer as T
    from repro.sharding import specs as SH, param_specs
    cfg = get_reduced("granite_moe_1b_a400m")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = ST.make_optimizer(cfg); state = opt.init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0,
                                          cfg.vocab)}
    batch["labels"] = batch["tokens"]
    fn = ST.make_train_step(cfg, opt, remat=True)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    losses = {}
    for name, perf in [("baseline", {}),
                       ("moe_ep", {"moe_ep": True}),
                       ("zero3", {"zero3": True}),
                       ("dp+local", {"dp_over_model": True,
                                     "moe_local": True}),
                       ("skip+dots+sp", {"causal_skip": True,
                                         "dots_remat": True,
                                         "seq_shard": True})]:
        with SH.activations_on(mesh, **perf):
            ps = param_specs(params, mesh,
                             fsdp=not perf.get("dp_over_model"))
            args = (jax.device_put(params, ps),
                    {"step": state["step"],
                     "m": jax.device_put(state["m"], ps),
                     "v": jax.device_put(state["v"], ps)},
                    jax.device_put(batch,
                                   ST.batch_shardings(cfg, mesh, batch)))
            _, _, m = jax.jit(fn)(*args)
            losses[name] = float(m["loss"])
    base = losses["baseline"]
    assert all(abs(v - base) < 2e-2 for v in losses.values()), losses
    print("perf options ok", losses)
    """
    assert "perf options ok" in _run_in_subprocess(code)


def test_decode_cache_seq_shard_preserves_logits():
    code = """
    import jax, jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.launch import steps as ST
    from repro.models import transformer as T
    from repro.sharding import specs as SH, param_specs
    cfg = get_reduced("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 8, 32
    cache = T.init_cache(cfg, B, S, dtype=jnp.float32)
    tok = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
    fn = ST.make_decode_step(cfg)
    ref, _ = jax.jit(fn)(params, cache, tok, jnp.int32(S - 1))
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    with SH.activations_on(mesh, no_fsdp=True, cache_seq_shard=True):
        ps = param_specs(params, mesh, fsdp=False)
        cs = ST.cache_shardings(cfg, mesh, cache, B)
        lg, _ = jax.jit(fn)(
            jax.device_put(params, ps), jax.device_put(cache, cs),
            jax.device_put(tok,
                           ST.batch_shardings(cfg, mesh, {"t": tok})["t"]),
            jnp.int32(S - 1))
        assert float(jnp.abs(lg - ref).max()) < 1e-3
    print("decode seq-shard ok")
    """
    assert "decode seq-shard ok" in _run_in_subprocess(code)
