"""MoE dispatch equivalence + Mamba2 layer consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401
from conftest import given, settings, st  # hypothesis, or skip-stubs

import repro.models.layers as L
from repro.models.mamba2 import mamba_apply, mamba_init
from repro.models.moe import moe_apply, moe_init


def _dense_moe_ref(params, x, top_k):
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    probs = jax.nn.softmax(logits, -1)
    w, ids = jax.lax.top_k(probs, top_k)
    w = w / w.sum(-1, keepdims=True)
    h = jnp.einsum("bsd,edf->bsef", x, params["w_in"])
    g = jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, params["w_gate"]))
    y = jnp.einsum("bsef,efd->bsed", g * h, params["w_out"])
    out = jnp.zeros_like(x)
    for k in range(top_k):
        sel = jnp.take_along_axis(
            y, ids[..., k, None, None].repeat(x.shape[-1], -1), axis=2)[:, :, 0]
        out = out + w[..., k:k + 1] * sel
    if "shared" in params:
        out = out + L.mlp(params["shared"], x)
    return out


def test_moe_matches_dense_reference_no_drops():
    B, S, D, F, E, K = 3, 16, 32, 48, 8, 2
    params = moe_init(jax.random.PRNGKey(0), D, F, E, shared_f=64)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
    out, aux = moe_apply(params, x, top_k=K, capacity_factor=8.0)
    ref = _dense_moe_ref(params, x, K)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    assert float(aux["drop_fraction"]) == 0.0


def test_moe_capacity_drops_counted():
    B, S, D, F, E, K = 2, 64, 16, 16, 8, 4
    params = moe_init(jax.random.PRNGKey(0), D, F, E)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))
    _, aux = moe_apply(params, x, top_k=K, capacity_factor=0.25)
    assert float(aux["drop_fraction"]) > 0.1
    assert float(aux["aux_loss"]) > 0


def test_moe_grads_finite():
    params = moe_init(jax.random.PRNGKey(0), 16, 24, 4)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))

    def loss(p):
        o, a = moe_apply(p, x, top_k=2)
        return (o ** 2).mean() + 0.01 * a["aux_loss"]

    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.isfinite(leaf).all())


@settings(max_examples=8, deadline=None)
@given(s=st.integers(2, 40), k=st.integers(1, 4))
def test_moe_property_output_finite(s, k):
    params = moe_init(jax.random.PRNGKey(s), 8, 8, 8)
    x = jax.random.normal(jax.random.PRNGKey(s + 1), (1, s, 8))
    out, aux = moe_apply(params, x, top_k=k)
    assert bool(jnp.isfinite(out).all())
    assert 0.0 <= float(aux["drop_fraction"]) <= 1.0


# ---------------------------------------------------------------------------
# mamba2 layer
# ---------------------------------------------------------------------------

_MKW = dict(d_inner=64, n_heads=4, head_dim=16, d_state=16, n_groups=2)


def test_mamba_train_vs_decode_consistency():
    """Full forward == token-by-token recurrent decode."""
    d = 32
    params = mamba_init(jax.random.PRNGKey(0), d, conv_width=4, **_MKW)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 12, d))
    full, _ = mamba_apply(params, x, chunk=4, **_MKW)

    ssm = jnp.zeros((2, 4, 16, 16))
    conv = jnp.zeros((2, 3, 64 + 2 * 2 * 16))
    outs = []
    for t in range(12):
        y, (ssm, conv) = mamba_apply(params, x[:, t:t + 1], ssm_state=ssm,
                                     conv_state=conv, **_MKW)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


def test_mamba_prefill_state_then_decode():
    """Prefill returning state, then continue decoding — matches full."""
    d = 32
    params = mamba_init(jax.random.PRNGKey(0), d, conv_width=4, **_MKW)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (1, 16, d))
    full, _ = mamba_apply(params, x, chunk=4, **_MKW)
    y1, (ssm, conv) = mamba_apply(params, x[:, :8], chunk=4,
                                  ssm_state=jnp.zeros((1, 4, 16, 16)),
                                  conv_state=jnp.zeros((1, 3, 64 + 64)),
                                  **_MKW)
    outs = [y1]
    for t in range(8, 16):
        y, (ssm, conv) = mamba_apply(params, x[:, t:t + 1], ssm_state=ssm,
                                     conv_state=conv, **_MKW)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=5e-3, atol=5e-3)
