"""Data pipeline / optimizer / checkpoint / compression substrates."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis, or skip-stubs

from repro.checkpoint import CheckpointManager
from repro.data import Pipeline, SyntheticLM
from repro.optim import AdamW, ErrorFeedback, clip_by_global_norm, \
    compress_int8, cosine_schedule, decompress_int8


def test_pipeline_deterministic_and_host_sharded():
    src = SyntheticLM(vocab=97, seq_len=16, global_batch=8, seed=1)
    a = src.batch_at(5)
    b = src.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # two hosts' shards are disjoint parts of the same global batch
    h0 = src.batch_at(5, host_index=0, host_count=2)
    h1 = src.batch_at(5, host_index=1, host_count=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(h0["tokens"], h1["tokens"])
    # labels are next-token shifted
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).mean() > 0.99


def test_pipeline_resume_replays_stream():
    src = SyntheticLM(vocab=97, seq_len=8, global_batch=4)
    p1 = Pipeline(src)
    seen = [next(p1)["tokens"] for _ in range(5)]
    p2 = Pipeline(src)
    p2.restore({"step": 3})
    np.testing.assert_array_equal(next(p2)["tokens"], seen[3])


def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, warmup=1, total=200, weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    target = jnp.array([1.0, 1.0])
    for _ in range(150):
        g = jax.grad(lambda p: ((p["w"] - target) ** 2).sum())(params)
        params, state, _ = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_adamw_bf16_state_dtype():
    opt = AdamW(state_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4))}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16    # 398B memory tradeoff


def test_clip_and_schedule():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-3)
    assert float(cosine_schedule(0, lr=1.0, warmup=10, total=100)) == 0.0
    assert float(cosine_schedule(10, lr=1.0, warmup=10, total=100)) \
        == pytest.approx(1.0, rel=1e-3)


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.int32(7)},
             "data": {"step": np.int64(9)}}
    for s in (10, 20, 30):
        mgr.save(s, state, extra={"arch": "t"})
    assert mgr.steps() == [20, 30]                  # gc keeps newest 2
    restored, manifest = mgr.restore(state)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    assert manifest["step"] == 30 and manifest["extra"]["arch"] == "t"


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.ones(3)})
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]


def test_int8_compression_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q, s = compress_int8(x)
    y = decompress_int8(q, s, x.shape, x.dtype)
    err = np.abs(np.asarray(x - y))
    # per-block max-abs / 127 bounds the quantization error
    assert err.max() <= float(np.abs(np.asarray(x)).max()) / 127 + 1e-6


def test_error_feedback_reduces_bias():
    """With error feedback, the mean compressed gradient over many steps
    tracks the true gradient (residual carries the rounding error)."""
    g_true = {"w": jnp.full((256,), 0.003)}   # below half-step of quantizer
    residual = ErrorFeedback.init(g_true)
    acc = jnp.zeros((256,))
    for _ in range(50):
        g_q, residual = ErrorFeedback.apply(g_true, residual)
        acc = acc + g_q["w"]
    mean = np.asarray(acc) / 50
    np.testing.assert_allclose(mean, 0.003, rtol=0.05)


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 1e3), n=st.integers(1, 2000))
def test_compression_property_roundtrip(scale, n):
    x = jnp.sin(jnp.arange(n, dtype=jnp.float32)) * scale
    q, s = compress_int8(x)
    y = decompress_int8(q, s, x.shape, x.dtype)
    assert np.abs(np.asarray(x - y)).max() <= scale / 127 + 1e-9
