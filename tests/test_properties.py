"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401
from conftest import given, settings, st  # hypothesis, or skip-stubs

from repro.core import Op, OpGraph, schedule
from repro.data import SyntheticLM
from repro.models import layers as L
from repro.roofline.analyze import HloModule


# ---------------------------------------------------------------------------
# graph invariants
# ---------------------------------------------------------------------------

def _random_dag(n_ops: int, seed: int) -> OpGraph:
    rng = np.random.default_rng(seed)
    g = OpGraph()
    for i in range(n_ops):
        deps = [f"op{j}" for j in range(i) if rng.random() < 0.3]
        g.add(Op.make(f"op{i}", "matmul", m=int(rng.integers(64, 512)),
                      k=256, n=256), deps)
    return g


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 12), seed=st.integers(0, 1000))
def test_graph_levels_partition_and_schedule_covers(n, seed):
    g = _random_dag(n, seed)
    levels = g.levels()
    flat = [x for lvl in levels for x in lvl]
    assert sorted(flat) == sorted(g.ops)           # levels partition the DAG
    # independence is symmetric and anti-reflexive on dependent pairs
    for lvl in levels:
        for a in lvl:
            for b in lvl:
                if a != b:
                    assert g.independent(a, b) == g.independent(b, a)
    # every schedule covers every op exactly once
    sch = schedule(g)
    seen = [o for grp in sch.groups for o in grp.ops]
    assert sorted(seen) == sorted(g.ops)
    # co-execution groups contain only mutually independent ops
    for grp in sch.groups:
        for a in grp.ops:
            for b in grp.ops:
                if a != b:
                    assert g.independent(a, b), (a, b)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 10), seed=st.integers(0, 1000))
def test_concurrent_never_slower_than_serial(n, seed):
    g = _random_dag(n, seed)
    serial = schedule(g, concurrent=False).makespan
    conc = schedule(g, concurrent=True).makespan
    assert conc <= serial * 1.001


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(hosts=st.sampled_from([1, 2, 4]), step=st.integers(0, 50))
def test_pipeline_host_decomposition(hosts, step):
    """Any host count yields the same per-host-shard determinism and the
    full batch is recoverable (shapes compose)."""
    src = SyntheticLM(vocab=101, seq_len=8, global_batch=8)
    shards = [src.batch_at(step, host_index=h, host_count=hosts)
              for h in range(hosts)]
    total = sum(s["tokens"].shape[0] for s in shards)
    assert total == 8
    again = [src.batch_at(step, host_index=h, host_count=hosts)
             for h in range(hosts)]
    for a, b in zip(shards, again):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(d=st.sampled_from([8, 32, 128]), scale=st.floats(0.1, 10.0))
def test_rmsnorm_scale_invariance(d, scale):
    """RMSNorm(x) == RMSNorm(c*x) — the defining invariant."""
    p = L.rmsnorm_init(d)
    x = jax.random.normal(jax.random.PRNGKey(d), (2, 5, d))
    a = L.rmsnorm(p, x)
    b = L.rmsnorm(p, x * scale)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                               atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(1, 16), theta=st.sampled_from([1e4, 5e5]))
def test_rope_preserves_norm_and_relativity(s, theta):
    d = 32
    x = jax.random.normal(jax.random.PRNGKey(s), (1, s, 2, d))
    pos = jnp.arange(s)[None]
    y = L.rope(x, pos, theta)
    # rotation preserves per-head norms
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-4, atol=1e-4)
    # dot products depend only on relative offset
    q = L.rope(x, pos, theta)
    k = L.rope(x, pos + 7, theta)
    d1 = jnp.einsum("bshd,bshd->bsh", q, k)
    q2 = L.rope(x, pos + 3, theta)
    k2 = L.rope(x, pos + 10, theta)
    d2 = jnp.einsum("bshd,bshd->bsh", q2, k2)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-3,
                               atol=1e-3)


def test_cross_entropy_matches_manual():
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 7, 13))
    labels = jax.random.randint(jax.random.PRNGKey(1), (4, 7), 0, 13)
    got = L.cross_entropy(logits, labels)
    p = jax.nn.log_softmax(logits, -1)
    want = -jnp.take_along_axis(p, labels[..., None], -1).mean()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# roofline analyzer ring formulas
# ---------------------------------------------------------------------------

def test_collective_ring_models():
    hlo = """
HloModule test
ENTRY %main.1 (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %ag = f32[64,128]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}
  %ar = f32[64,128]{1,0} all-reduce(%ag), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %cp = f32[64,128]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    cost = HloModule(hlo).cost()
    b = 64 * 128 * 4
    want = b * 3 / 4 + b * 2 * 3 / 4 + b   # AG + AR + permute
    assert abs(cost.wire_bytes - want) / want < 1e-6
