"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401
from conftest import given, settings, st  # hypothesis, or skip-stubs

from repro import kernels as K
from repro.core import Op, OpGraph, schedule
from repro.data import SyntheticLM
from repro.kernels import ops as kops
from repro.models import layers as L
from repro.roofline.analyze import HloModule


# ---------------------------------------------------------------------------
# graph invariants
# ---------------------------------------------------------------------------

def _random_dag(n_ops: int, seed: int) -> OpGraph:
    rng = np.random.default_rng(seed)
    g = OpGraph()
    for i in range(n_ops):
        deps = [f"op{j}" for j in range(i) if rng.random() < 0.3]
        g.add(Op.make(f"op{i}", "matmul", m=int(rng.integers(64, 512)),
                      k=256, n=256), deps)
    return g


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 12), seed=st.integers(0, 1000))
def test_graph_levels_partition_and_schedule_covers(n, seed):
    g = _random_dag(n, seed)
    levels = g.levels()
    flat = [x for lvl in levels for x in lvl]
    assert sorted(flat) == sorted(g.ops)           # levels partition the DAG
    # independence is symmetric and anti-reflexive on dependent pairs
    for lvl in levels:
        for a in lvl:
            for b in lvl:
                if a != b:
                    assert g.independent(a, b) == g.independent(b, a)
    # every schedule covers every op exactly once
    sch = schedule(g)
    seen = [o for grp in sch.groups for o in grp.ops]
    assert sorted(seen) == sorted(g.ops)
    # co-execution groups contain only mutually independent ops
    for grp in sch.groups:
        for a in grp.ops:
            for b in grp.ops:
                if a != b:
                    assert g.independent(a, b), (a, b)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 10), seed=st.integers(0, 1000))
def test_concurrent_never_slower_than_serial(n, seed):
    g = _random_dag(n, seed)
    serial = schedule(g, concurrent=False).makespan
    conc = schedule(g, concurrent=True).makespan
    assert conc <= serial * 1.001


# ---------------------------------------------------------------------------
# grouped kernel family: generated ragged branch sets vs the XLA oracle
# ---------------------------------------------------------------------------

# unaligned K/N widths straddling the 128 block boundary, odd M rows,
# both dtypes — the corners hand-picked RAGGED_SETS enumerations miss
_MS = (33, 77, 130)
_KS = (17, 64, 100, 129, 300)
_NS = (16, 60, 129, 208)
_DTYPES = ("float32", "bfloat16")


def _gen_branch_set(m, kidx, nidx, g, dtype, seed=0):
    """Deterministic ragged branch set from index choices (shared by the
    hypothesis strategies and the seeded fallback sweep)."""
    dt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3 * g)
    shapes = [(_KS[(kidx + i) % len(_KS)], _NS[(nidx + i) % len(_NS)])
              for i in range(g)]
    xs = [jax.random.normal(ks[3 * i], (m, kg), dt) * 0.3
          for i, (kg, _) in enumerate(shapes)]
    ws = [jax.random.normal(ks[3 * i + 1], (kg, ng), dt) * 0.3
          for i, (kg, ng) in enumerate(shapes)]
    bs = [jax.random.normal(ks[3 * i + 2], (ng,), dt)
          for i, (_, ng) in enumerate(shapes)]
    return shapes, xs, ws, bs


def _tol(dtype):
    return 1e-4 if dtype == "float32" else 6e-2


def _check_grouped_family(m, kidx, nidx, g, dtype, seed):
    """Forward + VJP equivalence of grouped / grouped_concat /
    grouped_pooled against the per-branch XLA oracle on one generated
    branch set."""
    shapes, xs, ws, bs = _gen_branch_set(m, kidx, nidx, g, dtype, seed)
    tol = _tol(dtype)

    def close(a, b):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=tol, atol=tol)

    # grouped forward
    got = K.grouped_matmul(xs, ws, bs, relu=True)
    want = K.grouped_matmul_ref(xs, ws, bs, relu=True)
    for a, b in zip(got, want):
        close(a, b)

    # grouped_concat forward (gap after branch 0 exercises passthrough)
    offs, off = [], 7
    for _, n in shapes:
        offs.append(off)
        off += n + 3
    y = kops.grouped_matmul_concat(xs, ws, bs, offsets=offs, total=off,
                                   relu=True)
    yref = K.grouped_matmul_concat_ref(xs, ws, bs, offsets=offs, total=off,
                                       relu=True)
    for o, (_, n) in zip(offs, shapes):
        close(y[:, o:o + n], yref[:, o:o + n])

    # grouped_pooled forward: branch 0's lhs becomes a pooled activation
    # (tap views of a (1, m, K0, 1)-shaped NHWC raw input -> same M)
    x4 = xs[0].reshape(1, m, shapes[0][0], 1)
    taps = tuple(t.reshape(m, shapes[0][0])
                 for t in K.pool_tap_views(x4, ((3, 1),)))
    xs_p = [taps] + xs[1:]
    got = kops.grouped_matmul_pooled(xs_p, ws, bs, relu=True)
    want = K.grouped_matmul_pooled_ref(xs_p, ws, bs, relu=True)
    for a, b in zip(got, want):
        close(a, b)

    # VJP equivalence on the grouped path (pooled branch included)
    def loss(fn):
        return lambda xs, ws, bs: sum(
            (y.astype(jnp.float32) ** 2).sum()
            for y in fn(xs, ws, bs, relu=True))

    ga = jax.grad(loss(kops.grouped_matmul_pooled),
                  argnums=(0, 1, 2))(xs_p, ws, bs)
    gb = jax.grad(loss(K.grouped_matmul_pooled_ref),
                  argnums=(0, 1, 2))(xs_p, ws, bs)
    for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        close(a, b)


@settings(max_examples=6, deadline=None)
@given(m=st.sampled_from(_MS), kidx=st.integers(0, len(_KS) - 1),
       nidx=st.integers(0, len(_NS) - 1), g=st.integers(1, 4),
       dtype=st.sampled_from(_DTYPES), seed=st.integers(0, 100))
def test_grouped_family_matches_oracle_property(m, kidx, nidx, g, dtype,
                                                seed):
    """Hypothesis sweep: any ragged branch set (mixed K/N, unaligned
    widths, f32/bf16) runs the grouped family to the same values and
    gradients as the per-branch XLA oracle."""
    _check_grouped_family(m, kidx, nidx, g, dtype, seed)


@pytest.mark.parametrize("m,kidx,nidx,g,dtype,seed", [
    (33, 0, 1, 2, "float32", 3),
    (77, 2, 0, 3, "bfloat16", 5),
    (130, 4, 3, 1, "float32", 7),
    (64, 1, 2, 4, "float32", 11),
])
def test_grouped_family_matches_oracle_seeded(m, kidx, nidx, g, dtype,
                                              seed):
    """Deterministic slice of the property sweep — runs even on hosts
    without hypothesis (where the @given test skips)."""
    _check_grouped_family(m, kidx, nidx, g, dtype, seed)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(hosts=st.sampled_from([1, 2, 4]), step=st.integers(0, 50))
def test_pipeline_host_decomposition(hosts, step):
    """Any host count yields the same per-host-shard determinism and the
    full batch is recoverable (shapes compose)."""
    src = SyntheticLM(vocab=101, seq_len=8, global_batch=8)
    shards = [src.batch_at(step, host_index=h, host_count=hosts)
              for h in range(hosts)]
    total = sum(s["tokens"].shape[0] for s in shards)
    assert total == 8
    again = [src.batch_at(step, host_index=h, host_count=hosts)
             for h in range(hosts)]
    for a, b in zip(shards, again):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(d=st.sampled_from([8, 32, 128]), scale=st.floats(0.1, 10.0))
def test_rmsnorm_scale_invariance(d, scale):
    """RMSNorm(x) == RMSNorm(c*x) — the defining invariant."""
    p = L.rmsnorm_init(d)
    x = jax.random.normal(jax.random.PRNGKey(d), (2, 5, d))
    a = L.rmsnorm(p, x)
    b = L.rmsnorm(p, x * scale)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                               atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(s=st.integers(1, 16), theta=st.sampled_from([1e4, 5e5]))
def test_rope_preserves_norm_and_relativity(s, theta):
    d = 32
    x = jax.random.normal(jax.random.PRNGKey(s), (1, s, 2, d))
    pos = jnp.arange(s)[None]
    y = L.rope(x, pos, theta)
    # rotation preserves per-head norms
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-4, atol=1e-4)
    # dot products depend only on relative offset
    q = L.rope(x, pos, theta)
    k = L.rope(x, pos + 7, theta)
    d1 = jnp.einsum("bshd,bshd->bsh", q, k)
    q2 = L.rope(x, pos + 3, theta)
    k2 = L.rope(x, pos + 10, theta)
    d2 = jnp.einsum("bshd,bshd->bsh", q2, k2)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), rtol=1e-3,
                               atol=1e-3)


def test_cross_entropy_matches_manual():
    logits = jax.random.normal(jax.random.PRNGKey(0), (4, 7, 13))
    labels = jax.random.randint(jax.random.PRNGKey(1), (4, 7), 0, 13)
    got = L.cross_entropy(logits, labels)
    p = jax.nn.log_softmax(logits, -1)
    want = -jnp.take_along_axis(p, labels[..., None], -1).mean()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


# ---------------------------------------------------------------------------
# roofline analyzer ring formulas
# ---------------------------------------------------------------------------

def test_collective_ring_models():
    hlo = """
HloModule test
ENTRY %main.1 (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %ag = f32[64,128]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}
  %ar = f32[64,128]{1,0} all-reduce(%ag), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %cp = f32[64,128]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    cost = HloModule(hlo).cost()
    b = 64 * 128 * 4
    want = b * 3 / 4 + b * 2 * 3 / 4 + b   # AG + AR + permute
    assert abs(cost.wire_bytes - want) / want < 1e-6
