"""Sharding specs + spatial branch-parallelism + ring collectives.

Multi-device cases run in a subprocess with 8 forced host devices so the
main pytest process keeps the real (1-device) topology.
"""
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.configs import get_config
from repro.launch.steps import input_specs  # noqa: F401 (import check)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_in_subprocess(code: str):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert res.returncode == 0, f"\nSTDOUT:{res.stdout}\nSTDERR:{res.stderr}"
    return res.stdout


def test_param_specs_divisibility_rules():
    """Non-divisible dims must stay unsharded in param specs."""
    code = """
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.sharding import param_specs
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    for arch in ("internvl2_1b", "qwen2_moe_a2_7b", "llama3_8b"):
        cfg = get_config(arch)
        sds = jax.eval_shape(lambda k: T.init_params(cfg, k, jnp.bfloat16),
                             jax.random.PRNGKey(0))
        specs = param_specs(sds, mesh)
        for (leaf, spec) in zip(jax.tree.leaves(sds), jax.tree.leaves(
                specs, is_leaf=lambda x: hasattr(x, "spec"))):
            for dim, ax in zip(leaf.shape, spec.spec):
                if ax is None: continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = 1
                for a in axes: n *= mesh.shape[a]
                assert dim % n == 0, (arch, leaf.shape, spec.spec)
    print("param specs ok")
    """
    assert "param specs ok" in _run_in_subprocess(code)


def test_spatial_branch_parallel_matches_serial():
    """Inter-chip spatial partitioning (the paper's inter-SM analogue)
    computes exactly what serial branch execution computes."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import Branches, run_spatial, run_xla
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("model",))
    fns = [lambda x, i=i: jnp.tanh(x * (i + 1)) for i in range(4)]
    br = Branches(fns, combine="concat")
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 12))
    want = run_xla(br, x)
    got = jax.jit(lambda x: run_spatial(br, x, mesh))(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # sum combine too (MoE-style join)
    br2 = Branches(fns, combine="sum")
    np.testing.assert_allclose(
        np.asarray(jax.jit(lambda x: run_spatial(br2, x, mesh))(x)),
        np.asarray(run_xla(br2, x)), rtol=1e-5, atol=1e-5)
    print("spatial ok")
    """
    assert "spatial ok" in _run_in_subprocess(code)


def test_ring_collective_matmuls():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.sharding.collectives import (matmul_allgather_x,
                                            matmul_reducescatter)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((8,), ("model",))
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (64, 32)); w = jax.random.normal(k2, (32, 48))
    xs = jax.device_put(x, NamedSharding(mesh, P("model", None)))
    ws = jax.device_put(w, NamedSharding(mesh, P(None, "model")))
    y = jax.jit(lambda a, b: matmul_allgather_x(a, b, mesh))(xs, ws)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-4,
                               atol=1e-4)
    x2 = jax.random.normal(k1, (64, 128)); w2 = jax.random.normal(k2, (128, 40))
    xs2 = jax.device_put(x2, NamedSharding(mesh, P(None, "model")))
    ws2 = jax.device_put(w2, NamedSharding(mesh, P("model", None)))
    y2 = jax.jit(lambda a, b: matmul_reducescatter(a, b, mesh))(xs2, ws2)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(x2 @ w2),
                               rtol=1e-4, atol=1e-4)
    print("rings ok")
    """
    assert "rings ok" in _run_in_subprocess(code)


def test_sharded_train_step_matches_single_device():
    """DP+TP sharded train step == single-device train step (same math)."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_reduced, SHAPES
    from repro.launch import steps as ST
    from repro.models import transformer as T
    from repro.sharding import specs as SH, param_specs, data_spec
    cfg = get_reduced("llama3_8b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = ST.make_optimizer(cfg)
    state = opt.init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                          cfg.vocab)}
    batch["labels"] = batch["tokens"]
    fn = ST.make_train_step(cfg, opt, remat=False)
    p1, s1, m1 = jax.jit(fn)(params, state, batch)

    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    ps = param_specs(params, mesh)
    params_sh = jax.device_put(params, ps)
    state_sh = {"step": jax.device_put(state["step"]),
                "m": jax.device_put(state["m"], ps),
                "v": jax.device_put(state["v"], ps)}
    batch_sh = jax.device_put(batch, ST.batch_shardings(cfg, mesh, batch))
    with SH.activations_on(mesh):
        p2, s2, m2 = jax.jit(fn)(params_sh, state_sh, batch_sh)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3, \
        (float(m1["loss"]), float(m2["loss"]))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                                   atol=2e-3)
    print("sharded step ok")
    """
    assert "sharded step ok" in _run_in_subprocess(code)
