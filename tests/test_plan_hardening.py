"""Hardening sweep for the plan stack: the device-resident offset-table
cache, tier-1 launch-count guardrails (promoted from the benchmark's
eager probe), and the grouped-pricing drift fix."""
import importlib

import jax
import jax.numpy as jnp
import numpy as np

from repro import kernels as K
from repro.configs import get_config, get_reduced
from repro.core import (Op, co_execution_time, gemm_profiles, gemm_shape,
                        group_execution_time, grouped_time, profile,
                        stacked_time)
from repro.kernels import ops as kops
from repro.models import cnn as CNN

gmm = importlib.import_module("repro.kernels.grouped_matmul")


# ---------------------------------------------------------------------------
# offset-table cache (_plan_tiles*_dev): the PR-4 wall fix, under test
# ---------------------------------------------------------------------------

def test_device_table_cache_hits_across_same_shape_calls():
    """Repeated same-shape launches reuse ONE device-resident table — the
    per-call re-upload was the bwd_wall_ordering regression PR 4 fixed."""
    gmm._device_table.cache_clear()
    xs = [jax.random.normal(jax.random.PRNGKey(0), (64, 100)) * 0.3,
          jax.random.normal(jax.random.PRNGKey(1), (64, 300)) * 0.3]
    ws = [jax.random.normal(jax.random.PRNGKey(2), (100, 60)) * 0.3,
          jax.random.normal(jax.random.PRNGKey(3), (300, 129)) * 0.3]
    K.grouped_matmul(xs, ws)
    info1 = gmm._device_table.cache_info()
    K.grouped_matmul(xs, ws)
    K.grouped_matmul([x * 2 for x in xs], ws)     # same shapes, new values
    info2 = gmm._device_table.cache_info()
    assert info2.currsize == info1.currsize        # no new entry
    assert info2.hits >= info1.hits + 2            # both calls hit
    # the cached table is the SAME concrete device array (no re-upload)
    t1 = gmm._device_table(gmm._plan_tiles, 1, (1, 3), (1, 2))
    t2 = gmm._device_table(gmm._plan_tiles, 1, (1, 3), (1, 2))
    assert t1 is t2
    assert isinstance(t1, jax.Array)


def test_device_table_cache_invalidates_on_new_tile_grid():
    """A new tile-grid shape gets its own entry; a same-grid call with
    different M padding inside the same block count does not."""
    gmm._device_table.cache_clear()
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 100)) * 0.3
    w = jax.random.normal(jax.random.PRNGKey(1), (100, 60)) * 0.3
    K.grouped_matmul([x], [w])
    size1 = gmm._device_table.cache_info().currsize
    # M=40 still pads to one 128-row block: same tile grid, cache hit
    K.grouped_matmul([x[:40]], [w])
    assert gmm._device_table.cache_info().currsize == size1
    # a second k-block is a NEW tile grid -> new entry
    x2 = jax.random.normal(jax.random.PRNGKey(2), (64, 200)) * 0.3
    w2 = jax.random.normal(jax.random.PRNGKey(3), (200, 60)) * 0.3
    K.grouped_matmul([x2], [w2])
    assert gmm._device_table.cache_info().currsize == size1 + 1
    # the backward/concat/pooled builders key separately (builder is part
    # of the cache key), never colliding with the forward tables
    gmm._device_table(gmm._plan_tiles_bwd, 1, (1,), (1,))
    gmm._device_table(gmm._plan_tiles_concat, 1, (1,), (1,))
    gmm._device_table(gmm._plan_tiles_pooled, 1, (1,), (1,), (9,), False)
    assert gmm._device_table.cache_info().currsize == size1 + 4


def test_device_table_cache_bounded_under_shape_sweep():
    """A sweep of distinct tile grids stays within the LRU bound and
    creates exactly one entry per distinct grid."""
    gmm._device_table.cache_clear()
    grids = [(mb, (kb,), (nb,))
             for mb in (1, 2, 3) for kb in (1, 2, 4) for nb in (1, 2)]
    for mb, kbs, nbs in grids:
        gmm._device_table(gmm._plan_tiles, mb, kbs, nbs)
        gmm._device_table(gmm._plan_tiles, mb, kbs, nbs)   # re-hit
    info = gmm._device_table.cache_info()
    assert info.currsize == len(grids)
    assert info.currsize <= 512                    # the LRU bound
    assert info.hits >= len(grids)


# ---------------------------------------------------------------------------
# launch-count guardrails (tier-1, was only a ci.sh benchmark probe)
# ---------------------------------------------------------------------------

def test_googlenet_launches_per_module_fwd_and_bwd():
    """The eager KERNEL_LAUNCHES probe as a pytest gate, on the runnable
    googlenet slice (googlenet-reduced — same family, one pooled module):
    with pooling fused, each inception module is exactly TWO
    grouped-family launches per direction (the pooled quad and the
    join-absorbing pair — its two stages are data-dependent, so two is
    the launch floor), i.e. ONE launch per co-execution group, and ZERO
    standalone pooling or join launches."""
    cfg = get_reduced("googlenet")
    plan, _ = CNN.plan_cnn(cfg, batch=2)
    fam = ("grouped", "grouped_concat", "grouped_pooled")
    n_groups = sum(1 for g in plan.groups if g.mode in fam)
    assert n_groups == 2 * len(cfg.modules)
    # zero standalone pool/join groups in the lowered plan
    assert not [g for g in plan.groups
                if any(n.endswith("/pool") or n.endswith("/pppool")
                       for n in g.ops)]
    assert not [g for g in plan.groups
                if g.mode != "grouped_concat"
                and any(n.endswith("/join") for n in g.ops)]
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, *cfg.img), jnp.float32)
    fwd_names = ("grouped_matmul", "grouped_matmul_concat",
                 "grouped_matmul_pooled", "grouped_matmul_pooled_concat")

    kops.reset_launch_counts()
    y, f_vjp = jax.vjp(lambda p: CNN.forward_plan(p, cfg, x, plan), params)
    fwd_launches = sum(kops.KERNEL_LAUNCHES.get(n, 0) for n in fwd_names)
    assert fwd_launches == n_groups, dict(kops.KERNEL_LAUNCHES)
    # the pooled quads launch the POOLED kernel (the pool stage is real)
    assert kops.KERNEL_LAUNCHES.get("grouped_matmul_pooled", 0) \
        == len(plan.groups_of_mode("grouped_pooled"))

    kops.reset_launch_counts()
    jax.block_until_ready(f_vjp(jnp.ones_like(y)))
    assert kops.KERNEL_LAUNCHES.get("grouped_matmul_bwd", 0) == n_groups, \
        dict(kops.KERNEL_LAUNCHES)
    # one COMBINED launch per grad CoGroup: no separate dw kernel, no
    # second grouped pass
    assert "grouped_matmul_dw" not in kops.KERNEL_LAUNCHES
    assert not any(kops.KERNEL_LAUNCHES.get(n, 0) for n in fwd_names)


def test_googlenet_full_plan_single_launch_structure():
    """Full-size googlenet, lowering level (execution is the reduced
    test's job): 9 pooled quads + 9 concat pairs and nothing else
    multi-op — the structure whose eager counters the reduced net pins."""
    plan, _ = CNN.plan_cnn(get_config("googlenet"), batch=32)
    fam = ("grouped", "grouped_concat", "grouped_pooled")
    multi = [g for g in plan.groups if len(g.ops) > 1]
    assert len(multi) == 18 and all(g.mode in fam for g in multi)
    assert len(plan.groups_of_mode("grouped_pooled")) == 9
    assert len(plan.groups_of_mode("grouped_concat")) == 9


# ---------------------------------------------------------------------------
# grouped pricing: off the GEMM lowering (the docstring-drift fix)
# ---------------------------------------------------------------------------

def _ragged_conv_fixture():
    """An inception-like ragged branch set sharing M (im2col views)."""
    return [
        Op.make("a", "conv2d", n=8, h=14, w=14, c=480, kh=1, kw=1, k=192),
        Op.make("b", "conv2d", n=8, h=14, w=14, c=96, kh=3, kw=3, k=208),
        Op.make("c", "conv2d", n=8, h=14, w=14, c=16, kh=5, kw=5, k=48),
    ]


def test_grouped_priced_off_gemm_shape_not_chosen_algorithm():
    """The fix: grouped/stacked makespans come from the GEMM lowering the
    kernel executes — the scheduler's per-op algorithm choice (which only
    governs the serial fallback) no longer moves the group's price."""
    ops = _ragged_conv_fixture()
    profs_im2col = [profile(op, "im2col_gemm") for op in ops]
    profs_direct = [profile(op, "direct") for op in ops]
    assert profs_im2col[1].time != profs_direct[1].time   # algs DO differ
    mode1, t1 = group_execution_time(ops, profs_im2col)
    mode2, t2 = group_execution_time(ops, profs_direct)
    assert mode1 == mode2 == "grouped"
    assert t1 == t2                                       # price does not
    assert t1 == grouped_time(ops) == co_execution_time(gemm_profiles(ops))


def test_modeled_grouped_not_worse_than_stacked_on_ragged():
    """With both arms priced off the same GEMM lowering, the ragged
    fixture's pad-to-max waste makes stacked strictly worse — the
    ordering the old chosen-algorithm proxy could invert."""
    ops = _ragged_conv_fixture()
    gprofs = gemm_profiles(ops)
    shapes = [gemm_shape(op) for op in ops]
    assert grouped_time(ops) <= stacked_time(gprofs, shapes)
    # and on a genuinely uniform set the two coincide (stacked pads
    # nothing), so the auto choice may legitimately pick stacked
    uni = [Op.make(f"u{i}", "matmul", m=1024, k=256, n=256)
           for i in range(3)]
    np.testing.assert_allclose(
        grouped_time(uni),
        stacked_time(gemm_profiles(uni), [gemm_shape(op) for op in uni]),
        rtol=1e-12)


def test_gemm_profiles_charge_patch_workspace_to_budget_only():
    """K×K/strided convs charge the im2col patch buffer to the C2
    workspace budget (like backward_profiles), not to the launch's HBM
    time — packing layout passes ride the kernel's DMA."""
    op3 = Op.make("b", "conv2d", n=8, h=14, w=14, c=96, kh=3, kw=3, k=208)
    op1 = Op.make("a", "conv2d", n=8, h=14, w=14, c=480, kh=1, kw=1, k=192)
    (p3,) = gemm_profiles([op3])
    (p1,) = gemm_profiles([op1])
    m, k, _ = gemm_shape(op3)
    assert p3.workspace_bytes == m * k * op3.dtype_bytes
    assert p1.workspace_bytes == 0.0
    mm = profile(Op.make("g", "matmul", dtype_bytes=op3.dtype_bytes,
                         m=m, k=k, n=208), "mxu128")
    assert p3.hbm_bytes == mm.hbm_bytes
