"""Pooled grouped launch (maxpool streamed through the grouped kernel):
tap-view semantics, kernel equivalence, the single combined backward
launch, pool absorption lowering + degrade, pool_profile pricing, and the
zero-reduce_window end state on googlenet."""
import dataclasses
import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels as K
from repro.configs import get_config, get_reduced
from repro.core import (Op, OpGraph, OpImpl, backward_plan, lower,
                        pool_profile, profile, run_plan, serial_time)
from repro.core.scheduler import CoGroup, Schedule
from repro.kernels import ops as kops
from repro.models import cnn as CNN
from repro.models.cnn import maxpool, maxpool_chain

gmm = importlib.import_module("repro.kernels.grouped_matmul")

RAGGED_SETS = [
    [(None, 60), (100, 129)],            # pooled + plain, unaligned
    [(None, 16)],                        # pooled singleton
    [(None, 96), (64, 16), (None, 208)],  # two pooled branches
]


def _pooled_branches(b, h, w, c, shapes, dtype, chain=((3, 1),), key=0):
    """Branch set over a (B, H, W, C) activation: K_g=None branches pool
    the activation with ``chain`` (tap views in, like the executor hands
    the kernel); others take an independent (M, K_g) lhs."""
    m_raw = b * h * w
    oh, ow = h, w
    for win, s in chain:
        oh, ow = -(-oh // s), -(-ow // s)
    m = b * oh * ow
    ks = jax.random.split(jax.random.PRNGKey(key), 3 * len(shapes) + 1)
    x4 = jnp.maximum(jax.random.normal(ks[-1], (b, h, w, c), dtype), 0)
    taps = tuple(t.reshape(-1, c) for t in K.pool_tap_views(x4, chain))
    xs, ws, bs = [], [], []
    for i, (kg, ng) in enumerate(shapes):
        if kg is None:
            xs.append(taps)
            kg = c
        else:
            xs.append(jax.random.normal(ks[3 * i], (m, kg), dtype) * 0.3)
        ws.append(jax.random.normal(ks[3 * i + 1], (kg, ng), dtype) * 0.3)
        bs.append(jax.random.normal(ks[3 * i + 2], (ng,), dtype))
    return x4, xs, ws, bs, m


# ---------------------------------------------------------------------------
# tap views: the pool-as-layout decomposition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chain", [((3, 1),), ((3, 2),), ((3, 2), (3, 1))])
def test_pool_tap_views_match_reduce_window(chain):
    """max over the tap views == reduce_window maxpool chain, forward AND
    gradient — including the first-argmax tie routing on ReLU-zero-heavy
    inputs (odd extents exercise the asymmetric SAME padding)."""
    x = jnp.maximum(jax.random.normal(jax.random.PRNGKey(0), (2, 7, 6, 3)),
                    0.0)
    want = maxpool_chain(x, chain)
    got = K.pool_from_taps(K.pool_tap_views(x, chain))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    wt = jnp.arange(1, want.size + 1, dtype=jnp.float32).reshape(want.shape)
    g_ref = jax.grad(lambda x: (maxpool_chain(x, chain) * wt).sum())(x)
    g_tap = jax.grad(lambda x: (K.pool_from_taps(
        K.pool_tap_views(x, chain)) * wt).sum())(x)
    np.testing.assert_array_equal(np.asarray(g_tap), np.asarray(g_ref))


def test_pool_from_taps_propagates_nan_like_reduce_window():
    """A NaN upstream must poison its pool windows on the fused path
    exactly as the reduce_window baseline does — a bare `v > acc` select
    would silently drop it, making the two documented-equivalent paths
    diverge precisely when someone is debugging a NaN."""
    x = jnp.maximum(jax.random.normal(jax.random.PRNGKey(0), (1, 5, 5, 2)),
                    0.0)
    x = x.at[0, 2, 3, 1].set(jnp.nan)
    want = maxpool(x, 3, 1)
    got = K.pool_from_taps(K.pool_tap_views(x, ((3, 1),)))
    np.testing.assert_array_equal(np.isnan(np.asarray(got)),
                                  np.isnan(np.asarray(want)))
    finite = ~np.isnan(np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got)[finite],
                                  np.asarray(want)[finite])
    # and through the kernel's in-kernel fold (the pool scratch uses the
    # same NaN-aware select); the GEMM then spreads a pooled NaN across
    # its output row (NaN * 0 = NaN), so the row pattern is the check
    taps = [t.reshape(-1, 2) for t in K.pool_tap_views(x, ((3, 1),))]
    w = jnp.eye(2, dtype=jnp.float32)
    (y,) = gmm.grouped_matmul_pooled([taps], [w], interpret=True)
    rows = np.isnan(np.asarray(want).reshape(-1, 2)).any(axis=1)
    np.testing.assert_array_equal(np.isnan(np.asarray(y)).any(axis=1), rows)
    np.testing.assert_array_equal(np.isnan(np.asarray(y)).all(axis=1), rows)


def test_pool_cotangent_taps_first_argmax():
    taps = [jnp.array([[1., 0.], [0., 2.]]), jnp.array([[1., 3.], [0., 2.]])]
    pooled = K.pool_from_taps(taps)
    d = jnp.ones((2, 2))
    d0, d1 = gmm.pool_cotangent_taps(taps, pooled, d)
    # ties (both rows of col 0, and (1,1)) go wholly to the FIRST maximal
    # tap, never split — only (0,1) belongs to tap 1 outright
    np.testing.assert_array_equal(np.asarray(d0),
                                  np.array([[1., 0.], [1., 1.]]))
    np.testing.assert_array_equal(np.asarray(d1),
                                  np.array([[0., 1.], [0., 0.]]))


# ---------------------------------------------------------------------------
# kernel equivalence
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shapes", RAGGED_SETS)
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 1e-5),
                                       (jnp.bfloat16, 5e-2)])
def test_pooled_kernel_matches_reference(shapes, dtype, tol):
    """The in-kernel pool stage (tap tiles maxed into the pooled-lhs
    scratch) + ragged GEMMs + fused bias/ReLU vs the XLA oracle."""
    _, xs, ws, bs, m = _pooled_branches(2, 7, 6, 20, shapes, dtype)
    got = kops.grouped_matmul_pooled(xs, ws, bs, relu=True)
    want = K.grouped_matmul_pooled_ref(xs, ws, bs, relu=True)
    for y, yw, (_, ng) in zip(got, want, shapes):
        assert y.shape == (m, ng) and y.dtype == dtype
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yw, np.float32),
                                   rtol=tol, atol=tol)


@pytest.mark.parametrize("chain", [((3, 2),), ((3, 2), (3, 1))])
@pytest.mark.parametrize("tap_limit", [None, 1000])
def test_pooled_kernel_strided_and_chained(chain, tap_limit):
    """Stride-2 and composed pools (the inter-module maxpool and the
    pool-proj of a pooled module) stream through the same launch — both
    with the in-kernel pool stage (tap_limit=1000 forces it even for the
    81-view chain) and with the pack-time fold the POOL_TAP_LIMIT
    heuristic applies to pathological tap counts (tap_limit=None)."""
    x4, xs, ws, bs, m = _pooled_branches(2, 8, 8, 16, [(None, 40)],
                                         jnp.float32, chain=chain)
    (got,) = gmm.grouped_matmul_pooled(xs, ws, bs, relu=True,
                                       interpret=True, tap_limit=tap_limit)
    pooled = maxpool_chain(x4, chain).reshape(-1, 16)
    want = jax.nn.relu(pooled @ ws[0] + bs[0])
    assert got.shape[0] == m
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_pooled_concat_kernel_matches_reference():
    """Pooling + GEMMs + epilogue + the join assembly in ONE launch."""
    shapes = [(None, 60), (100, 129), (None, 16)]
    _, xs, ws, bs, m = _pooled_branches(2, 7, 6, 20, shapes, jnp.float32)
    offs, total = [19, 98, 260], 300     # unaligned offsets + gaps
    got = kops.grouped_matmul_pooled_concat(xs, ws, bs, offsets=offs,
                                            total=total, relu=True)
    want = K.grouped_matmul_pooled_concat_ref(xs, ws, bs, offsets=offs,
                                              total=total, relu=True)
    assert got.shape == (m, total)
    for off, (_, n) in zip(offs, shapes):
        np.testing.assert_allclose(np.asarray(got[:, off:off + n]),
                                   np.asarray(want[:, off:off + n]),
                                   rtol=1e-5, atol=1e-5)


def test_pooled_delegates_when_nothing_pools():
    """All-plain branch sets take the unmodified grouped kernel (same
    launch counter, no pool descriptor overhead)."""
    xs = [jax.random.normal(jax.random.PRNGKey(i), (50, k)) * 0.3
          for i, k in enumerate((100, 300))]
    ws = [jax.random.normal(jax.random.PRNGKey(9 + i), (k, n)) * 0.3
          for i, (k, n) in enumerate(((100, 60), (300, 129)))]
    kops.reset_launch_counts()
    got = kops.grouped_matmul_pooled(xs, ws)
    assert kops.KERNEL_LAUNCHES.get("grouped_matmul") == 1
    assert "grouped_matmul_pooled" not in kops.KERNEL_LAUNCHES
    for y, yw in zip(got, K.grouped_matmul_ref(xs, ws)):
        np.testing.assert_allclose(np.asarray(y), np.asarray(yw),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# VJP: one combined backward launch, oracle-exact gradients
# ---------------------------------------------------------------------------

def test_pooled_vjp_is_one_combined_launch():
    shapes = [(None, 60), (100, 129)]
    _, xs, ws, bs, _ = _pooled_branches(2, 6, 6, 12, shapes, jnp.float32)

    def loss(xs, ws, bs):
        ys = kops.grouped_matmul_pooled(xs, ws, bs, relu=True)
        return sum((y * y).sum() for y in ys)

    kops.reset_launch_counts()
    jax.grad(loss, argnums=(0, 1, 2))(xs, ws, bs)
    assert kops.KERNEL_LAUNCHES.get("grouped_matmul_pooled") == 1
    assert kops.KERNEL_LAUNCHES.get("grouped_matmul_bwd") == 1
    assert "grouped_matmul_dw" not in kops.KERNEL_LAUNCHES


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-4),
                                       (jnp.bfloat16, 5e-2)])
def test_pooled_vjp_matches_reference_grads(dtype, tol):
    """Gradients through the pooled launch vs autodiff of the XLA
    reduce_window oracle — the pooled-input cotangent must match EXACTLY
    on ties (ReLU zeros make window ties the common case, and the
    first-argmax scatter mirrors reduce_window's select semantics)."""
    b, h, w, c = 2, 6, 6, 12
    x4 = jnp.maximum(jax.random.normal(jax.random.PRNGKey(0), (b, h, w, c),
                                       dtype), 0)
    w0 = jax.random.normal(jax.random.PRNGKey(1), (c, 40), dtype) * 0.3
    b0 = jax.random.normal(jax.random.PRNGKey(2), (40,), dtype)
    x1 = jax.random.normal(jax.random.PRNGKey(3), (b * h * w, 70),
                           dtype) * 0.3
    w1 = jax.random.normal(jax.random.PRNGKey(4), (70, 33), dtype) * 0.3
    b1 = jax.random.normal(jax.random.PRNGKey(5), (33,), dtype)

    def loss(x4, x1, ws, bs):
        taps = tuple(t.reshape(-1, c)
                     for t in K.pool_tap_views(x4, ((3, 1),)))
        ys = kops.grouped_matmul_pooled([taps, x1], ws, bs, relu=True)
        return sum((y.astype(jnp.float32) ** 2).sum() for y in ys)

    def loss_ref(x4, x1, ws, bs):
        p = maxpool(x4, 3, 1).reshape(-1, c)
        ys = K.grouped_matmul_ref([p, x1], ws, bs, relu=True)
        return sum((y.astype(jnp.float32) ** 2).sum() for y in ys)

    got = jax.grad(loss, argnums=(0, 1, 2, 3))(x4, x1, (w0, w1), (b0, b1))
    want = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x4, x1, (w0, w1),
                                                    (b0, b1))
    for a, bb in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(bb, np.float32),
                                   rtol=tol, atol=tol)


def test_pooled_concat_vjp_under_jit():
    shapes = [(None, 60), (100, 33)]
    _, xs, ws, bs, _ = _pooled_branches(2, 6, 6, 12, shapes, jnp.float32)

    def loss(xs, ws, bs):
        y = kops.grouped_matmul_pooled_concat(
            xs, ws, bs, offsets=(0, 60), total=93, relu=True)
        return (y * y).sum()

    got = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))(xs, ws, bs)
    eag = jax.grad(loss, argnums=(0, 1, 2))(xs, ws, bs)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(eag)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# cost model: the pool term
# ---------------------------------------------------------------------------

def test_pool_profile_prices_the_standalone_launch():
    op = Op.make("p", "maxpool", n=2, h=16, w=16, c=64, chain=((3, 2),))
    pr = pool_profile(op)
    e_in, e_out = 2 * 16 * 16 * 64, 2 * 8 * 8 * 64
    assert pr.hbm_bytes == (e_in + e_out) * op.dtype_bytes
    assert pr.flops == 9.0 * e_out
    assert pr.workspace_bytes == 0.0
    # a chained pool materializes the intermediate as workspace
    op2 = Op.make("p2", "maxpool", n=2, h=16, w=16, c=64,
                  chain=((3, 2), (3, 1)))
    pr2 = pool_profile(op2)
    assert pr2.workspace_bytes == e_out * op.dtype_bytes
    assert pr2.hbm_bytes > pr.hbm_bytes


def test_fused_pool_zeroes_the_term():
    """The absorbed plan is cheaper than the unfused one by at least the
    standalone pool rows (the fused rider is zero)."""
    cfg = get_reduced("googlenet")
    plan_f, _ = CNN.plan_cnn(cfg, batch=2)
    plan_u, _ = CNN.plan_cnn(cfg, batch=2, fuse_pool=False)
    g = CNN.build_graph(cfg, 2)
    pool_terms = sum(
        pool_profile(op).time for op in g.ops.values()
        if op.kind == "maxpool")
    assert pool_terms > 0
    assert plan_f.makespan <= plan_u.makespan - pool_terms * 0.99


# ---------------------------------------------------------------------------
# lowering: pool absorption
# ---------------------------------------------------------------------------

def _pool_fork_graph(consumer_mode="grouped"):
    """src -> pool -> two ragged (or uniform, for the stacked case)
    matmul branches."""
    g = OpGraph()
    g.add(Op.make("src", "pointwise", elements=256 * 128))
    g.add(Op.make("pl", "maxpool", n=4, h=8, w=8, c=128, chain=((3, 1),)),
          ["src"])
    widths = (384, 32) if consumer_mode == "grouped" else (128, 128)
    g.add(Op.make("a", "matmul", m=256, k=128, n=widths[0]), ["pl"])
    g.add(Op.make("b", "matmul", m=256, k=128, n=widths[1]), ["pl"])
    sch = Schedule([
        CoGroup(["src"], {"src": "vpu"}, 0.0),
        CoGroup(["pl"], {"pl": "reduce_window"}, 0.0),
        CoGroup(["a", "b"], {"a": "mxu128", "b": "mxu128"}, 1.0),
    ])
    return g, sch


def test_lower_absorbs_pool_into_grouped():
    g, sch = _pool_fork_graph()
    plan = lower(g, sch)
    assert [gr.mode for gr in plan.groups] == ["serial", "grouped_pooled"]
    pg = plan.groups[1]
    assert sorted(pg.pools) == [("a", "pl"), ("b", "pl")]
    # backward mirror: same combined launch, grad:-prefixed pools
    bwd = backward_plan(g, plan)
    assert bwd.groups[0].mode == "grouped_pooled"
    assert sorted(bwd.groups[0].pools) == [("grad:a", "grad:pl"),
                                           ("grad:b", "grad:pl")]
    # opting out keeps the standalone reduce_window group
    plan_u = lower(g, sch, fuse_pool=False)
    assert [gr.mode for gr in plan_u.groups] == ["serial", "serial",
                                                 "grouped"]
    assert plan.makespan < plan_u.makespan


def test_lower_pool_absorption_flips_stacked_to_grouped():
    """Uniform-shape consumers would lower stacked — absorbing the pool
    moves them onto the grouped kernel (the pad-to-max kernel has no pool
    stage), which must still beat stacked + the standalone pool."""
    g, sch = _pool_fork_graph(consumer_mode="stacked")
    plan_u = lower(g, sch, fuse_pool=False)
    assert plan_u.groups[-1].mode == "stacked"
    plan = lower(g, sch)
    assert plan.groups[-1].mode == "grouped_pooled"
    assert len(plan.groups) == 2         # pool group absorbed


def test_lower_pool_absorbed_by_multiple_groups():
    """A pool whose consumers span TWO grouped groups replicates into
    both (each launch pools its own taps) — the standalone group is
    dropped once and the aggregate win check credits its saving once."""
    g = OpGraph()
    g.add(Op.make("src", "pointwise", elements=256 * 128))
    g.add(Op.make("pl", "maxpool", n=4, h=8, w=8, c=128, chain=((3, 1),)),
          ["src"])
    for n, w1, w2 in (("a", 384, 32), ("c", 200, 72)):
        g.add(Op.make(n, "matmul", m=256, k=128, n=w1), ["pl"])
        g.add(Op.make(n + "2", "matmul", m=256, k=128, n=w2), ["pl"])
    sch = Schedule([
        CoGroup(["src"], {"src": "vpu"}, 0.0),
        CoGroup(["pl"], {"pl": "reduce_window"}, 0.0),
        CoGroup(["a", "a2"], {"a": "mxu128", "a2": "mxu128"}, 1.0),
        CoGroup(["c", "c2"], {"c": "mxu128", "c2": "mxu128"}, 1.0),
    ])
    plan = lower(g, sch)
    pooled = plan.groups_of_mode("grouped_pooled")
    assert len(pooled) == 2
    assert all(len(gr.pools) == 2 for gr in pooled)
    assert not any(gr.ops == ("pl",) for gr in plan.groups)


def test_run_plan_degrade_missing_pool_impl_raises_clearly():
    """A degraded pooled group whose absorbed pool op has NO impl at all
    fails with an explicit error naming the missing binding (not a bare
    KeyError from deep inside the branch fn)."""
    plan, impls, x, _ = _exec_fixture()
    impls_nopool = {n: im for n, im in impls.items() if n != "pl"}
    with pytest.raises(KeyError, match="absorbed pool op 'pl' has no"):
        run_plan(impls_nopool, {"x0": x}, plan)


def test_lower_pool_absorption_respects_c2_budget():
    """The pooled launch's tap-expanded X stack is extra workspace the C2
    gate must see: under a budget the unpooled grouped group fits but the
    tap expansion does not, the pool stays a standalone launch."""
    g, sch = _pool_fork_graph()
    # mxu128 matmul profiles carry zero workspace, so the unpooled group
    # always fits; the 8 extra tap tiles per lhs tile do not
    plan = lower(g, sch, hbm_budget=1e3)
    assert any(gr.ops == ("pl",) for gr in plan.groups)
    assert "grouped_pooled" not in plan.mode_counts()
    plan_ok = lower(g, sch)
    assert "grouped_pooled" in plan_ok.mode_counts()


def test_lower_pool_absorption_budget_accumulates_across_pools():
    """A group absorbing a SECOND pool must count the first pool's
    tap-expansion in its footprint: under a budget that fits one
    absorption but not two, the second pool stays standalone."""
    g = OpGraph()
    g.add(Op.make("src", "pointwise", elements=256 * 128))
    g.add(Op.make("p1", "maxpool", n=4, h=8, w=8, c=128, chain=((3, 1),)),
          ["src"])
    g.add(Op.make("p2", "maxpool", n=4, h=8, w=8, c=128, chain=((3, 1),)),
          ["src"])
    g.add(Op.make("a", "matmul", m=256, k=128, n=384), ["p1"])
    g.add(Op.make("b", "matmul", m=256, k=128, n=32), ["p2"])
    sch = Schedule([
        CoGroup(["src"], {"src": "vpu"}, 0.0),
        CoGroup(["p1"], {"p1": "reduce_window"}, 0.0),
        CoGroup(["p2"], {"p2": "reduce_window"}, 0.0),
        CoGroup(["a", "b"], {"a": "mxu128", "b": "mxu128"}, 1.0),
    ])
    # one pool's tap expansion is 8 * 256*128*2B = 512KiB
    one_pool = 8 * 256 * 128 * 2
    plan = lower(g, sch, hbm_budget=1.5 * one_pool)
    pooled = plan.groups_of_mode("grouped_pooled")
    assert len(pooled) == 1 and len(pooled[0].pools) == 1
    assert sum(1 for gr in plan.groups
               if gr.ops in (("p1",), ("p2",))) == 1
    # a roomier budget takes both
    plan2 = lower(g, sch, hbm_budget=3 * one_pool)
    assert len(plan2.groups_of_mode("grouped_pooled")[0].pools) == 2


def test_lower_keeps_pool_with_non_groupable_consumer():
    """A pool with any consumer outside a grouped-family group stays a
    standalone launch (absorption is all-or-nothing)."""
    g = OpGraph()
    g.add(Op.make("src", "pointwise", elements=256 * 128))
    g.add(Op.make("pl", "maxpool", n=4, h=8, w=8, c=128, chain=((3, 1),)),
          ["src"])
    g.add(Op.make("a", "matmul", m=256, k=128, n=384), ["pl"])
    g.add(Op.make("tap", "pointwise", elements=256 * 128), ["pl"])
    sch = Schedule([
        CoGroup(["src"], {"src": "vpu"}, 0.0),
        CoGroup(["pl"], {"pl": "reduce_window"}, 0.0),
        CoGroup(["a"], {"a": "mxu128"}, 1.0),
        CoGroup(["tap"], {"tap": "vpu"}, 0.0),
    ])
    plan = lower(g, sch)
    assert any(gr.ops == ("pl",) for gr in plan.groups)
    assert not any(gr.pools for gr in plan.groups)


def test_googlenet_single_launch_per_module_with_pooling():
    """The tentpole end state on FULL googlenet: every inception module
    lowers to exactly two grouped-family launches per direction (the quad
    with its pooling absorbed + the join-absorbing pair) — zero
    standalone maxpool groups, zero standalone joins, zero XLA
    fallbacks, forward and backward."""
    plan, _ = CNN.plan_cnn(get_config("googlenet"), batch=32, train=True)
    counts = plan.mode_counts()
    assert counts.get("grouped_pooled") == 9       # one pooled quad/module
    assert counts.get("grouped_concat") == 9       # one concat pair/module
    assert plan.groups_of_mode("xla") == []
    assert not [g for g in plan.groups
                if any(n.endswith("/pool") or n.endswith("/pppool")
                       for n in g.ops)]
    assert not [g for g in plan.groups
                if g.mode != "grouped_concat"
                and any(n.endswith("/join") for n in g.ops)]
    # every pool-proj branch pools in-launch; pooled modules pool the
    # whole quad (the inter-module maxpool absorbed too)
    quads = plan.groups_of_mode("grouped_pooled")
    assert all(any(b.endswith("/pp") for b, _ in g.pools) for g in quads)
    assert sum(1 for g in quads if len(g.pools) == 4) == 3  # pool_between
    bwd = plan.context["backward"]
    bcounts = bwd.mode_counts()
    assert bcounts.get("grouped_pooled") == 9
    assert bcounts.get("grouped_concat") == 9
    assert bwd.groups_of_mode("xla") == []
    assert all(g.pools for g in bwd.groups_of_mode("grouped_pooled"))


# ---------------------------------------------------------------------------
# execution: pooled groups run, degrade, and match the reference
# ---------------------------------------------------------------------------

def _exec_fixture():
    g, sch = _pool_fork_graph()
    plan = lower(g, sch)
    assert plan.groups[-1].mode == "grouped_pooled"
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jnp.maximum(jax.random.normal(ks[0], (4, 8, 8, 128)), 0) * 0.5
    wa = jax.random.normal(ks[1], (128, 384), jnp.float32) * 0.1
    wb = jax.random.normal(ks[2], (128, 32), jnp.float32) * 0.1

    def conv1x1(w):
        return OpImpl(
            deps=("pl",),
            fn=lambda x, algorithm=None, w=w: jax.nn.relu(
                x.reshape(-1, 128) @ w).reshape(4, 8, 8, -1),
            gemm_x=lambda x: x.reshape(-1, 128), gemm_w=w,
            gemm_post=lambda y: jax.nn.relu(y),
            gemm_bias=jnp.zeros((w.shape[1],), jnp.float32),
            gemm_relu=True,
            gemm_reshape=lambda y: y.reshape(4, 8, 8, -1))

    impls = {
        "src": OpImpl(deps=("x0",), fn=lambda x, algorithm=None: x),
        "pl": OpImpl(deps=("src",),
                     fn=lambda x, algorithm=None: maxpool(x, 3, 1),
                     pool_chain=((3, 1),)),
        "a": conv1x1(wa), "b": conv1x1(wb),
    }
    want_pool = maxpool(x, 3, 1).reshape(-1, 128)
    want = {"a": jax.nn.relu(want_pool @ wa).reshape(4, 8, 8, -1),
            "b": jax.nn.relu(want_pool @ wb).reshape(4, 8, 8, -1)}
    return plan, impls, x, want


def test_run_plan_grouped_pooled_executes_in_one_launch():
    plan, impls, x, want = _exec_fixture()
    kops.reset_launch_counts()
    timings: dict = {}
    env = run_plan(impls, {"x0": x}, plan, timings=timings)
    # ONE pooled grouped kernel, and the pooled activation is never
    # materialized in the env (no standalone reduce_window ran)
    assert kops.KERNEL_LAUNCHES.get("grouped_matmul_pooled") == 1
    assert "pl" not in env
    assert "grouped_pooled" in timings
    for n in ("a", "b"):
        np.testing.assert_allclose(np.asarray(env[n]), np.asarray(want[n]),
                                   rtol=2e-4, atol=2e-4)


def test_run_plan_grouped_pooled_degrades_gracefully():
    """A missing pool_chain (fn-only pool impl) degrades the group to the
    per-op path: the pool materializes via its reduce_window fn, values
    match, and the timing key records the degrade."""
    plan, impls, x, want = _exec_fixture()
    impls_nochain = dict(impls)
    impls_nochain["pl"] = dataclasses.replace(impls["pl"], pool_chain=None)
    timings: dict = {}
    env = run_plan(impls_nochain, {"x0": x}, plan, timings=timings)
    assert "grouped_pooled->xla" in timings
    assert "pl" in env                    # the standalone pool ran
    for n in ("a", "b"):
        np.testing.assert_allclose(np.asarray(env[n]), np.asarray(want[n]),
                                   rtol=2e-4, atol=2e-4)


def test_run_plan_pooled_wide_dedup_single_tap_set():
    """Uniform-K branches pooling the SAME pool op dedup into one wide
    pooled GEMM: one tap set, one in-kernel pool stage."""
    plan, impls, x, want = _exec_fixture()
    impls = {n: (dataclasses.replace(im, gemm_x_key=("shared", 128))
                 if n in ("a", "b") else im) for n, im in impls.items()}
    kops.reset_launch_counts()
    env = run_plan(impls, {"x0": x}, plan)
    assert kops.KERNEL_LAUNCHES.get("grouped_matmul_pooled") == 1
    for n in ("a", "b"):
        np.testing.assert_allclose(np.asarray(env[n]), np.asarray(want[n]),
                                   rtol=2e-4, atol=2e-4)


def test_grouped_pooled_gradcheck_through_run_plan():
    plan, impls_base, x, _ = _exec_fixture()
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    wa = jax.random.normal(ks[0], (128, 384), jnp.float32) * 0.1
    wb = jax.random.normal(ks[1], (128, 32), jnp.float32) * 0.1

    def build(wa, wb):
        import dataclasses as dc
        impls = dict(impls_base)
        impls["a"] = dc.replace(impls_base["a"], gemm_w=wa)
        impls["b"] = dc.replace(impls_base["b"], gemm_w=wb)
        return impls

    def loss(x, wa, wb):
        env = run_plan(build(wa, wb), {"x0": x}, plan)
        return (env["a"] ** 2).sum() + (env["b"] ** 2).sum()

    def loss_ref(x, wa, wb):
        p = maxpool(x, 3, 1).reshape(-1, 128)
        return (jax.nn.relu(p @ wa) ** 2).sum() \
            + (jax.nn.relu(p @ wb) ** 2).sum()

    got = jax.grad(loss, argnums=(0, 1, 2))(x, wa, wb)
    want = jax.grad(loss_ref, argnums=(0, 1, 2))(x, wa, wb)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# the full fused plan has no reduce_window anywhere
# ---------------------------------------------------------------------------

def _jaxpr_primitives(jaxpr, acc):
    for e in jaxpr.eqns:
        acc.add(str(e.primitive))
        for v in e.params.values():
            if hasattr(v, "jaxpr"):
                _jaxpr_primitives(v.jaxpr, acc)
            if isinstance(v, (list, tuple)):
                for vv in v:
                    if hasattr(vv, "jaxpr"):
                        _jaxpr_primitives(vv.jaxpr, acc)
    return acc


def test_fused_plan_jaxpr_has_zero_reduce_window():
    """The acceptance criterion at the strongest level: the traced fused
    forward contains NO reduce_window primitive at any nesting depth —
    pooling exists only as the kernel's pool stage (tap-view layout ops
    around the launch).  The unfused plan keeps them (the baseline)."""
    cfg = get_reduced("googlenet")     # has an inter-module pool
    plan, _ = CNN.plan_cnn(cfg, batch=2)
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, *cfg.img), jnp.float32)
    jx = jax.make_jaxpr(lambda p, x: CNN.forward_plan(p, cfg, x, plan))(
        params, x)
    prims = _jaxpr_primitives(jx.jaxpr, set())
    assert not [p for p in prims if "reduce_window" in p], prims
    plan_u, _ = CNN.plan_cnn(cfg, batch=2, fuse_pool=False)
    jx_u = jax.make_jaxpr(lambda p, x: CNN.forward_plan(p, cfg, x, plan_u))(
        params, x)
    prims_u = _jaxpr_primitives(jx_u.jaxpr, set())
    assert [p for p in prims_u if "reduce_window" in p]
