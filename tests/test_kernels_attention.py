"""Flash attention kernel vs oracle: GQA / causal / window / softcap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis, or skip-stubs

from repro import kernels as K
from repro.kernels import ref

CASES = [
    # (b, sq, skv, hq, hkv, d, causal, window, softcap)
    (2, 128, 128, 4, 2, 64, True, None, None),
    (1, 100, 100, 8, 8, 64, True, None, None),
    (1, 1, 256, 4, 1, 64, True, None, None),          # decode
    (2, 128, 128, 4, 4, 64, True, 32, None),          # sliding window
    (1, 96, 96, 2, 2, 64, True, None, 30.0),          # softcap (gemma2)
    (1, 64, 64, 2, 2, 64, False, None, None),         # encoder
    (1, 1, 300, 8, 2, 128, True, 64, 50.0),
    (2, 256, 256, 8, 2, 128, True, None, None),
]


@pytest.mark.parametrize("alg", K.ATTENTION_ALGORITHMS)
@pytest.mark.parametrize("case", CASES)
def test_attention_algorithms(alg, case):
    b, sq, skv, hq, hkv, d, causal, window, softcap = case
    ks = jax.random.split(jax.random.PRNGKey(abs(hash(case)) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), jnp.float32)
    got = K.attention(q, k, v, causal=causal, window=window, softcap=softcap,
                      algorithm=alg, block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_equals_materialized():
    """The two algorithms are numerically interchangeable (paper C3: the
    choice is a resource decision, not a semantics decision)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 64))
    k = jax.random.normal(ks[1], (2, 64, 2, 64))
    v = jax.random.normal(ks[2], (2, 64, 2, 64))
    a = K.attention(q, k, v, algorithm="flash", block_q=32, block_k=32)
    b = K.attention(q, k, v, algorithm="materialized")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-3, atol=2e-3)


def test_workspace_materialized_scales_with_skv():
    w1 = K.attention_workspace_bytes("materialized", 1, 128, 1024, 8)
    w2 = K.attention_workspace_bytes("materialized", 1, 128, 2048, 8)
    assert w2 == 2 * w1 and K.attention_workspace_bytes("flash", 1, 128, 2048, 8) == 0


@settings(max_examples=15, deadline=None)
@given(sq=st.integers(1, 96), skv=st.integers(8, 160),
       hkv=st.sampled_from([1, 2]), g=st.sampled_from([1, 2, 4]),
       window=st.sampled_from([None, 16, 64]))
def test_attention_property(sq, skv, hkv, g, window):
    """Property: flash == oracle for arbitrary (sq, skv, gqa, window)."""
    if sq > skv:
        sq = skv
    ks = jax.random.split(jax.random.PRNGKey(sq * 1000 + skv), 3)
    q = jax.random.normal(ks[0], (1, sq, hkv * g, 32))
    k = jax.random.normal(ks[1], (1, skv, hkv, 32))
    v = jax.random.normal(ks[2], (1, skv, hkv, 32))
    got = K.attention(q, k, v, window=window, block_q=32, block_k=32)
    want = ref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-3, atol=3e-3)
