"""Plan/offset-table cache: a warm serving request re-lowers NOTHING.

Mirrors test_plan_hardening.py's device-table identity assertions one
level up: the second request for the same (architecture fingerprint,
M-bucket) must hit the cache with ZERO ``core.plan.lower`` calls and
reuse the SAME device-resident offset-table arrays (object identity),
and the fingerprint must be structure-sensitive (a width edit changes
it) while staying process-stable (same cfg -> same hex digest).
"""
import dataclasses
import importlib

import jax

from repro.configs import get_reduced
from repro.core import plan_cache
from repro.models import cnn as CNN

gmm = importlib.import_module("repro.kernels.grouped_matmul")
planlib = importlib.import_module("repro.core.plan")


def setup_function(_fn):
    plan_cache.reset(clear_entries=True)


def test_warm_hit_zero_lower_calls(monkeypatch):
    """First request lowers; the second (same cfg, same bucket) must not
    call ``lower`` at all and must return the same entry object."""
    cfg = get_reduced("googlenet")
    calls = []
    real_lower = planlib.lower

    def counting_lower(*a, **kw):
        calls.append(1)
        return real_lower(*a, **kw)

    monkeypatch.setattr(planlib, "lower", counting_lower)
    e1 = plan_cache.cached_cnn_plan(cfg, 2)
    cold_calls = len(calls)
    assert cold_calls >= 1
    e2 = plan_cache.cached_cnn_plan(cfg, 2)
    assert e2 is e1
    assert len(calls) == cold_calls, "warm hit re-ran plan lowering"
    s = plan_cache.stats()
    assert s["hits"] == 1 and s["misses"] == 1 and s["entries"] == 1


def test_warm_hit_same_device_table_objects():
    """Executing through the cached plan twice reuses the SAME concrete
    device offset-table arrays — no re-upload on the warm path."""
    cfg = get_reduced("googlenet")
    entry = plan_cache.cached_cnn_plan(cfg, 2)
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    imgs = jax.random.normal(jax.random.PRNGKey(1), (2,) + cfg.img)

    CNN.forward_plan(params, cfg, imgs, entry.plan, valid_images=1)
    key = (gmm._plan_tiles, 1, (1,), (1,))   # probe identity directly
    t1 = gmm._device_table(*key)             # (may add the probe's entry)
    info1 = gmm._device_table.cache_info()
    CNN.forward_plan(params, cfg, imgs, entry.plan, valid_images=2)
    t2 = gmm._device_table(*key)
    info2 = gmm._device_table.cache_info()
    assert info2.currsize == info1.currsize, \
        "warm planned forward built a NEW offset table"
    assert t1 is t2


def test_bucket_and_flags_key_separately():
    cfg = get_reduced("googlenet")
    e2 = plan_cache.cached_cnn_plan(cfg, 2)
    e4 = plan_cache.cached_cnn_plan(cfg, 4)
    ec = plan_cache.cached_cnn_plan(cfg, 2, chain_modules=True)
    assert e2 is not e4 and e2 is not ec
    assert e2.plan.context["batch"] == 2 and e4.plan.context["batch"] == 4
    assert plan_cache.stats() == {"hits": 0, "misses": 3, "entries": 3,
                                  "hit_rate": 0.0, "evictions": 0,
                                  "capacity": plan_cache.CAPACITY}
    assert plan_cache.cached_cnn_plan(cfg, 4) is e4
    assert plan_cache.stats()["hit_rate"] == 0.25


def test_fingerprint_structure_sensitive_and_stable():
    cfg = get_reduced("googlenet")
    fp1 = plan_cache.graph_fingerprint(CNN.build_graph(cfg, 2))
    fp2 = plan_cache.graph_fingerprint(CNN.build_graph(cfg, 2))
    assert fp1 == fp2 and len(fp1) == 64
    # a conv-width edit is a different architecture -> different key
    m0 = cfg.modules[0]
    cfg_wide = dataclasses.replace(
        cfg, modules=(dataclasses.replace(m0, n1=m0.n1 + 8),)
        + cfg.modules[1:])
    fp3 = plan_cache.graph_fingerprint(CNN.build_graph(cfg_wide, 2))
    assert fp3 != fp1
    # batch is carried by the bucket key, not the fingerprint: the same
    # architecture at another batch may share the fingerprint only if the
    # graph is batch-invariant; either way the plan_key differs
    k2 = plan_cache.plan_key(fp1, 2, "float32", "cpu")
    k4 = plan_cache.plan_key(fp1, 4, "float32", "cpu")
    assert k2 != k4


# ---------------------------------------------------------------------------
# LRU eviction + pinned device tables + MoE plans
# ---------------------------------------------------------------------------

def test_lru_eviction_bounds_entries(monkeypatch):
    """Past CAPACITY the least-recent entry is evicted and counted; a
    recency refresh (hit) protects an entry from the next eviction."""
    monkeypatch.setattr(plan_cache, "CAPACITY", 2)
    cfg = get_reduced("googlenet")
    e2 = plan_cache.cached_cnn_plan(cfg, 2)
    e4 = plan_cache.cached_cnn_plan(cfg, 4)
    assert plan_cache.cached_cnn_plan(cfg, 2) is e2   # refresh bucket 2
    e8 = plan_cache.cached_cnn_plan(cfg, 8)           # evicts bucket 4
    s = plan_cache.stats()
    assert s["entries"] == 2 and s["evictions"] == 1
    assert plan_cache.cached_cnn_plan(cfg, 8) is e8
    assert plan_cache.cached_cnn_plan(cfg, 2) is e2   # survivor: still a hit
    assert plan_cache.cached_cnn_plan(cfg, 4) is not e4  # evictee: re-lowered
    assert plan_cache.stats()["evictions"] == 2       # 4 pushed out 8


def test_eviction_unpins_device_tables(monkeypatch):
    """An evicted entry releases its pinned offset tables from the device
    registry; a surviving entry's pins keep its tables resident."""
    monkeypatch.setattr(plan_cache, "CAPACITY", 1)
    cfg = get_reduced("googlenet")
    key = (gmm._plan_tiles, 1, (1,), (1,))
    gmm._device_table(*key)                           # ensure resident
    e2 = plan_cache.cached_cnn_plan(cfg, 2)
    plan_cache.attach_tables(e2, [key])
    assert gmm._device_table._pins.get(key) == 1
    plan_cache.cached_cnn_plan(cfg, 4)                # evicts e2
    assert plan_cache.stats()["evictions"] == 1
    assert gmm._device_table._pins.get(key) is None
    assert e2.table_keys == ()
    # double-attach is idempotent: second attach must not double-pin
    e4 = plan_cache.cached_cnn_plan(cfg, 4)
    plan_cache.attach_tables(e4, [key])
    plan_cache.attach_tables(e4, [key])
    assert gmm._device_table._pins.get(key) == 1
    plan_cache.reset(clear_entries=True)
    assert gmm._device_table._pins.get(key) is None


def test_moe_plan_cached_and_keyed():
    """MoE layers ride the same cache: warm call returns the same entry,
    a dim edit re-keys, and the plan's expert fork is ONE grouped_experts
    group priced below the einsum engine."""
    kw = dict(b=2, s=32, d=128, f=64, e=8, top_k=2, capacity_factor=4.0,
              gated=True, shared_f=128)
    e1 = plan_cache.cached_moe_plan(**kw)
    assert plan_cache.cached_moe_plan(**kw) is e1
    assert plan_cache.stats()["hits"] == 1
    assert e1.plan.mode_counts()["grouped_experts"] == 1
    (ge,) = e1.plan.groups_of_mode("grouped_experts")
    times = e1.plan.context["moe"]["times"]
    assert ge.modeled_time == times["grouped"]
    e2 = plan_cache.cached_moe_plan(**{**kw, "f": 128})
    assert e2 is not e1
