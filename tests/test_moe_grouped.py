"""Per-expert-ragged grouped GEMM: the MoE expert engine.

Kernel level — ``grouped_matmul_experts`` must BIT-match its packed-layout
per-expert XLA oracle for silu/f32 with D, F <= 128 (one k-block keeps the
kernel and the oracle on the same single f32 dot accumulation, the same
bar ``test_ragged_m.py`` sets), with exact zeros outside every expert's
valid segment — zero-token experts included; gelu (1-2 ulp of tanh
fusion drift) and bf16 use ``tol_for``.  Model level — ``moe_apply`` with
``impl="grouped"`` must reproduce the einsum engine bit-for-bit (routing,
drops and combine are SHARED code, so equivalence reduces to the expert
GEMMs), run ONE grouped-family launch per direction, and report the
``padded_slot_fraction`` the einsum engine wastes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st, tol_for

from repro import kernels as K
from repro.models import moe as MOE

# (counts, e) mixes: zero-token experts, all-one-expert, heavy imbalance
COUNT_SETS = [
    [16, 0, 9, 3],
    [0, 0, 40, 0],
    [1, 1, 1, 1, 1, 1, 1, 25],
    [0, 0],
]


def _packed_case(counts, d, f, dtype, *, gated, bm, key=0):
    offs = np.asarray(K.expert_row_offsets(counts, bm))
    e = len(counts)
    n_rows = int(np.maximum(-(-np.asarray(counts) // bm), 1).sum()) * bm
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    dt = jnp.dtype(dtype)
    xp = jnp.zeros((n_rows, d), dt)
    swp = jnp.zeros((n_rows,), jnp.float32)
    for g, c in enumerate(counts):
        if c:
            xp = xp.at[offs[g]:offs[g] + c].set(
                jax.random.normal(jax.random.fold_in(ks[0], g),
                                  (c, d), dt) * 0.3)
            swp = swp.at[offs[g]:offs[g] + c].set(
                jax.random.uniform(jax.random.fold_in(ks[1], g), (c,)))
    w_in = jax.random.normal(ks[2], (e, d, f), dt) * 0.3
    w_out = jax.random.normal(ks[3], (e, f, d), dt) * 0.3
    w_gate = jax.random.normal(ks[4], (e, d, f), dt) * 0.3 if gated else None
    return xp, swp, w_in, w_out, w_gate, jnp.asarray(counts, jnp.int32)


def _assert_expert_match(got, want, counts, bm, *, exact):
    got, want = np.asarray(got), np.asarray(want)
    if exact:
        assert np.array_equal(got, want), (
            f"expert output != oracle (max |d| "
            f"{np.abs(got.astype(np.float32) - want.astype(np.float32)).max()})")
    else:
        np.testing.assert_allclose(got.astype(np.float32),
                                   want.astype(np.float32), **tol_for(got.dtype))
    # exact zeros outside every expert's valid segment (either way)
    offs = np.asarray(K.expert_row_offsets(counts, bm))
    valid = np.zeros(got.shape[0], bool)
    for g, c in enumerate(np.asarray(counts)):
        valid[offs[g]:offs[g] + int(c)] = True
    assert not got[~valid].any(), "rows outside expert segments not zeroed"


@settings(max_examples=10, deadline=None)
@given(st.integers(0, len(COUNT_SETS) - 1),
       st.sampled_from(["float32", "bfloat16"]),
       st.booleans(), st.sampled_from(["silu", "gelu"]))
def test_experts_kernel_matches_oracle(set_idx, dtype, gated, act):
    """Mixed per-expert token counts (zero-token experts included) x
    dtypes x gated/ungated x activation: the ragged experts launch equals
    the per-expert oracle — bit-for-bit on the silu/f32 one-k-block bar."""
    counts = COUNT_SETS[set_idx]
    bm = 8
    case = _packed_case(counts, 128, 64, jnp.dtype(dtype), gated=gated,
                        bm=bm, key=set_idx)
    got = K.grouped_matmul_experts(*case, activation=act, bm=bm)
    want = K.grouped_matmul_experts_ref(*case, activation=act, bm=bm)
    exact = dtype == "float32" and act == "silu"
    _assert_expert_match(got, want, counts, bm, exact=exact)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("set_idx", range(len(COUNT_SETS)))
def test_experts_seeded_sweep(set_idx, dtype):
    """Seeded fallback for the property test above (runs without
    hypothesis, mirroring test_ragged_m.py): every count mix at the
    bit-match bar plus the multi-tile D > 128 shape at tolerance."""
    counts = COUNT_SETS[set_idx]
    bm = 8
    case = _packed_case(counts, 128, 64, jnp.dtype(dtype), gated=True,
                        bm=bm, key=set_idx)
    got = K.grouped_matmul_experts(*case, activation="silu", bm=bm)
    want = K.grouped_matmul_experts_ref(*case, activation="silu", bm=bm)
    _assert_expert_match(got, want, counts, bm, exact=dtype == "float32")


def test_experts_multitile_shapes():
    """D, F > 128 (db=fb=2): multi-k-block accumulation differs from the
    oracle's single dot only by f32 reduction order."""
    counts = [10, 6, 0]
    bm = 8
    case = _packed_case(counts, 200, 140, jnp.float32, gated=True, bm=bm)
    got = K.grouped_matmul_experts(*case, bm=bm)
    want = K.grouped_matmul_experts_ref(*case, bm=bm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("gated", [True, False])
def test_experts_combined_backward_matches_grad(gated):
    """ONE combined backward launch (dx + dW_in/dW_gate/dW_out) equals
    jax.grad of the oracle — zero-token experts get exact-zero dW."""
    counts = [16, 0, 9, 3]
    bm = 8
    xp, swp, w_in, w_out, w_gate, cnt = _packed_case(
        counts, 128, 64, jnp.float32, gated=gated, bm=bm)
    ct = jax.random.normal(jax.random.PRNGKey(9), xp.shape) * 0.5

    def loss(xp_, swp_, w_in_, w_out_, w_gate_):
        y = K.grouped_matmul_experts(xp_, swp_, w_in_, w_out_, w_gate_,
                                     cnt, bm=bm)
        return jnp.sum(y * ct)

    grads = jax.grad(loss, argnums=(0, 1, 2, 3) + ((4,) if gated else ()))(
        xp, swp, w_in, w_out, w_gate)

    def ref_loss(xp_, swp_, w_in_, w_out_, w_gate_):
        y = K.grouped_matmul_experts_ref(xp_, swp_, w_in_, w_out_,
                                         w_gate_, cnt, bm=bm)
        return jnp.sum(y * ct)

    want = jax.grad(ref_loss, argnums=(0, 1, 2, 3) + ((4,) if gated else ()))(
        xp, swp, w_in, w_out, w_gate)
    for g, w in zip(grads, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-5)
    # zero-token expert 1: its dW tiles must be stored as exact zeros
    assert not np.asarray(grads[2][1]).any()
    assert not np.asarray(grads[3][1]).any()


def test_experts_one_launch_per_direction():
    """The eager launch counters: forward is ONE grouped_matmul_experts
    launch, backward ONE grouped_matmul_experts_bwd launch (dsw is a row
    reduction outside the kernel, not a third launch)."""
    counts = [16, 0, 9, 3]
    bm = 8
    xp, swp, w_in, w_out, w_gate, cnt = _packed_case(
        counts, 128, 64, jnp.float32, gated=True, bm=bm)

    K.reset_launch_counts()
    y = K.grouped_matmul_experts(xp, swp, w_in, w_out, w_gate, cnt, bm=bm)
    assert dict(K.KERNEL_LAUNCHES) == {"grouped_matmul_experts": 1}

    K.reset_launch_counts()
    jax.grad(lambda *a: jnp.sum(K.grouped_matmul_experts(*a, cnt, bm=bm)))(
        xp, swp, w_in, w_out, w_gate)
    counts_d = dict(K.KERNEL_LAUNCHES)
    assert counts_d.pop("grouped_matmul_experts") == 1      # residual fwd
    assert counts_d == {"grouped_matmul_experts_bwd": 1}


# ---------------------------------------------------------------------------
# model level: moe_apply impl="grouped" vs the einsum engine
# ---------------------------------------------------------------------------

MODEL_CASES = [
    # b, s, d, f, e, k, cf, shared_f, gated
    (2, 32, 128, 64, 8, 2, 4.0, 0, True),      # granite-moe-reduced dims
    (2, 32, 128, 64, 8, 2, 4.0, 128, True),    # qwen2-moe-reduced (shared)
    (2, 16, 64, 32, 4, 1, 0.5, 0, True),       # top_k=1, heavy drops
    (1, 8, 64, 32, 16, 2, 4.0, 0, False),      # zero-token experts, ungated
]


@settings(max_examples=8, deadline=None)
@given(st.integers(0, len(MODEL_CASES) - 1), st.integers(0, 3),
       st.sampled_from(["float32", "bfloat16"]))
def test_moe_grouped_bitmatches_einsum(case_idx, seed, dtype):
    """The grouped engine reproduces the einsum engine BIT-for-bit (both
    dtypes: routing/drops/combine are shared code and the expert chain
    casts identically), with identical aux stats."""
    b, s, d, f, e, k, cf, shared_f, gated = MODEL_CASES[case_idx]
    dt = jnp.dtype(dtype)
    p = MOE.moe_init(jax.random.PRNGKey(seed), d, f, e, shared_f=shared_f,
                     gated=gated, dtype=dt)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, d), dt) * 0.5
    oe, auxe = MOE.moe_apply(p, x, top_k=k, capacity_factor=cf,
                             impl="einsum")
    og, auxg = MOE.moe_apply(p, x, top_k=k, capacity_factor=cf,
                             impl="grouped")
    np.testing.assert_array_equal(np.asarray(oe), np.asarray(og))
    assert auxe["capacity"] == auxg["capacity"]
    np.testing.assert_allclose(float(auxe["aux_loss"]),
                               float(auxg["aux_loss"]), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(auxe["padded_slot_fraction"]),
                                  np.asarray(auxg["padded_slot_fraction"]))


@pytest.mark.parametrize("case_idx", range(len(MODEL_CASES)))
def test_moe_grouped_seeded_sweep(case_idx):
    """Seeded no-hypothesis fallback of the property test above."""
    b, s, d, f, e, k, cf, shared_f, gated = MODEL_CASES[case_idx]
    p = MOE.moe_init(jax.random.PRNGKey(case_idx), d, f, e,
                     shared_f=shared_f, gated=gated)
    x = jax.random.normal(jax.random.PRNGKey(40 + case_idx), (b, s, d)) * 0.5
    oe, _ = MOE.moe_apply(p, x, top_k=k, capacity_factor=cf, impl="einsum")
    og, _ = MOE.moe_apply(p, x, top_k=k, capacity_factor=cf, impl="grouped")
    np.testing.assert_array_equal(np.asarray(oe), np.asarray(og))


def test_moe_grouped_grads_match_einsum():
    """jax.grad through the grouped engine (custom-vjp kernel + pack /
    combine gathers) equals grad through the einsum engine for every
    param and the input."""
    p = MOE.moe_init(jax.random.PRNGKey(0), 128, 64, 8, shared_f=128)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 128)) * 0.5
    ct = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 128)) * 0.5

    def loss(p_, x_, impl):
        out, aux = MOE.moe_apply(p_, x_, top_k=2, capacity_factor=4.0,
                                 impl=impl)
        return jnp.sum(out * ct) + 0.01 * aux["aux_loss"]

    ge = jax.grad(loss, argnums=(0, 1))(p, x, "einsum")
    gg = jax.grad(loss, argnums=(0, 1))(p, x, "grouped")
    for a, b in zip(jax.tree_util.tree_leaves(ge),
                    jax.tree_util.tree_leaves(gg)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-5)


def test_moe_grouped_padded_slot_fraction():
    """The new aux stat measures the einsum engine's FLOP waste: at
    cf=4.0 top_k=2 e=8, capacity slots are 4x the routed tokens -> 0.75
    padded; with no spare capacity the fraction is 0."""
    p = MOE.moe_init(jax.random.PRNGKey(0), 64, 32, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
    for impl in ("einsum", "grouped"):
        _, aux = MOE.moe_apply(p, x, top_k=2, capacity_factor=4.0,
                               impl=impl)
        assert abs(float(aux["padded_slot_fraction"]) - 0.75) < 1e-6
    # cap formula: sk=64, cf=1.0, e=8 -> cap=8 slots/expert = exactly sk*1
    _, aux = MOE.moe_apply(p, x, top_k=2, capacity_factor=1.0,
                           impl="grouped")
    kept = (1.0 - float(aux["drop_fraction"])) * 2 * 64
    slots = 2 * 8 * aux["capacity"]
    assert abs(float(aux["padded_slot_fraction"])
               - (slots - kept) / slots) < 1e-6


def test_moe_grouped_one_launch_per_direction_model_level():
    """A full moe_apply forward runs exactly ONE grouped-family launch;
    a grad adds exactly one combined backward launch."""
    p = MOE.moe_init(jax.random.PRNGKey(0), 128, 64, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 128)) * 0.5

    K.reset_launch_counts()
    MOE.moe_apply(p, x, top_k=2, capacity_factor=4.0, impl="grouped")
    assert dict(K.KERNEL_LAUNCHES) == {"grouped_matmul_experts": 1}

    K.reset_launch_counts()
    jax.grad(lambda p_: MOE.moe_apply(p_, x, top_k=2, capacity_factor=4.0,
                                      impl="grouped")[0].sum())(p)
    launches = dict(K.KERNEL_LAUNCHES)
    assert launches.pop("grouped_matmul_experts") == 1
    assert launches == {"grouped_matmul_experts_bwd": 1}


def test_moe_transformer_thread_through():
    """granite-moe-reduced loss_fn(moe_impl="grouped") == the einsum run
    bit-for-bit, through scan + remat + every MoE layer."""
    from repro.configs import get_reduced
    from repro.models import transformer as T

    cfg = get_reduced("granite_moe_1b_a400m")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    le, auxe = T.loss_fn(params, cfg, batch, moe_impl="einsum")
    lg, auxg = T.loss_fn(params, cfg, batch, moe_impl="grouped")
    np.testing.assert_array_equal(np.asarray(le), np.asarray(lg))
    gs = jax.grad(lambda pp: T.loss_fn(pp, cfg, batch,
                                       moe_impl="grouped")[0])(params)
    assert all(bool(jnp.all(jnp.isfinite(g)))
               for g in jax.tree_util.tree_leaves(gs))
