#!/usr/bin/env bash
# Tier-1 verification, exactly as ROADMAP.md specifies, plus the benchmark
# smoke so the bench code paths can't silently rot between PRs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"

# planlint: statically verify every lowered googlenet variant (fwd+bwd x
# fused/chained/unfused-concat/unfused-pool/serial-joins) plus the MoE
# expert tables, and lint traced fallback primitives against the
# named-scope provenance policy.  Zero findings is the gate.
python -m repro.analysis.lint --arch googlenet --fallbacks

make bench-smoke

# Co-execution guardrails on the smoke baseline:
#   - every co-executed backward (grouped AND stacked grad CoGroups) beats
#     the serial per-op backward on wall time, and the grouped backward
#     beats stacked within BWD_WALL_TOL (the re-enabled wall assertion:
#     the hoisted offset tables + single combined dx/dw/db launch fixed
#     the regression where the interpret emulation's per-call table
#     re-upload put grouped a few percent behind stacked, so the
#     tolerance is strict 1.0 — raise it only with a measured reason);
#   - modeled asserts grouped is the BEST mode (stacked-vs-serial is
#     shape-dependent: ragged branches pay pad-to-max — exactly why the
#     grouped kernel exists);
#   - fused-concat: the join-absorbing launch is no slower than grouped
#     (wall, within the FUSED_WALL_TOL jitter floor — the join the fusion
#     deletes is ~1ms of a ~400ms interpret-emulated module, so the wall
#     comparison is a tie-or-win; the decisive fused-vs-grouped claim is
#     the MODELED column, asserted strictly), googlenet lowers with ZERO
#     standalone join ops, and the backward runs exactly ONE combined
#     kernel per grouped-family grad CoGroup;
#   - pooled: the pool-absorbing launch deletes the standalone
#     reduce_window group (googlenet AND the bench module lower with ZERO
#     standalone pool groups, one grouped-family kernel per co-exec group
#     forward and backward).  The decisive claim is again the MODELED
#     column (strict: pool_profile's standalone term disappears); the
#     FORWARD wall gets POOLED_WALL_TOL because the interpret emulation
#     charges the in-kernel pool taps as real grid steps (~9 per pooled
#     (i,kk) tile, measured ~1.27x here) while the baseline's
#     reduce_window is a compiled XLA op — on hardware the pool steps are
#     memory-only and pipeline under the GEMM steps (ROADMAP calibration
#     item).  The backward wall is the SAME combined launch either way
#     (only the tap fold differs), so it gets the tight
#     POOLED_BWD_WALL_TOL;
#   - googlenet's backward plan lowers with zero XLA fallbacks;
#   - cross-module streaming: the chained googlenet forward stays under
#     LAUNCH_CEILING_CHAINED_FWD counting EVERY surviving launch-like
#     primitive (pallas_call + conv + reduce_window + concatenate in the
#     traced jaxpr — the honest total, not just our kernels), the default
#     plan's pallas count stays under LAUNCH_CEILING_UNCHAINED_PALLAS,
#     the chained trace is strictly cheaper than the default in both
#     directions, and the chained modeled makespan beats the unchained
#     one forward AND backward (googlenet_chained_modeled_ok);
#   - serving: the continuous-batching column (ragged-M + plan cache,
#     launch/serve.py) ran — post-warmup stream entirely from the plan
#     cache (hit rate 1.0: zero re-lowering / offset-table rebuilds /
#     re-tracing), REQUEST-level p50/p99 latency (one sample per request,
#     oversized requests split — every submitted image reaches a launch),
#     the served chained forward under the same launch ceiling as
#     training's forward, the masked chained forward bit-matching dense
#     on the valid images, and dead M-blocks skipped as no-op waves
#     (skip ratio exactly 1 - n/bucket on the rows/image == bm fixture);
#   - MoE expert dispatch: on the bench layer the grouped ragged engine's
#     MODELED time beats the capacity-padded einsum strictly (FLOPs scale
#     with routed tokens, not E*capacity), the smoke config runs exactly
#     MOE_LAUNCHES_PER_DIRECTION grouped-family kernels each way (one
#     fused forward, one combined dx+dW backward), the grouped output
#     BIT-matches the einsum oracle (routing/drops/combine are shared
#     code, the expert chain is single-k-block f32), zero-token experts
#     stay exact (output AND dW), and the wall comparison gets
#     MOE_WALL_TOL because the interpret emulation charges the grouped
#     grid per step while einsum is one compiled XLA op.
python - <<'PY'
import json
import sys

sys.path.insert(0, ".")
# The numbers live in benchmarks/tolerances.py — the SAME module
# benchmarks/run.py uses to record the *_ok booleans, so the recorded
# verdicts and these gates cannot disagree.  Rationale per number: the
# comment block above + the tolerances module docstring.
from benchmarks.tolerances import (
    BWD_WALL_TOL, FUSED_WALL_TOL, POOLED_WALL_TOL, POOLED_BWD_WALL_TOL,
    LAUNCH_CEILING_CHAINED_FWD, LAUNCH_CEILING_UNCHAINED_PALLAS,
    MOE_WALL_TOL, MOE_LAUNCHES_PER_DIRECTION)

d = json.load(open("BENCH_plan.smoke.json"))
bg = d["branch_gemm"]["bwd_wall_us"]
assert bg["grouped"] <= bg["serial"], f"grouped bwd slower than serial: {bg}"
assert bg["stacked"] <= bg["serial"], f"stacked bwd slower than serial: {bg}"
assert bg["grouped"] <= BWD_WALL_TOL * bg["stacked"], \
    f"grouped bwd >{BWD_WALL_TOL}x behind stacked: {bg}"
bm = d["branch_gemm"]["bwd_modeled_us"]
assert bm["grouped"] <= bm["stacked"] and bm["grouped"] <= bm["serial"], \
    f"modeled backward: grouped not the best mode: {bm}"

fg = d["branch_gemm"]
w = fg["wall_us"]
assert w["fused_concat"] <= FUSED_WALL_TOL * w["grouped"], \
    f"fused_concat slower than grouped on wall (> {FUSED_WALL_TOL}x): {w}"
assert fg["fused_modeled_ok"], \
    f"fused_concat not ahead in the modeled column: {fg['modeled_us']}"
assert fg["bwd_launches_per_group"] == 1, \
    f"grad CoGroup not a single combined launch: {fg['bwd_launches_per_group']}"
assert d["googlenet_standalone_join_groups"] == 0, d
assert d["googlenet_bwd_xla_fallback_groups"] == 0, d

# pooled grouped launch guardrails
assert w["pooled"] <= POOLED_WALL_TOL * w["fused_concat"], \
    f"pooled fwd wall > {POOLED_WALL_TOL}x fused_concat: {w}"
assert fg["bwd_wall_us"]["pooled"] \
    <= POOLED_BWD_WALL_TOL * fg["bwd_wall_us"]["fused_concat"], \
    f"pooled bwd wall > {POOLED_BWD_WALL_TOL}x fused_concat: {fg['bwd_wall_us']}"
assert fg["pooled_modeled_ok"], \
    f"pooled not ahead in the modeled column: {fg['modeled_us']} " \
    f"{fg['bwd_modeled_us']}"
assert fg["pooled_fwd_launches_per_group"] == 1, fg
assert fg["pooled_bwd_launches_per_group"] == 1, fg
assert fg["pooled_standalone_pool_groups"] == 0, fg
assert d["googlenet_standalone_pool_groups"] == 0, d

# cross-module streaming launch ceilings + modeled ordering
l = d["googlenet_launches"]
assert l["chained"]["per_forward"] <= LAUNCH_CEILING_CHAINED_FWD, l
assert l["default"]["pallas_per_forward"] <= LAUNCH_CEILING_UNCHAINED_PALLAS, l
assert l["chained"]["per_forward"] < l["default"]["per_forward"], l
assert l["chained"]["grad_trace_total"] < l["default"]["grad_trace_total"], l
assert d["googlenet_chained_modeled_ok"], \
    f"chained modeled makespan not ahead: " \
    f"{d['googlenet_chained_makespan_modeled_s']} vs " \
    f"{d['googlenet_makespan_modeled_s']}"
# serving smoke gates: the continuous-batching column must exist, the
# post-warmup stream must have run entirely from the plan cache (zero
# re-lowering, zero offset-table rebuilds, zero re-tracing), latency
# percentiles must be real measurements, and the served chained forward
# must stay under the training forward's launch ceiling (raggedness adds
# no launches).
s = d["serving"]
assert s["plan_cache"]["hit_rate"] == 1.0 and s["plan_cache"]["misses"] == 0, \
    f"warm serving path missed the plan cache: {s['plan_cache']}"
assert s["p50_ms"] > 0 and s["p99_ms"] >= s["p50_ms"], s
assert s["latency_samples"] == s["requests"], \
    f"latency percentiles not request-level: {s}"
assert s["dispatch_p99_ms"] >= s["dispatch_p50_ms"] > 0, s
assert s["images"] == s["images_submitted"], \
    f"a submitted image never reached a launch: {s}"
assert s["qps"] > 0 and s["dispatches"] > 0, s
assert s["padded_m_factor_mean"] >= 1.0, s
# the masked CHAINED forward rides the same ceiling — raggedness must
# not add launches to the cross-module streaming path either
assert s["served_chained_launches_per_forward"] <= \
    LAUNCH_CEILING_CHAINED_FWD, s
assert s["chained_masked_ok"], \
    "ragged chained serving forward != dense on the valid images"
db = s["dead_block_skip"]
assert db["skip_ratio"] == db["expected_skip_ratio"], \
    f"dead M-blocks not skipped as no-op waves: {db}"
# MoE expert-dispatch gates: modeled grouped beats einsum strictly, one
# grouped-family launch per direction, bit-match vs the einsum oracle,
# zero-token experts exact, wall within the interpret-emulation tolerance
m = d["moe"]
assert m["modeled_grouped_ok"] and \
    m["modeled_us"]["grouped"] <= m["modeled_us"]["einsum"], \
    f"modeled grouped not ahead of einsum: {m['modeled_us']}"
assert m["launches"]["per_forward"] == MOE_LAUNCHES_PER_DIRECTION, m
assert m["launches"]["per_backward"] == MOE_LAUNCHES_PER_DIRECTION, m
assert m["bitmatch_ok"], "grouped engine output != einsum oracle"
assert m["zero_token_expert_ok"], "zero-token expert not exact"
assert m["wall_us"]["grouped"] <= MOE_WALL_TOL * m["wall_us"]["einsum"], \
    f"grouped fwd wall > {MOE_WALL_TOL}x einsum: {m['wall_us']}"
assert m["plan_mode_counts"].get("grouped_experts") == 1, m
assert 0.0 <= m["padded_slot_fraction"] < 1.0, m

print("smoke guardrails ok:", fg["wall_us"], bg)
print("launch ceilings ok:", l)
print("serving gates ok:", {k: s[k] for k in
                            ("qps", "p50_ms", "p99_ms", "plan_cache")})
print("moe gates ok:", {k: m[k] for k in
                        ("wall_us", "modeled_us", "launches",
                         "padded_slot_fraction")})
PY
