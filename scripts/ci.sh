#!/usr/bin/env bash
# Tier-1 verification, exactly as ROADMAP.md specifies, plus the benchmark
# smoke so the bench code paths can't silently rot between PRs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"
make bench-smoke

# Backward co-execution guardrails on the smoke baseline: every co-executed
# backward (grouped AND stacked grad CoGroups) must beat the serial per-op
# backward on wall time, and googlenet's backward plan must lower with zero
# XLA fallbacks.  grouped-vs-stacked wall gets a loose 2x tolerance (NOT
# an ordering claim — a catastrophic-regression tripwire only): the
# interpret-mode emulation charges the grouped kernel's scalar-prefetch
# offset table per grid step — a cost the hardware path doesn't pay —
# and the reps=2 smoke run is noisy (committed baseline sits at ~1.24x);
# the real ordering claim lives in the modeled (TPU) column.  Modeled asserts grouped is
# the BEST mode; stacked-vs-serial is shape-dependent (ragged branches
# pay pad-to-max — exactly why the grouped kernel exists).
python - <<'PY'
import json
d = json.load(open("BENCH_plan.smoke.json"))
bg = d["branch_gemm"]["bwd_wall_us"]
assert bg["grouped"] <= bg["serial"], f"grouped bwd slower than serial: {bg}"
assert bg["stacked"] <= bg["serial"], f"stacked bwd slower than serial: {bg}"
assert bg["grouped"] <= 2.0 * bg["stacked"], \
    f"grouped bwd >2x behind stacked: {bg}"
bm = d["branch_gemm"]["bwd_modeled_us"]
assert bm["grouped"] <= bm["stacked"] and bm["grouped"] <= bm["serial"], \
    f"modeled backward: grouped not the best mode: {bm}"
assert d["googlenet_bwd_xla_fallback_groups"] == 0, d
print("backward smoke guardrails ok:", bg)
PY
