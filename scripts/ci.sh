#!/usr/bin/env bash
# Tier-1 verification, exactly as ROADMAP.md specifies, plus the benchmark
# smoke so the bench code paths can't silently rot between PRs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -x -q "$@"
make bench-smoke
