#!/usr/bin/env bash
# Tier-1 verification, exactly as ROADMAP.md specifies.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
