"""Paper-table analogues (SPAA '20 brief announcement).

Table 1 — resource profiles of different algorithms for the two independent
convolutions of GoogleNet's first inception module (3x3 and 5x5 branches):
our TPU analogue reports modeled MXU utilization, HBM pressure, VMEM claim
and measured XLA-CPU wall time per algorithm.

Table 2 — workspace memory vs runtime for the 5x5 convolution of the third
inception module across every supported algorithm: demonstrates C4
(non-correlation of time and workspace).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import kernels as K
from repro.core import Op, profile, supported_algorithms


def _timeit(fn, *args, reps: int = 3) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6  # us


def table1_resource_profiles(batch: int = 4):
    """Two independent convs of inception 3a: (1x1->)3x3 and (1x1->)5x5."""
    rows = []
    convs = [("incep3a/3x3", 28, 96, 128, 3), ("incep3a/5x5", 28, 16, 32, 5)]
    for name, hw, cin, cout, k in convs:
        op = Op.make(name, "conv2d", n=batch, h=hw, w=hw, c=cin, kh=k, kw=k,
                     k=cout, stride=1)
        x = jax.random.normal(jax.random.PRNGKey(0), (batch, hw, hw, cin),
                              jnp.float32)
        w = 0.1 * jax.random.normal(jax.random.PRNGKey(1),
                                    (k, k, cin, cout), jnp.float32)
        for alg in supported_algorithms(op):
            pr = profile(op, alg)
            fn = jax.jit(lambda x, w, a=alg: K.conv2d(x, w, algorithm=a))
            us = _timeit(fn, x, w)
            mxu_util = min(pr.compute_time / pr.time, 1.0)
            hbm_util = min(pr.memory_time / pr.time, 1.0)
            rows.append({
                "table": "t1", "layer": name, "algorithm": alg,
                "us_per_call": round(us, 1),
                "mxu_frac": round(mxu_util, 3),
                "hbm_frac": round(hbm_util, 3),
                "vmem_bytes": int(pr.vmem_bytes),
                "workspace_bytes": int(pr.workspace_bytes),
                "bound": pr.bound,
            })
    return rows


def table2_workspace_vs_time(batch: int = 4):
    """5x5 conv of inception 4d-ish: workspace vs runtime per algorithm."""
    rows = []
    hw, cin, cout, k = 14, 32, 64, 5
    op = Op.make("incep4/5x5", "conv2d", n=batch, h=hw, w=hw, c=cin, kh=k,
                 kw=k, k=cout, stride=1)
    x = jax.random.normal(jax.random.PRNGKey(0), (batch, hw, hw, cin),
                          jnp.float32)
    w = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (k, k, cin, cout),
                                jnp.float32)
    for alg in ("im2col_gemm", "direct", "winograd3x3"):
        if not K.conv2d_supported(alg, k, k, 1):
            rows.append({"table": "t2", "algorithm": alg,
                         "us_per_call": None,
                         "workspace_bytes": None,
                         "note": "not supported for this input"})
            continue
        ws = K.conv2d_workspace_bytes(alg, x.shape, w.shape)
        fn = jax.jit(lambda x, w, a=alg: K.conv2d(x, w, algorithm=a))
        us = _timeit(fn, x, w)
        pr = profile(op, alg)
        rows.append({"table": "t2", "algorithm": alg,
                     "us_per_call": round(us, 1),
                     "workspace_bytes": int(ws),
                     "modeled_tpu_us": round(pr.time * 1e6, 1)})
    return rows


def matmul_algorithm_table(m=512, k=1024, n=512):
    """GEMM zoo (the LM-scale analogue of the conv zoo)."""
    rows = []
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    y = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    op = Op.make("gemm", "matmul", m=m, k=k, n=n)
    for alg in K.MATMUL_ALGORITHMS:
        fn = jax.jit(lambda x, y, a=alg: K.matmul(x, y, algorithm=a))
        us = _timeit(fn, x, y)
        pr = profile(op, alg)
        rows.append({"table": "gemm", "algorithm": alg,
                     "us_per_call": round(us, 1),
                     "workspace_bytes": int(
                         K.matmul_workspace_bytes(alg, m, n, k)),
                     "vmem_bytes": int(K.matmul_vmem_bytes(alg)),
                     "modeled_tpu_us": round(pr.time * 1e6, 2)})
    return rows
