"""Branch-parallel makespan benchmark — the paper's headline experiment.

Serial/fastest-per-op execution (what TF r1.10 does) vs concurrency-aware
co-scheduling (the paper's proposal) on GoogleNet's full inception graph,
plus the stacked-branch-GEMM kernel vs per-branch GEMMs (the intra-chip
fusion analogue), measured on this host.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import kernels as K
from repro.configs import get_config
from repro.core import compare_policies, run_stacked_matmul
from repro.models.cnn import build_graph


def makespan_table(batch: int = 32):
    rows = []
    g = build_graph(get_config("googlenet"), batch=batch)
    res = compare_policies(g)
    co_groups = [grp for grp in res["concurrent"].groups if len(grp.ops) > 1]
    # count complementary algorithm pairs (the "27 similar cases" claim)
    n_pairs = sum(1 for grp in co_groups
                  if len(set(grp.algorithms.values())) > 1)
    rows.append({
        "table": "makespan", "network": "googlenet", "batch": batch,
        "ops": len(g),
        "serial_modeled_ms": round(res["serial_makespan"] * 1e3, 3),
        "concurrent_modeled_ms": round(res["concurrent_makespan"] * 1e3, 3),
        "speedup": round(res["speedup"], 3),
        "co_exec_groups": len(co_groups),
        "complementary_pairs": n_pairs,
    })
    return rows


def stacked_branch_gemm_bench(g: int = 4, m: int = 256, k: int = 512,
                              n: int = 256):
    """Intra-chip co-execution: one stacked kernel vs G separate GEMMs."""
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    ws = jax.random.normal(jax.random.PRNGKey(1), (g, k, n), jnp.float32)

    stacked = jax.jit(lambda x, ws: run_stacked_matmul(x, ws, combine="concat"))
    serial = jax.jit(lambda x, ws: jnp.concatenate(
        [K.matmul(x, ws[i]) for i in range(g)], axis=-1))

    def t(fn):
        fn(x, ws)
        t0 = time.time()
        for _ in range(3):
            jax.block_until_ready(fn(x, ws))
        return (time.time() - t0) / 3 * 1e6

    us_stacked, us_serial = t(stacked), t(serial)
    return [{
        "table": "stacked_gemm", "branches": g, "shape": f"{m}x{k}x{n}",
        "us_per_call": round(us_stacked, 1),
        "us_serial": round(us_serial, 1),
        "host_speedup": round(us_serial / max(us_stacked, 1e-9), 3),
        "note": "XLA-CPU wall time; TPU gain comes from DMA/MXU overlap",
    }]


def modeled_vs_executed_table(batch: int = 4, reps: int = 3):
    """Modeled vs executed makespan per execution mode — the cost-model
    validation loop the plan layer closes.

    Lowers googlenet-reduced twice (serial baseline vs concurrent plan),
    executes each plan eagerly with per-mode wall timing, and times the
    jitted end-to-end forward.  Modeled columns are TPU-v5e analytic
    seconds; executed columns are XLA-CPU wall time on this host — absolute
    scales differ, the serial/planned RATIO is the comparable quantity.
    The grouped_pooled executed column carries the interpret emulation's
    per-grid-step charge for every in-kernel pool tap (~9 extra steps per
    pooled (i, kk) tile), which swamps the small reduced-net quads — the
    hardware claim for the pool stage is the MODELED column (tap reads
    pipeline under the GEMM steps; ROADMAP calibration item), and the
    controlled pooled-vs-fused wall comparison lives in
    ``branch_mode_bench`` behind ci.sh's POOLED_WALL_TOL.
    """
    from repro.configs import get_reduced
    from repro.models import cnn as CNN

    cfg = get_reduced("googlenet")
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, *cfg.img),
                          jnp.float32)
    rows, totals = [], {}
    for policy, concurrent in (("serial", False), ("planned", True)):
        plan, _ = CNN.plan_cnn(cfg, batch, concurrent=concurrent)
        CNN.forward_plan(params, cfg, x, plan)            # warm caches
        timings: dict = {}
        for _ in range(reps):
            CNN.forward_plan(params, cfg, x, plan, timings=timings)
        modeled: dict = {}
        for g in plan.groups:
            modeled[g.mode] = modeled.get(g.mode, 0.0) + g.modeled_time
        for mode in sorted(set(modeled) | set(timings)):
            rows.append({
                "table": "plan_makespan", "policy": policy, "mode": mode,
                "groups": sum(1 for g in plan.groups if g.mode == mode),
                "modeled_us": round(modeled.get(mode, 0.0) * 1e6, 3),
                "executed_us": round(timings.get(mode, 0.0) / reps * 1e6, 1),
            })
        fwd = jax.jit(lambda p, x: CNN.forward_plan(p, cfg, x, plan))
        jax.block_until_ready(fwd(params, x))
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(fwd(params, x))
        wall = (time.time() - t0) / reps
        totals[policy] = (plan.makespan, wall)
        rows.append({
            "table": "plan_makespan", "policy": policy, "mode": "TOTAL(jit)",
            "groups": len(plan.groups),
            "modeled_us": round(plan.makespan * 1e6, 3),
            "executed_us": round(wall * 1e6, 1),
        })
    rows.append({
        "table": "plan_makespan", "policy": "speedup", "mode": "-",
        "groups": "-",
        "modeled_us": round(totals["serial"][0]
                            / max(totals["planned"][0], 1e-12), 3),
        "executed_us": round(totals["serial"][1]
                             / max(totals["planned"][1], 1e-12), 3),
    })
    return rows


def branch_mode_bench(batch: int = 2, reps: int = 5):
    """pooled vs fused_concat vs grouped vs stacked vs serial wall time
    on one ragged Inception module — forward AND backward — the
    branch-GEMM benchmark.

    The SAME CoGroups (the 1x1 quad and the im2col-viewed 3x3/5x5 pair)
    execute under each forced plan mode: ``serial`` launches the
    scheduler-chosen algorithm-zoo kernel per branch plus the separate
    bias+ReLU pass, ``stacked`` pads every branch to the widest (K, N)
    and runs the branch-grid kernel, ``grouped`` runs the ragged
    grouped-GEMM kernel with the epilogue fused in-kernel (the module's
    join still a standalone concat op), and ``fused_concat`` is grouped
    with the join ABSORBED — the pair launch's epilogue writes straight
    into the join buffer (``grouped_concat`` groups, zero standalone
    concat ops).  All four keep the pool-proj pre-pool as its standalone
    ``reduce_window`` group; ``pooled`` additionally absorbs it into the
    quad's launch (``grouped_pooled`` — the in-kernel pre-GEMM pool
    stage, zero standalone pooling groups) and measures
    launches-per-group forward AND backward with the eager counter.

    The backward pass is timed as the eager VJP pullback alone (forward
    residuals held fixed): serial pulls every conv back through its
    per-op GEMM-view backward (two matmul-zoo launches per branch),
    stacked through the branch kernel's VJP, grouped/fused_concat
    through ONE combined launch per grad CoGroup (masked dx + dw/db over
    the concatenated offset table) — the mirrored grad CoGroups of
    ``core.plan.backward_plan``.  The fused variant also measures
    ``bwd_launches_per_group`` with the eager kernel-launch counter.
    Wall times are this host (XLA-CPU, Pallas interpret); modeled columns
    are the TPU-v5e analytic cost model — the same ordering story at
    both scales.
    """
    import dataclasses as _dc

    from repro.core import (backward_profiles, gemm_profiles, gemm_shape,
                            group_execution_time, group_execution_time_bwd,
                            grouped_time, profile, serial_time, stacked_time)
    from repro.core.plan import Plan
    from repro.kernels.ops import KERNEL_LAUNCHES, reset_launch_counts
    from repro.models import cnn as CNN
    from repro.models.cnn import CNNConfig, InceptionSpec

    cfg = CNNConfig(name="bench-module", img=(16, 16, 64), stem=(),
                    modules=(InceptionSpec(384, 96, 384, 8, 64, 48),),
                    pool_between=(), num_classes=10)
    g = CNN.build_graph(cfg, batch)
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, *cfg.img),
                          jnp.float32) * 0.1
    # unfused baselines keep the pool-proj pre-pool as the standalone
    # reduce_window group every serial framework launches; the ``pooled``
    # variant absorbs it into the quad's grouped launch
    plan, _ = CNN.plan_cnn(cfg, batch, fuse_concat=False, fuse_pool=False)
    plan_fused, _ = CNN.plan_cnn(cfg, batch, fuse_pool=False)
    plan_pooled, _ = CNN.plan_cnn(cfg, batch)

    def modeled_times(forced):
        fwd = bwd = 0.0
        for gr in forced.groups:
            ops = [g.ops[n] for n in gr.ops]
            profs = [profile(op, gr.algorithms[op.name]) for op in ops]
            if gr.mode == "grouped_concat":
                branch = [op for op in ops if op.name != gr.join]
                bprofs = [p for op, p in zip(ops, profs)
                          if op.name != gr.join]
                fwd += group_execution_time(branch, bprofs,
                                            join=g.ops[gr.join])[1]
                bwd += group_execution_time_bwd(
                    branch, gr.algorithms, mode="grouped_concat",
                    join=g.ops[gr.join])[1]
            elif len(ops) == 1 or gr.mode == "serial":
                # singleton maxpool groups price their pool_profile row
                # here — the standalone-launch term the pooled variant's
                # absorbed groups zero out
                fwd += serial_time(profs)
                bwd += sum(
                    p.time for op in ops
                    for p in backward_profiles(op, gr.algorithms[op.name]))
            elif gr.mode == "stacked":
                fwd += stacked_time(gemm_profiles(ops),
                                    [gemm_shape(op) for op in ops])
                bwd += group_execution_time_bwd(ops, gr.algorithms,
                                                mode="stacked")[1]
            else:
                fwd += grouped_time(ops)
                bwd += group_execution_time_bwd(
                    ops, gr.algorithms,
                    mode=gr.mode if gr.mode == "grouped_pooled"
                    else "grouped")[1]
        return fwd, bwd

    variants = {}
    for mode in ("serial", "stacked", "grouped"):
        variants[mode] = Plan(
            [_dc.replace(gr, mode=mode) if len(gr.ops) > 1 else gr
             for gr in plan.groups], dict(plan.context))
    # fused_concat == grouped everywhere except the join handling: the
    # concat group keeps its absorbed join, every other multi group runs
    # the grouped kernel
    variants["fused_concat"] = Plan(
        [gr if gr.mode == "grouped_concat" or len(gr.ops) == 1
         else _dc.replace(gr, mode="grouped")
         for gr in plan_fused.groups], dict(plan_fused.context))
    # pooled == fused_concat plus pool absorption: the quad's launch pools
    # the pool-proj lhs in-kernel (grouped_pooled), zero standalone
    # reduce_window groups — the tentpole configuration, as lowered
    variants["pooled"] = plan_pooled

    # warm every variant, then time them INTERLEAVED and keep the
    # per-variant minimum across reps: a load spike on this shared host
    # hits all modes of that rep alike instead of biasing whichever
    # variant it landed on (sequential per-mode averaging made the
    # fused-vs-grouped comparison a coin flip under load)
    rows, result, pullbacks = [], {}, {}
    for mode, forced in variants.items():
        result[mode] = {"wall_us": float("inf"), "bwd_wall_us": float("inf")}
        CNN.forward_plan(params, cfg, x, forced)             # warm caches
        y, f_vjp = jax.vjp(
            lambda p, forced=forced: CNN.forward_plan(p, cfg, x, forced),
            params)
        ct = jnp.ones_like(y)
        jax.block_until_ready(f_vjp(ct))                     # warm caches
        pullbacks[mode] = (f_vjp, ct)
    for _ in range(reps):
        for mode, forced in variants.items():
            timings: dict = {}      # per-group eager wall, this rep only
            CNN.forward_plan(params, cfg, x, forced, timings=timings)
            result[mode]["wall_us"] = min(result[mode]["wall_us"],
                                          sum(timings.values()) * 1e6)
            f_vjp, ct = pullbacks[mode]
            t0 = time.time()
            jax.block_until_ready(f_vjp(ct))   # eager VJP pullback alone
            result[mode]["bwd_wall_us"] = min(result[mode]["bwd_wall_us"],
                                              (time.time() - t0) * 1e6)
    for mode, forced in variants.items():
        modeled, modeled_bwd = modeled_times(forced)
        result[mode]["wall_us"] = round(result[mode]["wall_us"], 1)
        result[mode]["bwd_wall_us"] = round(result[mode]["bwd_wall_us"], 1)
        result[mode]["modeled_us"] = round(modeled * 1e6, 3)
        result[mode]["bwd_modeled_us"] = round(modeled_bwd * 1e6, 3)
        if mode in ("fused_concat", "pooled"):
            # one grouped-family kernel per co-exec group, forward AND
            # backward (one combined dx+dw/db launch per grad CoGroup) —
            # measured by the eager launch counter
            n_groups = sum(1 for gr in forced.groups
                           if gr.mode in ("grouped", "grouped_concat",
                                          "grouped_pooled"))
            f_vjp, ct = pullbacks[mode]
            reset_launch_counts()
            jax.block_until_ready(f_vjp(ct))
            launches = KERNEL_LAUNCHES.get("grouped_matmul_bwd", 0)
            result[mode]["bwd_launches_per_group"] = launches / max(
                n_groups, 1)
            reset_launch_counts()
            CNN.forward_plan(params, cfg, x, forced)
            fwd_names = ("grouped_matmul", "grouped_matmul_concat",
                         "grouped_matmul_pooled",
                         "grouped_matmul_pooled_concat")
            result[mode]["fwd_launches_per_group"] = sum(
                KERNEL_LAUNCHES.get(nm, 0) for nm in fwd_names) / max(
                n_groups, 1)
            # standalone reduce_window groups left in the plan (0 once
            # pooling streams through the grouped launch)
            result[mode]["standalone_pool_groups"] = sum(
                1 for gr in forced.groups
                if any(g.ops[n].kind == "maxpool" for n in gr.ops))
        rows.append({
            "table": "branch_gemm_modes", "mode": mode, "batch": batch,
            "us_per_call": result[mode]["wall_us"],
            "modeled_us": result[mode]["modeled_us"],
            "bwd_us_per_call": result[mode]["bwd_wall_us"],
            "bwd_modeled_us": result[mode]["bwd_modeled_us"],
            "module": "inc(384,96r3,384,8r5,64,48) c64 16x16",
        })
    return rows, result


def fused_complementary_bench(m=2048, k=2048, n=2048, r=65536, c=128):
    """The intra-SM analogue made literal: one kernel co-executing an
    MXU-bound GEMM with an HBM-bound reduction.  Reports the modeled TPU
    co-execution win (cost model) — the quantity the paper's Table 1
    argues for."""
    from repro.core import Op, co_execution_time, profile, serial_time
    a = profile(Op.make("gemm", "matmul", m=m, k=k, n=n), "mxu128")
    b = profile(Op.make("red", "pointwise", elements=r * c), "vpu")
    t_serial = serial_time([a, b])
    t_co = co_execution_time([a, b])
    return [{
        "table": "fused_branches", "shape": f"gemm{m}x{k}x{n}+reduce{r}x{c}",
        "us_per_call": round(t_co * 1e6, 2),
        "us_serial_modeled": round(t_serial * 1e6, 2),
        "modeled_speedup": round(t_serial / max(t_co, 1e-12), 3),
        "gemm_bound": a.bound, "reduce_bound": b.bound,
        "kernel": "kernels/fused_branches.py (validated interpret=True)",
    }]
