"""MoE expert-dispatch bench column: the per-expert-ragged grouped engine
vs the capacity-padded einsum oracle on one serving-representative layer.

The shape (b=2, s=256, top_k=2, e=8, cf=4.0, d=128, f=64 — granite-moe
reduced dims at a serving sequence length) is chosen so the comparison is
honest on BOTH axes: enough routed tokens that the grouped kernel's
per-expert partial blocks are amortized (at tiny s the +E partial-block
overhead would flip the modeled ordering), and a real 4x capacity factor
so the einsum engine pays the padded-slot waste the paper's FLOP argument
is about.  Recorded per engine:

  wall_us / bwd_wall_us — jitted XLA-CPU wall (interpret-mode Pallas; the
      comparable quantity is engine-vs-engine on the SAME host, the
      decisive column is modeled);
  modeled_us            — ``cost_model.moe_dispatch_times`` (TPU-v5e
      analytic) read back from the CACHED ``lower_moe`` plan so the bench
      exercises ``plan_cache.cached_moe_plan`` and the recorded pricing
      is exactly what the plan layer decided from;
  launches              — eager per-direction grouped-family launch
      counts (ONE forward kernel, ONE combined backward kernel);
  bitmatch_ok           — model-level grouped output == einsum output,
      bit-for-bit (routing/drops/combine are shared code);
  zero_token_expert_ok  — kernel vs per-expert oracle on a count mix with
      an empty expert: outputs bit-match AND the empty expert's dW comes
      back exact zeros from the combined backward;
  padded_slot_fraction  — the new aux stat: the fraction of einsum
      capacity slots that hold no routed token (pure FLOP waste the
      grouped grid never materializes).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

MOE_SHAPE = dict(b=2, s=256, d=128, f=64, e=8, top_k=2,
                 capacity_factor=4.0)


def moe_dispatch_bench(reps: int = 3):
    """-> (csv rows, BENCH_plan.json column dict)."""
    from repro import kernels as K
    from repro.core import plan_cache
    from repro.models import moe as MOE

    b, s, d, f, e = (MOE_SHAPE[k] for k in "bsdfe")
    k, cf = MOE_SHAPE["top_k"], MOE_SHAPE["capacity_factor"]
    params = MOE.moe_init(jax.random.PRNGKey(0), d, f, e)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5

    def fwd(impl):
        return jax.jit(lambda p, xx: MOE.moe_apply(
            p, xx, top_k=k, capacity_factor=cf, impl=impl)[0])

    def bwd(impl):
        return jax.jit(jax.grad(lambda p, xx: MOE.moe_apply(
            p, xx, top_k=k, capacity_factor=cf, impl=impl)[0].sum()))

    def t(fn):
        jax.block_until_ready(fn(params, x))        # compile + warm
        t0 = time.time()
        for _ in range(reps):
            jax.block_until_ready(fn(params, x))
        return (time.time() - t0) / reps * 1e6

    wall = {impl: t(fwd(impl)) for impl in ("einsum", "grouped")}
    bwd_wall = {impl: t(bwd(impl)) for impl in ("einsum", "grouped")}

    # bit-match + the padded-slot waste the einsum engine pays
    oe, auxe = MOE.moe_apply(params, x, top_k=k, capacity_factor=cf,
                             impl="einsum")
    og, auxg = MOE.moe_apply(params, x, top_k=k, capacity_factor=cf,
                             impl="grouped")
    bitmatch = bool(np.array_equal(np.asarray(oe), np.asarray(og)))
    padded = float(auxg["padded_slot_fraction"])
    assert abs(padded - float(auxe["padded_slot_fraction"])) < 1e-9

    # modeled pricing via the CACHED plan — exercises cached_moe_plan and
    # reads back exactly what lower_moe decided from
    entry = plan_cache.cached_moe_plan(b=b, s=s, d=d, f=f, e=e, top_k=k,
                                       capacity_factor=cf)
    moe_ctx = entry.plan.context["moe"]
    modeled = {eng: tm * 1e6 for eng, tm in moe_ctx["times"].items()}
    (grp,) = entry.plan.groups_of_mode("grouped_experts")

    # eager per-direction launch counts: ONE kernel each way
    K.reset_launch_counts()
    MOE.moe_apply(params, x, top_k=k, capacity_factor=cf, impl="grouped")
    fwd_launches = dict(K.KERNEL_LAUNCHES)
    K.reset_launch_counts()
    jax.grad(lambda p: MOE.moe_apply(p, x, top_k=k, capacity_factor=cf,
                                     impl="grouped")[0].sum())(params)
    grad_launches = dict(K.KERNEL_LAUNCHES)
    launches = {
        "per_forward": fwd_launches.get("grouped_matmul_experts", 0),
        "per_backward": grad_launches.get("grouped_matmul_experts_bwd", 0),
    }

    # zero-token-expert correctness at the kernel level (deterministic —
    # model-level routing of a random batch need not leave an expert
    # empty): counts [16, 0, 9, 3] vs the per-expert oracle, bit-for-bit,
    # and the empty expert's dW exact zero from the combined backward
    counts = jnp.asarray([16, 0, 9, 3], jnp.int32)
    bm = 8
    offs = np.asarray(K.expert_row_offsets(counts, bm))
    n_rows = int(offs[-1]) + max(-(-int(counts[-1]) // bm), 1) * bm
    kx = jnp.zeros((n_rows, d))
    ksw = jnp.zeros((n_rows,))
    for g, c in enumerate(np.asarray(counts)):
        if c:
            kx = kx.at[offs[g]:offs[g] + c].set(jax.random.normal(
                jax.random.PRNGKey(10 + g), (int(c), d)) * 0.3)
            ksw = ksw.at[offs[g]:offs[g] + c].set(0.5)
    kw_in = jax.random.normal(jax.random.PRNGKey(2), (4, d, f)) * 0.3
    kw_out = jax.random.normal(jax.random.PRNGKey(3), (4, f, d)) * 0.3
    kw_gate = jax.random.normal(jax.random.PRNGKey(4), (4, d, f)) * 0.3
    ky = K.grouped_matmul_experts(kx, ksw, kw_in, kw_out, kw_gate, counts,
                                  bm=bm)
    kref = K.grouped_matmul_experts_ref(kx, ksw, kw_in, kw_out, kw_gate,
                                        counts, bm=bm)
    dwin = jax.grad(lambda w: K.grouped_matmul_experts(
        kx, ksw, w, kw_out, kw_gate, counts, bm=bm).sum())(kw_in)
    zero_ok = bool(np.array_equal(np.asarray(ky), np.asarray(kref))
                   and not np.asarray(dwin[1]).any())

    col = {
        "shape": dict(MOE_SHAPE),
        "wall_us": {eng: round(v, 1) for eng, v in wall.items()},
        "bwd_wall_us": {eng: round(v, 1) for eng, v in bwd_wall.items()},
        "modeled_us": {eng: round(v, 3) for eng, v in modeled.items()},
        "modeled_grouped_ok": modeled["grouped"] <= modeled["einsum"],
        "bitmatch_ok": bitmatch,
        "zero_token_expert_ok": zero_ok,
        "launches": launches,
        "padded_slot_fraction": round(padded, 4),
        "plan_mode_counts": entry.plan.mode_counts(),
        "grouped_experts_reason": grp.reason,
        "bm": moe_ctx["bm"], "capacity": moe_ctx["cap"],
    }
    rows = [{
        "table": "moe", "engine": eng,
        "us_per_call": round(wall[eng], 1),
        "bwd_us": round(bwd_wall[eng], 1),
        "modeled_us": round(modeled[eng], 3),
        "note": "one ragged launch/direction" if eng == "grouped"
        else f"padded_slot_fraction={padded:.2f}",
    } for eng in ("einsum", "grouped")]
    return rows, col
