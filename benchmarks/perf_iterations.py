"""§Perf: hypothesis -> change -> before/after on the three hillclimb cells.

Analyzes the perf-variant dry-run HLOs (produced by dryrun.py --perf ...)
against each cell's baseline and emits results/perf_iterations.json +
a markdown log for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import SHAPES, get_config                  # noqa: E402
from repro.roofline.analyze import HloModule, roofline_terms  # noqa: E402

DRY = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

CELLS = {
    "llama3_8b__train_4k": [
        ("baseline", ""),
        ("causal_skip", "causal_skip"),
        ("dots_remat", "dots_remat"),
        ("seq_shard", "seq_shard"),
        ("skip+dots+sp", "causal_skip_dots_remat_seq_shard"),
        ("zero3", "zero3"),
        ("zero3+skip+dots", "causal_skip_dots_remat_zero3"),
    ],
    "granite_moe_1b_a400m__train_4k": [
        ("baseline", ""),
        ("dp_over_model", "dp_over_model"),
        ("dp+skip+dots", "causal_skip_dots_remat_dp_over_model"),
        ("dp+moe_local", "dp_over_model_moe_local"),
        ("dp+local+sk+dt", "causal_skip_dots_remat_dp_over_model_moe_local"),
    ],
    "jamba_1_5_large_398b__train_4k": [
        ("baseline", ""),
        ("dots_remat", "dots_remat"),
        ("seq_shard", "seq_shard"),
        ("dots+sp", "dots_remat_seq_shard"),
        ("zero3", "zero3"),
        ("zero3+dots", "dots_remat_zero3"),
        ("moe_ep", "moe_ep"),
        ("moe_ep+dots", "dots_remat_moe_ep"),
        ("moe_ep+dots+skip", "causal_skip_dots_remat_moe_ep"),
    ],
    "gemma2_27b__train_4k": [
        ("baseline", ""),
        ("zero3+skip+dots", "causal_skip_dots_remat_zero3"),
    ],
    "qwen2_moe_a2_7b__train_4k": [
        ("baseline", ""),
        ("zero3", "zero3"),
        ("zero3+skip+dots", "causal_skip_dots_remat_zero3"),
    ],
    # serving-path hillclimb (decode/prefill cells)
    "llama3_8b__decode_32k": [
        ("baseline", ""),
        ("no_fsdp", "no_fsdp"),
        ("no_fsdp+cacheSP", "cache_seq_shard_no_fsdp"),
    ],
    "jamba_1_5_large_398b__decode_32k": [
        ("baseline", ""),
        ("no_fsdp", "no_fsdp"),
    ],
    "jamba_1_5_large_398b__prefill_32k": [
        ("baseline", ""),
        ("no_fsdp", "no_fsdp"),
        ("no_fsdp+moe_ep", "moe_ep_no_fsdp"),
    ],
}


def analyze(cell: str, suffix: str):
    tag = f"{cell}__single" + (f"__{suffix}" if suffix else "")
    hpath = os.path.join(DRY, tag + ".hlo.txt")
    jpath = os.path.join(DRY, tag + ".json")
    if not os.path.exists(hpath):
        return None
    rec = json.load(open(jpath))
    cost = HloModule(open(hpath).read()).cost()
    t = roofline_terms(cost)
    arch, shape_name = cell.split("__", 1)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        mf = 6.0 * cfg.active_param_count() * shape.global_batch \
            * shape.seq_len / rec["chips"]
    elif shape.kind == "prefill":
        mf = 2.0 * cfg.active_param_count() * shape.global_batch \
            * shape.seq_len / rec["chips"]
    else:  # decode: one token per sequence per step
        mf = 2.0 * cfg.active_param_count() * shape.global_batch \
            / rec["chips"]
    step = max(t["compute_s"], t["memory_s"], t["collective_s"])
    t["roofline_fraction"] = (mf / 197e12) / max(step, 1e-30)
    t["usefulness"] = mf / max(t["flops"], 1.0)
    t["temp_gb"] = rec.get("temp_size_in_bytes", 0) / 1e9
    return t


def main():
    out = {}
    for cell, variants in CELLS.items():
        rows = []
        for name, suffix in variants:
            t = analyze(cell, suffix)
            if t is None:
                continue
            rows.append({"variant": name, **{k: t[k] for k in (
                "compute_s", "memory_s", "collective_s", "dominant",
                "roofline_fraction", "usefulness", "temp_gb", "flops",
                "wire_bytes")}})
        out[cell] = rows
        print(f"\n== {cell} ==")
        print(f"{'variant':14s} {'compute':>9s} {'memory':>9s} {'coll':>9s} "
              f"{'dom':>11s} {'frac':>7s} {'useful':>7s} {'tempGB':>7s}")
        for r in rows:
            print(f"{r['variant']:14s} {r['compute_s']:9.3f} "
                  f"{r['memory_s']:9.3f} {r['collective_s']:9.3f} "
                  f"{r['dominant']:>11s} {r['roofline_fraction']:7.3f} "
                  f"{r['usefulness']:7.3f} {r['temp_gb']:7.1f}")
    with open(os.path.join(os.path.dirname(__file__), "..", "results",
                           "perf_iterations.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
