"""Named wall-clock tolerances and launch ceilings — the ONLY place the
numbers live.  Both ``benchmarks/run.py`` (which records the ``*_ok``
booleans into BENCH_plan*.json) and ``scripts/ci.sh`` (which gates on
them) import from here, so the recorded verdicts and the CI gates can
never disagree about what "ok" means.

The prose rationale for each number lives next to the gates in
``scripts/ci.sh``; the short version:

  BWD_WALL_TOL         grouped-vs-stacked backward wall — strict 1.0
                       (raise only with a measured reason).
  FUSED_WALL_TOL       fused-concat vs grouped forward wall jitter floor
                       (the deleted join is ~1ms of a ~400ms module; the
                       decisive fused claim is the MODELED column).
  POOLED_WALL_TOL      pooled vs fused-concat forward wall: the interpret
                       emulation charges in-kernel pool taps as real grid
                       steps (~9 per pooled tile) while the baseline's
                       reduce_window is a compiled XLA op.
  POOLED_BWD_WALL_TOL  pooled backward is the SAME combined launch either
                       way (only the tap fold differs) — near-strict.
  LAUNCH_CEILING_CHAINED_FWD    chained googlenet forward: 10 launches
                       today, ceiling 12 (every launch-like primitive).
  LAUNCH_CEILING_UNCHAINED_PALLAS  default plan: 21 pallas kernels today,
                       ceiling 22.  Keep in sync with tests/test_chained.py.
  MOE_WALL_TOL         grouped vs einsum expert-engine forward wall on the
                       bench layer: the interpret emulation executes every
                       grid step of the ragged kernel as python (~70 steps
                       on the bench layer) while the einsum engine is ONE
                       compiled XLA einsum, so the ratio measures the
                       emulation overhead under host load (5-8x observed),
                       not the engines — the gate is only a
                       does-not-explode guard against e.g. an accidental
                       per-call repack; the decisive claim is the MODELED
                       column (strict: grouped FLOPs scale with routed
                       tokens, einsum with E*capacity) plus the bit-match
                       and one-launch-per-direction invariants, which
                       have no tolerance at all.
  MOE_LAUNCHES_PER_DIRECTION  the tentpole invariant: ONE grouped-family
                       kernel forward, ONE combined (dx + every dW)
                       kernel backward.
"""

BWD_WALL_TOL = 1.0
FUSED_WALL_TOL = 1.10
POOLED_WALL_TOL = 1.5
POOLED_BWD_WALL_TOL = 1.15

LAUNCH_CEILING_CHAINED_FWD = 12
LAUNCH_CEILING_UNCHAINED_PALLAS = 22

MOE_WALL_TOL = 20.0
MOE_LAUNCHES_PER_DIRECTION = 1
