"""§Roofline: three-term roofline per (arch x shape) from the dry-run HLO.

Reads results/dryrun/<arch>__<shape>__single.{json,hlo.txt}, runs the
while-corrected HLO analyzer, and emits results/roofline.json plus a
markdown table for EXPERIMENTS.md.

  compute_s  = FLOPs_per_chip / 197e12
  memory_s   = HBM_bytes_per_chip / 819e9
  coll_s     = wire_bytes_per_chip / 50e9
  MODEL_FLOPS = c * N_active * tokens   (c=6 train fwd+bwd, c=2 fwd-only)
  usefulness  = MODEL_FLOPS_per_chip / HLO_FLOPs_per_chip
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCHS, SHAPES, get_config          # noqa: E402
from repro.roofline.analyze import HloModule, roofline_terms  # noqa: E402

DRY = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
OUT = os.path.join(os.path.dirname(__file__), "..", "results",
                   "roofline.json")


def model_flops(cfg, shape) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch


def analyze_cell(arch: str, shape_name: str, mesh: str = "single"):
    tag = f"{arch}__{shape_name}__{mesh}"
    jpath = os.path.join(DRY, tag + ".json")
    hpath = os.path.join(DRY, tag + ".hlo.txt")
    if not os.path.exists(jpath):
        return None
    rec = json.load(open(jpath))
    if not rec.get("ok") or not os.path.exists(hpath):
        return rec
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cost = HloModule(open(hpath).read()).cost()
    terms = roofline_terms(cost)
    mf = model_flops(cfg, shape) / rec["chips"]
    terms["model_flops_per_chip"] = mf
    terms["usefulness"] = mf / max(cost.flops, 1.0)
    # roofline fraction: the useful-compute time over the modeled step time
    ideal = mf / 197e12
    step = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
    terms["roofline_fraction"] = ideal / max(step, 1e-30)
    rec["roofline"] = terms
    return rec


def main():
    rows = []
    for arch in (a for a in ARCHS if a != "googlenet"):
        cfg = get_config(arch)
        shapes = ["train_4k", "prefill_32k", "decode_32k"] + \
            (["long_500k"] if cfg.sub_quadratic else [])
        for s in shapes:
            print(f"[roofline] {arch} {s}", flush=True)
            rec = analyze_cell(arch, s)
            if rec is not None:
                rows.append(rec)
    with open(OUT, "w") as f:
        json.dump(rows, f, indent=1)

    # markdown table
    print("\n| arch | shape | compute_s | memory_s | coll_s | dominant | "
          "useful | roofline_frac |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        t = r.get("roofline")
        if not t:
            print(f"| {r['arch']} | {r['shape']} | - | - | - | FAILED | - | - |")
            continue
        print(f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
              f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
              f"{t['dominant']} | {t['usefulness']:.3f} | "
              f"{t['roofline_fraction']:.3f} |")


if __name__ == "__main__":
    main()
