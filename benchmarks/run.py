"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  t1        — Table-1 analogue: per-algorithm resource profiles of two
              independent inception convolutions.
  t2        — Table-2 analogue: workspace memory vs runtime per conv
              algorithm (C4 non-correlation).
  gemm      — the GEMM algorithm zoo (LM-scale analogue).
  makespan  — serial vs concurrency-aware scheduling on GoogleNet (the
              paper's proposal, modeled TPU makespan) + the 27-cases count.
  stacked   — intra-chip stacked branch GEMM vs per-branch GEMMs.
  branch_gemm_modes — pooled vs fused_concat vs grouped vs stacked vs
              serial execution of one ragged Inception module's CoGroups,
              forward AND backward (the eager VJP pullback per forced
              mode — the grad CoGroups of core/plan.py backward_plan;
              fused_concat absorbs the join into the grouped launch,
              pooled additionally streams the pool-proj maxpool through
              the quad's launch, and both run ONE combined backward
              launch per grad CoGroup).
  plan_makespan — modeled vs executed makespan per execution mode for the
              lowered plan (core/plan.py), serial vs planned — the
              cost-model validation table.
  roofline  — summary of the dry-run roofline table (if generated).

Wall times are XLA-CPU (this host); modeled columns are TPU-v5e analytic.

Besides the CSV, writes ``BENCH_plan.json`` (machine-readable perf
baseline: branch-GEMM mode wall/modeled times forward+backward, googlenet
forward/backward mode counts and modeled train-step makespan, the
cross-module-streaming column — chained-plan mode counts, modeled
makespans and traced-jaxpr ``googlenet_launches`` per direction for the
default AND ``chain_modules=True`` plans — the continuous-batching
serving column (QPS + request-level p50/p99 latency through the cached
ragged plans of ``launch/serve.py``, plan-cache hit stats, padded-M
waste, the served chained forward's traced launch count, the
masked-chained bit-match verdict and the dead-block skip ratio) — the MoE
expert-dispatch column (grouped ragged engine vs capacity-padded einsum:
wall + modeled per engine, one-launch-per-direction counts, bit-match
and zero-token-expert verdicts, padded_slot_fraction) — and the
plan_makespan rows).  ``--smoke`` runs a seconds-scale subset (fewer
reps, no plan_makespan; same batch=2 module — batch 1 is unrepresentative
of the grouped-vs-stacked backward) and writes ``BENCH_plan.smoke.json``
instead
so a quick CI pass never clobbers the committed baseline; ``scripts/ci.sh``
asserts the smoke guardrails (backward wall ordering grouped <= stacked
<= serial, fused_concat no slower than grouped, one combined backward
launch per grad CoGroup, zero standalone googlenet joins).
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

REPO = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..")


def _emit(rows):
    for r in rows:
        name = r.pop("table")
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}", flush=True)


def _dead_block_skip():
    """Executed-vs-skipped grid steps of a masked chained launch on a
    rows/image == bm fixture (4 images, 4 M-blocks) at one live image:
    the grid-step counter must show the dead blocks ran ZERO steps, so
    the skip ratio is exactly 1 - n/bucket = 0.75."""
    import importlib

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis import tables
    from repro.core import plan as planlib
    gmm = importlib.import_module("repro.kernels.grouped_matmul")

    b, h, w = 4, 16, 8                      # h*w = 128 rows/image = bm
    m = b * h * w
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x0 = jax.random.normal(ks[0], (m, 64)) * 0.3
    w0 = jax.random.normal(ks[1], (64, 48)) * 0.3
    wmat = jax.random.normal(ks[3], (48 * 9, 40)) * 0.3
    phases = [
        [{"n": 48, "w": planlib._pad_w_dense(w0, 128),
          "b": jax.random.normal(ks[2], (48,)),
          "src": ("x", [x0]), "ring_write": (0,)}],
        [{"n": 40, "w": planlib._pack_w_ring(wmat, 3, 3, 48, 1, 128),
          "b": jax.random.normal(ks[4], (40,)),
          "src": ("ring", 3, 3, (0,)), "ring_write": None}],
    ]
    _, steps = gmm.grouped_matmul_chained(
        phases, m=m, h=h, w=w, m_valid=h * w, debug_steps=True,
        interpret=True)
    tab = np.asarray(gmm._plan_tiles_chained(
        m // 128, gmm._chain_static(phases, 128, 128, w)))
    total = tab.shape[1]
    executed = int(jnp.asarray(steps)[0, 0])
    return {
        "bucket_images": b,
        "live_images": 1,
        "grid_steps": total,
        "executed_steps": executed,
        "skip_ratio": (total - executed) / total,
        "expected_skip_ratio": 1 - 1 / b,
    }


def main(smoke: bool = False) -> None:
    from benchmarks.paper_tables import (matmul_algorithm_table,
                                         table1_resource_profiles,
                                         table2_workspace_vs_time)
    from benchmarks.branch_parallel_bench import (
        branch_mode_bench, fused_complementary_bench, makespan_table,
        modeled_vs_executed_table, stacked_branch_gemm_bench)
    from benchmarks.tolerances import FUSED_WALL_TOL, POOLED_WALL_TOL
    from repro.configs import get_config, get_reduced
    from repro.models import cnn as CNN

    bench_json: dict = {"host": "xla-cpu (Pallas interpret)",
                        "modeled": "TPU-v5e analytic cost model"}

    print("name,us_per_call,derived")
    if not smoke:
        _emit(table1_resource_profiles())
        _emit(table2_workspace_vs_time())
        _emit(matmul_algorithm_table())
    _emit(makespan_table())

    # batch 2 even in smoke: at batch 1 (M=256 rows) the grouped kernels'
    # fixed packing overhead dominates the interpret-mode wall and the
    # grouped-vs-stacked backward ordering is not representative
    mode_rows, modes = branch_mode_bench(batch=2, reps=3 if smoke else 5)
    _emit([dict(r) for r in mode_rows])
    wall = {m: v["wall_us"] for m, v in modes.items()}
    bwd_wall = {m: v["bwd_wall_us"] for m, v in modes.items()}
    modeled = {m: v["modeled_us"] for m, v in modes.items()}
    bwd_modeled = {m: v["bwd_modeled_us"] for m, v in modes.items()}
    bench_json["branch_gemm"] = {
        "module": mode_rows[0]["module"] if mode_rows else "",
        "wall_us": wall,
        "modeled_us": modeled,
        "wall_ordering_ok": wall["grouped"] <= wall["stacked"]
        <= wall["serial"],
        # *_wall_ok booleans apply the SAME named tolerances ci.sh gates
        # with (benchmarks/tolerances.py) — previously they recorded the
        # raw strict comparison, so a run inside tolerance could write
        # "fused_wall_ok": false into the baseline while CI passed
        "fused_wall_ok":
            wall["fused_concat"] <= FUSED_WALL_TOL * wall["grouped"],
        "fused_modeled_ok": modeled["fused_concat"] <= modeled["grouped"]
        and bwd_modeled["fused_concat"] <= bwd_modeled["grouped"],
        # pooled = fused_concat + the pool-proj maxpool absorbed into the
        # quad launch: modeled drops the standalone reduce_window term
        # (strict win); wall trades a compiled reduce_window for in-kernel
        # pool steps the interpret emulation charges per grid step, so the
        # wall gate lives in ci.sh behind a named tolerance
        "pooled_wall_ok":
            wall["pooled"] <= POOLED_WALL_TOL * wall["fused_concat"],
        "pooled_modeled_ok":
            modeled["pooled"] < modeled["fused_concat"]
            and bwd_modeled["pooled"] <= bwd_modeled["fused_concat"],
        "bwd_wall_us": bwd_wall,
        "bwd_modeled_us": bwd_modeled,
        "bwd_wall_ordering_ok": bwd_wall["grouped"] <= bwd_wall["stacked"]
        <= bwd_wall["serial"],
        "bwd_grouped_beats_serial": bwd_wall["grouped"] <= bwd_wall["serial"],
        "bwd_launches_per_group":
            modes["fused_concat"]["bwd_launches_per_group"],
        "pooled_fwd_launches_per_group":
            modes["pooled"]["fwd_launches_per_group"],
        "pooled_bwd_launches_per_group":
            modes["pooled"]["bwd_launches_per_group"],
        "pooled_standalone_pool_groups":
            modes["pooled"]["standalone_pool_groups"],
    }
    # train=True: the same packing + per-direction budget checks the train
    # driver lowers with — the recorded backward metrics describe the plan
    # the training step actually executes, not an inference-packed one
    plan, _ = CNN.plan_cnn(get_config("googlenet"), batch=32, train=True)
    bwd_plan = plan.context["backward"]
    bench_json["googlenet_mode_counts"] = plan.mode_counts()
    bench_json["googlenet_xla_fallback_groups"] = len(
        plan.groups_of_mode("xla"))
    # zero standalone inception joins on the fused path: every join rides
    # a grouped_concat launch
    bench_json["googlenet_standalone_join_groups"] = sum(
        1 for g in plan.groups
        if g.mode != "grouped_concat" and any("join" in n for n in g.ops))
    # zero standalone maxpool (reduce_window) groups: every pooling
    # primitive streams through a grouped launch (_absorb_pools) — count
    # by op KIND from the graph, not by name, so a rename can't make the
    # ci.sh gate vacuous
    g32 = CNN.build_graph(get_config("googlenet"), 32)
    bench_json["googlenet_standalone_pool_groups"] = sum(
        1 for g in plan.groups
        if any(n in g32.ops and g32.ops[n].kind == "maxpool"
               for n in g.ops))
    bench_json["googlenet_bwd_mode_counts"] = bwd_plan.mode_counts()
    bench_json["googlenet_bwd_xla_fallback_groups"] = len(
        bwd_plan.groups_of_mode("xla"))
    # forward+backward modeled makespans (TPU-v5e analytic, seconds): the
    # training step's two halves under the lowered plans
    bench_json["googlenet_makespan_modeled_s"] = {
        "forward": plan.makespan,
        "backward": bwd_plan.makespan,
        "train_step": plan.makespan + bwd_plan.makespan,
    }

    # cross-module streaming: the chained plan's column next to the
    # default — modeled makespans (both directions) and the traced-jaxpr
    # launch counts the ci.sh launch-ceiling gate pins.  Counts are
    # batch-invariant (plan structure, not data), so the trace runs at
    # batch 2 to keep the smoke pass seconds-scale.
    import jax
    import jax.numpy as jnp
    from repro.core import launch_count as launch_lc
    gcfg = get_config("googlenet")
    plan_c, _ = CNN.plan_cnn(gcfg, batch=32, train=True, chain_modules=True)
    bwd_c = plan_c.context["backward"]
    bench_json["googlenet_chained_mode_counts"] = plan_c.mode_counts()
    bench_json["googlenet_chained_makespan_modeled_s"] = {
        "forward": plan_c.makespan,
        "backward": bwd_c.makespan,
        "train_step": plan_c.makespan + bwd_c.makespan,
    }
    bench_json["googlenet_chained_modeled_ok"] = (
        plan_c.makespan < plan.makespan and bwd_c.makespan < bwd_plan.makespan)

    cparams = CNN.init_params(gcfg, jax.random.PRNGKey(0))
    cbatch = {"images": jnp.zeros((2,) + gcfg.img, jnp.float32),
              "labels": jnp.zeros((2,), jnp.int32)}
    pc2, _ = CNN.plan_cnn(gcfg, batch=2, train=True, chain_modules=True)
    pu2, _ = CNN.plan_cnn(gcfg, batch=2, train=True)
    launches = {}
    for lname, lplan in (("default", pu2), ("chained", pc2)):
        def _loss(p, b, _pl=lplan):
            return CNN.loss_fn(p, gcfg, b, plan=_pl)[0]
        fwd = launch_lc.count_launches(_loss, cparams, cbatch)
        both = launch_lc.count_grad_launches(_loss, cparams, cbatch)
        launches[lname] = {
            "per_forward": fwd["total"],
            "per_backward": max(both["total"] - fwd["total"], 0),
            "pallas_per_forward": fwd.get("pallas_call", 0),
            "grad_trace_total": both["total"],
        }
    bench_json["googlenet_launches"] = launches

    # continuous-batching serving column (runs in smoke too — ci.sh gates
    # it): the ragged-M + plan-cache path of launch/serve.py on
    # googlenet-reduced.  Executed QPS and p50/p99 dispatch latency
    # through ONE cached chained plan + offset tables + jitted executable
    # per M-bucket; the driver itself asserts the post-warmup stream runs
    # at plan-cache hit rate 1.0.  Interpret-mode wall times — the
    # recorded value is the cache/raggedness behavior, not TPU latency.
    from repro.core import plan_cache
    from repro.launch.serve import serve_cnn_metrics
    from repro.launch.steps import make_cnn_serve_step
    plan_cache.reset(clear_entries=True)
    bench_json["serving"] = serve_cnn_metrics(
        get_reduced("googlenet"), max_images=4,
        num_requests=10 if smoke else 24, seed=0)
    # trace-only ceiling for FULL googlenet: the served (ragged, chained)
    # forward must stay under the same launch ceiling as the training
    # trace above — raggedness must not add launches
    sentry = plan_cache.cached_cnn_plan(gcfg, 2, chain_modules=True)
    sfwd = launch_lc.count_launches(
        make_cnn_serve_step(gcfg, sentry.plan), cparams,
        jnp.zeros((2,) + gcfg.img, jnp.float32), jnp.int32(1))
    bench_json["serving"]["served_chained_launches_per_forward"] = \
        sfwd["total"]

    # masked-chained correctness + dead-block skip, gated by ci.sh:
    # (a) a CHAINED reduced-googlenet plan served ragged must bit-match
    # the dense forward on the valid images; (b) at the kernel layer a
    # rows/image == bm fixture must skip exactly 1 - n/bucket of the
    # chained grid's steps (the no-op guard executes nothing for dead
    # M-blocks — the serving win raggedness buys the chained launch)
    rcfg = get_reduced("googlenet")
    rplan, _ = CNN.plan_cnn(rcfg, batch=4, chain_modules=True)
    rparams = CNN.init_params(rcfg, jax.random.PRNGKey(0))
    rimgs = jax.random.normal(jax.random.PRNGKey(2), (4,) + rcfg.img)
    rdense = CNN.forward_plan(rparams, rcfg, rimgs, rplan)
    rragged = CNN.forward_plan(rparams, rcfg, rimgs, rplan,
                               valid_images=2)
    bench_json["serving"]["chained_masked_ok"] = bool(
        any(g.mode == "grouped_chained" for g in rplan.groups)
        and jnp.array_equal(rragged[:2], rdense[:2]))
    bench_json["serving"]["dead_block_skip"] = _dead_block_skip()

    # MoE expert-dispatch column (runs in smoke too — ci.sh gates it):
    # grouped ragged engine vs capacity-padded einsum on a
    # serving-representative layer; modeled times come back through the
    # CACHED lower_moe plan so cached_moe_plan is exercised end-to-end
    from benchmarks.moe_bench import moe_dispatch_bench
    moe_rows, moe_col = moe_dispatch_bench(reps=3 if smoke else 5)
    _emit([dict(r) for r in moe_rows])
    bench_json["moe"] = moe_col

    if not smoke:
        _emit(stacked_branch_gemm_bench())
        _emit(fused_complementary_bench())
        pm_rows = modeled_vs_executed_table()
        _emit([dict(r) for r in pm_rows])
        bench_json["plan_makespan"] = pm_rows

    out = os.path.join(REPO, "BENCH_plan.smoke.json" if smoke
                       else "BENCH_plan.json")
    with open(out, "w") as f:
        json.dump(bench_json, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {os.path.relpath(out, REPO)}", flush=True)

    # roofline summary (from results/roofline.json if the dry-run ran)
    rl = os.path.join(os.path.dirname(__file__), "..", "results",
                      "roofline.json")
    if os.path.exists(rl):
        rows = json.load(open(rl))
        for r in rows:
            t = r.get("roofline")
            if not t:
                continue
            print(f"roofline,,arch={r['arch']};shape={r['shape']};"
                  f"dominant={t['dominant']};compute_s={t['compute_s']:.4f};"
                  f"memory_s={t['memory_s']:.4f};"
                  f"coll_s={t['collective_s']:.4f};"
                  f"useful={t['usefulness']:.3f};"
                  f"roofline_frac={t['roofline_fraction']:.3f}", flush=True)


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
