"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  t1        — Table-1 analogue: per-algorithm resource profiles of two
              independent inception convolutions.
  t2        — Table-2 analogue: workspace memory vs runtime per conv
              algorithm (C4 non-correlation).
  gemm      — the GEMM algorithm zoo (LM-scale analogue).
  makespan  — serial vs concurrency-aware scheduling on GoogleNet (the
              paper's proposal, modeled TPU makespan) + the 27-cases count.
  stacked   — intra-chip stacked branch GEMM vs per-branch GEMMs.
  plan_makespan — modeled vs executed makespan per execution mode for the
              lowered plan (core/plan.py), serial vs planned — the
              cost-model validation table.
  roofline  — summary of the dry-run roofline table (if generated).

Wall times are XLA-CPU (this host); modeled columns are TPU-v5e analytic.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def _emit(rows):
    for r in rows:
        name = r.pop("table")
        us = r.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{us},{derived}", flush=True)


def main() -> None:
    from benchmarks.paper_tables import (matmul_algorithm_table,
                                         table1_resource_profiles,
                                         table2_workspace_vs_time)
    from benchmarks.branch_parallel_bench import (
        fused_complementary_bench, makespan_table, modeled_vs_executed_table,
        stacked_branch_gemm_bench)

    print("name,us_per_call,derived")
    _emit(table1_resource_profiles())
    _emit(table2_workspace_vs_time())
    _emit(matmul_algorithm_table())
    _emit(makespan_table())
    _emit(stacked_branch_gemm_bench())
    _emit(fused_complementary_bench())
    _emit(modeled_vs_executed_table())

    # roofline summary (from results/roofline.json if the dry-run ran)
    rl = os.path.join(os.path.dirname(__file__), "..", "results",
                      "roofline.json")
    if os.path.exists(rl):
        rows = json.load(open(rl))
        for r in rows:
            t = r.get("roofline")
            if not t:
                continue
            print(f"roofline,,arch={r['arch']};shape={r['shape']};"
                  f"dominant={t['dominant']};compute_s={t['compute_s']:.4f};"
                  f"memory_s={t['memory_s']:.4f};"
                  f"coll_s={t['collective_s']:.4f};"
                  f"useful={t['usefulness']:.3f};"
                  f"roofline_frac={t['roofline_fraction']:.3f}", flush=True)


if __name__ == "__main__":
    main()
