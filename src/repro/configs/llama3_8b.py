"""llama3-8b [dense] — 32L d4096 32H (GQA kv=8) d_ff=14336 vocab=128256.
[arXiv:2407.21783; unverified]"""
import dataclasses

from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b", family="dense",
    d_model=4096, n_layers=32, vocab=128256,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336,
    pattern=(BlockSpec(mixer="attn", mlp="dense"),),
    rope_theta=500000.0, activation="silu", tie_embeddings=False,
    notes="linear topology: selection-only (DESIGN.md §Arch-applicability)",
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="llama3-8b-reduced", d_model=128, n_layers=4, vocab=512,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256)
