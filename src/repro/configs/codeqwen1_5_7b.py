"""codeqwen1.5-7b [dense] — 32L d4096 32H (MHA kv=32) d_ff=13440 vocab=92416.
[hf:Qwen/CodeQwen1.5-7B; hf]"""
import dataclasses

from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    d_model=4096, n_layers=32, vocab=92416,
    n_heads=32, n_kv_heads=32, head_dim=128, d_ff=13440,
    pattern=(BlockSpec(mixer="attn", mlp="dense"),),
    rope_theta=1000000.0, qkv_bias=True, activation="silu",
    tie_embeddings=True,
    notes="qwen1.5 arch (qkv bias); linear topology: selection-only",
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="codeqwen1.5-7b-reduced", d_model=128, n_layers=4,
        vocab=512, n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256)
