"""GoogleNet (Inception-v1) — the paper's native subject (extra arch).

Full ImageNet-scale config (Szegedy et al. 2015) + a CIFAR-scale
``reduced()`` used by the runnable training example and smoke tests.
"""
import dataclasses

from repro.models.cnn import CNNConfig, InceptionSpec

CONFIG = CNNConfig(
    name="googlenet", img=(224, 224, 3),
    stem=((7, 64, 2), (1, 64, 1), (3, 192, 1)),
    modules=(
        InceptionSpec(64, 96, 128, 16, 32, 32),      # 3a
        InceptionSpec(128, 128, 192, 32, 96, 64),    # 3b
        InceptionSpec(192, 96, 208, 16, 48, 64),     # 4a
        InceptionSpec(160, 112, 224, 24, 64, 64),    # 4b
        InceptionSpec(128, 128, 256, 24, 64, 64),    # 4c
        InceptionSpec(112, 144, 288, 32, 64, 64),    # 4d
        InceptionSpec(256, 160, 320, 32, 128, 128),  # 4e
        InceptionSpec(256, 160, 320, 32, 128, 128),  # 5a
        InceptionSpec(384, 192, 384, 48, 128, 128),  # 5b
    ),
    pool_between=(0, 2, 7),
    num_classes=1000,
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="googlenet-reduced", img=(32, 32, 3),
        stem=((3, 32, 1),),
        modules=(InceptionSpec(16, 24, 32, 4, 8, 8),
                 InceptionSpec(32, 32, 48, 8, 24, 16)),
        pool_between=(1,),
        num_classes=10)
