"""jamba-1.5-large-398b [hybrid] — 72L d8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba:attention 1:7 interleave, MoE every 2
layers.  [arXiv:2403.19887; hf]

TPU adaptation note (DESIGN.md §8): Jamba's Mamba-1 layers are implemented
as Mamba-2 SSD (chunked TPU kernel), d_inner = 2*d, 256 heads x 64,
state 128, 8 groups — FLOP-comparable, kernel-friendly.
"""
import dataclasses

from repro.configs.base import BlockSpec, ModelConfig, MoESpec, SSMSpec

_P = []
for i in range(8):
    mixer = "attn" if i == 4 else "mamba"      # 1 attn : 7 mamba per period
    mlp = "moe" if i % 2 == 1 else "dense"     # MoE every 2 layers
    _P.append(BlockSpec(mixer=mixer, mlp=mlp))

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    d_model=8192, n_layers=72, vocab=65536,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=24576,
    pattern=tuple(_P),
    moe=MoESpec(n_experts=16, top_k=2, d_expert=24576),
    ssm=SSMSpec(d_inner=16384, n_heads=256, head_dim=64, d_state=128,
                n_groups=8),
    rope_theta=None,     # Jamba uses no positional embeddings (Mamba provides)
    activation="silu", tie_embeddings=True,
    sub_quadratic=True,  # hybrid: runs long_500k
    notes=("most-representative arch: MoE experts = branches (EP, 16e | "
           "16-way), hybrid mamba/attn fork-join at the graph level"),
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="jamba-reduced", d_model=128, n_layers=8, vocab=512,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256,
        moe=MoESpec(n_experts=4, top_k=2, d_expert=256, capacity_factor=4.0),
        ssm=SSMSpec(d_inner=256, n_heads=8, head_dim=32, d_state=32,
                    n_groups=2, chunk=32))
