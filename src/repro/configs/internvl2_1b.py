"""internvl2-1b [vlm] — 24L d896 14H (GQA kv=2) d_ff=4864 vocab=151655.
InternViT frontend + Qwen2-0.5B backbone [arXiv:2404.16821; hf]

Frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (B, 256, d) prepended to the token stream.
"""
import dataclasses

from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    d_model=896, n_layers=24, vocab=151655,
    n_heads=14, n_kv_heads=2, head_dim=64, d_ff=4864,
    pattern=(BlockSpec(mixer="attn", mlp="dense"),),
    rope_theta=1000000.0, qkv_bias=True, activation="silu",
    tie_embeddings=True,
    frontend="patch", frontend_len=256,
    notes=("backbone linear: selection-only; 14 heads !| 16-way axis -> "
           "GSPMD pads head shards (DESIGN.md §Arch-applicability)"),
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="internvl2-reduced", d_model=128, n_layers=4, vocab=512,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, frontend_len=16)
