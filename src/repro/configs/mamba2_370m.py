"""mamba2-370m [ssm] — 48L d1024 attention-free, ssm_state=128 vocab=50280.
SSD (state-space duality) [arXiv:2405.21060; unverified]"""
import dataclasses

from repro.configs.base import BlockSpec, ModelConfig, SSMSpec

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    d_model=1024, n_layers=48, vocab=50280,
    n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0,
    pattern=(BlockSpec(mixer="mamba", mlp="none"),),
    ssm=SSMSpec(d_inner=2048, n_heads=32, head_dim=64, d_state=128,
                n_groups=1),
    rope_theta=None, activation="silu", tie_embeddings=True,
    sub_quadratic=True,   # SSM: runs long_500k
    notes=("attention-free: branch-parallelism inapplicable to topology "
           "(linear chain); algorithm selection applies to the SSD mixer "
           "(chunked vs quadratic) — DESIGN.md §Arch-applicability"),
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="mamba2-reduced", d_model=128, n_layers=4, vocab=512,
        ssm=SSMSpec(d_inner=256, n_heads=8, head_dim=32, d_state=32,
                    n_groups=1, chunk=32))
