"""gemma2-27b [dense] — 46L d4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
Local+global alternating attention, logit softcaps [arXiv:2408.00118; hf]"""
import dataclasses

from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    d_model=4608, n_layers=46, vocab=256000,
    n_heads=32, n_kv_heads=16, head_dim=128, d_ff=36864,
    # alternating sliding-window(4096) / global layers
    pattern=(BlockSpec(mixer="attn", mlp="dense", window=4096),
             BlockSpec(mixer="attn", mlp="dense", window=None)),
    rope_theta=10000.0, activation="gelu",
    attn_softcap=50.0, final_softcap=30.0,
    post_norm=True, tie_embeddings=True, embed_scale=True,
    query_scale=(4608 // 32) ** -0.5,   # query_pre_attn_scalar = d/nh
    notes=("local/global alternate sequentially (not parallel branches): "
           "selection-only. long_500k skipped: global layers' full-attention "
           "KV at 512k exceeds per-chip HBM (DESIGN.md)."),
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="gemma2-27b-reduced", d_model=128, n_layers=4, vocab=512,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=384,
        pattern=(BlockSpec(mixer="attn", mlp="dense", window=64),
                 BlockSpec(mixer="attn", mlp="dense", window=None)),
        query_scale=32 ** -0.5)
