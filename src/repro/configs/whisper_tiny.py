"""whisper-tiny [audio] — enc-dec, 4L d384 6H (MHA kv=6) d_ff=1536
vocab=51865, conv frontend STUB.  [arXiv:2212.04356; unverified]

Assignment semantics: shapes apply to the DECODER token stream (decode_* =
one token against a seq_len KV cache); the encoder consumes a fixed stub
context of 1500 precomputed frame embeddings.  Real Whisper caps target
length at 448 — the assigned shapes are applied literally (DESIGN.md §5).
"""
import dataclasses

from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    d_model=384, n_layers=4, vocab=51865,
    n_heads=6, n_kv_heads=6, head_dim=64, d_ff=1536,
    pattern=(BlockSpec(mixer="attn", mlp="dense", cross=True),),
    rope_theta=None,            # whisper uses learned/sinusoidal abs pos
    activation="gelu", norm="ln", tie_embeddings=True,
    enc_dec=True, n_enc_layers=4, enc_context_len=1500,
    frontend="frame",
    notes=("enc/dec self+cross attention per decoder layer form a branch "
           "pair given inputs; conv frontend stubbed per assignment"),
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="whisper-reduced", d_model=128, n_layers=2, vocab=512,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
        n_enc_layers=2, enc_context_len=64)
