"""qwen2-moe-a2.7b [moe] — 24L d2048 16H (MHA kv=16) d_ff(expert)=1408
vocab=151936, MoE 60e top-4 + 4-expert-wide shared expert (5632).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
import dataclasses

from repro.configs.base import BlockSpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    d_model=2048, n_layers=24, vocab=151936,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408,
    pattern=(BlockSpec(mixer="attn", mlp="moe"),),
    moe=MoESpec(n_experts=60, top_k=4, d_expert=1408, shared_f=5632),
    rope_theta=1000000.0, qkv_bias=True, activation="silu",
    tie_embeddings=True,
    notes=("shared-vs-routed experts are a fork/join; 60 % 16 != 0 -> "
           "TP inside experts instead of EP (DESIGN.md §Arch-applicability)"),
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="qwen2-moe-reduced", d_model=128, n_layers=4, vocab=512,
        n_heads=4, n_kv_heads=4, head_dim=32, d_ff=64,
        moe=MoESpec(n_experts=8, top_k=2, d_expert=64, shared_f=128,
                    capacity_factor=4.0))
