"""Architecture registry: ``get_config(arch)`` / ``--arch <id>``.

Ten assigned architectures (exact public configs) + the paper's native
GoogleNet CNN.  Each <id>.py defines ``CONFIG`` and ``reduced()`` (the
smoke-test config of the same family).
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    BlockSpec, ModelConfig, MoESpec, SSMSpec, ShapeConfig, SHAPES, TrainConfig,
)

ARCHS = (
    "jamba_1_5_large_398b",
    "granite_moe_1b_a400m",
    "qwen2_moe_a2_7b",
    "internvl2_1b",
    "whisper_tiny",
    "codeqwen1_5_7b",
    "minitron_8b",
    "llama3_8b",
    "gemma2_27b",
    "mamba2_370m",
    "googlenet",          # paper-native CNN (extra)
)

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch)
    return importlib.import_module(f"repro.configs.{arch}").CONFIG


def get_reduced(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch)
    return importlib.import_module(f"repro.configs.{arch}").reduced()
