"""minitron-8b [dense] — 32L d4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
Pruned nemotron [arXiv:2407.14679; hf]"""
import dataclasses

from repro.configs.base import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    d_model=4096, n_layers=32, vocab=256000,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=16384,
    pattern=(BlockSpec(mixer="attn", mlp="dense"),),
    rope_theta=10000.0, activation="silu", tie_embeddings=False,
    notes="linear topology: selection-only",
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="minitron-8b-reduced", d_model=128, n_layers=4, vocab=512,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=320)
