"""granite-moe-1b-a400m [moe] — 24L d1024 16H (GQA kv=8) d_ff(expert)=512
vocab=49155, MoE 32e top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
import dataclasses

from repro.configs.base import BlockSpec, ModelConfig, MoESpec

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    d_model=1024, n_layers=24, vocab=49155,
    n_heads=16, n_kv_heads=8, head_dim=64, d_ff=512,
    pattern=(BlockSpec(mixer="attn", mlp="moe"),),
    moe=MoESpec(n_experts=32, top_k=8, d_expert=512),
    rope_theta=10000.0, activation="silu", tie_embeddings=True,
    notes="experts = branches: full branch-parallel EP (32e | 16-way axis)",
)


def reduced():
    return dataclasses.replace(
        CONFIG, name="granite-moe-reduced", d_model=128, n_layers=4, vocab=512,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=64,
        moe=MoESpec(n_experts=8, top_k=2, d_expert=64, capacity_factor=4.0))
