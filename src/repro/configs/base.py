"""Config dataclasses: model architecture, input-shape cells, training."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int
    shared_f: int = 0            # shared-expert ffn width (0 = none)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_inner: int
    n_heads: int
    head_dim: int
    d_state: int
    n_groups: int
    conv_width: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One position of the repeating layer pattern."""
    mixer: str = "attn"          # "attn" | "mamba"
    mlp: str = "dense"           # "dense" | "moe" | "none"
    window: Optional[int] = None  # sliding-window attention
    cross: bool = False          # add cross-attention (enc-dec decoder)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio | cnn
    d_model: int
    n_layers: int
    vocab: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    pattern: tuple[BlockSpec, ...] = (BlockSpec(),)
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    rope_theta: Optional[float] = 10000.0
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    qkv_bias: bool = False
    activation: str = "silu"
    norm: str = "rms"            # "rms" | "ln"
    post_norm: bool = False      # gemma2-style post-block norms
    tie_embeddings: bool = True
    embed_scale: bool = False    # gemma-style sqrt(d) embed multiplier
    query_scale: Optional[float] = None
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_context_len: int = 1500  # stub frontend frames
    # modality frontend stub: None | "patch" | "frame"
    frontend: Optional[str] = None
    frontend_len: int = 256      # prepended patch embeddings (vlm)
    # technique applicability / serving notes
    sub_quadratic: bool = False  # may run long_500k
    notes: str = ""

    @property
    def is_attention_free(self) -> bool:
        return all(b.mixer != "attn" for b in self.pattern)

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6*N*D)."""
        d, v = self.d_model, self.vocab
        n = v * d  # embed
        if not self.tie_embeddings:
            n += v * d
        per = {"attn": 0, "mamba": 0, "dense": 0, "moe": 0, "cross": 0}
        per["attn"] = d * self.n_heads * self.head_dim * 2 \
            + d * self.n_kv_heads * self.head_dim * 2
        gate = 1 if self.activation in ("silu", "gelu") else 0
        per["dense"] = (2 + gate) * d * self.d_ff
        if self.moe:
            per["moe"] = self.moe.n_experts * (2 + gate) * d * self.moe.d_expert \
                + d * self.moe.n_experts
            if self.moe.shared_f:
                per["moe"] += (2 + gate) * d * self.moe.shared_f
        if self.ssm:
            s = self.ssm
            d_xbc = s.d_inner + 2 * s.n_groups * s.d_state
            per["mamba"] = d * (s.d_inner + d_xbc + s.n_heads) \
                + s.conv_width * d_xbc + s.d_inner * d + 3 * s.n_heads
        per["cross"] = per["attn"]
        reps = self.n_layers // len(self.pattern)
        for b in self.pattern:
            n += reps * per[b.mixer]
            n += reps * per[b.mlp] if b.mlp != "none" else 0
            if b.cross:
                n += reps * per["cross"]
        if self.enc_dec:
            n += self.n_enc_layers * (per["attn"] + per["dense"])
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        gate = 1 if self.activation in ("silu", "gelu") else 0
        reps = self.n_layers // len(self.pattern)
        n_moe_layers = sum(1 for b in self.pattern if b.mlp == "moe") * reps
        per_expert = (2 + gate) * self.d_model * self.moe.d_expert
        inactive = n_moe_layers * (self.moe.n_experts - self.moe.top_k) \
            * per_expert
        return full - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str                    # train_4k / prefill_32k / decode_32k / long_500k
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    opt_state_dtype: str = "float32"   # "bfloat16" for the 398B config
    param_dtype: str = "float32"
    remat: bool = True
    fsdp: bool = True
    moe_aux_weight: float = 0.01
