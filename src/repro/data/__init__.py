from repro.data.pipeline import SyntheticLM, SyntheticImages, Pipeline  # noqa: F401
