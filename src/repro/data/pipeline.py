"""Deterministic synthetic data pipeline (host-sharded, double-buffered).

Production posture without external datasets: a seeded counter-based
generator yields identical global batches for a given (seed, step)
regardless of host count — each host materializes only its shard
(``host_slice``), so the pipeline scales to any process count and resuming
from a checkpoint replays the exact stream (iterator state = the step).

The synthetic LM stream is a order-k Markov-ish mixture (next token depends
on the previous token plus a per-sequence drift) — enough structure that a
model visibly learns (loss decreases), unlike uniform noise.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int, *, host_index: int = 0,
                 host_count: int = 1) -> dict:
        """Host-sharded global batch for ``step`` (deterministic)."""
        assert self.global_batch % host_count == 0
        per_host = self.global_batch // host_count
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_index]))
        v = self.vocab
        # structured stream: x[t+1] = (a * x[t] + drift) % V with noise
        a = 6364136223846793005 % v | 1
        x = np.empty((per_host, self.seq_len + 1), np.int64)
        x[:, 0] = rng.integers(0, v, per_host)
        drift = rng.integers(1, v, (per_host, 1))
        noise = rng.random((per_host, self.seq_len)) < 0.1
        rand = rng.integers(0, v, (per_host, self.seq_len))
        for t in range(self.seq_len):
            nxt = (a * x[:, t] + drift[:, 0]) % v
            x[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        tokens = x[:, :-1].astype(np.int32)
        labels = x[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}


@dataclasses.dataclass
class SyntheticImages:
    img: tuple[int, int, int]
    num_classes: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int, *, host_index: int = 0,
                 host_count: int = 1) -> dict:
        per_host = self.global_batch // host_count
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_index]))
        labels = rng.integers(0, self.num_classes, per_host)
        h, w, c = self.img
        # class-dependent blobs so the CNN can actually learn
        base = rng.standard_normal((per_host, h, w, c)).astype(np.float32)
        yy, xx = np.mgrid[0:h, 0:w]
        for i in range(per_host):
            cy = (labels[i] * 7919) % h
            cx = (labels[i] * 104729) % w
            blob = np.exp(-(((yy - cy) % h) ** 2 + ((xx - cx) % w) ** 2)
                          / (0.02 * h * w))
            base[i] += 3.0 * blob[..., None]
        return {"images": base, "labels": labels.astype(np.int32)}


class Pipeline:
    """Step-indexed iterator with simple lookahead prefetch and exact
    resume (state == step)."""

    def __init__(self, source, start_step: int = 0, host_index: int = 0,
                 host_count: int = 1):
        self.source = source
        self.step = start_step
        self.host_index = host_index
        self.host_count = host_count
        self._next = None

    def __iter__(self):
        return self

    def __next__(self):
        if self._next is not None:
            batch, self._next = self._next, None
        else:
            batch = self.source.batch_at(self.step,
                                         host_index=self.host_index,
                                         host_count=self.host_count)
        self.step += 1
        # cheap lookahead (numpy gen overlaps with device step dispatch)
        self._next = self.source.batch_at(self.step,
                                          host_index=self.host_index,
                                          host_count=self.host_count)
        return batch

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict):
        self.step = int(state["step"])
        self._next = None
