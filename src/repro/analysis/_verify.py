"""planlint ``verify_plan`` — re-derive a lowered plan's launch geometry
and prove every static invariant without executing a kernel.

For each co-executed ExecGroup the verifier reconstructs the launch
geometry the executor would hand the kernel wrappers — (M, K, N) per
branch via ``cost_model.gemm_shape``, blocks via ``grouped_block_shape``,
pool tap counts via ``analysis.budgets.tap_count``, the chained phase
spec via the same rules ``_chain_static`` applies — then builds the REAL
offset table with the kernel's own ``_plan_tiles*`` planner and checks
it against the independent schema/replay implementations in
``analysis.tables`` plus the happens-before analysis in
``analysis.hazards``, and re-prices the group's C2 footprint against the
budgets the plan was lowered under (``plan.context["budgets"]``).

Two deliberate normalizations (the invariants checked are unaffected):

  * a chained branch whose lhs comes from OUTSIDE the launch (a previous
    launch's panel composite, a materialized env value) is specced as a
    packed-x source — the panel-descriptor block numbering needs the
    executor's env, which a static pass does not have, and the wave /
    ring schedule is invariant to the lhs source tag;
  * ragged-M serving launches are verified at the full bucket M — the
    offset table is identical for every request mix in the bucket, and
    the chained masked obligations (mrow slot addressing, in-image tap
    identity) are checked for ALL image-aligned cutoffs at once by
    ``hazards.check_chained_masked``.

Geometry checks are memoized: plans re-lower the same shapes constantly
(every pytest case, every serve bucket) and the tables are pure
functions of the geometry key.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp

from repro.analysis import Finding
from repro.analysis import budgets as _budgets
from repro.analysis import hazards, tables
from repro.core import cost_model as cm

BLK = 128


def _gm():
    # importlib, not ``from repro.kernels import grouped_matmul``: the
    # package re-exports a FUNCTION of that name which shadows the
    # submodule attribute once ``__init__`` finishes
    import importlib
    return importlib.import_module("repro.kernels.grouped_matmul")


#: modes whose groups carry a scalar-prefetch offset table to verify
TABLE_MODES = ("grouped", "grouped_pooled", "grouped_concat",
               "grouped_chained", "grouped_experts")


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def _strip(n: str) -> str:
    return n[5:] if n.startswith("grad:") else n


def _dtype_of(op):
    return jnp.bfloat16 if op.dtype_bytes == 2 else jnp.float32


def _findings(raw, fam, where):
    return [Finding(kind, fam, where, msg) for kind, msg in raw]


# ---------------------------------------------------------------------------
# memoized geometry checks (pure functions of the geometry key)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4096)
def _checked_plain(mb, kbs, nbs, concat):
    gm = _gm()
    tab = (gm._plan_tiles_concat(mb, kbs, nbs) if concat
           else gm._plan_tiles(mb, kbs, nbs))
    return tuple(tables.check_plain(tab, mb, kbs, nbs, concat=concat))


@functools.lru_cache(maxsize=4096)
def _checked_pooled(mb, kbs, nbs, taps, concat):
    gm = _gm()
    tab = gm._plan_tiles_pooled(mb, kbs, nbs, taps, concat)
    return tuple(tables.check_pooled(tab, mb, kbs, nbs, taps, concat))


@functools.lru_cache(maxsize=4096)
def _checked_bwd(mb, kbs, nbs):
    gm = _gm()
    tab = gm._plan_tiles_bwd(mb, kbs, nbs)
    return tuple(tables.check_bwd(tab, mb, kbs, nbs))


@functools.lru_cache(maxsize=1024)
def _checked_chained(mb, spec, h, w, nring):
    gm = _gm()
    tab = gm._plan_tiles_chained(mb, spec)
    raw = list(tables.check_chained(tab, mb, spec))
    raw += hazards.check_chained_schedule(tab, mb, len(spec), h=h, w=w,
                                          bm=BLK, nring=nring)
    raw += hazards.check_chained_masked(tab, mb, len(spec), h=h, w=w)
    return tuple(raw)


@functools.lru_cache(maxsize=256)
def _checked_experts(mbs, db, fb, gated):
    gm = _gm()
    raw = list(tables.check_experts(
        gm._plan_tiles_experts(mbs, db, fb, gated), mbs, db, fb, gated))
    raw += tables.check_experts_bwd(
        gm._plan_tiles_experts_bwd(mbs, db, fb, gated), mbs, db, fb, gated)
    return tuple(raw)


# ---------------------------------------------------------------------------
# per-group verification
# ---------------------------------------------------------------------------

def _branch_geometry(graph, names, where):
    """Shared-M (M, [(K, N)...], dtype) of a branch group, or
    (None, findings) when the geometry is inconsistent."""
    shapes = []
    for n in names:
        s = cm.gemm_shape(graph.ops[_strip(n)])
        if s is None:
            return None, [Finding("schema", "group", where,
                                  f"branch {n} has no GEMM view — it "
                                  "cannot ride a grouped launch")]
        shapes.append(s)
    ms = {s[0] for s in shapes}
    if len(ms) != 1:
        return None, [Finding("schema", "group", where,
                              f"branches disagree on shared M: {sorted(ms)}"
                              " — a grouped launch needs one row space")]
    dt = _dtype_of(graph.ops[_strip(names[0])])
    return (ms.pop(), [(k, n) for _, k, n in shapes], dt), []


def _verify_grouped(graph, g, where, direction):
    grouped_block_shape = _gm().grouped_block_shape
    names = [n for n in g.ops if n != g.join] if g.join else list(g.ops)
    geom, out = _branch_geometry(graph, names, where)
    if geom is None:
        return out
    m, kns, dt = geom
    pools = {b: p for b, p in g.pools}
    taps = tuple(_budgets.tap_count(graph.ops[_strip(pools[n])])
                 if n in pools else 1 for n in names)
    if direction == "bwd":
        # the combined masked-dx + dw/db launch: ONE uniform block size
        bl = grouped_block_shape(m, kns, dt)
        b = bl.bm if bl.bm == bl.bn == bl.bk else BLK
        mb = _ceil(m, b)
        kbs = tuple(_ceil(k, b) for k, _ in kns)
        nbs = tuple(_ceil(n, b) for _, n in kns)
        raw = _checked_bwd(mb, kbs, nbs)
        return out + _findings(raw, "grouped-bwd", where)
    bl = grouped_block_shape(m, kns, dt)
    mb = _ceil(m, bl.bm)
    kbs = tuple(_ceil(k, bl.bk) for k, _ in kns)
    nbs = tuple(_ceil(n, bl.bn) for _, n in kns)
    concat = bool(g.join)
    if any(t > 1 for t in taps):
        raw = _checked_pooled(mb, kbs, nbs, taps, concat)
        fam = "pooled-concat" if concat else "pooled"
    elif concat:
        raw = _checked_plain(mb, kbs, nbs, True)
        fam = "concat"
    else:
        raw = _checked_plain(mb, kbs, nbs, False)
        fam = "plain"
    out += _findings(raw, fam, where)
    if concat:
        # write-write tiling of the padded join panel (col-block space),
        # plus true-width coverage of the join against its declared size
        segs = []
        cb = 0
        for n, nb in zip(names, nbs):
            segs.append((cb, nb, n))
            cb += nb
        out += _findings(hazards.check_concat_segments(segs, cb),
                         "concat-panel", where)
        join_op = graph.ops[_strip(g.join)]
        if join_op.kind == "pointwise" and "elements" in join_op.p:
            total = join_op.p["elements"] // m
            in_launch = sum(n for _, n in kns)
            passthrough = sum(
                cm.gemm_shape(graph.ops[p])[2]
                for p in sorted(graph.pred[_strip(g.join)])
                if p not in {_strip(n) for n in names}
                and cm.gemm_shape(graph.ops[p]) is not None)
            if in_launch + passthrough != total:
                out.append(Finding(
                    "hazard", "concat-panel", where,
                    f"join {g.join} declares {total} columns but its "
                    f"writers cover {in_launch} in-launch + "
                    f"{passthrough} passthrough"))
    return out


def _chained_spec(graph, g, where):
    """Rebuild the hashable chained-launch spec ``_chain_static`` would
    produce, from the plan + graph alone.  Returns (mb, spec, oh, ow,
    nring, findings) — spec None when the chain is malformed."""
    fam = "chained"
    chain = [[_strip(n) for n in ph] for ph in g.chain]
    opset = {n for ph in chain for n in ph}
    pools = {_strip(b): _strip(p) for b, p in g.pools}
    out = []

    def dep_of(n):
        preds = sorted(graph.pred[n])
        if n in pools:
            return pools[n]
        if len(preds) != 1:
            out.append(Finding("schema", fam, where,
                               f"chained op {n} has {len(preds)} preds — "
                               "a chain branch streams exactly one lhs"))
            return None
        return preds[0]

    consumed = []
    for ph in chain:
        for n in ph:
            d = dep_of(n)
            if d is not None and d in opset and d not in consumed:
                consumed.append(d)
    ring_cols: dict[str, tuple] = {}
    nxt = 0
    for d in consumed:
        nbb = _ceil(cm.gemm_shape(graph.ops[d])[2], BLK)
        ring_cols[d] = tuple(range(nxt, nxt + nbb))
        nxt += nbb
    nring = max(nxt, 1)

    first = graph.ops[chain[0][0]]
    stride0 = first.p.get("stride", 1)
    oh = _ceil(first.p["h"], stride0)
    ow = _ceil(first.p["w"], stride0)
    ms = {cm.gemm_shape(graph.ops[n])[0] for ph in chain for n in ph}
    if len(ms) != 1:
        out.append(Finding("schema", fam, where,
                           f"chained phases disagree on shared M: "
                           f"{sorted(ms)} — the wave schedule advances "
                           "all phases over one row space"))
        return None, None, oh, ow, nring, out
    mb = _ceil(ms.pop(), BLK)

    spec = []
    for ph in chain:
        pspec = []
        for n in ph:
            op = graph.ops[n]
            _, kk, nn = cm.gemm_shape(op)
            nbb = _ceil(nn, BLK)
            d = dep_of(n)
            if d in opset:
                kh, kw = op.p.get("kh", 1), op.p.get("kw", 1)
                if op.p.get("stride", 1) != 1:
                    out.append(Finding(
                        "schema", fam, where,
                        f"ring consumer {n} has stride "
                        f"{op.p['stride']} — the shifted-window ring "
                        "only streams stride-1 taps"))
                    return None, None, oh, ow, nring, out
                taps = []
                for dh in range(kh):
                    for dw in range(kw):
                        delta = (dh - kh // 2) * ow + (dw - kw // 2)
                        if abs(delta) > BLK:
                            out.append(Finding(
                                "bounds", fam, where,
                                f"ring consumer {n} halo {delta} exceeds "
                                f"bm={BLK} (W={ow}, k={kh}x{kw}) — "
                                "chain-ineligible geometry"))
                            return None, None, oh, ow, nring, out
                        taps.append((delta, dh - kh // 2, dw - kw // 2))
                src = ("ring", (tuple(taps), ring_cols[d]))
            else:
                src = ("x", _ceil(kk, BLK))
            pspec.append((src[0], src[1], nbb,
                          tuple(ring_cols.get(n, ()))))
        spec.append(tuple(pspec))
    return mb, tuple(spec), oh, ow, nring, out


def _verify_chained(graph, g, where, direction):
    if direction == "bwd":
        # reverse-phase mirror: ONE combined masked-dx + dw/db grouped
        # launch per phase — verify each phase's two-phase bwd table
        out = []
        for p, ph in enumerate(g.chain):
            sub = _verify_grouped(
                graph, type(g)("grouped", tuple(ph), g.algorithms, 0.0),
                f"{where}/phase{p}", "bwd")
            out += sub
        return out
    mb, spec, oh, ow, nring, out = _chained_spec(graph, g, where)
    if spec is None:
        return out
    return out + _findings(_checked_chained(mb, spec, oh, ow, nring),
                           "chained", where)


def _verify_experts(plan, where):
    moe_static_blocks = _gm().moe_static_blocks
    moe = plan.context.get("moe")
    if not moe:
        return [Finding("schema", "experts", where,
                        "grouped_experts group without plan.context"
                        "['moe'] — the static block grid is underivable")]
    mbs = moe_static_blocks(moe["n_slots"], moe["e"], moe["bm"])
    db, fb = _ceil(moe["d"], BLK), _ceil(moe["f"], BLK)
    raw = _checked_experts(mbs, db, fb, int(moe["gated"]))
    return _findings(raw, "experts", where)


def _verify_budget(graph, g, where, direction, budgets):
    if not budgets:
        return []
    hbm, vmem = budgets["hbm"], budgets["vmem"]
    if g.mode == "grouped_chained":
        if direction == "bwd":
            return []   # per-phase grouped launches, priced by the mirror
        chain = [[_strip(n) for n in ph] for ph in g.chain]
        opset = {n for ph in chain for n in ph}
        ring = frozenset(n for ph in chain for n in ph
                         if graph.pred[n] & opset)
        fp = _budgets.chained_footprint(graph, chain, ring, block=BLK)
    else:
        names = tuple(_strip(n) for n in g.ops)
        algs = {_strip(k): v for k, v in g.algorithms.items()}
        fp = _budgets.group_footprint(
            graph, names, algs, direction=direction,
            pools=tuple((_strip(b), _strip(p)) for b, p in g.pools),
            include_gemm_ws=True if (direction == "fwd" and g.pools)
            else None)
    if not fp.fits(hbm, vmem):
        return [Finding("budget", g.mode, where,
                        f"footprint (ws={fp.workspace_bytes:.3g}B, "
                        f"vmem={fp.vmem_bytes:.3g}B) exceeds the lowered "
                        f"budgets (hbm={hbm:.3g}B, vmem={vmem:.3g}B) — "
                        "this group should have been priced serial")]
    return []


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def verify_plan(plan, graph=None):
    """Statically verify a lowered plan (see ``analysis.verify_plan``).

    ``graph`` defaults to the plan's own ``context["graph"]`` (stashed by
    ``lower`` / ``backward_plan``); a backward plan falls back to its
    forward plan's context.  Returns a list of ``Finding``."""
    fwd_ctx = plan.context.get("forward")
    if graph is None:
        graph = plan.context.get("graph")
    if graph is None and fwd_ctx is not None:
        graph = fwd_ctx.context.get("graph")
    budgets = plan.context.get("budgets")
    if budgets is None and fwd_ctx is not None:
        budgets = fwd_ctx.context.get("budgets")
    direction = "bwd" if any(n.startswith("grad:")
                             for g in plan.groups for n in g.ops) else "fwd"
    out: list[Finding] = []
    for gi, g in enumerate(plan.groups):
        if g.mode not in TABLE_MODES:
            continue
        where = f"group[{gi}] {g.mode}({', '.join(g.ops[:3])}" \
                + (", ..." if len(g.ops) > 3 else "") + ")"
        if g.mode == "grouped_experts":
            out += _verify_experts(plan, where)
            continue
        if graph is None:
            out.append(Finding("schema", "plan", where,
                               "no op graph available (pass one, or "
                               "lower the plan with a graph context) — "
                               "table checks skipped"))
            continue
        if g.mode == "grouped_chained":
            out += _verify_chained(graph, g, where, direction)
        else:
            out += _verify_grouped(graph, g, where, direction)
        out += _verify_budget(graph, g, where, direction, budgets)
    return out
