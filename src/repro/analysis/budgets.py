"""planlint static footprints — ONE C2 budget computation per ExecGroup.

Before this module, the HBM-workspace + VMEM footprint a co-executed
group must fit under was computed in three near-duplicate places (and a
fourth for the backward mirror): ``plan.lower``'s feasibility gate,
``plan._absorb_pools``'s pooled-launch re-check and
``plan._chain_budgets_ok``'s ring-scratch check — implementations that
have already drifted once (PR 5's review notes).  All of them now call
the two functions here, and so does ``analysis.verify_plan`` when it
re-derives a lowered plan's footprint and checks it against the budgets
the plan was lowered under (``Plan.context["budgets"]``).

The accounting, in one place:

  base profiles    the chosen-algorithm ``cost_model.profile`` rows —
                   the serial fallback's footprint.
  GEMM workspace   a multi-op all-GEMM group executes the GEMM lowering,
                   whose im2col patch buffers can exceed the serial
                   fallback's workspace — the gate takes the max.
  pool riders      an absorbed pool packs up to ``POOL_TAP_LIMIT`` tap
                   tiles per pooled-lhs tile into the X stack
                   ((taps-1) * M * K extra workspace bytes per pooled
                   branch) and claims one pooled-lhs VMEM scratch
                   (128^2 blocks over the widest pooled K).
  backward         each direction launches sequentially, so the
                   backward footprint is gated on its own (summed
                   ``cost_model.backward_profiles``), never added to
                   the forward's.
  chained          ``cost_model.chained_profiles`` workspace (ring
                   consumers drop their patch buffer) plus the launch's
                   ring scratch: 3 wave slots per ring column, the
                   (3*bm, blk) shift window and the f32 accumulator.
"""
from __future__ import annotations

import dataclasses

from repro.core import cost_model as cm


@dataclasses.dataclass(frozen=True)
class Footprint:
    """A group's static C2 footprint: HBM workspace + VMEM residency."""
    workspace_bytes: float
    vmem_bytes: float

    def fits(self, hbm_budget: float, vmem_budget: float) -> bool:
        return (self.workspace_bytes <= hbm_budget
                and self.vmem_bytes <= vmem_budget)


def tap_count(pool_op) -> int:
    """Tap tiles per pooled-lhs tile: the product of the pool chain's
    squared windows, folded to 1 past ``POOL_TAP_LIMIT`` (the packer
    folds the taps at pack time instead of expanding the X stack)."""
    from repro.kernels.grouped_matmul import POOL_TAP_LIMIT
    t = 1
    for win, _s in pool_op.p["chain"]:
        t *= win * win
    return t if t <= POOL_TAP_LIMIT else 1


def group_footprint(graph, names, algorithms, *, pools=(),
                    direction: str = "fwd",
                    include_gemm_ws: bool | None = None) -> Footprint:
    """The static footprint of one ExecGroup.

    ``names``/``algorithms`` identify the ops and their chosen
    algorithms; ``pools`` is the group's ``(branch, pool)`` rider list;
    ``direction="bwd"`` prices the mirrored backward launch instead
    (summed ``backward_profiles``, algorithm falling back to
    ``best_algorithm`` when the group never chose one — matching
    ``backward_plan``).  ``include_gemm_ws`` forces the GEMM-lowering
    workspace max on (pooled re-checks price the grouped kernel even
    when a join op rides in the group); ``None`` applies it exactly when
    ``lower`` would — a multi-op group of GEMM-viewed ops.
    """
    ops = [graph.ops[n] for n in names]
    if direction == "bwd":
        bprofs = [p for op in ops
                  for p in cm.backward_profiles(
                      op, algorithms.get(op.name)
                      or cm.best_algorithm(op)[0])]
        return Footprint(sum(p.workspace_bytes for p in bprofs),
                         sum(p.vmem_bytes for p in bprofs))
    base = [cm.profile(op, algorithms[op.name]) for op in ops]
    ws = sum(p.workspace_bytes for p in base)
    vmem = sum(p.vmem_bytes for p in base)
    if include_gemm_ws is None:
        include_gemm_ws = (len(ops) > 1
                           and all(cm.gemm_shape(op) is not None
                                   for op in ops))
    if include_gemm_ws:
        ws = max(ws, sum(p.workspace_bytes for p in cm.gemm_profiles(ops)))
    extra_ws, extra_vmem = 0.0, 0.0
    for b, pn in pools:
        s = cm.gemm_shape(graph.ops[b])
        extra_ws += (tap_count(graph.ops[pn]) - 1) \
            * s[0] * s[1] * graph.ops[b].dtype_bytes
        extra_vmem = max(extra_vmem, -(-s[1] // 128) * 128 * 128 * 4)
    return Footprint(ws + extra_ws, vmem + extra_vmem)


def chained_footprint(graph, phases, ring, *, block: int = 128) -> Footprint:
    """The static footprint of one chained launch: chained-priced GEMM
    workspace (ring consumers' lhs never exists outside VMEM) plus the
    VMEM ring scratch — 3 wave slots per ring column over every consumed
    producer's K blocks, the (3*bm, blk) shift window and the f32
    accumulator."""
    ops = [graph.ops[n] for ph in phases for n in ph]
    profs = cm.chained_profiles(ops, ring)
    allnames = {m for ph in phases for m in ph}
    consumed: set[str] = set()
    for ph in phases:
        for n in ph:
            if n in ring:
                consumed |= graph.pred[n] & allnames
    nring = sum(-(-graph.ops[n].p["k"] // block) for n in consumed)
    eb = max(op.dtype_bytes for op in ops)
    ring_vmem = (3 * nring + 3) * block * block * eb + block * block * 4
    return Footprint(sum(p.workspace_bytes for p in profs),
                     sum(p.vmem_bytes for p in profs) + ring_vmem)
