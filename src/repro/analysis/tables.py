"""planlint table schemas — named rows and static checkers for every
scalar-prefetch offset-table family the grouped kernels consume.

This module is the single source of truth for the table layouts: the
kernels in ``kernels/grouped_matmul.py`` import the ``*_ROWS`` row
constants below (no more magic ``tab[6, t]`` literals), and the verifier
in ``analysis.verify_plan`` replays every ``_plan_tiles*`` output
against the declarative checkers here — so kernel and verifier can
never disagree about what a row means.

Eight table families, one checker each:

  plain / concat   ``_plan_tiles`` / ``_plan_tiles_concat`` — (7, T)
                   branch-GEMM steps; the concat variant walks M-blocks
                   outermost and writes into one fused N-concatenated
                   output.                      -> ``check_plain``
  pooled           ``_plan_tiles_pooled`` — (11, T): in-kernel pool-tap
                   accumulation steps interleaved with GEMM steps that
                   read the pooled scratch.     -> ``check_pooled``
  dW               ``_plan_tiles_dw`` — (7, T): X^T @ dY accumulation
                   over M-blocks.               -> ``check_dw``
  backward 2-phase ``_plan_tiles_bwd`` — (8, T): every dX tile then
                   every dW tile in ONE launch. -> ``check_bwd``
  chained          ``_plan_tiles_chained`` — (_CH_ROWS + 2*P + 1, T):
                   the lag-1 wave schedule plus the trailing per-phase
                   valid-row metadata row (``ch_mrow_row`` — the slot a
                   ragged-M launch's prefetched mrow vector is read at,
                   so masked waves skip dead M-blocks).
                                                -> ``check_chained``
  experts fwd      ``_plan_tiles_experts`` — (10, T) per-expert-ragged
                   H then Y phases.             -> ``check_experts``
  experts bwd      ``_plan_tiles_experts_bwd`` — (13, T) A/B/C/D
                   phases (dHpost, dWout, dX, dWh).
                                                -> ``check_experts_bwd``

Every checker is pure numpy (this module imports NOTHING from the rest
of the package — the kernels import it, so it must stay leaf-level) and
returns a list of ``(kind, message)`` findings with ``kind`` in
``{"schema", "bounds"}``; an empty list means the table satisfies its
schema.  The checkers re-derive each column from a few anchor rows
(N-offset, M-block index, phase) and compare every other row, then
assert run discipline (first/last flags open and close accumulator runs
of exactly the right length) and coverage (every output tile produced
exactly once) — so mutating ANY single entry fires a finding: anchors
break the derived expectations, derived rows break the comparison,
flags break the run structure.
"""
from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# row-name constants (imported by kernels/grouped_matmul.py)

#: plain + concat family — ``_plan_tiles`` / ``_plan_tiles_concat``
GM_XT, GM_WT, GM_BJ, GM_FIRST, GM_LAST, GM_OT, GM_MI = range(7)
GM_ROWS = 7

#: pooled family — ``_plan_tiles_pooled`` (rows 0-5 match plain; 6-10
#: add pool-step discipline and the ragged M-block row)
(GP_XT, GP_WT, GP_BJ, GP_FIRST, GP_LAST, GP_OT,
 GP_POOL, GP_PFIRST, GP_PS, GP_UPOOL, GP_MI) = range(11)
GP_ROWS = 11

#: dW family — ``_plan_tiles_dw``
DW_XT, DW_DYT, DW_FIRST, DW_LAST, DW_OT, DW_BJ, DW_DODB = range(7)
DW_ROWS = 7

#: combined-backward family — ``_plan_tiles_bwd`` (dx phase, dw phase)
BW_DYT, BW_ABT, BW_FIRST, BW_LAST, BW_OT, BW_DODB, BW_DW, BW_BJ = range(8)
BW_ROWS = 8

#: chained family — ``_plan_tiles_chained``; out-row helpers below
(CH_I, CH_XT, CH_WT, CH_BJ, CH_FIRST, CH_LAST, CH_PH, CH_SRC,
 CH_PCA, CH_PCB, CH_RC, CH_DELTA, CH_DH, CH_DW, CH_RWC) = range(15)
CH_ROWS = 15

#: experts forward family — ``_plan_tiles_experts``
(EX_BI, EX_XT, EX_WH, EX_WO, EX_PH, EX_FIRST, EX_LAST,
 EX_HJ, EX_OT, EX_RES) = range(10)
EX_ROWS = 10

#: experts backward family — ``_plan_tiles_experts_bwd``
(EB_BI, EB_DYT, EB_XT, EB_WHT, EB_WOT, EB_RES, EB_PH, EB_FIRST,
 EB_LAST, EB_PJ, EB_DXOT, EB_DWH, EB_DWO) = range(13)
EB_ROWS = 13


def ch_out_i_row(p: int) -> int:
    """Stability-backfilled output M-block row for chained phase ``p``."""
    return CH_ROWS + 2 * p


def ch_out_j_row(p: int) -> int:
    """Stability-backfilled output column row for chained phase ``p``."""
    return CH_ROWS + 2 * p + 1


def ch_mrow_row(nph: int) -> int:
    """Per-phase valid-row metadata row of a chained table (the LAST
    row, after all ``nph`` phases' output rows): step t holds
    ``phase * m_blocks + block`` — the slot of the prefetched per-phase
    mrow vector a ragged-M chained launch reads its liveness from.  A
    block with ``mrow == 0`` is entirely past ``m_valid`` and the wave
    becomes a no-op guard (GEMM/ring/pool steps never execute)."""
    return CH_ROWS + 2 * nph


# ---------------------------------------------------------------------------
# shared helpers

def _runs(first, last, out, fam):
    """Split the step sequence into accumulator runs delimited by the
    first/last flags.  Returns a list of inclusive ``(lo, hi)`` spans,
    or ``None`` (with a finding appended) if the flags do not form a
    well-nested sequence of runs."""
    runs, open_ = [], None
    for t in range(first.shape[0]):
        f, l = int(first[t]), int(last[t])
        if f and open_ is not None:
            out.append(("schema",
                        f"{fam}: first flag at step {t} inside an open run"))
            return None
        if not f and open_ is None:
            out.append(("schema",
                        f"{fam}: step {t} belongs to no accumulator run"))
            return None
        if f:
            open_ = t
        if l:
            runs.append((open_, t))
            open_ = None
    if open_ is not None:
        out.append(("schema", f"{fam}: run opened at step {open_} "
                              "never sees a last flag"))
        return None
    return runs


def _group_of(base, v):
    """Index of the group whose [base[g], base[g+1]) span contains ``v``,
    or -1 if out of range.  ``base`` is a cumulative-offset array with a
    trailing total."""
    if v < 0 or v >= base[-1]:
        return -1
    return int(np.searchsorted(base, v, side="right") - 1)


def _check_exp(out, fam, tab, t, exp):
    """Compare every (row, expected) pair against column ``t``."""
    ok = True
    for row, want in exp.items():
        got = int(tab[row, t])
        if got != int(want):
            out.append(("schema", f"{fam}: row {row} at step {t} is "
                                  f"{got}, want {want}"))
            ok = False
    return ok


# ---------------------------------------------------------------------------
# plain / concat

def check_plain(tab, m_blocks, kbs, nbs, *, concat=False):
    """Validate a ``_plan_tiles`` (or ``_plan_tiles_concat``) table
    against the branch geometry: ``kbs[g]``/``nbs[g]`` are the K/N block
    counts of group ``g``, all groups share ``m_blocks`` M-blocks."""
    out = []
    fam = "concat" if concat else "plain"
    tab = np.asarray(tab)
    kbs, nbs = tuple(int(k) for k in kbs), tuple(int(n) for n in nbs)
    G = len(kbs)
    if tab.ndim != 2 or tab.shape[0] != GM_ROWS:
        out.append(("schema", f"{fam}: expected ({GM_ROWS}, T) table, "
                              f"got shape {tab.shape}"))
        return out
    T = m_blocks * sum(k * n for k, n in zip(kbs, nbs))
    if tab.shape[1] != T:
        out.append(("schema", f"{fam}: expected {T} steps, "
                              f"got {tab.shape[1]}"))
        return out
    cb = np.concatenate([[0], np.cumsum(nbs)])
    xb = np.concatenate([[0], np.cumsum([m_blocks * k for k in kbs])])
    wb = np.concatenate([[0], np.cumsum([k * n for k, n in zip(kbs, nbs)])])
    ob = np.concatenate([[0], np.cumsum([m_blocks * n for n in nbs])])
    ncbt = int(cb[-1])
    for row, nm in ((GM_FIRST, "first"), (GM_LAST, "last")):
        if not np.isin(tab[row], (0, 1)).all():
            out.append(("schema", f"{fam}: {nm}-flag row is not 0/1"))
            return out
    runs = _runs(tab[GM_FIRST], tab[GM_LAST], out, fam)
    if runs is None:
        return out
    seen = set()
    for lo, hi in runs:
        bj = int(tab[GM_BJ, lo])
        g = _group_of(cb, bj)
        if g < 0:
            out.append(("bounds", f"{fam}: N-offset {bj} at step {lo} "
                                  f"outside [0, {ncbt})"))
            continue
        j = bj - int(cb[g])
        i = int(tab[GM_MI, lo])
        if not 0 <= i < m_blocks:
            out.append(("bounds", f"{fam}: M-block {i} at step {lo} "
                                  f"outside [0, {m_blocks})"))
            continue
        nkb, npb = kbs[g], nbs[g]
        if hi - lo + 1 != nkb:
            out.append(("schema", f"{fam}: run at step {lo} has "
                                  f"{hi - lo + 1} k-steps, want {nkb}"))
            continue
        ot = (i * ncbt + int(cb[g]) + j) if concat \
            else (int(ob[g]) + i * npb + j)
        for kk, t in enumerate(range(lo, hi + 1)):
            _check_exp(out, fam, tab, t, {
                GM_XT: int(xb[g]) + i * nkb + kk,
                GM_WT: int(wb[g]) + kk * npb + j,
                GM_BJ: bj,
                GM_FIRST: int(kk == 0),
                GM_LAST: int(kk == nkb - 1),
                GM_OT: ot,
                GM_MI: i,
            })
        key = (g, i, j)
        if key in seen:
            out.append(("schema", f"{fam}: output tile {key} produced "
                                  "by two runs"))
        seen.add(key)
    want = m_blocks * sum(nbs)
    if len(seen) != want:
        out.append(("schema", f"{fam}: {len(seen)} distinct output tiles "
                              f"produced, want {want}"))
    return out


# ---------------------------------------------------------------------------
# pooled

def check_pooled(tab, m_blocks, kbs, nbs, taps, concat):
    """Validate a ``_plan_tiles_pooled`` table: ``taps[g] > 1`` marks a
    pooled group whose X tiles arrive pre-expanded ``taps[g]``-fold and
    are reduced into the pool scratch (slot = k-block) before the GEMM
    steps read them back (``GP_UPOOL``).  A sequential walk checks the
    scratch-slot ownership discipline on top of the per-step schema."""
    out = []
    fam = "pooled"
    tab = np.asarray(tab)
    kbs = tuple(int(k) for k in kbs)
    nbs = tuple(int(n) for n in nbs)
    taps = tuple(int(p) for p in taps)
    G = len(kbs)
    if tab.ndim != 2 or tab.shape[0] != GP_ROWS:
        out.append(("schema", f"{fam}: expected ({GP_ROWS}, T) table, "
                              f"got shape {tab.shape}"))
        return out
    T = m_blocks * sum(k * (tp if tp > 1 else 0) + k * n
                       for k, n, tp in zip(kbs, nbs, taps))
    if tab.shape[1] != T:
        out.append(("schema", f"{fam}: expected {T} steps, "
                              f"got {tab.shape[1]}"))
        return out
    xb = np.concatenate(
        [[0], np.cumsum([m_blocks * k * tp for k, tp in zip(kbs, taps)])])
    wb = np.concatenate([[0], np.cumsum([k * n for k, n in zip(kbs, nbs)])])
    ob = np.concatenate([[0], np.cumsum([m_blocks * n for n in nbs])])
    cb = np.concatenate([[0], np.cumsum(nbs)])
    ncbt = int(cb[-1])
    nkb_pool = max([k for k, tp in zip(kbs, taps) if tp > 1], default=1)

    owner = {}            # pool-scratch slot -> [x-tile base, taps done]
    open_tile = None      # the single (bm, bn) accumulator's owner
    next_kk = {}          # (g, i, j) -> next expected k-step
    seen = set()
    for t in range(T):
        pool = int(tab[GP_POOL, t])
        if pool not in (0, 1):
            out.append(("schema", f"{fam}: pool flag at step {t} not 0/1"))
            continue
        if pool:
            xt = int(tab[GP_XT, t])
            g = _group_of(xb, xt)
            if g < 0:
                out.append(("bounds", f"{fam}: pool X tile {xt} at step "
                                      f"{t} outside [0, {int(xb[-1])})"))
                continue
            tp, nkb, npb = taps[g], kbs[g], nbs[g]
            if tp <= 1:
                out.append(("schema", f"{fam}: pool step {t} reads the "
                                      f"unpooled group {g}"))
                continue
            rel = xt - int(xb[g])
            tap, idx = rel % tp, rel // tp
            i, kk = idx // nkb, idx % nkb
            first_ot = (i * ncbt + int(cb[g])) if concat \
                else (int(ob[g]) + i * npb)
            _check_exp(out, fam, tab, t, {
                GP_WT: int(wb[g]), GP_BJ: int(cb[g]), GP_FIRST: 0,
                GP_LAST: 0, GP_OT: first_ot, GP_PFIRST: int(tap == 0),
                GP_PS: kk, GP_UPOOL: 0, GP_MI: i,
            })
            ps = int(tab[GP_PS, t])
            if not 0 <= ps < nkb_pool:
                out.append(("bounds", f"{fam}: pool slot {ps} at step {t} "
                                      f"outside [0, {nkb_pool})"))
                continue
            if tap == 0:
                owner[ps] = [xt, 1]
            else:
                st = owner.get(ps)
                if st is None or xt != st[0] + st[1]:
                    out.append(("schema", f"{fam}: pool tap at step {t} "
                                          f"out of sequence for slot {ps}"))
                else:
                    st[1] += 1
        else:
            bj = int(tab[GP_BJ, t])
            g = _group_of(cb, bj)
            if g < 0:
                out.append(("bounds", f"{fam}: N-offset {bj} at step {t} "
                                      f"outside [0, {ncbt})"))
                continue
            j = bj - int(cb[g])
            i = int(tab[GP_MI, t])
            if not 0 <= i < m_blocks:
                out.append(("bounds", f"{fam}: M-block {i} at step {t} "
                                      f"outside [0, {m_blocks})"))
                continue
            tp, nkb, npb = taps[g], kbs[g], nbs[g]
            xt = int(tab[GP_XT, t])
            rel = xt - int(xb[g])
            if not (0 <= rel < m_blocks * nkb * tp and rel % tp == 0
                    and rel // tp // nkb == i):
                out.append(("schema", f"{fam}: GEMM X tile {xt} at step "
                                      f"{t} inconsistent with (g={g}, "
                                      f"i={i})"))
                continue
            kk = rel // tp % nkb
            pooled = tp > 1
            ot = (i * ncbt + int(cb[g]) + j) if concat \
                else (int(ob[g]) + i * npb + j)
            _check_exp(out, fam, tab, t, {
                GP_WT: int(wb[g]) + kk * npb + j,
                GP_FIRST: int(kk == 0), GP_LAST: int(kk == nkb - 1),
                GP_OT: ot, GP_PFIRST: 0,
                GP_PS: kk if pooled else 0,
                GP_UPOOL: int(pooled),
            })
            # accumulator-run discipline (one open tile at a time)
            want_kk = next_kk.get((g, i, j), 0)
            if kk != want_kk:
                out.append(("schema", f"{fam}: k-step {kk} at step {t} "
                                      f"for tile ({g}, {i}, {j}), "
                                      f"want {want_kk}"))
            next_kk[(g, i, j)] = kk + 1
            if kk == 0 and open_tile is not None:
                out.append(("schema", f"{fam}: GEMM run for tile "
                                      f"({g}, {i}, {j}) opens at step {t} "
                                      f"while {open_tile} is still open"))
            elif kk > 0 and open_tile != (g, i, j):
                out.append(("schema", f"{fam}: mid-run GEMM step {t} for "
                                      f"tile ({g}, {i}, {j}) does not own "
                                      "the accumulator"))
            open_tile = None if kk == nkb - 1 else (g, i, j)
            if pooled:
                st = owner.get(kk)
                if st is None or st != [int(xb[g]) + (i * nkb + kk) * tp,
                                        tp]:
                    out.append(("schema", f"{fam}: GEMM step {t} reads "
                                          f"pool slot {kk} before its "
                                          f"{tp} taps completed"))
            if kk == nkb - 1:
                key = (g, i, j)
                if key in seen:
                    out.append(("schema", f"{fam}: output tile {key} "
                                          "produced by two runs"))
                seen.add(key)
    want = m_blocks * sum(nbs)
    if len(seen) != want:
        out.append(("schema", f"{fam}: {len(seen)} distinct output tiles "
                              f"produced, want {want}"))
    return out


# ---------------------------------------------------------------------------
# dW

def check_dw(tab, m_blocks, kbs, nbs):
    """Validate a ``_plan_tiles_dw`` table: per group, each ``dW`` tile
    ``(ki, j)`` accumulates ``X^T @ dY`` over all ``m_blocks`` M-blocks
    in one run; ``DW_DODB`` marks the ``ki == 0`` runs that also reduce
    the bias gradient."""
    out = []
    fam = "dw"
    tab = np.asarray(tab)
    kbs, nbs = tuple(int(k) for k in kbs), tuple(int(n) for n in nbs)
    if tab.ndim != 2 or tab.shape[0] != DW_ROWS:
        out.append(("schema", f"{fam}: expected ({DW_ROWS}, T) table, "
                              f"got shape {tab.shape}"))
        return out
    T = m_blocks * sum(k * n for k, n in zip(kbs, nbs))
    if tab.shape[1] != T:
        out.append(("schema", f"{fam}: expected {T} steps, "
                              f"got {tab.shape[1]}"))
        return out
    xb = np.concatenate([[0], np.cumsum([m_blocks * k for k in kbs])])
    dyb = np.concatenate([[0], np.cumsum([m_blocks * n for n in nbs])])
    wb = np.concatenate([[0], np.cumsum([k * n for k, n in zip(kbs, nbs)])])
    cb = np.concatenate([[0], np.cumsum(nbs)])
    for row, nm in ((DW_FIRST, "first"), (DW_LAST, "last")):
        if not np.isin(tab[row], (0, 1)).all():
            out.append(("schema", f"{fam}: {nm}-flag row is not 0/1"))
            return out
    runs = _runs(tab[DW_FIRST], tab[DW_LAST], out, fam)
    if runs is None:
        return out
    seen = set()
    for lo, hi in runs:
        bj = int(tab[DW_BJ, lo])
        g = _group_of(cb, bj)
        if g < 0:
            out.append(("bounds", f"{fam}: N-offset {bj} at step {lo} "
                                  f"outside [0, {int(cb[-1])})"))
            continue
        j = bj - int(cb[g])
        nkb, npb = kbs[g], nbs[g]
        ot = int(tab[DW_OT, lo])
        ki = (ot - int(wb[g]) - j) // npb if npb else 0
        if not (0 <= ki < nkb and ot == int(wb[g]) + ki * npb + j):
            out.append(("bounds", f"{fam}: dW tile {ot} at step {lo} "
                                  f"inconsistent with (g={g}, j={j})"))
            continue
        if hi - lo + 1 != m_blocks:
            out.append(("schema", f"{fam}: run at step {lo} has "
                                  f"{hi - lo + 1} M-steps, want "
                                  f"{m_blocks}"))
            continue
        for mi, t in enumerate(range(lo, hi + 1)):
            _check_exp(out, fam, tab, t, {
                DW_XT: int(xb[g]) + mi * nkb + ki,
                DW_DYT: int(dyb[g]) + mi * npb + j,
                DW_FIRST: int(mi == 0),
                DW_LAST: int(mi == m_blocks - 1),
                DW_OT: ot, DW_BJ: bj,
                DW_DODB: int(ki == 0),
            })
        key = (g, ki, j)
        if key in seen:
            out.append(("schema", f"{fam}: dW tile {key} produced by "
                                  "two runs"))
        seen.add(key)
    want = sum(k * n for k, n in zip(kbs, nbs))
    if len(seen) != want:
        out.append(("schema", f"{fam}: {len(seen)} distinct dW tiles "
                              f"produced, want {want}"))
    return out


# ---------------------------------------------------------------------------
# combined backward (dx phase + dw phase, one launch)

def check_bwd(tab, m_blocks, kbs, nbs):
    """Validate a ``_plan_tiles_bwd`` table (uniform block): phase 0
    produces every ``dX`` tile (accumulating over N-blocks against
    ``W^T``), phase 1 every ``dW`` tile (accumulating over M-blocks
    against ``X``); the A-operand buffer holds all ``W^T`` tiles then
    all ``X`` tiles, the output buffer all ``dX`` then all ``dW``."""
    out = []
    fam = "bwd"
    tab = np.asarray(tab)
    kbs, nbs = tuple(int(k) for k in kbs), tuple(int(n) for n in nbs)
    if tab.ndim != 2 or tab.shape[0] != BW_ROWS:
        out.append(("schema", f"{fam}: expected ({BW_ROWS}, T) table, "
                              f"got shape {tab.shape}"))
        return out
    T = sum(m_blocks * k * n + k * n * m_blocks
            for k, n in zip(kbs, nbs))
    if tab.shape[1] != T:
        out.append(("schema", f"{fam}: expected {T} steps, "
                              f"got {tab.shape[1]}"))
        return out
    dyb = np.concatenate([[0], np.cumsum([m_blocks * n for n in nbs])])
    wtb = np.concatenate([[0], np.cumsum([n * k for k, n in zip(kbs, nbs)])])
    dxb = np.concatenate([[0], np.cumsum([m_blocks * k for k in kbs])])
    total_wt, total_dx = int(wtb[-1]), int(dxb[-1])
    xb = dxb + total_wt          # X tiles follow all W^T tiles
    dwb = wtb + total_dx         # dW tiles follow all dX tiles
    cb = np.concatenate([[0], np.cumsum(nbs)])
    for row, nm in ((BW_FIRST, "first"), (BW_LAST, "last"),
                    (BW_DW, "phase"), (BW_DODB, "dodb")):
        if not np.isin(tab[row], (0, 1)).all():
            out.append(("schema", f"{fam}: {nm}-flag row is not 0/1"))
            return out
    if (np.diff(tab[BW_DW].astype(np.int64)) < 0).any():
        out.append(("schema", f"{fam}: dW phase precedes a dX step"))
    runs = _runs(tab[BW_FIRST], tab[BW_LAST], out, fam)
    if runs is None:
        return out
    seen_dx, seen_dw = set(), set()
    for lo, hi in runs:
        phase = int(tab[BW_DW, lo])
        ot = int(tab[BW_OT, lo])
        if phase == 0:
            g = _group_of(dxb, ot)
            if g < 0:
                out.append(("bounds", f"{fam}: dX tile {ot} at step {lo} "
                                      f"outside [0, {total_dx})"))
                continue
            nkb, npb = kbs[g], nbs[g]
            rel = ot - int(dxb[g])
            i, kk = rel // nkb, rel % nkb
            if hi - lo + 1 != npb:
                out.append(("schema", f"{fam}: dX run at step {lo} has "
                                      f"{hi - lo + 1} N-steps, want "
                                      f"{npb}"))
                continue
            for j, t in enumerate(range(lo, hi + 1)):
                _check_exp(out, fam, tab, t, {
                    BW_DYT: int(dyb[g]) + i * npb + j,
                    BW_ABT: int(wtb[g]) + j * nkb + kk,
                    BW_FIRST: int(j == 0),
                    BW_LAST: int(j == npb - 1),
                    BW_OT: ot, BW_DODB: 0, BW_DW: 0, BW_BJ: 0,
                })
            key = (g, i, kk)
            if key in seen_dx:
                out.append(("schema", f"{fam}: dX tile {key} produced "
                                      "by two runs"))
            seen_dx.add(key)
        else:
            g = _group_of(dwb, ot)
            if g < 0 or ot < total_dx:
                out.append(("bounds", f"{fam}: dW tile {ot} at step {lo} "
                                      f"outside [{total_dx}, "
                                      f"{total_dx + total_wt})"))
                continue
            nkb, npb = kbs[g], nbs[g]
            rel = ot - int(dwb[g])
            ki, j = rel // npb, rel % npb
            if ki >= nkb:
                out.append(("bounds", f"{fam}: dW tile {ot} at step {lo} "
                                      f"inconsistent with group {g}"))
                continue
            if hi - lo + 1 != m_blocks:
                out.append(("schema", f"{fam}: dW run at step {lo} has "
                                      f"{hi - lo + 1} M-steps, want "
                                      f"{m_blocks}"))
                continue
            for mi, t in enumerate(range(lo, hi + 1)):
                _check_exp(out, fam, tab, t, {
                    BW_DYT: int(dyb[g]) + mi * npb + j,
                    BW_ABT: int(xb[g]) + mi * nkb + ki,
                    BW_FIRST: int(mi == 0),
                    BW_LAST: int(mi == m_blocks - 1),
                    BW_OT: ot, BW_DODB: int(ki == 0), BW_DW: 1,
                    BW_BJ: int(cb[g]) + j,
                })
            key = (g, ki, j)
            if key in seen_dw:
                out.append(("schema", f"{fam}: dW tile {key} produced "
                                      "by two runs"))
            seen_dw.add(key)
    want_dx = m_blocks * sum(kbs)
    want_dw = sum(k * n for k, n in zip(kbs, nbs))
    if len(seen_dx) != want_dx:
        out.append(("schema", f"{fam}: {len(seen_dx)} distinct dX tiles "
                              f"produced, want {want_dx}"))
    if len(seen_dw) != want_dw:
        out.append(("schema", f"{fam}: {len(seen_dw)} distinct dW tiles "
                              f"produced, want {want_dw}"))
    return out


# ---------------------------------------------------------------------------
# replay-compare helper (chained + experts families)
#
# The remaining three families carry phase interleavings (the lag-1 wave
# walk, the A/B/D expert phases with no first/last flags) that a
# run-structural check cannot pin down column-by-column, so their
# checkers REPLAY the emission independently from the declarative spec
# and diff the whole table — any mutated cell, flag, or reordering shows
# up as a mismatch.

def _compare(out, fam, tab, exp, limit=8):
    tab = np.asarray(tab)
    if tab.shape != exp.shape:
        out.append(("schema", f"{fam}: expected table shape {exp.shape}, "
                              f"got {tab.shape}"))
        return
    diff = np.argwhere(tab != exp)
    for r, t in diff[:limit]:
        out.append(("schema", f"{fam}: row {int(r)} at step {int(t)} is "
                              f"{int(tab[r, t])}, want {int(exp[r, t])}"))
    if len(diff) > limit:
        out.append(("schema",
                    f"{fam}: ... and {len(diff) - limit} more mismatches"))


# ---------------------------------------------------------------------------
# chained (lag-1 wave schedule)

def _chain_steps(tag, src):
    """The ordered k-steps of one chained branch — mirrors the kernel's
    ``_chain_ksteps`` (which imports its row constants from here)."""
    if tag == "x":
        return [("x", kk) for kk in range(src)]
    if tag == "panel":
        return [("panel", pc) for pc in src]
    taps, rcs = src
    return [("ring", (d, dh, dw, rc)) for (d, dh, dw) in taps
            for rc in rcs]


def expected_chained(m_blocks, spec):
    """Independent replay of ``_plan_tiles_chained`` from the planner
    spec (per phase a tuple of ``(tag, src, nbb, rwcs)`` branch specs):
    the expected (CH_ROWS + 2*P + 1, T) table including the wave walk,
    the per-phase output-stability backfill and the trailing
    ``ch_mrow_row`` liveness-slot row."""
    nph = len(spec)
    nrows = CH_ROWS + 2 * nph + 1
    info, xbase, wbase, bbase = [], 0, 0, 0
    for phase in spec:
        pinfo, ob = [], 0
        for (tag, src, nbb, rwcs) in phase:
            steps = _chain_steps(tag, src)
            pinfo.append((tag, src, nbb, rwcs, steps, xbase, wbase,
                          bbase, ob))
            if tag == "x":
                xbase += m_blocks * src
            wbase += len(steps) * nbb
            bbase += nbb
            ob += nbb
        info.append(pinfo)
    cols = []
    for wave in range(m_blocks + nph - 1):
        for p in range(nph):
            i = wave - p
            if not 0 <= i < m_blocks:
                continue
            for (tag, src, nbb, rwcs, steps, xb, wb, bb, ob) in info[p]:
                ns = len(steps)
                for j in range(nbb):
                    for s, (kt, kd) in enumerate(steps):
                        c = [0] * nrows
                        c[CH_I], c[CH_PH] = i, p
                        c[ch_mrow_row(nph)] = p * m_blocks + i
                        c[CH_WT] = wb + s * nbb + j
                        c[CH_BJ] = bb + j
                        c[CH_FIRST] = int(s == 0)
                        c[CH_LAST] = int(s == ns - 1)
                        c[CH_RWC] = -1
                        if kt == "x":
                            c[CH_SRC] = 0
                            c[CH_XT] = xb + i * src + kd
                        elif kt == "panel":
                            pidx, pcb = kd
                            c[CH_SRC] = 3 + pidx
                            c[CH_PCA if pidx == 0 else CH_PCB] = pcb
                        else:
                            d, dh, dw, rc = kd
                            c[CH_SRC] = 2
                            c[CH_RC], c[CH_DELTA] = rc, d
                            c[CH_DH], c[CH_DW] = dh, dw
                        if c[CH_LAST]:
                            c[ch_out_i_row(p)] = i
                            c[ch_out_j_row(p)] = ob + j
                            if rwcs:
                                c[CH_RWC] = rwcs[j]
                        cols.append(c)
    ncbs = [sum(br[2] for br in pinfo) for pinfo in info]
    for p in range(nph):
        nr, nc = ch_out_i_row(p), ch_out_j_row(p)
        nxt = (m_blocks - 1, ncbs[p] - 1)
        for c in reversed(cols):
            if c[CH_PH] == p and c[CH_LAST] == 1:
                nxt = (c[nr], c[nc])
            c[nr], c[nc] = nxt
    return np.array(cols, np.int32).T


def check_chained(tab, m_blocks, spec):
    """Validate a ``_plan_tiles_chained`` table against the planner spec
    by full replay-compare, plus explicit bounds on the wave anchors."""
    out = []
    fam = "chained"
    exp = expected_chained(m_blocks, spec)
    tab = np.asarray(tab)
    _compare(out, fam, tab, exp)
    if tab.shape == exp.shape and tab.shape[1]:
        nph = len(spec)
        if not ((tab[CH_I] >= 0) & (tab[CH_I] < m_blocks)).all():
            out.append(("bounds", f"{fam}: M-block row outside "
                                  f"[0, {m_blocks})"))
        if not ((tab[CH_PH] >= 0) & (tab[CH_PH] < nph)).all():
            out.append(("bounds", f"{fam}: phase row outside [0, {nph})"))
        wave = tab[CH_I].astype(np.int64) + tab[CH_PH].astype(np.int64)
        if (np.diff(wave) < 0).any():
            out.append(("schema", f"{fam}: wave order regresses — a step "
                                  "runs before its producers' wave"))
        mr = tab[ch_mrow_row(nph)].astype(np.int64)
        if not ((mr >= 0) & (mr < nph * m_blocks)).all():
            out.append(("bounds", f"{fam}: mrow slot row outside "
                                  f"[0, {nph * m_blocks})"))
        if (mr != tab[CH_PH].astype(np.int64) * m_blocks
                + tab[CH_I].astype(np.int64)).any():
            out.append(("schema", f"{fam}: mrow slot row disagrees with "
                                  "phase*m_blocks + block — a ragged "
                                  "launch would read the wrong liveness"))
    return out


# ---------------------------------------------------------------------------
# MoE experts (forward + combined backward)

def expected_experts(mbs, db, fb, gated):
    """Independent replay of ``_plan_tiles_experts``: per M-block the H
    phases (one per W_in channel, accumulating over D-blocks into the
    post-activation scratch) then the Y phase (accumulating H over
    F-blocks against W_out columns)."""
    nw = 1 + int(gated)
    cols = []
    for i in range(mbs):
        for j in range(fb):
            for wch in range(nw):
                for k in range(db):
                    cols.append([i, i * db + k, wch * db * fb + k * fb + j,
                                 0, wch, int(k == 0), int(k == db - 1),
                                 j, i * db, i * fb + j])
        for c in range(db):
            for j in range(fb):
                cols.append([i, i * db + db - 1, 0, j * db + c, 2,
                             int(j == 0), int(j == fb - 1), j,
                             i * db + c,
                             (i + 1) * fb if i + 1 < mbs
                             else i * fb + fb - 1])
    return np.array(cols, np.int32).T


def check_experts(tab, mbs, db, fb, gated):
    """Validate a ``_plan_tiles_experts`` table by replay-compare plus
    bounds on the block-index anchor row."""
    out = []
    fam = "experts"
    exp = expected_experts(mbs, db, fb, gated)
    tab = np.asarray(tab)
    _compare(out, fam, tab, exp)
    if tab.shape == exp.shape and tab.shape[1]:
        if not ((tab[EX_BI] >= 0) & (tab[EX_BI] < mbs)).all():
            out.append(("bounds", f"{fam}: expert block row outside "
                                  f"[0, {mbs})"))
        if (np.diff(tab[EX_BI].astype(np.int64)) < 0).any():
            out.append(("schema", f"{fam}: expert blocks out of order"))
    return out


def expected_experts_bwd(mbs, db, fb, gated):
    """Independent replay of ``_plan_tiles_experts_bwd``: per M-block
    the A (dH_post), B (dW_out accumulate), C (dX) and D (dW_h
    accumulate) phases."""
    nw = 1 + int(gated)
    hold = db * fb - 1
    cols = []
    for i in range(mbs):
        for j in range(fb):
            for c in range(db):
                cols.append([i, i * db + c, i * db, 0, c * fb + j,
                             i * fb + j, 0, int(c == 0),
                             int(c == db - 1), j, i * db, 0, 0])
        for j in range(fb):
            for c in range(db):
                cols.append([i, i * db + c, i * db, 0, hold, i * fb + j,
                             1, 0, 0, j, i * db, 0, j * db + c])
        for c in range(db):
            for wch in range(nw):
                for j in range(fb):
                    cols.append([i, i * db + db - 1, i * db,
                                 wch * fb * db + j * db + c, hold,
                                 i * fb + fb - 1, 2,
                                 int(wch == 0 and j == 0),
                                 int(wch == nw - 1 and j == fb - 1),
                                 wch * fb + j, i * db + c, 0, hold])
        for wch in range(nw):
            for c in range(db):
                for j in range(fb):
                    cols.append([i, i * db + db - 1, i * db + c,
                                 wch * fb * db, hold, i * fb + fb - 1,
                                 3, 0, 0, wch * fb + j, i * db + db - 1,
                                 wch * db * fb + c * fb + j, hold])
    return np.array(cols, np.int32).T


def check_experts_bwd(tab, mbs, db, fb, gated):
    """Validate a ``_plan_tiles_experts_bwd`` table by replay-compare
    plus bounds and phase-order checks."""
    out = []
    fam = "experts-bwd"
    exp = expected_experts_bwd(mbs, db, fb, gated)
    tab = np.asarray(tab)
    _compare(out, fam, tab, exp)
    if tab.shape == exp.shape and tab.shape[1]:
        if not ((tab[EB_BI] >= 0) & (tab[EB_BI] < mbs)).all():
            out.append(("bounds", f"{fam}: expert block row outside "
                                  f"[0, {mbs})"))
        if not ((tab[EB_PH] >= 0) & (tab[EB_PH] <= 3)).all():
            out.append(("bounds", f"{fam}: phase row outside [0, 3]"))
    return out
