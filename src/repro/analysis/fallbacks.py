"""planlint fallback provenance — a jaxpr lint that names the op.

``core/launch_count.py`` counts launch-like primitives; its gates can
only say "the count regressed".  This lint generalizes it: every
surviving fallback primitive (``gather`` / ``scatter*`` /
``concatenate`` / ``reduce_window*`` / ``conv_general_dilated``) in a
traced plan is attributed to the producing plan op through the
``jax.named_scope`` tags ``core/plan.py``'s executors wrap their
emissions in (``plan[<mode>:<op>]``), so a zero-fallback gate reports
WHICH op leaked instead of a bare number.

Policy (``lint_fallbacks``): a fallback primitive inside a
co-execution scope is a finding — those modes exist to delete exactly
these primitives — with two contractual exceptions:

  * ``grouped_concat`` may emit ``concatenate``: the fused launch
    writes branch tiles in place and the executor assembles the join
    from maximal buffer slices + passthrough segments with ONE final
    concat (strictly less copying than a standalone join; the launch
    ceiling gates budget for it).
  * ``grouped``, ``grouped_pooled`` and ``stacked`` may emit
    ``concatenate``: the packed tile stacks (im2col views, the
    tap-expanded pooled X stack, the pad-to-max branch stack) are
    PACKING copies the modes' cost model and C2 budgets price
    explicitly (``analysis/budgets.py``) — they feed the one launch,
    they are not a surviving join (a join runs under its own op's
    scope, never the producing group's).  ``grouped_chained`` gets no
    such allowance: its pack path is dynamic-update-slice only, by
    contract.
  * serial / xla / degraded (``-> xla``) scopes emit native primitives
    by design — they are reported in the attribution table but are not
    findings.

Tracing only — the plan is never executed.
"""
from __future__ import annotations

import jax

from repro.core.launch_count import _subjaxprs

#: primitive name -> report key (launch_count's COUNTED plus the
#: scatter/gather family the zero-fallback claims also cover)
FALLBACK_PRIMS = {
    "conv_general_dilated": "conv",
    "reduce_window": "reduce_window",
    "reduce_window_max": "reduce_window",
    "reduce_window_min": "reduce_window",
    "reduce_window_sum": "reduce_window",
    "select_and_scatter_add": "reduce_window",
    "concatenate": "concatenate",
    "gather": "gather",
    "scatter": "scatter",
    "scatter-add": "scatter",
    "scatter-mul": "scatter",
    "scatter-min": "scatter",
    "scatter-max": "scatter",
}

#: co-execution scope modes whose emissions must stay fallback-free
CLEAN_MODES = ("grouped", "grouped_pooled", "grouped_chained",
               "grouped_concat", "grouped_experts", "stacked", "fused")

#: (mode, primitive key) pairs the mode's contract allows (see the
#: module docstring for why each packing/assembly concat is budgeted)
ALLOWED = {("grouped", "concatenate"),
           ("grouped_concat", "concatenate"),
           ("grouped_pooled", "concatenate"),
           ("stacked", "concatenate")}


def _own_tag(eqn) -> str | None:
    """The innermost ``plan[...]`` tag on the equation's OWN name stack,
    or None — sub-jaxpr stacks are relative, so an equation nested in a
    pjit/scan body carries the enclosing call's scope instead (threaded
    down by ``fallback_report``'s walk)."""
    stack = str(eqn.source_info.name_stack)
    tags = [s for s in stack.split("/") if s.startswith("plan[")]
    return tags[-1] if tags else None


def _mode_of(scope: str) -> str:
    if scope.startswith("plan[") and ":" in scope:
        return scope[len("plan["):].split(":", 1)[0]
    return ""


def _walk_scoped(jaxpr, inherited, hits) -> None:
    """Recursive scoped walk: an equation's own ``plan[...]`` tag wins,
    otherwise it inherits the scope of the call that encloses it — so a
    ``gather`` hidden inside ``jnp.take``'s pjit body still attributes
    to the plan op that emitted it."""
    for eqn in jaxpr.eqns:
        scope = _own_tag(eqn) or inherited
        key = FALLBACK_PRIMS.get(eqn.primitive.name)
        if key is not None:
            k = (key, scope or "<untagged>")
            hits[k] = hits.get(k, 0) + 1
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                _walk_scoped(sub, scope, hits)


def fallback_report(fn, *args, **kwargs) -> dict:
    """Trace ``fn(*args, **kwargs)`` (never executed) and return the
    attribution table ``{(primitive key, scope): count}`` over every
    fallback primitive in the jaxpr, sub-jaxprs included."""
    closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    hits: dict[tuple[str, str], int] = {}
    _walk_scoped(closed.jaxpr, None, hits)
    return hits


def lint_fallbacks(fn, *args, **kwargs):
    """Findings for every fallback primitive that leaked into a
    co-execution scope: ``(kind, message)`` tuples with kind
    ``"fallback"``.  Serial/xla/degraded scopes are exempt (native
    primitives are their contract), as is the fused-concat assembly
    concatenate."""
    out = []
    for (key, scope), n in sorted(fallback_report(fn, *args,
                                                  **kwargs).items()):
        mode = _mode_of(scope)
        if mode not in CLEAN_MODES or (mode, key) in ALLOWED:
            continue
        out.append(("fallback",
                    f"{key} x{n} leaked into {scope} — the {mode} launch "
                    "claims to have deleted this primitive"))
    return out
