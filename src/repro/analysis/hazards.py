"""planlint hazard analysis — happens-before on the chained wave
schedule and write-write checks on fused-concat column layouts.

The chained kernel (``grouped_matmul_chained``) runs its phases in a
lag-1 wave: wave ``w`` executes phase ``p``'s M-block ``i = w - p``, so
when a phase-``p+1`` consumer runs block ``i`` the producer phase has
already stored blocks ``0..i+1`` — block ``i+1`` lands EARLIER in the
same wave (phases ascend within a wave).  The kernel banks on that: a
ring read assembles producer blocks ``i-1 / i / i+1`` from a 3-slot
VMEM ring (slot = block mod 3) and slices the halo-shifted row window
out of them.  Nothing at runtime checks the bank holds — these checkers
prove it statically from the offset table alone:

  ``check_chained_schedule``  walks the table in execution order,
      tracking which M-block each (slot, ring column) pair last
      received; every ring read must find exactly the block the slice
      touches (mid always; lo when the halo shifts backward; hi when it
      shifts forward), every tap must satisfy ``delta == dh*W + dw``
      and ``|delta| <= bm`` (rows the shift pushes past a resident
      block are exactly the rows the border mask zeroes — the algebra
      is in the function docstring), and every ring column index must
      sit inside the declared ring.

  ``check_chained_masked``  the ragged-M extension: a serving launch
      skips M-blocks entirely past ``m_valid`` (the per-phase mrow slot
      row, ``tables.ch_mrow_row``), so a consumer wave must never need
      a producer wave the mask could have skipped.  The checker proves
      the liveness lookup is sound for EVERY image-aligned cutoff.

  ``check_concat_segments``  the write-write hazard check for fused
      concat layouts: branch panel segments and passthrough
      dynamic-update-slice column ranges must tile the join's [M, N]
      output without overlap.

Pure numpy — callable on a mutated table in fault-injection tests
without touching a kernel.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.tables import (CH_DELTA, CH_DH, CH_DW, CH_I, CH_LAST,
                                   CH_PH, CH_RC, CH_ROWS, CH_RWC, CH_SRC,
                                   ch_mrow_row)


def check_chained_schedule(tab, m_blocks, nph, *, h, w, bm, nring):
    """Happens-before + geometry check on a chained offset table.

    Ring-read soundness: a read of producer block ``b`` is safe when the
    slot ``b % 3`` last received exactly block ``b`` at an earlier step.
    The border mask covers the rest: a window row ``r`` (global output
    row) reads producer row ``r + delta`` with ``delta = dh*W + dw``;
    when the tap is unmasked (``0 <= r//W%H + dh < H`` and
    ``0 <= r%W + dw < W``) then ``r + delta`` provably stays inside the
    same image — ``rem = r % (H*W)`` satisfies ``rem + dh*W + dw in
    [0, H*W)`` — so unmasked rows never cross into a block outside the
    resident ``i-1..i+1`` window as long as ``|delta| <= bm``.

    Findings are ``(kind, message)`` with kind ``"hazard"`` (order or
    slot violations) or ``"bounds"`` (ring geometry).
    """
    out = []
    fam = "chained-schedule"
    tab = np.asarray(tab)
    if tab.ndim != 2 or tab.shape[0] < CH_ROWS + 2 * nph:
        out.append(("hazard", f"{fam}: table has {tab.shape[0] if tab.ndim == 2 else 0} "
                              f"rows, want >= {CH_ROWS + 2 * nph}"))
        return out
    ring: dict[tuple[int, int], int] = {}   # (slot, ring col) -> block
    for t in range(tab.shape[1]):
        i = int(tab[CH_I, t])
        if not 0 <= i < m_blocks:
            out.append(("bounds", f"{fam}: step {t} runs M-block {i} "
                                  f"outside [0, {m_blocks})"))
            continue
        src = int(tab[CH_SRC, t])
        if src == 2:
            rc = int(tab[CH_RC, t])
            d = int(tab[CH_DELTA, t])
            dh, dw = int(tab[CH_DH, t]), int(tab[CH_DW, t])
            if not 0 <= rc < nring:
                out.append(("bounds", f"{fam}: ring read at step {t} "
                                      f"addresses column {rc} outside "
                                      f"[0, {nring})"))
                continue
            if d != dh * w + dw:
                out.append(("bounds", f"{fam}: tap at step {t} has "
                                      f"delta {d} != dh*W+dw = "
                                      f"{dh * w + dw} (W={w})"))
            if abs(d) > bm:
                out.append(("bounds", f"{fam}: halo {d} at step {t} "
                                      f"exceeds bm={bm} — the shift "
                                      "window cannot cover it"))
                continue
            # which of the three ring slots does the shifted slice touch?
            needs = []
            if d < 0:
                needs.append(i - 1)        # lo slot
            if -bm < d < bm:
                needs.append(i)            # mid slot
            if d > 0:
                needs.append(i + 1)        # hi slot
            for b in needs:
                if not 0 <= b < m_blocks:
                    continue               # border-masked edge rows
                got = ring.get((b % 3, rc))
                if got != b:
                    out.append((
                        "hazard",
                        f"{fam}: step {t} (block {i}) reads producer "
                        f"block {b} from ring column {rc}, but slot "
                        f"{b % 3} holds "
                        f"{'nothing' if got is None else f'block {got}'}"
                        " — the wave schedule broke happens-before"))
        if int(tab[CH_LAST, t]) == 1:
            rwc = int(tab[CH_RWC, t])
            if rwc >= 0:
                if rwc >= nring:
                    out.append(("bounds", f"{fam}: ring write at step "
                                          f"{t} addresses column {rwc} "
                                          f"outside [0, {nring})"))
                else:
                    ring[(i % 3, rwc)] = i
    return out


def check_chained_masked(tab, m_blocks, nph, *, h, w):
    """Prove a ragged-M chained launch cannot race for ANY image-aligned
    cutoff ``m_valid = valid_images * h * w``.

    The kernel guards every step with ``mrow[tab[ch_mrow_row, t]] > 0``
    and the per-phase mrow vector holds ``clip(m_valid - i*bm, 0, bm)``
    at slot ``p*m_blocks + i`` — liveness depends only on the block
    index, identically for every phase.  Two obligations make the skip
    safe:

      1. the liveness lookup addresses THIS step's (phase, block): the
         mrow slot row must equal ``phase * m_blocks + block``
         everywhere.  A wrong slot could report a consumer live while
         its producer wave was skipped (or mask a live block's store).
      2. a live consumer row never taps a skipped producer block: an
         unmasked ring tap of output row ``r`` reads ``r + delta`` with
         ``delta == dh*W + dw`` inside the SAME image (the border-mask
         algebra in ``check_chained_schedule``), and ``m_valid`` is
         image-aligned — so ``r < m_valid`` implies
         ``r + delta < m_valid``, i.e. the tapped block satisfies
         ``b*bm <= r + delta < m_valid`` and is live.  Statically that
         reduces to every ring tap satisfying the in-image identity,
         re-checked here so the masked proof stands alone.

    Dead blocks' epilogue stores are skipped too, but their panel slots
    are only ever addressed by equally-dead consumer blocks (same block
    index next launch), and live tail blocks store exact zeros past
    ``m_valid`` — the kernel's epilogue row mask, not a table property.
    """
    out = []
    fam = "chained-masked"
    tab = np.asarray(tab)
    mrr = ch_mrow_row(nph)
    if tab.ndim != 2 or tab.shape[0] <= mrr:
        out.append(("hazard", f"{fam}: table has no mrow slot row "
                              f"(want > {mrr} rows, got "
                              f"{tab.shape[0] if tab.ndim == 2 else 0})"))
        return out
    mr = tab[mrr].astype(np.int64)
    bad = np.nonzero((mr < 0) | (mr >= nph * m_blocks))[0]
    if bad.size:
        out.append(("bounds", f"{fam}: mrow slot {int(mr[bad[0]])} at "
                              f"step {int(bad[0])} outside "
                              f"[0, {nph * m_blocks})"))
    want = tab[CH_PH].astype(np.int64) * m_blocks + tab[CH_I].astype(
        np.int64)
    diff = np.nonzero(mr != want)[0]
    if diff.size:
        t = int(diff[0])
        out.append(("hazard", f"{fam}: step {t} reads liveness slot "
                              f"{int(mr[t])}, want {int(want[t])} "
                              f"(phase*m_blocks + block) — the no-op "
                              "guard would skip/run the wrong wave"))
    ring_steps = np.nonzero(tab[CH_SRC] == 2)[0]
    for t in ring_steps:
        d = int(tab[CH_DELTA, t])
        dh, dw = int(tab[CH_DH, t]), int(tab[CH_DW, t])
        if d != dh * w + dw:
            out.append(("bounds", f"{fam}: tap at step {int(t)} has "
                                  f"delta {d} != dh*W+dw = {dh * w + dw}"
                                  " — an unmasked row could tap across "
                                  "the image (and the m_valid) boundary"
                                  " into a skipped block"))
    return out


def check_concat_segments(segments, total):
    """Write-write hazard check on a fused-concat column layout.

    ``segments`` is a list of ``(offset, width, who)`` column ranges —
    branch panel segments plus passthrough DUS ranges — and ``total``
    the join's N.  Findings (kind ``"hazard"``) when any two ranges
    overlap or a range escapes ``[0, total)``; a gap is reported as a
    schema finding (a join column nobody writes would serve garbage).
    """
    out = []
    fam = "concat-segments"
    segs = sorted((int(o), int(n), str(who)) for o, n, who in segments)
    covered = 0
    prev = None
    for o, n, who in segs:
        if n <= 0 or o < 0 or o + n > total:
            out.append(("hazard", f"{fam}: segment {who} [{o}, {o + n}) "
                                  f"escapes the join's [0, {total})"))
            continue
        if prev is not None and o < prev[0] + prev[1]:
            out.append(("hazard", f"{fam}: segments {prev[2]} "
                                  f"[{prev[0]}, {prev[0] + prev[1]}) and "
                                  f"{who} [{o}, {o + n}) overlap — "
                                  "write-write hazard on the join"))
        prev = (o, n, who)
        covered += n
    if not out and covered != total:
        out.append(("schema", f"{fam}: segments cover {covered} of "
                              f"{total} join columns"))
    return out
