"""planlint hazard analysis — happens-before on the chained wave
schedule and write-write checks on fused-concat column layouts.

The chained kernel (``grouped_matmul_chained``) runs its phases in a
lag-1 wave: wave ``w`` executes phase ``p``'s M-block ``i = w - p``, so
when a phase-``p+1`` consumer runs block ``i`` the producer phase has
already stored blocks ``0..i+1`` — block ``i+1`` lands EARLIER in the
same wave (phases ascend within a wave).  The kernel banks on that: a
ring read assembles producer blocks ``i-1 / i / i+1`` from a 3-slot
VMEM ring (slot = block mod 3) and slices the halo-shifted row window
out of them.  Nothing at runtime checks the bank holds — these checkers
prove it statically from the offset table alone:

  ``check_chained_schedule``  walks the table in execution order,
      tracking which M-block each (slot, ring column) pair last
      received; every ring read must find exactly the block the slice
      touches (mid always; lo when the halo shifts backward; hi when it
      shifts forward), every tap must satisfy ``delta == dh*W + dw``
      and ``|delta| <= bm`` (rows the shift pushes past a resident
      block are exactly the rows the border mask zeroes — the algebra
      is in the function docstring), and every ring column index must
      sit inside the declared ring.

  ``check_concat_segments``  the write-write hazard check for fused
      concat layouts: branch panel segments and passthrough
      dynamic-update-slice column ranges must tile the join's [M, N]
      output without overlap.

Pure numpy — callable on a mutated table in fault-injection tests
without touching a kernel.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.tables import (CH_DELTA, CH_DH, CH_DW, CH_I, CH_LAST,
                                   CH_RC, CH_ROWS, CH_RWC, CH_SRC)


def check_chained_schedule(tab, m_blocks, nph, *, h, w, bm, nring):
    """Happens-before + geometry check on a chained offset table.

    Ring-read soundness: a read of producer block ``b`` is safe when the
    slot ``b % 3`` last received exactly block ``b`` at an earlier step.
    The border mask covers the rest: a window row ``r`` (global output
    row) reads producer row ``r + delta`` with ``delta = dh*W + dw``;
    when the tap is unmasked (``0 <= r//W%H + dh < H`` and
    ``0 <= r%W + dw < W``) then ``r + delta`` provably stays inside the
    same image — ``rem = r % (H*W)`` satisfies ``rem + dh*W + dw in
    [0, H*W)`` — so unmasked rows never cross into a block outside the
    resident ``i-1..i+1`` window as long as ``|delta| <= bm``.

    Findings are ``(kind, message)`` with kind ``"hazard"`` (order or
    slot violations) or ``"bounds"`` (ring geometry).
    """
    out = []
    fam = "chained-schedule"
    tab = np.asarray(tab)
    if tab.ndim != 2 or tab.shape[0] < CH_ROWS + 2 * nph:
        out.append(("hazard", f"{fam}: table has {tab.shape[0] if tab.ndim == 2 else 0} "
                              f"rows, want >= {CH_ROWS + 2 * nph}"))
        return out
    ring: dict[tuple[int, int], int] = {}   # (slot, ring col) -> block
    for t in range(tab.shape[1]):
        i = int(tab[CH_I, t])
        if not 0 <= i < m_blocks:
            out.append(("bounds", f"{fam}: step {t} runs M-block {i} "
                                  f"outside [0, {m_blocks})"))
            continue
        src = int(tab[CH_SRC, t])
        if src == 2:
            rc = int(tab[CH_RC, t])
            d = int(tab[CH_DELTA, t])
            dh, dw = int(tab[CH_DH, t]), int(tab[CH_DW, t])
            if not 0 <= rc < nring:
                out.append(("bounds", f"{fam}: ring read at step {t} "
                                      f"addresses column {rc} outside "
                                      f"[0, {nring})"))
                continue
            if d != dh * w + dw:
                out.append(("bounds", f"{fam}: tap at step {t} has "
                                      f"delta {d} != dh*W+dw = "
                                      f"{dh * w + dw} (W={w})"))
            if abs(d) > bm:
                out.append(("bounds", f"{fam}: halo {d} at step {t} "
                                      f"exceeds bm={bm} — the shift "
                                      "window cannot cover it"))
                continue
            # which of the three ring slots does the shifted slice touch?
            needs = []
            if d < 0:
                needs.append(i - 1)        # lo slot
            if -bm < d < bm:
                needs.append(i)            # mid slot
            if d > 0:
                needs.append(i + 1)        # hi slot
            for b in needs:
                if not 0 <= b < m_blocks:
                    continue               # border-masked edge rows
                got = ring.get((b % 3, rc))
                if got != b:
                    out.append((
                        "hazard",
                        f"{fam}: step {t} (block {i}) reads producer "
                        f"block {b} from ring column {rc}, but slot "
                        f"{b % 3} holds "
                        f"{'nothing' if got is None else f'block {got}'}"
                        " — the wave schedule broke happens-before"))
        if int(tab[CH_LAST, t]) == 1:
            rwc = int(tab[CH_RWC, t])
            if rwc >= 0:
                if rwc >= nring:
                    out.append(("bounds", f"{fam}: ring write at step "
                                          f"{t} addresses column {rwc} "
                                          f"outside [0, {nring})"))
                else:
                    ring[(i % 3, rwc)] = i
    return out


def check_concat_segments(segments, total):
    """Write-write hazard check on a fused-concat column layout.

    ``segments`` is a list of ``(offset, width, who)`` column ranges —
    branch panel segments plus passthrough DUS ranges — and ``total``
    the join's N.  Findings (kind ``"hazard"``) when any two ranges
    overlap or a range escapes ``[0, total)``; a gap is reported as a
    schema finding (a join column nobody writes would serve garbage).
    """
    out = []
    fam = "concat-segments"
    segs = sorted((int(o), int(n), str(who)) for o, n, who in segments)
    covered = 0
    prev = None
    for o, n, who in segs:
        if n <= 0 or o < 0 or o + n > total:
            out.append(("hazard", f"{fam}: segment {who} [{o}, {o + n}) "
                                  f"escapes the join's [0, {total})"))
            continue
        if prev is not None and o < prev[0] + prev[1]:
            out.append(("hazard", f"{fam}: segments {prev[2]} "
                                  f"[{prev[0]}, {prev[0] + prev[1]}) and "
                                  f"{who} [{o}, {o + n}) overlap — "
                                  "write-write hazard on the join"))
        prev = (o, n, who)
        covered += n
    if not out and covered != total:
        out.append(("schema", f"{fam}: segments cover {covered} of "
                              f"{total} join columns"))
    return out
