"""planlint CLI — sweep an architecture's lowered plans and exit nonzero
on any finding.

    python -m repro.analysis.lint --arch googlenet
    python -m repro.analysis.lint --arch googlenet --full --fallbacks

Per variant (fused default / chained / unfused-concat / unfused-pool /
serial-joins) the forward AND the mirrored backward plan are statically
verified (``analysis.verify_plan`` — offset-table schemas, chained-wave
happens-before, C2 budgets), plus the MoE layer plan's expert tables.
``--fallbacks`` additionally traces each variant's plan executor
(``jax.make_jaxpr`` — no kernel runs) and lints surviving fallback
primitives against the named-scope provenance policy
(``analysis.fallbacks``).  This is the ``scripts/ci.sh`` gate: a plan
change that breaks a table invariant, the wave schedule, a budget or the
zero-fallback contract fails CI with the op-attributed finding, not a
bare count.
"""
from __future__ import annotations

import argparse
import sys


def _report(label: str, findings) -> int:
    if findings:
        print(f"[planlint] {label}: {len(findings)} finding(s)")
        for f in findings:
            print(f"    {f}")
    else:
        print(f"[planlint] {label}: ok")
    return len(findings)


VARIANTS = (
    ("fused", {}),
    ("chained", {"chain_modules": True}),
    ("unfused-concat", {"fuse_concat": False}),
    ("unfused-pool", {"fuse_pool": False}),
    ("serial-joins", {"fuse_concat": False, "fuse_pool": False}),
)

#: the MoE layer swept alongside the CNN variants (small enough to lint
#: in seconds, big enough that every expert-table row family appears)
MOE_DIMS = dict(b=2, s=64, d=256, f=512, e=4, top_k=2,
                capacity_factor=1.25)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="statically verify lowered plans (planlint)")
    ap.add_argument("--arch", default="googlenet",
                    help="architecture config name (default: googlenet)")
    ap.add_argument("--batch", type=int, default=2,
                    help="lowering batch size (default: 2)")
    ap.add_argument("--full", action="store_true",
                    help="full config instead of the reduced one")
    ap.add_argument("--fallbacks", action="store_true",
                    help="also trace each plan executor and lint fallback"
                         " primitive provenance (tracing only)")
    args = ap.parse_args(argv)

    from repro import analysis
    from repro.configs import get_config, get_reduced
    from repro.core import plan as planlib
    from repro.models import cnn, moe

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    total = 0
    params = x = None
    for name, kw in VARIANTS:
        plan, _ = cnn.plan_cnn(cfg, args.batch, **kw)
        total += _report(f"{args.arch}/{name} fwd",
                         analysis.verify_plan(plan))
        total += _report(f"{args.arch}/{name} bwd",
                         analysis.verify_plan(plan.context["backward"]))
        if args.fallbacks:
            import jax
            import jax.numpy as jnp
            from repro.analysis.fallbacks import lint_fallbacks
            from repro.core.plan import execute_plan
            if params is None:
                h, w, c = cfg.img
                params = cnn.init_params(cfg, jax.random.PRNGKey(0))
                x = jnp.zeros((args.batch, h, w, c), jnp.float32)
            raw = lint_fallbacks(
                lambda p, xx, plan=plan: execute_plan(p, xx, plan,
                                                      interpret=True),
                params, x)
            total += _report(
                f"{args.arch}/{name} fallbacks",
                [analysis.Finding(kind, "fallback", name, msg)
                 for kind, msg in raw])

    g = moe.build_moe_graph(**MOE_DIMS)
    mplan = planlib.lower_moe(g, **MOE_DIMS)
    total += _report("moe/grouped_experts fwd+bwd tables",
                     analysis.verify_plan(mplan))

    print(f"[planlint] total findings: {total}")
    return 1 if total else 0


if __name__ == "__main__":
    sys.exit(main())
