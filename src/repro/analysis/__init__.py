"""planlint — static verification of lowered plans.

See ``analysis/tables.py`` (offset-table schemas), ``budgets.py`` (the
unified C2 footprint), ``hazards.py`` (chained wave happens-before) and
``fallbacks.py`` (jaxpr fallback provenance).  ``verify_plan`` is the
entry point; the CLI lives in ``analysis/lint.py``.

Module-level imports here must stay leaf-level (dataclasses + tables
only): ``kernels/grouped_matmul.py`` imports the table schemas, and
``core/plan.py`` imports ``budgets`` — anything heavier is imported
lazily inside ``verify_plan``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verifier finding.

    checker  which checker fired: "schema" | "bounds" | "hazard" |
             "budget" | "fallback"
    family   the table family / group mode it applies to
    where    the group or op the finding is anchored to
    detail   human-readable description
    """
    checker: str
    family: str
    where: str
    detail: str

    def __str__(self) -> str:
        return f"[{self.checker}] {self.family} @ {self.where}: {self.detail}"


class PlanVerificationError(AssertionError):
    """Raised by ``lower(..., verify=True)`` when planlint findings
    survive on the lowered plan."""

    def __init__(self, findings):
        self.findings = tuple(findings)
        super().__init__(
            f"{len(self.findings)} planlint finding(s):\n" +
            "\n".join(f"  {f}" for f in self.findings))


def verify_plan(plan, graph=None):
    """Statically verify a lowered plan; returns a list of ``Finding``.

    Implemented in ``analysis/_verify.py`` (lazy import — verification
    pulls in kernels and models, which must not load when the kernels
    themselves import ``analysis.tables``)."""
    from repro.analysis._verify import verify_plan as _impl
    return _impl(plan, graph)
