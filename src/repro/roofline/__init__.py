from repro.roofline.analyze import (  # noqa: F401
    analyze_hlo, roofline_terms, xla_cost_analysis, HloCost,
)
