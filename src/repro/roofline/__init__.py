from repro.roofline.analyze import analyze_hlo, roofline_terms, HloCost  # noqa: F401
