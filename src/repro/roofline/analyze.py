"""HLO-text cost analyzer with while-loop trip-count correction.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so a
scan-over-layers model under-reports FLOPs/bytes/collectives by the trip
count (verified empirically in this repo: scan=16.8MF vs unroll=134MF for
8 matmul layers).  Production roofline numbers therefore need a corrected
walk: this module parses the post-optimization HLO text, builds a
per-computation symbol table, and recursively accumulates

  * dot FLOPs        2 * prod(result_dims) * prod(lhs contracting dims)
  * HBM bytes        operands + results of top-level (fusion-boundary) ops
  * collective wire  ring-model bytes per chip by kind and replica-group

multiplying while bodies by their static trip counts (jax scans lower to
counters compared against a constant).

Roofline terms per (arch, mesh) — hardware constants per assignment:
  compute  = FLOPs_per_chip / 197e12
  memory   = HBM_bytes_per_chip / 819e9
  coll.    = wire_bytes_per_chip / 50e9 (per-link ICI)
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
                "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
                "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# computation header: "%name (params...) -> type {"  (params may nest parens)
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
# instruction: %name = type op(...)   (tuple types may contain /*index=N*/)
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[^\s]+)\s+"
    r"([\w\-]+)\(", re.M)
_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

_ZERO_COST = {"parameter", "constant", "get-tuple-element", "tuple",
              "bitcast", "copy-done", "all-gather-done", "all-reduce-done",
              "after-all", "partition-id", "replica-id", "domain",
              "opt-barrier"}

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start", "ragged-all-to-all"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str

    def operand_names(self) -> list[str]:
        """Operand instruction names, tolerant of both HLO spellings:
        bare (``dot(%a, %b)``) and inline-typed
        (``dot(f32[64,128]{1,0} %a, ...)``, older jax dumps).  Scans from
        the op's own paren (so tuple-typed results don't shadow the operand
        list) to the matching close paren (types may nest parens and embed
        commas)."""
        idx = self.line.find(self.op + "(")
        if idx < 0:
            return []
        rest = self.line[idx + len(self.op) + 1:]
        depth, end = 0, len(rest)
        for j, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = j
                    break
                depth -= 1
        seg = rest[:end]
        if "%" in seg:
            return re.findall(r"%([\w.\-]+)", seg)
        return [o.strip().split()[-1] for o in seg.split(",") if o.strip()]


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0        # raw: operands+results at CPU-fusion
                                  # boundaries (UPPER bound for TPU)
    hbm_fused: float = 0.0        # idealized fusion: 2x result bytes at
                                  # materialization points only (lower bound)
    wire_bytes: float = 0.0
    coll: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += mult * other.flops
        self.hbm_bytes += mult * other.hbm_bytes
        self.hbm_fused += mult * other.hbm_fused
        self.wire_bytes += mult * other.wire_bytes
        for k, v in other.coll.items():
            d = self.coll.setdefault(k, {"count": 0.0, "wire_bytes": 0.0})
            d["count"] += mult * v["count"]
            d["wire_bytes"] += mult * v["wire_bytes"]


# ops whose result must live in HBM even under perfect fusion
_MATERIALIZE = {"dot", "convolution", "custom-call", "copy", "concatenate",
                "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
                "sort", "rng", "reduce-window", "select-and-scatter",
                "transpose"} | _COLLECTIVES


class HloModule:
    def __init__(self, text: str):
        self.comps: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self._parse(text)
        self._cost_cache: dict[str, HloCost] = {}
        self._trip_cache: dict[str, int] = {}

    # -- parsing -------------------------------------------------------------

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            hdr = _COMP_HDR.match(line)
            if hdr:
                cur = hdr.group(2)
                self.comps[cur] = []
                if hdr.group(1):
                    self.entry = cur
                continue
            if cur is None:
                continue
            m = _INSTR.match(line)
            if m:
                self.comps[cur].append(
                    Instr(m.group(1), m.group(2), m.group(3), line))
        if self.entry is None and self.comps:
            # fall back: the computation named like the module entry
            self.entry = list(self.comps)[-1]

    def _types_in(self, comp: str) -> dict[str, str]:
        return {i.name: i.type_str for i in self.comps.get(comp, [])}

    # -- per-op costs ----------------------------------------------------------

    def _dot_flops(self, instr: Instr, types: dict[str, str]) -> float:
        out_elems = 1
        for d in _shape_dims(instr.type_str):
            out_elems *= d
        # contraction size from lhs operand shape + contracting dims
        names = instr.operand_names()
        lhs_k = 1
        cd = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
        if names and cd and names[0] in types:
            dims = _shape_dims(types[names[0]])
            for ax in cd.group(1).split(","):
                if ax and int(ax) < len(dims):
                    lhs_k *= dims[int(ax)]
        return 2.0 * out_elems * lhs_k

    def _operand_bytes(self, instr: Instr, types: dict[str, str]) -> int:
        total = 0
        for o in instr.operand_names():
            if o in types:
                total += _type_bytes(types[o])
        return total

    def _collective(self, instr: Instr) -> tuple[str, float]:
        rb = _type_bytes(instr.type_str)
        g = 2
        gm = re.search(r"replica_groups=\{\{([^}]*)\}", instr.line)
        if gm:
            g = max(len(gm.group(1).split(",")), 1)
        else:
            gm2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", instr.line)
            if gm2:
                g = max(int(gm2.group(2)), 1)
        kind = instr.op.replace("-start", "")
        if kind == "all-gather":
            wire = (g - 1) / g * rb
        elif kind == "reduce-scatter":
            wire = (g - 1) * rb
        elif kind == "all-reduce":
            wire = 2 * (g - 1) / g * rb
        elif kind in ("all-to-all", "ragged-all-to-all"):
            wire = (g - 1) / g * rb
        else:  # collective-permute
            wire = rb
        return kind, wire

    def _called_comps(self, instr: Instr) -> list[str]:
        out = []
        for key in ("calls=", "to_apply=", "body=", "condition=",
                    "true_computation=", "false_computation="):
            for m in re.finditer(re.escape(key) + r"%?([\w.\-]+)", instr.line):
                out.append(m.group(1))
        # branch_computations={%a, %b}
        bm = re.search(r"branch_computations=\{([^}]*)\}", instr.line)
        if bm:
            out += [c.strip().lstrip("%") for c in bm.group(1).split(",")]
        return [c for c in out if c in self.comps]

    def trip_count(self, cond_comp: str) -> int:
        """Static trip count from a jax-style while condition."""
        if cond_comp in self._trip_cache:
            return self._trip_cache[cond_comp]
        n = 1
        for i in self.comps.get(cond_comp, []):
            if i.op == "constant":
                m = re.search(r"constant\((\d+)\)", i.line)
                if m:
                    n = max(n, int(m.group(1)))
        self._trip_cache[cond_comp] = n
        return n

    # -- recursive cost --------------------------------------------------------

    def cost(self, comp: str | None = None, _depth=0) -> HloCost:
        comp = comp or self.entry
        if comp in self._cost_cache:
            return self._cost_cache[comp]
        if _depth > 64:
            return HloCost()
        total = HloCost()
        types = self._types_in(comp)
        # consumer counts (for the idealized-fusion byte model)
        uses: dict[str, int] = {}
        instrs = self.comps.get(comp, [])
        root_name = instrs[-1].name if instrs else None
        for instr in instrs:
            for o in instr.operand_names():
                if o in types:
                    uses[o] = uses.get(o, 0) + 1

        def _fused_bytes(instr):
            return 2.0 * _type_bytes(instr.type_str)

        for instr in self.comps.get(comp, []):
            op = instr.op
            if op in _ZERO_COST:
                continue
            if op == "while":
                bm = re.search(r"body=%?([\w.\-]+)", instr.line)
                cm = re.search(r"condition=%?([\w.\-]+)", instr.line)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                tm = _TRIP_CFG.search(instr.line)
                if tm:
                    trips = int(tm.group(1))
                else:
                    trips = self.trip_count(cond) if cond else 1
                if body in self.comps:
                    total.add(self.cost(body, _depth + 1), trips)
                # while carries re-read/written per iteration: already counted
                # inside body instrs; skip the while's own operand bytes.
                continue
            if op == "fusion":
                # HBM traffic at the fusion boundary; dots inside count FLOPs.
                total.hbm_bytes += self._operand_bytes(instr, types) \
                    + _type_bytes(instr.type_str)
                # idealized fusion: only multi-consumer or root fusion
                # outputs materialize
                if uses.get(instr.name, 0) > 1 or instr.name == root_name:
                    total.hbm_fused += _fused_bytes(instr)
                for c in self._called_comps(instr):
                    inner = self.cost(c, _depth + 1)
                    total.flops += inner.flops
                    total.wire_bytes += inner.wire_bytes
                    for k, v in inner.coll.items():
                        d = total.coll.setdefault(
                            k, {"count": 0.0, "wire_bytes": 0.0})
                        d["count"] += v["count"]
                        d["wire_bytes"] += v["wire_bytes"]
                continue
            if op in ("call", "conditional", "custom-call"):
                for c in self._called_comps(instr):
                    total.add(self.cost(c, _depth + 1))
                if op == "custom-call":
                    total.hbm_bytes += self._operand_bytes(instr, types) \
                        + _type_bytes(instr.type_str)
                    total.hbm_fused += _fused_bytes(instr)
                continue
            if op in _COLLECTIVES:
                kind, wire = self._collective(instr)
                d = total.coll.setdefault(kind,
                                          {"count": 0.0, "wire_bytes": 0.0})
                d["count"] += 1
                d["wire_bytes"] += wire
                total.wire_bytes += wire
                total.hbm_bytes += _type_bytes(instr.type_str)
                total.hbm_fused += _fused_bytes(instr)
                continue
            if op == "dot":
                total.flops += self._dot_flops(instr, types)
                total.hbm_bytes += self._operand_bytes(instr, types) \
                    + _type_bytes(instr.type_str)
                total.hbm_fused += self._operand_bytes(instr, types) \
                    + _type_bytes(instr.type_str)
                continue
            if op in ("convolution",):
                # rough: 2 * out_elems * (kh*kw*cin) — parse window
                out_elems = 1
                for d in _shape_dims(instr.type_str):
                    out_elems *= d
                total.flops += 2.0 * out_elems  # lower bound w/o window info
                total.hbm_bytes += self._operand_bytes(instr, types) \
                    + _type_bytes(instr.type_str)
                total.hbm_fused += _fused_bytes(instr)
                continue
            # default: elementwise-ish top-level op — HBM traffic only
            total.hbm_bytes += self._operand_bytes(instr, types) \
                + _type_bytes(instr.type_str)
            if op in _MATERIALIZE:
                total.hbm_fused += _fused_bytes(instr)
        self._cost_cache[comp] = total
        return total


def analyze_hlo(text: str) -> HloCost:
    return HloModule(text).cost()


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions.

    Older jax returns a one-element list of per-device-program dicts; newer
    jax returns the dict directly.  Comparisons against the while-corrected
    analyzer go through here.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ca


def roofline_terms(cost: HloCost, *, chips_note: str = "per-chip") -> dict:
    """Three-term roofline (inputs are PER-CHIP quantities: post-SPMD HLO
    describes one device's program).

    memory_s uses the idealized-fusion byte model (TPU XLA fuses elementwise
    chains the CPU backend leaves at fine granularity); memory_s_raw is the
    CPU-fusion-boundary upper bound.  Truth on hardware lies between.
    """
    ct = cost.flops / PEAK_FLOPS
    mt = cost.hbm_fused / HBM_BW
    mt_raw = cost.hbm_bytes / HBM_BW
    lt = cost.wire_bytes / ICI_BW
    dom = max(("compute", ct), ("memory", mt), ("collective", lt),
              key=lambda kv: kv[1])
    return {
        "compute_s": ct, "memory_s": mt, "memory_s_raw": mt_raw,
        "collective_s": lt,
        "dominant": dom[0], "bound_s": dom[1],
        "flops": cost.flops, "hbm_bytes": cost.hbm_fused,
        "hbm_bytes_raw": cost.hbm_bytes,
        "wire_bytes": cost.wire_bytes,
        "collectives": cost.coll,
    }
