"""Step functions + ShapeDtypeStruct input specs for every shape cell.

``input_specs(cfg, shape, mesh)`` returns (fn, args, in_shardings,
out_shardings, donate) ready for ``jax.jit(...).lower(*args)`` — the
shannon/kernels pattern: weak-type-correct stand-ins, no allocation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.models import transformer as T
from repro.optim import AdamW
from repro.sharding import specs as SH


def _sds(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _replicated(mesh, tree):
    return jax.tree.map(lambda x: NamedSharding(mesh, P()), tree)


def train_config_for(cfg: ModelConfig) -> TrainConfig:
    """Per-arch training knobs; bf16 moments for the 398B config (C4
    tradeoff — see DESIGN.md §8)."""
    opt_dtype = "bfloat16" if cfg.param_count() > 100e9 else "float32"
    return TrainConfig(opt_state_dtype=opt_dtype)


def make_optimizer(cfg: ModelConfig, tc: TrainConfig | None = None) -> AdamW:
    tc = tc or train_config_for(cfg)
    return AdamW(lr=tc.lr, b1=tc.b1, b2=tc.b2,
                 weight_decay=tc.weight_decay, warmup=tc.warmup_steps,
                 total=tc.total_steps, clip_norm=tc.clip_norm,
                 state_dtype=tc.opt_state_dtype)


def make_train_step(cfg: ModelConfig, optimizer: AdamW, *, impl="xla",
                    remat=True, moe_aux_weight=0.01):
    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            T.loss_fn, has_aux=True)(params, cfg, batch, impl=impl,
                                     remat=remat,
                                     moe_aux_weight=moe_aux_weight)
        new_params, new_opt, info = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, **parts, **info}
    return train_step


def make_cnn_train_step(cfg, optimizer: AdamW, *, plan=None, algorithms=None,
                        interpret=None):
    """Train step for the CNN family (the paper's native subject).

    ``plan`` is a ``core.plan.Plan`` from ``models.cnn.plan_cnn`` — branch
    groups execute in their lowered co-execution mode; ``plan=None`` falls
    back to the algorithms-dict serial path (``algorithms``), the knob
    ``forward`` has always had.
    """
    from repro.models import cnn as CNN

    kw: dict = {"plan": plan} if plan is not None \
        else {"algorithms": algorithms}
    if interpret is not None:
        kw["interpret"] = interpret

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            CNN.loss_fn, has_aux=True)(params, cfg, batch, **kw)
        new_params, new_opt, info = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, **parts, **info}
    return train_step


def make_cnn_serve_step(cfg, plan, *, interpret=None):
    """Inference step for the CNN serving path: one M-bucket's planned
    ragged forward.  ``plan`` must be lowered for the bucket's batch size
    (``core.plan_cache.cached_cnn_plan``); ``valid_images`` is a TRACED
    i32 scalar so every request mix admitted to the bucket re-enters the
    same jitted executable — the serving driver jits this once per bucket
    and stores it on the cache entry.  Returns (bucket, classes) logits
    whose rows at/past ``valid_images`` are padding."""
    from repro.models import cnn as CNN

    kw: dict = {"interpret": interpret} if interpret is not None else {}

    def serve_step(params, images, valid_images):
        return CNN.forward_plan(params, cfg, images, plan,
                                valid_images=valid_images, **kw)
    return serve_step


def make_prefill_step(cfg: ModelConfig, *, impl="xla"):
    def prefill_step(params, tokens, cache, extra_embeds=None):
        return T.prefill(params, cfg, tokens, cache,
                         extra_embeds=extra_embeds, impl=impl)
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, impl="xla"):
    def decode_step(params, cache, tokens, pos, context=None):
        return T.decode_step(params, cfg, cache, tokens, pos,
                             context=context, impl=impl)
    return decode_step


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------

def batch_shardings(cfg: ModelConfig, mesh, batch_tree):
    dp = SH.logical_axes(mesh, "dp")
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,) if dp else ()):
        dp_size *= mesh.shape[a]

    def spec(x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        lead = dp if x.shape[0] % max(dp_size, 1) == 0 else None
        return NamedSharding(mesh, P(lead, *([None] * (x.ndim - 1))))

    return jax.tree.map(spec, batch_tree)


def cache_shardings(cfg: ModelConfig, mesh, cache_tree, batch: int):
    """KV cache: batch over dp when divisible, else sequence over data
    (long_500k B=1); heads/state dims over model."""
    dp = SH.logical_axes(mesh, "dp")
    tp = SH.logical_axes(mesh, "tp")
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,) if dp else ()):
        dp_size *= mesh.shape[a]
    batch_ok = batch % max(dp_size, 1) == 0
    tp_size = mesh.shape[tp] if tp else 1

    def spec(x):
        nd = x.ndim
        if nd == 6:    # kv: (n_super, 2, B, S, Hkv, hd)
            s = [None] * 6
            if batch_ok:
                s[2] = dp
            else:
                s[3] = "data"
            if SH.perf_option("cache_seq_shard") and s[3] is None \
                    and x.shape[3] % max(tp_size, 1) == 0:
                # flash-decode style: shard the cache SEQUENCE over the
                # model axis (kv heads < tp would otherwise replicate the
                # whole cache per chip); attention joins with one psum.
                s[3] = tp
            elif x.shape[4] % tp_size == 0:
                s[4] = tp
            return NamedSharding(mesh, P(*s))
        if nd == 5:    # ssm: (n_super, B, H, N, P)
            s = [None] * 5
            if batch_ok:
                s[1] = dp
            if x.shape[2] % tp_size == 0:
                s[2] = tp
            return NamedSharding(mesh, P(*s))
        if nd == 4:    # conv: (n_super, B, W-1, C)
            s = [None] * 4
            if batch_ok:
                s[1] = dp
            if x.shape[3] % tp_size == 0:
                s[3] = tp
            return NamedSharding(mesh, P(*s))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree.map(spec, cache_tree)


# ---------------------------------------------------------------------------
# input specs per shape cell
# ---------------------------------------------------------------------------

def _batch_struct(cfg: ModelConfig, b: int, s: int, dtype=jnp.bfloat16):
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.frontend == "patch":
        # patches are part of the assigned backbone seq_len
        batch["tokens"] = jax.ShapeDtypeStruct((b, s - cfg.frontend_len),
                                               jnp.int32)
        batch["labels"] = jax.ShapeDtypeStruct((b, s - cfg.frontend_len),
                                               jnp.int32)
        batch["extra_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_len, cfg.d_model), dtype)
    elif cfg.frontend == "frame":
        batch["extra_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_context_len, cfg.d_model), dtype)
    return batch


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                param_dtype=jnp.bfloat16, impl="xla"):
    """Build (fn, args, in_shardings, out_shardings) for one cell.

    Call under ``SH.activations_on(mesh, **perf)`` — perf options
    (dp_over_model etc.) change the specs this builds."""
    params_sds = jax.eval_shape(
        functools.partial(T.init_params, cfg, dtype=param_dtype),
        jax.random.PRNGKey(0))
    # dp_over_model: params replicated (model axis becomes data parallelism)
    fsdp = not (SH.perf_option("dp_over_model") or SH.perf_option("no_fsdp"))
    pspecs = SH.param_specs(params_sds, mesh, fsdp=fsdp)
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        tc = train_config_for(cfg)
        opt = make_optimizer(cfg, tc)
        opt_sds = jax.eval_shape(opt.init, params_sds)
        ospecs = {"step": NamedSharding(mesh, P()),
                  "m": pspecs, "v": pspecs}
        batch = _batch_struct(cfg, b, s)
        bspecs = batch_shardings(cfg, mesh, batch)
        fn = make_train_step(cfg, opt, impl=impl, remat=True)
        args = (params_sds, opt_sds, batch)
        in_sh = (pspecs, ospecs, bspecs)
        out_sh = (pspecs, ospecs,
                  jax.tree.map(lambda _: NamedSharding(mesh, P()),
                               {"loss": 0, "ce": 0, "moe_aux": 0, "lr": 0,
                                "grad_norm": 0}))
        return fn, args, in_sh, out_sh, (0, 1)

    cache_sds = jax.eval_shape(
        functools.partial(T.init_cache, cfg, b, s, dtype=jnp.bfloat16))
    cspecs = cache_shardings(cfg, mesh, cache_sds, b)

    if shape.kind == "prefill":
        batch = _batch_struct(cfg, b, s)
        fn = make_prefill_step(cfg, impl=impl)
        toks = batch["tokens"]
        tspec = batch_shardings(cfg, mesh, {"t": toks})["t"]
        args = [params_sds, toks, cache_sds]
        in_sh = [pspecs, tspec, cspecs]
        out_sh = (NamedSharding(mesh, P()), cspecs)
        if "extra_embeds" in batch:
            args.append(batch["extra_embeds"])
            in_sh.append(batch_shardings(
                cfg, mesh, {"e": batch["extra_embeds"]})["e"])
        return fn, tuple(args), tuple(in_sh), out_sh, (2,)

    if shape.kind == "decode":
        toks = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        tspec = batch_shardings(cfg, mesh, {"t": toks})["t"]
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        fn = make_decode_step(cfg, impl=impl)
        args = [params_sds, cache_sds, toks, pos]
        in_sh = [pspecs, cspecs, tspec, NamedSharding(mesh, P())]
        out_sh = (NamedSharding(mesh, P()), cspecs)
        if cfg.enc_dec:
            ctx = jax.ShapeDtypeStruct(
                (b, cfg.enc_context_len, cfg.d_model), jnp.bfloat16)
            args.append(ctx)
            in_sh.append(batch_shardings(cfg, mesh, {"c": ctx})["c"])
        return fn, tuple(args), tuple(in_sh), out_sh, (1,)

    raise ValueError(shape.kind)
