"""End-to-end trainer: checkpoint/restart, preemption handling, logging.

Runs the reduced configs on this CPU host end-to-end; the same driver lowers
the full configs on a production mesh (the dry-run proves those compile).

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --ckpt-every 50

The CNN family (googlenet) trains through the execution-plan path:
``--plan concurrent`` lowers the scheduler's co-execution groups to a
``core/plan.py`` Plan (grouped/stacked branch kernels etc.) packed at
forward+backward cost — the custom VJPs co-execute the mirrored grad
CoGroups (``backward_plan``), so ``--plan`` covers the train step's
backward half too.  ``--plan serial`` re-plans with concurrency off
(singleton groups, per-op-fastest algorithms — the paper's serial
baseline), ``--plan none`` is the plain XLA forward:

  PYTHONPATH=src python -m repro.launch.train --arch googlenet --reduced \
      --steps 20 --batch 4 --plan concurrent

Fault tolerance (DESIGN.md §6): atomic checkpoints every N steps including
the data-iterator state; ``--resume`` restarts exactly where a previous run
(or a preempted pod) stopped; SIGTERM triggers a final checkpoint before
exit (the preemption path at datacenter scale).
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_reduced
from repro.data import Pipeline, SyntheticImages, SyntheticLM
from repro.launch import steps as ST
from repro.launch.mesh import make_local_mesh
from repro.models import cnn as CNN
from repro.models import transformer as T
from repro.sharding import specs as SH


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--impl", default="xla", choices=["xla", "pallas"])
    ap.add_argument("--plan", default="none",
                    choices=["none", "serial", "concurrent"],
                    help="CNN-family execution plan: lower the schedule to "
                         "core/plan.py ExecGroups (concurrent), keep it "
                         "serial, or bypass planning (none)")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_local_mesh()
    print(f"[train] {cfg.name}: N={cfg.param_count()/1e6:.2f}M params, "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    is_cnn = getattr(cfg, "family", "lm") == "cnn"
    key = jax.random.PRNGKey(args.seed)
    params = CNN.init_params(cfg, key) if is_cnn else T.init_params(cfg, key)
    tc = ST.train_config_for(cfg)
    opt = ST.make_optimizer(cfg, tc)
    opt = type(opt)(**{**opt.__dict__, "lr": args.lr,
                       "total": args.steps, "warmup": max(args.steps // 20, 1)})
    opt_state = opt.init(params)

    if is_cnn:
        source = SyntheticImages(cfg.img, cfg.num_classes, args.batch,
                                 seed=args.seed)
    else:
        source = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=args.seed)
    pipe = Pipeline(source)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if args.resume and mgr and mgr.latest_step() is not None:
        template = {"params": params, "opt": opt_state,
                    "data": {"step": np.zeros((), np.int64)}}
        state, manifest = mgr.restore(template)
        params, opt_state = state["params"], state["opt"]
        pipe.restore({"step": int(state["data"]["step"])})
        start = manifest["step"]
        print(f"[train] resumed from step {start}")

    if is_cnn:
        if args.impl != "xla":
            print(f"[train] --impl {args.impl} ignored for CNN arch "
                  "(kernel choice comes from the plan)")
        plan = None
        if args.plan != "none":
            # train=True: pack + budget-check groups at fwd+bwd cost —
            # the plan covers the whole training step, not just forward
            plan, _ = CNN.plan_cnn(cfg, args.batch,
                                   concurrent=args.plan == "concurrent",
                                   train=True)
            print(f"[train] plan: modes={plan.mode_counts()} "
                  f"modeled_makespan={plan.makespan * 1e3:.3f} ms")
            bwd = plan.context.get("backward")
            if bwd is not None:
                print(f"[train] backward plan: modes={bwd.mode_counts()} "
                      f"modeled_makespan={bwd.makespan * 1e3:.3f} ms "
                      f"xla_fallbacks={len(bwd.groups_of_mode('xla'))}")
        step_fn = ST.make_cnn_train_step(cfg, opt, plan=plan)
    else:
        if args.plan != "none":
            print(f"[train] --plan {args.plan} ignored for non-CNN arch")
        step_fn = ST.make_train_step(cfg, opt, impl=args.impl, remat=False)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    stop = {"now": False}

    def _sigterm(signum, frame):   # preemption: checkpoint + clean exit
        stop["now"] = True
    signal.signal(signal.SIGTERM, _sigterm)

    def save(step):
        if mgr:
            mgr.save(step, {"params": params, "opt": opt_state,
                            "data": {"step": np.int64(pipe.step)}},
                     extra={"arch": cfg.name})

    losses = []
    t0 = time.time()
    with SH.activations_on(mesh):
        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
            params, opt_state, metrics = jitted(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            if (step + 1) % args.log_every == 0:
                dt = (time.time() - t0) / args.log_every
                print(f"step {step+1:5d} loss={losses[-1]:.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f} ms/step", flush=True)
                t0 = time.time()
            if mgr and (step + 1) % args.ckpt_every == 0:
                save(step + 1)
            if stop["now"]:
                print("[train] SIGTERM -> checkpoint + exit")
                save(step + 1)
                return 0
    if mgr:
        save(args.steps)
    first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-10:])
    print(f"[train] done. loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
