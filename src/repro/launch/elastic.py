"""Elasticity & fault-tolerance runbook + mechanical pieces.

At 1000+ nodes the failure model is: chips/hosts vanish (hardware), pods are
preempted (scheduler), and individual hosts straggle (thermal, NIC).  This
module documents the policy and implements the host-side mechanics that the
trainer composes:

  1. Synchronous SPMD with atomic checkpoints (checkpoint/manager.py) is the
     recovery baseline: any failure -> restart from step N.  Checkpoint
     cadence trades lost work against write bandwidth; at bf16 398B params +
     moments (~2.4 TB) and a parallel FS, a 5-min cadence costs <2% overhead.
  2. ELASTIC RESTART: ``remesh_plan`` maps a checkpoint onto a smaller or
     larger mesh (chips lost, pod added).  Because checkpoints are stored
     unsharded per-leaf, restore = device_put against the new specs — no
     resharding pass.  The data pipeline is step-indexed, so the batch
     stream continues exactly.
  3. STRAGGLERS: synchronous steps bound progress by the slowest chip.  The
     mitigations here: (a) per-host step-time telemetry (``StepTimer``) with
     a p99/median trip-wire to flag hosts for eviction, (b) checkpoint +
     restart without the flagged host (elastic), (c) at the input layer the
     step-indexed pipeline makes host re-assignment trivial (host i of k
     reads shard i — no rendezvous state).
  4. PREEMPTION: SIGTERM -> final checkpoint (wired in launch/train.py).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.launch.mesh import make_production_mesh


@dataclasses.dataclass
class RemeshPlan:
    """From a checkpoint written on ``from_mesh`` to ``to_mesh``."""
    from_shape: dict
    to_shape: dict
    batch_ratio: float        # global-batch rescale if dp size changed
    note: str

    @staticmethod
    def plan(from_multi_pod: bool, to_multi_pod: bool) -> "RemeshPlan":
        # pure topology arithmetic — no device allocation (plans are made
        # on the coordinator before the new mesh exists)
        def shape(multi):
            return ({"pod": 2, "data": 16, "model": 16} if multi
                    else {"data": 16, "model": 16})
        a, b = shape(from_multi_pod), shape(to_multi_pod)
        dp_a = a.get("data", 1) * a.get("pod", 1)
        dp_b = b.get("data", 1) * b.get("pod", 1)
        return RemeshPlan(a, b, dp_b / dp_a,
                          "restore checkpoint with param_specs(new_mesh); "
                          "scale lr or accumulation by batch_ratio")


class StepTimer:
    """Rolling per-step time stats; trips when p99/median exceeds a bound
    (straggler detection at the host level)."""

    def __init__(self, window: int = 50, ratio: float = 2.0):
        self.window = window
        self.ratio = ratio
        self.times: list[float] = []
        self._t0 = None

    def start(self):
        self._t0 = time.time()

    def stop(self) -> float:
        dt = time.time() - self._t0
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        return dt

    @property
    def straggling(self) -> bool:
        if len(self.times) < 10:
            return False
        t = np.array(self.times)
        return float(np.percentile(t, 99)) > self.ratio * float(np.median(t))

    def stats(self) -> dict:
        if not self.times:
            return {}
        t = np.array(self.times)
        return {"median_s": float(np.median(t)),
                "p99_s": float(np.percentile(t, 99)),
                "straggling": self.straggling}
