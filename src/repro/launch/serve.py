"""Serving drivers — transformer decode AND planned-CNN continuous batching.

Two serving paths share this module:

  transformer (``--arch llama3-8b ...``): prefill (cache fill) + decode
  steps (one token per step, greedy) with a KV cache.  The same
  ``decode_step`` lowers at production shapes in the dry-run
  (decode_32k / long_500k cells).

      PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \\
          --reduced --batch 4 --prompt-len 32 --gen 32

  CNN (``--arch googlenet ...``): continuous-batching inference on the
  PLANNED executor — the paper's co-execution thesis applied where Opara
  aims it (small ragged inference batches).  Requests are split into
  chunks of at most ``max_images`` (an oversized request spans several
  dispatches — no image is silently dropped), admitted deadline- and
  size-aware (an EDF anchor plus a greedy fill that minimizes the
  dispatch's ``cost_model.padded_m_factor`` — padding waste, not queue
  order, decides who rides along), padded up to an M-bucket from the
  cost model's ladder (``cost_model.serve_buckets`` — bucket granularity
  is a modeled decision: pow2 image counts, merged where bm-alignment
  makes the padding free), and each bucket dispatches through ONE cached
  plan + offset tables + jitted executable (``core.plan_cache``).  The
  ragged ``valid_images`` operand is a traced i32 scalar, so every
  request mix in a bucket re-enters the same trace; the grouped-family
  kernels — INCLUDING the chained cross-module launch — mask the
  padded-M tail in-kernel (dead M-blocks skipped as no-op waves, live
  tails zero-stored).  A warm request pays zero lowering, zero
  ``_plan_tiles*`` rebuilds and zero re-tracing — the driver warms every
  bucket once, resets the cache counters, and asserts the measured
  stream runs at hit rate 1.0.  Latency is attributed per REQUEST
  (queue wait + dispatch wall, completion of the LAST chunk for split
  requests); p50/p99 are request-level percentiles with the sample
  count reported alongside, and the raw dispatch-wall percentiles keep
  their own ``dispatch_*`` keys (``serve_cnn_metrics`` — the numbers
  ``benchmarks/run.py`` records into BENCH_plan.json).

      PYTHONPATH=src python -m repro.launch.serve --arch googlenet \\
          --reduced --requests 12 --max-images 4
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.sharding import specs as SH

# importlib, not ``from repro.kernels import grouped_matmul``: the
# package re-exports a FUNCTION of that name which shadows the submodule
# attribute.  Module scope, NOT inside dispatch() — the import-machinery
# lookup has no business riding the per-dispatch hot loop.
_gmm = importlib.import_module("repro.kernels.grouped_matmul")


def _bucket_for(n: int, ladder: list[int]) -> int:
    for b in ladder:
        if n <= b:
            return b
    return ladder[-1]


def _split_request(rid: int, imgs, deadline: float, max_images: int):
    """Chunk one request into admission units of <= max_images images.
    Every submitted image lands in exactly one chunk — an oversized
    request spans several dispatches instead of being truncated."""
    return [{"rid": rid, "imgs": imgs[o:o + max_images],
             "deadline": deadline}
            for o in range(0, imgs.shape[0], max_images)]


def _admit(pending, max_images: int, ladder, rows_per_image: int, pmf):
    """Pick the next co-batch from ``pending`` chunks (mutates it).

    EDF anchor: the earliest-deadline chunk always dispatches next — a
    latency guarantee no packing heuristic may trade away.  Fill: among
    chunks that still fit under ``max_images``, greedily admit whichever
    minimizes the resulting dispatch's padded-M factor, stopping when no
    candidate improves on the current factor (a rider that bumps the
    bucket would pay more padding than it removes).  Ties fall to the
    earlier deadline via the stable sort.
    """
    pending.sort(key=lambda c: c["deadline"])
    batch = [pending.pop(0)]
    total = batch[0]["imgs"].shape[0]

    def factor(n):
        return pmf(n * rows_per_image,
                   _bucket_for(n, ladder) * rows_per_image)

    while True:
        cands = [c for c in pending
                 if total + c["imgs"].shape[0] <= max_images]
        if not cands:
            break
        best = min(cands,
                   key=lambda c: factor(total + c["imgs"].shape[0]))
        if factor(total + best["imgs"].shape[0]) > factor(total):
            break
        # identity removal — list.remove would == -compare image arrays
        pending.pop(next(i for i, c in enumerate(pending) if c is best))
        batch.append(best)
        total += best["imgs"].shape[0]
    return batch, total


def serve_cnn_metrics(cfg, *, max_images: int = 4, num_requests: int = 12,
                      seed: int = 0, chain_modules: bool = True,
                      interpret=None) -> dict:
    """Run the continuous-batching loop on ``cfg`` and return metrics.

    Synthetic seeded request stream: each request carries
    1..max_images+1 images (the +1 deliberately exercises the oversized
    path) and a deadline drawn from the same rng.  Requests split into
    <= max_images chunks, co-batches form by EDF-anchored
    padded-M-factor packing (``_admit``), and each dispatch rides the
    bucket's cached plan.  Warmup dispatches one batch per ladder bucket
    (populating plan cache, device offset tables and jit traces), then
    counters reset and the measured stream must be all cache hits.

    Latency is per REQUEST: completion of its last chunk minus
    submission, i.e. queue wait + dispatch wall.  ``p50_ms``/``p99_ms``
    are request-level (``latency_samples`` counts them); the dispatch
    walls keep their own ``dispatch_p50_ms``/``dispatch_p99_ms``.
    """
    from repro.core import cost_model as CM
    from repro.core import plan_cache
    from repro.launch.steps import make_cnn_serve_step
    from repro.models import cnn as CNN

    h, w, c = cfg.img
    ladder = CM.serve_buckets(max_images, h * w)
    rng = np.random.default_rng(seed)
    params = CNN.init_params(cfg, jax.random.PRNGKey(seed))

    def executable_for(bucket: int):
        entry = plan_cache.cached_cnn_plan(cfg, bucket,
                                           chain_modules=chain_modules)
        if entry.executable is None:
            step = make_cnn_serve_step(cfg, entry.plan, interpret=interpret)
            entry.executable = jax.jit(step)
        return entry

    def dispatch(arrs):
        n = sum(r.shape[0] for r in arrs)
        bucket = _bucket_for(n, ladder)
        entry = executable_for(bucket)
        imgs = np.zeros((bucket, h, w, c), np.float32)
        off = 0
        for r in arrs:
            imgs[off:off + r.shape[0]] = r
            off += r.shape[0]
        t0 = time.perf_counter()
        # record which device offset tables this entry's executable
        # touches and pin them to the entry (first dispatch only): the
        # plan cache's LRU eviction unpins them, so table memory tracks
        # LIVE entries, not everything ever traced
        with _gmm._device_table.recording() as touched:
            logits = entry.executable(params, jnp.asarray(imgs),
                                      jnp.int32(n))
            jax.block_until_ready(logits)
        plan_cache.attach_tables(entry, touched)
        lat = time.perf_counter() - t0
        return logits, lat, bucket, n

    # request stream: image counts in [1, max_images + 1] — the +1 makes
    # oversized requests (must split, never truncate) part of every run
    sizes = rng.integers(1, max_images + 2, size=num_requests)
    deadlines = rng.uniform(0.05, 0.5, size=num_requests)
    requests = [rng.normal(size=(int(s), h, w, c)).astype(np.float32)
                for s in sizes]

    # warmup: one dispatch per bucket — populates every cache layer
    for b in ladder:
        dispatch([np.zeros((b, h, w, c), np.float32)])
    plan_cache.reset()          # counters only; entries stay warm

    pending = []
    for rid, (r, dl) in enumerate(zip(requests, deadlines)):
        pending.extend(_split_request(rid, r, float(dl), max_images))
    chunks_left = {rid: sum(1 for c_ in pending if c_["rid"] == rid)
                   for rid in range(num_requests)}
    submitted_images = int(sum(sizes))

    dispatch_s, waste = [], []
    done_at: dict[int, float] = {}
    served_images = 0
    t_start = time.perf_counter()
    while pending:
        batch, total = _admit(pending, max_images, ladder, h * w,
                              CM.padded_m_factor)
        _, lat, bucket, n = dispatch([c_["imgs"] for c_ in batch])
        t_end = time.perf_counter()
        dispatch_s.append(lat)
        served_images += n
        waste.append(CM.padded_m_factor(n * h * w, bucket * h * w))
        for c_ in batch:
            chunks_left[c_["rid"]] -= 1
            if chunks_left[c_["rid"]] == 0:
                done_at[c_["rid"]] = t_end
    wall = time.perf_counter() - t_start

    assert len(done_at) == num_requests and served_images == \
        submitted_images, "a submitted image never reached a launch"
    stats = plan_cache.stats()
    assert stats["misses"] == 0 and stats["hit_rate"] == 1.0, (
        f"warm serving path re-lowered a plan: {stats}")
    req_ms = np.asarray([done_at[r] - t_start
                         for r in range(num_requests)]) * 1e3
    disp_ms = np.asarray(dispatch_s) * 1e3
    return {
        "arch": cfg.name,
        "buckets": ladder,
        "requests": int(num_requests),
        "dispatches": len(dispatch_s),
        "images": int(served_images),
        "images_submitted": submitted_images,
        "qps": float(num_requests / wall),
        "images_per_s": float(served_images / wall),
        # request-level latency: queue wait + dispatch wall, last chunk
        # for split requests
        "p50_ms": float(np.percentile(req_ms, 50)),
        "p99_ms": float(np.percentile(req_ms, 99)),
        "latency_samples": int(req_ms.size),
        "dispatch_p50_ms": float(np.percentile(disp_ms, 50)),
        "dispatch_p99_ms": float(np.percentile(disp_ms, 99)),
        "padded_m_factor_mean": float(np.mean(waste)),
        "plan_cache": stats,
        # per-ladder planlint coverage: a bucket's entry is verified when
        # its lowering ran analysis.verify_plan with zero findings
        # (pytest / REPRO_PLANLINT=1 — see plan._verify_requested)
        "plans_verified": sum(
            1 for b in ladder
            if plan_cache.cached_cnn_plan(
                cfg, b, chain_modules=chain_modules).verified),
    }


def _serve_cnn(args) -> int:
    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    m = serve_cnn_metrics(cfg, max_images=args.max_images,
                          num_requests=args.requests, seed=args.seed)
    print(f"[serve] {m['arch']}: {m['requests']} requests "
          f"({m['images']} images) in {m['dispatches']} dispatches, "
          f"buckets {m['buckets']}")
    print(f"[serve] qps {m['qps']:.2f} ({m['images_per_s']:.2f} img/s), "
          f"request p50 {m['p50_ms']:.1f} ms / p99 {m['p99_ms']:.1f} ms "
          f"(n={m['latency_samples']}), dispatch p50 "
          f"{m['dispatch_p50_ms']:.1f} ms, padded-M waste "
          f"x{m['padded_m_factor_mean']:.2f}")
    print(f"[serve] plan cache: {m['plan_cache']}")
    return 0


def _serve_transformer(args) -> int:
    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_local_mesh()
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)

    b = args.batch
    total = args.prompt_len + args.gen
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (b, args.prompt_len), 0, cfg.vocab)
    cache = T.init_cache(cfg, b, total)
    extra = None
    context = None
    if cfg.frontend == "frame":
        extra = jax.random.normal(
            jax.random.fold_in(key, 2),
            (b, cfg.enc_context_len, cfg.d_model)) * 0.02
    if cfg.frontend == "patch":
        extra = jax.random.normal(
            jax.random.fold_in(key, 2),
            (b, cfg.frontend_len, cfg.d_model)) * 0.02

    prefill = jax.jit(lambda p, t, c, e: T.prefill(p, cfg, t, c,
                                                   extra_embeds=e))
    decode = jax.jit(lambda p, c, t, pos, ctx: T.decode_step(
        p, cfg, c, t, pos, context=ctx))

    with SH.activations_on(mesh):
        if cfg.enc_dec:
            context = jax.jit(
                lambda p, e: T._encoder(cfg, p, e))(params, extra)
            extra_for_prefill = extra
        else:
            extra_for_prefill = extra
        t0 = time.time()
        logits, cache = prefill(params, prompts, cache, extra_for_prefill)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        t_prefill = time.time() - t0
        out = [tok]
        t0 = time.time()
        for i in range(args.gen - 1):
            pos = jnp.int32(args.prompt_len + i)
            logits, cache = decode(params, cache, tok, pos, context)
            tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)[:, None]
            out.append(tok)
        dt = time.time() - t0
        toks = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"[serve] {cfg.name}: prefill {args.prompt_len} tok in "
          f"{t_prefill*1e3:.0f} ms; {args.gen-1} decode steps at "
          f"{dt/(args.gen-1)*1e3:.1f} ms/tok (batch {b})")
    print("[serve] sample:", toks[0, :16].tolist())
    assert toks.shape == (b, args.gen) and (toks >= 0).all() \
        and (toks < cfg.vocab).all()
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=12,
                    help="CNN path: synthetic request count")
    ap.add_argument("--max-images", type=int, default=4,
                    help="CNN path: max images per request/co-batch")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    if getattr(cfg, "family", "") == "cnn":
        return _serve_cnn(args)
    return _serve_transformer(args)


if __name__ == "__main__":
    sys.exit(main())
