"""Batched serving loop: continuous batched decode with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --reduced \
      --batch 4 --prompt-len 32 --gen 32

Serving path = prefill (cache fill) + decode steps (one token per step,
greedy).  The same ``decode_step`` lowers at production shapes in the
dry-run (decode_32k / long_500k cells).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.sharding import specs as SH


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_local_mesh()
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, key)

    b = args.batch
    total = args.prompt_len + args.gen
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (b, args.prompt_len), 0, cfg.vocab)
    cache = T.init_cache(cfg, b, total)
    extra = None
    context = None
    if cfg.frontend == "frame":
        extra = jax.random.normal(
            jax.random.fold_in(key, 2),
            (b, cfg.enc_context_len, cfg.d_model)) * 0.02
    if cfg.frontend == "patch":
        extra = jax.random.normal(
            jax.random.fold_in(key, 2),
            (b, cfg.frontend_len, cfg.d_model)) * 0.02

    prefill = jax.jit(lambda p, t, c, e: T.prefill(p, cfg, t, c,
                                                   extra_embeds=e))
    decode = jax.jit(lambda p, c, t, pos, ctx: T.decode_step(
        p, cfg, c, t, pos, context=ctx))

    with SH.activations_on(mesh):
        if cfg.enc_dec:
            context = jax.jit(
                lambda p, e: T._encoder(cfg, p, e))(params, extra)
            extra_for_prefill = extra
        else:
            extra_for_prefill = extra
        t0 = time.time()
        logits, cache = prefill(params, prompts, cache, extra_for_prefill)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        t_prefill = time.time() - t0
        out = [tok]
        t0 = time.time()
        for i in range(args.gen - 1):
            pos = jnp.int32(args.prompt_len + i)
            logits, cache = decode(params, cache, tok, pos, context)
            tok = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)[:, None]
            out.append(tok)
        dt = time.time() - t0
        toks = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"[serve] {cfg.name}: prefill {args.prompt_len} tok in "
          f"{t_prefill*1e3:.0f} ms; {args.gen-1} decode steps at "
          f"{dt/(args.gen-1)*1e3:.1f} ms/tok (batch {b})")
    print("[serve] sample:", toks[0, :16].tolist())
    assert toks.shape == (b, args.gen) and (toks >= 0).all() \
        and (toks < cfg.vocab).all()
    return 0


if __name__ == "__main__":
    sys.exit(main())
