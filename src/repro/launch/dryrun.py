import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
                                         "--xla_force_host_platform_device_count=512")
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * single-pod (data=16, model=16) = 256 chips,
  * multi-pod (pod=2, data=16, model=16) = 512 chips,
for every assigned architecture x its shape set.  Emits per-cell JSON with
memory_analysis, cost_analysis and the HLO collective inventory that
§Roofline consumes.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import input_specs
from repro.roofline import xla_cost_analysis
from repro.sharding import specs as SH

LM_ARCHS = tuple(a for a in ARCHS if a != "googlenet")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"^\s*(?:%|ROOT\s+%?)?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_GROUP_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUP_RE2 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum bytes over one HLO type (possibly a tuple)."""
    total = 0
    for m in re.finditer(r"(\w+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_inventory(hlo_text: str) -> dict:
    """Per-kind result-bytes + ring-model wire bytes per chip.

    Ring model (documented in EXPERIMENTS.md §Roofline):
      all-gather:        wire = (g-1)/g * result_bytes
      reduce-scatter:    wire = (g-1)   * result_bytes   (operand = g*result)
      all-reduce:        wire = 2(g-1)/g * result_bytes
      all-to-all:        wire = (g-1)/g * result_bytes
      collective-permute: wire = result_bytes
    g = replica group size parsed per op (fallback: 2).
    """
    inv = {}
    wire_total = 0.0
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        rb = _shape_bytes(type_str)
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start():line_end if line_end > 0 else None]
        g = 2
        gm = _GROUP_RE.search(line)
        if gm:
            g = max(len(gm.group(1).split(",")), 1)
        else:
            gm2 = _GROUP_RE2.search(line)
            if gm2:
                g = max(int(gm2.group(2)), 1)
        if kind == "all-gather":
            wire = (g - 1) / g * rb
        elif kind == "reduce-scatter":
            wire = (g - 1) * rb
        elif kind == "all-reduce":
            wire = 2 * (g - 1) / g * rb
        elif kind == "all-to-all":
            wire = (g - 1) / g * rb
        else:
            wire = rb
        d = inv.setdefault(kind, {"count": 0, "result_bytes": 0.0,
                                  "wire_bytes": 0.0})
        d["count"] += 1
        d["result_bytes"] += rb
        d["wire_bytes"] += wire
        wire_total += wire
    inv["total_wire_bytes"] = wire_total
    return inv


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save_hlo: str | None = None, perf: dict | None = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "chips": mesh.size,
           "perf": sorted((perf or {}).keys()),
           "params": cfg.param_count(),
           "active_params": cfg.active_param_count()}
    t0 = time.time()
    with SH.activations_on(mesh, **(perf or {})):
        fn, args, in_sh, out_sh, donate = input_specs(cfg, shape, mesh)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
    mem = compiled.memory_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                rec[k] = int(v)
    cost = xla_cost_analysis(compiled)
    if cost:
        rec["cost_flops"] = float(cost.get("flops", -1))
        rec["cost_bytes"] = float(cost.get("bytes accessed", -1))
        rec["cost_transcendentals"] = float(cost.get("transcendentals", -1))
    hlo = compiled.as_text()
    rec["hlo_chars"] = len(hlo)
    rec["collectives"] = collective_inventory(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    del hlo, compiled, lowered
    return rec


def cells_for(arch: str) -> list[str]:
    cfg = get_config(arch)
    out = []
    for name in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        if name == "long_500k" and not cfg.sub_quadratic:
            continue   # skipped per assignment: pure full-attention archs
        out.append(name)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--perf", default="",
                    help="comma-separated perf options: seq_shard,"
                         "dp_over_model,causal_skip,dots_remat")
    args = ap.parse_args()
    perf = {k: True for k in args.perf.split(",") if k}
    perf_tag = ("__" + "_".join(sorted(perf))) if perf else ""

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    if args.all:
        todo = [(a, s) for a in LM_ARCHS for s in cells_for(a)]
    else:
        assert args.arch, "--arch or --all"
        shapes = [args.shape] if args.shape else cells_for(args.arch)
        todo = [(args.arch, s) for s in shapes]

    n_fail = 0
    for arch, shape in todo:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'multi' if mp else 'single'}{perf_tag}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print(f"[skip] {tag} (cached)", flush=True)
                continue
            print(f"[cell] {tag} ...", flush=True)
            try:
                hlo_path = (os.path.join(args.out, tag + ".hlo.txt")
                            if args.save_hlo else None)
                rec = run_cell(arch, shape, mp, save_hlo=hlo_path, perf=perf)
                rec["ok"] = True
            except Exception as e:
                rec = {"arch": arch, "shape": shape,
                       "mesh": "multi" if mp else "single", "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-4000:]}
                n_fail += 1
                print(f"[FAIL] {tag}: {rec['error']}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec.get("ok"):
                coll = rec["collectives"].get("total_wire_bytes", 0)
                print(f"[ok]   {tag} lower={rec['lower_s']}s "
                      f"compile={rec['compile_s']}s "
                      f"flops/dev={rec.get('cost_flops', -1):.3g} "
                      f"wire/dev={coll:.3g}B", flush=True)
    print(f"done. failures={n_fail}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
