"""Production mesh definitions (functions only — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_local_mesh():
    """Whatever this host has (tests / CPU examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), axis_types=_auto(2))
