"""Production mesh definitions (functions only — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def make_mesh(shape, names):
    """``jax.make_mesh`` across jax versions.

    Newer jax wants explicit ``axis_types=(AxisType.Auto, ...)`` to keep the
    mesh out of explicit-sharding mode; older releases (< 0.5) have neither
    the kwarg nor ``jax.sharding.AxisType``.  Every mesh in this repo (and in
    the test subprocesses) goes through here so version skew lives in one
    place.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, names,
                                 axis_types=(axis_type.Auto,) * len(names))
        except TypeError:
            pass
    return jax.make_mesh(shape, names)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh():
    """Whatever this host has (tests / CPU examples)."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))
