"""Atomic checkpoint manager: save / resume / elastic remesh.

Fault-tolerance contract (DESIGN.md §6):
  * atomic: write to ``step_N.tmp/`` then rename — a crash mid-write never
    corrupts the latest checkpoint;
  * complete: params + optimizer state + data-iterator state + step + a
    manifest (tree structure, shapes, dtypes, mesh metadata);
  * elastic: ``restore(..., sharding=specs_for_new_mesh)`` reloads a
    checkpoint written on mesh A onto any mesh B — arrays are saved
    unsharded (gathered per-leaf) and re-placed with jax.device_put against
    the new specs, so pod-count changes and chip-failure reshapes are a
    restore, not a migration;
  * bounded: keeps the newest ``keep`` checkpoints.

Storage is one ``.npz`` per checkpoint plus a JSON manifest (no external
checkpoint libs in this environment; the layout mirrors what a
tensorstore-backed store would hold per shard).
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif tree is None:
        pass
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/")
                for k, v in template.items()}
    if isinstance(template, (list, tuple)):
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/")
                for i, v in enumerate(template)]
        return type(template)(vals)
    if template is None:
        return None
    return flat[prefix[:-1]]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: dict, extra: dict | None = None):
        """state: pytree of jax/np arrays; extra: small JSON-able dict."""
        flat = _flatten(state)
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "extra": extra or {},
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in arrays.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)           # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- load ---------------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template, step: int | None = None,
                sharding=None) -> tuple[dict, dict]:
        """Restore into ``template`` structure.  ``sharding``: optional
        pytree of NamedSharding (same structure) for elastic re-placement
        onto a (possibly different) mesh."""
        step = self.latest_step() if step is None else step
        assert step is not None, f"no checkpoints in {self.dir}"
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        flat = {k: data[k] for k in data.files}
        state = _unflatten_into(template, flat)
        if sharding is not None:
            flat_sh = _flatten(sharding)
            flat_st = _flatten(state)
            placed = {k: jax.device_put(v, flat_sh[k]) if k in flat_sh else v
                      for k, v in flat_st.items()}
            state = _unflatten_into(template, placed)
        else:
            state = jax.tree.map(jnp.asarray, state)
        return state, manifest
