"""AdamW (pure pytree), cosine schedule, global-norm clipping.

Moment dtype is configurable: ``bfloat16`` moments are the C4 tradeoff that
lets the 398B config fit a 256-chip pod (DESIGN.md §8).  States inherit the
param sharding (ZeRO comes from the param specs, not from this module).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def cosine_schedule(step, *, lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return lr * warm * cos


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gn


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup: int = 100
    total: int = 1000
    clip_norm: float = 1.0
    state_dtype: str = "float32"

    def init(self, params):
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = cosine_schedule(step, lr=self.lr, warmup=self.warmup,
                             total=self.total)
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * delta
            return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                    v_new.astype(v.dtype))

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"step": step, "m": new_m, "v": new_v}, \
            {"lr": lr, "grad_norm": gnorm}
