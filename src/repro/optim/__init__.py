from repro.optim.adamw import AdamW, cosine_schedule, clip_by_global_norm  # noqa: F401
from repro.optim.compression import compress_int8, decompress_int8, ErrorFeedback  # noqa: F401
