"""Int8 error-feedback gradient compression (cross-pod DP all-reduce).

At 2-pod scale the DCN gradient all-reduce is the slowest collective; int8
block quantization cuts its bytes 4x (fp32) / 2x (bf16).  Error feedback
(residual carried to the next step) keeps convergence — standard 1-bit
Adam / PowerSGD-family practice.  Applied only on the ``pod`` axis; intra-
pod reductions stay full precision.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def compress_int8(x: jax.Array):
    """Per-block symmetric int8 quantization: returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def decompress_int8(q, scale, shape, dtype):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


class ErrorFeedback:
    """Stateless helpers; the residual rides in the optimizer state."""

    @staticmethod
    def init(params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)

    @staticmethod
    def apply(grads, residual):
        """Returns (quantize-roundtripped grads, new residual)."""
        def one(g, r):
            gf = g.astype(jnp.float32) + r.astype(jnp.float32)
            q, s = compress_int8(gf)
            deq = decompress_int8(q, s, g.shape, jnp.float32)
            return deq.astype(g.dtype), (gf - deq).astype(jnp.bfloat16)
        out = jax.tree.map(one, grads, residual)
        new_g = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_r = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_g, new_r
