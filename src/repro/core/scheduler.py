"""Ready-queue co-execution scheduling (paper C5).

"Selecting independent operations from the ready queue for concurrent
execution is a challenging scheduling problem that highly depends on the
network topology and resource utilization of operations."  This module is
that scheduler: Kahn's ready queue + list-scheduling by critical path,
packing ready ops into co-execution groups when (a) combined workspace and
VMEM fit the budgets and (b) the modeled co-execution makespan beats serial
execution.  Algorithm choice inside each group delegates to the
concurrency-aware selector.

A ``Schedule`` is a *decision*, not an execution: ``core/plan.py::lower``
turns it into an executable Plan (stacked / fused / spatial / serial / xla
per group) — without that lowering the co-execution choices never reach a
kernel, which is precisely the framework flaw the paper documents.
"""
from __future__ import annotations

import dataclasses
import functools

from repro.core import cost_model as cm
from repro.core import selector as sel
from repro.core.graph import OpGraph


@dataclasses.dataclass
class CoGroup:
    ops: list[str]
    algorithms: dict[str, str]
    time: float                      # modeled group makespan
    serialized: bool = False         # True if budgets forced serial fallback


@dataclasses.dataclass
class Schedule:
    groups: list[CoGroup]

    @property
    def makespan(self) -> float:
        return sum(g.time for g in self.groups)

    @property
    def algorithms(self) -> dict[str, str]:
        out: dict[str, str] = {}
        for g in self.groups:
            out.update(g.algorithms)
        return out


def schedule(graph: OpGraph, *, max_group: int = 4,
             hbm_budget: float = cm.HBM_BYTES * 0.25,
             vmem_budget: float = cm.VMEM_BYTES,
             concurrent: bool = True, train: bool = False) -> Schedule:
    """List-schedule the DAG into co-execution groups.

    concurrent=False reproduces the serial baseline (every op its own group,
    per-op-fastest algorithm) — the framework behaviour the paper critiques.

    train=True packs for the whole training step: candidate groups are
    judged (and CoGroup times recorded) at forward PLUS backward cost —
    the grad CoGroup mirrors the forward packing (the VJP of a grouped
    group launches the grouped dx/dw kernels), so a group only forms when
    co-execution wins in both directions AND each direction's launch fits
    the C2 budgets on its own (matching ``plan.lower(train=True)``).
    Backward pricing comes from ``cost_model.group_execution_time_bwd``
    over ``gemm_shape_bwd``.
    """

    @functools.cache
    def bwd_serial(name: str) -> float:
        # memoized: the greedy packer re-prices the same op across
        # O(ready * max_group) candidate extensions
        op = graph.ops[name]
        return sum(p.time
                   for p in cm.backward_profiles(op, cm.best_algorithm(op)[0]))
    fastest = sel.select_fastest(graph)
    prio = graph.critical_path_weights(
        lambda op: fastest.profiles[op.name].time)

    indeg = {n: len(graph.pred[n]) for n in graph.ops}
    ready = sorted([n for n, d in indeg.items() if d == 0],
                   key=lambda n: -prio[n])
    groups: list[CoGroup] = []

    while ready:
        pool_ready = [n for n in ready
                      if graph.ops[n].kind == "maxpool"]
        if not concurrent:
            chosen = [ready.pop(0)]
        elif pool_ready:
            # Pooling primitives launch immediately as singletons: they
            # gate the fork's GEMM branches (draining them first exposes
            # the full branch width to the packer — else the pool-proj
            # conv surfaces one level late and misses its quad), and no
            # co-execution kernel runs a reduce_window — a maxpool's
            # co-execution story is ABSORPTION into the consuming grouped
            # launch, decided at lowering (plan._absorb_pools), never XLA
            # interleave.
            chosen = [pool_ready[0]]
            ready.remove(pool_ready[0])
        else:
            # Greedy pack: seed with the most critical ready op, then add
            # ready ops while the modeled group time improves on serial and
            # budgets hold.
            chosen = [ready.pop(0)]
            i = 0
            while i < len(ready) and len(chosen) < max_group:
                cand = chosen + [ready[i]]
                ops = [graph.ops[n] for n in cand]
                algs, _ = sel.select_for_group(ops, hbm_budget, vmem_budget)
                t_serial = sum(
                    cm.best_algorithm(graph.ops[n])[1] for n in cand)
                profs = [cm.profile(graph.ops[n], algs[n]) for n in cand]
                # Judge the candidate at the mode a kernel can actually
                # realize (grouped/stacked/fused vs XLA interleave), not at
                # the ideal co-execution overlap: ragged GEMM branches keep
                # their full win (grouped has no padding-waste term) while
                # heterogeneous groups stop looking better than they run.
                _, t_group = cm.group_execution_time(ops, profs)
                if train:
                    t_serial += sum(bwd_serial(n) for n in cand)
                    t_group += cm.group_execution_time_bwd(ops, algs)[1]
                feasible = sel._group_feasible(profs, hbm_budget, vmem_budget)
                if train and feasible:
                    # mirror lower(train=True): the backward launch must
                    # fit the budgets on its own, or the lowered plan
                    # demotes the group this packing relied on
                    feasible = sel._group_feasible(
                        [p for op in ops
                         for p in cm.backward_profiles(op, algs[op.name])],
                        hbm_budget, vmem_budget)
                if feasible and t_group < t_serial * 0.98:
                    chosen = cand
                    ready.pop(i)
                else:
                    i += 1
        ops = [graph.ops[n] for n in chosen]
        algs, _ = sel.select_for_group(ops, hbm_budget, vmem_budget)
        profs = [cm.profile(graph.ops[n], algs[n]) for n in chosen]
        # Record the realizable-mode makespan (lower() re-derives the mode
        # itself — budgets and the mesh can still override it there).
        _, t = cm.group_execution_time(ops, profs)
        if train:
            t += cm.group_execution_time_bwd(ops, algs)[1]
        serialized = (len(chosen) > 1 and not (
            sel._group_feasible(profs, hbm_budget, vmem_budget)
            and (not train or sel._group_feasible(
                [p for op in ops
                 for p in cm.backward_profiles(op, algs[op.name])],
                hbm_budget, vmem_budget))))
        if serialized:
            t = cm.serial_time(profs)
            if train:
                t += sum(bwd_serial(n) for n in chosen)
        groups.append(CoGroup(chosen, algs, t, serialized))
        # retire
        for n in chosen:
            for s in sorted(graph.succ[n]):
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        ready.sort(key=lambda n: -prio[n])
    return Schedule(groups)


def compare_policies(graph: OpGraph, **kw) -> dict:
    """The paper's experiment: serial/fastest vs concurrent/complementary."""
    serial = schedule(graph, concurrent=False, **kw)
    conc = schedule(graph, concurrent=True, **kw)
    return {
        "serial_makespan": serial.makespan,
        "concurrent_makespan": conc.makespan,
        "speedup": serial.makespan / max(conc.makespan, 1e-12),
        "serial": serial,
        "concurrent": conc,
    }
