"""Persistent plan + offset-table + executable cache for serving.

A warm serving request must pay ZERO plan lowering, ZERO ``_plan_tiles*``
offset-table rebuilds and ZERO re-tracing: everything shape-dependent is
keyed once per (graph fingerprint, M-bucket, dtype, backend, train,
fuse/chain flags) and reused for every later request that lands in the
same bucket.  The three cached layers and who provides them:

  lowered plan         — ``PlanCacheEntry.plan`` (this module): the
                         graph->schedule->ExecGroup lowering of
                         ``models.cnn.plan_cnn``, the expensive pure-python
                         pass a request must never re-run.
  device offset tables — ``kernels.grouped_matmul._device_table``'s
                         lru_cache: the ``_plan_tiles*`` builders key on
                         (builder, block counts), which the cached plan
                         pins, so a warm launch reuses the SAME
                         device-resident array (object identity — the
                         regression test asserts it).
  traced executable    — ``PlanCacheEntry.executable``: the jitted
                         bucket-shaped forward the serving driver stores on
                         the entry after its first trace; later mixes in
                         the bucket re-enter the same trace because the
                         ragged ``valid_images`` operand is a TRACED i32
                         scalar, not a python constant.

``graph_fingerprint`` hashes the full op-DAG structure (names, kinds,
params, dtype widths, edges) — two configs with identical topology but
different conv widths fingerprint differently, and a cfg edit invalidates
naturally because the key changes.  Hit/miss counters back the CI gate
that asserts a warmed-up serve loop runs at hit rate 1.0.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any

from repro.core.graph import OpGraph


def graph_fingerprint(graph: OpGraph) -> str:
    """Stable sha256 over the op-DAG: per-op (name, kind, sorted params,
    dtype_bytes, sorted preds), ops in sorted name order.  Pure structure
    — no arrays, no python ids — so equal-architecture graphs built in
    different processes fingerprint identically."""
    h = hashlib.sha256()
    for name in sorted(graph.ops):
        op = graph.ops[name]
        h.update(repr((op.name, op.kind, tuple(sorted(op.params)),
                       op.dtype_bytes,
                       tuple(sorted(graph.pred[name])))).encode())
    return h.hexdigest()


def plan_key(fingerprint: str, bucket: int, dtype, backend: str, *,
             train: bool = False, fuse_concat: bool = True,
             fuse_pool: bool = True, chain_modules: bool = False) -> tuple:
    """The cache key: everything the lowered plan, the offset tables and
    the traced executable depend on.  ``bucket`` is the padded image
    count (M-bucket), which fixes every per-group M and hence every
    ``_plan_tiles*`` table shape."""
    return (fingerprint, int(bucket), str(dtype), backend, bool(train),
            bool(fuse_concat), bool(fuse_pool), bool(chain_modules))


@dataclasses.dataclass
class PlanCacheEntry:
    plan: Any                      # core.plan.Plan (lowered for `bucket`)
    schedule: Any                  # the scheduler output it lowered from
    fingerprint: str
    bucket: int
    executable: Any = None         # jitted serve step, set by the driver


_CACHE: dict[tuple, PlanCacheEntry] = {}
_HITS = 0
_MISSES = 0


def stats() -> dict:
    total = _HITS + _MISSES
    return {"hits": _HITS, "misses": _MISSES, "entries": len(_CACHE),
            "hit_rate": (_HITS / total) if total else 0.0}


def reset(clear_entries: bool = False) -> None:
    """Zero the counters; ``clear_entries`` also drops the cached plans
    (the warmup boundary in the serve driver resets counters ONLY, so the
    post-warmup hit rate is measured against a populated cache)."""
    global _HITS, _MISSES
    _HITS = _MISSES = 0
    if clear_entries:
        _CACHE.clear()


def cached_cnn_plan(cfg, bucket: int, *, dtype="float32", backend=None,
                    train: bool = False, fuse_concat: bool = True,
                    fuse_pool: bool = True,
                    chain_modules: bool = False) -> PlanCacheEntry:
    """The serving entry point: (cfg, M-bucket) -> cached PlanCacheEntry.

    ``build_graph`` runs on every call — it is cheap pure-python shape
    bookkeeping and produces the fingerprint that keys the cache; the
    expensive ``plan_cnn`` lowering (schedule + lower + backward_plan +
    budget checks) runs only on a miss.  The entry's plan carries
    ``context["batch"] == bucket``, which is what the ragged
    ``valid_images`` executor divides by.
    """
    global _HITS, _MISSES
    import jax
    from repro.models import cnn  # lazy: mirrors core.plan.execute_plan

    backend = jax.default_backend() if backend is None else backend
    fp = graph_fingerprint(cnn.build_graph(cfg, int(bucket)))
    key = plan_key(fp, bucket, dtype, backend, train=train,
                   fuse_concat=fuse_concat, fuse_pool=fuse_pool,
                   chain_modules=chain_modules)
    entry = _CACHE.get(key)
    if entry is not None:
        _HITS += 1
        return entry
    _MISSES += 1
    plan, sch = cnn.plan_cnn(cfg, int(bucket), train=train,
                             fuse_concat=fuse_concat, fuse_pool=fuse_pool,
                             chain_modules=chain_modules)
    entry = PlanCacheEntry(plan=plan, schedule=sch, fingerprint=fp,
                           bucket=int(bucket))
    _CACHE[key] = entry
    return entry
