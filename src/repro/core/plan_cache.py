"""Persistent plan + offset-table + executable cache for serving.

A warm serving request must pay ZERO plan lowering, ZERO ``_plan_tiles*``
offset-table rebuilds and ZERO re-tracing: everything shape-dependent is
keyed once per (graph fingerprint, M-bucket, dtype, backend, train,
fuse/chain flags) and reused for every later request that lands in the
same bucket.  The three cached layers and who provides them:

  lowered plan         — ``PlanCacheEntry.plan`` (this module): the
                         graph->schedule->ExecGroup lowering of
                         ``models.cnn.plan_cnn`` (or ``plan.lower_moe``
                         for MoE layers), the expensive pure-python pass
                         a request must never re-run.
  device offset tables — ``kernels.grouped_matmul._device_table``'s
                         registry: the ``_plan_tiles*`` builders key on
                         (builder, block counts); the keys a plan's
                         executable touches are recorded on first
                         execution and PINNED to the entry
                         (``attach_tables``), so a warm launch reuses the
                         SAME device-resident array (object identity —
                         the regression test asserts it) and a table
                         outlives the registry's own LRU bound exactly as
                         long as a live entry needs it.
  traced executable    — ``PlanCacheEntry.executable``: the jitted
                         bucket-shaped forward the serving driver stores on
                         the entry after its first trace; later mixes in
                         the bucket re-enter the same trace because the
                         ragged ``valid_images`` operand is a TRACED i32
                         scalar, not a python constant.  That includes the
                         chained cross-module launch: its offset table is
                         bucket-shaped and m_valid-independent (liveness
                         rides a prefetched mrow vector), so one pinned
                         table + one trace serve every masked request mix.

The cache itself is LRU-bounded (``CAPACITY`` entries — the transformer
zoo's MoE configs make one-cfg growth assumptions wrong): a hit refreshes
recency, an insert past capacity evicts the least-recent entry, counts it
in ``stats()["evictions"]``, and UNPINS the evicted entry's device tables
so only live entries hold table memory.

``graph_fingerprint`` hashes the full op-DAG structure (names, kinds,
params, dtype widths, edges) — two configs with identical topology but
different conv widths fingerprint differently, and a cfg edit invalidates
naturally because the key changes.  Hit/miss counters back the CI gate
that asserts a warmed-up serve loop runs at hit rate 1.0.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any

from repro.core.graph import OpGraph

#: LRU bound on cached entries.  Tests/benchmarks may rebind; the serving
#: ladder (a handful of buckets x a few cfgs) sits far below it, so
#: eviction only triggers under genuine zoo churn.
CAPACITY = 32


def graph_fingerprint(graph: OpGraph) -> str:
    """Stable sha256 over the op-DAG: per-op (name, kind, sorted params,
    dtype_bytes, sorted preds), ops in sorted name order.  Pure structure
    — no arrays, no python ids — so equal-architecture graphs built in
    different processes fingerprint identically."""
    h = hashlib.sha256()
    for name in sorted(graph.ops):
        op = graph.ops[name]
        h.update(repr((op.name, op.kind, tuple(sorted(op.params)),
                       op.dtype_bytes,
                       tuple(sorted(graph.pred[name])))).encode())
    return h.hexdigest()


def plan_key(fingerprint: str, bucket: int, dtype, backend: str, *,
             train: bool = False, fuse_concat: bool = True,
             fuse_pool: bool = True, chain_modules: bool = False) -> tuple:
    """The cache key: everything the lowered plan, the offset tables and
    the traced executable depend on.  ``bucket`` is the padded image
    count (M-bucket) — or the batch for MoE plans — which fixes every
    per-group M and hence every ``_plan_tiles*`` table shape."""
    return (fingerprint, int(bucket), str(dtype), backend, bool(train),
            bool(fuse_concat), bool(fuse_pool), bool(chain_modules))


@dataclasses.dataclass
class PlanCacheEntry:
    plan: Any                      # core.plan.Plan (lowered for `bucket`)
    schedule: Any                  # the scheduler output it lowered from
    fingerprint: str
    bucket: int
    executable: Any = None         # jitted serve step, set by the driver
    table_keys: tuple = ()         # pinned _device_table keys (attach_tables)
    # True when planlint (``analysis.verify_plan``) ran on the lowering
    # with zero findings — lower() stamps context["verified"] under
    # pytest / REPRO_PLANLINT=1, so serving can report which cached
    # plans were statically verified before their first launch
    verified: bool = False


_CACHE: "OrderedDict[tuple, PlanCacheEntry]" = OrderedDict()
_HITS = 0
_MISSES = 0
_EVICTIONS = 0


def stats() -> dict:
    total = _HITS + _MISSES
    return {"hits": _HITS, "misses": _MISSES, "entries": len(_CACHE),
            "hit_rate": (_HITS / total) if total else 0.0,
            "evictions": _EVICTIONS, "capacity": CAPACITY}


def _device_table():
    # importlib, not ``from repro.kernels import grouped_matmul``: the
    # package re-exports a FUNCTION of that name which shadows the
    # submodule attribute once ``__init__`` finishes
    import importlib
    return importlib.import_module(
        "repro.kernels.grouped_matmul")._device_table


def _unpin_entry(entry: PlanCacheEntry) -> None:
    if entry.table_keys:
        _device_table().unpin(entry.table_keys)
        entry.table_keys = ()


def attach_tables(entry: PlanCacheEntry, keys) -> None:
    """Pin the device offset tables ``keys`` (recorded by
    ``_device_table.recording()`` around the entry's first execution) to
    the entry: pinned tables survive the table registry's own LRU bound
    for as long as the entry is live, and are released on eviction or
    ``reset(clear_entries=True)``.  Idempotent per entry — only the first
    attach pins."""
    if entry.table_keys or not keys:
        return
    entry.table_keys = tuple(keys)
    _device_table().pin(entry.table_keys)


def _insert(key: tuple, entry: PlanCacheEntry) -> None:
    global _EVICTIONS
    _CACHE[key] = entry
    while len(_CACHE) > CAPACITY:
        _, old = _CACHE.popitem(last=False)     # least-recent first
        _unpin_entry(old)
        _EVICTIONS += 1


def reset(clear_entries: bool = False) -> None:
    """Zero the counters; ``clear_entries`` also drops the cached plans
    and unpins their device tables (the warmup boundary in the serve
    driver resets counters ONLY, so the post-warmup hit rate is measured
    against a populated cache)."""
    global _HITS, _MISSES, _EVICTIONS
    _HITS = _MISSES = _EVICTIONS = 0
    if clear_entries:
        for entry in _CACHE.values():
            _unpin_entry(entry)
        _CACHE.clear()


def _lookup(key: tuple) -> PlanCacheEntry | None:
    global _HITS
    entry = _CACHE.get(key)
    if entry is not None:
        _HITS += 1
        _CACHE.move_to_end(key)                 # refresh recency
    return entry


def cached_cnn_plan(cfg, bucket: int, *, dtype="float32", backend=None,
                    train: bool = False, fuse_concat: bool = True,
                    fuse_pool: bool = True,
                    chain_modules: bool = False) -> PlanCacheEntry:
    """The serving entry point: (cfg, M-bucket) -> cached PlanCacheEntry.

    ``build_graph`` runs on every call — it is cheap pure-python shape
    bookkeeping and produces the fingerprint that keys the cache; the
    expensive ``plan_cnn`` lowering (schedule + lower + backward_plan +
    budget checks) runs only on a miss.  The entry's plan carries
    ``context["batch"] == bucket``, which is what the ragged
    ``valid_images`` executor divides by.
    """
    global _MISSES
    import jax
    from repro.models import cnn  # lazy: mirrors core.plan.execute_plan

    backend = jax.default_backend() if backend is None else backend
    fp = graph_fingerprint(cnn.build_graph(cfg, int(bucket)))
    key = plan_key(fp, bucket, dtype, backend, train=train,
                   fuse_concat=fuse_concat, fuse_pool=fuse_pool,
                   chain_modules=chain_modules)
    entry = _lookup(key)
    if entry is not None:
        return entry
    _MISSES += 1
    plan, sch = cnn.plan_cnn(cfg, int(bucket), train=train,
                             fuse_concat=fuse_concat, fuse_pool=fuse_pool,
                             chain_modules=chain_modules)
    entry = PlanCacheEntry(plan=plan, schedule=sch, fingerprint=fp,
                           bucket=int(bucket),
                           verified=bool(plan.context.get("verified")))
    _insert(key, entry)
    return entry


def cached_moe_plan(*, b: int, s: int, d: int, f: int, e: int, top_k: int,
                    capacity_factor: float, gated: bool = True,
                    shared_f: int = 0, dtype="float32",
                    backend=None) -> PlanCacheEntry:
    """MoE layers through the same cache: (layer dims, batch bucket) ->
    cached ``plan.lower_moe`` Plan with its ``grouped_experts`` group.
    The fingerprint comes from ``models.moe.build_moe_graph`` — s, top_k,
    capacity and widths all land in op params, so any dim edit re-keys —
    and ``bucket`` carries the batch, mirroring the CNN path."""
    global _MISSES
    import jax
    from repro.core import plan as planlib
    from repro.models import moe

    backend = jax.default_backend() if backend is None else backend
    graph = moe.build_moe_graph(b=b, s=s, d=d, f=f, e=e, top_k=top_k,
                                capacity_factor=capacity_factor,
                                gated=gated, shared_f=shared_f)
    fp = graph_fingerprint(graph)
    key = plan_key(fp, b, dtype, backend)
    entry = _lookup(key)
    if entry is not None:
        return entry
    _MISSES += 1
    plan = planlib.lower_moe(graph, b=b, s=s, d=d, f=f, e=e, top_k=top_k,
                             capacity_factor=capacity_factor, gated=gated,
                             shared_f=shared_f)
    entry = PlanCacheEntry(plan=plan, schedule=None, fingerprint=fp,
                           bucket=int(b),
                           verified=bool(plan.context.get("verified")))
    _insert(key, entry)
    return entry
