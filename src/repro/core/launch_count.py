"""Traced-jaxpr launch counter — what actually compiles, not what eager ran.

The eager ``KERNEL_LAUNCHES`` probe in ``kernels/grouped_matmul.py`` counts
wrapper invocations; under ``jit`` that tells you nothing about surviving
XLA fallbacks.  This counter walks the jaxpr of a traced callable
(recursively, through pjit/custom-vjp/scan sub-jaxprs at any depth) and
counts the equations that become device launches a plan claims to have
deleted:

  pallas_call         — our kernels (one launch each)
  conv_general_dilated — an XLA convolution survived the GEMM lowering
  reduce_window_*     — a standalone pooling primitive survived absorption
  concatenate         — a join / packing copy survived epilogue-concat

``launches_per_forward`` on a plan is the pallas_call count PLUS the
surviving fallbacks — the honest per-direction launch total the ISSUE's
ceiling gates (and the chained plan's <= 12 claim) are measured by.
"""
from __future__ import annotations

import jax

# primitive name -> report key
COUNTED = {
    "pallas_call": "pallas_call",
    "conv_general_dilated": "conv",
    "reduce_window": "reduce_window",
    "reduce_window_max": "reduce_window",
    "reduce_window_min": "reduce_window",
    "reduce_window_sum": "reduce_window",
    "concatenate": "concatenate",
}


def walk_eqns(jaxpr):
    """Yield every equation of ``jaxpr`` and, recursively, of every
    sub-jaxpr reachable through its params (pjit's ``jaxpr``, custom-vjp
    call_jaxpr, scan/cond/checkpoint bodies, ...) — the traversal both
    this counter and the planlint fallback lint
    (``analysis/fallbacks.py``) are built on."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from walk_eqns(sub)


def _walk(jaxpr, counts: dict) -> None:
    for eqn in walk_eqns(jaxpr):
        key = COUNTED.get(eqn.primitive.name)
        if key is not None:
            counts[key] = counts.get(key, 0) + 1


def _subjaxprs(v):
    """Yield every Jaxpr reachable from one params value (pjit's ``jaxpr``,
    custom-vjp call_jaxpr, scan/cond branches, ...)."""
    try:
        from jax.extend.core import ClosedJaxpr, Jaxpr  # jax >= 0.4.x
    except ImportError:  # pragma: no cover - older jax layouts
        from jax.core import ClosedJaxpr, Jaxpr  # type: ignore
    if isinstance(v, ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _subjaxprs(x)


def count_launches(fn, *args, **kwargs) -> dict:
    """Trace ``fn(*args, **kwargs)`` and return the counted-primitive
    histogram plus its ``total`` — the per-direction launch number the
    CI ceiling gates pin.  ``fn`` is traced, never executed."""
    closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    counts: dict = {}
    _walk(closed.jaxpr, counts)
    counts["total"] = sum(v for k, v in counts.items() if k != "total")
    return counts


def count_grad_launches(loss_fn, *args, **kwargs) -> dict:
    """Launch histogram of the BACKWARD half: trace grad of ``loss_fn``
    wrt its first argument and subtract nothing — the counted total is
    fwd+bwd of the differentiated computation, so callers wanting the
    backward-only number subtract their ``count_launches`` forward total
    (see ``launches_per_direction``)."""
    g = jax.grad(lambda *a: loss_fn(*a, **kwargs))
    closed = jax.make_jaxpr(g)(*args)
    counts: dict = {}
    _walk(closed.jaxpr, counts)
    counts["total"] = sum(v for k, v in counts.items() if k != "total")
    return counts


def launches_per_direction(loss_fn, *args, **kwargs) -> tuple[int, int]:
    """(launches_per_forward, launches_per_backward) of a scalar loss.

    Forward = traced ``loss_fn``; backward = traced ``grad(loss_fn)``
    minus the forward residual recomputation is NOT separable in a jaxpr,
    so the backward number is the grad trace's total minus the forward
    total — the launches the backward half ADDS, which is the quantity
    the mirrored backward plan prices."""
    fwd = count_launches(loss_fn, *args, **kwargs)["total"]
    both = count_grad_launches(loss_fn, *args, **kwargs)["total"]
    return fwd, max(both - fwd, 0)
