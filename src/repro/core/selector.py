"""Algorithm selection — per-op-fastest vs concurrency-aware (paper C3).

Two policies:

  select_fastest    — what TF r1.10 does (paper Sec 2.1): per-op argmin of
                      modeled time, ignoring workspace and co-execution.
  select_concurrent — the paper's proposal: for each co-execution group,
                      jointly choose algorithms minimizing the *group
                      makespan* under the co-execution model, subject to
                      the HBM-workspace and VMEM budgets (C2/C4).  Groups
                      of <= 4 ops are solved exactly (product space is
                      tiny); larger groups greedily.
"""
from __future__ import annotations

import dataclasses
import itertools

from repro.core import cost_model as cm
from repro.core.graph import Op, OpGraph


@dataclasses.dataclass
class Selection:
    """algorithm choice + modeled profile per op."""
    algorithms: dict[str, str]
    profiles: dict[str, cm.OpProfile]

    def time(self, name: str) -> float:
        return self.profiles[name].time


def select_fastest(graph: OpGraph) -> Selection:
    algs, profs = {}, {}
    for name, op in graph.ops.items():
        a, _ = cm.best_algorithm(op)
        algs[name] = a
        profs[name] = cm.profile(op, a)
    return Selection(algs, profs)


def _group_feasible(profiles: list[cm.OpProfile],
                    hbm_budget: float, vmem_budget: float) -> bool:
    return (sum(p.workspace_bytes for p in profiles) <= hbm_budget
            and sum(p.vmem_bytes for p in profiles) <= vmem_budget)


def select_for_group(ops: list[Op], hbm_budget: float = cm.HBM_BYTES * 0.25,
                     vmem_budget: float = cm.VMEM_BYTES) -> tuple[dict[str, str], float]:
    """Joint algorithm choice minimizing co-execution makespan for one group.

    Returns ({op: algorithm}, modeled group time).  If no combination fits
    the budgets, falls back to per-op-fastest run *serially* (the paper's
    C2: workspace exhaustion forces serialization).
    """
    if len(ops) == 1:
        a, t = cm.best_algorithm(ops[0])
        return {ops[0].name: a}, t

    spaces = [cm.supported_algorithms(op) for op in ops]
    best: tuple[float, dict[str, str]] | None = None
    n_combos = 1
    for s in spaces:
        n_combos *= len(s)
    if n_combos <= 256:
        combos = itertools.product(*spaces)
    else:  # greedy: fastest for op 0, then coordinate descent
        combos = [_greedy_combo(ops, spaces, hbm_budget, vmem_budget)]
    for combo in combos:
        profs = [cm.profile(op, a) for op, a in zip(ops, combo)]
        if not _group_feasible(profs, hbm_budget, vmem_budget):
            continue
        t = cm.co_execution_time(profs)
        if best is None or t < best[0]:
            best = (t, dict(zip((o.name for o in ops), combo)))
    if best is None:  # C2: nothing fits together -> serialize
        sel = {}
        t = 0.0
        for op in ops:
            a, ti = cm.best_algorithm(op)
            sel[op.name] = a
            t += ti
        return sel, t
    return best[1], best[0]


def _greedy_combo(ops, spaces, hbm_budget, vmem_budget):
    combo = [cm.best_algorithm(op)[0] for op in ops]
    improved = True
    while improved:
        improved = False
        for i, op in enumerate(ops):
            cur = list(combo)
            base_profs = [cm.profile(o, a) for o, a in zip(ops, cur)]
            base = cm.co_execution_time(base_profs) \
                if _group_feasible(base_profs, hbm_budget, vmem_budget) \
                else float("inf")
            for a in spaces[i]:
                cur[i] = a
                profs = [cm.profile(o, aa) for o, aa in zip(ops, cur)]
                if not _group_feasible(profs, hbm_budget, vmem_budget):
                    continue
                t = cm.co_execution_time(profs)
                if t < base:
                    base = t
                    combo = list(cur)
                    improved = True
    return tuple(combo)


def select_concurrent(graph: OpGraph, groups: list[list[str]],
                      hbm_budget: float = cm.HBM_BYTES * 0.25,
                      vmem_budget: float = cm.VMEM_BYTES) -> Selection:
    """Concurrency-aware selection over a schedule's co-execution groups."""
    algs: dict[str, str] = {}
    for g in groups:
        ops = [graph.ops[n] for n in g]
        sel, _ = select_for_group(ops, hbm_budget, vmem_budget)
        algs.update(sel)
    for name, op in graph.ops.items():   # singletons not covered by groups
        if name not in algs:
            algs[name] = cm.best_algorithm(op)[0]
    profs = {n: cm.profile(graph.ops[n], a) for n, a in algs.items()}
    return Selection(algs, profs)
