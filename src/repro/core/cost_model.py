"""Analytic TPU roofline cost model per (op, algorithm).

The paper profiles kernels with nvprof to get per-algorithm resource
profiles (Table 1) and workspace/time (Table 2).  This container has no TPU,
so the equivalent instrument is an analytic model over the target hardware
constants (TPU v5e-class, per assignment):

    peak bf16 FLOP/s : 197e12 per chip
    HBM bandwidth    : 819e9  B/s per chip
    ICI link bw      : 50e9   B/s per link
    VMEM             : 128 MiB per core (static-resource budget,
                       the SM register/smem analogue)

Per algorithm we model: FLOPs, HBM traffic (algorithm-dependent — direct
conv re-reads the input per tap, im2col writes+reads the patch matrix,
materialized attention writes+reads the score matrix), HBM *workspace*
(Table-2 quantity), and VMEM claim (Table-1 static-resource quantity).
``op_time`` is the roofline max(compute, memory); ``co_execution_time``
models a fused/batched co-execution group where one op's DMA traffic
overlaps another's MXU work — the paper's complementarity argument.
"""
from __future__ import annotations

import dataclasses

from repro.core.graph import Op

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link
VMEM_BYTES = 128 * 1024 * 1024
HBM_BYTES = 16 * 1024**3     # v5e-class per-chip HBM

# A single kernel cannot perfectly overlap its own DMA with its own MXU work:
# intra-op dependencies (next block's compute needs this block's data) leave
# pipeline bubbles — the TPU analogue of the paper's "memory stalls" column in
# Table 1.  We model a lone op as max(c, m) + LAMBDA * min(c, m); a
# co-execution group has independent work available to fill those bubbles, so
# the loss term amortizes by the group size (see co_execution_time).
PIPELINE_LOSS = 0.2


@dataclasses.dataclass(frozen=True)
class OpProfile:
    """The per-(op, algorithm) profile — Table-1/Table-2 analogue row."""
    op: str
    algorithm: str
    flops: float
    hbm_bytes: float          # total HBM traffic
    workspace_bytes: float    # HBM workspace (Table 2)
    vmem_bytes: float         # static VMEM claim (Table 1)

    @property
    def compute_time(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_time(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def time(self) -> float:
        c, m = self.compute_time, self.memory_time
        return max(c, m) + PIPELINE_LOSS * min(c, m)

    @property
    def intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1.0)

    @property
    def bound(self) -> str:
        return "compute" if self.compute_time >= self.memory_time else "memory"


def _mxu_efficiency(*dims: int) -> float:
    """Alignment-derate: each matmul dim not a multiple of 128 wastes the
    padded fraction of the systolic array."""
    eff = 1.0
    for d in dims:
        pad = -(-d // 128) * 128
        eff *= d / pad
    return max(eff, 0.05)


ALGORITHMS_BY_KIND = {
    "matmul": ("mxu128", "large_tile", "ksplit"),
    "conv2d": ("im2col_gemm", "direct", "winograd3x3"),
    "attention": ("flash", "materialized"),
    "ssd": ("chunked", "quadratic"),
    "pointwise": ("vpu",),
    "maxpool": ("reduce_window",),
}


def profile(op: Op, algorithm: str) -> OpProfile:
    p, eb = op.p, op.dtype_bytes
    if op.kind == "matmul":
        m, k, n = p["m"], p["k"], p["n"]
        flops = 2.0 * m * k * n / _mxu_efficiency(m, k, n)
        io = (m * k + k * n + m * n) * eb
        ws = 0.0
        vmem = 0.0
        if algorithm == "mxu128":
            vmem = (128 * 128 * 2) * eb + 128 * 128 * 4
        elif algorithm == "large_tile":
            flops = 2.0 * m * k * n / _mxu_efficiency(m, n)  # K always aligned
            vmem = (256 * 128 + 128 * 256) * eb + 256 * 256 * 4
            # 256-tiles halve the number of lhs/rhs reloads across the grid:
            io = (m * k + k * n) * eb * 0.75 + m * n * eb
        elif algorithm == "ksplit":
            splits = 4
            ws = splits * m * n * 4
            io = (m * k + k * n + m * n) * eb + 2 * ws  # write + reduce read
            vmem = (128 * 128 * 2) * eb + 128 * 128 * 4
        return OpProfile(op.name, algorithm, flops, io, ws, vmem)

    if op.kind == "conv2d":
        n_, h, w, c = p["n"], p["h"], p["w"], p["c"]
        kh, kw, k, s = p["kh"], p["kw"], p["k"], p.get("stride", 1)
        oh, ow = -(-h // s), -(-w // s)
        mac = n_ * oh * ow * kh * kw * c * k
        xin = n_ * h * w * c * eb
        xout = n_ * oh * ow * k * eb
        wts = kh * kw * c * k * eb
        if algorithm == "im2col_gemm":
            ws = n_ * oh * ow * kh * kw * c * eb
            flops = 2.0 * mac / _mxu_efficiency(n_ * oh * ow, kh * kw * c, k)
            io = xin + xout + wts + 2 * ws
            vmem = (128 * 128 * 2) * eb + 128 * 128 * 4
        elif algorithm == "direct":
            ws = 0.0
            flops = 2.0 * mac / _mxu_efficiency(c, k)
            # overlapping window re-reads; a 1x1 tap still reads X once in
            # full (the kh*kw*0.5 re-read factor bottoms out at 1)
            io = xin * max(kh * kw * 0.5, 1.0) + xout + wts
            vmem = (h + kh) * (w + kw) * c * eb  # whole row-window resident
        elif algorithm == "winograd3x3":
            t = n_ * -(-oh // 2) * -(-ow // 2)
            flops = 2.0 * 16 * t * c * k / _mxu_efficiency(t, c, k) \
                + 2.0 * (16 + 16) * 4 * t * c  # transforms (VPU)
            ws = 16 * (t * c + c * k + t * k) * eb
            io = xin + xout + wts + 2 * ws
            vmem = (128 * 128 * 2) * eb + 128 * 128 * 4
        else:
            raise ValueError(algorithm)
        return OpProfile(op.name, algorithm, flops, io, ws, vmem)

    if op.kind == "attention":
        b, sq, skv = p["b"], p["sq"], p["skv"]
        hq, hkv, d = p["hq"], p["hkv"], p["d"]
        flops = 2.0 * b * hq * sq * skv * d * 2  # qk + pv
        qio = b * sq * hq * d * eb
        kvio = 2 * b * skv * hkv * d * eb
        oio = b * sq * hq * d * eb
        if algorithm == "flash":
            ws = 0.0
            io = qio + kvio + oio
            vmem = (128 * d * 3) * eb + 128 * 128 * 4 + 128 * d * 4
        elif algorithm == "materialized":
            ws = b * hq * sq * skv * 4.0
            io = qio + kvio + oio + 3 * ws     # write scores, read, write probs
            vmem = (128 * 128 * 2) * eb + 128 * 128 * 4
        else:
            raise ValueError(algorithm)
        return OpProfile(op.name, algorithm, flops, io, ws, vmem)

    if op.kind == "ssd":
        b, s, h = p["b"], p["s"], p["h"]
        pp, g, n = p["p"], p["g"], p["n"]
        l = p.get("chunk", 128)
        nc = -(-s // l)
        xio = b * s * h * pp * eb
        bcio = 2 * b * s * g * n * eb
        if algorithm == "chunked":
            # intra-chunk quadratic + state build + off-diagonal apply
            flops = 2.0 * b * nc * (l * l * g * n + l * l * h * pp
                                    + 2 * l * h * n * pp)
            ws = b * nc * h * n * pp * 4.0
            io = 2 * xio + bcio + 2 * ws
            vmem = (l * l * h + l * h * pp + h * n * pp) * 4
        elif algorithm == "quadratic":
            flops = 2.0 * b * (s * s * g * n + s * s * h * pp)
            ws = b * s * s * h * 4.0
            io = xio * 2 + bcio + 3 * ws
            vmem = (128 * 128 * 2) * eb + 128 * 128 * 4
        else:
            raise ValueError(algorithm)
        return OpProfile(op.name, algorithm, flops, io, ws, vmem)

    if op.kind == "pointwise":
        e = p["elements"]
        return OpProfile(op.name, "vpu", 1.0 * e, 2.0 * e * eb, 0.0,
                         128 * 1024)

    if op.kind == "maxpool":
        # the standalone pooling primitive (cuDNN pooling / XLA
        # reduce_window): each chain stage reads its input and writes the
        # pooled output — pure VPU compares, pure HBM traffic.  A chained
        # pool (pool-proj of a pooled inception module) materializes the
        # intermediate stages as workspace.  This is the launch (and the
        # pre-GEMM round-trip) the pooled grouped kernel absorbs; see
        # ``pool_profile``.
        n_, h, w, c = p["n"], p["h"], p["w"], p["c"]
        flops = io = ws = 0.0
        e_in = n_ * h * w * c
        for i, (window, stride) in enumerate(p["chain"]):
            h, w = -(-h // stride), -(-w // stride)
            e_out = n_ * h * w * c
            flops += float(window * window) * e_out
            io += (e_in + e_out) * eb
            if i < len(p["chain"]) - 1:
                ws += e_out * eb
            e_in = e_out
        return OpProfile(op.name, "reduce_window", flops, io, ws, 128 * 1024)

    raise ValueError(f"unknown op kind {op.kind}")


def op_time(op: Op, algorithm: str) -> float:
    return profile(op, algorithm).time


def best_algorithm(op: Op) -> tuple[str, float]:
    """Per-op fastest (the TF-r1.10 policy the paper critiques)."""
    algs = ALGORITHMS_BY_KIND[op.kind]
    times = {a: op_time(op, a) for a in algs if _supported(op, a)}
    a = min(times, key=times.get)
    return a, times[a]


def _supported(op: Op, algorithm: str) -> bool:
    if op.kind == "conv2d" and algorithm == "winograd3x3":
        p = op.p
        return (p["kh"], p["kw"]) == (3, 3) and p.get("stride", 1) == 1
    return True


def supported_algorithms(op: Op) -> tuple[str, ...]:
    return tuple(a for a in ALGORITHMS_BY_KIND[op.kind] if _supported(op, a))


def gemm_shape(op: Op) -> tuple[int, int, int] | None:
    """(M, K, N) if the op is expressible as ONE GEMM, else None.

    matmul ops are themselves; a conv2d is its im2col view
    (M = N*OH*OW, K = C*KH*KW, N = K_out) — the cuDNN GEMM lowering the
    paper profiles, which is what lets K×K branches join a grouped
    branch-GEMM co-execution group instead of falling back to XLA.
    """
    p = op.p
    if op.kind == "matmul":
        return p["m"], p["k"], p["n"]
    if op.kind == "conv2d":
        s = p.get("stride", 1)
        oh, ow = -(-p["h"] // s), -(-p["w"] // s)
        return p["n"] * oh * ow, p["c"] * p["kh"] * p["kw"], p["k"]
    return None


def gemm_shape_bwd(op: Op) -> tuple[tuple[int, int, int],
                                    tuple[int, int, int]] | None:
    """The op's two backward GEMMs as (M, K, N) shapes, or None.

    For a forward GEMM view (M, K, N) — convs via im2col like
    ``gemm_shape`` — the VJP computes

        dx = dY (M, N) @ W^T (N, K)      ->  (M, N, K)   shared-M ragged
        dw = X^T (K, M) @ dY (M, N)      ->  (K, M, N)   shared-M contraction

    which is why a forward co-execution group mirrors into a backward
    one: the dx GEMMs of G branches again share M, and the dw GEMMs
    share the M *contraction* with ragged (K_g, N_g) outputs — the two
    phases of the combined backward kernel (``grouped_matmul_bwd``,
    ReLU cotangent mask folded into the dY packing, db reduced on the
    first k-row).
    """
    s = gemm_shape(op)
    if s is None:
        return None
    m, k, n = s
    return (m, n, k), (k, m, n)


def backward_profiles(op: Op, algorithm: str) -> list[OpProfile]:
    """Profiles of the op's VJP computation (the Table-1 rows of the
    backward pass).

    GEMM-view ops price as their two backward GEMMs (``gemm_shape_bwd``),
    each an aligned MXU matmul — the lowering the combined backward
    kernel's two phases execute.  pointwise grads are the same traffic shape (a concat
    backward is a split), so the forward profile stands; a maxpool
    backward is likewise ONE scatter pass of forward-equal traffic (dy
    read, dx written through the argmax mask), not the doubled fallback.
    Remaining kinds (attention/ssd) use the forward profile doubled —
    their backward does roughly twice the forward work.
    """
    sb = gemm_shape_bwd(op)
    if sb is None:
        p = profile(op, algorithm)
        return [p] if op.kind in ("pointwise", "maxpool") else [p, p]
    profs = [profile(Op.make(f"{op.name}:{tag}", "matmul",
                             dtype_bytes=op.dtype_bytes, m=m, k=k, n=n),
                     "mxu128")
             for tag, (m, k, n) in zip(("dx", "dw"), sb)]
    kh, kw = op.p.get("kh", 1), op.p.get("kw", 1)
    stride = op.p.get("stride", 1)
    if op.kind == "conv2d" and ((kh, kw) != (1, 1) or stride != 1):
        # the GEMM view of a KxK / strided conv backward materializes the
        # im2col patch buffer both ways (dw reads the patches, dx scatters
        # the patch cotangent) — the same M*(C*KH*KW) workspace the
        # forward im2col_gemm profile charges.  A 1x1 stride-1 conv's
        # backward is pure reshapes (no patch buffer, see _conv_gemm_bwd's
        # fast path) and charges nothing.  The aligned-matmul time proxy
        # stands (ROADMAP calibration caveat), but the C2 budget checks
        # must see the real HBM footprint or they are vacuous for convs.
        m, k, _ = gemm_shape(op)
        ws = m * k * op.dtype_bytes
        profs = [dataclasses.replace(p, workspace_bytes=p.workspace_bytes + ws)
                 for p in profs]
    return profs


def concat_profile(join_op: Op, elements: float | None = None) -> OpProfile:
    """The fork/join concat as an explicit profile row: reading the branch
    outputs back and writing the joint buffer — 2 * elements * eb bytes of
    pure HBM traffic, zero MXU work.  ``elements`` defaults to the join
    op's full element count (the standalone-concat cost every unfused mode
    pays); the fused epilogue-concat passes only the passthrough columns
    (branch slices produced by an earlier launch), because its in-launch
    branches leave the kernel already inside the join buffer."""
    e = join_op.p["elements"] if elements is None else elements
    return OpProfile(f"{join_op.name}:concat", "concat", 0.0,
                     2.0 * e * join_op.dtype_bytes, 0.0, 0.0)


def pool_profile(op: Op) -> OpProfile:
    """The branch maxpool as an explicit profile row — the term the cost
    model used to leave invisible (the pre-GEMM ``reduce_window`` launch
    ran outside every priced group).  Standalone (unfused) plans pay this
    row as the pool op's own singleton group; when the pool is ABSORBED
    into a pooled grouped launch the rider is ZERO — the tap reads stream
    through the launch's existing lhs DMA and the pooled activation never
    touches HBM, so the whole row disappears with the launch (same shape
    as ``concat_profile``, whose fused rider keeps only the passthrough
    columns).  Calibrating the zero-rider claim on real hardware rides
    the ROADMAP's cost-model validation item."""
    assert op.kind == "maxpool", op
    return profile(op, "reduce_window")


def gemm_profiles(ops: list[Op]) -> list[OpProfile]:
    """Per-branch profiles of the GEMM lowering the grouped/stacked
    kernels actually execute: each op priced as its aligned
    ``gemm_shape`` matmul, with a K×K/strided conv additionally charged
    the im2col patch workspace its view materializes (write + read) —
    mirroring ``backward_profiles``'s treatment of the same lowering.

    This replaces the old proxy (the scheduler-chosen per-op algorithm
    profiles), which priced grouped groups at whatever algorithm the
    SERIAL path would have picked — a direct-conv or winograd profile for
    a kernel that always executes the GEMM lowering (the docstring-
    acknowledged drift).  The patch buffer charges the C2 *budget* only,
    not the time: packing/unpacking layout passes around the kernel are
    fused by XLA and modeled as riding the launch's DMA throughout this
    file — exactly how ``backward_profiles`` prices the same lowering."""
    profs = []
    for op in ops:
        s = gemm_shape(op)
        assert s is not None, op
        m, k, n = s
        pr = profile(Op.make(f"{op.name}:gemm", "matmul",
                             dtype_bytes=op.dtype_bytes, m=m, k=k, n=n),
                     "mxu128")
        kh, kw = op.p.get("kh", 1), op.p.get("kw", 1)
        stride = op.p.get("stride", 1)
        if op.kind == "conv2d" and ((kh, kw) != (1, 1) or stride != 1):
            ws = m * k * op.dtype_bytes
            pr = dataclasses.replace(pr, workspace_bytes=pr.workspace_bytes + ws)
        profs.append(pr)
    return profs


def _passthrough_elements(shapes, join_op: Op) -> float:
    """Join elements NOT produced by the group's own branch GEMMs — the
    columns a fused epilogue-concat still has to copy in."""
    own = sum(m * n for m, _, n in shapes)
    return max(join_op.p["elements"] - own, 0.0)


def group_execution_time_bwd(ops: list[Op], algorithms: dict | None = None,
                             mode: str | None = None,
                             join: Op | None = None) -> tuple[str, float]:
    """(realizable mode, modeled makespan) for the GRAD group mirroring a
    forward co-execution group — the backward analogue of
    ``group_execution_time``, and what the custom VJPs actually launch.

    Branches with shared-M GEMM views backward-co-execute in ONE combined
    grouped launch (masked dx + dw/db over a concatenated offset table —
    the single kernel ``kernels.ops``' VJPs emit) or, for uniform shapes,
    two stacked ones (``branch_matmul``'s VJP).  Anything else only has
    the per-op XLA pullback, priced with the interleave loss.  ``mode``
    forces the pricing to a known forward mode (``plan.backward_plan``
    passes the lowered mode; the scheduler omits it to judge candidates).
    ``join`` + mode="grouped_concat" prices the grad of a fused
    epilogue-concat group: the joint cotangent is sliced straight into
    the combined launch's packing, so only the passthrough columns pay
    the split's read+write (the standalone join backward disappears).
    """
    algs = algorithms or {}

    def bprofs(op):
        return backward_profiles(
            op, algs.get(op.name) or best_algorithm(op)[0])

    if len(ops) == 1:
        return "serial", sum(p.time for p in bprofs(ops[0]))
    shapes = [gemm_shape(op) for op in ops]
    grouped_ok = (all(s is not None for s in shapes)
                  and len({s[0] for s in shapes}) == 1)
    if grouped_ok and mode in ("grouped", "grouped_pooled",
                               "grouped_concat", "stacked", None):
        per_op = [bprofs(op) for op in ops]
        dxp = [p[0] for p in per_op]
        dwp = [p[1] for p in per_op]
        if mode == "grouped_concat":
            assert join is not None, "grouped_concat backward needs the join"
            rider = concat_profile(join, _passthrough_elements(shapes, join))
            return "grouped_concat", co_execution_time(dxp + dwp + [rider])
        # ONE combined launch: dx and dw/db share the grid, so compute of
        # one phase overlaps memory of the other across the whole union
        t_grouped = co_execution_time(dxp + dwp)
        uniform = len({s[:2] for s in shapes}) == 1
        # a FORCED stacked mode prices pad-to-max even on ragged branches
        # (the stacked kernel pads K and N to the widest, so it executes
        # — and pays — exactly that); the auto choice (mode=None) only
        # prefers stacked on uniform shapes, like the forward judgement
        if mode == "stacked" or (uniform and mode is None):
            dx_shapes = [(m, n, k) for m, k, n in shapes]
            dw_shapes = [(k, m, n) for m, k, n in shapes]
            t_stacked = (stacked_time(dxp, dx_shapes)
                         + stacked_time(dwp, dw_shapes))
            if mode == "stacked" or t_stacked <= t_grouped:
                return "stacked", t_stacked
        # a pooled forward mirrors to the SAME combined launch (the
        # pooling cotangent mask rides its unpacking — zero rider, like
        # the forward's pool_profile when fused)
        return ("grouped_pooled" if mode == "grouped_pooled"
                else "grouped"), t_grouped
    flat = [p for op in ops for p in bprofs(op)]
    return "xla", xla_interleave_time(flat)


def co_execution_time(profiles: list[OpProfile]) -> float:
    """Modeled makespan of a co-execution group on ONE chip.

    Fused/batched ops share the chip: MXU work serializes across the group,
    HBM traffic serializes across the group, but compute of one op overlaps
    memory traffic of another (DMA/MXU pipelining) — so the group finishes at
    max(sum_compute, sum_memory) instead of sum(max(c_i, m_i)).
    Complementary groups (compute-bound + memory-bound) win; same-bound
    groups don't — exactly the paper's Table-1 observation.  The lone-kernel
    pipeline-loss term amortizes by the group size: other branches' blocks
    fill the bubbles one op's intra-dependencies leave.
    """
    c = sum(pr.compute_time for pr in profiles)
    m = sum(pr.memory_time for pr in profiles)
    return max(c, m) + PIPELINE_LOSS * min(c, m) / len(profiles)


def serial_time(profiles: list[OpProfile]) -> float:
    return sum(pr.time for pr in profiles)


def grouped_time(ops: list[Op]) -> float:
    """Makespan of a grouped ragged branch GEMM (kernels/grouped_matmul):
    every branch runs only its own alignment-padded tiles, so there is no
    padding-waste term — the group is pure co-execution, priced directly
    off the ``gemm_shape`` lowering the kernel executes
    (``gemm_profiles``; was the scheduler-chosen per-op algorithm
    profiles — a proxy whose drift the docstring used to acknowledge).
    Calibrating against hardware stays a ROADMAP open item."""
    return co_execution_time(gemm_profiles(ops))


def stacked_time(profiles: list[OpProfile],
                 shapes: list[tuple[int, int, int]]) -> float:
    """Makespan of the pad-to-max stacked kernel (kernels/branch_matmul):
    every branch's MXU grid is inflated to the widest branch's aligned
    (K, N), so branch g pays round128(Kmax)*round128(Nmax) /
    (round128(K_g)*round128(N_g)) of its own compute.  (Memory traffic is
    dominated by the shared-M inputs; padded tiles are modeled as noise.)
    ``profiles`` should be the ``gemm_profiles`` of the branches — the
    stacked kernel executes the same GEMM lowering the grouped one does,
    just padded (``group_execution_time`` prices both arms off it)."""
    def al(d):
        return -(-d // 128) * 128
    kmax = max(al(k) for _, k, _ in shapes)
    nmax = max(al(n) for _, _, n in shapes)
    c = sum(pr.compute_time * (kmax * nmax) / (al(k) * al(n))
            for pr, (_, k, n) in zip(profiles, shapes))
    m = sum(pr.memory_time for pr in profiles)
    return max(c, m) + PIPELINE_LOSS * min(c, m) / len(profiles)


def padded_m_factor(m_true: int, m_bucket: int, *, bm: int = 128) -> float:
    """Padded-M waste of serving a ragged request mix through an M-bucket:
    the grouped grid runs ``ceil(M_bucket/bm)`` row-blocks regardless of
    how many rows are real, so a mix with ``m_true`` true rows pays
    ``al(M_bucket)/al(m_true)`` of its useful compute (the same
    aligned-tile inflation idiom ``stacked_time`` prices pad-to-max
    branches with — M is just the dimension being padded here).  1.0 means
    the bucket is free for this mix."""
    def al(d):
        return max(-(-d // bm) * bm, bm)
    return al(m_bucket) / al(m_true)


def serve_buckets(max_images: int, rows_per_image: int, *,
                  bm: int = 128) -> list[int]:
    """The serving driver's M-bucket ladder, a MODELED decision: start
    from powers-of-two image counts up to ``max_images`` and merge any
    bucket whose worst-case padded-M factor over the next bucket is 1.0 —
    when ``rows_per_image`` image-rows already tile the bm-aligned grid
    identically for both bucket sizes (every googlenet group has
    rows_per_image a multiple of bm once H*W*B aligns), the smaller bucket
    buys no fewer row-blocks and only fragments the plan/executable cache.
    The surviving ladder is exactly the set of bucket sizes whose grids
    actually differ."""
    assert max_images >= 1 and rows_per_image >= 1
    ladder = []
    b = 1
    while b < max_images:
        ladder.append(b)
        b *= 2
    ladder.append(max_images)
    kept = []
    for lo, hi in zip(ladder, ladder[1:]):
        # worst case inside bucket `hi` but servable by `lo`: m_true =
        # lo * rows_per_image.  If hi's grid is no bigger, lo is redundant.
        if padded_m_factor(lo * rows_per_image, hi * rows_per_image,
                           bm=bm) > 1.0:
            kept.append(lo)
    kept.append(ladder[-1])
    return kept


# XLA interleaving recovers only part of the co-execution overlap: the
# framework baseline the paper critiques emits ops together and hopes, so we
# model it halfway between perfect overlap and serial launch.  Giving the
# scheduler this (worse) number for groups no kernel can realize stops it
# over-grouping heterogeneous ops whose only execution path is XLA.
XLA_INTERLEAVE_LOSS = 0.5


def xla_interleave_time(profiles: list[OpProfile]) -> float:
    co = co_execution_time(profiles)
    return co + XLA_INTERLEAVE_LOSS * (serial_time(profiles) - co)


def group_execution_time(ops: list[Op], profiles: list[OpProfile],
                         join: Op | None = None) -> tuple[str, float]:
    """(realizable single-chip mode, modeled makespan) for a co-execution
    group — the shared judgement ``scheduler`` packs with and
    ``plan.lower`` turns into an ExecGroup.

    Branches expressible as shared-M GEMMs co-execute as one grouped
    (ragged) or stacked (uniform-shape) kernel; a compute+memory
    complementary (GEMM, pointwise) pair fuses; anything else only has the
    XLA-interleave path, modeled with its overlap loss.  ``spatial`` needs
    a mesh and is decided by ``plan.lower`` on top of this.

    ``join``: the fork/join concat this group's outputs feed, when the
    caller wants the concat traffic priced WITH the group (the absorption
    judgement in ``plan.lower``).  A grouped group then becomes
    ``grouped_concat`` — the fused epilogue-concat writes branch slices
    in place, so only the passthrough columns keep their copy cost
    (``concat_profile``) — while any other mode pays the standalone
    concat's full read+write on top (the term the join's own singleton
    group prices when it is NOT absorbed; never count both).
    """
    if len(ops) == 1:
        return "serial", profiles[0].time
    shapes = [gemm_shape(op) for op in ops]
    if all(s is not None for s in shapes) \
            and len({s[0] for s in shapes}) == 1:
        # grouped/stacked price off the GEMM lowering the kernels execute
        # (gemm_profiles), not the serial path's chosen algorithms
        gprofs = gemm_profiles(ops)
        if join is not None:
            rider = concat_profile(join, _passthrough_elements(shapes, join))
            return "grouped_concat", co_execution_time(gprofs + [rider])
        t_grouped = co_execution_time(gprofs)
        if len({s[:2] for s in shapes}) == 1:   # uniform (M, K): stackable
            t_stacked = stacked_time(gprofs, shapes)
            if t_stacked <= t_grouped:
                return "stacked", t_stacked
        return "grouped", t_grouped
    if join is not None:
        mode, t = group_execution_time(ops, profiles)
        return mode, t + concat_profile(join).time
    gemm = [i for i, s in enumerate(shapes) if s is not None]
    stream = [i for i, op in enumerate(ops) if op.kind == "pointwise"]
    if (len(ops) == 2 and len(gemm) == 1 and len(stream) == 1
            and gemm[0] != stream[0]
            and profiles[gemm[0]].bound == "compute"
            and profiles[stream[0]].bound == "memory"):
        return "fused", co_execution_time(profiles)
    return "xla", xla_interleave_time(profiles)


def spatial_time(profiles: list[OpProfile], chips: int,
                 split: list[int] | None = None) -> float:
    """Makespan when branches run on disjoint chip groups (inter-chip
    spatial partitioning).  ``split`` = chips per branch; defaults to equal.
    Assumes per-branch work is chip-divisible (true for our batched GEMMs)."""
    k = len(profiles)
    split = split or [max(chips // k, 1)] * k
    return max(
        max(pr.compute_time / c, pr.memory_time / c)
        for pr, c in zip(profiles, split)
    )


# ---------------------------------------------------------------------------
# chained launches (cross-module streaming)
# ---------------------------------------------------------------------------

def chained_profiles(ops: list[Op], ring=frozenset()) -> list[OpProfile]:
    """``gemm_profiles`` with ring-consumer branches repriced for the
    chained launch: a branch whose lhs streams from the in-kernel VMEM
    ring (its producer runs one wave ahead in the SAME launch) never
    reads its input activation from HBM and never materializes an im2col
    patch buffer — drop the M*K lhs read from traffic and the patch
    workspace from the C2 budget.  Every other term (weights, bias,
    output write) stands: chained outputs still land in HBM as the next
    launch's panel operands."""
    ring = frozenset(ring)
    profs = []
    for op, pr in zip(ops, gemm_profiles(ops)):
        if op.name in ring:
            s = gemm_shape(op)
            assert s is not None, op
            m, k, _ = s
            lhs = m * k * op.dtype_bytes
            pr = dataclasses.replace(
                pr,
                hbm_bytes=max(pr.hbm_bytes - lhs, 0.0),
                workspace_bytes=max(pr.workspace_bytes - lhs, 0.0))
        profs.append(pr)
    return profs


def chained_time(phase_ops: list[list[Op]], ring=frozenset(),
                 m_valid: int | None = None) -> float:
    """Modeled makespan of ONE chained launch over ``phase_ops`` (one op
    list per phase, Shi-et-al.-style honest pricing rather than
    assertion): the union co-executes like one big grouped launch —
    MXU work and HBM traffic serialize across ALL branches of ALL
    phases, compute overlapping memory — with ring consumers' lhs
    traffic dropped (``chained_profiles``) and NO concat rider (the next
    launch consumes the padded panels in place via its lhs-source
    descriptors).  On top rides the pipeline-FILL term the wave schedule
    costs: a P-phase chain runs mb + P - 1 waves for mb row blocks, so
    the steady-state makespan stretches by (P-1)/(mb+P-1).

    ``m_valid`` prices the ragged serving launch: dead M-blocks past the
    cutoff are skipped as no-op waves, so the steady-state work scales
    by the live-block fraction and the fill term runs over live blocks
    only (the no-op waves cost grid steps, not GEMMs — negligible next
    to a block's tap-GEMM ladder, so the model drops them)."""
    ops = [op for ph in phase_ops for op in ph]
    t = co_execution_time(chained_profiles(ops, ring))
    m = max(gemm_shape(op)[0] for op in ops)
    mb = max(-(-m // 128), 1)
    if m_valid is not None:
        mbl = min(max(-(-m_valid // 128), 1), mb)
        t *= mbl / mb
        mb = mbl
    nph = len(phase_ops)
    return t * (1.0 + (nph - 1) / (mb + nph - 1))


def chained_time_bwd(phase_ops: list[list[Op]],
                     algorithms: dict | None = None) -> float:
    """Backward makespan of a chained launch: the VJP mirrors the chain
    in REVERSE phase order with one combined grouped launch (masked dx +
    dw/db) per phase — phases cannot backward-co-execute with each other
    because a ring consumer's lhs cotangent feeds the producer phase's
    dy.  Ring consumers' lhs is recomputed from the residual panels
    (HBM reads the forward skipped), so no traffic is dropped here —
    the backward win is launch count and the vanished join split, not
    bytes."""
    algs = algorithms or {}
    total = 0.0
    for ops in phase_ops:
        per = [backward_profiles(op, algs.get(op.name)
                                 or best_algorithm(op)[0])
               for op in ops]
        total += co_execution_time([p[0] for p in per]
                                   + [p[1] for p in per])
    return total


# ---------------------------------------------------------------------------
# MoE expert dispatch: ragged-per-expert grouped vs capacity-padded einsum
# ---------------------------------------------------------------------------

def _al128(d: int) -> int:
    return -(-d // 128) * 128


def moe_grouped_profile(n_slots: int, e: int, d: int, f: int, *,
                        gated: bool, bm: int, dtype_bytes: int = 4,
                        train: bool = False) -> OpProfile:
    """Forward profile of ``grouped_matmul_experts``: the static grid is
    ``n_slots // bm + e`` M-blocks (every routed token once, plus at most
    one partial block per expert), each running (1+gated) in-GEMMs and
    one out-GEMM on 128-aligned tiles — FLOPs scale with routed tokens,
    never with E*capacity.  ``bm`` is a parameter so this module stays
    free of the kernels dependency (plan passes ``kernels.moe_block_m``).

    Traffic by index-change counting on the offset table: with one
    k-block the X tile is fetched once per M-block (held through every
    H and Y step); expert weights are fetched per block they serve."""
    mbs = n_slots // bm + e
    dp, fp = _al128(d), _al128(f)
    db, fb = dp // 128, fp // 128
    nw = 1 + int(gated)
    ngemm = nw + 1
    flops = 2.0 * mbs * bm * dp * fp * ngemm
    x_fetch = 1 if db == 1 else db * nw * fb
    bytes_ = (mbs * bm * dp * dtype_bytes * x_fetch          # X
              + mbs * nw * db * fb * 128 * 128 * dtype_bytes  # W_in/W_gate
              + mbs * fb * db * 128 * 128 * dtype_bytes       # W_out
              + mbs * bm * 4                                  # sw
              + mbs * bm * dp * dtype_bytes)                  # Y
    if train:
        bytes_ += mbs * bm * fp * dtype_bytes * nw            # preacts
    vmem = (bm * 128 + 2 * fb * bm * 128) * 4
    return OpProfile("moe_experts", "grouped_ragged", flops, bytes_,
                     0.0, vmem)


def moe_einsum_profile(b: int, cap: int, e: int, d: int, f: int, *,
                       gated: bool, dtype_bytes: int = 4) -> OpProfile:
    """The capacity-padded E-leading stacked einsum (``_moe_apply_core``):
    every one of the B*E*cap capacity slots pays the full expert chain
    whether a token was routed to it or not, and the per-expert M is
    ``cap`` — both the padding waste and the alignment derate are priced.
    Dispatch gather/scatter traffic is skipped on BOTH engines (identical
    routing work), so the comparison isolates the expert compute."""
    rows = b * e * cap
    nw = 1 + int(gated)
    eff = _mxu_efficiency(cap, d, f)
    flops = 2.0 * rows * d * f * (nw + 1) / eff
    bytes_ = (rows * d * dtype_bytes * nw                     # xe reads
              + 2 * rows * f * dtype_bytes                    # h write+read
              + rows * d * dtype_bytes                        # ye
              + e * (nw * d * f + f * d) * dtype_bytes)       # weights
    return OpProfile("moe_experts", "einsum_padded", flops, bytes_,
                     rows * f * dtype_bytes, 0.0)


def moe_stacked_profile(b: int, cap: int, e: int, d: int, f: int, *,
                        gated: bool, bm: int,
                        dtype_bytes: int = 4) -> OpProfile:
    """Pad-to-max stacked branch kernel baseline (``branch_matmul``
    generalized to the expert chain): E branches each inflated to the
    shared capacity M = B*cap, tiles 128-aligned — what PR 2's stacked
    mode would charge if pointed at the expert fork."""
    mbs = e * (-(-(b * cap) // bm))
    dp, fp = _al128(d), _al128(f)
    nw = 1 + int(gated)
    flops = 2.0 * mbs * bm * dp * fp * (nw + 1)
    bytes_ = (mbs * bm * dp * dtype_bytes
              + e * (nw * dp * fp + fp * dp) * dtype_bytes
              + mbs * bm * dp * dtype_bytes)
    return OpProfile("moe_experts", "stacked_padded", flops, bytes_,
                     0.0, 0.0)


def moe_dispatch_times(n_slots: int, b: int, cap: int, e: int, d: int,
                       f: int, *, gated: bool, bm: int,
                       dtype_bytes: int = 4) -> dict:
    """Modeled forward wall per expert engine — the pricing ``lower_moe``
    picks from and the bench/CI gate compares."""
    return {
        "grouped": moe_grouped_profile(n_slots, e, d, f, gated=gated,
                                       bm=bm, dtype_bytes=dtype_bytes).time,
        "einsum": moe_einsum_profile(b, cap, e, d, f, gated=gated,
                                     dtype_bytes=dtype_bytes).time,
        "stacked": moe_stacked_profile(b, cap, e, d, f, gated=gated,
                                       bm=bm, dtype_bytes=dtype_bytes).time,
    }
