"""Branch-parallel execution — the paper's concurrency, TPU-native.

Three execution modes for a fork/join of independent branches (paper Sec 2):

  stacked  — same-shape branch GEMMs fused into ONE Pallas kernel with a
             branch grid axis (``kernels.branch_matmul``): the intra-chip
             analogue of intra-SM sharing (DMA of branch g+1 overlaps MXU
             of branch g).
  spatial  — inter-chip spatial partitioning via ``shard_map`` over the
             ``model`` mesh axis: the axis is factored into
             (branch-group, within-group batch shard); each chip computes
             one branch on a fraction of the batch; a single all-gather
             joins.  This is the paper's inter-SM partitioning realized on
             hardware that actually exposes partitioning (C5's complaint
             about CUDA does not apply to a TPU mesh).
  xla      — emit branches independently inside one jit and let XLA's
             scheduler interleave them (the "trust the framework" baseline).

All modes require branches with identical output shapes (pad-and-slice for
heterogeneous Inception widths happens in the model layer).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import branch_matmul as stacked_matmul


@dataclasses.dataclass
class Branches:
    """Model-definition combinator: a fork of independent branch callables
    whose outputs are joined by ``combine`` ('concat' | 'sum' | 'stack')."""
    fns: Sequence[Callable]
    combine: str = "concat"
    name: str = "branches"


def _join(ys: list[jax.Array], combine: str) -> jax.Array:
    if combine == "concat":
        return jnp.concatenate(ys, axis=-1)
    if combine == "sum":
        out = ys[0]
        for y in ys[1:]:
            out = out + y
        return out
    if combine == "stack":
        return jnp.stack(ys, axis=0)
    raise ValueError(combine)


def run_xla(branches: Branches, x: jax.Array) -> jax.Array:
    return _join([f(x) for f in branches.fns], branches.combine)


def run_stacked_matmul(x: jax.Array, ws: jax.Array, combine: str = "concat",
                       interpret: bool | None = None) -> jax.Array:
    """Fused same-shape branch projections: x (M, K), ws (G, K, N)."""
    g = ws.shape[0]
    xs = jnp.broadcast_to(x[None], (g, *x.shape))
    ys = stacked_matmul(xs, ws, interpret=interpret)  # (G, M, N)
    return _join(list(ys), combine)


def run_spatial(branches: Branches, x: jax.Array, mesh: jax.sharding.Mesh,
                axis: str = "model") -> jax.Array:
    """Spatial partitioning over ``axis``: branch g on chips
    [g*W, (g+1)*W), each chip handling 1/W of the local batch.

    x: (B, ...) — batch leading.  Output joined on all chips (replicated
    along ``axis``).
    """
    from jax.experimental.shard_map import shard_map

    fns = list(branches.fns)
    g = len(fns)
    m = mesh.shape[axis]
    assert m % g == 0, f"{g} branches must divide mesh axis {axis}={m}"
    w = m // g

    def local(xl):
        idx = jax.lax.axis_index(axis)
        grp, within = idx // w, idx % w
        bl = xl.shape[0]
        assert bl % w == 0, f"local batch {bl} not divisible by {w}"
        sub = jax.lax.dynamic_slice_in_dim(xl, within * (bl // w), bl // w, 0)
        y_sub = jax.lax.switch(grp, fns, sub)      # (bl/w, ...out)
        gath = jax.lax.all_gather(y_sub, axis)     # (M, bl/w, ...out)
        # device m = grp*W + within holds batch rows [within*bl/w, ...):
        # (G, W, bl/w, ...) reshapes straight to (G, bl, ...) in batch order
        ys = gath.reshape(g, bl, *y_sub.shape[1:])
        return _join(list(ys), branches.combine)

    in_spec = P(*([None] * x.ndim))
    # Trace one branch to get the output rank for the replicated out_spec.
    out_shape = jax.eval_shape(fns[0], jax.ShapeDtypeStruct(
        (x.shape[0],) + x.shape[1:], x.dtype))
    out_rank = len(out_shape.shape)
    out_spec = P(*([None] * out_rank))
    return shard_map(local, mesh=mesh, in_specs=(in_spec,),
                     out_specs=out_spec, check_rep=False)(x)


def run(branches: Branches, x: jax.Array, *, mode: str = "xla",
        mesh: jax.sharding.Mesh | None = None, axis: str = "model"):
    if mode == "spatial":
        assert mesh is not None, "spatial mode needs a mesh"
        return run_spatial(branches, x, mesh, axis)
    return run_xla(branches, x)
