"""The paper's primary contribution: inter-op parallelism for non-linear
networks — op graph, analytic cost model, concurrency-aware algorithm
selection, workspace-budgeted co-execution scheduling, and branch-parallel
execution (stacked kernels intra-chip, spatial mesh partitioning inter-chip).
"""
from repro.core.graph import Op, OpGraph                      # noqa: F401
from repro.core.cost_model import (                            # noqa: F401
    OpProfile, profile, op_time, backward_profiles, best_algorithm,
    co_execution_time, concat_profile, gemm_profiles, gemm_shape,
    gemm_shape_bwd, pool_profile,
    group_execution_time, group_execution_time_bwd, grouped_time, serial_time,
    spatial_time, stacked_time, supported_algorithms, xla_interleave_time,
    PEAK_FLOPS, HBM_BW, ICI_BW, VMEM_BYTES, HBM_BYTES,
)
from repro.core.selector import (                              # noqa: F401
    Selection, select_fastest, select_concurrent, select_for_group,
)
from repro.core.scheduler import CoGroup, Schedule, schedule, compare_policies  # noqa: F401
from repro.core.branch_parallel import (                       # noqa: F401
    Branches, run, run_xla, run_spatial, run_stacked_matmul,
)
from repro.core.plan import (                                  # noqa: F401
    ExecGroup, OpImpl, Plan, backward_plan, execute_plan, lower, run_plan,
    MODES,
)
