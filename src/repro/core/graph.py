"""Op-level computation graph with fork/join structure (paper C1/C5).

The paper's subject is the DAG a DL framework builds at op granularity
(conv / matmul / attention / ...) and the *independent chains* a non-linear
topology exposes.  ``OpGraph`` is that DAG: nodes carry enough shape
information for the analytic cost model, edges are data dependencies, and
the ready-queue view (`levels`, `ready_after`) is what the scheduler packs
into co-execution groups.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class Op:
    """One schedulable operator.

    kind/params drive the cost model:
      matmul:    m, k, n
      conv2d:    n, h, w, c, kh, kw, k, stride
      attention: b, sq, skv, hq, hkv, d
      ssd:       b, s, h, p, g, n
      pointwise: elements
    """
    name: str
    kind: str
    params: tuple  # sorted (key, value) pairs — hashable
    dtype_bytes: int = 2

    @property
    def p(self) -> dict:
        return dict(self.params)

    @staticmethod
    def make(name: str, kind: str, dtype_bytes: int = 2, **params) -> "Op":
        return Op(name, kind, tuple(sorted(params.items())), dtype_bytes)


class OpGraph:
    """DAG of Ops with fork/join queries."""

    def __init__(self):
        self.ops: dict[str, Op] = {}
        self.succ: dict[str, set[str]] = defaultdict(set)
        self.pred: dict[str, set[str]] = defaultdict(set)

    def add(self, op: Op, deps: Iterable[str] = ()) -> Op:
        if op.name in self.ops:
            raise ValueError(f"duplicate op {op.name}")
        self.ops[op.name] = op
        for d in deps:
            if d not in self.ops:
                raise ValueError(f"unknown dep {d} for {op.name}")
            self.succ[d].add(op.name)
            self.pred[op.name].add(d)
        return op

    # -- topology ----------------------------------------------------------

    def levels(self) -> list[list[str]]:
        """ALAP-free BFS levels: ops in the same level are independent
        *if* they share the level (sufficient, not necessary)."""
        indeg = {n: len(self.pred[n]) for n in self.ops}
        q = deque(sorted(n for n, d in indeg.items() if d == 0))
        out = []
        while q:
            nxt = []
            level = sorted(q)
            q.clear()
            out.append(level)
            for n in level:
                for s in sorted(self.succ[n]):
                    indeg[s] -= 1
                    if indeg[s] == 0:
                        nxt.append(s)
            for n in nxt:
                q.append(n)
        return out

    def independent(self, a: str, b: str) -> bool:
        """True iff neither op reaches the other (co-schedulable)."""
        return not self._reaches(a, b) and not self._reaches(b, a)

    def _reaches(self, src: str, dst: str) -> bool:
        seen, stack = set(), [src]
        while stack:
            n = stack.pop()
            if n == dst:
                return True
            for s in self.succ[n]:
                if s not in seen:
                    seen.add(s)
                    stack.append(s)
        return False

    def independent_sets(self) -> list[list[str]]:
        """Maximal antichains found greedily per level (the paper's
        'independent operations across layers' — 27 cases in GoogleNet)."""
        return [lvl for lvl in self.levels() if len(lvl) > 1]

    def critical_path_weights(self, time_fn) -> dict[str, float]:
        """Longest path to exit under ``time_fn(op)`` — list-scheduling
        priority."""
        order = [n for lvl in self.levels() for n in lvl]
        w: dict[str, float] = {}
        for n in reversed(order):
            tail = max((w[s] for s in self.succ[n]), default=0.0)
            w[n] = time_fn(self.ops[n]) + tail
        return w

    def __len__(self):
        return len(self.ops)
