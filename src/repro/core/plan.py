"""Executable plan IR — the lowering layer between Schedule and kernels.

``core/scheduler.py`` decides WHAT co-executes (CoGroups + per-op
algorithms); this module decides HOW: ``lower()`` turns each CoGroup into an
``ExecGroup`` with a concrete execution mode and ``run_plan`` /
``execute_plan`` actually run it.  This is the piece the paper says
frameworks are missing — they model inter-op parallelism but launch
kernels serially — and the piece Opara-style systems add: an operator
execution plan compiled from the DAG.

Modes (mirroring ``core/branch_parallel.py``):

  grouped — branches expressible as shared-M GEMMs with *per-branch*
            (K_g, N_g) — ragged 1x1 widths, and K×K convs through their
            im2col view — run as ONE Pallas kernel over a flattened tile
            grid with a scalar-prefetched offset table and the bias+ReLU
            epilogue fused in-kernel (``kernels/grouped_matmul.py``).  No
            pad-to-max-N waste, no post-kernel HBM round-trip.
  grouped_concat — a grouped group that ABSORBS the fork/join concat its
            branches feed: the epilogue writes each branch's tiles
            straight into its slice of the join's [M, sum N_g] layout
            (``grouped_matmul_concat``), join inputs produced by earlier
            groups are copied in as passthrough column slices, and the
            standalone join op disappears from the plan.  The grad group
            mirrors as ONE combined dx+dw/db launch whose packing slices
            the joint cotangent directly.
  grouped_pooled — a grouped group that ABSORBS the maxpool op(s) feeding
            its branches: the launch's offset table gains per-branch pool
            descriptors and the kernel maxes raw-input tap tiles into a
            VMEM pooled-lhs scratch before each M-block's GEMM steps
            (``grouped_matmul_pooled``) — the pooled activation never
            round-trips HBM and the standalone ``reduce_window`` launch
            disappears from the plan.  A ``grouped_concat`` group absorbs
            pools the same way (mode stays grouped_concat, its ``pools``
            recorded), so a pool-proj branch rides the single
            pool+GEMM+epilogue+concat launch.  The grad group mirrors as
            the same ONE combined launch, the pooling cotangent scattered
            through the first-argmax window mask in its unpacking.
  grouped_chained — cross-MODULE streaming (opt-in via
            ``lower(chain_modules=True)``): a module's quad group, the
            concat-pair riding on its reductions, and stem conv runs
            merge into ONE launch running their phases in a lag-1 wave
            schedule (``grouped_matmul_chained``).  Phase p+1 branches
            ring-consume phase p's freshly computed row blocks from VMEM
            (K*K convs as K^2 shifted tap-GEMMs), the join never
            materializes — the launch's padded panels flow to the NEXT
            chained launch as a ``ChainPanels`` value addressed in place
            by panel lhs-source descriptors — and the grad group mirrors
            as one combined dx+dw/db launch per phase in reverse order.
  grouped_experts — an MoE layer's E expert chains (the router's fork)
            run as ONE per-expert-ragged grouped launch per direction
            (``kernels.grouped_matmul_experts``): each expert owns its
            routed token count M_g via the dynamic block-meta prefetch,
            the router's gating weights and activation fuse into the
            epilogue, and FLOPs scale with routed tokens instead of the
            einsum engine's E*capacity slots (``lower_moe``).
  stacked — same-GEMM-shape branches fuse into ONE Pallas kernel with a
            branch grid axis (``kernels/branch_matmul.py``); heterogeneous
            output widths are padded to a common N and sliced back.  Kept
            for uniform shapes, where the padding-waste term vanishes.
  fused   — a compute-bound GEMM paired with a memory-bound streamed
            reduction co-execute in one grid (``kernels/fused_branches.py``)
            so the reduction's HBM bytes ride under the GEMM's MXU work.
  spatial — branches run on disjoint chips of a mesh's ``model`` axis via
            ``core.branch_parallel.run_spatial`` (needs a mesh, branch
            count dividing the axis, and identical output shapes).
  serial  — one op after another with the scheduler-chosen per-op
            algorithms (the algorithms-dict path ``models/cnn.py::forward``
            has always had); also the fallback when budgets are infeasible.
  xla     — emit the ops together inside one jit and trust XLA to
            interleave them (the framework baseline the paper critiques).

Mode choice delegates to ``cost_model.group_execution_time`` (the same
judgement the scheduler packs with); ``lower`` re-checks the
workspace/VMEM budgets (paper C2) — a group whose combined footprint no
longer fits is demoted to ``serial`` — and upgrades to ``spatial`` when a
mesh makes that faster than any single-chip mode.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.analysis import budgets as _budgets
from repro.core import cost_model as cm
from repro.core.graph import OpGraph
from repro.core.scheduler import Schedule

MODES = ("grouped", "grouped_concat", "grouped_pooled", "grouped_chained",
         "grouped_experts", "stacked", "fused", "spatial", "serial", "xla")


@dataclasses.dataclass(frozen=True)
class ExecGroup:
    """One schedulable unit of the executable plan."""
    mode: str                      # one of MODES
    ops: tuple[str, ...]
    algorithms: dict[str, str]     # op -> algorithm (serial fallback path)
    modeled_time: float            # cost-model makespan under ``mode``
    reason: str = ""               # why ``mode`` was chosen (debugging)
    join: str = ""                 # grouped_concat: the absorbed join op
    # absorbed maxpools: (branch op, pool op) pairs — the branch's lhs is
    # pooled in-launch from the pool op's input (grouped_pooled, and
    # grouped_concat groups whose branches pool)
    pools: tuple[tuple[str, str], ...] = ()
    # grouped_chained: the launch's phase structure — one tuple of op
    # names per phase (the join, if any, rides ``join`` and appears in
    # ``ops`` but not in ``chain``).  Phase p+1 branches whose producer
    # sits in phase p consume it through the in-kernel VMEM ring.
    chain: tuple[tuple[str, ...], ...] = ()

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"unknown mode {self.mode}")


@dataclasses.dataclass
class ChainPanels:
    """The composite value a chained launch leaves in ``env``: the padded
    per-phase output panels of ``grouped_matmul_chained`` plus the
    (panel, col-block base, true width) segment layout of the logical
    join, in join order.  The next chained launch consumes it IN PLACE
    (panel lhs-source descriptors, or a per-segment pooled fold) — no
    concat, no reshape; any non-chained consumer materializes it to NHWC
    through ``_env_val`` (one concatenate: exactly the join the chain
    otherwise deleted)."""
    panels: tuple                       # padded (Mp, ncb*blk) arrays
    segments: tuple[tuple[int, int, int], ...]   # (panel, col block, n)
    m: int                              # true rows (B*H*W)
    h: int
    w: int
    blk: int = 128

    @property
    def width(self) -> int:
        return sum(n for _, _, n in self.segments)


@dataclasses.dataclass
class Plan:
    """Ordered ExecGroups + the context needed to execute them."""
    groups: list[ExecGroup]
    context: dict = dataclasses.field(default_factory=dict)

    @property
    def makespan(self) -> float:
        return sum(g.modeled_time for g in self.groups)

    @property
    def algorithms(self) -> dict[str, str]:
        out: dict[str, str] = {}
        for g in self.groups:
            out.update(g.algorithms)
        return out

    def mode_counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for g in self.groups:
            out[g.mode] = out.get(g.mode, 0) + 1
        return out

    def groups_of_mode(self, mode: str) -> list[ExecGroup]:
        return [g for g in self.groups if g.mode == mode]


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------

# (M, K, N) GEMM view of an op — matmuls verbatim, convs via im2col; the
# shared definition lives next to the times it feeds.
_gemm_shape = cm.gemm_shape


def _spatial_ok(graph: OpGraph, ops, mesh) -> bool:
    """Branches with one shared producer and identical output element
    counts, dividing the mesh's model axis."""
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return False
    if mesh.shape["model"] % len(ops) != 0 or mesh.shape["model"] < len(ops):
        return False
    preds = [graph.pred[op.name] for op in ops]
    if any(len(p) != 1 for p in preds) or len({tuple(sorted(p))
                                               for p in preds}) != 1:
        return False
    outs = set()
    for op in ops:
        p = op.p
        if op.kind == "conv2d":
            s = p.get("stride", 1)
            outs.add((p["n"], -(-p["h"] // s), -(-p["w"] // s), p["k"]))
        elif op.kind == "matmul":
            outs.add((p["m"], p["n"]))
        else:
            return False
    return len(outs) == 1


def _absorb_concat_joins(graph: OpGraph,
                         groups: list[ExecGroup]) -> list[ExecGroup]:
    """Fuse fork/join concats into the grouped launches that feed them.

    A grouped group absorbs a join when (a) the join is the ONLY consumer
    of every op in the group (their outputs exist solely to be
    concatenated), (b) the join is a pointwise op lowered as its own
    singleton group later in the plan, and (c) every OTHER join input is
    produced by an earlier group (those arrive as passthrough column
    slices).  The merged ``grouped_concat`` group prices at
    ``cost_model.group_execution_time(..., join=...)`` — branch slices
    leave the kernel inside the join buffer, so only the passthrough
    columns keep a copy cost — and the standalone join group is dropped.
    """
    out: list[ExecGroup | None] = list(groups)
    for idx, g in enumerate(out):
        if g is None or g.mode not in ("grouped", "grouped_pooled") \
                or len(g.ops) < 2:
            continue
        succs = {s for n in g.ops for s in graph.succ[n]}
        if len(succs) != 1:
            continue
        (jname,) = succs
        jop = graph.ops.get(jname)
        if jop is None or jop.kind != "pointwise":
            continue
        if any(graph.succ[n] != {jname} for n in g.ops):
            continue
        jidx = next((k for k, gg in enumerate(out)
                     if gg is not None and gg.ops == (jname,)), None)
        if jidx is None or jidx < idx:
            continue
        produced = {n for gg in out[:idx] if gg is not None for n in gg.ops}
        produced.update(n for n in graph.ops if not graph.pred[n])
        if not all(p in produced for p in graph.pred[jname] - set(g.ops)):
            continue
        ops = [graph.ops[n] for n in g.ops]
        profs = [cm.profile(op, g.algorithms[op.name]) for op in ops]
        mode, t = cm.group_execution_time(ops, profs, join=jop)
        if mode != "grouped_concat" \
                or t >= g.modeled_time + out[jidx].modeled_time:
            continue
        algs = dict(g.algorithms)
        algs.update(out[jidx].algorithms)
        out[idx] = ExecGroup(
            "grouped_concat", g.ops + (jname,), algs, t,
            "fused epilogue-concat: branch slices land in the join "
            "buffer in-kernel", join=jname, pools=g.pools)
        out[jidx] = None
    return [g for g in out if g is not None]


def _absorb_pools(graph: OpGraph, groups: list[ExecGroup], *,
                  hbm_budget: float = cm.HBM_BYTES * 0.25,
                  vmem_budget: float = cm.VMEM_BYTES) -> list[ExecGroup]:
    """Stream standalone maxpool ops through the grouped launches that
    consume them (the pool analogue of ``_absorb_concat_joins``).

    A maxpool singleton group is absorbed when EVERY consumer of the pool
    is a GEMM-viewed branch of a LATER grouped-family group and none of
    those branches already pools another input — each consuming group
    then gains a per-branch ``pools`` descriptor (its launch pools the
    pool op's RAW input in-kernel: tap tiles maxed into the pooled-lhs
    scratch, see ``kernels/grouped_matmul.py``) and the standalone
    ``reduce_window`` group is dropped.  The fused rider is ZERO
    (``cost_model.pool_profile`` — the tap reads stream through the
    launch's existing lhs DMA and the pooled activation never touches
    HBM), so absorption wins by exactly the pool group's makespan; a
    consuming STACKED group is re-priced onto the grouped kernel (the
    pad-to-max kernel has no pool stage), which must still beat keeping
    the pool standalone.  Consumers may span several groups — an
    inter-module pool feeding two launches is pooled by each (recomputed
    taps instead of a materialized pooled tensor; recompute is free under
    the rider model, the ROADMAP's hw-calibration caveat applies).

    The pooled launch's footprint is re-checked against the C2 budgets
    ``lower`` gated the unpooled group on: the tap-expanded X stack packs
    up to ``POOL_TAP_LIMIT`` tap tiles per pooled lhs tile (extra HBM
    workspace; past the limit the taps fold at pack time and add
    nothing), and the pooled-lhs scratch claims VMEM — a pool whose
    absorption would bust a consuming group's budget stays standalone."""
    out: list[ExecGroup | None] = list(groups)
    for idx, pg in enumerate(out):
        if pg is None or len(pg.ops) != 1:
            continue
        (pname,) = pg.ops
        pop = graph.ops.get(pname)
        if pop is None or pop.kind != "maxpool":
            continue
        consumers = sorted(graph.succ[pname])
        if not consumers:
            continue
        targets: dict[int, list[str]] = {}
        ok = True
        for c in consumers:
            j = next((k for k, gg in enumerate(out)
                      if gg is not None and c in gg.ops), None)
            if (j is None or j <= idx
                    or out[j].mode not in ("grouped", "grouped_pooled",
                                           "grouped_concat", "stacked")
                    or _gemm_shape(graph.ops[c]) is None
                    # the branch must read the pool as its ONLY input (its
                    # gemm_x maps each raw tap view single-argument) and a
                    # branch can absorb at most one pool chain
                    or graph.pred[c] != {pname}
                    or any(b == c for b, _ in out[j].pools)):
                ok = False
                break
            targets.setdefault(j, []).append(c)
        if not ok:
            continue
        # price every affected group first — absorption is all-or-nothing
        # across the pool's consumers (a partially absorbed pool would
        # still have to launch standalone), and the win check aggregates:
        # dropping the pool group saves its makespan exactly ONCE, so the
        # SUM of repriced-group increases (stacked consumers moving onto
        # the grouped kernel) must stay below it
        repriced: dict[int, ExecGroup] = {}
        delta = 0.0
        for j, branches in targets.items():
            gg = out[j]
            # C2 re-check on the WHOLE pooled launch (pools already
            # absorbed into this group included); ``include_gemm_ws``
            # prices the grouped kernel's im2col patch buffers even when
            # a join op rides in the group, matching the gate ``lower``
            # applied to the unpooled group
            fp = _budgets.group_footprint(
                graph, gg.ops, gg.algorithms, include_gemm_ws=True,
                pools=tuple(gg.pools) + tuple((b, pname)
                                              for b in branches))
            if not fp.fits(hbm_budget, vmem_budget):
                ok = False
                break
            mode, t, reason = gg.mode, gg.modeled_time, gg.reason
            if gg.mode == "stacked":
                branch_ops = [graph.ops[n] for n in gg.ops]
                t = cm.grouped_time(branch_ops)
                mode = "grouped_pooled"
                reason = ("pool absorption: stacked branches take the "
                          "grouped kernel (the pooled lhs needs its "
                          "pool stage)")
                delta += t - gg.modeled_time
            elif gg.mode == "grouped":
                mode = "grouped_pooled"
                reason = ("in-kernel pre-GEMM maxpool: pooled lhs "
                          "streams from raw-input tap tiles")
            algs = dict(gg.algorithms)
            algs.update(pg.algorithms)   # the pool's choice survives
            repriced[j] = ExecGroup(
                mode, gg.ops, algs, t, reason, join=gg.join,
                pools=gg.pools + tuple((b, pname) for b in branches))
        if not ok or delta >= pg.modeled_time:
            continue
        for j, gg in repriced.items():
            out[j] = gg
        out[idx] = None
    return [g for g in out if g is not None]


def _chain_feasible(graph: OpGraph, phase0: list[str], branches: list[str],
                    join: str, *, block: int = 128) -> bool:
    """Geometry/topology gates for merging a quad group (phase 0) with the
    grouped_concat pair (phase 1) feeding off it into ONE chained launch:

      * every phase-1 branch is a stride-1 conv whose single producer is a
        phase-0 op and whose halo fits the ring window — the kernel loads
        row blocks i-1/i/i+1 into a (3*bm, blk) window and slices at
        bm+delta, so |delta| = (kh//2)*W + kw//2 must stay <= bm (= block);
      * phase-0 ops read no phase-0 op (the wave schedule runs a phase's
        branches at the same lag — intra-phase chaining has no ring slot);
      * nothing escapes the launch: every phase-0 output is consumed only
        by phase-1 branches or the join, and the join reads only in-launch
        branches (the ChainPanels segments must all come from this launch);
      * one shared GEMM M across every branch of both phases (the wave
        schedule advances all phases over the same row blocks).
    """
    qset, bset = set(phase0), set(branches)
    for b in branches:
        op = graph.ops.get(b)
        preds = graph.pred[b]
        if (op is None or op.kind != "conv2d"
                or op.p.get("stride", 1) != 1
                or len(preds) != 1 or not preds <= qset):
            return False
        halo = (op.p.get("kh", 1) // 2) * op.p["w"] + op.p.get("kw", 1) // 2
        if halo > block:
            return False
    for n in phase0:
        if graph.pred[n] & qset:
            return False
        if not graph.succ[n] <= bset | {join}:
            return False
    if not graph.pred[join] <= qset | bset:
        return False
    ms = {(_gemm_shape(graph.ops[n]) or (None,))[0] for n in phase0 + branches}
    return None not in ms and len(ms) == 1


def _chain_budgets_ok(graph: OpGraph, phases: list[list[str]], ring, *,
                      hbm_budget: float, vmem_budget: float,
                      block: int = 128) -> bool:
    """C2 re-check on the chained launch: the HBM workspace of its
    chained-priced GEMM lowering (ring consumers drop their patch buffer —
    their lhs never exists outside VMEM) plus the launch's ring scratch
    against the VMEM budget: 3 wave slots per ring column, the (3*bm, blk)
    shift window and the f32 accumulator.  The footprint itself comes
    from ``analysis.budgets.chained_footprint``."""
    return _budgets.chained_footprint(graph, phases, ring,
                                      block=block).fits(hbm_budget,
                                                        vmem_budget)


def _chain_modules(graph: OpGraph, groups: list[ExecGroup], *,
                   hbm_budget: float = cm.HBM_BYTES * 0.25,
                   vmem_budget: float = cm.VMEM_BYTES,
                   block: int = 128) -> list[ExecGroup]:
    """Chain grouped launches ACROSS module boundaries (the cross-module
    streaming pass, after ``_absorb_pools`` + ``_absorb_concat_joins``).

    Two rewrites, both producing ``grouped_chained`` groups that execute
    as ONE ``grouped_matmul_chained`` launch (kernels/grouped_matmul.py)
    running their phases in a lag-1 wave schedule — phase p+1 consumes
    phase p's freshly computed row blocks from an in-kernel VMEM ring,
    never touching HBM for that lhs:

      A. a quad group (grouped/grouped_pooled — e.g. an inception module's
         1x1/r3/r5/pp) merges with the grouped_concat pair riding on its
         reductions (3x3/5x5 + join) into a two-phase launch.  The join
         vanishes entirely: the launch's padded per-phase panels ARE the
         module output (a ``ChainPanels`` value), consumed in place by the
         next chained launch via panel lhs-source descriptors — the
         concat/copy the epilogue-concat mode still paid is gone.
      B. maximal runs of singleton serial conv groups (the stem) fold into
         one multi-phase launch, each conv a phase ring-consuming its
         predecessor — K*K convs stream as K^2 shifted tap-GEMMs.

    Gates: ``_chain_feasible`` (topology + ring-halo geometry),
    ``_chain_budgets_ok`` (C2), and a strict modeled win vs the groups
    merged (``cost_model.chained_time`` — co-execution over all phases
    with ring lhs traffic dropped, stretched by the wave-schedule fill
    factor).  Impl-level requirements (bias+ReLU epilogue, chain_geom)
    are the executor's to verify — a chained group whose bindings don't
    carry them degrades per-op like every other mode."""
    out: list[ExecGroup | None] = list(groups)
    # --- pass A: quad + pair -> one two-phase chained launch -------------
    for idx in range(len(out)):
        q = out[idx]
        if q is None or q.mode not in ("grouped", "grouped_pooled"):
            continue
        match = None
        for jdx in range(idx + 1, len(out)):
            pg = out[jdx]
            if pg is None or pg.mode != "grouped_concat" or not pg.join:
                continue
            branches = [n for n in pg.ops if n != pg.join]
            if {p for n in branches for p in graph.pred[n]} <= set(q.ops):
                match = (jdx, pg, branches)
                break
        if match is None:
            continue
        jdx, pg, branches = match
        if not _chain_feasible(graph, list(q.ops), branches, pg.join,
                               block=block):
            continue
        phases = [list(q.ops), branches]
        ring = frozenset(branches)
        if not _chain_budgets_ok(graph, phases, ring,
                                 hbm_budget=hbm_budget,
                                 vmem_budget=vmem_budget, block=block):
            continue
        phase_ops = [[graph.ops[n] for n in ph] for ph in phases]
        t = cm.chained_time(phase_ops, ring)
        if t >= q.modeled_time + pg.modeled_time:
            continue
        algs = dict(q.algorithms)
        algs.update(pg.algorithms)
        out[idx] = ExecGroup(
            "grouped_chained", q.ops + pg.ops, algs, t,
            "cross-module chain: reduction outputs stream to the K*K "
            "convs through the VMEM ring and the module output stays a "
            "panel composite (no join, no concat)",
            join=pg.join, pools=q.pools + pg.pools,
            chain=(tuple(q.ops), tuple(branches)))
        out[jdx] = None
    out = [g for g in out if g is not None]
    # --- pass B: serial conv runs -> one multi-phase chained launch ------
    sidx: dict[str, int] = {}
    for i, g in enumerate(out):
        if g.mode == "serial" and len(g.ops) == 1:
            op = graph.ops.get(g.ops[0])
            if op is not None and op.kind == "conv2d" \
                    and _gemm_shape(op) is not None:
                sidx[g.ops[0]] = i
    dead: set[int] = set()
    used: set[str] = set()
    for name in list(sidx):
        if name in used:
            continue
        run = [name]
        cur = name
        while True:
            succ = graph.succ[cur]
            if len(succ) != 1:
                break
            (nxt,) = succ
            if nxt not in sidx or nxt in used or graph.pred[nxt] != {cur}:
                break
            opn = graph.ops[nxt]
            if opn.p.get("stride", 1) != 1:
                break
            halo = (opn.p.get("kh", 1) // 2) * opn.p["w"] \
                + opn.p.get("kw", 1) // 2
            if halo > block:
                break
            if _gemm_shape(opn)[0] != _gemm_shape(graph.ops[cur])[0]:
                break
            run.append(nxt)
            cur = nxt
        used.update(run)
        if len(run) < 2:
            continue
        phases = [[n] for n in run]
        ring = frozenset(run[1:])
        if not _chain_budgets_ok(graph, phases, ring,
                                 hbm_budget=hbm_budget,
                                 vmem_budget=vmem_budget, block=block):
            continue
        phase_ops = [[graph.ops[n]] for n in run]
        t = cm.chained_time(phase_ops, ring)
        base = sum(out[sidx[n]].modeled_time for n in run)
        if t >= base:
            continue
        algs: dict[str, str] = {}
        for n in run:
            algs.update(out[sidx[n]].algorithms)
        out[sidx[run[0]]] = ExecGroup(
            "grouped_chained", tuple(run), algs, t,
            "serial-conv chain: each conv a phase ring-consuming its "
            "predecessor (K*K convs as K^2 shifted tap-GEMMs)",
            chain=tuple((n,) for n in run))
        dead.update(sidx[n] for n in run[1:])
    return [g for i, g in enumerate(out) if g is not None and i not in dead]


def _verify_requested(verify) -> bool:
    """planlint default: explicit flag wins; otherwise on under pytest or
    ``REPRO_PLANLINT=1`` (CI), off in production lowering paths."""
    if verify is not None:
        return bool(verify)
    import os
    return (os.environ.get("REPRO_PLANLINT") == "1"
            or "PYTEST_CURRENT_TEST" in os.environ)


def _maybe_verify(plan: Plan, graph: OpGraph | None, verify) -> Plan:
    """Run ``analysis.verify_plan`` on a freshly lowered plan when
    requested; raise ``PlanVerificationError`` on findings, stamp
    ``context["verified"]`` on success (what ``plan_cache`` records)."""
    if not _verify_requested(verify):
        return plan
    from repro import analysis
    findings = analysis.verify_plan(plan, graph)
    if findings:
        raise analysis.PlanVerificationError(findings)
    plan.context["verified"] = True
    return plan


def lower(graph: OpGraph, schedule: Schedule, *, mesh=None,
          hbm_budget: float = cm.HBM_BYTES * 0.25,
          vmem_budget: float = cm.VMEM_BYTES, train: bool = False,
          fuse_concat: bool = True, fuse_pool: bool = True,
          chain_modules: bool = False, verify: bool | None = None) -> Plan:
    """Lower a Schedule to an executable Plan.

    Mode choice per CoGroup: budget-infeasible or singleton -> serial;
    otherwise ``cost_model.group_execution_time`` picks the realizable
    single-chip mode (grouped ragged branch GEMM / stacked uniform-shape /
    fused complementary pair / xla interleave) at its modeled makespan,
    and a mesh upgrades same-output branches to ``spatial`` when the
    chip-split beats every single-chip mode.  ``fuse_pool`` (default)
    then streams each standalone maxpool through the grouped launch(es)
    consuming it (``_absorb_pools`` -> ``grouped_pooled`` / pooled
    groups — zero standalone ``reduce_window`` ops on the fused path),
    and ``fuse_concat`` (default) absorbs each fork/join concat into the
    grouped launch feeding it (``_absorb_concat_joins`` ->
    ``grouped_concat`` groups — zero standalone join ops).

    ``train=True`` additionally checks the C2 budgets against the
    group's backward profiles (each direction on its own — forward and
    backward are sequential launches, so their footprints never
    co-reside): a training step realizes the grad CoGroup of every
    co-executed group through its custom VJP (see ``backward_plan``), so
    a group whose backward footprint doesn't fit must run serial in BOTH
    directions — the mirrored plan never takes a co-execution decision
    the backward can't honor.
    """
    _REASON = {
        "grouped": "ragged shared-M GEMM branches -> grouped kernel "
                   "(uniform-K shared-X branches dedup to one wide GEMM "
                   "at execution)",
        "stacked": "same-shape GEMM branches",
        "fused": "compute+memory complementary pair",
        "xla": "heterogeneous group -> XLA interleave",
    }
    groups: list[ExecGroup] = []
    for cg in schedule.groups:
        ops = [graph.ops[n] for n in cg.ops]
        profs = [cm.profile(op, cg.algorithms[op.name]) for op in ops]
        # the footprint computation lives in ``analysis.budgets`` — it
        # prices the serial fallback AND (for a multi-op all-GEMM group)
        # the GEMM lowering's im2col patch buffers, whichever is larger
        feasible = _budgets.group_footprint(
            graph, cg.ops, cg.algorithms).fits(hbm_budget, vmem_budget)
        if train and feasible:
            # forward and backward are separate sequential launches whose
            # footprints never co-reside: each direction must fit the
            # budgets on its own (not their sum)
            feasible = _budgets.group_footprint(
                graph, cg.ops, cg.algorithms,
                direction="bwd").fits(hbm_budget, vmem_budget)
        if len(ops) == 1:
            mode, t, reason = "serial", cm.serial_time(profs), "singleton"
        elif cg.serialized or not feasible:
            mode, t = "serial", cm.serial_time(profs)
            reason = "budget-infeasible (C2 fallback)"
        else:
            mode, t = cm.group_execution_time(ops, profs)
            reason = _REASON[mode]
            if _spatial_ok(graph, ops, mesh):
                t_sp = cm.spatial_time(profs, mesh.shape["model"])
                if t_sp < t:
                    mode, t = "spatial", t_sp
                    reason = "branches fit the mesh model axis"
        groups.append(ExecGroup(mode, tuple(cg.ops), dict(cg.algorithms),
                                t, reason))
    if fuse_pool:
        groups = _absorb_pools(graph, groups, hbm_budget=hbm_budget,
                               vmem_budget=vmem_budget)
    if fuse_concat:
        groups = _absorb_concat_joins(graph, groups)
    if chain_modules:
        # cross-module streaming (opt-in): chain the absorbed launches —
        # quad + concat-pair pairs and serial conv runs — into
        # grouped_chained groups (see ``_chain_modules``)
        groups = _chain_modules(graph, groups, hbm_budget=hbm_budget,
                                vmem_budget=vmem_budget)
    plan = Plan(groups, context={"mesh": mesh, "graph": graph,
                                 "budgets": {"hbm": hbm_budget,
                                             "vmem": vmem_budget}})
    return _maybe_verify(plan, graph, verify)


# ---------------------------------------------------------------------------
# backward-plan lowering
# ---------------------------------------------------------------------------

def backward_plan(graph: OpGraph, plan: Plan, *,
                  hbm_budget: float = cm.HBM_BYTES * 0.25,
                  vmem_budget: float = cm.VMEM_BYTES,
                  verify: bool | None = None) -> Plan:
    """Derive the mirrored backward Plan from a lowered forward plan.

    The backward graph of a fork/join network is the forward graph
    reversed — the same CoGroups in mirrored order — and autodiff of
    ``run_plan`` realizes exactly that structure: a co-executed forward
    group pulls all its cotangents back through ONE custom VJP, so each
    forward ExecGroup becomes one grad ExecGroup (ops ``grad:<name>``)
    whose mode is what that VJP launches:

      grouped -> grouped   ONE combined launch: masked dx + dw/db over a
                           concatenated two-phase offset table
                           (``grouped_matmul_bwd``) — zero XLA fallbacks
                           and a single kernel per grad CoGroup.
      grouped_concat -> grouped_concat   the same combined launch; the
                           joint cotangent is sliced straight into its
                           packing, so the standalone join backward
                           (split) disappears with its forward.
      grouped_pooled -> grouped_pooled   the same combined launch; pooled
                           branches' lhs fold at pack time and the
                           pooling cotangent scatters through the
                           first-argmax window mask in the unpacking, so
                           the standalone pool backward disappears with
                           its forward (absorbed pools mirror as
                           ``grad:`` pools on the grad group).
      stacked -> stacked   ``branch_matmul``'s VJP runs the stacked
                           kernel on the backward GEMMs.
      serial  -> serial    per-op VJPs (convs take the stride-aware
                           GEMM-view backward ``models/cnn.py`` binds).
      fused / spatial -> serial   those VJPs pull back per-op through XLA.
      xla     -> xla       XLA interleaves the grad ops as it likes.

    The same C2 safety net applies: a grad group whose summed backward
    profiles exceed the budgets is priced serial (``lower(train=True)``
    makes the demotion bidirectional, so the mirror stays faithful).
    Makespans come from ``cost_model.group_execution_time_bwd`` /
    ``backward_profiles``.  The returned Plan is the lowering + pricing
    artifact for the training step's backward half — mode counts,
    ``Plan.makespan``, the benchmarks' modeled columns; execution flows
    through the VJPs of the forward plan, not through ``run_plan``.
    """
    _REASON = {
        "grouped": "mirror: ONE combined masked-dx + dw/db launch",
        "grouped_concat": "mirror: ONE combined launch, joint cotangent "
                          "sliced straight into its packing",
        "grouped_pooled": "mirror: ONE combined launch, pooling cotangent "
                          "scattered through the argmax mask in its "
                          "unpacking",
        "grouped_chained": "mirror: reverse-phase chain — ONE combined "
                           "masked-dx + dw/db launch per phase",
        "stacked": "mirror: stacked kernel VJP on the backward GEMMs",
        "serial": "per-op VJPs",
        "fused": "fused VJP pulls back per-op",
        "spatial": "spatial VJP pulls back per-op",
        "xla": "forward group already XLA-interleaved",
    }
    groups: list[ExecGroup] = []
    for g in reversed(plan.groups):
        ops = [graph.ops[n] for n in g.ops]
        bprofs = [p for op in ops
                  for p in cm.backward_profiles(
                      op, g.algorithms.get(op.name)
                      or cm.best_algorithm(op)[0])]
        # same accounting as ``lower(train=True)``'s gate — the shared
        # ``analysis.budgets`` computation keeps the mirror faithful
        feasible = _budgets.group_footprint(
            graph, g.ops, g.algorithms,
            direction="bwd").fits(hbm_budget, vmem_budget)
        if g.mode == "grouped_concat" and feasible:
            branch_ops = [op for op in ops if op.name != g.join]
            mode, t = cm.group_execution_time_bwd(
                branch_ops, g.algorithms, mode="grouped_concat",
                join=graph.ops[g.join])
            reason = _REASON[mode]
        elif g.mode == "grouped_chained" and feasible and g.chain:
            # the chained VJP mirrors the chain in REVERSE phase order —
            # one combined grouped launch per phase (a ring consumer's lhs
            # cotangent seeds the producer phase's dy, so phases cannot
            # backward-co-execute with each other)
            phase_ops = [[graph.ops[n] for n in ph] for ph in g.chain]
            mode, t = "grouped_chained", cm.chained_time_bwd(phase_ops,
                                                             g.algorithms)
            reason = _REASON[mode]
        elif g.mode in ("grouped", "grouped_pooled", "stacked") and feasible:
            mode, t = cm.group_execution_time_bwd(ops, g.algorithms,
                                                  mode=g.mode)
            reason = _REASON[mode]
        elif g.mode == "xla":
            mode, t = "xla", cm.xla_interleave_time(bprofs)
            reason = _REASON["xla"]
        else:
            mode, t = "serial", sum(p.time for p in bprofs)
            reason = ("budget-infeasible (C2 fallback)"
                      if g.mode in ("grouped", "grouped_concat",
                                    "grouped_pooled", "grouped_chained",
                                    "stacked")
                      else _REASON[g.mode])
        groups.append(ExecGroup(
            mode, tuple(f"grad:{n}" for n in g.ops),
            {f"grad:{n}": a for n, a in g.algorithms.items()}, t, reason,
            join=f"grad:{g.join}" if g.join else "",
            pools=tuple((f"grad:{b}", f"grad:{p}") for b, p in g.pools),
            chain=tuple(tuple(f"grad:{n}" for n in ph)
                        for ph in reversed(g.chain)) if g.chain else ()))
    bwd = Plan(groups, context={"forward": plan, "graph": graph,
                                "budgets": {"hbm": hbm_budget,
                                            "vmem": vmem_budget}})
    return _maybe_verify(bwd, graph, verify)


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class OpImpl:
    """Executable binding of one graph op (built by the model layer).

    ``fn(*dep_arrays, algorithm=...)`` is the universal path (serial / xla
    groups).  The optional views unlock the co-execution kernels:

      gemm_x/gemm_w/gemm_post — the op as ``post(x2d @ w)`` with
          x2d (M, K) from the deps and w (K, N): grouped + stacked + fused
          modes.  For a K×K conv, gemm_x is the im2col patch view.
      gemm_x_key — opt-in hashable token identifying the gemm_x
          *transform*: two impls with equal (deps, gemm_x_key) promise to
          produce the identical x2d.  When every branch of a grouped
          group shares one (deps, key) and one K, the executor dedups the
          shared X into ONE wide GEMM (weights concatenated along N — a
          single X read); the ragged kernel stays for mixed-K groups.
          ``None`` (the default) never dedups.
      gemm_bias/gemm_relu/gemm_reshape — split epilogue for grouped mode:
          when every branch provides bias + ReLU + a pure reshape, the
          grouped kernel fuses bias+ReLU in-kernel (no HBM round-trip)
          and only ``gemm_reshape`` runs outside.  ``gemm_post`` remains
          the out-of-kernel epilogue for stacked/fused and the non-fused
          grouped fallback — providing both must be equivalent.
      stream_z/stream_post — the op as ``post(silu(z).sum(0))`` with
          z (R, C) from the deps: the streamed branch of fused mode.
      pool_chain — maxpool ops only: the ((window, stride), ...) chain.
          What lets a grouped launch ABSORB the pool (grouped_pooled /
          pooled grouped_concat): the executor expands the pool's raw
          input into tap views (``kernels.pool_tap_views``) and the
          consuming branch's ``gemm_x`` maps each view; ``fn`` stays the
          standalone ``reduce_window`` chain (serial/degrade baseline).
      chain_geom — convs only: (kh, kw, stride, cin, oh, ow), the raw
          spatial geometry a ``grouped_chained`` launch needs to build
          ring tap-GEMM descriptors, panel-block weight layouts and the
          border masks — information ``gemm_x``'s closure hides.
    """
    deps: tuple[str, ...]
    fn: Callable[..., Any]
    gemm_x: Callable[..., Any] | None = None
    gemm_x_key: Any = None
    gemm_w: Any = None
    gemm_post: Callable[..., Any] | None = None
    gemm_bias: Any = None
    gemm_relu: bool = False
    gemm_reshape: Callable[..., Any] | None = None
    stream_z: Callable[..., Any] | None = None
    stream_post: Callable[..., Any] | None = None
    pool_chain: tuple | None = None
    chain_geom: tuple | None = None


def _materialize_chain(v: ChainPanels):
    """NHWC composite of a ChainPanels — the ONE concatenate a chained
    launch deleted, paid back only when a non-chained consumer (degrade
    path, custom graphs) actually needs the assembled tensor."""
    parts = [v.panels[p][:v.m, cb * v.blk: cb * v.blk + n]
             for p, cb, n in v.segments]
    x2 = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=-1)
    return x2.reshape(-1, v.h, v.w, x2.shape[-1])


def _env_val(env: dict, d: str):
    """Read ``env[d]``, materializing (and caching back) a ChainPanels for
    consumers that expect the plain NHWC value."""
    v = env[d]
    if isinstance(v, ChainPanels):
        v = _materialize_chain(v)
        env[d] = v
    return v


def _dep_args(impl: OpImpl, env: dict):
    return [_env_val(env, d) for d in impl.deps]


def _has_gemm_views(impl: OpImpl) -> bool:
    return (impl.gemm_x is not None and impl.gemm_w is not None
            and impl.gemm_post is not None)


def _has_stream_views(impl: OpImpl) -> bool:
    return impl.stream_z is not None and impl.stream_post is not None


def _stacked_runnable(group: ExecGroup, impls, pending) -> bool:
    """All ops unseeded and every impl carries the GEMM views the stacked
    kernel needs — ``lower`` decides modes from the graph alone, so fn-only
    ``OpImpl`` bindings (the model-agnostic path) must fall back here."""
    return (len(pending) == len(group.ops)
            and all(_has_gemm_views(impls[n]) for n in group.ops))


def _grouped_fusable(impls, names) -> bool:
    """Every branch carries the split epilogue -> bias+ReLU fuse in-kernel."""
    return all(impls[n].gemm_bias is not None and impls[n].gemm_relu
               and impls[n].gemm_reshape is not None for n in names)


def _grouped_runnable(group: ExecGroup, impls, pending) -> bool:
    if len(pending) != len(group.ops):
        return False
    if not all(impls[n].gemm_x is not None and impls[n].gemm_w is not None
               for n in group.ops):
        return False
    return _grouped_fusable(impls, group.ops) or all(
        impls[n].gemm_post is not None for n in group.ops)


def _pools_runnable(group: ExecGroup, impls, env) -> bool:
    """Every absorbed pool has a chain-carrying impl whose raw input is
    already materialized — else the group degrades (the pools run
    standalone via their ``fn`` and the branches read them normally)."""
    for _b, p in group.pools:
        pimpl = impls.get(p)
        if pimpl is None or pimpl.pool_chain is None \
                or len(pimpl.deps) != 1 or pimpl.deps[0] not in env:
            return False
    return True


def _branch_lhs(group: ExecGroup, impls, env, names):
    """Per-branch GEMM lhs: a plain 2D array, or — for a pool-absorbed
    branch — the tuple of raw-input tap views (each mapped through the
    branch's own ``gemm_x``) the pooled launch maxes in-kernel.

    Tap views are built ONCE per absorbed pool op and shared by every
    branch pooling it (the memo role the deleted ``memo1`` pre-transform
    helper played, now at tap granularity).  A chain whose expansion
    exceeds ``POOL_TAP_LIMIT`` folds HERE, before the per-tap ``gemm_x``
    mapping — max commutes with the gather/reshape views, so folding
    early is value- and gradient-identical while never materializing the
    (e.g. 81-view) expansion the kernel wrapper would immediately fold
    anyway."""
    from repro.kernels.grouped_matmul import (POOL_TAP_LIMIT,
                                              pool_from_taps,
                                              pool_tap_views)
    pools = dict(group.pools)
    views: dict[str, Any] = {}
    xs = []
    for n in names:
        impl = impls[n]
        if n in pools:
            pname = pools[n]
            if pname not in views:
                pimpl = impls[pname]
                vs = pool_tap_views(_env_val(env, pimpl.deps[0]),
                                    pimpl.pool_chain)
                views[pname] = pool_from_taps(vs) \
                    if len(vs) > POOL_TAP_LIMIT else vs
            v = views[pname]
            xs.append(impl.gemm_x(v) if not isinstance(v, list)
                      else tuple(impl.gemm_x(t) for t in v))
        else:
            xs.append(impl.gemm_x(*_dep_args(impl, env)))
    return xs


def _grouped_concat_runnable(group: ExecGroup, impls, env, pending) -> bool:
    """The absorbed-join launch needs: every branch with GEMM views AND
    the split in-kernel epilogue (the output goes straight into the join
    buffer — there is no out-of-kernel ``gemm_post`` stage to run), the
    join impl with its 2D->NHWC ``gemm_reshape`` view, and every
    passthrough join input already in ``env``."""
    if len(pending) != len(group.ops) or not group.join \
            or group.join not in impls:
        return False
    jimpl = impls[group.join]
    branches = [n for n in group.ops if n != group.join]
    if jimpl.gemm_reshape is None or not set(branches) <= set(jimpl.deps):
        return False
    if not all(impls[n].gemm_x is not None and impls[n].gemm_w is not None
               for n in branches):
        return False
    return _grouped_fusable(impls, branches) and all(
        d in env for d in jimpl.deps if d not in branches)


def _fused_runnable(group: ExecGroup, impls, pending) -> bool:
    if len(pending) != len(group.ops):
        return False
    gemm = [n for n in group.ops if _has_gemm_views(impls[n])]
    stream = [n for n in group.ops if _has_stream_views(impls[n])]
    return len(gemm) == 1 and len(stream) == 1 and gemm[0] != stream[0]


def _run_stacked(group: ExecGroup, impls: dict[str, OpImpl], env: dict,
                 interpret):
    """Pad-to-max stacking: every branch is padded to the widest (K, N)
    so the uniform-shape branch kernel applies — the baseline the grouped
    mode exists to beat on ragged branches."""
    from repro.kernels import branch_matmul  # padded (G,M,K)x(G,K,N) wrapper
    xs, ws, ns = [], [], []
    for name in group.ops:
        impl = impls[name]
        xs.append(impl.gemm_x(*_dep_args(impl, env)))
        ws.append(impl.gemm_w)
        ns.append(impl.gemm_w.shape[1])
    k_max = max(w.shape[0] for w in ws)
    n_max = max(ns)
    xs = [jnp.pad(x, ((0, 0), (0, k_max - x.shape[1]))) for x in xs]
    ws = [jnp.pad(w, ((0, k_max - w.shape[0]), (0, n_max - w.shape[1])))
          for w in ws]
    ys = branch_matmul(jnp.stack(xs), jnp.stack(ws), interpret=interpret)
    for i, name in enumerate(group.ops):
        impl = impls[name]
        env[name] = impl.gemm_post(ys[i][:, :ns[i]])


def _shared_x_wide(impls, names) -> bool:
    """Shared-input X dedup condition (ROADMAP item): every branch reads
    the SAME GEMM lhs — one (deps, gemm_x_key) bucket, opt-in via the
    key — with one K, so the group is a single wide GEMM along N."""
    i0 = impls[names[0]]
    if i0.gemm_x_key is None:
        return False
    if any(impls[n].deps != i0.deps or impls[n].gemm_x_key != i0.gemm_x_key
           for n in names):
        return False
    return len({impls[n].gemm_w.shape[0] for n in names}) == 1


def _dedup_buckets(impls, names, pools) -> list[list[str]]:
    """Order-preserving PARTIAL shared-X dedup: branches with equal
    (deps, gemm_x_key, K, absorbed pool) promise the identical GEMM lhs
    and bucket together — each multi-branch bucket becomes one wide
    sub-GEMM of the launch (lhs read once, weights concatenated along N)
    while the remaining singletons ride the same launch as ragged
    branches.  Generalizes ``_shared_x_wide``'s all-or-nothing condition:
    e.g. an inception quad's 1x1/r3/r5 trio dedups even though the
    pool-proj branch reads a different (pooled) input.  ``gemm_x_key is
    None`` (the default) never buckets."""
    buckets: list[list[str]] = []
    keyof: dict = {}
    for n in names:
        i = impls[n]
        key = None if i.gemm_x_key is None else (
            i.deps, i.gemm_x_key, i.gemm_w.shape[0], pools.get(n))
        if key is not None and key in keyof:
            buckets[keyof[key]].append(n)
        else:
            if key is not None:
                keyof[key] = len(buckets)
            buckets.append([n])
    return buckets


def _valid_rows(xs, valid_images, batch):
    """Per-group ragged-M row count: ``valid_images`` requests pack
    contiguously at the head of the batch axis, and every lhs of a group
    has M = batch * rows_per_image for ITS spatial extent — so the true
    row count is ``valid_images * (M // batch)``.  None when the launch
    is not ragged.

    Every lhs must agree on M and M must divide by ``batch`` — a silent
    floor here would hand the kernel a cutoff that splits an image and
    the masked launch would serve truncated rows as if they were real.
    """
    if valid_images is None:
        return None
    ms = {(x[0] if isinstance(x, (list, tuple)) else x).shape[0]
          for x in xs}
    if len(ms) != 1:
        raise ValueError(
            f"ragged group mixes lhs row counts {sorted(ms)} — "
            "valid-row masking needs one M per launch")
    return _valid_rows_from_m(ms.pop(), valid_images, batch)


def _valid_rows_from_m(m, valid_images, batch):
    """``_valid_rows`` from a known M (the chained path carries M as a
    python int rather than arrays)."""
    if valid_images is None:
        return None
    if m % batch != 0:
        raise ValueError(
            f"lhs M={m} is not a multiple of batch={batch} — "
            "rows_per_image would be fractional, so an image-aligned "
            "ragged cutoff cannot exist")
    return valid_images * (m // batch)


def _run_grouped(group: ExecGroup, impls: dict[str, OpImpl], env: dict,
                 interpret, valid_images=None, batch=None):
    # ragged, fused epilogue; pooled branches hand the launch their tap
    # views and the kernel's pool stage folds them (grouped_matmul_pooled
    # delegates to the plain grouped kernel when nothing pools)
    from repro.kernels.ops import grouped_matmul_pooled
    names = group.ops
    pools = dict(group.pools)
    fusable = _grouped_fusable(impls, names)
    buckets = _dedup_buckets(impls, names, pools)
    if len(buckets) < len(names):
        # shared-lhs buckets concatenate weights along N into ONE wide
        # sub-GEMM — the shared input is read (and, when pooled, tap-
        # folded) once per bucket instead of once per branch, and the
        # wide GEMM's VJP keeps the backward deduped too (one dx, one
        # wide dw/db, split by the concat's own pullback).  Singleton
        # buckets stay ragged branches of the SAME launch.
        xs = [_branch_lhs(group, impls, env, bk[:1])[0] for bk in buckets]
        mv = _valid_rows(xs, valid_images, batch)
        ws_b = [impls[bk[0]].gemm_w if len(bk) == 1 else
                jnp.concatenate([impls[n].gemm_w for n in bk], axis=1)
                for bk in buckets]
        if fusable:
            bs_b = [impls[bk[0]].gemm_bias if len(bk) == 1 else
                    jnp.concatenate([impls[n].gemm_bias for n in bk])
                    for bk in buckets]
            ys = grouped_matmul_pooled(xs, ws_b, bs_b, relu=True,
                                       m_valid=mv, interpret=interpret)
        else:
            ys = grouped_matmul_pooled(xs, ws_b, m_valid=mv,
                                       interpret=interpret)
        for bk, y in zip(buckets, ys):
            off = 0
            for n in bk:
                sl = y[:, off:off + impls[n].gemm_w.shape[1]]
                env[n] = impls[n].gemm_reshape(sl) if fusable \
                    else impls[n].gemm_post(sl)
                off += impls[n].gemm_w.shape[1]
        return
    ws = [impls[n].gemm_w for n in names]
    xs = _branch_lhs(group, impls, env, names)
    mv = _valid_rows(xs, valid_images, batch)
    if fusable:
        ys = grouped_matmul_pooled(xs, ws,
                                   [impls[n].gemm_bias for n in names],
                                   relu=True, m_valid=mv,
                                   interpret=interpret)
        for n, y in zip(names, ys):
            env[n] = impls[n].gemm_reshape(y)
    else:
        ys = grouped_matmul_pooled(xs, ws, m_valid=mv, interpret=interpret)
        for n, y in zip(names, ys):
            env[n] = impls[n].gemm_post(y)


def _chained_runnable(group: ExecGroup, impls, env, pending) -> bool:
    """The chained launch needs every phase op bound with the in-kernel
    epilogue (bias+ReLU is hardcoded in the chained kernel), its raw conv
    geometry (``chain_geom``) and a single dep that is either an earlier
    phase (ring), an absorbed pool, or already materialized; the join (if
    any) must read only in-launch ops.  Anything missing degrades the
    whole group to the per-op path."""
    if len(pending) != len(group.ops) or not group.chain:
        return False
    names = [n for ph in group.chain for n in ph]
    if set(group.ops) - set(names) - ({group.join} if group.join else set()):
        return False
    pools = dict(group.pools)
    opset = set(names)
    for n in names:
        impl = impls.get(n)
        if impl is None or impl.chain_geom is None or impl.gemm_w is None \
                or impl.gemm_bias is None or not impl.gemm_relu \
                or len(impl.deps) != 1:
            return False
        d = impl.deps[0]
        if d not in opset and n not in pools and d not in env:
            return False
    if group.join:
        jimpl = impls.get(group.join)
        if jimpl is None or set(jimpl.deps) - opset:
            return False
    return _pools_runnable(group, impls, env)


def _pool_fold(v, chain):
    """Maxpool ``chain`` applied to an NHWC array or — per segment, since
    pooling commutes with the channel concat — to a ChainPanels composite,
    packed back into ONE dense (B*OH*OW, C) lhs with dynamic_update_slice:
    no concatenate, no standalone reduce_window."""
    from repro.kernels.ops import pool_from_taps, pool_tap_views
    if not isinstance(v, ChainPanels):
        p = pool_from_taps(pool_tap_views(v, chain))
        return p.reshape(-1, p.shape[-1])
    segs = []
    for pidx, cb, n in v.segments:
        seg = v.panels[pidx][:v.m, cb * v.blk: cb * v.blk + n]
        p = pool_from_taps(pool_tap_views(seg.reshape(-1, v.h, v.w, n),
                                          chain))
        segs.append(p.reshape(-1, n))
    out = jnp.zeros((segs[0].shape[0], sum(s.shape[1] for s in segs)),
                    segs[0].dtype)
    off = 0
    for s in segs:
        out = jax.lax.dynamic_update_slice(out, s, (0, off))
        off += s.shape[1]
    return out


def _panel_desc(v: ChainPanels):
    """Panel lhs-source descriptors of a ChainPanels consumed IN PLACE:
    one (panel, col block) per padded block in segment (= join) order,
    plus the true-channel row range of the consumer's weight each block
    covers (block rows past a segment's true width meet zero weight
    rows, so the panels' zero-padded columns contribute nothing)."""
    blocks, ranges = [], []
    coff = 0
    for pidx, cb, n in v.segments:
        nbb = -(-n // v.blk)
        for j in range(nbb):
            blocks.append((pidx, cb + j))
            lo = coff + j * v.blk
            ranges.append((lo, min(coff + n, lo + v.blk)))
        coff += n
    return blocks, ranges


def _pad_w_dense(wmat, blk):
    """Row-pad a dense (K, N) weight to the k-step grid (ceil(K/blk)*blk
    rows) — the layout matching a dense x lhs's padded col blocks."""
    kb = -(-wmat.shape[0] // blk)
    return jnp.pad(wmat, ((0, kb * blk - wmat.shape[0]), (0, 0)))


def _pack_w_blocks(wmat, ranges, blk):
    """Weight rows rearranged to panel-descriptor k-step order: block s
    holds ``wmat[lo:hi]`` at its top (zero rows elsewhere), matching the
    consumed panel block's true channels."""
    buf = jnp.zeros((len(ranges) * blk, wmat.shape[1]), wmat.dtype)
    for s, (lo, hi) in enumerate(ranges):
        buf = jax.lax.dynamic_update_slice(buf, wmat[lo:hi], (s * blk, 0))
    return buf


def _pack_w_ring(wmat, kh, kw, cin, nrc, blk):
    """Ring-consumer weight in tap-major/ring-col-minor k-step order: the
    (C, KH, KW)-ordered im2col weight ``wmat`` strided-sliced per tap
    (rows dh*kw+dw :: kh*kw give w[dh, dw]) and laid out per ring column
    block — the order ``_chain_ksteps`` emits the tap-GEMMs in."""
    buf = jnp.zeros((kh * kw * nrc * blk, wmat.shape[1]), wmat.dtype)
    s = 0
    for dh in range(kh):
        for dw in range(kw):
            tap = jax.lax.slice(wmat, (dh * kw + dw, 0), wmat.shape,
                                (kh * kw, 1))          # (cin, nout)
            for j in range(nrc):
                lo = j * blk
                if lo < cin:
                    buf = jax.lax.dynamic_update_slice(
                        buf, tap[lo:min(lo + blk, cin)], (s * blk, 0))
                s += 1
    return buf


def _panel_index(panels: list, arr) -> int:
    for i, p in enumerate(panels):
        if p is arr:
            return i
    panels.append(arr)
    return len(panels) - 1


def _run_grouped_chained(group: ExecGroup, impls: dict[str, OpImpl],
                         env: dict, interpret, valid_images=None,
                         batch=None):
    """Execute a ``grouped_chained`` group as ONE multi-phase launch.

    Per-branch lhs sources, in preference order:
      ring   — dep is an earlier phase of THIS launch: the kernel streams
               the producer's row-block panels through the VMEM ring
               (K*K convs as K^2 shifted tap-GEMMs; weights repacked
               tap-major by ``_pack_w_ring``).
      pooled — dep is an absorbed pool: the pool folds OUTSIDE the kernel
               (``_pool_fold``, per ChainPanels segment — max commutes
               with the channel concat) into one dense lhs.
      panel  — dep is the PREVIOUS chained launch's ChainPanels and the
               conv is pointwise: lhs-source descriptors address the
               producer's padded panels in place (zero copies; weights
               repacked per block by ``_pack_w_blocks``).
      x      — anything else: the branch's own ``gemm_x`` view (the stem
               head's strided im2col, custom graphs), packed by the
               kernel wrapper.

    The launch's padded output panels become a ``ChainPanels`` env value
    under the join's name (or the last phase op's, stem chains) — the
    module boundary never materializes."""
    from repro.kernels.ops import grouped_matmul_chained
    blk = 128
    pools = dict(group.pools)
    opset = {n for ph in group.chain for n in ph}
    consumed = {impls[n].deps[0] for ph in group.chain for n in ph
                if impls[n].deps[0] in opset}
    ring_cols: dict[str, tuple] = {}
    nxt = 0
    for ph in group.chain:
        for n in ph:
            if n in consumed:
                nbb = -(-impls[n].gemm_w.shape[1] // blk)
                ring_cols[n] = tuple(range(nxt, nxt + nbb))
                nxt += nbb
    pooled: dict[str, Any] = {}
    for _b, pname in group.pools:
        if pname not in pooled:
            pimpl = impls[pname]
            pooled[pname] = _pool_fold(env[pimpl.deps[0]],
                                       pimpl.pool_chain)
    panels: list = []
    phase_dicts = []
    m = None
    geom = None
    for ph in group.chain:
        brs = []
        for n in ph:
            impl = impls[n]
            kh, kw, stride, cin, oh, ow = impl.chain_geom
            wmat = impl.gemm_w
            d = impl.deps[0]
            if d in opset:
                rcs = ring_cols[d]
                src = ("ring", kh, kw, rcs)
                wpk = _pack_w_ring(wmat, kh, kw, cin, len(rcs), blk)
            elif n in pools:
                x2d = pooled[pools[n]]
                src, wpk, m = ("x", [x2d]), _pad_w_dense(wmat, blk), \
                    x2d.shape[0]
            else:
                v = env[d]
                if isinstance(v, ChainPanels) and (kh, kw) == (1, 1) \
                        and stride == 1:
                    blocks, ranges = _panel_desc(v)
                    used = sorted({p for p, _ in blocks})
                    if len(used) <= 2:     # kernel addresses <= 2 panels
                        remap = {p: _panel_index(panels, v.panels[p])
                                 for p in used}
                        src = ("panel", [(remap[p], cb)
                                         for p, cb in blocks])
                        wpk, m = _pack_w_blocks(wmat, ranges, blk), v.m
                    else:
                        x2d = _materialize_chain(v).reshape(v.m, -1)
                        src, wpk, m = ("x", [x2d]), \
                            _pad_w_dense(wmat, blk), v.m
                else:
                    x2d = impl.gemm_x(_env_val(env, d))
                    src, wpk, m = ("x", [x2d]), _pad_w_dense(wmat, blk), \
                        x2d.shape[0]
            if geom is None:
                geom = (oh, ow)
            brs.append({"n": wmat.shape[1], "w": wpk, "b": impl.gemm_bias,
                        "src": src, "ring_write": ring_cols.get(n)})
        phase_dicts.append(brs)
    assert m is not None and geom is not None, group.ops
    mv = _valid_rows_from_m(m, valid_images, batch)
    outs = grouped_matmul_chained(phase_dicts, m=m, h=geom[0], w=geom[1],
                                  panels=tuple(panels), block=blk,
                                  m_valid=mv, interpret=interpret)
    lay: dict[str, tuple[int, int, int]] = {}
    for p, ph in enumerate(group.chain):
        cb = 0
        for n in ph:
            nout = impls[n].gemm_w.shape[1]
            lay[n] = (p, cb, nout)
            cb += -(-nout // blk)
    if group.join:
        out_name = group.join
        order = list(impls[group.join].deps)
    else:
        out_name = group.chain[-1][-1]
        order = [out_name]
    env[out_name] = ChainPanels(
        panels=tuple(outs), segments=tuple(lay[n] for n in order),
        m=m, h=geom[0], w=geom[1], blk=blk)


def _run_grouped_concat(group: ExecGroup, impls: dict[str, OpImpl], env: dict,
                        interpret, valid_images=None, batch=None):
    """Fused epilogue-concat execution: the grouped kernel writes every
    in-launch branch's bias+ReLU output straight into its slice of the
    join's (M, sum N_g) buffer; join inputs produced by EARLIER groups
    (e.g. the 1x1/pool-proj outputs of an inception quad) are copied in
    as passthrough column slices.  Only the join gets an env entry — the
    absorption condition guarantees the join is every in-launch branch's
    sole consumer, so their standalone outputs would be dead values (and
    materializing them would be exactly the per-branch round-trip this
    mode deletes)."""
    from repro.kernels.ops import (grouped_block_shape,
                                   grouped_matmul_pooled_concat)
    jimpl = impls[group.join]
    branches = [n for n in group.ops if n != group.join]
    offs: dict[str, int] = {}
    widths: dict[str, int] = {}
    off = 0
    for d in jimpl.deps:
        w = impls[d].gemm_w.shape[1] if d in branches \
            else _env_val(env, d).shape[-1]
        offs[d], widths[d] = off, w
        off += w
    order = [d for d in jimpl.deps if d in branches]
    xs = _branch_lhs(group, impls, env, order)
    ws = [impls[n].gemm_w for n in order]
    x0 = xs[0][0] if isinstance(xs[0], tuple) else xs[0]
    # the PADDED join buffer (compact=False): branch g's true columns sit
    # at the cumulative padded base, so the join assembles as ONE
    # concatenate of passthrough segments and (maximal) buffer slices —
    # strictly less copying than per-branch outputs + a standalone concat
    # (pooled branches ride the same launch via their tap views)
    y2d = grouped_matmul_pooled_concat(
        xs, ws, [impls[n].gemm_bias for n in order],
        offsets=[offs[n] for n in order], total=off, relu=True,
        compact=False, m_valid=_valid_rows(xs, valid_images, batch),
        interpret=interpret)
    bn = grouped_block_shape(
        x0.shape[0], [(w.shape[0], w.shape[1]) for w in ws],
        x0.dtype).bn
    pbase = {}
    base = 0
    for n, w in zip(order, ws):
        pbase[n] = base
        base += -(-w.shape[1] // bn) * bn
    segs: list = []       # (lo, hi) buffer slices interleaved with pt 2D
    for d in jimpl.deps:
        if d in branches:
            lo, hi = pbase[d], pbase[d] + widths[d]
            if segs and isinstance(segs[-1], tuple) and segs[-1][1] == lo:
                segs[-1] = (segs[-1][0], hi)       # extend a contiguous run
            else:
                segs.append((lo, hi))
        else:
            segs.append(_env_val(env, d).reshape(-1, widths[d])
                        .astype(y2d.dtype))
    parts = [y2d[:, s[0]:s[1]] if isinstance(s, tuple) else s for s in segs]
    joined = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=-1)
    env[group.join] = jimpl.gemm_reshape(joined)


def _run_fused(group: ExecGroup, impls: dict[str, OpImpl], env: dict,
               interpret):
    from repro.kernels.ops import fused_gemm_reduce  # padded wrapper
    gemm = [n for n in group.ops if _has_gemm_views(impls[n])]
    stream = [n for n in group.ops if _has_stream_views(impls[n])]
    assert len(gemm) == 1 and len(stream) == 1, group.ops
    gi, si = impls[gemm[0]], impls[stream[0]]
    x2d = gi.gemm_x(*_dep_args(gi, env))
    z = si.stream_z(*_dep_args(si, env))
    c, r = fused_gemm_reduce(x2d, gi.gemm_w, z, interpret=interpret)
    env[gemm[0]] = gi.gemm_post(c)
    env[stream[0]] = si.stream_post(r)


def _run_spatial_group(group: ExecGroup, impls: dict[str, OpImpl], env: dict,
                       mesh):
    from repro.core import branch_parallel as bp
    dep = impls[group.ops[0]].deps[0]
    fns = [impls[n].fn for n in group.ops]
    br = bp.Branches(fns, combine="stack")
    ys = bp.run_spatial(br, _env_val(env, dep), mesh)    # (G, B, ...)
    for i, name in enumerate(group.ops):
        env[name] = ys[i]


def _scope(group: ExecGroup, executed: str | None = None, *, op=None):
    """Provenance scope for everything a group (or one serial/degraded
    op) emits: ``analysis/fallbacks.py`` attributes surviving fallback
    primitives in a traced plan to these ``jax.named_scope`` tags, so a
    zero-fallback gate reports WHICH op regressed instead of a bare
    count.  ``/`` nests scopes in a jaxpr name stack, so op names
    sanitize to ``.``."""
    mode = executed or group.mode
    tag = (op if op is not None else group.ops[0]).replace("/", ".")
    return jax.named_scope(f"plan[{mode}:{tag}]")


def run_plan(impls: dict[str, OpImpl], env: dict, plan: Plan, *,
             mesh=None, interpret=None, timings: dict | None = None,
             valid_images=None) -> dict:
    """Execute a lowered plan over ``impls``; returns the op->value env.

    ``env`` seeds graph sources (ops with no deps / externally computed
    values); seeded ops are never recomputed in any mode.  A co-execution
    group (stacked / fused) whose impls lack the gemm/stream views — or
    that is partially seeded — degrades to the per-op xla path rather than
    failing: ``lower`` picks modes from the graph alone and cannot see the
    bindings.  ``timings``, when a dict, collects eager per-mode wall time
    {mode: seconds} — only meaningful outside jit; degraded groups are
    keyed ``"<mode>->xla"`` so they never masquerade as the co-execution
    kernel they skipped.

    ``valid_images`` (python int or traced i32 scalar) makes every
    grouped/pooled/concat launch ragged-M: requests pack contiguously at
    the head of the batch axis and only the first ``valid_images`` images
    are real — each launch masks its padded-M tail in-kernel (zero-stored
    epilogue rows past the group's true row count).  Inference-only (the
    ragged kernels bypass the custom VJPs), and requires
    ``plan.context["batch"]`` (the bucket size the plan was lowered for).
    Batch elements never mix inside a launch (im2col, pooling and ring
    taps are image-local by the border masks), so the first
    ``valid_images`` outputs are exactly the dense run's.  Chained groups
    mask too: the launch skips M-blocks past the cutoff as no-op waves
    (dead blocks run zero GEMM/ring/pool steps) and zero-stores the live
    tail block, so the next launch's panel descriptors and ring taps read
    clean producer slots instead of relying on the caller to drop
    garbage.
    """
    import time as _time
    import jax as _jax

    mesh = mesh if mesh is not None else plan.context.get("mesh")
    batch = plan.context.get("batch")
    if valid_images is not None:
        assert batch is not None, \
            "valid_images needs plan.context['batch'] (the bucket size)"
    for group in plan.groups:
        t0 = _time.perf_counter() if timings is not None else 0.0
        pending = [n for n in group.ops if n not in env]
        if not pending:
            continue
        executed = group.mode
        if group.mode in ("grouped", "grouped_pooled") \
                and _grouped_runnable(group, impls, pending) \
                and _pools_runnable(group, impls, env):
            with _scope(group):
                _run_grouped(group, impls, env, interpret,
                             valid_images=valid_images, batch=batch)
        elif group.mode == "grouped_concat" and _grouped_concat_runnable(
                group, impls, env, pending) \
                and _pools_runnable(group, impls, env):
            with _scope(group):
                _run_grouped_concat(group, impls, env, interpret,
                                    valid_images=valid_images, batch=batch)
        elif group.mode == "grouped_chained" and _chained_runnable(
                group, impls, env, pending):
            with _scope(group):
                _run_grouped_chained(group, impls, env, interpret,
                                     valid_images=valid_images,
                                     batch=batch)
        elif group.mode == "stacked" and _stacked_runnable(group, impls,
                                                           pending):
            with _scope(group):
                _run_stacked(group, impls, env, interpret)
        elif group.mode == "fused" and _fused_runnable(group, impls,
                                                       pending):
            with _scope(group):
                _run_fused(group, impls, env, interpret)
        elif group.mode == "spatial" and len(pending) == len(group.ops):
            with _scope(group):
                _run_spatial_group(group, impls, env, mesh)
        else:
            # serial: scheduler-chosen per-op algorithm kernels.
            # xla: native ops emitted together; XLA interleaves.  Also the
            # degraded path for co-execution groups (see docstring).
            if group.mode not in ("serial", "xla"):
                executed = f"{group.mode}->xla"
            # a degraded pooled group must first materialize its absorbed
            # pools (the plan dropped their standalone groups): run each
            # pool op's fn — the reduce_window baseline — so the branch
            # fns can read their declared deps
            for _b, p in group.pools:
                if p in env:
                    continue
                pimpl = impls.get(p)
                if pimpl is None:
                    raise KeyError(
                        f"absorbed pool op {p!r} has no OpImpl: a degraded "
                        f"pooled group runs the pool's fn to materialize "
                        f"its branches' input — pool ops ride group.pools "
                        f"(not group.ops), so bind an impl for {p!r} too")
                with _scope(group, executed, op=p):
                    env[p] = pimpl.fn(*_dep_args(pimpl, env))
            for name in pending:
                impl = impls[name]
                alg = group.algorithms.get(name) if group.mode == "serial" \
                    else "xla"
                with _scope(group, executed, op=name):
                    env[name] = impl.fn(*_dep_args(impl, env),
                                        algorithm=alg)
        if timings is not None:
            vals = []
            for n in group.ops:
                v = env.get(n)
                if isinstance(v, ChainPanels):
                    vals.extend(v.panels)
                elif v is not None:
                    vals.append(v)
            _jax.block_until_ready(vals)
            timings[executed] = timings.get(executed, 0.0) \
                + (_time.perf_counter() - t0)
    return env


def execute_plan(params, x, plan: Plan, *, mesh=None, interpret=None,
                 valid_images=None):
    """Entry point for the repo's native subject: run a plan produced by
    ``models.cnn.plan_cnn`` on images ``x`` with CNN ``params``.

    Model-agnostic execution (custom graphs) goes through ``run_plan`` with
    explicit ``OpImpl`` bindings instead.  ``valid_images`` as in
    ``run_plan`` (ragged-M serving batches; inference-only).
    """
    cfg = plan.context.get("cfg")
    if cfg is None:
        raise ValueError("plan has no cfg context — produce it with "
                         "models.cnn.plan_cnn, or use run_plan directly")
    from repro.models import cnn
    return cnn.forward_plan(params, cfg, x, plan, mesh=mesh,
                            interpret=interpret,
                            valid_images=valid_images)


# ---------------------------------------------------------------------------
# MoE lowering: the expert fork as ONE grouped-family launch
# ---------------------------------------------------------------------------

def lower_moe(graph: OpGraph, *, b: int, s: int, d: int, f: int, e: int,
              top_k: int, capacity_factor: float, gated: bool = True,
              shared_f: int = 0, bm: int | None = None,
              dtype_bytes: int = 4, verify: bool | None = None) -> Plan:
    """Lower one MoE layer's op graph (``models.moe.build_moe_graph``) to
    a Plan whose expert fork is a single ``grouped_experts`` ExecGroup.

    The E expert chains the graph exposes as 3E (2E ungated) independent
    matmuls at the einsum engine's padded M = B*cap collapse into ONE
    per-expert-ragged launch per direction; the group's ``modeled_time``
    is ``cost_model.moe_grouped_profile`` over the static routed-token
    grid, and ``reason`` records the pricing against the capacity-padded
    einsum and the pad-to-max stacked baselines so the decision is
    auditable from the plan alone.  Router / combine / shared-MLP ops
    stay serial groups (they are the fork and join, not branches)."""
    from repro.models.moe import moe_capacity

    if bm is None:
        from repro.kernels import moe_block_m
        bm = moe_block_m(b * s * top_k, e)
    sk = s * top_k
    cap = moe_capacity(sk, capacity_factor, e)
    n_slots = b * sk
    times = cm.moe_dispatch_times(n_slots, b, cap, e, d, f, gated=gated,
                                  bm=bm, dtype_bytes=dtype_bytes)

    expert_ops = tuple(n for n in graph.ops if n.startswith("expert"))
    assert len(expert_ops) == (3 if gated else 2) * e, expert_ops
    groups = [
        ExecGroup("serial", ("moe_router",), {"moe_router": "mxu128"},
                  cm.profile(graph.ops["moe_router"], "mxu128").time),
        ExecGroup(
            "grouped_experts", expert_ops, {}, times["grouped"],
            reason=(f"{len(expert_ops)} expert GEMMs -> 1 ragged launch: "
                    f"grouped {times['grouped'] * 1e6:.2f}us vs einsum "
                    f"{times['einsum'] * 1e6:.2f}us vs stacked "
                    f"{times['stacked'] * 1e6:.2f}us")),
        ExecGroup("serial", ("moe_combine",), {"moe_combine": "vpu"},
                  cm.profile(graph.ops["moe_combine"], "vpu").time),
    ]
    if shared_f:
        shared_ops = tuple(n for n in graph.ops if n.startswith("shared"))
        sprofs = [cm.profile(graph.ops[n], "mxu128") for n in shared_ops]
        groups.append(ExecGroup("serial", shared_ops,
                                {n: "mxu128" for n in shared_ops},
                                cm.serial_time(sprofs)))
    ctx = {"moe": {"b": b, "s": s, "d": d, "f": f, "e": e, "top_k": top_k,
                   "capacity_factor": capacity_factor, "gated": gated,
                   "shared_f": shared_f, "bm": bm, "cap": cap,
                   "n_slots": n_slots, "times": times}}
    ctx["graph"] = graph
    return _maybe_verify(Plan(groups, ctx), graph, verify)
