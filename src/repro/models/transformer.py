"""Generic transformer/hybrid/SSM language model.

One implementation serves all ten assigned architectures:
  * per-layer block *pattern* (attention / mamba / MoE / dense / windows /
    cross-attention) repeated ``n_layers / len(pattern)`` times;
  * scan-over-super-blocks with stacked params — HLO size is independent of
    depth (mandatory for the 512-device dry-run compiles);
  * optional encoder stack (whisper) and modality-frontend stubs (vlm/audio
    embeddings are inputs, per the assignment);
  * KV cache (attention) + recurrent state (mamba) for decode;
  * activation sharding via ``repro.sharding.constrain`` (no-op without mesh).

Params are plain nested dicts; everything is a pure function.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import BlockSpec, ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.models import moe as MOE
from repro.sharding import constrain


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, spec: BlockSpec, dtype):
    ks = iter(jax.random.split(key, 8))
    norm_init = L.rmsnorm_init if cfg.norm == "rms" else L.layernorm_init
    p: dict = {"norm1": norm_init(cfg.d_model, dtype)}
    if spec.mixer == "attn":
        p["attn"] = A.attn_init(next(ks), cfg.d_model, cfg.n_heads,
                                cfg.n_kv_heads, cfg.head_dim, dtype,
                                qkv_bias=cfg.qkv_bias)
    elif spec.mixer == "mamba":
        s = cfg.ssm
        p["mamba"] = M.mamba_init(next(ks), cfg.d_model, d_inner=s.d_inner,
                                  n_heads=s.n_heads, head_dim=s.head_dim,
                                  d_state=s.d_state, n_groups=s.n_groups,
                                  conv_width=s.conv_width, dtype=dtype)
    if spec.cross:
        p["norm_x"] = norm_init(cfg.d_model, dtype)
        p["cross"] = A.attn_init(next(ks), cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.head_dim, dtype)
    if spec.mlp != "none":
        p["norm2"] = norm_init(cfg.d_model, dtype)
    if spec.mlp == "dense":
        p["mlp"] = L.mlp_init(next(ks), cfg.d_model, cfg.d_ff,
                              gated=cfg.activation in ("silu", "gelu"),
                              dtype=dtype)
    elif spec.mlp == "moe":
        mo = cfg.moe
        p["moe"] = MOE.moe_init(next(ks), cfg.d_model, mo.d_expert,
                                mo.n_experts, shared_f=mo.shared_f,
                                dtype=dtype)
    if cfg.post_norm:
        p["post_norm1"] = norm_init(cfg.d_model, dtype)
        if spec.mlp != "none":
            p["post_norm2"] = norm_init(cfg.d_model, dtype)
    return p


def init_params(cfg: ModelConfig, key, dtype=jnp.float32):
    kemb, kblocks, kenc, kfin = jax.random.split(key, 4)
    n_super = cfg.n_layers // len(cfg.pattern)
    assert n_super * len(cfg.pattern) == cfg.n_layers, \
        f"{cfg.name}: pattern {len(cfg.pattern)} !| layers {cfg.n_layers}"
    params: dict = {
        "embed": L.embed_init(kemb, cfg.vocab, cfg.d_model, dtype),
        "final_norm": (L.rmsnorm_init if cfg.norm == "rms"
                       else L.layernorm_init)(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.embed_init(kfin, cfg.vocab, cfg.d_model, dtype)

    # stacked blocks: params["blocks"][pos] has leaves (n_super, ...)
    def stack_pos(pos):
        keys = jax.random.split(jax.random.fold_in(kblocks, pos), n_super)
        per = [_block_init(k, cfg, cfg.pattern[pos], dtype) for k in keys]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    params["blocks"] = [stack_pos(p) for p in range(len(cfg.pattern))]

    if cfg.enc_dec:
        enc_spec = BlockSpec(mixer="attn", mlp="dense")
        keys = jax.random.split(kenc, cfg.n_enc_layers)
        per = [_block_init(k, cfg, enc_spec, dtype) for k in keys]
        params["enc_blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        params["enc_norm"] = (L.rmsnorm_init if cfg.norm == "rms"
                              else L.layernorm_init)(cfg.d_model, dtype)
        params["enc_pos"] = L.normal_init(
            jax.random.fold_in(kenc, 1), (cfg.enc_context_len, cfg.d_model),
            0.02, dtype)
    return params


# ---------------------------------------------------------------------------
# block apply
# ---------------------------------------------------------------------------

def _norm(cfg, p, x):
    return L.rmsnorm(p, x) if cfg.norm == "rms" else L.layernorm(p, x)


def _block_apply(cfg: ModelConfig, spec: BlockSpec, p, x, *, cache=None,
                 cache_pos=None, positions=None, context=None,
                 causal=True, impl="xla", moe_impl="einsum"):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    h = _norm(cfg, p["norm1"], x)
    if spec.mixer == "attn":
        kv = cache.get("kv") if cache else None
        h, new_kv = A.attn_apply(
            p["attn"], h, hq=cfg.n_heads, hkv=cfg.n_kv_heads, hd=cfg.head_dim,
            positions=positions, kv_cache=kv, cache_pos=cache_pos,
            causal=causal, window=spec.window, softcap=cfg.attn_softcap,
            rope_theta=cfg.rope_theta, query_scale=cfg.query_scale, impl=impl)
        if new_kv is not None:
            new_cache["kv"] = new_kv
    elif spec.mixer == "mamba":
        s = cfg.ssm
        ssm_state = cache.get("ssm") if cache else None
        conv_state = cache.get("conv") if cache else None
        h, (new_ssm, new_conv) = M.mamba_apply(
            p["mamba"], h, d_inner=s.d_inner, n_heads=s.n_heads,
            head_dim=s.head_dim, d_state=s.d_state, n_groups=s.n_groups,
            chunk=s.chunk, ssm_state=ssm_state, conv_state=conv_state,
            impl=impl)
        if cache:
            new_cache["ssm"] = new_ssm.astype(cache["ssm"].dtype)
            new_cache["conv"] = new_conv.astype(cache["conv"].dtype)
    if cfg.post_norm:
        h = _norm(cfg, p["post_norm1"], h)
    x = x + h
    x = constrain(x, "dp", "sp", None)

    if spec.cross and context is not None:
        h = _norm(cfg, p["norm_x"], x)
        h, _ = A.attn_apply(p["cross"], h, hq=cfg.n_heads, hkv=cfg.n_kv_heads,
                            hd=cfg.head_dim, context=context,
                            rope_theta=None, impl=impl)
        x = x + h

    if spec.mlp != "none":
        h = _norm(cfg, p["norm2"], x)
        if spec.mlp == "dense":
            h = L.mlp(p["mlp"], h, cfg.activation)
        else:
            h, moe_aux = MOE.moe_apply(
                p["moe"], h, top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor,
                activation=cfg.activation, impl=moe_impl)
            aux = aux + moe_aux["aux_loss"]
        if cfg.post_norm:
            h = _norm(cfg, p["post_norm2"], h)
        x = x + h
        x = constrain(x, "dp", "sp", None)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def _run_stack(cfg: ModelConfig, params, x, *, cache=None, cache_pos=None,
               positions=None, context=None, causal=True, impl="xla",
               moe_impl="einsum", remat=False):
    """Scan over super-blocks.  cache: list per pattern pos of stacked
    pytrees (n_super leading) or None."""
    pat = cfg.pattern
    n_super = cfg.n_layers // len(pat)

    def super_block(carry, xs):
        h = carry
        block_params, block_cache = xs
        new_caches, aux_tot = [], jnp.zeros((), jnp.float32)
        for i, spec in enumerate(pat):
            c = block_cache[i] if block_cache is not None else None
            h, nc, aux = _block_apply(cfg, spec, block_params[i], h,
                                      cache=c, cache_pos=cache_pos,
                                      positions=positions, context=context,
                                      causal=causal, impl=impl,
                                      moe_impl=moe_impl)
            new_caches.append(nc)
            aux_tot = aux_tot + aux
        return h, (new_caches, aux_tot)

    body = super_block
    if remat:
        # §Perf lever: "dots_remat" saves GEMM outputs instead of full
        # recompute — trades HBM residency for backward FLOPs/collectives.
        from repro.sharding.specs import perf_option
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if perf_option("dots_remat")
                  else jax.checkpoint_policies.nothing_saveable)
        body = jax.checkpoint(super_block, policy=policy)
    xs = (params["blocks"],
          cache if cache is not None else
          [{} for _ in pat])
    # scan needs every xs leaf to carry the n_super leading dim; empty dicts
    # have no leaves so this is consistent.
    x, (new_cache, auxs) = jax.lax.scan(body, x, xs)
    return x, (new_cache if cache is not None else None), jnp.sum(auxs)


def _encoder(cfg, params, frames, impl="xla"):
    """Whisper-style encoder over stub frame embeddings (B, T, D)."""
    x = frames + params["enc_pos"][None, : frames.shape[1]]
    spec = BlockSpec(mixer="attn", mlp="dense")

    def enc_block(h, p):
        h, _, _ = _block_apply(cfg, spec, p, h, causal=False, impl=impl)
        return h, None

    x, _ = jax.lax.scan(enc_block, x, params["enc_blocks"])
    return _norm(cfg, params["enc_norm"], x)


def _embed_inputs(cfg, params, tokens, extra_embeds):
    x = L.embed(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.frontend == "patch" and extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    return x


def forward(params, cfg: ModelConfig, tokens, *, extra_embeds=None,
            impl="xla", moe_impl="einsum", remat=False):
    """Full-sequence forward -> logits (B, S_total, V).

    tokens: (B, S) int32.  extra_embeds: vlm patches (B, Sp, D) prepended,
    or whisper frames (B, T, D) for the encoder.
    """
    context = None
    if cfg.enc_dec:
        assert extra_embeds is not None, "enc-dec needs frontend frames"
        context = _encoder(cfg, params, extra_embeds, impl)
    x = _embed_inputs(cfg, params, tokens, extra_embeds)
    x = constrain(x, "dp", "sp", None)
    x, _, aux = _run_stack(cfg, params, x, context=context, impl=impl,
                           moe_impl=moe_impl, remat=remat)
    x = _norm(cfg, params["final_norm"], x)
    table = params["unembed" if "unembed" in params else "embed"]
    logits = L.unembed(table, x, cfg.final_softcap)
    return constrain(logits, "dp", None, "tp"), aux


def loss_fn(params, cfg: ModelConfig, batch, *, impl="xla",
            moe_impl="einsum", remat=True,
            moe_aux_weight: float = 0.01):
    logits, aux = forward(params, cfg, batch["tokens"],
                          extra_embeds=batch.get("extra_embeds"),
                          impl=impl, moe_impl=moe_impl, remat=remat)
    # vlm: patches prepended -> only score the token region
    if cfg.frontend == "patch" and "extra_embeds" in batch:
        logits = logits[:, batch["extra_embeds"].shape[1]:]
    loss = L.cross_entropy(logits.astype(jnp.bfloat16), batch["labels"])
    return loss + moe_aux_weight * aux, {"ce": loss, "moe_aux": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16):
    """Stacked cache: list per pattern position, leaves (n_super, ...)."""
    n_super = cfg.n_layers // len(cfg.pattern)
    caches = []
    for spec in cfg.pattern:
        if spec.mixer == "attn":
            c = {"kv": jnp.zeros(
                (n_super, 2, batch, cache_len, cfg.n_kv_heads, cfg.head_dim),
                dtype)}
        elif spec.mixer == "mamba":
            s = cfg.ssm
            c = {"ssm": jnp.zeros(
                    (n_super, batch, s.n_heads, s.d_state, s.head_dim),
                    jnp.float32),
                 "conv": jnp.zeros(
                    (n_super, batch,
                     s.conv_width - 1,
                     s.d_inner + 2 * s.n_groups * s.d_state), dtype)}
        else:
            c = {}
        caches.append(c)
    return caches


def prefill(params, cfg: ModelConfig, tokens, cache, *, extra_embeds=None,
            impl="xla"):
    """Prompt prefill: forward over (B, S) tokens writing the KV cache at
    positions [0, S).  Returns (last-token logits (B, V), new_cache)."""
    context = None
    if cfg.enc_dec:
        context = _encoder(cfg, params, extra_embeds, impl)
    x = _embed_inputs(cfg, params, tokens, extra_embeds)
    x = constrain(x, "dp", "sp", None)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                 (x.shape[0], x.shape[1]))
    x, new_cache, _ = _run_stack(cfg, params, x, cache=cache,
                                 cache_pos=0, positions=positions,
                                 context=context, impl=impl)
    x = _norm(cfg, params["final_norm"], x[:, -1:])
    table = params["unembed" if "unembed" in params else "embed"]
    logits = L.unembed(table, x, cfg.final_softcap)[:, 0]
    return logits, new_cache


def decode_step(params, cfg: ModelConfig, cache, tokens, pos, *,
                context=None, impl="xla"):
    """One-token decode. tokens (B, 1); pos scalar int32 — write position
    (the KV cache covers positions [0, cache_len))."""
    x = L.embed(params["embed"], tokens)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.broadcast_to(pos, (tokens.shape[0], 1))
    x, new_cache, _ = _run_stack(cfg, params, x, cache=cache, cache_pos=pos,
                                 positions=positions, context=context,
                                 impl=impl)
    x = _norm(cfg, params["final_norm"], x)
    table = params["unembed" if "unembed" in params else "embed"]
    logits = L.unembed(table, x, cfg.final_softcap)
    return logits, new_cache
