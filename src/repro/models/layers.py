"""Shared neural-net layers (pure JAX, pytree params, no framework deps)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def uniform_init(key, shape, scale, dtype):
    return jax.random.uniform(key, shape, dtype, -scale, scale)


def normal_init(key, shape, std, dtype):
    return jax.random.normal(key, shape, dtype) * jnp.asarray(std, dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (dense / gated)
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, f: int, gated: bool = True, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    std = d ** -0.5
    p = {"w_in": normal_init(k1, (d, f), std, dtype),
         "w_out": normal_init(k2, (f, d), f ** -0.5, dtype)}
    if gated:
        p["w_gate"] = normal_init(k3, (d, f), std, dtype)
    return p


def mlp(params, x, activation: str = "silu"):
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
           "relu": jax.nn.relu}[activation]
    h = jnp.einsum("...d,df->...f", x, params["w_in"])
    if "w_gate" in params:
        h = act(jnp.einsum("...d,df->...f", x, params["w_gate"])) * h
    else:
        h = act(h)
    return jnp.einsum("...f,fd->...d", h, params["w_out"])


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": normal_init(key, (vocab, d), d ** -0.5, dtype)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x, softcap: float | None = None):
    logits = jnp.einsum("...d,vd->...v", x, params["table"])
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def cross_entropy(logits, labels, ignore: int = -1):
    """Mean token NLL; logits (..., V) any dtype, fp32 reduction."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = lse - ll
    mask = (labels != ignore).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
