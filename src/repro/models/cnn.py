"""Inception-style CNN — the paper's native subject (GoogleNet, Fig. 1).

Every conv routes through the kernel algorithm zoo (``kernels.conv2d``),
with per-op algorithms chosen by the core scheduler/selector; Inception
modules are ``core.Branches`` fork/joins, executable in any branch-parallel
mode (xla / spatial).  ``build_graph`` exports the op-level DAG the paper
reasons about — the benchmark harness runs the Table-1/Table-2 analogues
and the 27-case complementary-pair sweep on it.

Execution is plan-driven: ``plan_cnn`` lowers the scheduler's CoGroups to a
``core.plan.Plan`` (grouped / stacked / fused / spatial / serial / xla per
group) and ``forward_plan`` executes it.  Every branch conv carries its
GEMM view (1x1 = channel matmul; K×K = im2col patches), so a whole
Inception module co-executes: the ragged 1x1 projections AND the 3x3/5x5
critical-path convs each run as ONE grouped Pallas kernel with bias+ReLU
fused in-kernel, instead of six serial convs.  The algorithms-dict path
(``forward(algorithms=...)``) remains as the serial fallback.

The backward pass co-executes the mirrored fork/join: grouped (and
join-absorbing ``grouped_concat``) groups differentiate through ONE
combined dx/dw/db launch per grad CoGroup (``grouped_matmul_bwd``, their
custom VJP), serial convs through the stride-aware im2col GEMM-view
backward (``_conv_gemm_bwd`` — no XLA conv-transpose anywhere on the zoo
path), and ``plan_cnn`` attaches the lowered grad CoGroups as
``plan.context["backward"]`` (``core.plan.backward_plan``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import Op, OpGraph
# import from the conv2d module file directly (the package re-exports the
# ops.conv2d *function* under the same name, shadowing the submodule)
from repro.kernels.conv2d import CONV2D_ALGORITHMS as _CONV_ALGS
from repro.kernels.ops import default_interpret
from repro.kernels import ref as k_ref
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class InceptionSpec:
    n1: int      # 1x1 branch
    r3: int      # 3x3 reduce
    n3: int      # 3x3 branch
    r5: int      # 5x5 reduce
    n5: int      # 5x5 branch
    pp: int      # pool-proj branch

    @property
    def out(self) -> int:
        return self.n1 + self.n3 + self.n5 + self.pp


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    img: tuple[int, int, int]            # (H, W, C)
    stem: tuple[tuple[int, int, int], ...]  # (k, out_ch, stride) convs
    modules: tuple[InceptionSpec, ...]
    pool_between: tuple[int, ...]        # module idxs preceded by 2x2 maxpool
    num_classes: int = 1000
    family: str = "cnn"

    def param_count(self) -> int:
        n, c = 0, self.img[2]
        for (k, out, _s) in self.stem:
            n += k * k * c * out + out
            c = out
        for m in self.modules:
            n += c * m.n1 + m.n1
            n += c * m.r3 + m.r3 + 9 * m.r3 * m.n3 + m.n3
            n += c * m.r5 + m.r5 + 25 * m.r5 * m.n5 + m.n5
            n += c * m.pp + m.pp
            c = m.out
        return n + c * self.num_classes + self.num_classes


def conv(x, w, b, *, stride=1, algorithm="xla", interpret=None):
    if algorithm == "xla":
        y = k_ref.conv2d_ref(x, w, stride=stride, padding="SAME")
    else:
        y = _conv_alg(x, w, stride, algorithm,
                      default_interpret() if interpret is None else interpret)
    return jax.nn.relu(y + b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _conv_alg(x, w, stride, algorithm, interpret):
    """Algorithm-zoo conv with a GEMM-view VJP: the paper's algorithm knob
    concerns the FORWARD kernel; the gradient of the mathematical op is
    algorithm-independent and routes through the stride-aware im2col GEMM
    lowering (``_conv_gemm_bwd``) — the same cuDNN-style view the grouped
    dw/dx kernels co-execute for branch groups, here launched per-op
    through the matmul zoo (the serial regime's one-kernel-per-op
    backward)."""
    return _CONV_ALGS[algorithm](x, w, stride=stride, padding="SAME",
                                 interpret=interpret)


def _conv_alg_fwd(x, w, stride, algorithm, interpret):
    return _conv_alg(x, w, stride, algorithm, interpret), (x, w)


def _conv_alg_bwd(stride, algorithm, interpret, res, g):
    x, w = res
    return _conv_gemm_bwd(x, w, g.astype(x.dtype), stride,
                          interpret=interpret)


_conv_alg.defvjp(_conv_alg_fwd, _conv_alg_bwd)


def _im2col(x, kh, kw, stride):
    """SAME-padded im2col patches, feature order (C, KH, KW) — the GEMM
    lhs every conv's forward AND backward lowering shares.

    Built from pad + strided slices + dynamic_update_slice (NOT
    ``conv_general_dilated_patches``): the patch gather must not lower to
    an XLA convolution primitive, or the traced-jaxpr launch counter
    (``core.launch_count``) would charge every im2col view as a surviving
    conv launch.  Matches the patches primitive bit-for-bit, tap order
    included."""
    b, h, w, c = x.shape
    oh, ow = -(-h // stride), -(-w // stride)
    pad_h = max((oh - 1) * stride + kh - h, 0)
    pad_w = max((ow - 1) * stride + kw - w, 0)
    plo_h, plo_w = pad_h // 2, pad_w // 2
    xp = jnp.pad(x, ((0, 0), (plo_h, pad_h - plo_h),
                     (plo_w, pad_w - plo_w), (0, 0)))
    buf = jnp.zeros((b, oh, ow, c, kh * kw), x.dtype)
    for ki in range(kh):
        for kj in range(kw):
            tap = jax.lax.slice(
                xp, (0, ki, kj, 0),
                (b, ki + (oh - 1) * stride + 1, kj + (ow - 1) * stride + 1,
                 c), (1, stride, stride, 1))
            buf = jax.lax.dynamic_update_slice(
                buf, tap[..., None], (0, 0, 0, 0, ki * kw + kj))
    # (..., C, KH*KW) -> flat (C, KH, KW)-major feature axis
    return buf.reshape(b, oh, ow, c * kh * kw)


def _conv_gemm_bwd(x, w, dy, stride, interpret=None):
    """Conv backward through the stride-aware GEMM view (no XLA
    conv-transpose): dw is the transposed GEMM patches^T @ dY2d — exactly
    the contraction the grouped dw kernel co-executes for branch groups —
    and dx pulls the patch cotangent back through the im2col gather.
    The two GEMMs launch per-op through the Pallas matmul zoo, so the
    serial baseline's backward is kernel-for-kernel comparable with the
    grouped backward (one launch per op vs one per group)."""
    from repro.kernels.ops import matmul as k_matmul
    kh, kw, cin, cout = w.shape
    dy2 = dy.reshape(-1, cout)
    if (kh, kw) == (1, 1) and stride == 1:
        x2 = x.reshape(-1, cin)
        dx = k_matmul(dy2, w.reshape(cin, cout).T,
                      interpret=interpret).reshape(x.shape)
        dw2 = k_matmul(x2.T, dy2, interpret=interpret)
        return dx, dw2.reshape(1, 1, cin, cout)
    patches, pat_vjp = jax.vjp(lambda xx: _im2col(xx, kh, kw, stride), x)
    p2 = patches.reshape(-1, cin * kh * kw)
    wmat = w.transpose(2, 0, 1, 3).reshape(cin * kh * kw, cout)
    dpat = k_matmul(dy2, wmat.T, interpret=interpret)
    dx = pat_vjp(dpat.reshape(patches.shape))[0]
    dw2 = k_matmul(p2.T, dy2, interpret=interpret)
    return dx, dw2.reshape(cin, kh, kw, cout).transpose(1, 2, 0, 3)


def maxpool(x, k=3, stride=2):
    # numpy (not jnp) init: dtype-preserving for bf16, and still a
    # concrete monoid identity — a traced jnp array defeats
    # reduce_window's max-monoid detection, lowering to the generic
    # reduce_window_p which has no transpose rule (jit+grad asserts)
    return jax.lax.reduce_window(
        x, np.array(-np.inf, x.dtype), jax.lax.max, (1, k, k, 1),
        (1, stride, stride, 1), "SAME")


def maxpool_chain(x, chain):
    """A ``((window, stride), ...)`` maxpool chain via ``reduce_window`` —
    the standalone pooling primitive the serial/unfused paths launch (and
    the baseline the pooled grouped launch absorbs)."""
    for k, s in chain:
        x = maxpool(x, k, s)
    return x


def _conv_init(key, kh, cin, cout, dtype):
    w = L.normal_init(key, (kh, kh, cin, cout), (kh * kh * cin) ** -0.5,
                      dtype)
    return {"w": w, "b": jnp.zeros((cout,), dtype)}


def init_params(cfg: CNNConfig, key, dtype=jnp.float32):
    ks = iter(jax.random.split(key, 8 + 8 * len(cfg.modules)))
    params: dict = {"stem": []}
    c = cfg.img[2]
    for (k, out, s) in cfg.stem:
        params["stem"].append(_conv_init(next(ks), k, c, out, dtype))
        c = out
    params["modules"] = []
    for m in cfg.modules:
        p = {
            "b1": _conv_init(next(ks), 1, c, m.n1, dtype),
            "r3": _conv_init(next(ks), 1, c, m.r3, dtype),
            "b3": _conv_init(next(ks), 3, m.r3, m.n3, dtype),
            "r5": _conv_init(next(ks), 1, c, m.r5, dtype),
            "b5": _conv_init(next(ks), 5, m.r5, m.n5, dtype),
            "pp": _conv_init(next(ks), 1, c, m.pp, dtype),
        }
        params["modules"].append(p)
        c = m.out
    params["head"] = {
        "w": L.normal_init(next(ks), (c, cfg.num_classes), c ** -0.5, dtype),
        "b": jnp.zeros((cfg.num_classes,), dtype)}
    return params


def inception_module(p, x, spec: InceptionSpec, alg, interpret=None):
    """alg: dict branch-name -> algorithm (from the scheduler) or str."""
    a = (lambda n: alg.get(n, "xla")) if isinstance(alg, dict) else (lambda n: alg)
    b1 = conv(x, p["b1"]["w"], p["b1"]["b"], algorithm=a("1x1"),
              interpret=interpret)
    r3 = conv(x, p["r3"]["w"], p["r3"]["b"], algorithm=a("r3"),
              interpret=interpret)
    b3 = conv(r3, p["b3"]["w"], p["b3"]["b"], algorithm=a("3x3"),
              interpret=interpret)
    r5 = conv(x, p["r5"]["w"], p["r5"]["b"], algorithm=a("r5"),
              interpret=interpret)
    b5 = conv(r5, p["b5"]["w"], p["b5"]["b"], algorithm=a("5x5"),
              interpret=interpret)
    pp = conv(maxpool(x, 3, 1), p["pp"]["w"], p["pp"]["b"],
              algorithm=a("pp"), interpret=interpret)
    return jnp.concatenate([b1, b3, b5, pp], axis=-1)


def forward(params, cfg: CNNConfig, images, *, algorithms=None,
            interpret=None):
    """images (B, H, W, C) -> logits (B, classes).

    algorithms: None (XLA), a str, or {module_idx: {branch: alg}} from the
    scheduler (`schedule_cnn`).
    """
    x = images
    for i, (p, (k, out, s)) in enumerate(zip(params["stem"], cfg.stem)):
        alg = "xla" if algorithms is None else (
            algorithms if isinstance(algorithms, str)
            else algorithms.get(f"stem{i}", "xla"))
        x = conv(x, p["w"], p["b"], stride=s, algorithm=alg,
                 interpret=interpret)
    for i, (p, m) in enumerate(zip(params["modules"], cfg.modules)):
        if i in cfg.pool_between:
            x = maxpool(x, 3, 2)
        alg = "xla" if algorithms is None else (
            algorithms if isinstance(algorithms, str)
            else algorithms.get(i, {}))
        x = inception_module(p, x, m, alg, interpret=interpret)
    x = x.mean(axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


def loss_fn(params, cfg: CNNConfig, batch, *, plan=None, **kw):
    if plan is not None:
        logits = forward_plan(params, cfg, batch["images"], plan, **kw)
    else:
        logits = forward(params, cfg, batch["images"], **kw)
    return L.cross_entropy(logits, batch["labels"]), {}


# ---------------------------------------------------------------------------
# plan-driven execution (core/plan.py lowering of the schedule)
# ---------------------------------------------------------------------------

def _plan_impls(params, cfg: CNNConfig, interpret=None):
    """``core.plan.OpImpl`` binding for every ``build_graph`` op.

    Mirrors the shape walk of ``build_graph``.  The maxpools are explicit
    graph ops now, so each pool impl carries its ``pool_chain`` (what the
    pooled grouped launch absorbs) and an ``fn`` running the standalone
    ``reduce_window`` chain (the serial/unfused baseline); the pool-proj
    conv reads its pre-pool op's output directly.  Returns (impls, name
    of the final join op).
    """
    from repro.core.plan import OpImpl

    impls: dict = {}
    h, w = cfg.img[:2]
    dep = "input"

    def conv_impl(pb, dep, oh, ow, stride=1):
        """OpImpl with the conv's GEMM views: a 1x1 conv is a channel
        matmul; a K×K conv is its im2col view (M = B*OH*OW, K = C*KH*KW)
        — the cuDNN GEMM lowering, which is what lets the 3x3/5x5
        branches join grouped co-execution groups.  ``oh``/``ow`` must be
        the POST-stride output extent (matching cost_model.gemm_shape).
        The bias+ReLU epilogue is split out (gemm_bias/gemm_relu/
        gemm_reshape) so the grouped kernel can fuse it in-kernel;
        gemm_post keeps the equivalent out-of-kernel epilogue for
        stacked/fused modes.  ``gemm_x`` is a pure function of the dep
        value — for a pool-absorbed branch the executor applies it to
        each raw-input tap view instead of the materialized pooled dep."""
        kh, kw, cin, _ = pb["w"].shape
        # (KH, KW, C, K) -> (C, KH, KW, K) -> (C*KH*KW, K): matches the
        # (C, KH, KW) feature order of conv_general_dilated_patches.
        wmat = pb["w"].transpose(2, 0, 1, 3).reshape(cin * kh * kw, -1)

        def gemm_x(x, kh=kh, kw=kw, cin=cin, s=stride):
            if (kh, kw) == (1, 1) and s == 1:
                return x.reshape(-1, cin)
            return _im2col(x, kh, kw, s).reshape(-1, cin * kh * kw)

        def gemm_reshape(y2d, oh=oh, ow=ow):
            return y2d.reshape(-1, oh, ow, y2d.shape[-1])

        def gemm_post(y2d, pb=pb):
            return jax.nn.relu(gemm_reshape(y2d) + pb["b"])

        return OpImpl(
            deps=(dep,),
            fn=lambda x, algorithm="xla", pb=pb, s=stride: conv(
                x, pb["w"], pb["b"], stride=s, algorithm=algorithm,
                interpret=interpret),
            gemm_x=gemm_x,
            # branches whose dep AND filter geometry coincide produce the
            # identical x2d -> wide-GEMM dedup (deps equality carries the
            # input identity now that pools are explicit ops)
            gemm_x_key=("conv_x", kh, kw, stride, cin),
            gemm_w=wmat,
            gemm_post=gemm_post,
            gemm_bias=pb["b"],
            gemm_relu=True,
            gemm_reshape=gemm_reshape,
            # raw conv geometry for grouped_chained launches: ring tap
            # descriptors, panel-block weight repacking and border masks
            # need what gemm_x's closure hides
            chain_geom=(kh, kw, stride, cin, oh, ow))

    def pool_impl(dep, chain):
        return OpImpl(
            deps=(dep,),
            fn=lambda x, algorithm=None, chain=chain: maxpool_chain(
                x, chain),
            pool_chain=tuple(chain))

    for i, (pb, (k, out, s)) in enumerate(zip(params["stem"], cfg.stem)):
        h, w = -(-h // s), -(-w // s)
        impls[f"stem{i}"] = conv_impl(pb, dep, h, w, stride=s)
        dep = f"stem{i}"

    for i, p in enumerate(params["modules"]):
        pooled = i in cfg.pool_between
        nm = f"inc{i}"
        if pooled:
            impls[f"{nm}/pool"] = pool_impl(dep, ((3, 2),))
            impls[f"{nm}/pppool"] = pool_impl(dep, ((3, 2), (3, 1)))
            bdep = f"{nm}/pool"
            h, w = -(-h // 2), -(-w // 2)
        else:
            impls[f"{nm}/pppool"] = pool_impl(dep, ((3, 1),))
            bdep = dep
        impls[f"{nm}/1x1"] = conv_impl(p["b1"], bdep, h, w)
        impls[f"{nm}/r3"] = conv_impl(p["r3"], bdep, h, w)
        impls[f"{nm}/r5"] = conv_impl(p["r5"], bdep, h, w)
        impls[f"{nm}/pp"] = conv_impl(p["pp"], f"{nm}/pppool", h, w)
        impls[f"{nm}/3x3"] = conv_impl(p["b3"], f"{nm}/r3", h, w)
        impls[f"{nm}/5x5"] = conv_impl(p["b5"], f"{nm}/r5", h, w)
        impls[f"{nm}/join"] = OpImpl(
            deps=(f"{nm}/1x1", f"{nm}/3x3", f"{nm}/5x5", f"{nm}/pp"),
            fn=lambda *ys, algorithm=None: jnp.concatenate(ys, axis=-1),
            # 2D (M, sum N_g) -> NHWC view: what lets a grouped_concat
            # group absorb this join — the grouped kernel's epilogue
            # assembles the concat buffer and only this reshape runs out
            # of kernel (a pure layout view, like gemm_reshape on convs)
            gemm_reshape=lambda y2d, oh=h, ow=w: y2d.reshape(
                -1, oh, ow, y2d.shape[-1]))
        dep = f"{nm}/join"
    return impls, dep


def forward_plan(params, cfg: CNNConfig, images, plan, *, mesh=None,
                 interpret=None, timings=None, valid_images=None):
    """Plan-driven forward: images (B, H, W, C) -> logits (B, classes).

    ``plan`` comes from ``plan_cnn``; stacked groups run in one branch
    kernel, serial groups use the scheduler algorithms, xla groups trust
    XLA — see ``core/plan.py``.  ``valid_images`` makes the grouped
    launches ragged-M for a bucketed serving batch whose first
    ``valid_images`` images are real (see ``core.plan.run_plan``;
    inference-only) — logits rows at/past it are padding.
    """
    from repro.core import plan as planlib
    impls, out_name = _plan_impls(params, cfg, interpret=interpret)
    env = {"input": images}
    planlib.run_plan(impls, env, plan, mesh=mesh, interpret=interpret,
                     timings=timings, valid_images=valid_images)
    out = env[out_name]
    hw = params["head"]["w"]
    if isinstance(out, planlib.ChainPanels):
        # split head: the final chained launch's output never assembles —
        # global-average-pool each panel segment in place and multiply by
        # the matching head-row slab (sum over segments == the composite
        # GAP @ head exactly), so no concatenate survives the forward
        logits = params["head"]["b"]
        coff = 0
        for pidx, cb, n in out.segments:
            seg = out.panels[pidx][:out.m, cb * out.blk: cb * out.blk + n]
            segm = seg.reshape(-1, out.h * out.w, n).mean(axis=1)
            rows = jax.lax.slice(hw, (coff, 0), (coff + n, hw.shape[1]))
            logits = logits + segm @ rows.astype(segm.dtype)
            coff += n
        return logits
    x = out.mean(axis=(1, 2))
    return x @ hw + params["head"]["b"]


def plan_cnn(cfg: CNNConfig, batch: int, *, mesh=None, concurrent=True,
             max_group: int = 4, hbm_budget: float | None = None,
             vmem_budget: float | None = None, train: bool = False,
             fuse_concat: bool = True, fuse_pool: bool = True,
             chain_modules: bool = False):
    """graph -> schedule -> executable plan for this CNN.

    Returns (Plan, Schedule).  This supersedes ``schedule_algorithms``: the
    plan carries the same per-op algorithm choices AND the per-group
    execution mode that makes the co-execution decisions real.
    ``fuse_concat`` (default) absorbs each inception module's join into
    the grouped launch feeding it (``grouped_concat`` groups — the
    3x3/5x5 outputs land in the join buffer in-kernel, the 1x1/pool-proj
    outputs copy in as passthrough slices, and no standalone concat op
    remains on the fused path); ``fuse_concat=False`` keeps the
    standalone joins (the unfused baseline the benchmarks compare
    against).  ``fuse_pool`` (default) likewise streams every maxpool op
    through the grouped launch that consumes it (``_absorb_pools`` ->
    ``grouped_pooled`` / pooled ``grouped_concat`` groups — zero
    standalone ``reduce_window`` launches on the fused path);
    ``fuse_pool=False`` keeps the pooling primitives standalone.

    ``chain_modules=True`` additionally chains the absorbed launches
    ACROSS module boundaries (``core.plan._chain_modules``): each
    module's quad + concat-pair merge into ONE two-phase
    ``grouped_chained`` launch (reductions stream to the K*K convs
    through the in-kernel VMEM ring; the join vanishes — the next launch
    consumes the padded output panels in place), and the stem's serial
    convs fold into one multi-phase launch.  On googlenet this takes the
    forward from ~21 kernel launches to one per module plus one for the
    stem.

    The mirrored backward plan (``core.plan.backward_plan``) is attached
    as ``plan.context["backward"]`` — the lowering/pricing of the grad
    CoGroups the training step's VJPs execute.  ``train=True`` packs and
    budget-checks groups at forward+backward cost (a group only forms
    when co-execution wins across the whole step).
    """
    from repro.core import plan as planlib
    from repro.core import scheduler as S
    kw = {}
    if hbm_budget is not None:
        kw["hbm_budget"] = hbm_budget
    if vmem_budget is not None:
        kw["vmem_budget"] = vmem_budget
    g = build_graph(cfg, batch)
    sch = S.schedule(g, concurrent=concurrent, max_group=max_group,
                     train=train, **kw)
    plan = planlib.lower(g, sch, mesh=mesh, train=train,
                         fuse_concat=fuse_concat, fuse_pool=fuse_pool,
                         chain_modules=chain_modules, **kw)
    plan.context.update({"cfg": cfg, "batch": batch})
    plan.context["backward"] = planlib.backward_plan(g, plan, **kw)
    return plan, sch


# ---------------------------------------------------------------------------
# op-graph export (for the scheduler / paper benchmarks)
# ---------------------------------------------------------------------------

def build_graph(cfg: CNNConfig, batch: int) -> OpGraph:
    """Op-level DAG with the pooling primitives EXPLICIT: the inter-module
    maxpool (``inc{i}/pool``) and each pool-proj pre-pool
    (``inc{i}/pppool``) are ``maxpool`` ops — the separate launched
    primitives they are in a cuDNN-style framework, and the ops the plan
    layer's ``_absorb_pools`` streams into the grouped launches.  The
    pool-proj pre-pool reads the RAW module input with its COMPOSED chain
    ((3,2)+(3,1) for pooled modules), so the four branch convs of a
    module still share one ready level (the quad the scheduler packs)."""
    g = OpGraph()
    h, w, c = cfg.img
    g.add(Op.make("input", "pointwise", elements=batch * h * w * c))
    dep = "input"
    for i, (k, out, s) in enumerate(cfg.stem):
        g.add(Op.make(f"stem{i}", "conv2d", n=batch, h=h, w=w, c=c, kh=k,
                      kw=k, k=out, stride=s), [dep])
        dep = f"stem{i}"
        h, w, c = -(-h // s), -(-w // s), out
    for i, m in enumerate(cfg.modules):
        nm = f"inc{i}"
        pooled = i in cfg.pool_between
        if pooled:
            g.add(Op.make(f"{nm}/pool", "maxpool", n=batch, h=h, w=w, c=c,
                          chain=((3, 2),)), [dep])
            pp_chain = ((3, 2), (3, 1))
        else:
            pp_chain = ((3, 1),)
        g.add(Op.make(f"{nm}/pppool", "maxpool", n=batch, h=h, w=w, c=c,
                      chain=pp_chain), [dep])
        branch_dep = f"{nm}/pool" if pooled else dep
        if pooled:
            h, w = -(-h // 2), -(-w // 2)
        g.add(Op.make(f"{nm}/1x1", "conv2d", n=batch, h=h, w=w, c=c, kh=1,
                      kw=1, k=m.n1, stride=1), [branch_dep])
        g.add(Op.make(f"{nm}/r3", "conv2d", n=batch, h=h, w=w, c=c, kh=1,
                      kw=1, k=m.r3, stride=1), [branch_dep])
        g.add(Op.make(f"{nm}/3x3", "conv2d", n=batch, h=h, w=w, c=m.r3,
                      kh=3, kw=3, k=m.n3, stride=1), [f"{nm}/r3"])
        g.add(Op.make(f"{nm}/r5", "conv2d", n=batch, h=h, w=w, c=c, kh=1,
                      kw=1, k=m.r5, stride=1), [branch_dep])
        g.add(Op.make(f"{nm}/5x5", "conv2d", n=batch, h=h, w=w, c=m.r5,
                      kh=5, kw=5, k=m.n5, stride=1), [f"{nm}/r5"])
        g.add(Op.make(f"{nm}/pp", "conv2d", n=batch, h=h, w=w, c=c, kh=1,
                      kw=1, k=m.pp, stride=1), [f"{nm}/pppool"])
        g.add(Op.make(f"{nm}/join", "pointwise",
                      elements=batch * h * w * m.out),
              [f"{nm}/1x1", f"{nm}/3x3", f"{nm}/5x5", f"{nm}/pp"])
        dep = f"{nm}/join"
        c = m.out
    return g


def schedule_algorithms(cfg: CNNConfig, batch: int, concurrent=True):
    """Run the core scheduler on the CNN graph -> per-module algorithm map
    usable by ``forward(algorithms=...)``.

    Superseded by ``plan_cnn`` + ``forward_plan`` (the ``core/plan.py``
    execution-plan IR): this path keeps only the algorithm choices and runs
    every branch serially — the exact framework behaviour the paper
    critiques.  It remains as the plan's ``serial`` fallback."""
    from repro.core import scheduler as S
    g = build_graph(cfg, batch)
    sch = S.schedule(g, concurrent=concurrent)
    algs = sch.algorithms
    out: dict = {}
    for name, alg in algs.items():
        if name.startswith("stem"):
            out[name] = alg
        elif name.startswith("inc"):
            mod, branch = name.split("/")
            out.setdefault(int(mod[3:]), {})[branch] = alg
    return out, sch
