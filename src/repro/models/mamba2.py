"""Mamba-2 (SSD) mixer block: init/apply, train + single-step decode.

impl="xla"    — chunked SSD in pure jnp with a lax.scan over chunks
                (bounded memory, GSPMD-partitionable; heads shard over the
                ``model`` axis so the per-chunk (L, L, H_local) decay tensor
                stays small).  This is what the dry-run lowers.
impl="pallas" — the ``kernels/ssd.py`` chunked kernel (per-device shapes).

Decode threads a recurrent state (B, H, N, P) plus a causal-conv tail
(B, W-1, C_conv) — O(1) per token, the reason long_500k is runnable for
SSM/hybrid archs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ssd as pallas_ssd
from repro.models import layers as L


def mamba_init(key, d: int, *, d_inner: int, n_heads: int, head_dim: int,
               d_state: int, n_groups: int, conv_width: int = 4,
               dtype=jnp.float32):
    assert d_inner == n_heads * head_dim
    ks = jax.random.split(key, 4)
    d_xbc = d_inner + 2 * n_groups * d_state
    d_proj = d_inner + d_xbc + n_heads          # z, xBC, dt
    p = {
        "w_in": L.normal_init(ks[0], (d, d_proj), d ** -0.5, dtype),
        "conv_w": L.normal_init(ks[1], (conv_width, d_xbc), 0.1, dtype),
        "conv_b": jnp.zeros((d_xbc,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(dtype),
        "D": jnp.ones((n_heads,), dtype),
        "dt_bias": jnp.zeros((n_heads,), dtype),
        "norm": L.rmsnorm_init(d_inner, dtype),
        "w_out": L.normal_init(ks[2], (d_inner, d), d_inner ** -0.5, dtype),
    }
    return p


def _split_proj(proj, d_inner, n_groups, d_state, n_heads):
    d_xbc = d_inner + 2 * n_groups * d_state
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner:d_inner + d_xbc]
    dt = proj[..., d_inner + d_xbc:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv, width W.  xbc: (B, S, C).
    conv_state: (B, W-1, C) tail of previous tokens (decode)."""
    w = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], w - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)          # (B, S+W-1, C)
    out = sum(xp[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(w))
    new_state = xp[:, -(w - 1):]
    return jax.nn.silu(out + conv_b), new_state


def _ssd_xla_chunked(x, a_log, b, c, *, chunk: int = 128, init_state=None):
    """Pure-jnp chunked SSD (same math as kernels/ssd.py) with scan over
    chunks — the partitionable dry-run path."""
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    l = min(chunk, s)
    s_p = -(-s // l) * l
    pad = s_p - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = s_p // l
    rep = h // g
    xc = x.reshape(bsz, nc, l, h, p).astype(jnp.float32)
    ac = a_log.reshape(bsz, nc, l, h).astype(jnp.float32)
    bc = b.reshape(bsz, nc, l, g, n).astype(jnp.float32)
    cc = c.reshape(bsz, nc, l, g, n).astype(jnp.float32)
    ts = jnp.arange(l)
    causal = ts[:, None] >= ts[None, :]

    def chunk_step(state, inp):
        xk, ak, bk, ck = inp                      # (B,L,H,P) (B,L,H) ...
        cum = jnp.cumsum(ak, axis=1)              # (B, L, H)
        # mask INSIDE the exp: exp of +large for s>t would overflow and
        # poison the backward pass (0 * inf = NaN)
        diff = jnp.where(causal[None, :, :, None],
                         cum[:, :, None, :] - cum[:, None, :, :], -1e30)
        decay = jnp.exp(diff)
        cb = jnp.einsum("btgn,bsgn->btsg", ck, bk)
        cb = jnp.repeat(cb, rep, axis=3)          # (B, L, L, H)
        y_diag = jnp.einsum("btsh,bshp->bthp", cb * decay, xk)
        # off-diagonal from carried state
        ch = jnp.repeat(ck, rep, axis=2)          # (B, L, H, N)
        y_off = jnp.einsum("blhn,bhnp,blh->blhp", ch, state, jnp.exp(cum))
        # state update
        sdecay = jnp.exp(cum[:, -1:, :] - cum)    # (B, L, H)
        bh = jnp.repeat(bk, rep, axis=2)          # (B, L, H, N)
        st_new = jnp.einsum("blhn,blh,blhp->bhnp", bh, sdecay, xk)
        state = state * jnp.exp(cum[:, -1, :])[:, :, None, None] + st_new
        return state, y_diag + y_off

    init = (jnp.zeros((bsz, h, n, p), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final_state, ys = jax.lax.scan(
        chunk_step, init,
        (xc.transpose(1, 0, 2, 3, 4), ac.transpose(1, 0, 2, 3),
         bc.transpose(1, 0, 2, 3, 4), cc.transpose(1, 0, 2, 3, 4)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s_p, h, p)[:, :s]
    return y, final_state


def mamba_apply(params, x, *, d_inner: int, n_heads: int, head_dim: int,
                d_state: int, n_groups: int, chunk: int = 128,
                ssm_state=None, conv_state=None, impl: str = "xla"):
    """x: (B, S, D) -> (out, (new_ssm_state, new_conv_state)).

    Training: pass ssm_state=None.  Decode: S==1 with states from init_cache.
    """
    b, s, d = x.shape
    proj = jnp.einsum("bsd,dp->bsp", x, params["w_in"])
    z, xbc, dt = _split_proj(proj, d_inner, n_groups, d_state, n_heads)
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    xs = xbc[..., :d_inner].reshape(b, s, n_heads, head_dim)
    bmat = xbc[..., d_inner:d_inner + n_groups * d_state] \
        .reshape(b, s, n_groups, d_state)
    cmat = xbc[..., d_inner + n_groups * d_state:] \
        .reshape(b, s, n_groups, d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))              # (H,)
    a_log = a[None, None, :] * dt                                  # (B,S,H) <0
    xdt = xs.astype(jnp.float32) * dt[..., None]

    if s > 1:
        if impl == "pallas":
            y, new_ssm = pallas_ssd.ssd_chunked(
                xdt, a_log, bmat, cmat, chunk=chunk, d_skip=None,
                init_state=ssm_state, return_final_state=True)
            y = y.astype(jnp.float32)
        else:
            y, new_ssm = _ssd_xla_chunked(xdt, a_log, bmat, cmat, chunk=chunk,
                                          init_state=ssm_state)
    else:
        # single-step recurrence (decode)
        state = ssm_state if ssm_state is not None else \
            jnp.zeros((b, n_heads, d_state, head_dim), jnp.float32)
        rep = n_heads // n_groups
        bh = jnp.repeat(bmat[:, 0], rep, axis=1).astype(jnp.float32)  # (B,H,N)
        ch = jnp.repeat(cmat[:, 0], rep, axis=1).astype(jnp.float32)
        state = state * jnp.exp(a_log[:, 0])[:, :, None, None] + \
            jnp.einsum("bhn,bhp->bhnp", bh, xdt[:, 0])
        y = jnp.einsum("bhn,bhnp->bhp", ch, state)[:, None]           # (B,1,H,P)
        new_ssm = state

    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * \
        xs.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = L.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = jnp.einsum("bsi,id->bsd", y, params["w_out"])
    return out, (new_ssm, new_conv[:, -(params["conv_w"].shape[0] - 1):])
