"""Mixture-of-Experts layer: top-k router + capacity-bounded sort dispatch.

Experts ARE the paper's independent branches: E disjoint GEMM chains forked
by the router and joined by the weighted combine.  At mesh scale they are
spatially partitioned (expert dim sharded over the ``model`` axis = the
paper's inter-SM partitioning, one expert group per chip group); intra-chip
the E-leading einsum is exactly the stacked branch-GEMM pattern of
``kernels/branch_matmul``.

Dispatch is sort-based with a static capacity (GShard/Switch family), done
PER BATCH ROW so every sort/scatter is local to a data shard — a global
token sort would force cross-device sorting and SPMD full-rematerialization
(observed: 424 GB/device temp on the 398B config before this formulation).
FLOPs scale with top_k (not E); tokens over capacity are dropped (standard)
and counted in aux stats.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding import constrain


def moe_init(key, d: int, f: int, n_experts: int, *, shared_f: int = 0,
             gated: bool = True, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    std = d ** -0.5
    p = {
        "router": L.normal_init(ks[0], (d, n_experts), std, dtype),
        "w_in": L.normal_init(ks[1], (n_experts, d, f), std, dtype),
        "w_out": L.normal_init(ks[2], (n_experts, f, d), f ** -0.5, dtype),
    }
    if gated:
        p["w_gate"] = L.normal_init(ks[3], (n_experts, d, f), std, dtype)
    if shared_f:
        p["shared"] = L.mlp_init(ks[4], d, shared_f, gated=gated, dtype=dtype)
    return p


def moe_apply(params, x, *, top_k: int, capacity_factor: float = 1.25,
              activation: str = "silu", impl: str = "einsum",
              interpret: bool | None = None):
    """x: (B, S, D) -> (out (B, S, D), aux dict).

    ``impl`` picks the expert engine: ``"einsum"`` is the capacity-padded
    E-leading stacked einsum (the oracle — FLOPs spent on every empty
    capacity slot), ``"grouped"`` packs routed tokens into per-expert
    ragged segments and runs ONE ``grouped_matmul_experts`` launch per
    direction (FLOPs scale with routed tokens).  Both share ``_route``,
    so routing, drops and the combine scatter are identical — the
    grouped path reproduces the einsum path for routed tokens exactly.
    The shard_map perf paths (``moe_local``/``moe_ep``) always use the
    einsum core; ``impl`` applies to the single-mesh path.

    Under the ``moe_local`` perf option (requires replicated expert params,
    i.e. dp_over_model), the whole dispatch/combine runs inside shard_map
    per data shard: sorts/scatters become chip-local, eliminating the
    GSPMD scatter-add all-reduce (measured 4.3 GB x n_layers on granite)."""
    assert impl in ("einsum", "grouped"), impl
    from repro.sharding import specs as SH
    mesh = getattr(SH._CTX, "mesh", None)
    if SH.perf_option("moe_local") and mesh is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        dp = SH.logical_axes(mesh, "dp")
        dp_size = 1
        for a in (dp if isinstance(dp, tuple) else (dp,) if dp else ()):
            dp_size *= mesh.shape[a]
        if dp and x.shape[0] % dp_size == 0:
            def local(p, xl):
                with SH.activations_on(None):   # no GSPMD constraints inside
                    out, aux = _moe_apply_core(p, xl, top_k=top_k,
                                               capacity_factor=capacity_factor,
                                               activation=activation)
                aux = {k: jax.lax.pmean(v, dp) if jnp.ndim(v) == 0 else v
                       for k, v in aux.items()}
                return out, aux

            fn = shard_map(local, mesh=mesh,
                           in_specs=(P(), P(dp, None, None)),
                           out_specs=(P(dp, None, None), P()),
                           check_rep=False)
            return fn(params, x)

    # moe_ep: expert-parallel local dispatch — experts stay sharded over the
    # ``model`` axis (the paper's spatial branch partitioning); each chip
    # routes its data shard locally, computes ONLY its local experts, and a
    # single psum over ``model`` joins the branches.  Eliminates the GSPMD
    # gather/scatter all-reduces (measured ~600 GB/step on jamba train_4k).
    e_total = params["router"].shape[1]
    if SH.perf_option("moe_ep") and mesh is not None \
            and "model" in mesh.axis_names \
            and e_total % mesh.shape["model"] == 0 \
            and "shared" not in params:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        dp = SH.logical_axes(mesh, "dp")
        dp_size = 1
        for a in (dp if isinstance(dp, tuple) else (dp,) if dp else ()):
            dp_size *= mesh.shape[a]
        if dp and x.shape[0] % dp_size == 0:
            e_local = e_total // mesh.shape["model"]

            def local_ep(p, xl):
                off = jax.lax.axis_index("model") * e_local
                with SH.activations_on(None):   # no GSPMD constraints inside
                    out, aux = _moe_apply_core(
                        p, xl, top_k=top_k, capacity_factor=capacity_factor,
                        activation=activation, expert_offset=off,
                        n_global_experts=e_total)
                out = jax.lax.psum(out, "model")        # join the branches
                aux = {k: (jax.lax.pmean(jax.lax.pmean(v, dp), "model")
                           if jnp.ndim(v) == 0 else v)
                       for k, v in aux.items()}
                return out, aux

            pspec = {"router": P(), "w_in": P("model", None, None),
                     "w_out": P("model", None, None)}
            if "w_gate" in params:
                pspec["w_gate"] = P("model", None, None)
            fn = shard_map(local_ep, mesh=mesh,
                           in_specs=(pspec, P(dp, None, None)),
                           out_specs=(P(dp, None, None), P()),
                           check_rep=False)
            return fn(params, x)

    if impl == "grouped":
        return _moe_apply_grouped(params, x, top_k=top_k,
                                  capacity_factor=capacity_factor,
                                  activation=activation, interpret=interpret)
    return _moe_apply_core(params, x, top_k=top_k,
                           capacity_factor=capacity_factor,
                           activation=activation)


def moe_capacity(sk: int, capacity_factor: float, e_route: int) -> int:
    """Static per-(row, expert) capacity (GShard family): ceil to a
    multiple of 8 once past 8, never above S*k.  Shared by the dispatch,
    the plan pricing and the bench so the einsum engine's padded-slot
    denominator is the one the kernel path was actually compared to."""
    cap = int(-(-sk * capacity_factor // e_route))
    return max(1, min(-(-cap // 8) * 8 if cap >= 8 else cap, sk))


def _route(params, x, *, top_k: int, capacity_factor: float,
           expert_offset=0, n_global_experts: int | None = None):
    """Router + per-row sort-based dispatch shared by BOTH expert engines.

    Returns everything dispatch-order-dependent so the einsum and grouped
    paths see identical token ordering, identical drops and identical
    combine indices — the equivalence guarantee between the two engines
    reduces to the expert GEMMs themselves."""
    b, s, d = x.shape
    e = params["w_in"].shape[0]                # local experts to compute
    e_route = n_global_experts or e            # global routing space

    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)                    # (B, S, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    sk = s * top_k
    cap = moe_capacity(sk, capacity_factor, e_route)
    flat_e = ids.reshape(b, sk)                             # (B, S*k)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s), top_k)[None], (b, sk))
    flat_w = w.reshape(b, sk)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = jnp.take_along_axis(flat_t, order, axis=1)
    sw = jnp.take_along_axis(flat_w, order, axis=1)
    first = jax.vmap(
        lambda row: jnp.searchsorted(row, row, side="left"))(se)
    pos = jnp.arange(sk)[None] - first                      # rank in expert
    keep = pos < cap
    se_local = se - expert_offset                           # window shift
    in_window = (se_local >= 0) & (se_local < e)
    keep = keep & in_window
    brow = jnp.broadcast_to(jnp.arange(b)[:, None], (b, sk))
    return (probs, flat_e, se_local, st, sw, pos, keep, in_window, cap,
            brow, e, e_route, sk)


def _moe_aux(probs, flat_e, keep, in_window, brow, *, e, e_route, cap):
    """Switch load-balancing loss + drop/padding stats (shared)."""
    b, sk = flat_e.shape
    me = probs.mean((0, 1))                                 # (E_route,)
    ce = jnp.zeros((b, e_route), jnp.float32).at[brow, flat_e].add(1.0)
    ce = ce.sum(0) / (b * sk)
    aux_loss = e_route * jnp.sum(me * ce)
    n_window = jnp.maximum(in_window.sum().astype(jnp.float32), 1.0)
    kept = keep.sum().astype(jnp.float32)
    dropped = 1.0 - kept / n_window
    slots = float(b * e * cap)                 # the einsum engine's M rows
    return {"aux_loss": aux_loss, "drop_fraction": dropped,
            "capacity": cap,
            "padded_slot_fraction": (slots - kept) / slots}


def _moe_apply_core(params, x, *, top_k: int, capacity_factor: float = 1.25,
                    activation: str = "silu", expert_offset=0,
                    n_global_experts: int | None = None):
    """Batched-over-B dispatch/expert/combine (vmap-free sorts/gathers).

    With ``expert_offset``/``n_global_experts`` set (moe_ep shard_map path),
    routing runs over the GLOBAL expert space but only experts in the local
    window [offset, offset + E_local) are dispatched/computed; the caller
    psums the partial outputs over the expert axis."""
    b, s, d = x.shape
    (probs, flat_e, se_local, st, sw, pos, keep, in_window, cap, brow,
     e, e_route, sk) = _route(params, x, top_k=top_k,
                              capacity_factor=capacity_factor,
                              expert_offset=expert_offset,
                              n_global_experts=n_global_experts)
    slot = jnp.where(keep, se_local * cap + pos, e * cap)   # sentinel E*cap

    disp = jnp.full((b, e * cap + 1), s, jnp.int32)         # s -> zero row
    disp = disp.at[brow, slot].set(
        jnp.where(keep, st, s).astype(jnp.int32), mode="drop")
    xpad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(
        xpad, disp[:, : e * cap, None], axis=1).reshape(b, e, cap, d)
    xe = constrain(xe, "dp", "tp", None, None)

    # ---- expert branches (stacked GEMMs over the expert axis) --------------
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    h = jnp.einsum("becd,edf->becf", xe, params["w_in"])
    if "w_gate" in params:
        h = act(jnp.einsum("becd,edf->becf", xe, params["w_gate"])) * h
    else:
        h = act(h)
    h = constrain(h, "dp", "tp", None, None)
    ye = jnp.einsum("becf,efd->becd", h, params["w_out"])   # (B, E, C, D)
    ye = constrain(ye, "dp", "tp", None, None)

    # ---- weighted combine ---------------------------------------------------
    ypad = jnp.concatenate(
        [ye.reshape(b, e * cap, d),
         jnp.zeros((b, 1, d), ye.dtype)], axis=1)           # (B, E*C+1, D)
    contrib = jnp.take_along_axis(ypad, slot[..., None], axis=1) \
        * sw[..., None].astype(ye.dtype)                    # (B, S*k, D)
    out = jnp.zeros((b, s, d), ye.dtype).at[brow, st].add(contrib)

    if "shared" in params:
        out = out + L.mlp(params["shared"], x, activation).astype(out.dtype)

    aux = _moe_aux(probs, flat_e, keep, in_window, brow,
                   e=e, e_route=e_route, cap=cap)
    return out.reshape(b, s, d).astype(x.dtype), aux


def _moe_apply_grouped(params, x, *, top_k: int,
                       capacity_factor: float = 1.25,
                       activation: str = "silu",
                       interpret: bool | None = None):
    """Routed tokens packed into block-aligned per-expert segments of ONE
    (MBS*bm, D) buffer, expert compute in ONE ``grouped_matmul_experts``
    launch per direction.

    The pack permutation is a second stable argsort (by expert id, drops
    sorted last) on top of ``_route``'s per-row order; ``pp`` maps each
    routed assignment to its pack row and its inverse gathers the combine
    contributions, so combine indices and values match the einsum engine
    element-for-element (drops hit the appended zero row in both)."""
    from repro.kernels import ops as kops
    b, s, d = x.shape
    (probs, flat_e, se_local, st, sw, pos, keep, in_window, cap, brow,
     e, e_route, sk) = _route(params, x, top_k=top_k,
                              capacity_factor=capacity_factor)
    n = b * sk                                 # total routed assignments
    bm = kops.moe_block_m(n, e)
    n_pack = kops.moe_static_blocks(n, e, bm) * bm

    ge = jnp.where(keep, se_local, e).reshape(-1)           # drops -> E
    order2 = jnp.argsort(ge, stable=True)                   # global by expert
    sge = ge[order2]
    counts = jnp.zeros((e,), jnp.int32).at[sge].add(1, mode="drop")
    firstq = jnp.searchsorted(sge, sge, side="left")
    rank = jnp.arange(n, dtype=jnp.int32) - firstq          # rank in expert
    rowoff = kops.expert_row_offsets(counts, bm)
    pp_sorted = jnp.where(
        sge < e, rowoff[jnp.clip(sge, 0, e - 1)] + rank,
        n_pack).astype(jnp.int32)                           # drops -> trash
    pp = jnp.zeros((n,), jnp.int32).at[order2].set(pp_sorted)

    keep_f = keep.reshape(-1)
    fi = (brow * s + st).reshape(-1)                        # flat token idx
    dispv = jnp.full((n_pack + 1,), b * s, jnp.int32).at[pp].set(
        jnp.where(keep_f, fi, b * s).astype(jnp.int32), mode="drop")
    xflat = jnp.concatenate(
        [x.reshape(b * s, d), jnp.zeros((1, d), x.dtype)])  # b*s -> zeros
    xpk = xflat[dispv[:n_pack]]
    swpk = jnp.zeros((n_pack + 1,), jnp.float32).at[pp].set(
        jnp.where(keep_f, sw.reshape(-1), 0.0), mode="drop")[:n_pack]

    ypk = kops.grouped_matmul_experts(
        xpk, swpk, params["w_in"], params["w_out"], params.get("w_gate"),
        counts, activation=activation, bm=bm, interpret=interpret)

    ypad = jnp.concatenate([ypk, jnp.zeros((1, d), ypk.dtype)])
    contrib = ypad[pp].reshape(b, sk, d)       # drops gather the zero row
    out = jnp.zeros((b, s, d), ypk.dtype).at[brow, st].add(contrib)

    if "shared" in params:
        out = out + L.mlp(params["shared"], x, activation).astype(out.dtype)

    aux = _moe_aux(probs, flat_e, keep, in_window, brow,
                   e=e, e_route=e_route, cap=cap)
    return out.reshape(b, s, d).astype(x.dtype), aux


def build_moe_graph(*, b: int, s: int, d: int, f: int, e: int, top_k: int,
                    capacity_factor: float, gated: bool = True,
                    shared_f: int = 0, dtype_bytes: int = 4):
    """Op-graph view of one MoE layer for the plan layer: the router
    matmul forks into E independent expert chains (in/gate/out matmuls at
    the einsum engine's per-expert M = B*cap — the fork the scheduler
    sees; the grouped lowering re-prices them as ONE ragged launch) and
    the weighted combine joins them.  The optional shared MLP rides
    alongside the routed experts."""
    from repro.core.graph import Op, OpGraph

    g = OpGraph()
    sk = s * top_k
    cap = moe_capacity(sk, capacity_factor, e)
    g.add(Op.make("moe_router", "matmul", dtype_bytes, m=b * s, k=d, n=e))
    expert_ops = []
    for i in range(e):
        deps = ["moe_router"]
        g.add(Op.make(f"expert{i}_in", "matmul", dtype_bytes,
                      m=b * cap, k=d, n=f), deps)
        expert_ops.append(f"expert{i}_in")
        if gated:
            g.add(Op.make(f"expert{i}_gate", "matmul", dtype_bytes,
                          m=b * cap, k=d, n=f), deps)
            expert_ops.append(f"expert{i}_gate")
        g.add(Op.make(f"expert{i}_out", "matmul", dtype_bytes,
                      m=b * cap, k=f, n=d),
              [f"expert{i}_in"] + ([f"expert{i}_gate"] if gated else []))
        expert_ops.append(f"expert{i}_out")
    g.add(Op.make("moe_combine", "pointwise", dtype_bytes,
                  elements=b * sk * d), expert_ops)
    if shared_f:
        g.add(Op.make("shared_in", "matmul", dtype_bytes,
                      m=b * s, k=d, n=shared_f))
        if gated:
            g.add(Op.make("shared_gate", "matmul", dtype_bytes,
                          m=b * s, k=d, n=shared_f))
        g.add(Op.make("shared_out", "matmul", dtype_bytes,
                      m=b * s, k=shared_f, n=d),
              ["shared_in"] + (["shared_gate"] if gated else []))
    return g
