"""Mixture-of-Experts layer: top-k router + capacity-bounded sort dispatch.

Experts ARE the paper's independent branches: E disjoint GEMM chains forked
by the router and joined by the weighted combine.  At mesh scale they are
spatially partitioned (expert dim sharded over the ``model`` axis = the
paper's inter-SM partitioning, one expert group per chip group); intra-chip
the E-leading einsum is exactly the stacked branch-GEMM pattern of
``kernels/branch_matmul``.

Dispatch is sort-based with a static capacity (GShard/Switch family), done
PER BATCH ROW so every sort/scatter is local to a data shard — a global
token sort would force cross-device sorting and SPMD full-rematerialization
(observed: 424 GB/device temp on the 398B config before this formulation).
FLOPs scale with top_k (not E); tokens over capacity are dropped (standard)
and counted in aux stats.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.sharding import constrain


def moe_init(key, d: int, f: int, n_experts: int, *, shared_f: int = 0,
             gated: bool = True, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    std = d ** -0.5
    p = {
        "router": L.normal_init(ks[0], (d, n_experts), std, dtype),
        "w_in": L.normal_init(ks[1], (n_experts, d, f), std, dtype),
        "w_out": L.normal_init(ks[2], (n_experts, f, d), f ** -0.5, dtype),
    }
    if gated:
        p["w_gate"] = L.normal_init(ks[3], (n_experts, d, f), std, dtype)
    if shared_f:
        p["shared"] = L.mlp_init(ks[4], d, shared_f, gated=gated, dtype=dtype)
    return p


def moe_apply(params, x, *, top_k: int, capacity_factor: float = 1.25,
              activation: str = "silu"):
    """x: (B, S, D) -> (out (B, S, D), aux dict).

    Under the ``moe_local`` perf option (requires replicated expert params,
    i.e. dp_over_model), the whole dispatch/combine runs inside shard_map
    per data shard: sorts/scatters become chip-local, eliminating the
    GSPMD scatter-add all-reduce (measured 4.3 GB x n_layers on granite)."""
    from repro.sharding import specs as SH
    mesh = getattr(SH._CTX, "mesh", None)
    if SH.perf_option("moe_local") and mesh is not None:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        dp = SH.logical_axes(mesh, "dp")
        dp_size = 1
        for a in (dp if isinstance(dp, tuple) else (dp,) if dp else ()):
            dp_size *= mesh.shape[a]
        if dp and x.shape[0] % dp_size == 0:
            def local(p, xl):
                with SH.activations_on(None):   # no GSPMD constraints inside
                    out, aux = _moe_apply_core(p, xl, top_k=top_k,
                                               capacity_factor=capacity_factor,
                                               activation=activation)
                aux = {k: jax.lax.pmean(v, dp) if jnp.ndim(v) == 0 else v
                       for k, v in aux.items()}
                return out, aux

            fn = shard_map(local, mesh=mesh,
                           in_specs=(P(), P(dp, None, None)),
                           out_specs=(P(dp, None, None), P()),
                           check_rep=False)
            return fn(params, x)

    # moe_ep: expert-parallel local dispatch — experts stay sharded over the
    # ``model`` axis (the paper's spatial branch partitioning); each chip
    # routes its data shard locally, computes ONLY its local experts, and a
    # single psum over ``model`` joins the branches.  Eliminates the GSPMD
    # gather/scatter all-reduces (measured ~600 GB/step on jamba train_4k).
    e_total = params["router"].shape[1]
    if SH.perf_option("moe_ep") and mesh is not None \
            and "model" in mesh.axis_names \
            and e_total % mesh.shape["model"] == 0 \
            and "shared" not in params:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        dp = SH.logical_axes(mesh, "dp")
        dp_size = 1
        for a in (dp if isinstance(dp, tuple) else (dp,) if dp else ()):
            dp_size *= mesh.shape[a]
        if dp and x.shape[0] % dp_size == 0:
            e_local = e_total // mesh.shape["model"]

            def local_ep(p, xl):
                off = jax.lax.axis_index("model") * e_local
                with SH.activations_on(None):   # no GSPMD constraints inside
                    out, aux = _moe_apply_core(
                        p, xl, top_k=top_k, capacity_factor=capacity_factor,
                        activation=activation, expert_offset=off,
                        n_global_experts=e_total)
                out = jax.lax.psum(out, "model")        # join the branches
                aux = {k: (jax.lax.pmean(jax.lax.pmean(v, dp), "model")
                           if jnp.ndim(v) == 0 else v)
                       for k, v in aux.items()}
                return out, aux

            pspec = {"router": P(), "w_in": P("model", None, None),
                     "w_out": P("model", None, None)}
            if "w_gate" in params:
                pspec["w_gate"] = P("model", None, None)
            fn = shard_map(local_ep, mesh=mesh,
                           in_specs=(pspec, P(dp, None, None)),
                           out_specs=(P(dp, None, None), P()),
                           check_rep=False)
            return fn(params, x)

    return _moe_apply_core(params, x, top_k=top_k,
                           capacity_factor=capacity_factor,
                           activation=activation)


def _moe_apply_core(params, x, *, top_k: int, capacity_factor: float = 1.25,
                    activation: str = "silu", expert_offset=0,
                    n_global_experts: int | None = None):
    """Batched-over-B dispatch/expert/combine (vmap-free sorts/gathers).

    With ``expert_offset``/``n_global_experts`` set (moe_ep shard_map path),
    routing runs over the GLOBAL expert space but only experts in the local
    window [offset, offset + E_local) are dispatched/computed; the caller
    psums the partial outputs over the expert axis."""
    b, s, d = x.shape
    e = params["w_in"].shape[0]                # local experts to compute
    e_route = n_global_experts or e            # global routing space

    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)                    # (B, S, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    # ---- per-row sort-based dispatch ---------------------------------------
    sk = s * top_k
    cap = int(-(-sk * capacity_factor // e_route))
    cap = max(1, min(-(-cap // 8) * 8 if cap >= 8 else cap, sk))
    flat_e = ids.reshape(b, sk)                             # (B, S*k)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s), top_k)[None], (b, sk))
    flat_w = w.reshape(b, sk)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st = jnp.take_along_axis(flat_t, order, axis=1)
    sw = jnp.take_along_axis(flat_w, order, axis=1)
    first = jax.vmap(
        lambda row: jnp.searchsorted(row, row, side="left"))(se)
    pos = jnp.arange(sk)[None] - first                      # rank in expert
    keep = pos < cap
    se_local = se - expert_offset                           # window shift
    in_window = (se_local >= 0) & (se_local < e)
    keep = keep & in_window
    slot = jnp.where(keep, se_local * cap + pos, e * cap)   # sentinel E*cap

    disp = jnp.full((b, e * cap + 1), s, jnp.int32)         # s -> zero row
    brow = jnp.broadcast_to(jnp.arange(b)[:, None], (b, sk))
    disp = disp.at[brow, slot].set(
        jnp.where(keep, st, s).astype(jnp.int32), mode="drop")
    xpad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(
        xpad, disp[:, : e * cap, None], axis=1).reshape(b, e, cap, d)
    xe = constrain(xe, "dp", "tp", None, None)

    # ---- expert branches (stacked GEMMs over the expert axis) --------------
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    h = jnp.einsum("becd,edf->becf", xe, params["w_in"])
    if "w_gate" in params:
        h = act(jnp.einsum("becd,edf->becf", xe, params["w_gate"])) * h
    else:
        h = act(h)
    h = constrain(h, "dp", "tp", None, None)
    ye = jnp.einsum("becf,efd->becd", h, params["w_out"])   # (B, E, C, D)
    ye = constrain(ye, "dp", "tp", None, None)

    # ---- weighted combine ---------------------------------------------------
    ypad = jnp.concatenate(
        [ye.reshape(b, e * cap, d),
         jnp.zeros((b, 1, d), ye.dtype)], axis=1)           # (B, E*C+1, D)
    contrib = jnp.take_along_axis(ypad, slot[..., None], axis=1) \
        * sw[..., None].astype(ye.dtype)                    # (B, S*k, D)
    out = jnp.zeros((b, s, d), ye.dtype).at[brow, st].add(contrib)

    if "shared" in params:
        out = out + L.mlp(params["shared"], x, activation).astype(out.dtype)

    # ---- aux: switch load-balancing loss + drop stats -----------------------
    me = probs.mean((0, 1))                                 # (E_route,)
    ce = jnp.zeros((b, e_route), jnp.float32).at[brow, flat_e].add(1.0)
    ce = ce.sum(0) / (b * sk)
    aux_loss = e_route * jnp.sum(me * ce)
    n_window = jnp.maximum(in_window.sum().astype(jnp.float32), 1.0)
    dropped = 1.0 - keep.sum().astype(jnp.float32) / n_window
    return out.reshape(b, s, d).astype(x.dtype), {
        "aux_loss": aux_loss, "drop_fraction": dropped, "capacity": cap}
