"""GQA attention layer: init/apply + KV cache, XLA and Pallas paths.

impl="xla"    — einsum/scan implementation, fully GSPMD-partitionable: this
                is what the multi-pod dry-run lowers (clean HLO, exact FLOPs).
                Long sequences use a kv-chunked online-softmax scan (bounded
                memory, flash-equivalent math).
impl="pallas" — the flash kernel from ``kernels/`` (per-device shapes;
                used on real TPU inside shard_map, and in tests/benchmarks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import attention as pallas_attention
from repro.models import layers as L
from repro.sharding import constrain

_NEG_INF = -1e30


def attn_init(key, d: int, hq: int, hkv: int, hd: int, dtype=jnp.float32,
              qkv_bias: bool = False):
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": L.normal_init(ks[0], (d, hq * hd), std, dtype),
        "wk": L.normal_init(ks[1], (d, hkv * hd), std, dtype),
        "wv": L.normal_init(ks[2], (d, hkv * hd), std, dtype),
        "wo": L.normal_init(ks[3], (hq * hd, d), (hq * hd) ** -0.5, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _sdpa_xla(q, k, v, *, causal, window, softcap, scale, qpos_base=None,
              chunk_kv: int = 1024, chunk_q: int = 1024):
    """Online-softmax chunked attention in pure jnp (flash-equivalent).

    qpos_base: position of q[0] among the keys (default skv - sq: suffix
    alignment for decode; 0 for prefill-into-cache)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    if qpos_base is None:
        qpos_base = skv - sq
    # Materialized scores only when the f32 score matrix is small (or decode):
    # at 4k+ train shapes the (Sq, Skv) f32 scores dominate HBM traffic.
    if sq * skv <= 1024 * 1024 or sq == 1:
        return _sdpa_materialized(q, k, v, causal=causal, window=window,
                                  softcap=softcap, scale=scale,
                                  qpos_base=qpos_base)
    g = hq // hkv
    nq = -(-sq // chunk_q)
    sq_p = nq * chunk_q
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    qp = qp.reshape(b, nq, chunk_q, hq, d)

    nk = -(-skv // chunk_kv)
    skv_p = nk * chunk_kv
    kp = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    kc = kp.reshape(b, nk, chunk_kv, hkv, d)
    vc = vp.reshape(b, nk, chunk_kv, hkv, d)

    def q_block(qi_and_idx, nk_used=None):
        qi, iq = qi_and_idx            # (B, cq, Hq, D), scalar
        qi = qi.astype(jnp.float32).reshape(b, chunk_q, hkv, g, d)

        def kv_step(carry, inp):
            acc, m, l = carry
            kj, vj, jk = inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi,
                           kj.astype(jnp.float32)) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            qpos = iq * chunk_q + jnp.arange(chunk_q) + qpos_base
            kpos = jk * chunk_kv + jnp.arange(chunk_kv)
            mask = kpos[None, :] < skv
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None, None], s, _NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vj.astype(jnp.float32))
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, hkv, g, chunk_q, d), jnp.float32)
        m0 = jnp.full((b, hkv, g, chunk_q), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, chunk_q), jnp.float32)
        used = nk if nk_used is None else nk_used
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (kc[:, :used].transpose(1, 0, 2, 3, 4),
             vc[:, :used].transpose(1, 0, 2, 3, 4),
             jnp.arange(used)))
        l = jnp.where(l == 0.0, 1.0, l)
        out = (acc / l[..., None]).transpose(0, 3, 1, 2, 4)  # (B, cq, hkv, g, D)
        return out.reshape(b, chunk_q, hq, d)

    from repro.sharding.specs import perf_option
    if causal and isinstance(qpos_base, int) and qpos_base == skv - sq \
            and perf_option("causal_skip"):
        # §Perf lever: triangular schedule — q block i only visits kv chunks
        # [0, ceil((i+1)*cq/ckv)]; fully-masked chunks are never computed.
        # Unrolled over q blocks (static per-block kv lengths); ~2x FLOP
        # saving at sq == skv.
        outs = []
        for i in range(nq):
            hi = min(-(-((i + 1) * chunk_q) // chunk_kv), nk)
            outs.append(q_block((qp[:, i], jnp.int32(i)), nk_used=hi))
        out = jnp.stack(outs, axis=1).reshape(b, sq_p, hq, d)[:, :sq]
        return out.astype(q.dtype)

    outs = jax.lax.map(q_block, (qp.transpose(1, 0, 2, 3, 4), jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq_p, hq, d)[:, :sq]
    return out.astype(q.dtype)


def _sdpa_materialized(q, k, v, *, causal, window, softcap, scale,
                       qpos_base=None):
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    if qpos_base is None:
        qpos_base = skv - sq
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(sq) + qpos_base
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def attn_apply(params, x, *, hq: int, hkv: int, hd: int,
               positions=None, kv_cache=None, cache_pos=None,
               causal: bool = True, window: int | None = None,
               softcap: float | None = None, rope_theta: float | None = 10000.0,
               query_scale: float | None = None,
               impl: str = "xla", context=None):
    """Self-attention with optional KV cache.

    x: (B, S, D).  kv_cache: (2, B, Smax, Hkv, hd) or None.
    cache_pos: int32 scalar — write position of x's first token in the cache.
    context: (B, Sctx, D) for cross-attention (k/v from context, no cache,
    no causal mask).
    Returns (out, new_kv_cache).
    """
    b, s, _ = x.shape
    src = context if context is not None else x
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"])
    k = jnp.einsum("bsd,dh->bsh", src, params["wk"])
    v = jnp.einsum("bsd,dh->bsh", src, params["wv"])
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = constrain(q.reshape(b, s, hq, hd), "dp", None, "tp", None)
    k = constrain(k.reshape(b, src.shape[1], hkv, hd), "dp", None, "tp", None)
    v = constrain(v.reshape(b, src.shape[1], hkv, hd), "dp", None, "tp", None)

    if rope_theta is not None and context is None:
        if positions is None:
            base = 0 if cache_pos is None else cache_pos
            positions = base + jnp.arange(s)[None, :]
        q = L.rope(q, positions, rope_theta)
        k = L.rope(k, positions, rope_theta)

    if kv_cache is not None:
        # Write new k/v at cache_pos, attend over the whole cache.
        kc = jax.lax.dynamic_update_slice(
            kv_cache[0], k.astype(kv_cache.dtype), (0, cache_pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            kv_cache[1], v.astype(kv_cache.dtype), (0, cache_pos, 0, 0))
        new_cache = jnp.stack([kc, vc])
        k, v = kc.astype(x.dtype), vc.astype(x.dtype)
    else:
        new_cache = None

    scale = query_scale if query_scale is not None else hd ** -0.5
    # qpos_base: with a cache, q[0] sits at cache_pos (prefill writes from 0,
    # decode writes one slot) — masks out not-yet-written cache slots.
    # Without a cache (training), suffix alignment (skv - sq) applies.
    qpos_base = cache_pos if kv_cache is not None else None
    kw = dict(causal=causal and context is None, window=window,
              softcap=softcap, scale=scale)
    if impl == "pallas" and qpos_base is None:
        out = pallas_attention(q, k, v, algorithm="flash", **kw)
    else:
        out = _sdpa_xla(q, k, v, qpos_base=qpos_base, **kw)
    out = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, hq * hd), params["wo"])
    return out, new_cache
