"""Mamba-2 SSD (state-space duality) chunked-scan Pallas kernel.

Algorithm zoo for the SSM mixer:

  chunked   — the SSD blocked algorithm: a Pallas kernel computes, per
              (batch, chunk) grid cell, the quadratic *intra-chunk* output
              and the end-of-chunk state contribution; a cheap inter-chunk
              linear recurrence (jnp scan) threads states across chunks.
              Workspace = per-chunk states (B * nc * H * N * P).
  quadratic — the full S x S materialized semiseparable matrix (ref-like,
              XLA).  Workspace = B * S * S * H * 4 bytes: fine for short
              sequences, catastrophic at 32k+ — the exact Table-2 tradeoff.

Interface is pre-discretized (the model layer applies dt):
  x (B, S, H, P), a_log (B, S, H) negative log-decays,
  b, c (B, S, G, N) with H % G == 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import ssd_ref


def _ssd_chunk_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref, cum_ref, *,
                      l: int, heads: int, p: int, g: int, n: int):
    """One (batch, chunk) cell: intra-chunk quadratic output + chunk state."""
    x = x_ref[0, 0].astype(jnp.float32)          # (L, H, P)
    a = a_ref[0, 0].astype(jnp.float32)          # (L, H)
    bb = b_ref[0, 0].astype(jnp.float32)         # (L, G, N)
    cc = c_ref[0, 0].astype(jnp.float32)         # (L, G, N)
    rep = heads // g

    cum = jnp.cumsum(a, axis=0)                  # (L, H)
    # decay[t, s, h] = exp(cum[t] - cum[s]) for s <= t; mask inside the exp
    # so masked lanes never overflow (NaN-safe under autodiff)
    diff = cum[:, None, :] - cum[None, :, :]     # (L, L, H)
    ts = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    ss = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    decay = jnp.exp(jnp.where((ss <= ts)[..., None], diff, -1e30))
    cb = jnp.einsum("tgn,sgn->tsg", cc, bb,
                    preferred_element_type=jnp.float32)
    cb = jnp.repeat(cb, rep, axis=2)             # (L, L, H)
    y = jnp.einsum("tsh,shp->thp", cb * decay, x,
                   preferred_element_type=jnp.float32)
    # End-of-chunk state: sum_s exp(cum[-1] - cum[s]) * b[s] (x) x[s]
    sdecay = jnp.exp(cum[-1:] - cum)             # (L, H)
    bh = jnp.repeat(bb, rep, axis=1)             # (L, H, N)
    st = jnp.einsum("shn,sh,shp->hnp", bh, sdecay, x,
                    preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    st_ref[0, 0] = st
    cum_ref[0, 0] = cum


def ssd_chunked(x, a_log, b, c, *, chunk: int = 128, d_skip=None,
                init_state=None, return_final_state: bool = False,
                interpret: bool = False):
    bsz, s, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    l = min(chunk, s)
    s_p = -(-s // l) * l
    pad = s_p - s
    if pad:
        # Zero x (no output contribution) and zero a_log (decay 1, harmless
        # since padded x contributes nothing to states).
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = s_p // l
    xc = x.reshape(bsz, nc, l, h, p)
    ac = a_log.reshape(bsz, nc, l, h)
    bc = b.reshape(bsz, nc, l, g, n)
    cc = c.reshape(bsz, nc, l, g, n)

    y_diag, states, cum = pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, l=l, heads=h, p=p, g=g, n=n),
        grid=(bsz, nc),
        in_specs=[
            pl.BlockSpec((1, 1, l, h, p), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, l, h), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, l, g, n), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, l, g, n), lambda i, j: (i, j, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, l, h, p), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, h, n, p), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, l, h), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nc, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, nc, h, n, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, nc, l, h), jnp.float32),
        ],
        interpret=interpret,
    )(xc, ac, bc, cc)

    # Inter-chunk recurrence: S_in[c] = sum_{c'<c} exp(sum a over (c', c)) st[c']
    a_tot = cum[:, :, -1]                        # (B, nc, H)

    def step(s_in, inp):
        a_c, st_c = inp
        s_next = s_in * jnp.exp(a_c)[:, :, None, None] + st_c
        return s_next, s_in

    init = (jnp.zeros((bsz, h, n, p), jnp.float32) if init_state is None
            else init_state.astype(jnp.float32))
    final_state, s_in = jax.lax.scan(
        step, init, (a_tot.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    s_in = s_in.transpose(1, 0, 2, 3, 4)         # (B, nc, H, N, P) entering each chunk

    # Off-diagonal: y_off[t] = (c[t] . S_in) * exp(cum[t])
    rep = h // g
    ch = jnp.repeat(cc, rep, axis=3)             # (B, nc, L, H, N)
    y_off = jnp.einsum("bclhn,bchnp,bclh->bclhp", ch.astype(jnp.float32),
                       s_in, jnp.exp(cum))
    y = y_diag.astype(jnp.float32) + y_off
    y = y.reshape(bsz, s_p, h, p)[:, :s]
    if d_skip is not None:
        y = y + d_skip.astype(jnp.float32)[None, None, :, None] * \
            xc.reshape(bsz, s_p, h, p)[:, :s].astype(jnp.float32)
    y = y.astype(x.dtype)
    if return_final_state:
        return y, final_state
    return y


def ssd_quadratic(x, a_log, b, c, *, d_skip=None, interpret: bool = False):
    """Materialized S x S algorithm (XLA path; huge workspace)."""
    return ssd_ref(x, a_log, b, c, d_skip=d_skip)


SSD_ALGORITHMS = {
    "chunked": ssd_chunked,
    "quadratic": ssd_quadratic,
}


def ssd_workspace_bytes(algorithm: str, bsz, s, h, n, p, chunk=128) -> int:
    if algorithm == "quadratic":
        return bsz * s * s * h * 4
    nc = -(-s // chunk)
    return bsz * nc * h * n * p * 4
