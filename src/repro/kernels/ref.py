"""Pure-jnp oracles for every Pallas kernel (the ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def branch_matmul_ref(x, y):
    return jnp.einsum("gmk,gkn->gmn", x, y,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def conv2d_ref(x, w, *, stride: int = 1, padding: str = "SAME"):
    # f32 accumulation via explicit casts (not preferred_element_type):
    # the conv TRANSPOSE then sees a same-dtype f32 conv, so bf16 inputs
    # stay differentiable (mixed-dtype conv transpose is rejected by lax)
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(x.dtype)


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  softcap: float | None = None, scale: float | None = None):
    """Grouped-query attention oracle.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D); Hq % Hkv == 0.
    window: sliding-window size (tokens attend to the last `window` keys).
    Query position i is aligned to key position i + (Skv - Sq) (decode case).
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, group, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(sq) + (skv - sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def ssd_ref(x, a_log, b, c, *, dt=None, d_skip=None):
    """Mamba-2 SSD oracle via the quadratic (attention-like) form.

    x: (B, S, H, P)   inputs (already multiplied by dt if dt is None)
    a_log: (B, S, H)  per-step log decay (negative); cumulative decay
    b: (B, S, G, N)   input->state projections (G state groups, GQA-style)
    c: (B, S, G, N)   state->output projections; H % G == 0
    y[t] = sum_{s<=t} (prod_{r=s+1..t} exp(a_log[r])) * (c[t]·b[s]) * x[s]
    """
    bsz, s, h, p = x.shape
    g = b.shape[2]
    rep = h // g
    xf = x.astype(jnp.float32)
    al = a_log.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    cf = c.astype(jnp.float32)
    cum = jnp.cumsum(al, axis=1)                     # (B, S, H)
    # L[t, s] = exp(cum[t] - cum[s]) for s <= t else 0 (mask inside exp:
    # NaN-safe under autodiff)
    diff = cum[:, :, None, :] - cum[:, None, :, :]   # (B, T, S, H)
    ts = jnp.arange(s)
    causal = ts[:, None] >= ts[None, :]
    decay = jnp.exp(jnp.where(causal[None, :, :, None], diff, -1e30))
    cb = jnp.einsum("btgn,bsgn->btsg", cf, bf)       # (B, T, S, G)
    cb = jnp.repeat(cb, rep, axis=3)                 # (B, T, S, H)
    y = jnp.einsum("btsh,btsh,bshp->bthp", cb, decay, xf)
    if d_skip is not None:
        y = y + d_skip.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype)
