"""Public jit-friendly wrappers over the kernel algorithm zoo.

Every op takes ``algorithm=`` (the paper's central knob) and an
``interpret=`` override; on a CPU-only host the Pallas kernels run in
interpret mode automatically so the whole framework is testable without TPU.
Wrappers pad to hardware-aligned block shapes and slice back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import matmul as _mm
from repro.kernels import conv2d as _conv
from repro.kernels import flash_attention as _attn
from repro.kernels import ssd as _ssd
from repro.kernels import branch_matmul as _bmm


@functools.cache
def default_interpret() -> bool:
    """Pallas interpret mode unless a real TPU backend is present."""
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

def matmul(x, y, *, algorithm: str = "mxu128", interpret: bool | None = None):
    """(…, M, K) @ (K, N) with padding to MXU-aligned blocks."""
    interpret = default_interpret() if interpret is None else interpret
    lead = x.shape[:-2] if x.ndim > 2 else ()
    m = int(jnp.prod(jnp.array(x.shape[:-1]))) if x.ndim > 2 else x.shape[0]
    x2 = x.reshape(-1, x.shape[-1])
    m, k = x2.shape
    k2, n = y.shape
    assert k == k2
    bm, bn, bk = _mm.matmul_block_shape(algorithm)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    out = _mm.MATMUL_ALGORITHMS[algorithm](xp, yp, interpret=interpret)
    out = out[:m, :n]
    return out.reshape(*lead, x.shape[-2] if x.ndim > 2 else m, n) \
        if x.ndim > 2 else out


matmul_workspace_bytes = _mm.matmul_workspace_bytes
matmul_vmem_bytes = _mm.matmul_vmem_bytes
MATMUL_ALGORITHMS = tuple(_mm.MATMUL_ALGORITHMS)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

def conv2d(x, w, *, stride: int = 1, padding: str = "SAME",
           algorithm: str = "im2col_gemm", interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    fn = _conv.CONV2D_ALGORITHMS[algorithm]
    return fn(x, w, stride=stride, padding=padding, interpret=interpret)


conv2d_workspace_bytes = _conv.conv2d_workspace_bytes
CONV2D_ALGORITHMS = tuple(_conv.CONV2D_ALGORITHMS)


def conv2d_supported(algorithm: str, kh: int, kw: int, stride: int) -> bool:
    """cuDNN-style support matrix ("DIRECT and WINOGRAD are not supported
    for this input" — Table 2 footnote analogue)."""
    if algorithm == "winograd3x3":
        return (kh, kw) == (3, 3) and stride == 1
    return True


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              softcap: float | None = None, scale: float | None = None,
              algorithm: str = "flash", block_q: int = 128, block_k: int = 128,
              interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    if algorithm == "materialized":
        return _attn.attention_materialized(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale)
    return _attn.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)


attention_workspace_bytes = _attn.attention_workspace_bytes
ATTENTION_ALGORITHMS = tuple(_attn.ATTENTION_ALGORITHMS)


# ---------------------------------------------------------------------------
# ssd (Mamba-2)
# ---------------------------------------------------------------------------

def ssd(x, a_log, b, c, *, chunk: int = 128, d_skip=None,
        algorithm: str = "chunked", interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    if algorithm == "quadratic":
        return _ssd.ssd_quadratic(x, a_log, b, c, d_skip=d_skip)
    return _ssd.ssd_chunked(x, a_log, b, c, chunk=chunk, d_skip=d_skip,
                            interpret=interpret)


ssd_workspace_bytes = _ssd.ssd_workspace_bytes
SSD_ALGORITHMS = tuple(_ssd.SSD_ALGORITHMS)


# ---------------------------------------------------------------------------
# branch matmul (stacked independent GEMMs)
# ---------------------------------------------------------------------------

def branch_matmul(x, y, *, interpret: bool | None = None):
    """(G, M, K) @ (G, K, N) -> (G, M, N), padded per-branch."""
    interpret = default_interpret() if interpret is None else interpret
    g, m, k = x.shape
    _, _, n = y.shape
    bm = bn = bk = 128
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, 0), (0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, 0), (0, kp - k), (0, np_ - n)))
    out = _bmm.branch_matmul(xp, yp, interpret=interpret)
    return out[:, :m, :n]
