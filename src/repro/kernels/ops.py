"""Public jit-friendly wrappers over the kernel algorithm zoo.

Every op takes ``algorithm=`` (the paper's central knob) and an
``interpret=`` override; on a CPU-only host the Pallas kernels run in
interpret mode automatically so the whole framework is testable without TPU.
Wrappers pad to hardware-aligned block shapes and slice back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import matmul as _mm
from repro.kernels import conv2d as _conv
from repro.kernels import flash_attention as _attn
from repro.kernels import ssd as _ssd
from repro.kernels import branch_matmul as _bmm
from repro.kernels import fused_branches as _fused
from repro.kernels import grouped_matmul as _gmm


@functools.cache
def default_interpret() -> bool:
    """Pallas interpret mode unless a real TPU backend is present."""
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

def matmul(x, y, *, algorithm: str = "mxu128", interpret: bool | None = None):
    """(…, M, K) @ (K, N) with padding to MXU-aligned blocks."""
    interpret = default_interpret() if interpret is None else interpret
    lead = x.shape[:-2] if x.ndim > 2 else ()
    m = int(jnp.prod(jnp.array(x.shape[:-1]))) if x.ndim > 2 else x.shape[0]
    x2 = x.reshape(-1, x.shape[-1])
    m, k = x2.shape
    k2, n = y.shape
    assert k == k2
    bm, bn, bk = _mm.matmul_block_shape(algorithm)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    out = _mm.MATMUL_ALGORITHMS[algorithm](xp, yp, interpret=interpret)
    out = out[:m, :n]
    return out.reshape(*lead, x.shape[-2] if x.ndim > 2 else m, n) \
        if x.ndim > 2 else out


matmul_workspace_bytes = _mm.matmul_workspace_bytes
matmul_vmem_bytes = _mm.matmul_vmem_bytes
MATMUL_ALGORITHMS = tuple(_mm.MATMUL_ALGORITHMS)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

def conv2d(x, w, *, stride: int = 1, padding: str = "SAME",
           algorithm: str = "im2col_gemm", interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    fn = _conv.CONV2D_ALGORITHMS[algorithm]
    return fn(x, w, stride=stride, padding=padding, interpret=interpret)


conv2d_workspace_bytes = _conv.conv2d_workspace_bytes
CONV2D_ALGORITHMS = tuple(_conv.CONV2D_ALGORITHMS)


def conv2d_supported(algorithm: str, kh: int, kw: int, stride: int) -> bool:
    """cuDNN-style support matrix ("DIRECT and WINOGRAD are not supported
    for this input" — Table 2 footnote analogue)."""
    if algorithm == "winograd3x3":
        return (kh, kw) == (3, 3) and stride == 1
    return True


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              softcap: float | None = None, scale: float | None = None,
              algorithm: str = "flash", block_q: int = 128, block_k: int = 128,
              interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    if algorithm == "materialized":
        return _attn.attention_materialized(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale)
    return _attn.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)


attention_workspace_bytes = _attn.attention_workspace_bytes
ATTENTION_ALGORITHMS = tuple(_attn.ATTENTION_ALGORITHMS)


# ---------------------------------------------------------------------------
# ssd (Mamba-2)
# ---------------------------------------------------------------------------

def ssd(x, a_log, b, c, *, chunk: int = 128, d_skip=None,
        algorithm: str = "chunked", interpret: bool | None = None):
    interpret = default_interpret() if interpret is None else interpret
    if algorithm == "quadratic":
        return _ssd.ssd_quadratic(x, a_log, b, c, d_skip=d_skip)
    return _ssd.ssd_chunked(x, a_log, b, c, chunk=chunk, d_skip=d_skip,
                            interpret=interpret)


ssd_workspace_bytes = _ssd.ssd_workspace_bytes
SSD_ALGORITHMS = tuple(_ssd.SSD_ALGORITHMS)


# ---------------------------------------------------------------------------
# branch matmul (stacked independent GEMMs)
# ---------------------------------------------------------------------------

def branch_matmul(x, y, *, interpret: bool | None = None):
    """(G, M, K) @ (G, K, N) -> (G, M, N), padded per-branch.

    Differentiable: the custom VJP computes dx/dy with the SAME stacked
    kernel (the backward GEMMs of G independent branches are themselves G
    independent same-shape GEMMs)."""
    interpret = default_interpret() if interpret is None else interpret
    return _branch_matmul_vjp(x, y, interpret)


def _branch_matmul_padded(x, y, interpret: bool):
    g, m, k = x.shape
    _, _, n = y.shape
    bm = bn = bk = 128
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, 0), (0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, 0), (0, kp - k), (0, np_ - n)))
    out = _bmm.branch_matmul(xp, yp, interpret=interpret)
    return out[:, :m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _branch_matmul_vjp(x, y, interpret):
    return _branch_matmul_padded(x, y, interpret)


def _branch_matmul_fwd(x, y, interpret):
    return _branch_matmul_padded(x, y, interpret), (x, y)


def _branch_matmul_bwd(interpret, res, g):
    x, y = res
    g = g.astype(x.dtype)
    dx = _branch_matmul_padded(g, y.transpose(0, 2, 1), interpret)
    dy = _branch_matmul_padded(x.transpose(0, 2, 1), g, interpret)
    return dx, dy


_branch_matmul_vjp.defvjp(_branch_matmul_fwd, _branch_matmul_bwd)


# ---------------------------------------------------------------------------
# grouped ragged branch GEMM (per-branch (K_g, N_g), fused epilogue)
# ---------------------------------------------------------------------------

def grouped_matmul(xs, ws, bs=None, *, relu: bool = False, m_valid=None,
                   interpret: bool | None = None):
    """G ragged branch GEMMs (M, K_g) @ (K_g, N_g) (+bias, +ReLU) in ONE
    kernel — see ``kernels/grouped_matmul.py``.

    Differentiable, and the backward pass co-executes too: the custom VJP
    emits exactly ONE combined grouped launch
    (``kernels/grouped_matmul.py::grouped_matmul_bwd``) — masked dx, dw
    and db over a concatenated two-phase offset table, with the dY/mask
    tile stacks packed once and shared between the phases.  No per-branch
    XLA fallback, and no second launch, remains on the grouped path.

    ``m_valid`` (python int or traced i32 scalar) makes the launch
    ragged-M — the serving path's bucketed multi-request batches, where
    rows at/past ``m_valid`` are padding and the epilogue stores zeros
    there.  The ragged path is INFERENCE-ONLY (a direct kernel call, no
    custom VJP: an integer row count has no meaningful cotangent and the
    serving driver never differentiates)."""
    interpret = default_interpret() if interpret is None else interpret
    if m_valid is not None:
        return list(_gmm.grouped_matmul(list(xs), list(ws),
                                        None if bs is None else list(bs),
                                        relu=relu, m_valid=m_valid,
                                        interpret=interpret))
    return _grouped_vjp(tuple(xs), tuple(ws),
                        None if bs is None else tuple(bs), relu, interpret)


def grouped_matmul_dw(xs, dys, ys=None, *, interpret: bool | None = None):
    """(dws, dbs) of a grouped branch GEMM in ONE kernel: dw_g = x_g^T @
    dy_g (dy masked by y_g > 0 when ``ys`` is given) with db_g reduced in
    the same pass — see ``kernels/grouped_matmul.py``."""
    interpret = default_interpret() if interpret is None else interpret
    return _gmm.grouped_matmul_dw(xs, dys, ys, interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _grouped_vjp(xs, ws, bs, relu, interpret):
    return tuple(_gmm.grouped_matmul(xs, ws, bs, relu=relu,
                                     interpret=interpret))


def _grouped_fwd(xs, ws, bs, relu, interpret):
    ys = _grouped_vjp(xs, ws, bs, relu, interpret)
    return ys, (xs, ws, bs, ys if relu else None)


def _grouped_bwd(relu, interpret, res, gs):
    xs, ws, bs, ys = res
    dys = [g.astype(x.dtype) for g, x in zip(gs, xs)]
    mask = list(ys) if relu else None
    # ONE combined launch: masked dx + dw + db over the concatenated
    # two-phase offset table (was two grouped launches, with the dY and
    # mask stacks packed once per launch instead of once per call)
    dxs, dws, dbs = _gmm.grouped_matmul_bwd(xs, ws, dys, mask,
                                            interpret=interpret)
    dws = tuple(dw.astype(w.dtype) for dw, w in zip(dws, ws))
    dbs = None if bs is None else tuple(
        db.astype(b.dtype) for db, b in zip(dbs, bs))
    return tuple(dxs), dws, dbs


_grouped_vjp.defvjp(_grouped_fwd, _grouped_bwd)


def grouped_matmul_concat(xs, ws, bs=None, *, offsets, total: int,
                          relu: bool = False, compact: bool = True,
                          m_valid=None, interpret: bool | None = None):
    """Fused epilogue-concat grouped GEMM: G ragged branches whose
    bias+ReLU epilogues write straight into the fork/join's (M, total)
    concat layout at per-branch column ``offsets`` — the join leaves the
    kernel assembled, with no per-branch HBM round-trip and no standalone
    concatenate op (``kernels/grouped_matmul.py::grouped_matmul_concat``).

    Columns not covered by a branch (passthrough slices produced by an
    earlier launch) are placeholders — overwrite them before use.
    ``compact=False`` returns the padded (M, sum Np_g) join buffer
    instead (see the kernel wrapper).  Differentiable: the custom VJP
    slices each branch's cotangent (and its ReLU mask) out of the joint
    buffer and emits ONE combined backward launch (masked dx + dw/db,
    ``grouped_matmul_bwd``).  ``m_valid`` makes the launch ragged-M
    (inference-only direct kernel call — see ``grouped_matmul``)."""
    interpret = default_interpret() if interpret is None else interpret
    if m_valid is not None:
        return _gmm.grouped_matmul_concat(
            list(xs), list(ws), None if bs is None else list(bs),
            offsets=tuple(int(o) for o in offsets), total=int(total),
            relu=relu, compact=compact, m_valid=m_valid,
            interpret=interpret)
    return _concat_vjp(tuple(xs), tuple(ws),
                       None if bs is None else tuple(bs),
                       tuple(int(o) for o in offsets), int(total), relu,
                       compact, interpret)


def grouped_matmul_bwd(xs, ws, dys, ys=None, *,
                       interpret: bool | None = None):
    """(dxs, dws, dbs) of a grouped branch GEMM in ONE combined launch
    (masked dx + dw/db over a concatenated two-phase offset table; dy is
    masked by y_g > 0 when ``ys`` is given) — see
    ``kernels/grouped_matmul.py``."""
    interpret = default_interpret() if interpret is None else interpret
    return _gmm.grouped_matmul_bwd(xs, ws, dys, ys, interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _concat_vjp(xs, ws, bs, offsets, total, relu, compact, interpret):
    return _gmm.grouped_matmul_concat(xs, ws, bs, offsets=offsets,
                                      total=total, relu=relu,
                                      compact=compact, interpret=interpret)


def _concat_fwd(xs, ws, bs, offsets, total, relu, compact, interpret):
    y = _concat_vjp(xs, ws, bs, offsets, total, relu, compact, interpret)
    return y, (xs, ws, bs, y if relu else None)


def _concat_offsets(xs, ws, offsets, compact):
    """Branch column offsets in the buffer the forward returned: the true
    join offsets when compact, the cumulative padded bases otherwise."""
    if compact:
        return offsets
    blocks = _gmm.grouped_block_shape(
        xs[0].shape[0], [(w.shape[0], w.shape[1]) for w in ws],
        xs[0].dtype)
    offs, base = [], 0
    for w in ws:
        offs.append(base)
        base += _round_up(w.shape[1], blocks.bn)
    return offs


def _concat_bwd(offsets, total, relu, compact, interpret, res, g):
    xs, ws, bs, y = res
    offs = _concat_offsets(xs, ws, offsets, compact)
    dys = [g[:, off:off + w.shape[1]].astype(x.dtype)
           for off, w, x in zip(offs, ws, xs)]
    mask = [y[:, off:off + w.shape[1]]
            for off, w in zip(offs, ws)] if relu else None
    dxs, dws, dbs = _gmm.grouped_matmul_bwd(xs, ws, dys, mask,
                                            interpret=interpret)
    dws = tuple(dw.astype(w.dtype) for dw, w in zip(dws, ws))
    dbs = None if bs is None else tuple(
        db.astype(b.dtype) for db, b in zip(dbs, bs))
    return tuple(dxs), dws, dbs


_concat_vjp.defvjp(_concat_fwd, _concat_bwd)


# ---------------------------------------------------------------------------
# pooled grouped launch (in-kernel maxpool pre-GEMM stage)
# ---------------------------------------------------------------------------

def grouped_matmul_pooled(xs, ws, bs=None, *, relu: bool = False,
                          m_valid=None, interpret: bool | None = None):
    """Grouped ragged branch GEMMs with each pooled branch's maxpool
    computed IN-KERNEL as a pre-GEMM stage (``xs[g]`` a sequence of
    ``pool_tap_views`` tap arrays) — ONE launch covers pooling, GEMMs and
    the bias+ReLU epilogue; no standalone pooling kernel remains.

    Differentiable: the custom VJP emits exactly ONE combined backward
    launch (``grouped_matmul_bwd`` — masked dx + dw/db), with the pooled
    branches' lhs folded at pack time and the pooling cotangent scattered
    back through the first-argmax window mask in the unpacking pass
    (elementwise, like the ReLU cotangent mask folded into the packing —
    gradients match the XLA ``reduce_window`` oracle bit-for-bit,
    tie-breaking included).  ``m_valid`` makes the launch ragged-M
    (inference-only direct kernel call — see ``grouped_matmul``)."""
    interpret = default_interpret() if interpret is None else interpret
    if m_valid is not None:
        return list(_gmm.grouped_matmul_pooled(
            list(xs), list(ws), None if bs is None else list(bs),
            relu=relu, m_valid=m_valid, interpret=interpret))
    xs_t = tuple(tuple(x) if isinstance(x, (list, tuple)) else x
                 for x in xs)
    return _pooled_vjp(xs_t, tuple(ws),
                       None if bs is None else tuple(bs), relu, interpret)


def grouped_matmul_pooled_concat(xs, ws, bs=None, *, offsets, total: int,
                                 relu: bool = False, compact: bool = True,
                                 m_valid=None, interpret: bool | None = None):
    """The fused epilogue-concat grouped GEMM with the in-kernel pool
    stage: pooling + GEMMs + bias/ReLU + the join assembly in ONE launch
    (``kernels/grouped_matmul.py::grouped_matmul_pooled_concat``).  Same
    ``offsets``/``total``/``compact`` semantics as
    ``grouped_matmul_concat``; the custom VJP slices the joint cotangent
    and emits ONE combined backward launch, scattering pooled branches'
    cotangents through their argmax masks in its unpacking.  ``m_valid``
    makes the launch ragged-M (inference-only direct kernel call — see
    ``grouped_matmul``)."""
    interpret = default_interpret() if interpret is None else interpret
    if m_valid is not None:
        return _gmm.grouped_matmul_pooled_concat(
            list(xs), list(ws), None if bs is None else list(bs),
            offsets=tuple(int(o) for o in offsets), total=int(total),
            relu=relu, compact=compact, m_valid=m_valid,
            interpret=interpret)
    xs_t = tuple(tuple(x) if isinstance(x, (list, tuple)) else x
                 for x in xs)
    return _pooled_concat_vjp(xs_t, tuple(ws),
                              None if bs is None else tuple(bs),
                              tuple(int(o) for o in offsets), int(total),
                              relu, compact, interpret)


def _pooled_flatten(xs):
    """(plain lhs per branch, {branch: folded pooled lhs}) — the pack-time
    fold the forward kernel performs in its pool stage."""
    flat, pooled = [], {}
    for i, x in enumerate(xs):
        if isinstance(x, tuple):
            pooled[i] = _gmm.pool_from_taps(list(x))
            flat.append(pooled[i])
        else:
            flat.append(x)
    return flat, pooled


def _pooled_scatter(xs, pooled, dxs):
    """Route each pooled branch's lhs cotangent back onto its taps."""
    outs = []
    for i, x in enumerate(xs):
        if isinstance(x, tuple):
            outs.append(tuple(_gmm.pool_cotangent_taps(
                list(x), pooled[i], dxs[i])))
        else:
            outs.append(dxs[i])
    return tuple(outs)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _pooled_vjp(xs, ws, bs, relu, interpret):
    return tuple(_gmm.grouped_matmul_pooled(list(xs), ws, bs, relu=relu,
                                            interpret=interpret))


def _pooled_fwd(xs, ws, bs, relu, interpret):
    ys = _pooled_vjp(xs, ws, bs, relu, interpret)
    return ys, (xs, ws, bs, ys if relu else None)


def _pooled_bwd(relu, interpret, res, gs):
    xs, ws, bs, ys = res
    flat, pooled = _pooled_flatten(xs)
    dys = [g.astype(f.dtype) for g, f in zip(gs, flat)]
    mask = list(ys) if relu else None
    dxs, dws, dbs = _gmm.grouped_matmul_bwd(flat, ws, dys, mask,
                                            interpret=interpret)
    dws = tuple(dw.astype(w.dtype) for dw, w in zip(dws, ws))
    dbs = None if bs is None else tuple(
        db.astype(b.dtype) for db, b in zip(dbs, bs))
    return _pooled_scatter(xs, pooled, dxs), dws, dbs


_pooled_vjp.defvjp(_pooled_fwd, _pooled_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _pooled_concat_vjp(xs, ws, bs, offsets, total, relu, compact,
                       interpret):
    return _gmm.grouped_matmul_pooled_concat(
        list(xs), ws, bs, offsets=offsets, total=total, relu=relu,
        compact=compact, interpret=interpret)


def _pooled_concat_fwd(xs, ws, bs, offsets, total, relu, compact,
                       interpret):
    y = _pooled_concat_vjp(xs, ws, bs, offsets, total, relu, compact,
                           interpret)
    return y, (xs, ws, bs, y if relu else None)


def _pooled_concat_bwd(offsets, total, relu, compact, interpret, res, g):
    xs, ws, bs, y = res
    flat, pooled = _pooled_flatten(xs)
    offs = _concat_offsets(flat, ws, offsets, compact)
    dys = [g[:, off:off + w.shape[1]].astype(f.dtype)
           for off, w, f in zip(offs, ws, flat)]
    mask = [y[:, off:off + w.shape[1]]
            for off, w in zip(offs, ws)] if relu else None
    dxs, dws, dbs = _gmm.grouped_matmul_bwd(flat, ws, dys, mask,
                                            interpret=interpret)
    dws = tuple(dw.astype(w.dtype) for dw, w in zip(dws, ws))
    dbs = None if bs is None else tuple(
        db.astype(b.dtype) for db, b in zip(dbs, bs))
    return _pooled_scatter(xs, pooled, dxs), dws, dbs


_pooled_concat_vjp.defvjp(_pooled_concat_fwd, _pooled_concat_bwd)

pool_tap_views = _gmm.pool_tap_views
pool_from_taps = _gmm.pool_from_taps
grouped_matmul_pooled_ref = _gmm.grouped_matmul_pooled_ref
grouped_matmul_pooled_concat_ref = _gmm.grouped_matmul_pooled_concat_ref

grouped_matmul_ref = _gmm.grouped_matmul_ref
grouped_matmul_dw_ref = _gmm.grouped_matmul_dw_ref
grouped_matmul_bwd_ref = _gmm.grouped_matmul_bwd_ref
grouped_matmul_concat_ref = _gmm.grouped_matmul_concat_ref
grouped_matmul_flops = _gmm.grouped_matmul_flops
grouped_block_shape = _gmm.grouped_block_shape
grouped_debug = _gmm.grouped_debug
KERNEL_LAUNCHES = _gmm.KERNEL_LAUNCHES
reset_launch_counts = _gmm.reset_launch_counts


# ---------------------------------------------------------------------------
# fused complementary pair (GEMM + streamed reduction)
# ---------------------------------------------------------------------------

def fused_gemm_reduce(x, y, z, *, bm: int = 128, bn: int = 128,
                      bk: int = 128, interpret: bool | None = None):
    """(M, K) @ (K, N) co-executed with silu(z).sum(0) in one grid.

    Pads x/y to the kernel's block shapes and slices the GEMM result back
    (z row-padding is handled inside the kernel wrapper).  Differentiable:
    like ``_conv_alg`` and ``branch_matmul``, the co-execution knob
    concerns the forward kernel only — the custom VJP computes the GEMM
    cotangents as plain GEMMs and pulls the reduction back through XLA's
    silu, so plans with fused groups stay trainable."""
    interpret = default_interpret() if interpret is None else interpret
    return _fused_vjp(x, y, z, bm, bn, bk, interpret)


def _fused_padded(x, y, z, bm, bn, bk, interpret):
    m, k = x.shape
    _, n = y.shape
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    c, r = _fused.fused_gemm_reduce(xp, yp, z, bm=bm, bn=bn, bk=bk,
                                    interpret=interpret)
    return c[:m, :n], r


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _fused_vjp(x, y, z, bm, bn, bk, interpret):
    return _fused_padded(x, y, z, bm, bn, bk, interpret)


def _fused_fwd(x, y, z, bm, bn, bk, interpret):
    return _fused_padded(x, y, z, bm, bn, bk, interpret), (x, y, z)


def _fused_bwd(bm, bn, bk, interpret, res, g):
    x, y, z = res
    dc, dr = g
    dc = dc.astype(x.dtype)
    _, red_vjp = jax.vjp(
        lambda zz: jax.nn.silu(zz.astype(jnp.float32)).sum(0).astype(
            zz.dtype), z)
    return dc @ y.T, x.T @ dc, red_vjp(dr.astype(z.dtype))[0]


_fused_vjp.defvjp(_fused_fwd, _fused_bwd)


# ---------------------------------------------------------------------------
# chained multi-phase launch (cross-module streaming)
# ---------------------------------------------------------------------------

def grouped_matmul_chained(phases, *, m: int, h: int, w: int, panels=(),
                           block: int = 128, m_valid=None,
                           interpret: bool | None = None):
    """A CHAIN of grouped branch phases in ONE kernel — join-chaining
    (panel-source lhs descriptors), in-launch KxK ring convs and the
    fused bias+ReLU epilogue; see
    ``kernels/grouped_matmul.py::grouped_matmul_chained``.

    Differentiable: the custom VJP mirrors the chain in reverse phase
    order with ONE combined ``grouped_matmul_bwd`` launch per phase.  The
    joint cotangent arrives per-phase on the padded panels; ring
    consumers' lhs is rebuilt as the differentiable tap-shift of the
    producer's residual panel (``jax.vjp`` routes their lhs cotangent
    back onto the producer's slab before its own phase runs), and
    panel-source branches' lhs cotangents accumulate onto the previous
    launch's panel arguments — so gradients flow across the whole chain
    exactly as through the unchained plan.

    ``m_valid`` (python int or traced i32 scalar, image-aligned) makes
    the launch ragged-M and bypasses the VJP entirely — the serving
    path's masked chained launch, where dead M-blocks are skipped as
    no-op waves and live tail blocks store exact zeros.  Inference-only,
    like every other ragged grouped-family wrapper."""
    interpret = default_interpret() if interpret is None else interpret
    if m_valid is not None:
        return list(_gmm.grouped_matmul_chained(
            phases, m=m, h=h, w=w, panels=list(panels), block=block,
            m_valid=m_valid, interpret=interpret))
    spec, xs_flat, ws, bss = [], [], [], []
    for phase in phases:
        ps = []
        for br in phase:
            tag = br["src"][0]
            if tag == "x":
                arrs = list(br["src"][1])
                meta = len(arrs)
                xs_flat.extend(arrs)
            elif tag == "panel":
                meta = tuple((int(p), int(c)) for p, c in br["src"][1])
            else:
                meta = (int(br["src"][1]), int(br["src"][2]),
                        tuple(int(c) for c in br["src"][3]))
            ws.append(br["w"])
            bss.append(br.get("b"))
            ps.append((tag, meta, int(br["n"]),
                       tuple(br.get("ring_write") or ())))
        spec.append(tuple(ps))
    return list(_chained_vjp(tuple(xs_flat), tuple(ws), tuple(bss),
                             tuple(panels), tuple(spec), int(m), int(h),
                             int(w), int(block), interpret))


def _chained_rebuild(xs_flat, ws, bss, spec):
    phases, cur, bi = [], 0, 0
    for pspec in spec:
        phase = []
        for (tag, meta, n, rw) in pspec:
            if tag == "x":
                src = ("x", list(xs_flat[cur:cur + meta]))
                cur += meta
            elif tag == "panel":
                src = ("panel", list(meta))
            else:
                src = ("ring", meta[0], meta[1], meta[2])
            phase.append({"n": n, "w": ws[bi], "b": bss[bi], "src": src,
                          "ring_write": rw or None})
            bi += 1
        phases.append(phase)
    return phases


def _pack_cols(arrs, widths, blk, dtype):
    """dus-pack 2D arrays into an (M, sum ceil(w/blk)*blk) buffer, each at
    its own block-aligned column base — the branch lhs layout the chained
    forward GEMM consumed (padding columns zero)."""
    total = sum(-(-wd // blk) for wd in widths) * blk
    buf = jnp.zeros((arrs[0].shape[0], total), dtype)
    off = 0
    for a, wd in zip(arrs, widths):
        buf = jax.lax.dynamic_update_slice(buf, a.astype(dtype), (0, off))
        off += -(-wd // blk) * blk
    return buf


def _add_block(buf, upd, r0: int, c0: int):
    """buf[r0:r0+R, c0:c0+C] += upd via slice + dynamic_update_slice — a
    scatter-add here would build its index vector with concatenates the
    launch counter counts."""
    cur = jax.lax.slice(buf, (r0, c0),
                        (r0 + upd.shape[0], c0 + upd.shape[1]))
    return jax.lax.dynamic_update_slice(
        buf, cur + upd.astype(buf.dtype), (r0, c0))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _chained_vjp(xs_flat, ws, bss, panels, spec, m, h, w, block, interpret):
    phases = _chained_rebuild(xs_flat, ws, bss, spec)
    return tuple(_gmm.grouped_matmul_chained(
        phases, m=m, h=h, w=w, panels=list(panels), block=block,
        interpret=interpret))


def _chained_fwd(xs_flat, ws, bss, panels, spec, m, h, w, block, interpret):
    outs = _chained_vjp(xs_flat, ws, bss, panels, spec, m, h, w, block,
                        interpret)
    return outs, (xs_flat, ws, bss, panels, outs)


def _chained_bwd(spec, m, h, w, block, interpret, res, gs):
    xs_flat, ws, bss, panels, outs = res
    blk = block
    # branch layout + ring col -> (producer phase, producer panel col block)
    flat, ringmap, xoffs = [], {}, []
    cur = 0
    for p, pspec in enumerate(spec):
        cb = 0
        for (tag, meta, n, rw) in pspec:
            nbb = -(-n // blk)
            flat.append((p, cb, nbb, tag, meta, n, rw))
            for j, rc in enumerate(rw):
                ringmap[rc] = (p, cb + j)
            xoffs.append(cur)
            if tag == "x":
                cur += meta
            cb += nbb
    gpanels = [jnp.asarray(g) for g in gs]
    dxs_flat = [None] * len(xs_flat)
    dws = [None] * len(ws)
    dbs = [None] * len(bss)
    dpanels = [jnp.zeros_like(pa) for pa in panels]
    dtype = outs[0].dtype
    for p in reversed(range(len(spec))):
        idxs = [bi for bi, br in enumerate(flat) if br[0] == p]
        lhss, dys, masks, wsl, vjps = [], [], [], [], []
        for bi in idxs:
            _, cb, nbb, tag, meta, n, rw = flat[bi]
            dy = gpanels[p][:m, cb * blk:cb * blk + n].astype(dtype)
            y = outs[p][:m, cb * blk:cb * blk + n]
            if tag == "x":
                arrs = xs_flat[xoffs[bi]:xoffs[bi] + meta]
                lhs = _pack_cols(arrs, [a.shape[1] for a in arrs], blk,
                                 dtype)
                vjps.append(None)
            elif tag == "panel":
                lhs = _pack_cols(
                    [panels[pi][:m, c * blk:(c + 1) * blk]
                     for pi, c in meta],
                    [blk] * len(meta), blk, dtype)
                vjps.append(None)
            else:
                kh, kw, rcs = meta
                blocks = tuple(
                    outs[ringmap[rc][0]][:m, ringmap[rc][1] * blk:
                                         (ringmap[rc][1] + 1) * blk]
                    for rc in rcs)

                def _taps(bl, kh=kh, kw=kw):
                    parts = [_gmm._shift_spatial(seg, m, h, w,
                                                 dh - kh // 2,
                                                 dw_ - kw // 2)
                             for dh in range(kh) for dw_ in range(kw)
                             for seg in bl]
                    return _pack_cols(parts, [blk] * len(parts), blk,
                                      dtype)

                lhs, tapvjp = jax.vjp(_taps, blocks)
                vjps.append((tapvjp, rcs))
            lhss.append(lhs)
            dys.append(dy)
            masks.append(y)
            wsl.append(ws[bi])
        # ONE combined launch for this phase's dx + dw + db
        dxs, dws_p, dbs_p = _gmm.grouped_matmul_bwd(
            lhss, wsl, dys, masks, interpret=interpret)
        for k, bi in enumerate(idxs):
            _, cb, nbb, tag, meta, n, rw = flat[bi]
            dws[bi] = dws_p[k].astype(ws[bi].dtype)
            dbs[bi] = None if bss[bi] is None else \
                dbs_p[k].astype(bss[bi].dtype)
            dx = dxs[k]
            if tag == "x":
                off = 0
                for a_i, a in enumerate(
                        xs_flat[xoffs[bi]:xoffs[bi] + meta]):
                    da = dx[:, off:off + a.shape[1]].astype(a.dtype)
                    j = xoffs[bi] + a_i
                    dxs_flat[j] = da if dxs_flat[j] is None \
                        else dxs_flat[j] + da
                    off += -(-a.shape[1] // blk) * blk
            elif tag == "panel":
                for s, (pi, c) in enumerate(meta):
                    dpanels[pi] = _add_block(
                        dpanels[pi],
                        dx[:m, s * blk:(s + 1) * blk], 0, c * blk)
            else:
                tapvjp, rcs = vjps[k]
                gblocks = tapvjp(dx)[0]
                for rc, gb in zip(rcs, gblocks):
                    pp, pcb = ringmap[rc]
                    gpanels[pp] = _add_block(
                        gpanels[pp], gb[:m], 0, pcb * blk)
    dxs_flat = tuple(jnp.zeros_like(a) if d is None else d
                     for a, d in zip(xs_flat, dxs_flat))
    return dxs_flat, tuple(dws), tuple(dbs), tuple(dpanels)


_chained_vjp.defvjp(_chained_fwd, _chained_bwd)

grouped_matmul_chained_ref = _gmm.grouped_matmul_chained_ref
chained_layout = _gmm.chained_layout

# ---------------------------------------------------------------------------
# per-expert ragged grouped GEMM: the MoE expert engine
# ---------------------------------------------------------------------------

def grouped_matmul_experts(xp, swp, w_in, w_out, w_gate, counts, *,
                           activation: str = "silu",
                           interpret: bool | None = None, bm: int):
    """Differentiable per-expert ragged expert stack in ONE launch per
    direction: forward fuses in/gate GEMMs, the activation, the out GEMM
    and the router combine-weight row scale; backward is ONE combined
    ``grouped_matmul_experts_bwd`` launch (dx + every dW) plus the dsw
    row reduction computed outside the kernel from the saved output.

    ``counts`` is a TRACED (E,) int32 of routed tokens per expert — it is
    a real custom_vjp operand (cotangent ``float0``) rather than a
    closure capture, so the vjp stays leak-free under ``jax.checkpoint``
    and ``scan``; ``w_gate=None`` flows through the pytree and comes back
    as a ``None`` cotangent, mirroring ``_grouped_bwd``'s optional-bias
    handling."""
    interpret = default_interpret() if interpret is None else interpret

    @jax.custom_vjp
    def run(xp, swp, w_in, w_out, w_gate, counts):
        return _gmm.grouped_matmul_experts(
            xp, swp, w_in, w_out, w_gate, counts,
            activation=activation, bm=bm, interpret=interpret)

    def run_fwd(xp, swp, w_in, w_out, w_gate, counts):
        y, hinp, gatep = _gmm.grouped_matmul_experts(
            xp, swp, w_in, w_out, w_gate, counts, activation=activation,
            train=True, bm=bm, interpret=interpret)
        return y, (xp, swp, w_in, w_out, w_gate, counts, y, hinp, gatep)

    def run_bwd(res, dy):
        xp, swp, w_in, w_out, w_gate, counts, y, hinp, gatep = res
        dy = dy.astype(xp.dtype)
        dyp = dy * swp[:, None].astype(dy.dtype)
        dx, dwin, dwgate, dwout = _gmm.grouped_matmul_experts_bwd(
            xp, dyp, w_in, w_out, w_gate, hinp, gatep, counts,
            activation=activation, bm=bm, interpret=interpret)
        # dsw_r = <dy_r, y_r/sw_r>: recover the unscaled row from the
        # saved output instead of a third kernel pass
        num = jnp.sum(dy.astype(jnp.float32) * y.astype(jnp.float32),
                      axis=-1)
        dsw = jnp.where(swp != 0, num / jnp.where(swp != 0, swp, 1.0),
                        0.0).astype(swp.dtype)
        dwin = dwin.astype(w_in.dtype)
        dwout = dwout.astype(w_out.dtype)
        if w_gate is not None:
            dwgate = dwgate.astype(w_gate.dtype)
        dcounts = np.zeros(counts.shape, jax.dtypes.float0)
        return dx, dsw, dwin, dwout, dwgate, dcounts

    run.defvjp(run_fwd, run_bwd)
    return run(xp, swp, w_in, w_out, w_gate, counts)


grouped_matmul_experts_ref = _gmm.grouped_matmul_experts_ref
moe_block_m = _gmm.moe_block_m
moe_static_blocks = _gmm.moe_static_blocks
expert_row_offsets = _gmm.expert_row_offsets
