"""Fused complementary-branch kernel — intra-SM co-execution, literally.

The paper's intra-SM partitioning argument (Table 1): co-locate a
compute-bound kernel with a memory-bound kernel so the latter's memory
stalls hide under the former's ALU work.  A TPU core cannot time-share two
``pallas_call``s, so this kernel makes the co-location explicit: ONE grid
executes

  branch A (MXU-bound):  c = x @ y            (tiled GEMM)
  branch B (HBM-bound):  r = sum_rows(silu(z))  (streamed reduction)

Each grid step issues the MXU matmul for A's tile while the DMA engine
streams the next slice of B from HBM — B's bytes ride entirely under A's
FLOPs (the Pallas pipeline double-buffers every input).  This is the
``co_execution_time = max(sum_compute, sum_memory)`` model of
``core/cost_model.py`` made concrete, and the strongest TPU analogue of the
paper's PRECOMP_GEMM + FFT_TILING pairing.

The B tensor is partitioned across A's whole grid: slice index = the
linearized (i, j, k) grid position, so B's streaming is spread evenly over
the kernel's lifetime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_kernel(x_ref, y_ref, z_ref, c_ref, r_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    # branch A: accumulate the GEMM tile (MXU)
    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        c_ref[...] = acc_ref[...].astype(c_ref.dtype)

    # branch B: reduce this grid step's slice of z (VPU + HBM stream);
    # partial sums land in r's per-slice row, summed by the wrapper.
    zb = z_ref[...].astype(jnp.float32)
    r_ref[0, :] = jax.nn.silu(zb).sum(axis=0).astype(r_ref.dtype)


def fused_gemm_reduce(x, y, z, *, bm: int = 128, bn: int = 128,
                      bk: int = 128, interpret: bool = False):
    """Returns (x @ y, silu(z).sum(0)).

    x: (M, K), y: (K, N) — padded to block multiples by the caller (ops.py
    pads); z: (R, C) with R divisible by the grid size (wrapper pads).
    """
    m, kdim = x.shape
    _, n = y.shape
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0
    gm, gn, nk = m // bm, n // bn, kdim // bk
    steps = gm * gn * nk
    r_, c_ = z.shape
    rows = -(-r_ // steps)
    zp = jnp.pad(z, ((0, rows * steps - r_), (0, 0)))

    def z_index(i, j, kk):
        return (i * gn * nk + j * nk + kk, 0)

    c, partials = pl.pallas_call(
        functools.partial(_fused_kernel, nk=nk),
        grid=(gm, gn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((rows, zp.shape[1]), z_index),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((1, zp.shape[1]), lambda i, j, kk:
                         (i * gn * nk + j * nk + kk, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((steps, zp.shape[1]), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y, zp)
    return c, partials.sum(axis=0).astype(z.dtype)


def fused_gemm_reduce_ref(x, y, z):
    c = jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)
    r = jax.nn.silu(z.astype(jnp.float32)).sum(0).astype(z.dtype)
    return c, r
