"""Pallas TPU kernels: the per-op algorithm zoo (paper C3/C4) + oracles."""
from repro.kernels.ops import (  # noqa: F401
    attention, branch_matmul, conv2d, conv2d_supported, fused_gemm_reduce,
    grouped_matmul, grouped_matmul_bwd, grouped_matmul_bwd_ref,
    grouped_matmul_concat, grouped_matmul_concat_ref,
    grouped_matmul_dw, grouped_matmul_dw_ref,
    grouped_matmul_pooled, grouped_matmul_pooled_ref,
    grouped_matmul_pooled_concat, grouped_matmul_pooled_concat_ref,
    pool_tap_views, pool_from_taps,
    grouped_matmul_flops, grouped_matmul_ref, grouped_block_shape,
    grouped_matmul_experts, grouped_matmul_experts_ref,
    moe_block_m, moe_static_blocks, expert_row_offsets,
    grouped_debug, matmul, ssd, KERNEL_LAUNCHES, reset_launch_counts,
    ATTENTION_ALGORITHMS, CONV2D_ALGORITHMS, MATMUL_ALGORITHMS, SSD_ALGORITHMS,
    attention_workspace_bytes, conv2d_workspace_bytes, matmul_workspace_bytes,
    matmul_vmem_bytes, ssd_workspace_bytes, default_interpret,
)
from repro.kernels.fused_branches import fused_gemm_reduce_ref  # noqa: F401
