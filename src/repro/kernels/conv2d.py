"""Conv2D algorithm zoo — the paper's core op, adapted to TPU.

Faithful analogue of the cuDNN algorithm table the paper profiles
(Sec. 2 / Tables 1 & 2).  Each algorithm has a distinct (time, HBM workspace,
arithmetic-intensity) profile, which is what the selector reasons about:

  im2col_gemm — materializes the (N*OH*OW, KH*KW*C) patch matrix in HBM
                (workspace = the full im2col buffer), then a single
                MXU-aligned Pallas GEMM.  Compute-bound, big workspace.
                (cuDNN GEMM / PRECOMP_GEMM analogue.)
  direct      — zero-workspace Pallas kernel: the padded input stays in HBM,
                each grid cell loads an input window into VMEM and iterates
                the KH*KW taps with channel-dim GEMMs.  More HBM traffic per
                FLOP -> memory-bound.  (IMPLICIT_GEMM / DIRECT analogue.)
  winograd3x3 — F(2x2, 3x3): 2.25x fewer MXU FLOPs, moderate workspace for
                the 16 transformed-domain GEMMs, which are *independent
                branches* executed with the stacked ``branch_matmul`` kernel.
                Only for 3x3/stride-1.  (WINOGRAD_NONFUSED analogue; its
                16 pointwise GEMMs are themselves an inter-op parallelism
                instance.)

Layouts: x (N, H, W, C), w (KH, KW, C, K), NHWC out.  Channels last keeps the
GEMM contraction on the TPU lane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.matmul import matmul_tiled
from repro.kernels.branch_matmul import branch_matmul


def _out_size(h: int, kh: int, stride: int, padding: str) -> int:
    if padding == "SAME":
        return -(-h // stride)
    return (h - kh) // stride + 1


def _pad_amount(h: int, kh: int, stride: int, padding: str) -> tuple[int, int]:
    if padding == "VALID":
        return (0, 0)
    oh = -(-h // stride)
    total = max((oh - 1) * stride + kh - h, 0)
    return (total // 2, total - total // 2)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# im2col + GEMM
# ---------------------------------------------------------------------------

def conv2d_im2col_gemm(x, w, *, stride: int = 1, padding: str = "SAME",
                       interpret: bool = False):
    n, h, wd, c = x.shape
    kh, kw, c2, k = w.shape
    assert c == c2
    oh = _out_size(h, kh, stride, padding)
    ow = _out_size(wd, kw, stride, padding)
    # HBM workspace: the full patch matrix (the paper's Table-2 quantity).
    patches = jax.lax.conv_general_dilated_patches(
        x, filter_shape=(kh, kw), window_strides=(stride, stride),
        padding=padding, dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (N, OH, OW, C*KH*KW), feature dim ordered (C, KH, KW)
    m = n * oh * ow
    kk = c * kh * kw
    lhs = patches.reshape(m, kk)
    rhs = w.transpose(2, 0, 1, 3).reshape(kk, k)  # (C,KH,KW,K) -> (CKK, K)
    # Pad to MXU-aligned blocks.
    bm, bn, bk = 128, 128, 128
    mp, kp, np_ = _round_up(m, bm), _round_up(kk, bk), _round_up(k, bn)
    lhs = jnp.pad(lhs, ((0, mp - m), (0, kp - kk)))
    rhs = jnp.pad(rhs, ((0, kp - kk), (0, np_ - k)))
    out = matmul_tiled(lhs, rhs, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :k].reshape(n, oh, ow, k)


def conv2d_im2col_workspace_bytes(x_shape, w_shape, stride=1, padding="SAME",
                                  bytes_per_el: int = 2) -> int:
    n, h, wd, c = x_shape
    kh, kw, _, _ = w_shape
    oh = _out_size(h, kh, stride, padding)
    ow = _out_size(wd, kw, stride, padding)
    return n * oh * ow * c * kh * kw * bytes_per_el


# ---------------------------------------------------------------------------
# direct (zero HBM workspace)
# ---------------------------------------------------------------------------

def _direct_kernel(x_ref, w_ref, o_ref, *, kh, kw, stride, oh, ow, bh):
    """One grid cell: one image, ``bh`` output rows, all output channels.

    x_ref: (1, bh*stride + kh - 1, W_pad, C) input window (VMEM)
    w_ref: (KH, KW, C, K)
    o_ref: (1, bh, OW, K)
    """
    x = x_ref[0]
    c = x.shape[-1]
    k = w_ref.shape[-1]
    acc = jnp.zeros((bh * ow, k), jnp.float32)
    for i in range(kh):            # static unroll over filter taps
        for j in range(kw):
            # rows i, i+stride, ...; cols j, j+stride, ...
            window = jax.lax.slice(
                x, (i, j, 0), (i + (bh - 1) * stride + 1,
                               j + (ow - 1) * stride + 1, c),
                (stride, stride, 1))            # (bh, ow, C)
            acc += jnp.dot(window.reshape(bh * ow, c),
                           w_ref[i, j],
                           preferred_element_type=jnp.float32)
    o_ref[0] = acc.reshape(bh, ow, k).astype(o_ref.dtype)


def conv2d_direct(x, w, *, stride: int = 1, padding: str = "SAME",
                  block_rows: int = 8, interpret: bool = False):
    n, h, wd, c = x.shape
    kh, kw, _, k = w.shape
    oh = _out_size(h, kh, stride, padding)
    ow = _out_size(wd, kw, stride, padding)
    ph, pw = _pad_amount(h, kh, stride, padding), _pad_amount(wd, kw, stride, padding)
    xp = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    bh = min(block_rows, oh)
    n_row_blocks = -(-oh // bh)
    # Pad rows so oh divides evenly into blocks of bh.
    oh_pad = n_row_blocks * bh
    extra_in_rows = (oh_pad - 1) * stride + kh - xp.shape[1]
    if extra_in_rows > 0:
        xp = jnp.pad(xp, ((0, 0), (0, extra_in_rows), (0, 0), (0, 0)))
    in_rows_per_block = (bh - 1) * stride + kh
    # Overlapping row blocks -> express via stride-bh index map on a
    # pre-sliced view: materialize overlapping row windows with XLA gather.
    starts = np.arange(n_row_blocks) * bh * stride
    xwin = jnp.stack([
        jax.lax.dynamic_slice_in_dim(xp, int(s), in_rows_per_block, axis=1)
        for s in starts
    ], axis=1)  # (N, n_row_blocks, in_rows_per_block, W_pad, C)

    out = pl.pallas_call(
        functools.partial(_direct_kernel, kh=kh, kw=kw, stride=stride,
                          oh=oh, ow=ow, bh=bh),
        grid=(n, n_row_blocks),
        in_specs=[
            pl.BlockSpec((1, None, in_rows_per_block, xp.shape[2], c),
                         lambda b, r: (b, r, 0, 0, 0)),
            pl.BlockSpec((kh, kw, c, k), lambda b, r: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, None, bh, ow, k), lambda b, r: (b, r, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_row_blocks, bh, ow, k), x.dtype),
        interpret=interpret,
    )(xwin, w)
    return out.reshape(n, oh_pad, ow, k)[:, :oh]


# ---------------------------------------------------------------------------
# Winograd F(2x2, 3x3)
# ---------------------------------------------------------------------------

_BT = np.array([[1, 0, -1, 0],
                [0, 1, 1, 0],
                [0, -1, 1, 0],
                [0, 1, 0, -1]], np.float32)
_G = np.array([[1, 0, 0],
               [0.5, 0.5, 0.5],
               [0.5, -0.5, 0.5],
               [0, 0, 1]], np.float32)
_AT = np.array([[1, 1, 1, 0],
                [0, 1, -1, -1]], np.float32)


def conv2d_winograd3x3(x, w, *, stride: int = 1, padding: str = "SAME",
                       interpret: bool = False):
    """F(2x2,3x3) Winograd; requires kh=kw=3, stride=1."""
    n, h, wd, c = x.shape
    kh, kw, _, k = w.shape
    assert (kh, kw) == (3, 3) and stride == 1, "winograd3x3 needs 3x3/s1"
    oh = _out_size(h, 3, 1, padding)
    ow = _out_size(wd, 3, 1, padding)
    ph, pw = _pad_amount(h, 3, 1, padding), _pad_amount(wd, 3, 1, padding)
    # Tile grid of 4x4 input tiles with stride 2 producing 2x2 outputs.
    th, tw = -(-oh // 2), -(-ow // 2)
    need_h, need_w = 2 * th + 2, 2 * tw + 2
    xp = jnp.pad(x, ((0, 0),
                     (ph[0], max(need_h - h - ph[0], 0)),
                     (pw[0], max(need_w - wd - pw[0], 0)),
                     (0, 0)))
    # Extract 4x4 tiles: (N, th, tw, 4, 4, C)
    idx_h = (np.arange(th) * 2)[:, None] + np.arange(4)[None, :]
    idx_w = (np.arange(tw) * 2)[:, None] + np.arange(4)[None, :]
    tiles = xp[:, idx_h][:, :, :, idx_w]          # (N, th, 4, tw, 4, C)
    tiles = tiles.transpose(0, 1, 3, 2, 4, 5)     # (N, th, tw, 4, 4, C)
    bt = jnp.asarray(_BT, x.dtype)
    g = jnp.asarray(_G, x.dtype)
    at = jnp.asarray(_AT, x.dtype)
    # Input transform: B^T d B  -> (N, th, tw, 4, 4, C)
    v = jnp.einsum("ij,nxyjkc,kl->nxyilc", bt, tiles, bt.T)
    # Filter transform: G g G^T -> (4, 4, C, K)
    u = jnp.einsum("ij,jkco,kl->ilco", g, w.astype(x.dtype), g.T)
    # 16 independent transformed-domain GEMMs -> stacked branch kernel.
    t = n * th * tw
    v16 = v.transpose(3, 4, 0, 1, 2, 5).reshape(16, t, c)
    u16 = u.reshape(16, c, k)
    bm, bn, bk = 128, 128, 128
    tp, cp, kp = _round_up(t, bm), _round_up(c, bk), _round_up(k, bn)
    v16 = jnp.pad(v16, ((0, 0), (0, tp - t), (0, cp - c)))
    u16 = jnp.pad(u16, ((0, 0), (0, cp - c), (0, kp - k)))
    m16 = branch_matmul(v16, u16, interpret=interpret)[:, :t, :k]
    m = m16.reshape(4, 4, n, th, tw, k)
    # Inverse transform: A^T m A -> (N, th, tw, 2, 2, K)
    y = jnp.einsum("ij,jkntwo,kl->ntwilo", at.astype(m.dtype), m, at.T.astype(m.dtype))
    y = y.transpose(0, 1, 3, 2, 4, 5).reshape(n, 2 * th, 2 * tw, k)
    return y[:, :oh, :ow].astype(x.dtype)


def conv2d_winograd_workspace_bytes(x_shape, w_shape, padding="SAME",
                                    bytes_per_el: int = 2) -> int:
    n, h, wd, c = x_shape
    _, _, _, k = w_shape
    oh = _out_size(h, 3, 1, padding)
    ow = _out_size(wd, 3, 1, padding)
    t = n * -(-oh // 2) * -(-ow // 2)
    return 16 * (t * c + c * k + t * k) * bytes_per_el


CONV2D_ALGORITHMS = {
    "im2col_gemm": conv2d_im2col_gemm,
    "direct": conv2d_direct,
    "winograd3x3": conv2d_winograd3x3,
}


def conv2d_workspace_bytes(algorithm: str, x_shape, w_shape, stride=1,
                           padding="SAME", bytes_per_el: int = 2) -> int:
    if algorithm == "im2col_gemm":
        return conv2d_im2col_workspace_bytes(x_shape, w_shape, stride, padding,
                                             bytes_per_el)
    if algorithm == "winograd3x3":
        return conv2d_winograd_workspace_bytes(x_shape, w_shape, padding,
                                               bytes_per_el)
    return 0  # direct
