"""Grouped ragged branch GEMM — co-execution without pad-to-max waste.

``branch_matmul`` (the stacked mode) batches G *same-shape* GEMMs on a
branch grid axis and pads heterogeneous widths to a common (K, N) — on
ragged Inception branches most of those MXU tiles multiply zeros.  This
kernel runs G GEMMs with *per-branch* (K_g, N_g) sharing one M (the
spatial-flattened activation rows every branch of a fork reads):

    y_g = epilogue(x_g @ w_g + b_g)          g = 0..G-1
    x_g: (M, K_g)   w_g: (K_g, N_g)   y_g: (M, N_g)

The grid is the *flattened union of every branch's tile grid* — one step
per (branch, row-block, col-block, k-block) — and a scalar-prefetched
int32 offset table (SMEM) tells each step which slots of the packed
operands it touches:

    row 0  xt     slot index into the packed X tile stack (T_x, bm, bk)
    row 1  wt     slot index into the packed W tile stack (T_w, bk, bn)
    row 2  bj     col-block index into the packed bias (1, sum Np_g)
    row 3  first  1 on a tile's first k-step (zero the accumulator)
    row 4  last   1 on a tile's last k-step (epilogue + store)
    row 5  ot     slot index into the packed output tile stack

k-steps of one output tile are consecutive grid steps, so the fp32
accumulator lives in VMEM scratch across them.  The bias + optional ReLU
epilogue is applied in-kernel at the last k-step — branch outputs leave
the kernel finished, with no post-kernel bias/activation round-trip.
The optional ``mask`` operand (tiled like X) zeroes LHS elements where
mask <= 0 before the dot: the fused-ReLU *cotangent* mask of the
backward pass, applied in-kernel instead of a separate XLA pass.
Per-branch dims pad only to the block alignment, never to the widest
branch: zero pad-to-max-N FLOPs.

``grouped_matmul_concat`` is the fused epilogue-concat variant: the same
kernel, but the scalar-prefetched table lays output slots out as the
fork/join's padded panel layout (m-outermost, per-branch column-block
offsets), so each branch's bias+ReLU epilogue stores its finished tile
directly into the branch's slice of the join buffer.  The per-branch
output buffers, their tile-stack unpacks, and the standalone
``concatenate`` join all disappear — one bulk layout pass plus a single
column gather (identity for bn-aligned widths) yields the true
``[M, sum N_g]`` join.

``grouped_matmul_pooled`` / ``grouped_matmul_pooled_concat`` stream a
branch's maxpool through the SAME launch as an in-kernel pre-GEMM stage:
the offset table gains a per-branch pool descriptor (rows 6-9 — derived
from the branch's (window, stride) chain) and the packed X stack holds,
for pooled branches, the pool-window *tap views* of the RAW input
(``pool_tap_views`` — shifted slices, pure layout like the im2col view,
never a ``reduce_window``).  Pool steps max tap tiles into a VMEM
pooled-lhs scratch; the GEMM steps of that M-block then draw their lhs
from the scratch — the pooled activation never round-trips HBM and the
standalone pooling launch disappears (cuDNN's pooling primitive, and the
last pre-GEMM round-trip of an inception module).

``grouped_matmul_dw`` is the mirrored backward-weight kernel: G
*transposed* GEMMs dw_g = x_g^T @ dy_g with per-branch (K_g, N_g)
outputs sharing the M contraction, db_g = sum_M dy_g reduced in the same
pass (accumulated on the first k-row, where each dy column block is
streamed in anyway, and stored at the last m-step).

``grouped_matmul_bwd`` merges the masked-dx pass and ``grouped_matmul_dw``
into ONE launch over a concatenated two-phase offset table: the dY and
mask tile stacks both phases read are identically tiled (bm, bn) blocks,
so they are packed once and shared — half the packing traffic of the
separate dx + dw launches, and the whole grad CoGroup of a grouped
branch group is a single kernel (the shape ``kernels/ops.py``'s VJPs
emit).

Block sizes default to ``grouped_block_shape`` (ROADMAP "block-size
tuning"): 256-row M-blocks once M > 16384, and 256-wide (bk, bn) weight
tiles for bf16 when every branch is already 256-aligned; the returned
``GroupedBlocks`` repr records the choice (``grouped_debug`` prints the
whole launch).

Every tensor operand is packed as a (T, block, block) tile stack —
branch g's X tiles occupy slots [xbase_g, xbase_g + mb * nkb_g), its
outputs [obase_g, obase_g + mb * npb_g), and so on — so each grid step
addresses *leading-dim* slots: contiguous for the TPU DMA engine and for
the interpret-mode emulation this repo tests under (block reads/writes
against a (M, sum K) matrix are strided in the lane dim and dominate the
emulated wall time).  Tiling X in and the output back out are pure
layout passes (zero FLOPs), fused by XLA around the kernel.

Like the rest of the zoo this runs under ``interpret=True`` on CPU; the
differentiable wrapper (custom VJP) lives in ``kernels/ops.py``.
"""
from __future__ import annotations

import contextlib
import functools
from collections import OrderedDict
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.analysis.tables import (
    GM_XT, GM_WT, GM_BJ, GM_FIRST, GM_LAST, GM_OT, GM_MI,
    GP_XT, GP_WT, GP_FIRST, GP_LAST, GP_OT,
    GP_POOL, GP_PFIRST, GP_PS, GP_UPOOL, GP_MI,
    DW_XT, DW_DYT, DW_FIRST, DW_LAST, DW_OT, DW_BJ, DW_DODB,
    BW_DYT, BW_ABT, BW_FIRST, BW_LAST, BW_OT, BW_DODB, BW_DW, BW_BJ,
    CH_I, CH_XT, CH_WT, CH_BJ, CH_FIRST, CH_LAST, CH_PH, CH_SRC,
    CH_PCA, CH_PCB, CH_RC, CH_DELTA, CH_DH, CH_DW, CH_RWC, CH_ROWS,
    EX_BI, EX_XT, EX_WH, EX_WO, EX_PH, EX_FIRST, EX_LAST,
    EX_HJ, EX_OT, EX_RES,
    EB_BI, EB_DYT, EB_XT, EB_WHT, EB_WOT, EB_RES, EB_PH, EB_FIRST,
    EB_LAST, EB_PJ, EB_DXOT, EB_DWH, EB_DWO,
    ch_out_i_row, ch_out_j_row, ch_mrow_row)


# Eager kernel launches by wrapper name — the benchmark's
# launches-per-grad-CoGroup instrument (under jit the wrapper runs once
# at trace time, so only eager measurement is meaningful).
KERNEL_LAUNCHES: dict[str, int] = {}


def _count_launch(name: str) -> None:
    KERNEL_LAUNCHES[name] = KERNEL_LAUNCHES.get(name, 0) + 1


def reset_launch_counts() -> None:
    KERNEL_LAUNCHES.clear()


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _tile_stack(a2d, b0: int, b1: int):
    """(D0, D1) -> (D0/b0 * D1/b1, b0, b1) leading-dim tile stack,
    row-block major (the slot layout every kernel here addresses)."""
    d0, d1 = a2d.shape
    t = a2d.reshape(d0 // b0, b0, d1 // b1, b1).transpose(0, 2, 1, 3)
    return t.reshape(-1, b0, b1)


# ---------------------------------------------------------------------------
# block-size heuristic (ROADMAP "block-size tuning")
# ---------------------------------------------------------------------------

M_LARGE_ROWS = 16384     # B*OH*OW beyond which 256-row M-blocks pay off


class GroupedBlocks(NamedTuple):
    """Chosen (bm, bn, bk) with the reason — the kernel's debug repr."""
    bm: int
    bn: int
    bk: int
    note: str = "default 128^3"

    def __repr__(self):
        return (f"GroupedBlocks(bm={self.bm}, bn={self.bn}, bk={self.bk}, "
                f"note={self.note!r})")


def grouped_block_shape(m: int, kns, dtype=jnp.float32) -> GroupedBlocks:
    """Pick (bm, bn, bk) for a grouped launch over branch widths ``kns``
    = [(K_g, N_g)] sharing ``m`` rows.

    Large-M groups (M = B*OH*OW > 16384) take 256-row M-blocks — half
    the grid steps, twice the MXU work per DMA.  bf16 operands take
    256-wide (bk, bn) weight tiles whenever EVERY branch's K (resp. N)
    is already a multiple of 256, so the wider alignment adds zero pad
    FLOPs; a (256, 256) bf16 W tile plus the f32 accumulator still sit
    comfortably in VMEM.  f32 keeps 128 lanes (the MXU native tile).
    """
    notes = []
    bm, bn, bk = 128, 128, 128
    if m > M_LARGE_ROWS:
        bm = 256
        notes.append(f"M={m}>16k -> bm=256")
    if jnp.dtype(dtype) == jnp.dtype(jnp.bfloat16):
        if all(n % 256 == 0 for _, n in kns):
            bn = 256
        if all(k % 256 == 0 for k, _ in kns):
            bk = 256
        if bn == 256 or bk == 256:
            notes.append(f"bf16 256-aligned -> (bk,bn)=({bk},{bn})")
    return GroupedBlocks(bm, bn, bk, "; ".join(notes) or "default 128^3")


def grouped_debug(xs, ws, *, bm=None, bn=None, bk=None) -> str:
    """Human-readable description of the launch ``grouped_matmul(xs, ws)``
    would make — branch count, shared M, dtype, chosen blocks (heuristic
    or explicit), and the flattened grid size."""
    m = xs[0].shape[0]
    kns = [(w.shape[0], w.shape[1]) for w in ws]
    blocks = grouped_block_shape(m, kns, xs[0].dtype)
    if not (bm is None and bn is None and bk is None):
        # mirror the kernels: explicit dims override, the rest still come
        # from the heuristic — the repr must report the ACTUAL launch
        blocks = GroupedBlocks(bm or blocks.bm, bn or blocks.bn,
                               bk or blocks.bk,
                               f"explicit over ({blocks.note})")
    mb = _round_up(m, blocks.bm) // blocks.bm
    steps = sum(mb * (_round_up(k, blocks.bk) // blocks.bk)
                * (_round_up(n, blocks.bn) // blocks.bn) for k, n in kns)
    return (f"grouped_matmul[G={len(ws)} M={m} "
            f"{jnp.dtype(xs[0].dtype).name} {blocks!r} grid={steps}]")


# ---------------------------------------------------------------------------
# forward kernel: y_g = epilogue(x_g @ w_g + b_g)
# ---------------------------------------------------------------------------

def _gmm_kernel(tab_ref, *refs, relu: bool, masked: bool,
                ragged: bool = False):
    if ragged:
        mrow_ref, *refs = refs
    if masked:
        x_ref, m_ref, w_ref, b_ref, o_ref, acc_ref = refs
    else:
        x_ref, w_ref, b_ref, o_ref, acc_ref = refs
    t = pl.program_id(0)

    @pl.when(tab_ref[GM_FIRST, t] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    if masked:
        x = jnp.where(m_ref[...] > 0, x, jnp.zeros_like(x))
    acc_ref[...] += jnp.dot(x, w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(tab_ref[GM_LAST, t] == 1)
    def _store():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if relu:
            y = jnp.maximum(y, 0.0)
        if ragged:
            # ragged-M epilogue mask: the table's M-block-index row picks
            # this tile's per-block valid-row count out of the second
            # prefetched scalar vector; rows at/past it store zeros (the
            # deterministic padded-M tail — same first-class in-kernel
            # masking as the ReLU cotangent's dY fold)
            valid = mrow_ref[tab_ref[GM_MI, t]]
            ri = jax.lax.broadcasted_iota(jnp.int32, y.shape, 0)
            y = jnp.where(ri < valid, y, 0.0)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.lru_cache(maxsize=512)
def _plan_tiles(m_blocks: int, kbs: tuple[int, ...], nbs: tuple[int, ...]):
    """Offset table for the flattened grid (hashable block counts in,
    (7, T) int32 out) — pure shape bookkeeping, cached across traces.
    Row 6 is the step's M-block index — consumed only by ragged-M
    launches (the epilogue mask's index into the per-M-block valid-row
    vector); appended so rows 0-5 keep their positions for every
    existing consumer."""
    rows: list[list[int]] = [[], [], [], [], [], [], []]
    noff = xbase = wbase = obase = 0
    for nkb, npb in zip(kbs, nbs):
        for i in range(m_blocks):
            for j in range(npb):
                for kk in range(nkb):
                    rows[0].append(xbase + i * nkb + kk)
                    rows[1].append(wbase + kk * npb + j)
                    rows[2].append(noff + j)
                    rows[3].append(1 if kk == 0 else 0)
                    rows[4].append(1 if kk == nkb - 1 else 0)
                    rows[5].append(obase + i * npb + j)
                    rows[6].append(i)
        noff += npb
        xbase += m_blocks * nkb
        wbase += nkb * npb
        obase += m_blocks * npb
    return np.array(rows, np.int32)


class _DeviceTableCache:
    """Device-resident offset tables — hoisted: built and uploaded ONCE per
    tile-grid shape and reused across launches.  Re-uploading the table
    every call is what put the grouped backward behind stacked on host
    wall under the interpret emulation (BENCH ``bwd_wall_ordering_ok``
    regression).  ensure_compile_time_eval: a first call from inside a
    jit trace must still cache a CONCRETE device array, not a traced
    constant that would leak into later eager calls.

    Was a plain ``functools.lru_cache``; now a registry with PIN COUNTS so
    ``core.plan_cache`` eviction can release exactly the tables no live
    cache entry needs: a pinned key survives any recency pressure, an
    unpinned key falls off the LRU tail once ``maxsize`` unpinned entries
    accumulate, and ``unpin`` drops keys whose pin count hits zero.  The
    ``cache_info``/``cache_clear`` surface of the old lru_cache is kept —
    the identity regression tests probe it."""

    def __init__(self, maxsize: int = 512):
        self.maxsize = maxsize
        self._data: "OrderedDict[tuple, object]" = OrderedDict()
        self._pins: dict[tuple, int] = {}
        self._hits = self._misses = 0
        self._recorders: list[set] = []

    def __call__(self, builder, *args):
        key = (builder,) + tuple(args)
        for rec in self._recorders:
            rec.add(key)
        t = self._data.get(key)
        if t is not None:
            self._hits += 1
            self._data.move_to_end(key)
            return t
        self._misses += 1
        with jax.ensure_compile_time_eval():
            t = jnp.asarray(builder(*args))
        self._data[key] = t
        if len(self._data) > self.maxsize:
            for k in list(self._data):
                if len(self._data) <= self.maxsize:
                    break
                if self._pins.get(k, 0) == 0:
                    del self._data[k]
        return t

    @contextlib.contextmanager
    def recording(self):
        """Collect the table keys touched inside the block (the set a
        plan-cache entry pins as its live working set)."""
        rec: set = set()
        self._recorders.append(rec)
        try:
            yield rec
        finally:
            self._recorders.remove(rec)

    def pin(self, keys) -> None:
        for k in keys:
            self._pins[k] = self._pins.get(k, 0) + 1

    def unpin(self, keys) -> None:
        """Drop a pin per key; a key left with zero pins is released from
        the registry (plan-cache eviction -> its tables go too, unless a
        surviving entry still pins them)."""
        for k in keys:
            n = self._pins.get(k, 0) - 1
            if n > 0:
                self._pins[k] = n
            else:
                self._pins.pop(k, None)
                self._data.pop(k, None)

    def cache_info(self):
        return functools._CacheInfo(self._hits, self._misses, self.maxsize,
                                    len(self._data))

    def cache_clear(self):
        self._data.clear()
        self._pins.clear()
        self._hits = self._misses = 0


_device_table = _DeviceTableCache()


def _ragged_mrows(m_valid, mb: int, bm: int):
    """Per-M-block valid-row counts for a ragged-M launch: block i holds
    ``clip(m_valid - i*bm, 0, bm)`` true rows.  ``m_valid`` is the TOTAL
    true row count (requests pack contiguously along M, so raggedness is
    tail-only) — a python int or a traced i32 scalar: every request mix
    inside one padded-M bucket shares the same offset table and traced
    executable and differs only in this runtime vector, which rides the
    launch as a second scalar-prefetch operand."""
    mv = jnp.asarray(m_valid, jnp.int32)
    return jnp.clip(mv - jnp.arange(mb, dtype=jnp.int32) * bm, 0, bm)


def _ragged_index_maps(ragged: bool):
    """(tile index map builder, bias index map) for a grouped-family
    launch: ragged launches prefetch TWO scalar operands (table + valid
    rows), so every index map gains the trailing ``mrow`` argument."""
    if ragged:
        return (lambda row: (lambda t, tab, mrow, row=row:
                             (tab[row, t], 0, 0)),
                lambda t, tab, mrow: (0, tab[GM_BJ, t]))
    return (lambda row: (lambda t, tab, row=row: (tab[row, t], 0, 0)),
            lambda t, tab: (0, tab[GM_BJ, t]))


def grouped_matmul(xs, ws, bs=None, *, relu: bool = False, mask=None,
                   m_valid=None, bm: int | None = None, bn: int | None = None,
                   bk: int | None = None, interpret: bool = False):
    """[x_g @ w_g (+ b_g) (+ ReLU)] for ragged (K_g, N_g), one kernel.

    xs: G arrays (M, K_g) — shared M; ws: G arrays (K_g, N_g);
    bs: G arrays (N_g,) or None; mask: G arrays (M, K_g) or None —
    x_g is zeroed where mask_g <= 0 in-kernel (the ReLU cotangent mask
    of the backward dx GEMMs).  ``m_valid`` (python int or traced i32
    scalar) makes the launch ragged-M: rows at/past it are padding and
    the epilogue stores zeros there (``_ragged_mrows``) — the serving
    path's bucketed multi-request batches.  Block sizes default to
    ``grouped_block_shape``.  Returns G arrays (M, N_g).
    """
    g = len(xs)
    assert g == len(ws) and g >= 1, (len(xs), len(ws))
    assert bs is None or len(bs) == g
    assert mask is None or len(mask) == g
    m = xs[0].shape[0]
    assert all(x.shape[0] == m for x in xs), [x.shape for x in xs]
    assert all(x.shape[1] == w.shape[0] for x, w in zip(xs, ws)), \
        [(x.shape, w.shape) for x, w in zip(xs, ws)]
    if bm is None or bn is None or bk is None:
        blocks = grouped_block_shape(
            m, [(w.shape[0], w.shape[1]) for w in ws], xs[0].dtype)
        bm, bn, bk = bm or blocks.bm, bn or blocks.bn, bk or blocks.bk
    mp = _round_up(m, bm)
    mb = mp // bm
    kps = [_round_up(x.shape[1], bk) for x in xs]
    nps = [_round_up(w.shape[1], bn) for w in ws]
    nsum = sum(nps)

    def pack_x(arrs):
        return jnp.concatenate(
            [_tile_stack(jnp.pad(a, ((0, mp - m), (0, kp - a.shape[1]))),
                         bm, bk)
             for a, kp in zip(arrs, kps)], axis=0)

    xpk = pack_x(xs)
    wpk = jnp.concatenate(
        [_tile_stack(jnp.pad(w, ((0, kp - w.shape[0]),
                                 (0, np_ - w.shape[1]))), bk, bn)
         for w, kp, np_ in zip(ws, kps, nps)], axis=0).astype(xpk.dtype)
    if bs is None:
        bpk = jnp.zeros((1, nsum), xpk.dtype)
    else:
        bpk = jnp.concatenate(
            [jnp.pad(b, (0, np_ - b.shape[0]))
             for b, np_ in zip(bs, nps)]).reshape(1, nsum).astype(xpk.dtype)

    _count_launch("grouped_matmul")
    tab = _device_table(
        _plan_tiles,
        mb, tuple(kp // bk for kp in kps), tuple(np_ // bn for np_ in nps))
    o_tiles = mb * sum(np_ // bn for np_ in nps)

    ragged = m_valid is not None
    ix, ixb = _ragged_index_maps(ragged)
    in_specs = [pl.BlockSpec((None, bm, bk), ix(GM_XT))]
    ins = [xpk]
    if mask is not None:
        assert all(mk.shape == x.shape for mk, x in zip(mask, xs)), \
            [(mk.shape, x.shape) for mk, x in zip(mask, xs)]
        in_specs.append(pl.BlockSpec((None, bm, bk), ix(GM_XT)))
        ins.append(pack_x(mask))
    in_specs += [
        pl.BlockSpec((None, bk, bn), ix(GM_WT)),
        pl.BlockSpec((1, bn), ixb),
    ]
    ins += [wpk, bpk]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2 if ragged else 1,
        grid=(tab.shape[1],),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, bm, bn), ix(GM_OT)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    scalars = (tab, _ragged_mrows(m_valid, mb, bm)) if ragged else (tab,)
    out = pl.pallas_call(
        functools.partial(_gmm_kernel, relu=relu, masked=mask is not None,
                          ragged=ragged),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((o_tiles, bm, bn), xs[0].dtype),
        interpret=interpret,
    )(*scalars, *ins)

    outs, obase = [], 0
    for w, np_ in zip(ws, nps):
        npb = np_ // bn
        tiles = out[obase:obase + mb * npb]
        y = tiles.reshape(mb, npb, bm, bn).transpose(0, 2, 1, 3)
        outs.append(y.reshape(mp, np_)[:m, :w.shape[1]])
        obase += mb * npb
    return outs


def grouped_matmul_ref(xs, ws, bs=None, *, relu: bool = False, mask=None,
                       m_valid=None):
    """Per-branch XLA oracle for tests/benchmarks.  ``m_valid`` mirrors
    the ragged-M launch: rows at/past it are zeroed in the output."""
    outs = []
    for i, (x, w) in enumerate(zip(xs, ws)):
        if mask is not None:
            x = jnp.where(mask[i] > 0, x, jnp.zeros_like(x))
        y = jnp.dot(x, w, preferred_element_type=jnp.float32)
        if bs is not None:
            y = y + bs[i].astype(jnp.float32)
        if relu:
            y = jnp.maximum(y, 0.0)
        if m_valid is not None:
            ri = jnp.arange(y.shape[0], dtype=jnp.int32)[:, None]
            y = jnp.where(ri < jnp.asarray(m_valid, jnp.int32), y, 0.0)
        outs.append(y.astype(x.dtype))
    return outs


# ---------------------------------------------------------------------------
# fused epilogue-concat: y_g tiles land in the join's [M, sum N_g] layout
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=512)
def _plan_tiles_concat(m_blocks: int, kbs: tuple[int, ...],
                       nbs: tuple[int, ...]):
    """Offset table for the fused-concat grid — the SAME six rows as
    ``_plan_tiles`` (the launch runs the unmodified ``_gmm_kernel``, so a
    grid step costs exactly what a plain grouped step costs), but ordered
    m-outermost with output slots laid out as the join's padded panel
    layout: slot = mi * sum(npb_g) + (colblock base of branch g) + j.
    One ``reshape . transpose . reshape`` then yields the whole
    (Mp, sum Np_g) padded join — no per-branch unpack — and a single
    column gather compacts away the per-branch block padding.  Row 6 is
    the appended M-block index (ragged-M epilogue mask; see
    ``_plan_tiles``)."""
    rows: list[list[int]] = [[] for _ in range(7)]
    xbases, wbases, cbases = [], [], []
    xb = wb = cb = 0
    for nkb, npb in zip(kbs, nbs):
        xbases.append(xb)
        wbases.append(wb)
        cbases.append(cb)
        xb += m_blocks * nkb
        wb += nkb * npb
        cb += npb
    ncbt = cb
    for i in range(m_blocks):
        for g, (nkb, npb) in enumerate(zip(kbs, nbs)):
            for j in range(npb):
                for kk in range(nkb):
                    rows[0].append(xbases[g] + i * nkb + kk)
                    rows[1].append(wbases[g] + kk * npb + j)
                    rows[2].append(cbases[g] + j)
                    rows[3].append(1 if kk == 0 else 0)
                    rows[4].append(1 if kk == nkb - 1 else 0)
                    rows[5].append(i * ncbt + cbases[g] + j)
                    rows[6].append(i)
    return np.array(rows, np.int32)


@functools.lru_cache(maxsize=512)
def _concat_gather_index(offsets: tuple[int, ...], ns: tuple[int, ...],
                         nps: tuple[int, ...], total: int):
    """Column map join-buffer -> padded-panel layout: true column
    offsets[g] + c reads padded column base_g + c; passthrough holes
    (columns no branch owns) read column 0 — placeholder values the
    caller's ``dynamic_update_slice`` overwrites."""
    idx = np.zeros(total, np.int32)
    base = 0
    for off, n, np_ in zip(offsets, ns, nps):
        idx[off:off + n] = base + np.arange(n, dtype=np.int32)
        base += np_
    with jax.ensure_compile_time_eval():
        return jnp.asarray(idx)


def grouped_matmul_concat(xs, ws, bs=None, *, offsets, total: int,
                          relu: bool = False, compact: bool = True,
                          m_valid=None, bm: int | None = None,
                          bn: int | None = None, bk: int | None = None,
                          interpret: bool = False):
    """[x_g @ w_g (+ b_g) (+ ReLU)] assembled into the fork/join's concat
    layout — ONE (M, total) output, branch g's columns at ``offsets[g]``.

    The launch IS a grouped launch (the unmodified ``_gmm_kernel`` —
    identical per-step cost), but its output slots are the join's padded
    panel layout, m-outermost: one bulk layout pass yields the whole
    (Mp, sum Np_g) padded join at once — the per-branch output buffers
    and their unpacks disappear — and one column gather compacts the
    per-branch block padding into the true [M, total] layout (for
    bn-aligned branch widths it degenerates to the identity).

    Columns of ``total`` not covered by any branch (passthrough slices of
    branch outputs computed by an EARLIER launch) carry placeholder
    values — the caller overwrites them (``core/plan.py`` uses
    ``lax.dynamic_update_slice``).  Returns the (M, total) join buffer.

    ``compact=False`` skips the gather and returns the PADDED
    (M, sum Np_g) join buffer instead — branch g's true columns at the
    cumulative padded base — for callers that splice the passthrough
    segments and strip the padding in one pass (``core/plan.py``'s
    grouped_concat executor); ``offsets``/``total`` then only fix the
    branch order.  ``m_valid`` as in ``grouped_matmul`` (ragged-M
    epilogue mask: rows at/past it store zeros).
    """
    g = len(xs)
    assert g == len(ws) and g == len(offsets) and g >= 1
    assert bs is None or len(bs) == g
    m = xs[0].shape[0]
    assert all(x.shape[0] == m for x in xs), [x.shape for x in xs]
    assert all(x.shape[1] == w.shape[0] for x, w in zip(xs, ws))
    ns = [w.shape[1] for w in ws]
    segs = sorted(zip(offsets, ns))
    assert all(o1 >= o0 + n0 for (o0, n0), (o1, _) in zip(segs, segs[1:])) \
        and segs[-1][0] + segs[-1][1] <= total, (offsets, ns, total)
    if bm is None or bn is None or bk is None:
        blocks = grouped_block_shape(
            m, [(w.shape[0], w.shape[1]) for w in ws], xs[0].dtype)
        bm, bn, bk = bm or blocks.bm, bn or blocks.bn, bk or blocks.bk
    mp = _round_up(m, bm)
    mb = mp // bm
    kps = [_round_up(x.shape[1], bk) for x in xs]
    nps = [_round_up(n, bn) for n in ns]
    nsum = sum(nps)

    xpk = jnp.concatenate(
        [_tile_stack(jnp.pad(x, ((0, mp - m), (0, kp - x.shape[1]))),
                     bm, bk)
         for x, kp in zip(xs, kps)], axis=0)
    wpk = jnp.concatenate(
        [_tile_stack(jnp.pad(w, ((0, kp - w.shape[0]),
                                 (0, np_ - w.shape[1]))), bk, bn)
         for w, kp, np_ in zip(ws, kps, nps)], axis=0).astype(xpk.dtype)
    if bs is None:
        bpk = jnp.zeros((1, nsum), xpk.dtype)
    else:
        bpk = jnp.concatenate(
            [jnp.pad(b, (0, np_ - b.shape[0]))
             for b, np_ in zip(bs, nps)]).reshape(1, nsum).astype(xpk.dtype)

    _count_launch("grouped_matmul_concat")
    tab = _device_table(
        _plan_tiles_concat,
        mb, tuple(kp // bk for kp in kps), tuple(np_ // bn for np_ in nps))
    ncbt = sum(np_ // bn for np_ in nps)

    ragged = m_valid is not None
    ix, ixb = _ragged_index_maps(ragged)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2 if ragged else 1,
        grid=(tab.shape[1],),
        in_specs=[
            pl.BlockSpec((None, bm, bk), ix(GM_XT)),
            pl.BlockSpec((None, bk, bn), ix(GM_WT)),
            pl.BlockSpec((1, bn), ixb),
        ],
        out_specs=pl.BlockSpec((None, bm, bn), ix(GM_OT)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    scalars = (tab, _ragged_mrows(m_valid, mb, bm)) if ragged else (tab,)
    out = pl.pallas_call(
        functools.partial(_gmm_kernel, relu=relu, masked=False,
                          ragged=ragged),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mb * ncbt, bm, bn), xs[0].dtype),
        interpret=interpret,
    )(*scalars, xpk, wpk, bpk)
    # m-outermost slots: ONE layout pass unpacks the padded join whole
    y2 = out.reshape(mb, ncbt, bm, bn).transpose(0, 2, 1, 3)
    y2 = y2.reshape(mp, ncbt * bn)[:m]
    if not compact:
        return y2
    idx = _concat_gather_index(tuple(int(o) for o in offsets), tuple(ns),
                               tuple(nps), int(total))
    return jnp.take(y2, idx, axis=1)


def grouped_matmul_concat_ref(xs, ws, bs=None, *, offsets, total: int,
                              relu: bool = False, m_valid=None):
    """Per-branch XLA oracle: scatter each branch's GEMM into the join
    layout (uncovered columns are zero here, unspecified in the kernel)."""
    m = xs[0].shape[0]
    out = jnp.zeros((m, total), xs[0].dtype)
    ys = grouped_matmul_ref(xs, ws, bs, relu=relu, m_valid=m_valid)
    for y, off in zip(ys, offsets):
        out = jax.lax.dynamic_update_slice(out, y, (0, off))
    return out


# ---------------------------------------------------------------------------
# pooled grouped launch: in-kernel maxpool as a pre-GEMM stage
# ---------------------------------------------------------------------------

def _tap_views_one(x, window: int, stride: int):
    """One SAME-padded maxpool stage as ``window**2`` shifted views of
    ``x`` (NHWC): view ``(dh, dw)`` holds, at output position (oh, ow),
    the input element the pool window reads at tap (dh, dw) — out-of-image
    taps are -inf (the max monoid identity, exactly ``reduce_window``'s
    SAME padding).  A pure pad+strided-slice layout pass: no
    ``reduce_window``, no compute beyond the pad."""
    b, h, w, c = x.shape
    oh, ow = -(-h // stride), -(-w // stride)
    ph = max((oh - 1) * stride + window - h, 0)
    pw = max((ow - 1) * stride + window - w, 0)
    plh, plw = ph // 2, pw // 2
    xp = jnp.pad(x, ((0, 0), (plh, ph - plh), (plw, pw - plw), (0, 0)),
                 constant_values=-np.inf)
    # lax.slice, not __getitem__: jnp's strided getitem lowers to a gather
    # whose index grid is built with a concatenate — a layout launch the
    # chained plan's launch-ceiling gate would count.
    return [jax.lax.slice(xp, (0, dh, dw, 0),
                          (b, dh + (oh - 1) * stride + 1,
                           dw + (ow - 1) * stride + 1, xp.shape[3]),
                          (1, stride, stride, 1))
            for dh in range(window) for dw in range(window)]


def pool_tap_views(x, chain):
    """A maxpool *chain* ``((window, stride), ...)`` applied to NHWC ``x``
    as a flat list of shifted views whose elementwise max IS the pooled
    output: ``max_t views[t] == maxpool_chain(x)``.

    Views are ordered so that a first-max-wins fold reproduces the
    cotangent routing of the XLA oracle exactly (``reduce_window``'s max
    grad sends ties to the first maximal tap in window scan order; for a
    chain, the OUTER pool's scatter runs first, so its taps are the major
    axis of the composed order)."""
    views = [x]
    for window, stride in chain:
        exp = [_tap_views_one(v, window, stride) for v in views]
        ntap = window * window
        views = [exp[i][e] for e in range(ntap) for i in range(len(exp))]
    return views


def pool_from_taps(taps):
    """Left-fold ``where(isnan(v) | (v > acc), v, acc)`` over tap views:
    values equal ``reduce_window`` max — including NaN propagation (a
    NaN tap poisons its windows, as XLA's max does; a bare ``v > acc``
    select would silently drop it) — and the select routing makes
    autodiff send tie cotangents to the FIRST maximal tap: bit-identical
    gradients to the XLA oracle on finite inputs (``lax.max``'s
    balanced-eq tie splitting would not be; under NaNs gradients are
    meaningless either way)."""
    acc = taps[0]
    for v in taps[1:]:
        acc = jnp.where(jnp.isnan(v) | (v > acc), v, acc)
    return acc


def pool_cotangent_taps(taps, pooled, d_pooled):
    """Scatter the pooled-lhs cotangent back onto the tap views through
    the first-argmax window mask: tap t receives ``d_pooled`` where it
    equals the pooled max AND no earlier tap does — the mask the combined
    backward launch's unpacking pass applies (elementwise, like the ReLU
    cotangent mask folded into its dY packing)."""
    assigned = jnp.zeros(pooled.shape, jnp.bool_)
    outs = []
    for v in taps:
        take = (v == pooled) & ~assigned
        assigned = assigned | take
        outs.append(jnp.where(take, d_pooled, jnp.zeros_like(d_pooled)))
    return outs


def _gmm_pooled_kernel(tab_ref, *refs, relu: bool, ragged: bool = False):
    """``_gmm_kernel`` plus the in-kernel pre-GEMM pool stage.  Pool steps
    (row 6) max one tap tile of the raw input into the pooled-lhs VMEM
    scratch slot ``ps`` (row 8; row 7 marks the first tap, which seeds the
    slot); GEMM steps with row 9 set draw their lhs from that slot instead
    of the X ref.  Everything else is the unmodified grouped step —
    including the ragged-M epilogue mask (row 10 = M-block index into the
    second prefetched scalar vector)."""
    if ragged:
        mrow_ref, *refs = refs
    x_ref, w_ref, b_ref, o_ref, acc_ref, pool_ref = refs
    t = pl.program_id(0)
    is_pool = tab_ref[GP_POOL, t] == 1
    ps = tab_ref[GP_PS, t]

    @pl.when(is_pool)
    def _pool():
        tile = x_ref[...].astype(jnp.float32)

        @pl.when(tab_ref[GP_PFIRST, t] == 1)
        def _seed():
            pool_ref[ps] = tile

        @pl.when(tab_ref[GP_PFIRST, t] == 0)
        def _max():
            # same NaN-propagating select as pool_from_taps (lax.max may
            # drop a NaN acc against a later finite tap on some backends)
            cur = pool_ref[ps]
            pool_ref[ps] = jnp.where(jnp.isnan(tile) | (tile > cur),
                                     tile, cur)

    @pl.when(~is_pool)
    def _gemm():
        @pl.when(tab_ref[GP_FIRST, t] == 1)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        x = x_ref[...]
        x = jnp.where(tab_ref[GP_UPOOL, t] == 1,
                      pool_ref[ps].astype(x.dtype), x)
        acc_ref[...] += jnp.dot(x, w_ref[...],
                                preferred_element_type=jnp.float32)

        @pl.when(tab_ref[GP_LAST, t] == 1)
        def _store():
            y = acc_ref[...] + b_ref[...].astype(jnp.float32)
            if relu:
                y = jnp.maximum(y, 0.0)
            if ragged:
                valid = mrow_ref[tab_ref[GP_MI, t]]
                ri = jax.lax.broadcasted_iota(jnp.int32, y.shape, 0)
                y = jnp.where(ri < valid, y, 0.0)
            o_ref[...] = y.astype(o_ref.dtype)


@functools.lru_cache(maxsize=512)
def _plan_tiles_pooled(m_blocks: int, kbs: tuple[int, ...],
                       nbs: tuple[int, ...], taps: tuple[int, ...],
                       concat: bool):
    """Offset table for the pooled grouped grid — the per-branch pool
    descriptor the tentpole adds to the scalar-prefetch table.  ``taps[g]``
    is the branch's pool-window tap count (1 = unpooled; window and stride
    live in the tap-slot layout the packing derives from the branch's
    (window, stride) chain).  Branch g's packed X region holds, for every
    (row-block i, k-block kk), its ``taps[g]`` tap tiles consecutively;
    before an M-block's GEMM steps, one pool step per (kk, tap) maxes the
    taps into the pooled-lhs scratch slot kk.  ``concat=True`` lays output
    slots out as the join's padded panel layout, m-outermost
    (``_plan_tiles_concat``).  Rows:

        row 0  xt     slot into the packed X stack (pool step: the tap
                      tile; unpooled GEMM step: the lhs tile; pooled GEMM
                      step: the tile's first tap — fetched, unused)
        row 1  wt     slot into the packed W tile stack
        row 2  bj     col-block index into the packed bias
        row 3  first  1 on a tile's first k-step (zero the accumulator)
        row 4  last   1 on a tile's last k-step (epilogue + store)
        row 5  ot     output slot (pool steps: the upcoming tile's slot —
                      never stored, keeps the revisit window stable)
        row 6  pool   1 = pool step (max a tap tile into scratch)
        row 7  pfirst 1 on a tile's first tap (seed the scratch slot)
        row 8  ps     pooled-lhs scratch slot (the tile's k-block index)
        row 9  upool  1 = GEMM step draws its lhs from the scratch
        row 10 mi     M-block index (ragged-M epilogue mask; appended —
                      rows 0-9 keep their positions)
    """
    rows: list[list[int]] = [[] for _ in range(11)]
    # cbases doubles as the bias col-block offset: the packed bias and
    # the concat panel share one column-block numbering (like
    # _plan_tiles_concat's single accumulator)
    xbases, wbases, obases, cbases = [], [], [], []
    xb = wb = ob = cb = 0
    for nkb, npb, tp in zip(kbs, nbs, taps):
        xbases.append(xb)
        wbases.append(wb)
        obases.append(ob)
        cbases.append(cb)
        xb += m_blocks * nkb * tp
        wb += nkb * npb
        ob += m_blocks * npb
        cb += npb
    ncbt = cb

    def emit(g, i):
        nkb, npb, tp = kbs[g], nbs[g], taps[g]
        pooled = tp > 1
        first_ot = (i * ncbt + cbases[g]) if concat else (obases[g] + i * npb)
        if pooled:
            for kk in range(nkb):
                for t in range(tp):
                    rows[0].append(xbases[g] + (i * nkb + kk) * tp + t)
                    rows[1].append(wbases[g])
                    rows[2].append(cbases[g])
                    rows[3].append(0)
                    rows[4].append(0)
                    rows[5].append(first_ot)
                    rows[6].append(1)
                    rows[7].append(1 if t == 0 else 0)
                    rows[8].append(kk)
                    rows[9].append(0)
                    rows[10].append(i)
        for j in range(npb):
            for kk in range(nkb):
                rows[0].append(xbases[g] + (i * nkb + kk) * tp)
                rows[1].append(wbases[g] + kk * npb + j)
                rows[2].append(cbases[g] + j)
                rows[3].append(1 if kk == 0 else 0)
                rows[4].append(1 if kk == nkb - 1 else 0)
                rows[5].append((i * ncbt + cbases[g] + j) if concat
                               else (obases[g] + i * npb + j))
                rows[6].append(0)
                rows[7].append(0)
                # unpooled steps still read the scratch (both select arms
                # are fetched) — pin them to slot 0, always in bounds
                rows[8].append(kk if pooled else 0)
                rows[9].append(1 if pooled else 0)
                rows[10].append(i)

    if concat:
        for i in range(m_blocks):
            for g in range(len(kbs)):
                emit(g, i)
    else:
        for g in range(len(kbs)):
            for i in range(m_blocks):
                emit(g, i)
    return np.array(rows, np.int32)


# A single pool window keeps its taps as in-kernel pool steps; a chained
# pool (e.g. the (3,2)+(3,1) pool-proj of a pooled module) expands to
# window1^2 * window2^2 = 81 views, and 81 pool grid steps per (i, kk)
# tile cost more than they save (on hardware: more steps than the GEMM
# they feed; on the interpret emulation: each is a fully-charged grid
# step).  Past the limit the taps fold at PACK time instead — an
# elementwise max fused into the tile-stack layout pass, still zero
# reduce_window, still one launch, same VJP (the backward folds at pack
# time in all cases).  Heuristic knob in the grouped_block_shape spirit.
POOL_TAP_LIMIT = 16


def _branch_taps(xs, tap_limit: int | None = None):
    """Normalize xs entries: an array is one tap (unpooled); a list/tuple
    of tap arrays is a pooled branch — folded at pack time when its tap
    count exceeds ``tap_limit``.  Returns (tap lists, tap counts)."""
    limit = POOL_TAP_LIMIT if tap_limit is None else tap_limit
    tls, tns = [], []
    for x in xs:
        if isinstance(x, (list, tuple)):
            assert len(x) >= 1
            assert all(t.shape == x[0].shape for t in x)
            if len(x) > limit:
                tls.append([pool_from_taps(list(x))])
                tns.append(1)
            else:
                tls.append(list(x))
                tns.append(len(x))
        else:
            tls.append([x])
            tns.append(1)
    return tls, tns


def _pooled_launch(xs, ws, bs, *, relu, concat, offsets=None, total=None,
                   compact=True, m_valid=None, bm=None, bn=None, bk=None,
                   interpret=False, tap_limit=None):
    """Shared implementation of the pooled grouped launch (plain and
    fused-concat output layouts)."""
    g = len(xs)
    assert g == len(ws) and g >= 1
    assert bs is None or len(bs) == g
    tls, tns = _branch_taps(xs, tap_limit)
    m = tls[0][0].shape[0]
    assert all(t.shape[0] == m for tl in tls for t in tl)
    assert all(tl[0].shape[1] == w.shape[0] for tl, w in zip(tls, ws))
    ns = [w.shape[1] for w in ws]
    if concat:
        assert offsets is not None and total is not None \
            and len(offsets) == g
        segs = sorted(zip(offsets, ns))
        assert all(o1 >= o0 + n0 for (o0, n0), (o1, _)
                   in zip(segs, segs[1:])) \
            and segs[-1][0] + segs[-1][1] <= total, (offsets, ns, total)
    if bm is None or bn is None or bk is None:
        blocks = grouped_block_shape(
            m, [(w.shape[0], w.shape[1]) for w in ws], tls[0][0].dtype)
        bm, bn, bk = bm or blocks.bm, bn or blocks.bn, bk or blocks.bk
    mp = _round_up(m, bm)
    mb = mp // bm
    kps = [_round_up(tl[0].shape[1], bk) for tl in tls]
    nps = [_round_up(n, bn) for n in ns]
    nsum = sum(nps)

    # X stack: branch g's region holds, tile by tile, its taps
    # consecutively — (i, kk)-tile slots [base + (i*nkb + kk)*taps, +taps)
    parts = []
    for tl, kp in zip(tls, kps):
        stacks = [_tile_stack(
            jnp.pad(t, ((0, mp - m), (0, kp - t.shape[1]))), bm, bk)
            for t in tl]
        if len(stacks) == 1:
            parts.append(stacks[0])
        else:
            # interleave taps per tile: (T_tiles, taps, bm, bk) flattened
            parts.append(jnp.stack(stacks, axis=1).reshape(-1, bm, bk))
    xpk = jnp.concatenate(parts, axis=0)
    wpk = jnp.concatenate(
        [_tile_stack(jnp.pad(w, ((0, kp - w.shape[0]),
                                 (0, np_ - w.shape[1]))), bk, bn)
         for w, kp, np_ in zip(ws, kps, nps)], axis=0).astype(xpk.dtype)
    if bs is None:
        bpk = jnp.zeros((1, nsum), xpk.dtype)
    else:
        bpk = jnp.concatenate(
            [jnp.pad(b, (0, np_ - b.shape[0]))
             for b, np_ in zip(bs, nps)]).reshape(1, nsum).astype(xpk.dtype)

    name = "grouped_matmul_pooled_concat" if concat \
        else "grouped_matmul_pooled"
    _count_launch(name)
    tab = _device_table(
        _plan_tiles_pooled,
        mb, tuple(kp // bk for kp in kps), tuple(np_ // bn for np_ in nps),
        tuple(tns), concat)
    nkb_pool = max((kp // bk for kp, tn in zip(kps, tns) if tn > 1),
                   default=1)
    o_tiles = mb * sum(np_ // bn for np_ in nps)

    ragged = m_valid is not None
    ix, ixb = _ragged_index_maps(ragged)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2 if ragged else 1,
        grid=(tab.shape[1],),
        in_specs=[
            pl.BlockSpec((None, bm, bk), ix(GP_XT)),
            pl.BlockSpec((None, bk, bn), ix(GP_WT)),
            pl.BlockSpec((1, bn), ixb),
        ],
        out_specs=pl.BlockSpec((None, bm, bn), ix(GP_OT)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((nkb_pool, bm, bk), jnp.float32)],
    )
    scalars = (tab, _ragged_mrows(m_valid, mb, bm)) if ragged else (tab,)
    out = pl.pallas_call(
        functools.partial(_gmm_pooled_kernel, relu=relu, ragged=ragged),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((o_tiles, bm, bn), tls[0][0].dtype),
        interpret=interpret,
    )(*scalars, xpk, wpk, bpk)

    if concat:
        ncbt = sum(np_ // bn for np_ in nps)
        y2 = out.reshape(mb, ncbt, bm, bn).transpose(0, 2, 1, 3)
        y2 = y2.reshape(mp, ncbt * bn)[:m]
        if not compact:
            return y2
        idx = _concat_gather_index(tuple(int(o) for o in offsets),
                                  tuple(ns), tuple(nps), int(total))
        return jnp.take(y2, idx, axis=1)
    outs, obase = [], 0
    for w, np_ in zip(ws, nps):
        npb = np_ // bn
        tiles = out[obase:obase + mb * npb]
        y = tiles.reshape(mb, npb, bm, bn).transpose(0, 2, 1, 3)
        outs.append(y.reshape(mp, np_)[:m, :w.shape[1]])
        obase += mb * npb
    return outs


def grouped_matmul_pooled(xs, ws, bs=None, *, relu: bool = False,
                          m_valid=None, bm: int | None = None,
                          bn: int | None = None, bk: int | None = None,
                          interpret: bool = False,
                          tap_limit: int | None = None):
    """[maxpool(x_g) @ w_g (+ b_g) (+ ReLU)] for ragged (K_g, N_g) in ONE
    launch, the maxpool computed IN-KERNEL as a pre-GEMM stage.

    ``xs[g]`` is either an (M, K_g) array (unpooled branch — a plain
    grouped lhs) or a sequence of (M, K_g) *tap views* of the raw input
    (``pool_tap_views``): the kernel maxes the tap tiles into a VMEM
    pooled-lhs scratch per the table's pool descriptor, so the pooled
    activation never materializes in HBM and no standalone pooling launch
    remains.  Branches whose tap count exceeds ``tap_limit`` (default
    ``POOL_TAP_LIMIT``) fold at pack time instead — see the constant's
    comment.  ``m_valid`` as in ``grouped_matmul`` (ragged-M epilogue
    mask).  With no pooled branch this is exactly ``grouped_matmul``.
    Returns G arrays (M, N_g).
    """
    if all(not isinstance(x, (list, tuple)) for x in xs):
        return grouped_matmul(xs, ws, bs, relu=relu, m_valid=m_valid,
                              bm=bm, bn=bn, bk=bk, interpret=interpret)
    return _pooled_launch(xs, ws, bs, relu=relu, concat=False,
                          m_valid=m_valid, bm=bm, bn=bn, bk=bk,
                          interpret=interpret, tap_limit=tap_limit)


def grouped_matmul_pooled_concat(xs, ws, bs=None, *, offsets, total: int,
                                 relu: bool = False, compact: bool = True,
                                 m_valid=None, bm: int | None = None,
                                 bn: int | None = None,
                                 bk: int | None = None,
                                 interpret: bool = False,
                                 tap_limit: int | None = None):
    """``grouped_matmul_concat`` with the in-kernel pool stage: pooled
    branches' epilogues land in the join's [M, total] layout like every
    other branch — one launch covers pooling, GEMMs, bias+ReLU AND the
    concat.  ``xs``/``compact``/``m_valid`` semantics as in the
    pooled/concat wrappers.  With no pooled branch this is
    ``grouped_matmul_concat``."""
    if all(not isinstance(x, (list, tuple)) for x in xs):
        return grouped_matmul_concat(xs, ws, bs, offsets=offsets,
                                     total=total, relu=relu,
                                     compact=compact, m_valid=m_valid,
                                     bm=bm, bn=bn, bk=bk,
                                     interpret=interpret)
    return _pooled_launch(xs, ws, bs, relu=relu, concat=True,
                          offsets=offsets, total=total, compact=compact,
                          m_valid=m_valid, bm=bm, bn=bn, bk=bk,
                          interpret=interpret, tap_limit=tap_limit)


def grouped_matmul_pooled_ref(xs, ws, bs=None, *, relu: bool = False,
                              m_valid=None):
    """Per-branch XLA oracle: fold each branch's taps, then plain GEMMs."""
    tls, tns = _branch_taps(xs)
    flat = [pool_from_taps(tl) if tn > 1 else tl[0]
            for tl, tn in zip(tls, tns)]
    return grouped_matmul_ref(flat, ws, bs, relu=relu, m_valid=m_valid)


def grouped_matmul_pooled_concat_ref(xs, ws, bs=None, *, offsets,
                                     total: int, relu: bool = False,
                                     m_valid=None):
    """Oracle for the pooled concat layout (uncovered columns zero)."""
    tls, tns = _branch_taps(xs)
    flat = [pool_from_taps(tl) if tn > 1 else tl[0]
            for tl, tn in zip(tls, tns)]
    return grouped_matmul_concat_ref(flat, ws, bs, offsets=offsets,
                                     total=total, relu=relu,
                                     m_valid=m_valid)


# ---------------------------------------------------------------------------
# backward-weight kernel: dw_g = x_g^T @ dy_g, db_g = sum_M dy_g
# ---------------------------------------------------------------------------

def _gmm_dw_kernel(tab_ref, *refs, masked: bool):
    if masked:
        x_ref, dy_ref, y_ref, dw_ref, db_ref, acc_ref, db_acc_ref = refs
    else:
        x_ref, dy_ref, dw_ref, db_ref, acc_ref, db_acc_ref = refs
    t = pl.program_id(0)
    dy = dy_ref[...]
    if masked:
        dy = jnp.where(y_ref[...] > 0, dy, jnp.zeros_like(dy))

    @pl.when(tab_ref[DW_FIRST, t] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((tab_ref[DW_FIRST, t] == 1) & (tab_ref[DW_DODB, t] == 1))
    def _init_db():
        db_acc_ref[...] = jnp.zeros_like(db_acc_ref)

    # x^T @ dy: contract the shared m-rows of both tiles -> (bk, bn)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], dy, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(tab_ref[DW_DODB, t] == 1)
    def _acc_db():
        # db rides the first k-row, whose dy blocks are streamed in anyway
        db_acc_ref[...] += dy.astype(jnp.float32).sum(0, keepdims=True)

    @pl.when(tab_ref[DW_LAST, t] == 1)
    def _store():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)
        db_ref[...] = db_acc_ref[...]


@functools.lru_cache(maxsize=512)
def _plan_tiles_dw(m_blocks: int, kbs: tuple[int, ...], nbs: tuple[int, ...]):
    """Offset table for the dw grid — one step per (branch, col-block,
    k-row-block, m-step), m-steps consecutive so the fp32 (bk, bn)
    accumulator lives in VMEM scratch across them.  Column-major per
    branch (j outermost) so the db output block of column j is visited
    consecutively and holds its finished sum before the grid moves on.

        row 0  xt     slot into the packed X tile stack (T_x, bm, bk)
        row 1  dyt    slot into the packed dY tile stack (T_dy, bm, bn)
        row 2  first  1 on a tile's first m-step (zero the accumulators)
        row 3  last   1 on a tile's last m-step (store dw + db)
        row 4  ot     slot into the packed dW tile stack (T_w, bk, bn)
        row 5  bj     col-block index into the packed db (1, sum Np_g)
        row 6  dodb   1 on k-row 0 (the k-row that accumulates db)
    """
    rows: list[list[int]] = [[] for _ in range(7)]
    noff = xbase = dybase = wbase = 0
    for nkb, npb in zip(kbs, nbs):
        for j in range(npb):
            for ki in range(nkb):
                for mi in range(m_blocks):
                    rows[0].append(xbase + mi * nkb + ki)
                    rows[1].append(dybase + mi * npb + j)
                    rows[2].append(1 if mi == 0 else 0)
                    rows[3].append(1 if mi == m_blocks - 1 else 0)
                    rows[4].append(wbase + ki * npb + j)
                    rows[5].append(noff + j)
                    rows[6].append(1 if ki == 0 else 0)
        noff += npb
        xbase += m_blocks * nkb
        dybase += m_blocks * npb
        wbase += nkb * npb
    return np.array(rows, np.int32)


def grouped_matmul_dw(xs, dys, mask=None, *, bm: int | None = None,
                      bn: int | None = None, bk: int | None = None,
                      interpret: bool = False):
    """G transposed GEMMs dw_g = x_g^T @ dy_g with db_g = sum_M dy_g
    reduced in the same pass — the backward-weight half of a grouped
    branch group in ONE kernel.

    xs: G arrays (M, K_g) — the forward GEMM inputs (im2col patches for
    convs); dys: G arrays (M, N_g) — output cotangents; mask: optional G
    arrays (M, N_g) — dy_g is zeroed where mask_g <= 0 before BOTH the
    GEMM and the db reduction (the fused-ReLU cotangent mask, applied
    in-kernel).  Returns (dws, dbs): G arrays (K_g, N_g) in the input
    dtype and G float32 arrays (N_g,).
    """
    g = len(xs)
    assert g == len(dys) and g >= 1, (len(xs), len(dys))
    assert mask is None or len(mask) == g
    m = xs[0].shape[0]
    assert all(x.shape[0] == m and dy.shape[0] == m
               for x, dy in zip(xs, dys)), \
        [(x.shape, dy.shape) for x, dy in zip(xs, dys)]
    kns = [(x.shape[1], dy.shape[1]) for x, dy in zip(xs, dys)]
    if bm is None or bn is None or bk is None:
        blocks = grouped_block_shape(m, kns, xs[0].dtype)
        bm, bn, bk = bm or blocks.bm, bn or blocks.bn, bk or blocks.bk
    mp = _round_up(m, bm)
    mb = mp // bm
    kps = [_round_up(k, bk) for k, _ in kns]
    nps = [_round_up(n, bn) for _, n in kns]
    nsum = sum(nps)

    xpk = jnp.concatenate(
        [_tile_stack(jnp.pad(x, ((0, mp - m), (0, kp - x.shape[1]))),
                     bm, bk)
         for x, kp in zip(xs, kps)], axis=0)

    def pack_dy(arrs):
        return jnp.concatenate(
            [_tile_stack(jnp.pad(a, ((0, mp - m), (0, np_ - a.shape[1]))),
                         bm, bn)
             for a, np_ in zip(arrs, nps)], axis=0)

    ins = [xpk, pack_dy(dys).astype(xpk.dtype)]
    in_specs = [
        pl.BlockSpec((None, bm, bk), lambda t, tab: (tab[DW_XT, t], 0, 0)),
        pl.BlockSpec((None, bm, bn), lambda t, tab: (tab[DW_DYT, t], 0, 0)),
    ]
    if mask is not None:
        assert all(mk.shape == dy.shape for mk, dy in zip(mask, dys)), \
            [(mk.shape, dy.shape) for mk, dy in zip(mask, dys)]
        ins.append(pack_dy(mask))
        in_specs.append(
            pl.BlockSpec((None, bm, bn), lambda t, tab: (tab[DW_DYT, t], 0, 0)))

    _count_launch("grouped_matmul_dw")
    tab = _device_table(
        _plan_tiles_dw,
        mb, tuple(kp // bk for kp in kps), tuple(np_ // bn for np_ in nps))
    w_tiles = sum((kp // bk) * (np_ // bn) for kp, np_ in zip(kps, nps))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tab.shape[1],),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((None, bk, bn), lambda t, tab: (tab[DW_OT, t], 0, 0)),
            pl.BlockSpec((1, bn), lambda t, tab: (0, tab[DW_BJ, t])),
        ],
        scratch_shapes=[pltpu.VMEM((bk, bn), jnp.float32),
                        pltpu.VMEM((1, bn), jnp.float32)],
    )
    dwt, dbp = pl.pallas_call(
        functools.partial(_gmm_dw_kernel, masked=mask is not None),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((w_tiles, bk, bn), xs[0].dtype),
                   jax.ShapeDtypeStruct((1, nsum), jnp.float32)],
        interpret=interpret,
    )(tab, *ins)

    dws, dbs, wbase, noff = [], [], 0, 0
    for (k, n), kp, np_ in zip(kns, kps, nps):
        nkb, npb = kp // bk, np_ // bn
        tiles = dwt[wbase:wbase + nkb * npb]
        dw = tiles.reshape(nkb, npb, bk, bn).transpose(0, 2, 1, 3)
        dws.append(dw.reshape(kp, np_)[:k, :n])
        dbs.append(dbp[0, noff:noff + n])
        wbase += nkb * npb
        noff += np_
    return dws, dbs


def grouped_matmul_dw_ref(xs, dys, mask=None):
    """Per-branch XLA oracle: (dws, dbs) with the same mask semantics."""
    dws, dbs = [], []
    for i, (x, dy) in enumerate(zip(xs, dys)):
        if mask is not None:
            dy = jnp.where(mask[i] > 0, dy, jnp.zeros_like(dy))
        dws.append(jnp.dot(x.T, dy,
                           preferred_element_type=jnp.float32).astype(x.dtype))
        dbs.append(dy.astype(jnp.float32).sum(0))
    return dws, dbs


# ---------------------------------------------------------------------------
# combined backward: masked dx + dw/db in ONE launch (concatenated table)
# ---------------------------------------------------------------------------

def _gmm_bwd_kernel(tab_ref, dy_ref, ab_ref, o_ref, db_ref,
                    acc_ref, accb_ref):
    t = pl.program_id(0)
    is_dw = tab_ref[BW_DW, t] == 1
    first = tab_ref[BW_FIRST, t] == 1
    last = tab_ref[BW_LAST, t] == 1
    dodb = tab_ref[BW_DODB, t] == 1
    dy = dy_ref[...]          # pre-masked at pack time (ReLU cotangent)

    @pl.when(first)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # phase 0 — dx_g = dy_g @ w_g^T: ab is the W^T tile
    @pl.when(~is_dw)
    def _acc_dx():
        acc_ref[...] += jnp.dot(dy, ab_ref[...],
                                preferred_element_type=jnp.float32)

    # phase 1 — dw_g = x_g^T @ dy_g: ab is the X tile; db on k-row 0
    @pl.when(is_dw)
    def _acc_dw():
        acc_ref[...] += jax.lax.dot_general(
            ab_ref[...], dy, dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(is_dw & first & dodb)
    def _init_db():
        accb_ref[...] = jnp.zeros_like(accb_ref)

    @pl.when(is_dw & dodb)
    def _acc_db():
        accb_ref[...] += dy.astype(jnp.float32).sum(0, keepdims=True)

    @pl.when(last)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    @pl.when(is_dw & last)
    def _store_db():
        db_ref[...] = accb_ref[...]


@functools.lru_cache(maxsize=512)
def _plan_tiles_bwd(m_blocks: int, kbs: tuple[int, ...],
                    nbs: tuple[int, ...]):
    """Concatenated two-phase offset table: every dx step, then every dw
    step, one flat grid over ONE uniform block size b = bm = bn = bk.
    Uniform blocks let both phases share one operand stack (W^T tiles ++
    X tiles), one output stack (dX tiles ++ dW tiles) and one fp32
    accumulator — vs separate per-phase operands, the interpret emulation
    (and a naive pipeline) moves one less input and one less output block
    per step.  Rows:

        row 0  dyt    slot into the packed dY tile stack (both phases)
        row 1  abt    slot into the shared W^T ++ X tile stack
        row 2  first  1 on a tile's first accumulation step
        row 3  last   1 on a tile's last step (store)
        row 4  ot     slot into the shared dX ++ dW output tile stack
        row 5  dodb   1 on k-row 0 of the dw phase (accumulates db)
        row 6  phase  0 = dx step, 1 = dw step
        row 7  bj     col-block index into the packed db (1, sum Np_g)
    """
    rows: list[list[int]] = [[] for _ in range(8)]
    xbases, dybases, wtbases, dxbases, dwbases, noffs = [], [], [], [], [], []
    xb = dyb = wtb = dxb = dwb = nb = 0
    for nkb, npb in zip(kbs, nbs):
        dybases.append(dyb)
        wtbases.append(wtb)
        dxbases.append(dxb)
        dyb += m_blocks * npb
        wtb += npb * nkb
        dxb += m_blocks * nkb
    for nkb, npb in zip(kbs, nbs):
        xbases.append(wtb + xb)         # X tiles follow ALL W^T tiles
        dwbases.append(dxb + dwb)       # dW tiles follow ALL dX tiles
        noffs.append(nb)
        xb += m_blocks * nkb
        dwb += nkb * npb
        nb += npb
    # dx phase: (branch, row-block, K col-block, N contraction-block)
    for g, (nkb, npb) in enumerate(zip(kbs, nbs)):
        for i in range(m_blocks):
            for kk in range(nkb):
                for j in range(npb):
                    rows[0].append(dybases[g] + i * npb + j)
                    rows[1].append(wtbases[g] + j * nkb + kk)
                    rows[2].append(1 if j == 0 else 0)
                    rows[3].append(1 if j == npb - 1 else 0)
                    rows[4].append(dxbases[g] + i * nkb + kk)
                    rows[5].append(0)
                    rows[6].append(0)
                    rows[7].append(0)
    # dw phase: (branch, N col-block, K row-block, m-step)
    for g, (nkb, npb) in enumerate(zip(kbs, nbs)):
        for j in range(npb):
            for ki in range(nkb):
                for mi in range(m_blocks):
                    rows[0].append(dybases[g] + mi * npb + j)
                    rows[1].append(xbases[g] + mi * nkb + ki)
                    rows[2].append(1 if mi == 0 else 0)
                    rows[3].append(1 if mi == m_blocks - 1 else 0)
                    rows[4].append(dwbases[g] + ki * npb + j)
                    rows[5].append(1 if ki == 0 else 0)
                    rows[6].append(1)
                    rows[7].append(noffs[g] + j)
    return np.array(rows, np.int32)


def grouped_matmul_bwd(xs, ws, dys, mask=None, *, block: int | None = None,
                       interpret: bool = False):
    """The whole grad CoGroup of a grouped branch group in ONE launch:
    dx_g = (dy_g ⊙ mask_g) @ w_g^T, dw_g = x_g^T @ (dy_g ⊙ mask_g),
    db_g = sum_M (dy_g ⊙ mask_g), over a concatenated two-phase offset
    table (``_plan_tiles_bwd``).

    The dY tile stack both phases read is packed ONCE — with the ReLU
    cotangent mask folded into the packing pass, so no mask operand rides
    the grid — and the W^T/X operands (resp. dX/dW outputs) share one
    tile stack over a single uniform block size: half the packing traffic
    of the separate dx + dw launches this replaces, and one block less in
    and out per grid step.

    xs: G arrays (M, K_g) — forward GEMM inputs; ws: G arrays (K_g, N_g);
    dys: G arrays (M, N_g); mask: optional G arrays (M, N_g) — the
    fused-ReLU cotangent mask (dy zeroed where mask <= 0, both phases).
    Returns (dxs, dws, dbs): G×(M, K_g), G×(K_g, N_g) in the input dtype
    and G float32 (N_g,).
    """
    g = len(xs)
    assert g == len(ws) == len(dys) and g >= 1, (len(xs), len(ws), len(dys))
    assert mask is None or len(mask) == g
    m = xs[0].shape[0]
    assert all(x.shape[0] == m and dy.shape[0] == m
               and x.shape[1] == w.shape[0] and dy.shape[1] == w.shape[1]
               for x, w, dy in zip(xs, ws, dys)), \
        [(x.shape, w.shape, dy.shape) for x, w, dy in zip(xs, ws, dys)]
    kns = [(w.shape[0], w.shape[1]) for w in ws]
    if block is None:
        blocks = grouped_block_shape(m, kns, xs[0].dtype)
        # the shared operand/output stacks need ONE block size
        b = blocks.bm if blocks.bm == blocks.bn == blocks.bk else 128
    else:
        b = block
    mp = _round_up(m, b)
    mb = mp // b
    kps = [_round_up(k, b) for k, _ in kns]
    nps = [_round_up(n, b) for _, n in kns]
    nsum = sum(nps)

    if mask is not None:
        assert all(mk.shape == dy.shape for mk, dy in zip(mask, dys))
        dys = [jnp.where(mk > 0, dy, jnp.zeros_like(dy))
               for mk, dy in zip(mask, dys)]
    dypk = jnp.concatenate(
        [_tile_stack(jnp.pad(dy, ((0, mp - m), (0, np_ - dy.shape[1]))),
                     b, b)
         for dy, np_ in zip(dys, nps)], axis=0)
    # shared second operand: every branch's W^T tiles, then every X's
    abpk = jnp.concatenate(
        [_tile_stack(jnp.pad(w.T, ((0, np_ - w.shape[1]),
                                   (0, kp - w.shape[0]))), b, b)
         for w, kp, np_ in zip(ws, kps, nps)]
        + [_tile_stack(jnp.pad(x, ((0, mp - m), (0, kp - x.shape[1]))),
                       b, b)
           for x, kp in zip(xs, kps)], axis=0).astype(dypk.dtype)

    _count_launch("grouped_matmul_bwd")
    tab = _device_table(
        _plan_tiles_bwd,
        mb, tuple(kp // b for kp in kps), tuple(np_ // b for np_ in nps))
    dx_tiles = mb * sum(kp // b for kp in kps)
    w_tiles = sum((kp // b) * (np_ // b) for kp, np_ in zip(kps, nps))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tab.shape[1],),
        in_specs=[
            pl.BlockSpec((None, b, b), lambda t, tab: (tab[BW_DYT, t], 0, 0)),
            pl.BlockSpec((None, b, b), lambda t, tab: (tab[BW_ABT, t], 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, b, b), lambda t, tab: (tab[BW_OT, t], 0, 0)),
            pl.BlockSpec((1, b), lambda t, tab: (0, tab[BW_BJ, t])),
        ],
        scratch_shapes=[pltpu.VMEM((b, b), jnp.float32),
                        pltpu.VMEM((1, b), jnp.float32)],
    )
    ot, dbp = pl.pallas_call(
        _gmm_bwd_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((dx_tiles + w_tiles, b, b),
                                        xs[0].dtype),
                   jax.ShapeDtypeStruct((1, nsum), jnp.float32)],
        interpret=interpret,
    )(tab, dypk, abpk)

    dxs, dws, dbs = [], [], []
    dxbase, wbase, noff = 0, dx_tiles, 0
    for (k, n), kp, np_ in zip(kns, kps, nps):
        nkb, npb = kp // b, np_ // b
        xt = ot[dxbase:dxbase + mb * nkb]
        dx = xt.reshape(mb, nkb, b, b).transpose(0, 2, 1, 3)
        dxs.append(dx.reshape(mp, kp)[:m, :k])
        wt = ot[wbase:wbase + nkb * npb]
        dw = wt.reshape(nkb, npb, b, b).transpose(0, 2, 1, 3)
        dws.append(dw.reshape(kp, np_)[:k, :n])
        dbs.append(dbp[0, noff:noff + n])
        dxbase += mb * nkb
        wbase += nkb * npb
        noff += np_
    return dxs, dws, dbs


def grouped_matmul_bwd_ref(xs, ws, dys, mask=None):
    """Per-branch XLA oracle: (dxs, dws, dbs) with the same mask
    semantics as ``grouped_matmul_bwd``."""
    dxs, dws, dbs = [], [], []
    for i, (x, w, dy) in enumerate(zip(xs, ws, dys)):
        if mask is not None:
            dy = jnp.where(mask[i] > 0, dy, jnp.zeros_like(dy))
        dxs.append(jnp.dot(dy, w.T,
                           preferred_element_type=jnp.float32).astype(x.dtype))
        dws.append(jnp.dot(x.T, dy,
                           preferred_element_type=jnp.float32).astype(x.dtype))
        dbs.append(dy.astype(jnp.float32).sum(0))
    return dxs, dws, dbs


def grouped_matmul_flops(shapes, bm: int = 128, bn: int = 128,
                         bk: int = 128) -> tuple[int, int]:
    """(grouped, stacked) MXU FLOPs for branch GEMM shapes [(M, K_g, N_g)]:
    grouped pads per-branch to alignment; stacked additionally pads every
    branch to the widest (K, N) — the waste this kernel removes."""
    ms = {m for m, _, _ in shapes}
    assert len(ms) == 1, shapes
    mp = _round_up(ms.pop(), bm)
    kmax = max(_round_up(k, bk) for _, k, _ in shapes)
    nmax = max(_round_up(n, bn) for _, _, n in shapes)
    grouped = sum(2 * mp * _round_up(k, bk) * _round_up(n, bn)
                  for _, k, n in shapes)
    stacked = len(shapes) * 2 * mp * kmax * nmax
    return grouped, stacked


# ---------------------------------------------------------------------------
# chained multi-phase launch (cross-module streaming)
# ---------------------------------------------------------------------------
#
# ONE pallas_call executes a short CHAIN of grouped branch sets ("phases"):
# phase p's branches may draw their GEMM lhs from
#
#   src=0  the packed X tile stack (im2col / pooled-fold lhs prepped outside),
#   src=2  a VMEM ring holding the last 3 row-block panels a PRODUCER phase
#          of the same launch wrote — a KxK conv consumes them as K^2
#          shifted 1x1 tap-GEMMs with iota-decoded border masking, so the
#          producer activation never touches HBM,
#   src=3/4  a PANEL operand — the padded join buffer a PREVIOUS chained
#          launch emitted, consumed in place via a per-branch lhs-source
#          descriptor (panel id + column block) in the scalar-prefetch
#          table: join-chaining with no intervening concat/reshape.
#
# Phases run in a lag-1 wave schedule (wave w runs phase p's row block
# w - p, ascending p), so a ring consumer always finds producer blocks
# i-1, i, i+1 resident and un-overwritten (ring depth 3).  Each phase
# writes one output panel whose segments are its branches' padded column
# slabs — the layout the NEXT launch's panel descriptors address.
# The bias+ReLU epilogue is fused (chained branches must be relu convs).

# table rows are the CH_* constants in ``analysis.tables`` (plus 2 per
# phase via ch_out_i_row/ch_out_j_row: output row-block / col-block, kept
# on the "slot of the next write at step >= t" stability rule)


def _chain_ksteps(tag, src):
    """The ordered k-steps of one chained branch."""
    if tag == "x":
        return [("x", kk) for kk in range(src)]
    if tag == "panel":
        return [("panel", pc) for pc in src]
    taps, rcs = src
    return [("ring", (d, dh, dw, rc)) for (d, dh, dw) in taps for rc in rcs]


@functools.lru_cache(maxsize=512)
def _plan_tiles_chained(m_blocks: int, phases):
    """Offset table for a chained launch.  ``phases``: per phase a tuple of
    branch specs (tag, src, nbb, rwcs) with tag 'x' (src = k-block count),
    'panel' (src = ((panel, colblock), ...)) or 'ring' (src = (taps, ring
    cols), taps = ((delta, dh, dw), ...)); nbb = output n-blocks; rwcs =
    per-n-block ring write col (or ()).  The trailing ``ch_mrow_row``
    holds ``phase * m_blocks + block`` — the slot a ragged-M launch's
    prefetched per-phase mrow vector is read at; dense launches carry
    (and ignore) the same row, so one table serves both.  Pure shape
    bookkeeping, cached."""
    nph = len(phases)
    nrows = CH_ROWS + 2 * nph + 1
    info = []
    xbase = wbase = bbase = 0
    for phase in phases:
        pinfo = []
        ob = 0
        for (tag, src, nbb, rwcs) in phase:
            ksteps = _chain_ksteps(tag, src)
            pinfo.append((tag, src, nbb, rwcs, ksteps, xbase, wbase,
                          bbase, ob))
            if tag == "x":
                xbase += m_blocks * src
            wbase += len(ksteps) * nbb
            bbase += nbb
            ob += nbb
        info.append(pinfo)
    cols: list[list[int]] = []
    for wave in range(m_blocks + nph - 1):
        for p in range(nph):
            i = wave - p
            if not (0 <= i < m_blocks):
                continue
            for (tag, src, nbb, rwcs, ksteps, xb, wb, bb, ob) in info[p]:
                ns = len(ksteps)
                for j in range(nbb):
                    for s, (kt, kd) in enumerate(ksteps):
                        c = [0] * nrows
                        c[CH_I] = i
                        c[ch_mrow_row(nph)] = p * m_blocks + i
                        c[CH_WT] = wb + s * nbb + j
                        c[CH_BJ] = bb + j
                        c[CH_FIRST] = 1 if s == 0 else 0
                        c[CH_LAST] = 1 if s == ns - 1 else 0
                        c[CH_PH] = p
                        c[CH_RWC] = -1
                        if kt == "x":
                            c[CH_SRC] = 0
                            c[CH_XT] = xb + i * src + kd
                        elif kt == "panel":
                            pidx, cb = kd
                            c[CH_SRC] = 3 + pidx
                            c[CH_PCA if pidx == 0 else CH_PCB] = cb
                        else:
                            d, dh, dw, rc = kd
                            c[CH_SRC] = 2
                            c[CH_RC] = rc
                            c[CH_DELTA] = d
                            c[CH_DH] = dh
                            c[CH_DW] = dw
                        if c[CH_LAST]:
                            c[ch_out_i_row(p)] = i
                            c[ch_out_j_row(p)] = ob + j
                            if rwcs:
                                c[CH_RWC] = rwcs[j]
                        cols.append(c)
    # output stability: each phase's index rows = slot of the next write at
    # step >= t (single transition between consecutive writes; the final
    # write is the phase's last (row, col) slab, which is also the default)
    ncbs = [sum(br[2] for br in pinfo) for pinfo in info]
    for p in range(nph):
        nr, nc = ch_out_i_row(p), ch_out_j_row(p)
        nxt = (m_blocks - 1, ncbs[p] - 1)
        for c in reversed(cols):
            if c[CH_PH] == p and c[CH_LAST] == 1:
                nxt = (c[nr], c[nc])
            c[nr], c[nc] = nxt
    return np.array(cols, np.int32).T


def _gmm_chained_kernel(*args, nphases: int, npanels: int, bm: int,
                        blk: int, ragged: bool = False,
                        debug_steps: bool = False):
    if ragged:
        tab_ref, mrow_ref, dims_ref = args[0], args[1], args[2]
        refs = args[3:]
    else:
        tab_ref, dims_ref = args[0], args[1]
        refs = args[2:]
    x_ref, w_ref, b_ref = refs[0], refs[1], refs[2]
    p_refs = refs[3:3 + npanels]
    out_refs = refs[3 + npanels:3 + npanels + nphases]
    nout = 3 + npanels + nphases
    cnt_ref = refs[nout] if debug_steps else None
    acc_ref, ring_ref, win_ref = refs[nout + (1 if debug_steps else 0):]
    t = pl.program_id(0)
    i = tab_ref[CH_I, t]
    src = tab_ref[CH_SRC, t]
    hd = dims_ref[0]
    wd = dims_ref[1]
    # per-phase liveness: this (phase, block)'s true row count.  mrow == 0
    # means the block is entirely past m_valid and the whole wave is a
    # no-op guard — init, window assembly, GEMM, store and ring write all
    # skipped, never merely zeroed.
    mrow = mrow_ref[tab_ref[ch_mrow_row(nphases), t]] if ragged else None
    live = (mrow > 0) if ragged else None

    if debug_steps:
        @pl.when(t == 0)
        def _cnt_init():
            cnt_ref[0, 0] = 0

        def _cnt():
            cnt_ref[0, 0] += 1
        if ragged:
            pl.when(live)(_cnt)
        else:
            _cnt()

    def _body():
        @pl.when(tab_ref[CH_FIRST, t] == 1)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        xop = x_ref[...]
        # ring window: producer row-block panels i-1, i, i+1 assembled
        # into a (3*bm, blk) scratch, then one dynamic-start shifted load
        # + border mask
        slo = (i + 2) % 3
        smi = i % 3
        shi = (i + 1) % 3
        rc = tab_ref[CH_RC, t]
        win_ref[pl.ds(0, bm), :] = ring_ref[slo, rc]
        win_ref[pl.ds(bm, bm), :] = ring_ref[smi, rc]
        win_ref[pl.ds(2 * bm, bm), :] = ring_ref[shi, rc]
        shifted = win_ref[pl.ds(bm + tab_ref[CH_DELTA, t], bm), :]
        r = i * bm + jax.lax.broadcasted_iota(jnp.int32, (bm, 1), 0)[:, 0]
        rem = r % (hd * wd)
        hh = rem // wd + tab_ref[CH_DH, t]
        ww = rem % wd + tab_ref[CH_DW, t]
        valid = (hh >= 0) & (hh < hd) & (ww >= 0) & (ww < wd)
        xop = jnp.where(src == 2,
                        jnp.where(valid[:, None], shifted,
                                  jnp.zeros_like(shifted)), xop)
        for pi, p_ref in enumerate(p_refs):
            xop = jnp.where(src == 3 + pi, p_ref[...], xop)
        acc_ref[...] += jnp.dot(xop, w_ref[...],
                                preferred_element_type=jnp.float32)

    def _store():
        bj = tab_ref[CH_BJ, t]
        y = jnp.maximum(
            acc_ref[...] + b_ref[bj, :].astype(jnp.float32)[None, :], 0.0)
        if ragged:
            # live tail block: exact zeros past the block's true rows, so
            # next-phase ring taps and next-launch panel descriptors read
            # clean producer slots
            ri = jax.lax.broadcasted_iota(jnp.int32, (bm, blk), 0)
            y = jnp.where(ri < mrow, y, 0.0)
        y = y.astype(out_refs[0].dtype)
        ph = tab_ref[CH_PH, t]
        for p, o_ref in enumerate(out_refs):
            @pl.when(ph == p)
            def _(o_ref=o_ref):
                o_ref[...] = y

        rwc = tab_ref[CH_RWC, t]

        @pl.when(rwc >= 0)
        def _ring():
            ring_ref[i % 3, jnp.maximum(rwc, 0)] = y

    last = tab_ref[CH_LAST, t] == 1
    if ragged:
        pl.when(live)(_body)
        pl.when(last & live)(_store)
    else:
        _body()
        pl.when(last)(_store)


def _chain_dims(h: int, w: int):
    return np.array([h, w], np.int32)


def chained_layout(phases, blk: int = 128):
    """Per-branch (phase, col base, n-blocks, true n) of the panel layout a
    chained launch emits — what the NEXT launch's panel descriptors (and
    the caller's output slicing) address."""
    out = []
    for p, phase in enumerate(phases):
        cb = 0
        for br in phase:
            nbb = -(-br["n"] // blk)
            out.append((p, cb, nbb, br["n"]))
            cb += nbb
    return out


def _chain_static(phases, blk, bm, wimg):
    """Hashable planner spec + validation for one chained launch."""
    spec = []
    for phase in phases:
        pspec = []
        for br in phase:
            nbb = -(-br["n"] // blk)
            tag = br["src"][0]
            if tag == "x":
                kbs = sum(-(-a.shape[1] // blk) for a in br["src"][1])
                src = kbs
            elif tag == "panel":
                src = tuple(br["src"][1])
            else:
                _, kh, kw, rcs = br["src"]
                taps = []
                for dh in range(kh):
                    for dw in range(kw):
                        d = (dh - kh // 2) * wimg + (dw - kw // 2)
                        assert abs(d) <= bm, (
                            f"halo {d} exceeds bm={bm} (W={wimg}, "
                            f"k={kh}x{kw}) — chain ineligible")
                        taps.append((d, dh - kh // 2, dw - kw // 2))
                src = (tuple(taps), tuple(rcs))
            rwcs = tuple(br.get("ring_write") or ())
            if rwcs:
                assert len(rwcs) == nbb, (rwcs, nbb)
            s = len(_chain_ksteps(tag, src))
            assert br["w"].shape[0] == s * blk, \
                (br["w"].shape, s, blk, "weight rows must be k-step-major")
            pspec.append((tag, src, nbb, rwcs))
        spec.append(tuple(pspec))
    return tuple(spec)


def grouped_matmul_chained(phases, *, m: int, h: int, w: int, panels=(),
                           block: int = 128, m_valid=None,
                           debug_steps: bool = False,
                           interpret: bool = False):
    """Execute a chain of grouped branch phases as ONE kernel.

    ``phases``: list of phases, each a list of branch dicts
      n     true output width
      w     (S*block, n) weight — rows in K-STEP-MAJOR order (one
            ``block``-row slab per k-step, zero-padded where the lhs slab
            is panel padding), S the branch's k-step count
      b     (n,) bias or None
      src   ('x', [2D (m, K_i) arrays])               packed-lhs branch
            ('panel', [(panel_idx, col_block), ...])  join-chained branch
            ('ring', kh, kw, (ring_cols...))          in-launch KxK conv
      ring_write  per-n-block ring col this branch's output feeds, or None

    ``panels``: previous-launch padded panels (rows >= m, cols a multiple
    of ``block``) consumed by 'panel' branches in place.  ``h``/``w`` are
    the shared spatial dims (m = B*h*w) the ring border mask decodes.

    Returns one padded (Mp, ncb_p * block) panel per phase; true values
    sit at [:m, col_base*block : col_base*block + n] per ``chained_layout``
    — padding columns are exactly zero (relu(0 + 0)).

    ``m_valid`` (python int or traced i32 scalar) makes the launch
    ragged-M: rows at/past it are padding.  The wave schedule SKIPS
    M-blocks entirely past ``m_valid`` (no-op guard — dead-block
    GEMM/ring steps never execute), live tail blocks mask their epilogue
    stores to exact zeros, and the per-phase liveness vector
    (``_ragged_mrows`` tiled per phase) rides the launch as a second
    scalar-prefetch operand.  ``m_valid`` must be image-aligned
    (a multiple of h*w): ring taps are image-local, so valid rows never
    read skipped blocks (``analysis.hazards.check_chained_masked``).
    Every request mix in one padded-M bucket shares the same offset
    table and traced executable.  Inference-only — the differentiable
    wrapper in ``kernels/ops.py`` rejects ragged chains from its VJP.

    ``debug_steps=True`` additionally returns an executed-step counter
    (the skip instrument): ``(panels, steps)`` where ``steps`` is a
    (1, 1) i32 of grid steps that ran their body — dense launches count
    every step, ragged launches only live-block steps.
    """
    blk = block
    bm = blk
    mb = -(-m // bm)
    mp = mb * bm
    # dtype: follow the lhs operands
    dtype = None
    for phase in phases:
        for br in phase:
            if br["src"][0] == "x" and br["src"][1]:
                dtype = br["src"][1][0].dtype
    if dtype is None:
        dtype = panels[0].dtype if panels else phases[0][0]["w"].dtype
    spec = _chain_static(phases, blk, bm, w)
    nph = len(phases)

    # ---- pack (dynamic_update_slice only: the chained path must emit no
    # concatenate primitives — the traced launch counter counts them) ----
    flat = [br for phase in phases for br in phase]
    flat_spec = [bs for pspec in spec for bs in pspec]
    tx = sum(mb * bs[1] for bs in flat_spec if bs[0] == "x")
    tw = sum(len(_chain_ksteps(bs[0], bs[1])) * bs[2] for bs in flat_spec)
    nb = sum(bs[2] for bs in flat_spec)
    xstack = jnp.zeros((max(tx, 1), bm, blk), dtype)
    wstack = jnp.zeros((tw, blk, blk), dtype)
    bstack = jnp.zeros((nb, blk), dtype)
    xbase = wbase = bbase = 0
    for br, (tag, src, nbb, _rw) in zip(flat, flat_spec):
        ksteps = _chain_ksteps(tag, src)
        s = len(ksteps)
        if tag == "x":
            kbs = src
            bb = jnp.zeros((mb, kbs, bm, blk), dtype)
            off = 0
            for a in br["src"][1]:
                kbi = -(-a.shape[1] // blk)
                ap = jnp.pad(a, ((0, mp - a.shape[0]),
                                 (0, kbi * blk - a.shape[1])))
                t4 = ap.reshape(mb, bm, kbi, blk).transpose(0, 2, 1, 3)
                bb = jax.lax.dynamic_update_slice(
                    bb, t4.astype(dtype), (0, off, 0, 0))
                off += kbi
            xstack = jax.lax.dynamic_update_slice(
                xstack, bb.reshape(-1, bm, blk), (xbase, 0, 0))
            xbase += mb * kbs
        wp = jnp.pad(br["w"], ((0, 0), (0, nbb * blk - br["n"])))
        t4 = wp.reshape(s, blk, nbb, blk).transpose(0, 2, 1, 3)
        wstack = jax.lax.dynamic_update_slice(
            wstack, t4.reshape(-1, blk, blk).astype(dtype), (wbase, 0, 0))
        wbase += s * nbb
        bias = br.get("b")
        if bias is not None:
            bp = jnp.pad(bias, (0, nbb * blk - br["n"]))
            bstack = jax.lax.dynamic_update_slice(
                bstack, bp.reshape(nbb, blk).astype(dtype), (bbase, 0))
        bbase += nbb
    pads = []
    for pa in panels:
        pr, pc = pa.shape
        assert pc % blk == 0, pa.shape
        pads.append(jnp.pad(pa, ((0, mp - pr), (0, 0))) if pr < mp
                    else pa[:mp])
    nring = 1
    for bs in flat_spec:
        if bs[0] == "ring":
            nring = max(nring, max(bs[1][1]) + 1)
        if bs[3]:
            nring = max(nring, max(bs[3]) + 1)

    _count_launch("grouped_matmul_chained")
    tab = _device_table(_plan_tiles_chained, mb, spec)
    dims = _device_table(_chain_dims, h, w)

    ragged = m_valid is not None
    if ragged:
        # one liveness slot per (phase, block) — same per-block counts in
        # every phase (all phases share m), laid out phase-major to match
        # the table's ch_mrow_row slots.  broadcast+reshape, never
        # concatenate: the chained pack path must stay concat-free.
        mrows = jnp.broadcast_to(_ragged_mrows(m_valid, mb, bm)[None, :],
                                 (nph, mb)).reshape(nph * mb)

        def _im(fn):
            return lambda t, tab, mrow, dims: fn(t, tab, dims)
    else:
        def _im(fn):
            return lambda t, tab, dims: fn(t, tab, dims)

    in_specs = [
        pl.BlockSpec((None, bm, blk),
                     _im(lambda t, tab, dims: (tab[CH_XT, t], 0, 0))),
        pl.BlockSpec((None, blk, blk),
                     _im(lambda t, tab, dims: (tab[CH_WT, t], 0, 0))),
        pl.BlockSpec(memory_space=pltpu.VMEM),
    ]
    ins = [xstack, wstack, bstack]
    for pi, pa in enumerate(pads):
        row = CH_PCA if pi == 0 else CH_PCB
        in_specs.append(pl.BlockSpec(
            (bm, blk), _im(lambda t, tab, dims, row=row:
                           (tab[CH_I, t], tab[row, t]))))
        ins.append(pa)
    ncbs = [sum(bs[2] for bs in pspec) for pspec in spec]
    out_specs = [
        pl.BlockSpec((bm, blk),
                     _im(lambda t, tab, dims, ri=ch_out_i_row(p),
                         rj=ch_out_j_row(p): (tab[ri, t], tab[rj, t])))
        for p in range(nph)
    ]
    out_shape = [jax.ShapeDtypeStruct((mp, ncb * blk), dtype)
                 for ncb in ncbs]
    if debug_steps:
        out_specs.append(pl.BlockSpec(
            (1, 1), _im(lambda t, tab, dims: (0, 0))))
        out_shape.append(jax.ShapeDtypeStruct((1, 1), jnp.int32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3 if ragged else 2,
        grid=(tab.shape[1],),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((bm, blk), jnp.float32),
            pltpu.VMEM((3, nring, bm, blk), dtype),
            pltpu.VMEM((3 * bm, blk), dtype),
        ],
    )
    scalars = (tab, mrows, dims) if ragged else (tab, dims)
    outs = pl.pallas_call(
        functools.partial(_gmm_chained_kernel, nphases=nph,
                          npanels=len(pads), bm=bm, blk=blk,
                          ragged=ragged, debug_steps=debug_steps),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*scalars, *ins)
    if debug_steps:
        return list(outs[:nph]), outs[nph]
    return list(outs)


def _shift_spatial(seg2d, m, h, w, dh, dw):
    """Zero-padded spatial shift of a (rows>=m, C) activation (m = B*h*w):
    row r of the result is row r + dh*w + dw where (h+dh, w+dw) stays in
    bounds, else 0 — the reference for one ring tap."""
    b = m // (h * w)
    img = seg2d[:m].reshape(b, h, w, -1)
    # pad + slice, not .at[].set: the scatter lowering builds its index
    # vector with concatenates that the launch counter would see.
    pb_h, pa_h = max(-dh, 0), max(dh, 0)
    pb_w, pa_w = max(-dw, 0), max(dw, 0)
    pimg = jnp.pad(img, ((0, 0), (pb_h, pa_h), (pb_w, pa_w), (0, 0)))
    out = jax.lax.slice(pimg, (0, pa_h, pa_w, 0),
                        (b, pa_h + h, pa_w + w, pimg.shape[3]))
    return out.reshape(m, -1)


def grouped_matmul_chained_ref(phases, *, m: int, h: int, w: int,
                               panels=(), block: int = 128):
    """XLA oracle for ``grouped_matmul_chained`` — same padded panels (true
    rows/cols; padding rows are zeros here, garbage in the kernel)."""
    blk = block
    mb = -(-m // blk)
    mp = mb * blk
    # ring col -> (producer phase, producer panel col block), from the
    # branches' ring_write descriptors — the mapping the kernel realizes
    # through its VMEM ring slots
    ringmap: dict[int, tuple[int, int]] = {}
    for p, phase in enumerate(phases):
        cb = 0
        for br in phase:
            nbb = -(-br["n"] // blk)
            for j, rc in enumerate(br.get("ring_write") or ()):
                ringmap[rc] = (p, cb + j)
            cb += nbb
    outs = []
    for phase in phases:
        segs = []
        for br in phase:
            nbb = -(-br["n"] // blk)
            tag = br["src"][0]
            if tag == "x":
                parts = []
                for a in br["src"][1]:
                    kbi = -(-a.shape[1] // blk)
                    parts.append(jnp.pad(
                        a, ((0, 0), (0, kbi * blk - a.shape[1]))))
                lhs = jnp.concatenate(parts, axis=1) if len(parts) > 1 \
                    else parts[0]
            elif tag == "panel":
                lhs = jnp.concatenate(
                    [panels[pidx][:m, cb * blk:(cb + 1) * blk]
                     for pidx, cb in br["src"][1]], axis=1)
            else:
                _, kh, kw, rcs = br["src"]
                taps = []
                for dh in range(kh):
                    for dw in range(kw):
                        for rc in rcs:
                            pp, pcb = ringmap[rc]
                            seg = outs[pp][:m, pcb * blk:(pcb + 1) * blk]
                            taps.append(_shift_spatial(
                                seg, m, h, w, dh - kh // 2, dw - kw // 2))
                lhs = jnp.concatenate(taps, axis=1)
            bias = br.get("b")
            y = lhs.astype(jnp.float32) @ br["w"].astype(jnp.float32)
            if bias is not None:
                y = y + bias.astype(jnp.float32)
            y = jnp.maximum(y, 0.0).astype(lhs.dtype)
            segs.append(jnp.pad(y, ((0, mp - m), (0, nbb * blk - br["n"]))))
        outs.append(jnp.concatenate(segs, axis=1))
    return outs


# ---------------------------------------------------------------------------
# per-expert ragged grouped GEMM: the MoE expert engine
# ---------------------------------------------------------------------------
#
# PR 7's raggedness is ONE shared M tail mask (requests pack contiguously,
# every branch sees the same m_valid).  MoE needs each branch (expert) g to
# own its routed token count M_g: tokens pack into per-expert block-aligned
# segments of a single (MBS*bm, D) buffer, the grid flattens over the ragged
# per-expert M-block counts, and the scalar-prefetch machinery splits into
#
#   static table (``_plan_tiles_experts``)  — per-step tile slots, phase and
#       first/last flags, scratch panel index.  Depends only on (MBS, DB,
#       FB, gated): every routing outcome reuses the SAME device table.
#   dynamic vector (``_expert_block_meta``)  — per-M-block expert id,
#       valid-row count (the per-branch ``_ragged_mrows``), and
#       first/last-block-of-expert flags, computed from the TRACED per-
#       expert counts.  Weight index maps do arithmetic on it
#       (``eid[bi] * tiles_per_expert + rel``), so which expert's tiles a
#       block fetches is a runtime decision inside a static grid.
#
# The static grid bound is MBS = floor(n_slots/bm) + E (each expert wastes
# at most one partial block, and every expert keeps >= 1 block so zero-token
# experts still store their — zero — dW tiles).  Blocks past the last live
# one ("dead tail") get eid = E-1, valid 0, zero packed rows: their stores
# are zeroed by the valid mask and their dW contributions are zero, so the
# combined backward's cross-block dW accumulation runs through them safely.
#
# The epilogue fuses the whole expert chain: H = act(X@Wg) * (X@Wi) (or
# act(X@Wi) ungated) through a VMEM panel, Y = (H@Wo) * sw with the router's
# combine weight sw row-scaled in-kernel and the per-block valid mask
# zeroing the tail — ONE launch per MoE layer per direction.

_MOE_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}


def moe_block_m(n_slots: int, e: int) -> int:
    """Packed M-block rows for the experts launch: the largest power of two
    <= clamp(n_slots/E, 8, 128) — full 128-row MXU tiles once the uniform
    per-expert count supports them, down to the f32 sublane floor of 8 for
    tiny batches (where one partial block per expert is the whole grid)."""
    per = max(n_slots // max(e, 1), 1)
    bm = 8
    while bm * 2 <= min(per, 128):
        bm *= 2
    return bm


def moe_static_blocks(n_slots: int, e: int, bm: int) -> int:
    """Static M-block bound for the experts grid: sum_g ceil(c_g/bm) <=
    floor(sum_g c_g / bm) + E for any routing outcome with sum c_g <=
    n_slots, and the +E also funds the >=1 block every expert keeps."""
    return n_slots // bm + e


def _expert_block_meta(counts, mbs: int, bm: int):
    """(4, MBS) int32 dynamic prefetch: rows [expert id, valid rows,
    first-block-of-expert, last-block-of-expert] per static M-block, from
    the TRACED per-expert routed counts.  Zero-token experts keep one
    block (valid 0); dead tail blocks take eid E-1 with valid 0."""
    counts = jnp.asarray(counts, jnp.int32)
    e = counts.shape[0]
    blocks = jnp.maximum(-(-counts // bm), 1)
    cum = jnp.cumsum(blocks)
    bi = jnp.arange(mbs, dtype=jnp.int32)
    eid = jnp.clip(jnp.searchsorted(cum, bi, side="right"),
                   0, e - 1).astype(jnp.int32)
    start = cum - blocks                          # first block of expert
    rel = bi - start[eid]
    mrows = jnp.clip(counts[eid] - rel * bm, 0, bm)
    febl = (bi == start[eid]).astype(jnp.int32)
    nxt = jnp.concatenate([eid[1:], jnp.full((1,), -1, jnp.int32)])
    lebl = (nxt != eid).astype(jnp.int32)
    return jnp.stack([eid, mrows, febl, lebl])


def expert_row_offsets(counts, bm: int):
    """(E,) packed-row offset of each expert's segment — the per-branch
    M-row offsets the dispatch scatters against (block-aligned so segment
    starts coincide with M-block starts)."""
    counts = jnp.asarray(counts, jnp.int32)
    blocks = jnp.maximum(-(-counts // bm), 1)
    return (jnp.cumsum(blocks) - blocks) * bm


@functools.lru_cache(maxsize=512)
def _plan_tiles_experts(mbs: int, db: int, fb: int, gated: int):
    """Static offset table for the experts forward, (10, T) int32.

    Per M-block i the steps run H phase (j over F-blocks, which over
    {in[, gate]}, k over D-blocks; accumulate X@W into the f32 acc, close
    each (j, which) tile into the VMEM H panel) then Y phase (c over
    D-blocks, j over F-blocks; accumulate Hpanel@Wout, close with the
    sw-scale + per-block valid mask epilogue).  Rows:

      0 bi      M-block index (keys the dynamic eid/mrows/sw lookups)
      1 xt      packed-X tile slot (held at last H value through Y)
      2 whrel   H-weight tile rel index: which*DB*FB + k*FB + j
      3 worel   Wout tile rel index: j*DB + c (held at next-use during H)
      4 phase   0 = H-in step, 1 = H-gate step, 2 = Y step
      5 first   1 on the tile's first accumulation step (zero the acc)
      6 last    1 on the tile's last accumulation step (close the tile)
      7 hj      F-block index (H panel scratch slot)
      8 ot      Y output tile slot i*DB + c (next-write during H)
      9 rres    residual (preact) output tile slot i*FB + j (next-write)
    """
    nw = 1 + gated
    rows: list[list[int]] = [[] for _ in range(10)]
    for i in range(mbs):
        for j in range(fb):
            for wch in range(nw):
                for k in range(db):
                    rows[0].append(i)
                    rows[1].append(i * db + k)
                    rows[2].append(wch * db * fb + k * fb + j)
                    rows[3].append(0)
                    rows[4].append(wch)
                    rows[5].append(1 if k == 0 else 0)
                    rows[6].append(1 if k == db - 1 else 0)
                    rows[7].append(j)
                    rows[8].append(i * db)
                    rows[9].append(i * fb + j)
        for c in range(db):
            for j in range(fb):
                rows[0].append(i)
                rows[1].append(i * db + db - 1)
                rows[2].append(0)
                rows[3].append(j * db + c)
                rows[4].append(2)
                rows[5].append(1 if j == 0 else 0)
                rows[6].append(1 if j == fb - 1 else 0)
                rows[7].append(j)
                rows[8].append(i * db + c)
                rows[9].append((i + 1) * fb if i + 1 < mbs
                               else i * fb + fb - 1)
    return np.array(rows, np.int32)


def _gmm_experts_kernel(tab_ref, dyn_ref, x_ref, wh_ref, wo_ref, sw_ref,
                        *rest, activation: str, gated: bool, train: bool):
    nres = (2 if gated else 1) if train else 0
    y_ref = rest[0]
    res_refs = rest[1:1 + nres]
    acc_ref, hin_s, hpost_s = rest[1 + nres:]
    t = pl.program_id(0)
    phase = tab_ref[EX_PH, t]
    last = tab_ref[EX_LAST, t] == 1
    hj = tab_ref[EX_HJ, t]
    dt = y_ref.dtype
    act = _MOE_ACTS[activation]

    @pl.when(tab_ref[EX_FIRST, t] == 1)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(phase < 2)
    def _h_step():
        acc_ref[...] += jnp.dot(x_ref[...], wh_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(phase == 2)
    def _y_step():
        acc_ref[...] += jnp.dot(hpost_s[hj].astype(dt), wo_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when((phase == 0) & last)
    def _close_in():
        pre = acc_ref[...]
        if gated:
            hin_s[hj] = pre
        else:
            # oracle order: act applied to the dtype-cast preact
            hpost_s[hj] = act(pre.astype(dt)).astype(jnp.float32)
        if train:
            res_refs[0][...] = pre.astype(dt)

    if gated:
        @pl.when((phase == 1) & last)
        def _close_gate():
            pre_g = acc_ref[...]
            pre_i = hin_s[hj]
            # oracle order: h = act(gate preact) * in preact, in dtype
            h = act(pre_g.astype(dt)) * pre_i.astype(dt)
            hpost_s[hj] = h.astype(jnp.float32)
            if train:
                res_refs[1][...] = pre_g.astype(dt)

    @pl.when((phase == 2) & last)
    def _close_y():
        valid = dyn_ref[1, tab_ref[EX_BI, t]]
        y = acc_ref[...].astype(dt) * sw_ref[...][:, None].astype(dt)
        ri = jax.lax.broadcasted_iota(jnp.int32, y.shape, 0)
        y_ref[...] = jnp.where(ri < valid, y, jnp.zeros_like(y))


def _expert_wstack(w, d0p: int, d1p: int):
    """(E, D0, D1) expert weights -> per-expert (D0p/128 * D1p/128, 128,
    128) tile stacks, concatenated expert-major."""
    e, d0, d1 = w.shape
    wq = jnp.pad(w, ((0, 0), (0, d0p - d0), (0, d1p - d1)))
    return jnp.concatenate([_tile_stack(wq[g], 128, 128) for g in range(e)])


def _pack_rows(a2d, bm: int, d_pad: int):
    aq = jnp.pad(a2d, ((0, 0), (0, d_pad - a2d.shape[1])))
    return _tile_stack(aq, bm, 128)


def _unpack_rows(tiles, mbs: int, bm: int, nb: int, d: int):
    return tiles.reshape(mbs, nb, bm, 128).transpose(0, 2, 1, 3) \
        .reshape(mbs * bm, nb * 128)[:, :d]


def grouped_matmul_experts(xp, swp, w_in, w_out, w_gate, counts, *,
                           activation: str = "silu", train: bool = False,
                           bm: int | None = None, interpret=True):
    """ONE launch over E expert chains with per-expert ragged M.

    xp     (MBS*bm, D)  tokens packed into block-aligned per-expert
                        segments (``expert_row_offsets``), zero elsewhere
    swp    (MBS*bm,)    f32 router combine weight per packed row (0 pad)
    w_in   (E, D, F);  w_out (E, F, D);  w_gate (E, D, F) or None
    counts (E,) i32     routed token count per expert — traced: every
                        routing outcome shares this trace and the static
                        offset table; only the dynamic (4, MBS) prefetch
                        vector changes
    train  also return the (MBS*bm, F) in/gate preacts (the combined
           backward's residuals)

    Returns y (MBS*bm, D) = act-gated expert chain output, row-scaled by
    swp, exact zeros at/past each block's valid count.
    """
    e, d, f = w_in.shape
    gated = w_gate is not None
    n_rows = xp.shape[0]
    bm = moe_block_m(n_rows, e) if bm is None else bm
    assert n_rows % bm == 0, (n_rows, bm)
    mbs = n_rows // bm
    dp_, fp_ = _round_up(d, 128), _round_up(f, 128)
    db, fb = dp_ // 128, fp_ // 128
    dt = xp.dtype

    x_tiles = _pack_rows(xp, bm, dp_)
    whs = []
    for g in range(e):
        whs.append(_expert_wstack(w_in[g:g + 1], dp_, fp_))
        if gated:
            whs.append(_expert_wstack(w_gate[g:g + 1], dp_, fp_))
    wh = jnp.concatenate(whs)
    wo = _expert_wstack(w_out, fp_, dp_)
    sw2 = jnp.asarray(swp, jnp.float32).reshape(mbs, bm)

    tab = _device_table(_plan_tiles_experts, mbs, db, fb, int(gated))
    dyn = _expert_block_meta(counts, mbs, bm)
    whpe, wope = (1 + int(gated)) * db * fb, fb * db

    in_specs = [
        pl.BlockSpec((None, bm, 128), lambda t, tab, dyn: (tab[EX_XT, t], 0, 0)),
        pl.BlockSpec((None, 128, 128),
                     lambda t, tab, dyn, s=whpe:
                     (dyn[0, tab[EX_BI, t]] * s + tab[EX_WH, t], 0, 0)),
        pl.BlockSpec((None, 128, 128),
                     lambda t, tab, dyn, s=wope:
                     (dyn[0, tab[EX_BI, t]] * s + tab[EX_WO, t], 0, 0)),
        pl.BlockSpec((None, bm), lambda t, tab, dyn: (tab[EX_BI, t], 0)),
    ]
    out_shape = [jax.ShapeDtypeStruct((mbs * db, bm, 128), dt)]
    out_specs = [pl.BlockSpec((None, bm, 128),
                              lambda t, tab, dyn: (tab[EX_OT, t], 0, 0))]
    if train:
        for _ in range(2 if gated else 1):
            out_shape.append(jax.ShapeDtypeStruct((mbs * fb, bm, 128), dt))
            out_specs.append(pl.BlockSpec(
                (None, bm, 128), lambda t, tab, dyn: (tab[EX_RES, t], 0, 0)))

    nw = 1 + int(gated)
    grid = (mbs * (nw * fb * db + db * fb),)
    fn = pl.pallas_call(
        functools.partial(_gmm_experts_kernel, activation=activation,
                          gated=gated, train=train),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=grid,
            in_specs=in_specs, out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((bm, 128), jnp.float32),
                            pltpu.VMEM((fb, bm, 128), jnp.float32),
                            pltpu.VMEM((fb, bm, 128), jnp.float32)]),
        out_shape=out_shape, interpret=interpret)
    _count_launch("grouped_matmul_experts")
    outs = fn(tab, dyn, x_tiles, wh, wo, sw2)
    y = _unpack_rows(outs[0], mbs, bm, db, d)
    if not train:
        return y
    res = [_unpack_rows(o, mbs, bm, fb, f) for o in outs[1:]]
    return (y, res[0], res[1] if gated else None)


@functools.lru_cache(maxsize=512)
def _plan_tiles_experts_bwd(mbs: int, db: int, fb: int, gated: int):
    """Static table for the ONE combined experts backward, (13, T) int32.

    Per M-block i (expert e = eid[i]), four phase types in order:
      A  dHpost_j = sum_c dYs(i,c) @ WoutT(e; c,j); at the last c derive
         the dHin/dGate cotangent panels and Hpost from the saved preacts
      B  dWout_acc[j*DB+c] += Hpost_j^T @ dYs(i,c) — zeroed on the
         DYNAMIC first-block-of-expert flag, stored on last-block (output
         slot eid*FB*DB + j*DB + c via index-map arithmetic): the dW
         accumulation crosses an expert's consecutive M-blocks
      C  dX(i,c) = sum_{which,j} dPanel[which*FB+j] @ WhT(e; which,j,c)
      D  dWh_acc[which*DB*FB + c*FB + j] += X(i,c)^T @ dPanel[which*FB+j]
         — same dynamic-flag accumulation as B

    Rows: 0 bi, 1 dyt, 2 xt, 3 whtrel, 4 wotrel, 5 rrest (saved-preact
    tile slot i*FB + j), 6 phase (0=A 1=B 2=C 3=D), 7 first, 8 last,
    9 pj (cotangent/Hpost panel slot: j in A/B, which*FB + j in C/D),
    10 dx out slot, 11 dWh rel (scratch slot AND output rel), 12 dWout
    rel (scratch slot AND output rel).  Unused operand rows hold a valid
    recent/next index so the block revisit semantics skip the refetch."""
    nw = 1 + gated
    rows: list[list[int]] = [[] for _ in range(13)]

    def emit(i, dyt, xt, whtrel, wotrel, rrest, phase, first, last, pj,
             dxot, dwhrel, dworel):
        vals = (i, dyt, xt, whtrel, wotrel, rrest, phase, first, last, pj,
                dxot, dwhrel, dworel)
        for r, v in zip(rows, vals):
            r.append(v)

    wot_hold = db * fb - 1
    for i in range(mbs):
        for j in range(fb):                    # A
            for c in range(db):
                emit(i, i * db + c, i * db, 0, c * fb + j, i * fb + j,
                     0, 1 if c == 0 else 0, 1 if c == db - 1 else 0,
                     j, i * db, 0, 0)
        for j in range(fb):                    # B
            for c in range(db):
                emit(i, i * db + c, i * db, 0, wot_hold, i * fb + j,
                     1, 0, 0, j, i * db, 0, j * db + c)
        for c in range(db):                    # C
            for wch in range(nw):
                for j in range(fb):
                    emit(i, i * db + db - 1, i * db,
                         wch * fb * db + j * db + c, wot_hold,
                         i * fb + fb - 1, 2,
                         1 if (wch == 0 and j == 0) else 0,
                         1 if (wch == nw - 1 and j == fb - 1) else 0,
                         wch * fb + j, i * db + c, 0, wot_hold)
        for wch in range(nw):                  # D
            for c in range(db):
                for j in range(fb):
                    emit(i, i * db + db - 1, i * db + c,
                         wch * fb * db, wot_hold, i * fb + fb - 1, 3,
                         0, 0, wch * fb + j, i * db + db - 1,
                         wch * db * fb + c * fb + j, wot_hold)
    return np.array(rows, np.int32)


def _gmm_experts_bwd_kernel(tab_ref, dyn_ref, x_ref, dy_ref, wht_ref,
                            wot_ref, hin_ref, *rest, activation: str,
                            gated: bool):
    if gated:
        gate_ref, *rest = rest
    dx_ref, dwh_ref, dwo_ref = rest[:3]
    acc_ref, dpan_s, hpost_s, dwo_acc, dwh_acc = rest[3:]
    t = pl.program_id(0)
    bi = tab_ref[EB_BI, t]
    phase = tab_ref[EB_PH, t]
    last = tab_ref[EB_LAST, t] == 1
    pj = tab_ref[EB_PJ, t]
    febl = dyn_ref[2, bi] == 1
    lebl = dyn_ref[3, bi] == 1
    dt = dx_ref.dtype
    act = _MOE_ACTS[activation]
    cdims = (((0,), (0,)), ((), ()))           # tile^T @ tile

    @pl.when(tab_ref[EB_FIRST, t] == 1)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(phase == 0)
    def _a_step():
        acc_ref[...] += jnp.dot(dy_ref[...], wot_ref[...],
                                preferred_element_type=jnp.float32)

    fb = dpan_s.shape[0] // (2 if gated else 1)

    @pl.when((phase == 0) & last)
    def _a_close():
        dh = acc_ref[...]
        pre_i = hin_ref[...].astype(jnp.float32)
        if gated:
            pre_g = gate_ref[...].astype(jnp.float32)
            actg, vjp_g = jax.vjp(act, pre_g)
            hpost_s[pj] = actg * pre_i
            dpan_s[pj] = dh * actg
            dpan_s[fb + pj] = vjp_g(dh * pre_i)[0]
        else:
            acti, vjp_i = jax.vjp(act, pre_i)
            hpost_s[pj] = acti
            dpan_s[pj] = vjp_i(dh)[0]

    @pl.when(phase == 1)
    def _b_step():
        slot = tab_ref[EB_DWO, t]

        @pl.when(febl)
        def _zero_b():
            dwo_acc[slot] = jnp.zeros_like(dwo_acc[slot])

        dwo_acc[slot] += jax.lax.dot_general(
            hpost_s[pj].astype(dt), dy_ref[...], cdims,
            preferred_element_type=jnp.float32)

        @pl.when(lebl)
        def _store_b():
            dwo_ref[...] = dwo_acc[slot]

    @pl.when(phase == 2)
    def _c_step():
        acc_ref[...] += jnp.dot(dpan_s[pj].astype(dt), wht_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when((phase == 2) & last)
    def _c_close():
        valid = dyn_ref[1, bi]
        dx = acc_ref[...].astype(dt)
        ri = jax.lax.broadcasted_iota(jnp.int32, dx.shape, 0)
        dx_ref[...] = jnp.where(ri < valid, dx, jnp.zeros_like(dx))

    @pl.when(phase == 3)
    def _d_step():
        slot = tab_ref[EB_DWH, t]

        @pl.when(febl)
        def _zero_d():
            dwh_acc[slot] = jnp.zeros_like(dwh_acc[slot])

        dwh_acc[slot] += jax.lax.dot_general(
            x_ref[...], dpan_s[pj].astype(dt), cdims,
            preferred_element_type=jnp.float32)

        @pl.when(lebl)
        def _store_d():
            dwh_ref[...] = dwh_acc[slot]


def _expert_wstack_t(w, d0p: int, d1p: int):
    """Transposed per-expert tile stacks: (E, D0, D1) -> tiles of W^T,
    expert-major, rel index r*D0B + c over the (D1p, D0p) transpose."""
    e = w.shape[0]
    wq = jnp.pad(w, ((0, 0), (0, d0p - w.shape[1]), (0, d1p - w.shape[2])))
    return jnp.concatenate(
        [_tile_stack(wq[g].T, 128, 128) for g in range(e)])


def grouped_matmul_experts_bwd(xp, dyp, w_in, w_out, w_gate, hinp, gatep,
                               counts, *, activation: str = "silu",
                               bm: int, interpret=True):
    """ONE combined backward launch (dX + dW_in/dW_gate/dW_out) mirroring
    ``grouped_matmul_bwd``, over the per-expert ragged packing.

    ``dyp`` is the packed output cotangent with the router combine weight
    already folded in (dYs = dY * sw — the same cotangent-fold idiom as
    the ReLU mask); ``hinp``/``gatep`` are the forward's saved preacts.
    dW tiles accumulate in VMEM across each expert's consecutive M-blocks
    (zeroed/stored on the DYNAMIC first/last-block-of-expert prefetch
    flags) and come back f32.  There are no expert biases (``moe_init``),
    so the db third of the usual triple is vacuous."""
    e, d, f = w_in.shape
    gated = w_gate is not None
    n_rows = xp.shape[0]
    assert n_rows % bm == 0, (n_rows, bm)
    mbs = n_rows // bm
    dp_, fp_ = _round_up(d, 128), _round_up(f, 128)
    db, fb = dp_ // 128, fp_ // 128
    dt = xp.dtype
    nw = 1 + int(gated)

    x_tiles = _pack_rows(xp, bm, dp_)
    dy_tiles = _pack_rows(dyp.astype(dt), bm, dp_)
    hin_tiles = _pack_rows(hinp, bm, fp_)
    whts = []
    for g in range(e):
        whts.append(_expert_wstack_t(w_in[g:g + 1], dp_, fp_))
        if gated:
            whts.append(_expert_wstack_t(w_gate[g:g + 1], dp_, fp_))
    # per-expert layout [in tiles, gate tiles]: rel = which*FB*DB + j*DB+c
    wht = jnp.concatenate(whts)
    wot = _expert_wstack_t(w_out, fp_, dp_)     # W_out^T tiles: c*FB + j

    tab = _device_table(_plan_tiles_experts_bwd, mbs, db, fb, int(gated))
    dyn = _expert_block_meta(counts, mbs, bm)
    whtpe, wope = nw * fb * db, fb * db

    tile_ix = lambda row: (lambda t, tab, dyn, r=row: (tab[r, t], 0, 0))
    exp_ix = lambda row, s: (lambda t, tab, dyn, r=row, s=s:
                             (dyn[0, tab[EB_BI, t]] * s + tab[r, t], 0, 0))
    in_specs = [
        pl.BlockSpec((None, bm, 128), tile_ix(EB_XT)),       # X
        pl.BlockSpec((None, bm, 128), tile_ix(EB_DYT)),       # dYs
        pl.BlockSpec((None, 128, 128), exp_ix(EB_WHT, whtpe)),  # Wh^T
        pl.BlockSpec((None, 128, 128), exp_ix(EB_WOT, wope)),   # Wout^T
        pl.BlockSpec((None, bm, 128), tile_ix(EB_RES)),       # hin preact
    ]
    ins = [x_tiles, dy_tiles, wht, wot, hin_tiles]
    if gated:
        in_specs.append(pl.BlockSpec((None, bm, 128), tile_ix(EB_RES)))
        ins.append(_pack_rows(gatep, bm, fp_))

    out_shape = [
        jax.ShapeDtypeStruct((mbs * db, bm, 128), dt),           # dX
        jax.ShapeDtypeStruct((e * whtpe, 128, 128), jnp.float32),  # dWh
        jax.ShapeDtypeStruct((e * wope, 128, 128), jnp.float32),  # dWout
    ]
    out_specs = [
        pl.BlockSpec((None, bm, 128), tile_ix(EB_DXOT)),
        pl.BlockSpec((None, 128, 128), exp_ix(EB_DWH, whtpe)),
        pl.BlockSpec((None, 128, 128), exp_ix(EB_DWO, wope)),
    ]
    grid = (mbs * fb * db * (2 + 2 * nw),)
    fn = pl.pallas_call(
        functools.partial(_gmm_experts_bwd_kernel, activation=activation,
                          gated=gated),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2, grid=grid,
            in_specs=in_specs, out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((bm, 128), jnp.float32),
                            pltpu.VMEM((nw * fb, bm, 128), jnp.float32),
                            pltpu.VMEM((fb, bm, 128), jnp.float32),
                            pltpu.VMEM((wope, 128, 128), jnp.float32),
                            pltpu.VMEM((whtpe, 128, 128), jnp.float32)]),
        out_shape=out_shape, interpret=interpret)
    _count_launch("grouped_matmul_experts_bwd")
    dx_t, dwh_t, dwo_t = fn(tab, dyn, *ins)

    dx = _unpack_rows(dx_t, mbs, bm, db, d)

    def _unstack_w(tiles, d0b, d1b, d0, d1):
        w = tiles.reshape(d0b, d1b, 128, 128).transpose(0, 2, 1, 3) \
            .reshape(d0b * 128, d1b * 128)
        return w[:d0, :d1]

    dwin = jnp.stack([_unstack_w(dwh_t[g * whtpe:g * whtpe + db * fb],
                                 db, fb, d, f) for g in range(e)])
    dwgate = None
    if gated:
        dwgate = jnp.stack(
            [_unstack_w(dwh_t[g * whtpe + db * fb:(g + 1) * whtpe],
                        db, fb, d, f) for g in range(e)])
    dwout = jnp.stack([_unstack_w(dwo_t[g * wope:(g + 1) * wope],
                                  fb, db, f, d) for g in range(e)])
    return dx, dwin, dwgate, dwout


def grouped_matmul_experts_ref(xp, swp, w_in, w_out, w_gate, counts, *,
                               activation: str = "silu", bm: int):
    """Per-expert XLA oracle on the packed layout: plain dense dots per
    expert (the same single-k-block f32 accumulation the kernel does for
    D, F <= 128), rows selected by the segment layout, sw row-scale, and
    exact zeros outside every expert's valid segment."""
    e, d, f = w_in.shape
    n_rows = xp.shape[0]
    act = _MOE_ACTS[activation]
    dt = xp.dtype
    offs = expert_row_offsets(counts, bm)
    counts = jnp.asarray(counts, jnp.int32)
    r = jnp.arange(n_rows)[:, None]
    y = jnp.zeros((n_rows, d), dt)
    for g in range(e):
        hin = (xp @ w_in[g])
        if w_gate is not None:
            h = act((xp @ w_gate[g]).astype(dt)) * hin.astype(dt)
        else:
            h = act(hin.astype(dt))
        yg = (h @ w_out[g]).astype(dt) * swp[:, None].astype(dt)
        seg = (r >= offs[g]) & (r < offs[g] + counts[g])
        y = jnp.where(seg, yg, y)
    return y


def grouped_matmul_experts_flops(n_slots: int, e: int, d: int, f: int, *,
                                 gated: bool, bm: int) -> int:
    """FLOPs of the static experts grid — scales with the routed budget
    n_slots plus at most one partial block per expert, NOT E*capacity."""
    mbs = moe_static_blocks(n_slots, e, bm)
    return 2 * mbs * bm * _round_up(d, 128) * _round_up(f, 128) \
        * (2 + int(gated))
