"""Grouped ragged branch GEMM — co-execution without pad-to-max waste.

``branch_matmul`` (the stacked mode) batches G *same-shape* GEMMs on a
branch grid axis and pads heterogeneous widths to a common (K, N) — on
ragged Inception branches most of those MXU tiles multiply zeros.  This
kernel runs G GEMMs with *per-branch* (K_g, N_g) sharing one M (the
spatial-flattened activation rows every branch of a fork reads):

    y_g = epilogue(x_g @ w_g + b_g)          g = 0..G-1
    x_g: (M, K_g)   w_g: (K_g, N_g)   y_g: (M, N_g)

The grid is the *flattened union of every branch's tile grid* — one step
per (branch, row-block, col-block, k-block) — and a scalar-prefetched
int32 offset table (SMEM) tells each step which slots of the packed
operands it touches:

    row 0  xt     slot index into the packed X tile stack (T_x, bm, bk)
    row 1  wt     slot index into the packed W tile stack (T_w, bk, bn)
    row 2  bj     col-block index into the packed bias (1, sum Np_g)
    row 3  first  1 on a tile's first k-step (zero the accumulator)
    row 4  last   1 on a tile's last k-step (epilogue + store)
    row 5  ot     slot index into the packed output tile stack

k-steps of one output tile are consecutive grid steps, so the fp32
accumulator lives in VMEM scratch across them.  The bias + optional ReLU
epilogue is applied in-kernel at the last k-step — branch outputs leave
the kernel finished, with no post-kernel bias/activation round-trip.
Per-branch dims pad only to the 128 lane/sublane alignment, never to the
widest branch: zero pad-to-max-N FLOPs.

Every tensor operand is packed as a (T, block, block) tile stack —
branch g's X tiles occupy slots [xbase_g, xbase_g + mb * nkb_g), its
outputs [obase_g, obase_g + mb * npb_g), and so on — so each grid step
addresses *leading-dim* slots: contiguous for the TPU DMA engine and for
the interpret-mode emulation this repo tests under (block reads/writes
against a (M, sum K) matrix are strided in the lane dim and dominate the
emulated wall time).  Tiling X in and the output back out are pure
layout passes (zero FLOPs), fused by XLA around the kernel.

Like the rest of the zoo this runs under ``interpret=True`` on CPU; the
differentiable wrapper (custom VJP) lives in ``kernels/ops.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _gmm_kernel(tab_ref, x_ref, w_ref, b_ref, o_ref, acc_ref, *, relu: bool):
    t = pl.program_id(0)

    @pl.when(tab_ref[3, t] == 1)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(tab_ref[4, t] == 1)
    def _store():
        y = acc_ref[...] + b_ref[...].astype(jnp.float32)
        if relu:
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.lru_cache(maxsize=512)
def _plan_tiles(m_blocks: int, kbs: tuple[int, ...], nbs: tuple[int, ...]):
    """Offset table for the flattened grid (hashable block counts in,
    (6, T) int32 out) — pure shape bookkeeping, cached across traces."""
    rows: list[list[int]] = [[], [], [], [], [], []]
    noff = xbase = wbase = obase = 0
    for nkb, npb in zip(kbs, nbs):
        for i in range(m_blocks):
            for j in range(npb):
                for kk in range(nkb):
                    rows[0].append(xbase + i * nkb + kk)
                    rows[1].append(wbase + kk * npb + j)
                    rows[2].append(noff + j)
                    rows[3].append(1 if kk == 0 else 0)
                    rows[4].append(1 if kk == nkb - 1 else 0)
                    rows[5].append(obase + i * npb + j)
        noff += npb
        xbase += m_blocks * nkb
        wbase += nkb * npb
        obase += m_blocks * npb
    return np.array(rows, np.int32)


def grouped_matmul(xs, ws, bs=None, *, relu: bool = False, bm: int = 128,
                   bn: int = 128, bk: int = 128, interpret: bool = False):
    """[x_g @ w_g (+ b_g) (+ ReLU)] for ragged (K_g, N_g), one kernel.

    xs: G arrays (M, K_g) — shared M; ws: G arrays (K_g, N_g);
    bs: G arrays (N_g,) or None.  Returns G arrays (M, N_g).
    """
    g = len(xs)
    assert g == len(ws) and g >= 1, (len(xs), len(ws))
    assert bs is None or len(bs) == g
    m = xs[0].shape[0]
    assert all(x.shape[0] == m for x in xs), [x.shape for x in xs]
    assert all(x.shape[1] == w.shape[0] for x, w in zip(xs, ws)), \
        [(x.shape, w.shape) for x, w in zip(xs, ws)]
    mp = _round_up(m, bm)
    mb = mp // bm
    kps = [_round_up(x.shape[1], bk) for x in xs]
    nps = [_round_up(w.shape[1], bn) for w in ws]
    nsum = sum(nps)

    xtiles = []
    for x, kp in zip(xs, kps):
        xp = jnp.pad(x, ((0, mp - m), (0, kp - x.shape[1])))
        xt = xp.reshape(mb, bm, kp // bk, bk).transpose(0, 2, 1, 3)
        xtiles.append(xt.reshape(-1, bm, bk))
    xpk = jnp.concatenate(xtiles, axis=0)
    wtiles = []
    for w, kp, np_ in zip(ws, kps, nps):
        wp = jnp.pad(w, ((0, kp - w.shape[0]), (0, np_ - w.shape[1])))
        wt = wp.reshape(kp // bk, bk, np_ // bn, bn).transpose(0, 2, 1, 3)
        wtiles.append(wt.reshape(-1, bk, bn))
    wpk = jnp.concatenate(wtiles, axis=0).astype(xpk.dtype)
    if bs is None:
        bpk = jnp.zeros((1, nsum), xpk.dtype)
    else:
        bpk = jnp.concatenate(
            [jnp.pad(b, (0, np_ - b.shape[0]))
             for b, np_ in zip(bs, nps)]).reshape(1, nsum).astype(xpk.dtype)

    tab = jnp.asarray(_plan_tiles(
        mb, tuple(kp // bk for kp in kps), tuple(np_ // bn for np_ in nps)))
    o_tiles = mb * sum(np_ // bn for np_ in nps)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(tab.shape[1],),
        in_specs=[
            pl.BlockSpec((None, bm, bk), lambda t, tab: (tab[0, t], 0, 0)),
            pl.BlockSpec((None, bk, bn), lambda t, tab: (tab[1, t], 0, 0)),
            pl.BlockSpec((1, bn), lambda t, tab: (0, tab[2, t])),
        ],
        out_specs=pl.BlockSpec((None, bm, bn),
                               lambda t, tab: (tab[5, t], 0, 0)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_gmm_kernel, relu=relu),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((o_tiles, bm, bn), xs[0].dtype),
        interpret=interpret,
    )(tab, xpk, wpk, bpk)

    outs, obase = [], 0
    for w, np_ in zip(ws, nps):
        npb = np_ // bn
        tiles = out[obase:obase + mb * npb]
        y = tiles.reshape(mb, npb, bm, bn).transpose(0, 2, 1, 3)
        outs.append(y.reshape(mp, np_)[:m, :w.shape[1]])
        obase += mb * npb
    return outs


def grouped_matmul_ref(xs, ws, bs=None, *, relu: bool = False):
    """Per-branch XLA oracle for tests/benchmarks."""
    outs = []
    for i, (x, w) in enumerate(zip(xs, ws)):
        y = jnp.dot(x, w, preferred_element_type=jnp.float32)
        if bs is not None:
            y = y + bs[i].astype(jnp.float32)
        if relu:
            y = jnp.maximum(y, 0.0)
        outs.append(y.astype(x.dtype))
    return outs


def grouped_matmul_flops(shapes, bm: int = 128, bn: int = 128,
                         bk: int = 128) -> tuple[int, int]:
    """(grouped, stacked) MXU FLOPs for branch GEMM shapes [(M, K_g, N_g)]:
    grouped pads per-branch to alignment; stacked additionally pads every
    branch to the widest (K, N) — the waste this kernel removes."""
    ms = {m for m, _, _ in shapes}
    assert len(ms) == 1, shapes
    mp = _round_up(ms.pop(), bm)
    kmax = max(_round_up(k, bk) for _, k, _ in shapes)
    nmax = max(_round_up(n, bn) for _, _, n in shapes)
    grouped = sum(2 * mp * _round_up(k, bk) * _round_up(n, bn)
                  for _, k, n in shapes)
    stacked = len(shapes) * 2 * mp * kmax * nmax
    return grouped, stacked
