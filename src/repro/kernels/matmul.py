"""Tiled matmul Pallas kernels — the "algorithm zoo" for GEMM.

The paper's C3/C4: each op has multiple algorithms with different
time / workspace / resource profiles, and the fastest one is not always the
right one to co-schedule.  We expose three GEMM algorithms:

  mxu128     — 128x128x128 MXU-aligned tiling, fp32 VMEM accumulator,
               zero HBM workspace.  (cuDNN IMPLICIT_GEMM analogue.)
  large_tile — 256x256 output tiles: fewer grid steps / higher VMEM claim,
               zero HBM workspace.  (register-hungry PRECOMP_GEMM analogue:
               "exhausts the static resource".)
  ksplit     — split-K: the K dimension is partitioned across grid cells and
               partial products are written to an HBM workspace of
               ``splits * M * N * 4`` bytes, reduced afterwards.  Trades HBM
               workspace for parallelism on small-M GEMMs.  (FFT/PRECOMP-style
               "big workspace" analogue.)

All kernels require padded inputs (the ``ops.py`` wrappers pad); block sizes
keep the MXU matmul dims multiples of 128 and the accumulator in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    """Accumulating tiled matmul body shared by mxu128/large_tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_tiled(x, y, *, bm: int, bn: int, bk: int, interpret: bool = False):
    """Generic tiled matmul; x:(M,K) y:(K,N) padded to block multiples."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (x.shape, y.shape, (bm, bn, bk))
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)


def _ksplit_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    """Split-K partial-product kernel: grid (split, m, n, k_within_split).

    Each ``split`` writes its partial (bm, bn) product into its own slice of
    the (splits, M, N) HBM workspace output.
    """
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...]


def matmul_ksplit(x, y, *, bm: int, bn: int, bk: int, splits: int,
                  interpret: bool = False):
    """Split-K matmul: HBM workspace of (splits, M, N) fp32 partials."""
    m, k = x.shape
    _, n = y.shape
    assert k % (bk * splits) == 0, (k, bk, splits)
    nk = k // (bk * splits)  # k-blocks per split
    partials = pl.pallas_call(
        functools.partial(_ksplit_kernel, nk=nk),
        grid=(splits, m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((None, bm, bk),
                         lambda s, i, j, kk: (s, i, kk)),
            pl.BlockSpec((None, bk, bn),
                         lambda s, i, j, kk: (s, kk, j)),
        ],
        out_specs=pl.BlockSpec((None, bm, bn), lambda s, i, j, kk: (s, i, j)),
        out_shape=jax.ShapeDtypeStruct((splits, m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(
        x.reshape(m, splits, k // splits).transpose(1, 0, 2),
        y.reshape(splits, k // splits, n),
    )
    return partials.sum(axis=0).astype(x.dtype)


# ---------------------------------------------------------------------------
# Algorithm registry — names mirror the cuDNN-zoo framing of the paper.
# ---------------------------------------------------------------------------

def _alg_mxu128(x, y, interpret=False):
    return matmul_tiled(x, y, bm=128, bn=128, bk=128, interpret=interpret)


def _alg_large_tile(x, y, interpret=False):
    return matmul_tiled(x, y, bm=256, bn=256, bk=128, interpret=interpret)


def _alg_ksplit(x, y, interpret=False, splits: int = 4):
    # Largest split count <= requested that divides the K block count.
    nkb = x.shape[1] // 128
    while splits > 1 and nkb % splits:
        splits -= 1
    return matmul_ksplit(x, y, bm=128, bn=128, bk=128, splits=splits,
                         interpret=interpret)


MATMUL_ALGORITHMS = {
    "mxu128": _alg_mxu128,
    "large_tile": _alg_large_tile,
    "ksplit": _alg_ksplit,
}


def matmul_block_shape(algorithm: str) -> tuple[int, int, int]:
    return {"mxu128": (128, 128, 128),
            "large_tile": (256, 256, 128),
            "ksplit": (128, 128, 128)}[algorithm]


def matmul_workspace_bytes(algorithm: str, m: int, n: int, k: int,
                           splits: int = 4) -> int:
    """HBM workspace per algorithm — the paper's Table-2 quantity."""
    if algorithm == "ksplit":
        return splits * m * n * 4
    return 0


def matmul_vmem_bytes(algorithm: str, bytes_per_el: int = 2) -> int:
    """Static VMEM claim per grid cell — the SM-register/smem analogue."""
    bm, bn, bk = matmul_block_shape(algorithm)
    return bm * bk * bytes_per_el + bk * bn * bytes_per_el + bm * bn * 4
