"""Flash attention Pallas kernel (GQA / causal / sliding-window / softcap).

Algorithm zoo for attention (paper C3/C4 applied to the LM hot-spot):

  flash        — streaming online-softmax Pallas kernel: O(bq*bk) VMEM
                 working set, zero HBM workspace.  Compute-bound at train
                 shapes, HBM-bound at decode.
  materialized — scores matrix materialized in HBM
                 (workspace = B*Hq*Sq*Skv*4 bytes), lowered by XLA.  The
                 "fast but workspace-hungry" cuDNN-FFT analogue; wins for
                 tiny Skv, blocks co-execution for long context.

Layout: q (B, Sq, Hq, D); k, v (B, Skv, Hkv, D); Hq % Hkv == 0 (GQA).
Query position i is aligned to key position i + (Skv - Sq) so the same
kernel serves training (Sq == Skv) and single-token decode (Sq == 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  nk: int, bq: int, bk: int, scale: float, causal: bool,
                  window: int | None, softcap: float | None,
                  sq: int, skv: int):
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    qpos = pl.program_id(2) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0) + (skv - sq)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < skv                            # key padding
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[:, :1]                        # (bq, 1)
    l_prev = l_ref[:, :1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)   # (bq, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                       # masked lanes: exp(-inf)=0
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nk - 1)
    def _store():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)          # fully-masked rows -> 0 out
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    softcap: float | None = None, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    bq = min(block_q, max(sq, 8))
    bk = min(block_k, max(skv, 128))
    sq_p = -(-sq // bq) * bq
    skv_p = -(-skv // bk) * bk
    qt = jnp.pad(q.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    kt = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    vt = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    nq, nk = sq_p // bq, skv_p // bk

    out = pl.pallas_call(
        functools.partial(_flash_kernel, nk=nk, bq=bq, bk=bk, scale=scale,
                          causal=causal, window=window, softcap=softcap,
                          sq=sq, skv=skv),
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, i, j: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, i, j, group=group: (bb, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bb, h, i, j, group=group: (bb, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, h, i, j: (bb, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq_p, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (lane-bcast)
            pltpu.VMEM((bq, 128), jnp.float32),   # running denom
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :sq].transpose(0, 2, 1, 3)


def attention_materialized(q, k, v, *, causal: bool = True,
                           window: int | None = None,
                           softcap: float | None = None,
                           scale: float | None = None):
    """XLA-lowered materialized-scores algorithm (big HBM workspace)."""
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, group, d)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    qpos = jnp.arange(sq) + (skv - sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


ATTENTION_ALGORITHMS = {
    "flash": flash_attention,
    "materialized": lambda q, k, v, interpret=False, **kw:
        attention_materialized(q, k, v, **kw),
}


def attention_workspace_bytes(algorithm: str, b, sq, skv, hq) -> int:
    if algorithm == "materialized":
        return b * hq * sq * skv * 4
    return 0
