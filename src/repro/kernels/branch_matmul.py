"""Stacked independent GEMMs — the intra-chip co-execution primitive.

The paper's intra-SM partitioning shares one SM between blocks of different
kernels.  A TPU core cannot time-share two ``pallas_call``s, so the analogue
is *batching*: G independent same-shape branch GEMMs (Inception branch
projections, MoE experts, Winograd's 16 pointwise GEMMs) are stacked into a
single kernel with a leading grid axis.  The chip then pipelines HBM loads of
branch g+1 under the MXU work of branch g — the memory stalls of one branch
hidden by the compute of another, which is exactly the paper's Table-1
complementarity argument, realized through the TPU's (automatic) DMA/compute
overlap instead of warp scheduling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bmm_kernel(x_ref, y_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def branch_matmul(x, y, *, bm: int = 128, bn: int = 128, bk: int = 128,
                  interpret: bool = False):
    """x: (G, M, K), y: (G, K, N) -> (G, M, N); one fused grid over branches."""
    g, m, k = x.shape
    g2, k2, n = y.shape
    assert g == g2 and k == k2, (x.shape, y.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_bmm_kernel, nk=nk),
        grid=(g, m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((None, bm, bk), lambda gg, i, j, kk: (gg, i, kk)),
            pl.BlockSpec((None, bk, bn), lambda gg, i, j, kk: (gg, kk, j)),
        ],
        out_specs=pl.BlockSpec((None, bm, bn), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)
