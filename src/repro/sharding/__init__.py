from repro.sharding.specs import (  # noqa: F401
    activations_on, constrain, param_specs, data_spec, logical_axes,
)
