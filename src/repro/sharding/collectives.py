"""Compute/communication overlap primitives (shard_map + ppermute rings).

GSPMD emits all-gather/reduce-scatter as monolithic ops that serialize with
compute.  These ring variants split the collective into per-step chunks and
interleave a partial matmul with each ``ppermute`` hop — the standard
"collective matmul" (Wang et al.) that hides TP communication under MXU
work.  They are the §Perf levers for the collective-bound cells.

  matmul_allgather_x(x_local, w_local, axis):
      y = allgather_M(x) @ w       (x row-sharded on M, w col-sharded on N)
      overlap: each ring step matmuls the chunk that just arrived.
  matmul_reducescatter(x_local, w_full_rows, axis):
      y_scattered = reduce_scatter_M(x_partial @ w)  done chunkwise so the
      partial-sum hop overlaps the next chunk's matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _ring_allgather_matmul_local(x_local, w_local, *, axis: str, p: int):
    """Per-device body: x_local (m, K), w_local (K, n_local).
    Computes allgather(x) @ w_local => (M, n_local), overlapped.
    ``p`` is the static ring size (mesh.shape[axis] — jax.lax.axis_size is
    not available on older jax, and the perm lists need a Python int)."""
    idx = jax.lax.axis_index(axis)
    m = x_local.shape[0]

    def step(carry, _):
        buf, out, i = carry
        # compute with the chunk currently held (originated at idx - i)
        src = (idx - i) % p
        partial = buf @ w_local                       # (m, n_local)
        out = jax.lax.dynamic_update_slice(out, partial, (src * m, 0))
        # pass the chunk along the ring (overlaps next matmul on TPU)
        buf = jax.lax.ppermute(buf, axis,
                               [(j, (j + 1) % p) for j in range(p)])
        return (buf, out, i + 1), None

    out0 = jnp.zeros((m * p, w_local.shape[1]), x_local.dtype)
    (buf, out, _), _ = jax.lax.scan(step, (x_local, out0, 0), None, length=p)
    return out


def matmul_allgather_x(x, w, mesh, axis: str = "model"):
    """x: (M, K) sharded on M over ``axis``; w: (K, N) sharded on N.
    Returns (M, N) sharded on N (replicated on M)."""
    from jax.experimental.shard_map import shard_map
    fn = shard_map(
        functools.partial(_ring_allgather_matmul_local, axis=axis,
                          p=mesh.shape[axis]),
        mesh=mesh,
        in_specs=(P(axis, None), P(None, axis)),
        out_specs=P(None, axis), check_rep=False)
    return fn(x, w)


def _ring_reducescatter_matmul_local(x_local, w_local, *, axis: str,
                                     p: int):
    """Per-device body: x_local (M, k_local) k-sharded, w_local (k_local, N).
    y = reduce-scatter_M( sum_k x_k @ w_k ): returns (M/p, N) shard."""
    idx = jax.lax.axis_index(axis)
    m = x_local.shape[0]
    ms = m // p

    def step(carry, i):
        acc, _ = carry
        # the accumulator currently held here is homed at (idx - i): add
        # this device's contribution to that output shard
        dst = (idx - i) % p
        xc = jax.lax.dynamic_slice(x_local, (dst * ms, 0),
                                   (ms, x_local.shape[1]))
        partial = xc @ w_local                         # (ms, N)
        acc = acc + partial
        acc_next = jax.lax.ppermute(
            acc, axis, [(j, (j + 1) % p) for j in range(p)])
        return (acc_next, 0), None

    acc0 = jnp.zeros((ms, w_local.shape[1]),
                     jnp.promote_types(x_local.dtype, jnp.float32))
    (acc, _), _ = jax.lax.scan(step, (acc0, 0), jnp.arange(p))
    return acc.astype(x_local.dtype)


def matmul_reducescatter(x, w, mesh, axis: str = "model"):
    """x: (M, K) sharded on K over ``axis``; w: (K, N) sharded on K.
    Returns y = x @ w reduce-scattered over M: (M, N) with M sharded."""
    from jax.experimental.shard_map import shard_map
    fn = shard_map(
        functools.partial(_ring_reducescatter_matmul_local, axis=axis,
                          p=mesh.shape[axis]),
        mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(axis, None), check_rep=False)
    return fn(x, w)
