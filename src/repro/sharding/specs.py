"""Sharding rules: DP / FSDP(ZeRO) / TP / EP specs for params + activations.

Logical axes:
  dp  — batch:   all of ("pod", "data") present in the mesh.
  fsdp— params:  the "data" axis only (params replicate across pods; the pod
                 axis carries gradient all-reduce over DCN — one collective
                 per step instead of per-layer all-gathers across pods).
  tp  — model:   the "model" axis (heads / ffn / experts / vocab).

Every rule applies an axis only when the dim is divisible by the axis size
for *param* specs (in_shardings must match exactly); activation constraints
are always applied (GSPMD pads unevenly-sharded dims transparently).
"""
from __future__ import annotations

import threading

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX = threading.local()


class activations_on:
    """Context manager activating activation sharding constraints.

    perf options (the §Perf hillclimb levers, all default-off = baseline):
      seq_shard     — sequence-parallel residuals: constrain (B, S, D)
                      activations P(dp, tp, None) so TP boundary collectives
                      become reduce-scatter/all-gather pairs.
      dp_over_model — treat the model axis as extra data parallelism
                      (params replicated, batch sharded over data x model):
                      the right scheme for small models on a big pod.
      causal_skip   — triangular chunked attention (skip fully-masked kv
                      chunks): ~2x attention FLOP reduction for causal train.
    """

    def __init__(self, mesh: Mesh | None, **perf):
        self.mesh = mesh
        self.perf = perf

    def __enter__(self):
        self.prev = getattr(_CTX, "mesh", None)
        self.prev_perf = getattr(_CTX, "perf", {})
        _CTX.mesh = self.mesh
        _CTX.perf = self.perf
        return self.mesh

    def __exit__(self, *exc):
        _CTX.mesh = self.prev
        _CTX.perf = self.prev_perf
        return False


def perf_option(name: str, default=False):
    return getattr(_CTX, "perf", {}).get(name, default)


def logical_axes(mesh: Mesh, logical: str):
    names = mesh.axis_names
    # dp_over_model: params replicated, model axis = extra data parallelism.
    # zero3: same batch layout but params/opt fully sharded over
    # (data, model) with per-layer all-gather (ZeRO-3 / pure-FSDP).
    flat_dp = perf_option("dp_over_model") or perf_option("zero3")
    if logical == "dp":
        order = ("pod", "data", "model") if flat_dp else ("pod", "data")
        axes = tuple(a for a in order if a in names)
        return axes if axes else None
    if logical == "fsdp":
        if perf_option("zero3"):
            axes = tuple(a for a in ("data", "model") if a in names)
            return axes if axes else None
        if perf_option("dp_over_model") or perf_option("no_fsdp"):
            return None   # no_fsdp: serving keeps params TP-only (no
            # per-layer all-gathers on the decode path)
        return "data" if "data" in names else None
    if logical == "tp":
        if flat_dp:
            return None
        return "model" if "model" in names else None
    if logical == "sp":       # sequence-parallel residual axis
        if flat_dp or not perf_option("seq_shard"):
            return None
        return "model" if "model" in names else None
    return None


def constrain(x, *dims: str | None):
    """with_sharding_constraint by logical axis names; no-op without mesh.
    Axes are applied only when the dim divides evenly (e.g. 8 kv heads on a
    16-way model axis stay replicated rather than padded)."""
    mesh = getattr(_CTX, "mesh", None)
    if mesh is None:
        return x
    assert len(dims) == x.ndim, (dims, x.shape)
    spec = []
    for d, size in zip(dims, x.shape):
        ax = logical_axes(mesh, d) if d else None
        if ax is not None:
            n = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                n *= mesh.shape[a]
            if size % n != 0:
                ax = None
        spec.append(ax)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def data_spec(mesh: Mesh, ndim: int) -> NamedSharding:
    """Batch-leading arrays: shard dim0 over dp."""
    return NamedSharding(mesh, P(logical_axes(mesh, "dp"),
                                 *([None] * (ndim - 1))))


# ---------------------------------------------------------------------------
# parameter specs by leaf name
# ---------------------------------------------------------------------------

def _div(shape, i, mesh, ax):
    if ax is None:
        return None
    axes = (ax,) if isinstance(ax, str) else tuple(ax)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return ax if shape[i] % size == 0 else None


def _leaf_spec(path: tuple[str, ...], shape, mesh: Mesh, fsdp: bool):
    tp = logical_axes(mesh, "tp")
    fa = logical_axes(mesh, "fsdp") if fsdp else None
    name = path[-1]
    stacked = 1 if "blocks" in path else 0      # leading n_super dim
    nd = len(shape)
    spec = [None] * nd
    moe = "moe" in path and "shared" not in path

    def setd(i, ax):
        spec[i] = _div(shape, i, mesh, ax)

    if name in ("scale", "bias", "A_log", "D", "dt_bias", "conv_b",
                "bq", "bk", "bv") or nd - stacked <= 1:
        pass
    elif name == "table":
        setd(0, tp)
    elif name == "router":
        setd(nd - 2, fa)
    elif name == "conv_w":
        setd(nd - 1, tp)
    elif name in ("wq", "wk", "wv", "w_in", "w_gate"):
        if moe and nd - stacked == 3:           # (E, D, F)
            if tp and shape[stacked] % mesh.shape[tp] == 0:
                setd(stacked, tp)               # EP
                setd(nd - 2, fa)
            else:
                setd(nd - 2, fa)
                setd(nd - 1, tp)                # TP inside expert
        else:
            setd(nd - 2, fa)
            setd(nd - 1, tp)
    elif name in ("wo", "w_out"):
        if moe and nd - stacked == 3:           # (E, F, D)
            if tp and shape[stacked] % mesh.shape[tp] == 0:
                setd(stacked, tp)
                setd(nd - 1, fa)
            else:
                setd(nd - 2, tp)
                setd(nd - 1, fa)
        else:
            setd(nd - 2, tp)
            setd(nd - 1, fa)
    else:                                       # unknown 2D+: fsdp last dim
        setd(nd - 1, fa)
    return P(*spec)


def param_specs(params, mesh: Mesh, fsdp: bool = True):
    """Pytree of NamedSharding mirroring ``params``."""

    def walk(path, node):
        if isinstance(node, dict):
            return {k: walk(path + (k,), v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            out = [walk(path + (str(i),), v) for i, v in enumerate(node)]
            return type(node)(out)
        return NamedSharding(mesh, _leaf_spec(path, node.shape, mesh, fsdp))

    return walk((), params)


def named(mesh: Mesh, *axes) -> NamedSharding:
    return NamedSharding(mesh, P(*axes))
