.PHONY: test bench bench-smoke

test:
	./scripts/ci.sh

bench:
	python benchmarks/run.py

# Seconds-scale benchmark smoke (tiny batch, few reps): keeps the benchmark
# code paths compiling and running between PRs without the full run's cost.
# Writes BENCH_plan.smoke.json, never the committed BENCH_plan.json baseline.
bench-smoke:
	python benchmarks/run.py --smoke
