.PHONY: test bench

test:
	./scripts/ci.sh

bench:
	python benchmarks/run.py
