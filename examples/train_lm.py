"""End-to-end LM training driver example (deliverable (b) end-to-end).

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch llama3-8b]

Trains a reduced-config LM for a few hundred steps on the synthetic Markov
stream with checkpointing, then resumes for a few more steps to prove exact
restart — the same ``launch/train.py`` driver that runs the full configs on
a production mesh.
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    rc = train_mod.main(["--arch", args.arch, "--reduced",
                         "--steps", str(args.steps),
                         "--batch", str(args.batch), "--seq", str(args.seq),
                         "--lr", "3e-3", "--ckpt-dir", ckpt,
                         "--ckpt-every", str(max(args.steps // 4, 1)),
                         "--log-every", "25"])
    assert rc == 0
    print("\n-- resume for 20 more steps (fault-tolerance path) --")
    rc = train_mod.main(["--arch", args.arch, "--reduced",
                         "--steps", str(args.steps + 20),
                         "--batch", str(args.batch), "--seq", str(args.seq),
                         "--lr", "3e-3", "--ckpt-dir", ckpt, "--resume",
                         "--log-every", "10"])
    assert rc == 0


if __name__ == "__main__":
    main()
