"""MoE experts as the paper's branches: spatial partitioning at mesh scale.

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python examples/moe_expert_parallel.py

Experts ARE independent branches (DESIGN.md §4): this example shards the
granite-MoE reduced config over a (data=4, model=2) mesh — expert weights
partitioned over the ``model`` axis (the inter-SM partitioning analogue) —
and shows (a) identical loss to single-device execution, (b) the collective
schedule GSPMD emits for the fork (dispatch) and join (combine), and (c) a
few training steps under the production step function.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.launch import steps as ST
from repro.models import transformer as T
from repro.sharding import param_specs, specs as SH


def main():
    cfg = get_reduced("granite_moe_1b_a400m")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = ST.make_optimizer(cfg)
    state = opt.init(params)
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (8, 64), 0, cfg.vocab)}
    batch["labels"] = batch["tokens"]
    fn = ST.make_train_step(cfg, opt, remat=False)

    # single device reference
    _, _, m_ref = jax.jit(fn)(params, state, batch)
    print(f"[1] single-device loss = {float(m_ref['loss']):.5f}")

    # expert-parallel mesh
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    with SH.activations_on(mesh):
        ps = param_specs(params, mesh)
        spec = jax.tree.leaves(
            ps["blocks"][0]["moe"], is_leaf=lambda x: hasattr(x, "spec"))[1]
        print(f"[2] expert w_in spec (E sharded over 'model'): {spec.spec}")
        params_sh = jax.device_put(params, ps)
        state_sh = {"step": state["step"],
                    "m": jax.device_put(state["m"], ps),
                    "v": jax.device_put(state["v"], ps)}
        batch_sh = jax.device_put(batch,
                                  ST.batch_shardings(cfg, mesh, batch))
        jitted = jax.jit(fn)
        lowered = jitted.lower(params_sh, state_sh, batch_sh)
        hlo = lowered.compile().as_text()
        colls = {}
        for kind in ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute"):
            n = hlo.count(f" {kind}(")
            if n:
                colls[kind] = n
        print(f"[3] collective schedule for the fork/join: {colls}")

        p, s, m = jitted(params_sh, state_sh, batch_sh)
        print(f"[4] expert-parallel loss = {float(m['loss']):.5f} "
              f"(matches: {abs(float(m['loss']) - float(m_ref['loss'])) < 1e-2})")
        for i in range(5):
            p, s, m = jitted(p, s, batch_sh)
        print(f"[5] after 5 EP steps: loss={float(m['loss']):.5f}, "
              f"drop-free dispatch, grad_norm={float(m['grad_norm']):.3f}")


if __name__ == "__main__":
    main()
