"""Batched serving with a KV cache across architecture families.

  PYTHONPATH=src python examples/serve_decode.py

Runs prefill + greedy decode for a dense (llama3), a hybrid (jamba: KV
cache + SSM state + conv tail), and an encoder-decoder (whisper: cross
attention) reduced config — the same ``decode_step`` the decode_32k /
long_500k dry-run cells lower at production shapes.
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models import transformer as T


def serve(arch: str, prompt_len=16, gen=16, batch=2):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    total = prompt_len + gen
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (batch, prompt_len), 0, cfg.vocab)
    cache = T.init_cache(cfg, batch, total)
    extra = ctx = None
    if cfg.enc_dec:
        extra = 0.02 * jax.random.normal(
            jax.random.fold_in(key, 2),
            (batch, cfg.enc_context_len, cfg.d_model))
        ctx = jax.jit(lambda p, e: T._encoder(cfg, p, e))(params, extra)

    prefill = jax.jit(lambda p, t, c: T.prefill(p, cfg, t, c,
                                                extra_embeds=extra))
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, cfg, c, t, pos,
                                                        context=ctx))
    logits, cache = prefill(params, prompts, cache)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(gen - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
        out.append(tok)
    dt = (time.time() - t0) / (gen - 1) * 1e3
    toks = jnp.concatenate(out, axis=1)
    cache_kinds = sorted({k for c in cache for k in c})
    print(f"{cfg.name:24s} cache={cache_kinds} {dt:7.1f} ms/tok  "
          f"sample={toks[0, :8].tolist()}")
    assert bool((toks >= 0).all()) and bool((toks < cfg.vocab).all())


def main():
    print("arch                      cache kinds        latency    tokens")
    serve("llama3_8b")            # dense GQA: kv cache
    serve("jamba_1_5_large_398b")  # hybrid: kv + ssm + conv states
    serve("whisper_tiny")         # enc-dec: cross-attention context


if __name__ == "__main__":
    main()
