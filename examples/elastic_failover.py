"""Elastic failover demo: checkpoint on one topology, resume on another.

  PYTHONPATH=src python examples/elastic_failover.py

Simulates the 1000-node failure path (DESIGN.md §6):
  1. train 15 steps single-device, checkpoint at 10 (atomic publish);
  2. "pod dies" — restart in a fresh 8-device process, restore the SAME
     checkpoint onto a (4 data x 2 model) mesh via elastic re-placement
     (checkpoints are stored unsharded; restore = device_put against the
     new specs), data pipeline resumes at the exact step;
  3. verify the restored sharded step produces the same loss trajectory as
     an uninterrupted single-device run.
"""
import os
import subprocess
import sys
import tempfile
import textwrap

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

REPO = os.path.join(os.path.dirname(__file__), "..")


def main():
    ckpt = tempfile.mkdtemp(prefix="repro_elastic_")

    print("== phase 1: train on topology A (1 device), checkpoint ==")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "llama3-8b",
         "--reduced", "--steps", "10", "--batch", "8", "--seq", "64",
         "--lr", "3e-3", "--ckpt-dir", ckpt, "--ckpt-every", "10",
         "--log-every", "5"],
        env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr
    print(r.stdout.strip().splitlines()[-1])

    print("== phase 2: 'failure' -> restore on topology B (4x2 mesh) ==")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.checkpoint import CheckpointManager
        from repro.configs import get_reduced
        from repro.data import Pipeline, SyntheticLM
        from repro.launch import steps as ST
        from repro.models import transformer as T
        from repro.sharding import specs as SH, param_specs

        cfg = get_reduced("llama3-8b")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        opt = ST.make_optimizer(cfg)
        opt = type(opt)(**{**opt.__dict__, "lr": 3e-3, "warmup": 1,
                           "total": 20})
        state = opt.init(params)
        mgr = CheckpointManager("%s")
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((4, 2), ("data", "model"))
        ps = param_specs(params, mesh)
        sh = {"params": ps,
              "opt": {"step": None, "m": ps, "v": ps},
              "data": {"step": None}}
        template = {"params": params, "opt": state,
                    "data": {"step": np.zeros((), np.int64)}}
        restored, manifest = mgr.restore(template, sharding=sh)
        print("restored at step", manifest["step"], "onto",
              dict(zip(mesh.axis_names, mesh.devices.shape)))
        pipe = Pipeline(SyntheticLM(cfg.vocab, 64, 8, seed=0))
        pipe.restore({"step": int(restored["data"]["step"])})
        fn = jax.jit(ST.make_train_step(cfg, opt, remat=False))
        p, s = restored["params"], restored["opt"]
        with SH.activations_on(mesh):
            for i in range(5):
                batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
                batch = jax.device_put(
                    batch, ST.batch_shardings(cfg, mesh, batch))
                p, s, m = fn(p, s, batch)
                print(f"  elastic step {manifest['step']+i+1}: "
                      f"loss={float(m['loss']):.4f}")
        print("ELASTIC RESUME OK")
    """ % ckpt)
    env2 = dict(env, XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run([sys.executable, "-c", code], env=env2,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr
    print(r.stdout.strip())
    assert "ELASTIC RESUME OK" in r.stdout


if __name__ == "__main__":
    main()
