"""Quickstart: the paper's pipeline end to end, in five steps.

  PYTHONPATH=src python examples/quickstart.py

1. Build the op graph of a non-linear network (GoogleNet inception head).
2. Profile each op's algorithm zoo (the cuDNN-table analogue).
3. Schedule: serial/fastest (TF r1.10 policy) vs concurrency-aware
   co-execution (the paper's proposal).
4. Execute one inception module with scheduler-chosen Pallas kernel
   algorithms and check it against plain XLA.
5. Train the reduced GoogleNet for a few steps on synthetic data.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.core import compare_policies, profile, supported_algorithms
from repro.data import Pipeline, SyntheticImages
from repro.models import cnn as CNN
from repro.optim import AdamW


def main():
    # 1-2: graph + per-op algorithm profiles --------------------------------
    cfg_full = get_config("googlenet")
    g = CNN.build_graph(cfg_full, batch=32)
    print(f"[1] GoogleNet op graph: {len(g)} ops, "
          f"{len(g.independent_sets())} independent sets (C1)")
    op = g.ops["inc0/5x5"]
    print("[2] algorithm zoo for", op.name)
    for alg in supported_algorithms(op):
        pr = profile(op, alg)
        print(f"     {alg:12s} modeled={pr.time*1e6:8.1f}us "
              f"workspace={pr.workspace_bytes/1e6:7.1f}MB bound={pr.bound}")

    # 3: scheduling policies --------------------------------------------------
    res = compare_policies(g)
    print(f"[3] serial(fastest-per-op) makespan = "
          f"{res['serial_makespan']*1e3:.2f} ms ; concurrent = "
          f"{res['concurrent_makespan']*1e3:.2f} ms ; "
          f"speedup = {res['speedup']:.3f}x")

    # 4: kernel execution with scheduled algorithms --------------------------
    cfg = get_reduced("googlenet")
    algs, _ = CNN.schedule_algorithms(cfg, batch=2)
    params = CNN.init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, *cfg.img))
    y_kernels = CNN.forward(params, cfg, x, algorithms=algs)
    y_xla = CNN.forward(params, cfg, x)
    err = float(jnp.abs(y_kernels - y_xla).max())
    print(f"[4] scheduler-chosen Pallas kernels vs XLA: max|diff| = {err:.2e}")

    # 5: a short training run -------------------------------------------------
    src = SyntheticImages(cfg.img, cfg.num_classes, global_batch=16)
    pipe = Pipeline(src)
    opt = AdamW(lr=3e-3, warmup=5, total=60, weight_decay=0.0)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, _), grads = jax.value_and_grad(
            CNN.loss_fn, has_aux=True)(params, cfg, batch)
        params, state, info = opt.update(grads, state, params)
        return params, state, loss

    losses = []
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, state, loss = step(params, state, batch)
        losses.append(float(loss))
        if (i + 1) % 20 == 0:
            print(f"[5] step {i+1:3d} loss={losses[-1]:.4f}")
    assert losses[-1] < losses[0], "training did not improve"
    print(f"[5] GoogleNet-reduced: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          "(improved)")


if __name__ == "__main__":
    main()
